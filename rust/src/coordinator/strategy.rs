//! Data-describable operational strategies: a [`StrategySpec`] names a
//! strategy and carries its numeric parameters, and registries of
//! constructors turn specs into live [`Scheduler`] / [`RetrainTrigger`]
//! objects.
//!
//! This is the surface that makes strategies sweepable without
//! recompiling: a spec round-trips through JSON (`util::jsonio`), rides
//! inside `ExperimentConfig`, and is parsed from CLI grids
//! (`sweep --schedulers fifo,edf:slack_per_class=900`). Custom strategies
//! register at startup via [`register_scheduler`] / [`register_trigger`]
//! / [`register_placer`] / [`register_retry_policy`] and are then
//! selectable exactly like built-ins.

use std::sync::{OnceLock, RwLock};

use crate::des::place::{CheapestFit, FastestFit, Pack, Placer, Spread};
use crate::des::retry::{
    AlwaysRetry, DeadlineAwareRetry, ExpBackoffRetry, FixedRetry, RetryPolicy,
};
use crate::des::sched::{
    EarliestDeadlineFirst, EasyBackfill, Fifo, PreemptivePriority, Priority, RestartFirst,
    Scheduler, ShortestJobFirst, WeightedFair,
};
use crate::error::{Error, Result};

use super::triggers::{
    DriftThreshold, Eager, Never, OffPeak, PerformanceFloor, Periodic, RetrainTrigger,
};

/// A named operational strategy with numeric parameters — the
/// JSON-loadable description of a scheduler or retraining trigger.
///
/// JSON form: `{"name": "edf", "params": {"slack_per_class": 900}}`, or a
/// bare string `"fifo"` when there are no parameters. CLI form:
/// `edf:slack_per_class=900` (segments separated by `:`).
#[derive(Clone, Debug, PartialEq)]
pub struct StrategySpec {
    pub name: String,
    /// Parameter key/value pairs, in declaration order.
    pub params: Vec<(String, f64)>,
}

impl StrategySpec {
    pub fn new(name: impl Into<String>) -> Self {
        StrategySpec {
            name: name.into(),
            params: Vec::new(),
        }
    }

    /// Builder-style parameter.
    pub fn with(mut self, key: impl Into<String>, value: f64) -> Self {
        self.params.push((key.into(), value));
        self
    }

    pub fn get(&self, key: &str) -> Option<f64> {
        self.params
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| *v)
    }

    pub fn get_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).unwrap_or(default)
    }

    /// Reject parameters outside `allowed` — constructors call this so a
    /// typoed key fails loudly instead of silently using a default.
    pub fn check_keys(&self, allowed: &[&str]) -> Result<()> {
        for (k, _) in &self.params {
            if !allowed.contains(&k.as_str()) {
                return Err(Error::Config(format!(
                    "strategy '{}': unknown param '{}' (allowed: {})",
                    self.name,
                    k,
                    if allowed.is_empty() {
                        "none".to_string()
                    } else {
                        allowed.join(", ")
                    }
                )));
            }
        }
        Ok(())
    }

    /// Parse the CLI form: `name` or `name:key=value:key=value`.
    pub fn parse(text: &str) -> Result<Self> {
        let mut parts = text.split(':');
        let name = parts.next().unwrap_or("").trim();
        if name.is_empty() {
            return Err(Error::Config(format!("empty strategy spec '{text}'")));
        }
        let mut spec = StrategySpec::new(name);
        for p in parts {
            let (k, v) = p.split_once('=').ok_or_else(|| {
                Error::Config(format!("strategy param '{p}' must be key=value"))
            })?;
            let value: f64 = v.trim().parse().map_err(|_| {
                Error::Config(format!("strategy param '{k}': bad number '{v}'"))
            })?;
            spec.params.push((k.trim().to_string(), value));
        }
        Ok(spec)
    }

    /// Compact label for sweep group names and tables: the CLI form.
    pub fn label(&self) -> String {
        if self.params.is_empty() {
            return self.name.clone();
        }
        let mut s = self.name.clone();
        for (k, v) in &self.params {
            s.push(':');
            s.push_str(k);
            s.push('=');
            s.push_str(&format!("{v}"));
        }
        s
    }
}

/// Constructor turning a spec into a live scheduler.
pub type SchedulerCtor = fn(&StrategySpec) -> Result<Box<dyn Scheduler>>;
/// Constructor turning a spec into a live retraining trigger.
pub type TriggerCtor = fn(&StrategySpec) -> Result<Box<dyn RetrainTrigger>>;

fn ctor_fifo(spec: &StrategySpec) -> Result<Box<dyn Scheduler>> {
    spec.check_keys(&[])?;
    Ok(Box::new(Fifo))
}
fn ctor_priority(spec: &StrategySpec) -> Result<Box<dyn Scheduler>> {
    spec.check_keys(&[])?;
    Ok(Box::new(Priority))
}
fn ctor_sjf(spec: &StrategySpec) -> Result<Box<dyn Scheduler>> {
    spec.check_keys(&[])?;
    Ok(Box::new(ShortestJobFirst))
}
fn ctor_edf(spec: &StrategySpec) -> Result<Box<dyn Scheduler>> {
    spec.check_keys(&["slack_per_class"])?;
    Ok(Box::new(EarliestDeadlineFirst {
        slack_per_class: spec.get_or("slack_per_class", 1800.0),
    }))
}
fn ctor_weighted_fair(spec: &StrategySpec) -> Result<Box<dyn Scheduler>> {
    spec.check_keys(&["weight_power"])?;
    Ok(Box::new(WeightedFair::new(spec.get_or("weight_power", 1.0))))
}
fn ctor_preemptive_priority(spec: &StrategySpec) -> Result<Box<dyn Scheduler>> {
    spec.check_keys(&["min_class_gap"])?;
    Ok(Box::new(PreemptivePriority {
        min_class_gap: spec.get_or("min_class_gap", 1.0),
    }))
}
fn ctor_easy_backfill(spec: &StrategySpec) -> Result<Box<dyn Scheduler>> {
    spec.check_keys(&[])?;
    Ok(Box::new(EasyBackfill::default()))
}
fn ctor_restart_first(spec: &StrategySpec) -> Result<Box<dyn Scheduler>> {
    spec.check_keys(&["restart_boost"])?;
    Ok(Box::new(RestartFirst {
        restart_boost: spec.get_or("restart_boost", 1e6),
    }))
}

const BUILTIN_SCHEDULERS: &[(&str, SchedulerCtor)] = &[
    ("fifo", ctor_fifo),
    ("priority", ctor_priority),
    ("sjf", ctor_sjf),
    ("edf", ctor_edf),
    ("weighted_fair", ctor_weighted_fair),
    ("preemptive_priority", ctor_preemptive_priority),
    ("easy_backfill", ctor_easy_backfill),
    ("restart_first", ctor_restart_first),
];

fn ctor_eager(spec: &StrategySpec) -> Result<Box<dyn RetrainTrigger>> {
    spec.check_keys(&[])?;
    Ok(Box::new(Eager))
}
fn ctor_never(spec: &StrategySpec) -> Result<Box<dyn RetrainTrigger>> {
    spec.check_keys(&[])?;
    Ok(Box::new(Never))
}
fn ctor_drift_threshold(spec: &StrategySpec) -> Result<Box<dyn RetrainTrigger>> {
    spec.check_keys(&["threshold"])?;
    Ok(Box::new(DriftThreshold {
        threshold: spec.get_or("threshold", 0.05),
    }))
}
fn ctor_off_peak(spec: &StrategySpec) -> Result<Box<dyn RetrainTrigger>> {
    spec.check_keys(&["threshold", "max_intensity"])?;
    Ok(Box::new(OffPeak {
        threshold: spec.get_or("threshold", 0.05),
        max_intensity: spec.get_or("max_intensity", 0.5),
    }))
}
fn ctor_performance_floor(spec: &StrategySpec) -> Result<Box<dyn RetrainTrigger>> {
    spec.check_keys(&["floor"])?;
    Ok(Box::new(PerformanceFloor {
        floor: spec.get_or("floor", 0.7),
    }))
}
fn ctor_periodic(spec: &StrategySpec) -> Result<Box<dyn RetrainTrigger>> {
    spec.check_keys(&["interval"])?;
    Ok(Box::new(Periodic {
        interval: spec.get_or("interval", 7.0 * 86_400.0),
    }))
}

const BUILTIN_TRIGGERS: &[(&str, TriggerCtor)] = &[
    ("eager", ctor_eager),
    ("never", ctor_never),
    ("drift_threshold", ctor_drift_threshold),
    ("off_peak", ctor_off_peak),
    ("performance_floor", ctor_performance_floor),
    ("periodic", ctor_periodic),
];

/// Constructor turning a spec into a live placement strategy.
pub type PlacerCtor = fn(&StrategySpec) -> Result<Box<dyn Placer>>;

fn ctor_fastest_fit(spec: &StrategySpec) -> Result<Box<dyn Placer>> {
    spec.check_keys(&[])?;
    Ok(Box::new(FastestFit))
}
fn ctor_cheapest_fit(spec: &StrategySpec) -> Result<Box<dyn Placer>> {
    spec.check_keys(&[])?;
    Ok(Box::new(CheapestFit))
}
fn ctor_pack(spec: &StrategySpec) -> Result<Box<dyn Placer>> {
    spec.check_keys(&[])?;
    Ok(Box::new(Pack))
}
fn ctor_spread(spec: &StrategySpec) -> Result<Box<dyn Placer>> {
    spec.check_keys(&[])?;
    Ok(Box::new(Spread))
}

const BUILTIN_PLACERS: &[(&str, PlacerCtor)] = &[
    ("fastest_fit", ctor_fastest_fit),
    ("cheapest_fit", ctor_cheapest_fit),
    ("pack", ctor_pack),
    ("spread", ctor_spread),
];

/// Constructor turning a spec into a live retry policy.
pub type RetryCtor = fn(&StrategySpec) -> Result<Box<dyn RetryPolicy>>;

fn ctor_always(spec: &StrategySpec) -> Result<Box<dyn RetryPolicy>> {
    spec.check_keys(&["delay"])?;
    Ok(Box::new(AlwaysRetry::new(spec.get_or("delay", 0.0))))
}
fn ctor_fixed(spec: &StrategySpec) -> Result<Box<dyn RetryPolicy>> {
    spec.check_keys(&["max_attempts", "delay"])?;
    Ok(Box::new(FixedRetry::new(
        spec.get_or("max_attempts", 3.0) as u32,
        spec.get_or("delay", 0.0),
    )))
}
fn ctor_exp_backoff(spec: &StrategySpec) -> Result<Box<dyn RetryPolicy>> {
    spec.check_keys(&["base", "cap", "max_attempts"])?;
    Ok(Box::new(ExpBackoffRetry::new(
        spec.get_or("base", 60.0),
        spec.get_or("cap", 3600.0),
        spec.get_or("max_attempts", 5.0) as u32,
    )))
}
fn ctor_deadline_aware(spec: &StrategySpec) -> Result<Box<dyn RetryPolicy>> {
    spec.check_keys(&["base", "cap"])?;
    Ok(Box::new(DeadlineAwareRetry::new(
        spec.get_or("base", 60.0),
        spec.get_or("cap", 3600.0),
    )))
}

const BUILTIN_RETRIES: &[(&str, RetryCtor)] = &[
    ("always", ctor_always),
    ("fixed", ctor_fixed),
    ("exp_backoff", ctor_exp_backoff),
    ("deadline_aware", ctor_deadline_aware),
];

fn sched_ext() -> &'static RwLock<Vec<(String, SchedulerCtor)>> {
    static EXT: OnceLock<RwLock<Vec<(String, SchedulerCtor)>>> = OnceLock::new();
    EXT.get_or_init(|| RwLock::new(Vec::new()))
}

fn trigger_ext() -> &'static RwLock<Vec<(String, TriggerCtor)>> {
    static EXT: OnceLock<RwLock<Vec<(String, TriggerCtor)>>> = OnceLock::new();
    EXT.get_or_init(|| RwLock::new(Vec::new()))
}

fn placer_ext() -> &'static RwLock<Vec<(String, PlacerCtor)>> {
    static EXT: OnceLock<RwLock<Vec<(String, PlacerCtor)>>> = OnceLock::new();
    EXT.get_or_init(|| RwLock::new(Vec::new()))
}

fn retry_ext() -> &'static RwLock<Vec<(String, RetryCtor)>> {
    static EXT: OnceLock<RwLock<Vec<(String, RetryCtor)>>> = OnceLock::new();
    EXT.get_or_init(|| RwLock::new(Vec::new()))
}

/// Register a custom scheduler constructor under `name`. Later
/// registrations shadow earlier ones and built-ins, so tests/examples can
/// override.
pub fn register_scheduler(name: &str, ctor: SchedulerCtor) {
    sched_ext()
        .write()
        .expect("scheduler registry poisoned")
        .push((name.to_string(), ctor));
}

/// Register a custom retraining-trigger constructor under `name`.
pub fn register_trigger(name: &str, ctor: TriggerCtor) {
    trigger_ext()
        .write()
        .expect("trigger registry poisoned")
        .push((name.to_string(), ctor));
}

/// Register a custom placement-strategy constructor under `name`.
pub fn register_placer(name: &str, ctor: PlacerCtor) {
    placer_ext()
        .write()
        .expect("placer registry poisoned")
        .push((name.to_string(), ctor));
}

/// Register a custom retry-policy constructor under `name`.
pub fn register_retry_policy(name: &str, ctor: RetryCtor) {
    retry_ext()
        .write()
        .expect("retry registry poisoned")
        .push((name.to_string(), ctor));
}

/// Build a scheduler from its spec. Unknown names and unknown parameter
/// keys are configuration errors (reported with the known names).
pub fn build_scheduler(spec: &StrategySpec) -> Result<Box<dyn Scheduler>> {
    let ext = sched_ext().read().expect("scheduler registry poisoned");
    if let Some((_, ctor)) = ext.iter().rev().find(|(n, _)| *n == spec.name) {
        return ctor(spec);
    }
    drop(ext);
    if let Some((_, ctor)) = BUILTIN_SCHEDULERS.iter().find(|(n, _)| *n == spec.name) {
        return ctor(spec);
    }
    Err(Error::Config(format!(
        "unknown scheduler '{}' (known: {})",
        spec.name,
        scheduler_names().join(", ")
    )))
}

/// Build a retraining trigger from its spec.
pub fn build_trigger(spec: &StrategySpec) -> Result<Box<dyn RetrainTrigger>> {
    let ext = trigger_ext().read().expect("trigger registry poisoned");
    if let Some((_, ctor)) = ext.iter().rev().find(|(n, _)| *n == spec.name) {
        return ctor(spec);
    }
    drop(ext);
    if let Some((_, ctor)) = BUILTIN_TRIGGERS.iter().find(|(n, _)| *n == spec.name) {
        return ctor(spec);
    }
    Err(Error::Config(format!(
        "unknown retrain trigger '{}' (known: {})",
        spec.name,
        trigger_names().join(", ")
    )))
}

/// Build a placement strategy from its spec.
pub fn build_placer(spec: &StrategySpec) -> Result<Box<dyn Placer>> {
    let ext = placer_ext().read().expect("placer registry poisoned");
    if let Some((_, ctor)) = ext.iter().rev().find(|(n, _)| *n == spec.name) {
        return ctor(spec);
    }
    drop(ext);
    if let Some((_, ctor)) = BUILTIN_PLACERS.iter().find(|(n, _)| *n == spec.name) {
        return ctor(spec);
    }
    Err(Error::Config(format!(
        "unknown placer '{}' (known: {})",
        spec.name,
        placer_names().join(", ")
    )))
}

/// Build a retry policy from its spec.
pub fn build_retry_policy(spec: &StrategySpec) -> Result<Box<dyn RetryPolicy>> {
    let ext = retry_ext().read().expect("retry registry poisoned");
    if let Some((_, ctor)) = ext.iter().rev().find(|(n, _)| *n == spec.name) {
        return ctor(spec);
    }
    drop(ext);
    if let Some((_, ctor)) = BUILTIN_RETRIES.iter().find(|(n, _)| *n == spec.name) {
        return ctor(spec);
    }
    Err(Error::Config(format!(
        "unknown retry policy '{}' (known: {})",
        spec.name,
        retry_policy_names().join(", ")
    )))
}

/// All selectable scheduler names: built-ins plus registered extensions,
/// in registration order, deduplicated.
pub fn scheduler_names() -> Vec<String> {
    let mut names: Vec<String> = BUILTIN_SCHEDULERS
        .iter()
        .map(|(n, _)| n.to_string())
        .collect();
    for (n, _) in sched_ext().read().expect("scheduler registry poisoned").iter() {
        if !names.contains(n) {
            names.push(n.clone());
        }
    }
    names
}

/// All selectable retraining-trigger names.
pub fn trigger_names() -> Vec<String> {
    let mut names: Vec<String> = BUILTIN_TRIGGERS
        .iter()
        .map(|(n, _)| n.to_string())
        .collect();
    for (n, _) in trigger_ext().read().expect("trigger registry poisoned").iter() {
        if !names.contains(n) {
            names.push(n.clone());
        }
    }
    names
}

/// All selectable placement-strategy names.
pub fn placer_names() -> Vec<String> {
    let mut names: Vec<String> = BUILTIN_PLACERS
        .iter()
        .map(|(n, _)| n.to_string())
        .collect();
    for (n, _) in placer_ext().read().expect("placer registry poisoned").iter() {
        if !names.contains(n) {
            names.push(n.clone());
        }
    }
    names
}

/// All selectable retry-policy names.
pub fn retry_policy_names() -> Vec<String> {
    let mut names: Vec<String> = BUILTIN_RETRIES
        .iter()
        .map(|(n, _)| n.to_string())
        .collect();
    for (n, _) in retry_ext().read().expect("retry registry poisoned").iter() {
        if !names.contains(n) {
            names.push(n.clone());
        }
    }
    names
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::sched::SchedCtx;

    #[test]
    fn builtins_resolve_with_defaults() {
        for name in [
            "fifo",
            "priority",
            "sjf",
            "edf",
            "weighted_fair",
            "preemptive_priority",
            "easy_backfill",
            "restart_first",
        ] {
            let s = build_scheduler(&StrategySpec::new(name)).unwrap();
            assert_eq!(s.name(), name);
        }
        for name in [
            "eager",
            "never",
            "drift_threshold",
            "off_peak",
            "performance_floor",
            "periodic",
        ] {
            let t = build_trigger(&StrategySpec::new(name)).unwrap();
            assert_eq!(t.name(), name);
        }
        for name in ["fastest_fit", "cheapest_fit", "pack", "spread"] {
            let p = build_placer(&StrategySpec::new(name)).unwrap();
            assert_eq!(p.name(), name);
        }
        for name in ["always", "fixed", "exp_backoff", "deadline_aware"] {
            let r = build_retry_policy(&StrategySpec::new(name)).unwrap();
            assert_eq!(r.name(), name);
        }
    }

    #[test]
    fn unknown_names_and_params_rejected() {
        let err = build_scheduler(&StrategySpec::new("bogus")).unwrap_err();
        assert!(err.to_string().contains("fifo"), "{err}");
        assert!(build_scheduler(&StrategySpec::new("fifo").with("x", 1.0)).is_err());
        assert!(build_trigger(&StrategySpec::new("drift_threshold").with("thresh", 0.1)).is_err());
        let err = build_placer(&StrategySpec::new("bogus")).unwrap_err();
        assert!(err.to_string().contains("fastest_fit"), "{err}");
        assert!(build_placer(&StrategySpec::new("pack").with("x", 1.0)).is_err());
        let err = build_retry_policy(&StrategySpec::new("bogus")).unwrap_err();
        assert!(err.to_string().contains("exp_backoff"), "{err}");
        assert!(build_retry_policy(&StrategySpec::new("always").with("x", 1.0)).is_err());
    }

    #[test]
    fn retry_params_reach_the_policy_and_registry_extends() {
        use crate::des::retry::{RetryCtx, RetryDecision};
        let spec = StrategySpec::new("fixed").with("max_attempts", 2.0).with("delay", 7.0);
        let mut r = build_retry_policy(&spec).unwrap();
        let ctx = RetryCtx {
            attempt: 1,
            elapsed: 0.0,
            deadline_slack: 0.0,
            queue_depth: 0,
        };
        assert_eq!(r.decide(&ctx), RetryDecision::Retry { delay: 7.0 });
        let ctx = RetryCtx { attempt: 2, ..ctx };
        assert_eq!(r.decide(&ctx), RetryDecision::Abandon);

        fn ctor(spec: &StrategySpec) -> Result<Box<dyn RetryPolicy>> {
            spec.check_keys(&[])?;
            Ok(Box::new(AlwaysRetry::new(0.0)))
        }
        register_retry_policy("custom_test_retry", ctor);
        assert!(retry_policy_names().iter().any(|n| n == "custom_test_retry"));
        let r = build_retry_policy(&StrategySpec::new("custom_test_retry")).unwrap();
        assert_eq!(r.name(), "always"); // the ctor builds AlwaysRetry underneath
    }

    #[test]
    fn placer_registry_lists_and_extends() {
        fn ctor(spec: &StrategySpec) -> Result<Box<dyn Placer>> {
            spec.check_keys(&[])?;
            Ok(Box::new(crate::des::place::FastestFit))
        }
        register_placer("custom_test_placer", ctor);
        assert!(placer_names().iter().any(|n| n == "custom_test_placer"));
        let p = build_placer(&StrategySpec::new("custom_test_placer")).unwrap();
        assert_eq!(p.name(), "fastest_fit"); // the ctor builds FastestFit underneath
    }

    #[test]
    fn params_reach_the_strategy() {
        let spec = StrategySpec::new("edf").with("slack_per_class", 60.0);
        let mut s = build_scheduler(&spec).unwrap();
        let ctx = SchedCtx {
            now: 0.0,
            job: crate::des::sched::JobCtx::new(1.0, 2.0, 100.0),
            in_use: 1,
            capacity: 1,
            queued: 0,
        };
        // deadline = 100 + 60 * 2
        assert_eq!(s.queue_key(&ctx), 220.0);
    }

    #[test]
    fn cli_form_parses_and_labels_roundtrip() {
        let spec = StrategySpec::parse("edf:slack_per_class=900").unwrap();
        assert_eq!(spec.name, "edf");
        assert_eq!(spec.get("slack_per_class"), Some(900.0));
        assert_eq!(spec.label(), "edf:slack_per_class=900");
        assert_eq!(StrategySpec::parse("fifo").unwrap().label(), "fifo");
        assert!(StrategySpec::parse("").is_err());
        assert!(StrategySpec::parse("edf:slack").is_err());
        assert!(StrategySpec::parse("edf:slack=abc").is_err());
    }

    #[test]
    fn custom_registration_shadows_and_lists() {
        fn ctor(spec: &StrategySpec) -> Result<Box<dyn Scheduler>> {
            spec.check_keys(&[])?;
            Ok(Box::new(crate::des::sched::Fifo))
        }
        register_scheduler("custom_test_sched", ctor);
        assert!(scheduler_names().iter().any(|n| n == "custom_test_sched"));
        let s = build_scheduler(&StrategySpec::new("custom_test_sched")).unwrap();
        assert_eq!(s.name(), "fifo"); // the ctor builds a Fifo underneath
    }
}
