//! The decomposed simulation core: named state + one method per
//! calendar event (paper section V-B).
//!
//! This replaces the former 600-line `Experiment::run()` monolith (and
//! its `start_task!` / `sample_exec!` macros) with a [`Simulation`]
//! struct whose event handlers are ordinary methods — the well-defined
//! points where operational strategies hook in:
//!
//! * [`Simulation::start_task`] builds a [`JobCtx`] and asks the
//!   resource's pluggable `Scheduler` for admission/ordering;
//! * [`Simulation::on_drift`] builds a `TriggerCtx` per deployed model
//!   and asks the pluggable `RetrainTrigger` whether to launch
//!   retraining.
//!
//! Determinism is load-bearing: the RNG substream layout, the order of
//! draws inside every handler, and the series-interning order are
//! exactly those of the pre-decomposition runner, so existing
//! `(config, seed)` pairs keep their byte-identical
//! `ExperimentResult::digest()` values.

use std::sync::Arc;

use crate::arrivals::ArrivalModel;
use crate::des::sched::JobCtx;
use crate::des::{
    AcquireResult, Calendar, ClassPool, EventHandle, Granted, Resource, RetryCtx, RetryDecision,
    RetryPolicy, SimTime,
};
use crate::error::Result;
use crate::model::pipeline::TaskNode;
use crate::model::{
    ClusterFailureConfig, CompressionModel, DataAsset, Framework, ModelMetrics, ResourceKind,
    TaskExecutor, TaskType,
};
use crate::obs::{MeterReport, SimMeter, EVENT_KINDS};
use crate::runtime::pool::{Backend, SamplePool1};
use crate::runtime::{Runtime, K1};
use crate::stats::gmm::Gmm1;
use crate::stats::rng::Pcg64;
use crate::stats::Distribution;
use crate::synth::{AssetSynthesizer, PipelineSynthesizer, TaskList};
use crate::trace::{MemorySink, NullSink, Trace, TraceEvent, TraceEventKind, TraceSink};
use crate::tsdb::{SeriesHandle, SeriesKey, TsStore};

use super::config::ExperimentConfig;
use super::params::SimParams;
use super::result::{rss_mb, series, ExperimentResult};
use super::strategy::{build_placer, build_retry_policy, build_scheduler, build_trigger, StrategySpec};
use super::triggers::{DeployedModel, RetrainTrigger};

/// Calendar events.
#[derive(Clone, Copy, Debug)]
enum Event {
    /// Next pipeline arrival (self-rescheduling).
    Arrival,
    /// Task of pipeline `pid` finished (exec + write done).
    TaskDone(u32),
    /// Periodic utilization/queue sampling.
    Monitor,
    /// Run-time view detector sweep.
    Drift,
    /// Launch a (possibly deferred) retraining for deployed-model slot.
    RetrainLaunch(u32),
    /// Failure injection: one slot on the cluster fails
    /// (self-rescheduling through the cluster's MTBF distribution).
    SlotFailed(ResourceKind),
    /// A failed slot comes back after the carried repair time (the
    /// MTTR sample drawn when the failure landed — carried here so the
    /// trace can report the exact downtime without FIFO pairing).
    SlotRepaired(ResourceKind, f64),
    /// Per-class failure injection: one slot of hardware class `.1` on
    /// cluster `.0` fails (self-rescheduling through that class's own
    /// MTBF distribution — scheduled only for classes with a failure
    /// config).
    ClassFailed(ResourceKind, u32),
    /// A failed slot of hardware class `.1` comes back after the
    /// carried repair time. Also used by cluster-level failures when
    /// hardware classes are configured, so the repair restores the
    /// same class the failure was attributed to.
    ClassRepaired(ResourceKind, u32, f64),
    /// Task-level fault: the in-flight attempt of pipeline `pid` fails
    /// transiently (armed at service start from the cluster's
    /// fault-time distribution, cancelled on normal completion).
    TaskFault(u32),
    /// Per-attempt timeout: the in-flight attempt of pipeline `pid`
    /// exceeded the cluster's `timeout` (cancelled on completion or an
    /// earlier fault).
    TaskTimeout(u32),
    /// Retry backoff expired: re-submit pipeline `pid`'s current task.
    TaskRetry(u32),
}

/// Index of an event's kind in [`EVENT_KINDS`] (SimMeter accounting).
fn kind_index(ev: &Event) -> usize {
    match ev {
        Event::Arrival => 0,
        Event::TaskDone(_) => 1,
        Event::Monitor => 2,
        Event::Drift => 3,
        Event::RetrainLaunch(_) => 4,
        Event::SlotFailed(_) => 5,
        Event::SlotRepaired(..) => 6,
        Event::ClassFailed(..) => 7,
        Event::ClassRepaired(..) => 8,
        Event::TaskFault(_) => 9,
        Event::TaskTimeout(_) => 10,
        Event::TaskRetry(_) => 11,
    }
}

/// Deadline slack per priority class for the SLO/retry analytics —
/// mirrors `EdfScheduler`'s default, so "within deadline" means the
/// same thing to the attainment metric, the `deadline_aware` retry
/// policy, and the EDF scheduler.
const DEADLINE_SLACK: f64 = 1800.0;

/// Per-pipeline execution state (slab-allocated, freed on completion so
/// memory scales with *concurrent*, not total, pipelines).
struct PipelineState {
    tasks: TaskList,
    cur: usize,
    framework: Framework,
    asset: DataAsset,
    preproc_t: f64,
    /// Last sampled training duration (drives compress/harden cost).
    train_t: f64,
    metrics: ModelMetrics,
    model_bytes: f64,
    arrived_at: SimTime,
    total_wait: SimTime,
    /// Sampled exec duration for the task awaiting a resource grant.
    pending_exec: f64,
    pending_read: f64,
    pending_write: f64,
    /// Cancellation handle of the in-flight `TaskDone` while the current
    /// task runs (None while queued / between tasks). Preemption cancels
    /// it so the completion never fires.
    done_handle: Option<EventHandle>,
    /// Absolute completion time of the in-flight task (valid while
    /// `done_handle` is set); remaining service at preemption is
    /// `done_at - now`.
    done_at: SimTime,
    /// Service seconds left from a preemption or slot failure; consumed
    /// (instead of the full read+exec+write) when the task is
    /// re-granted a slot. After a failure it includes the re-done tail
    /// since the last checkpoint plus the restart cost.
    remaining_service: Option<f64>,
    /// When the in-flight attempt began service (valid while
    /// `done_handle` is set). A slot failure loses the attempt progress
    /// `t - attempt_start` back to the last checkpoint boundary.
    attempt_start: SimTime,
    /// Hardware-class allocation of the in-flight task: `(class index,
    /// slots)` per class, written at placement (grant) time and freed
    /// on completion, preemption, or failure. Always empty when the
    /// cluster has no `hw_classes`.
    allocation: Vec<(u32, u32)>,
    /// 1-based attempt number of the current task (reset when the
    /// pipeline advances, bumped on every fault/timeout retry).
    attempt: u32,
    /// Cancellation handle of the pending `TaskFault` armed for the
    /// in-flight attempt (None when no fault landed inside it).
    fault_handle: Option<EventHandle>,
    /// Cancellation handle of the pending `TaskTimeout` for the
    /// in-flight attempt.
    timeout_handle: Option<EventHandle>,
    /// Deployed-model slot to refresh when this (retraining) run deploys.
    retrain_of: Option<u32>,
    /// User priority (lower = more important; Fig 4's "model
    /// prioritization"). Retraining pipelines get priority 0.
    priority: f64,
}

const N_FW: usize = Framework::ALL.len() + 1; // +1 = untagged
const N_TASKS: usize = TaskType::ALL.len();

/// Interned hot-path series handles (created once, before the loop).
struct SeriesHandles {
    arrivals: SeriesHandle,
    completions: SeriesHandle,
    pipeline_wait: SeriesHandle,
    util_t: SeriesHandle,
    util_c: SeriesHandle,
    q_t: SeriesHandle,
    q_c: SeriesHandle,
    wait_t: SeriesHandle,
    wait_c: SeriesHandle,
    traffic_r: SeriesHandle,
    traffic_w: SeriesHandle,
    model_perf: SeriesHandle,
    retrains: SeriesHandle,
    /// Task exec series per (task, framework): a flat array indexed by
    /// (task, framework+1) — the per-event path never hashes anything,
    /// and the tag strings intern into the store's symbol table once.
    exec: [[Option<SeriesHandle>; N_FW]; N_TASKS],
}

impl SeriesHandles {
    fn intern(db: &mut TsStore) -> Self {
        SeriesHandles {
            arrivals: db.handle(SeriesKey::new(series::ARRIVALS)),
            completions: db.handle(SeriesKey::new(series::COMPLETIONS)),
            pipeline_wait: db.handle(SeriesKey::new(series::PIPELINE_WAIT)),
            util_t: db.handle(SeriesKey::new(series::UTILIZATION).tag("resource", "training")),
            util_c: db.handle(SeriesKey::new(series::UTILIZATION).tag("resource", "compute")),
            q_t: db.handle(SeriesKey::new(series::QUEUE_LEN).tag("resource", "training")),
            q_c: db.handle(SeriesKey::new(series::QUEUE_LEN).tag("resource", "compute")),
            wait_t: db.handle(SeriesKey::new(series::TASK_WAIT).tag("resource", "training")),
            wait_c: db.handle(SeriesKey::new(series::TASK_WAIT).tag("resource", "compute")),
            traffic_r: db.handle(SeriesKey::new(series::TRAFFIC).tag("dir", "read")),
            traffic_w: db.handle(SeriesKey::new(series::TRAFFIC).tag("dir", "write")),
            model_perf: db.handle(SeriesKey::new(series::MODEL_PERF)),
            retrains: db.handle(SeriesKey::new(series::RETRAINS)),
            exec: [[None; N_FW]; N_TASKS],
        }
    }
}

/// Outcome counters, named (formerly a pile of loop-local `let mut`s).
#[derive(Default)]
struct Counters {
    arrived: u64,
    /// Pipelines in flight (slab occupancy).
    live: u64,
    arrivals_stopped: bool,
    completed: u64,
    tasks_executed: u64,
    gate_failures: u64,
    preemptions: u64,
    retrains: u64,
    models_deployed: u64,
    events: u64,
    wire_read: f64,
    wire_write: f64,
    peak_rss: f64,
    // failure injection (all zero / empty when no FailureModel is set)
    failures: u64,
    repairs: u64,
    /// Service seconds thrown away by failures: un-checkpointed attempt
    /// tails plus restart costs.
    lost_work: f64,
    /// Service seconds of completed tasks (their nominal read+exec+write
    /// — the work that contributed to outcomes). Goodput is
    /// useful / (useful + lost).
    useful_work: f64,
    /// MTTR samples, one per landed failure — recovery-time percentiles.
    downtimes: Vec<f64>,
    /// Class-placement operations performed (meter-only; never enters
    /// the digest).
    placements: u64,
    // task-level faults (all zero when no FaultModel is set)
    task_faults: u64,
    task_timeouts: u64,
    retries: u64,
    abandoned: u64,
    shed: u64,
    /// Service seconds of faulted / timed-out attempts — progress the
    /// fault model threw away.
    wasted_work: f64,
    /// Completed pipelines that finished within their EDF deadline
    /// (`arrived_at + DEADLINE_SLACK × priority class`).
    slo_met: u64,
}

/// One experiment run in progress: the calendar, the resources with
/// their pluggable schedulers, the retraining trigger, per-pipeline
/// state, samplers, RNG streams, and outcome counters.
pub(super) struct Simulation {
    cfg: ExperimentConfig,
    params: Arc<SimParams>,
    backend: Backend,
    // world
    cal: Calendar<Event>,
    training: Resource<u32>,
    compute: Resource<u32>,
    /// Class-aware placement per cluster (`[training, compute]`), `None`
    /// without `hw_classes` — the whole placement layer then costs one
    /// branch per grant and perturbs nothing.
    class_pools: [Option<ClassPool>; 2],
    /// Landed failures per class, same indexing as `class_pools`.
    class_failures: [Vec<u64>; 2],
    trigger: Box<dyn RetrainTrigger>,
    slab: Vec<Option<PipelineState>>,
    free: Vec<u32>,
    deployed: Vec<DeployedModel>,
    db: TsStore,
    h: SeriesHandles,
    // samplers
    asset_synth: AssetSynthesizer,
    pipe_synth: PipelineSynthesizer,
    train_pools: Vec<SamplePool1>,
    eval_pool: SamplePool1,
    arrival: ArrivalModel,
    compression: CompressionModel,
    // RNG streams (asset/pipe streams live inside their synthesizers)
    rng_arrival: Pcg64,
    rng_noise: Pcg64,
    rng_drift: Pcg64,
    /// Dedicated failure-injection stream: drawn from only by failure
    /// events, so enabling failures perturbs no other stream and
    /// failure-off runs keep their digests byte-identical.
    rng_failure: Pcg64,
    /// Dedicated task-fault stream: drawn from only when a fault-time
    /// distribution is configured, so enabling task faults perturbs no
    /// other stream and fault-off runs keep their digests
    /// byte-identical.
    rng_fault: Pcg64,
    /// Pluggable retry policy consulted on every task fault/timeout
    /// (`infra.faults.retry`; the built-in `always` when no fault model
    /// is configured, in which case it is never asked).
    retry: Box<dyn RetryPolicy>,
    c: Counters,
    /// Self-profiling hooks (disabled unless `cfg.meter`): per-kind
    /// event counts/wall time and the calendar depth high-water mark.
    /// All readings stay out of the digest.
    meter: SimMeter,
    // event-level trace capture (NullSink when cfg.capture_trace is off;
    // every emission site checks `capture` so the off path costs one
    // branch and zero allocations)
    capture: bool,
    sink: Box<dyn TraceSink>,
    /// Scratch for multi-grant releases (a wide training job freeing
    /// room for several narrow tasks), reused across events.
    grant_buf: Vec<Granted<u32>>,
}

impl Simulation {
    /// Build the world: RNG substreams, samplers, resources (with their
    /// schedulers built from `cfg.infra.scheduler`), the retraining
    /// trigger, and the primed calendar. Assumes `cfg` already validated.
    /// `arrival_override` replaces the config-selected arrival process
    /// (the trace-replay path feeds recorded gaps through it).
    /// `sink_override` injects a caller-supplied [`TraceSink`]
    /// (`Experiment::with_sink`) — event capture is then on regardless of
    /// `cfg.capture_trace`, and a streaming sink that drains empty leaves
    /// no in-memory trace behind.
    pub(super) fn new(
        cfg: ExperimentConfig,
        params: Arc<SimParams>,
        runtime: Option<Arc<Runtime>>,
        arrival_override: Option<ArrivalModel>,
        sink_override: Option<Box<dyn TraceSink>>,
    ) -> Result<Self> {
        let backend = match &runtime {
            Some(rt) => Backend::Runtime(rt.clone()),
            None => Backend::Cpu,
        };

        let mut root = Pcg64::new(cfg.seed);
        let mut rng_arrival = root.substream(1);
        let rng_pipe = root.substream(2);
        let mut rng_asset = root.substream(3);
        let rng_noise = root.substream(4);
        let rng_drift = root.substream(5);

        // samplers (all mixture handles are Arc clones — no deep copies
        // of fitted parameters per experiment)
        let asset_synth = AssetSynthesizer::new(
            backend.clone(),
            params.asset_gmm.clone(),
            params.preproc_curve,
            params.preproc_noise,
            &mut rng_asset,
        );
        let pipe_synth = PipelineSynthesizer::new(cfg.synth, rng_pipe);
        let train_pools: Vec<SamplePool1> = Framework::ALL
            .iter()
            .map(|fw| {
                SamplePool1::new(
                    backend.clone(),
                    pad_gmm(params.train_gmm_shared(*fw)),
                    root.substream(0x100 + fw.index() as u64),
                )
            })
            .collect();
        let eval_pool = SamplePool1::new(
            backend.clone(),
            pad_gmm(&params.eval_log_gmm),
            root.substream(0x200),
        );
        // derived unconditionally, and *after* every pre-existing
        // substream: failure-off runs keep every other stream — and
        // therefore their digests — byte-identical
        let mut rng_failure = root.substream(0x300);
        // same pattern for task-level faults: derived unconditionally,
        // and *after* every pre-existing substream, so fault-off runs
        // keep every other stream — and their digests — byte-identical
        let rng_fault = root.substream(0x400);
        // the retry policy only decides anything when a fault model is
        // configured; the unconditional `always` default keeps the
        // field total without an Option on the hot path
        let retry = match cfg.infra.retry_spec() {
            Some(spec) => build_retry_policy(spec)?,
            None => build_retry_policy(&StrategySpec::new("always"))?,
        };
        let mut arrival = match arrival_override {
            Some(model) => model,
            None => params.resolve_arrival(cfg.arrival),
        };
        let compression = CompressionModel::from_table1();

        // world: each resource owns its scheduler instance (stateful
        // strategies never share state across clusters), built from its
        // cluster's resolved spec — `infra.scheduler_training` /
        // `infra.scheduler_compute` override the shared `infra.scheduler`
        let training = Resource::with_scheduler(
            "training",
            cfg.infra.training_capacity,
            build_scheduler(cfg.infra.scheduler_for(ResourceKind::Training))?,
        );
        let compute = Resource::with_scheduler(
            "compute",
            cfg.infra.compute_capacity,
            build_scheduler(cfg.infra.scheduler_for(ResourceKind::Compute))?,
        );
        let trigger = build_trigger(&cfg.runtime_view.trigger)?;
        // class-aware placement: each configured cluster gets its own
        // placer instance (stateful placers never share state across
        // clusters); clusters without classes stay plain pools
        let mut class_pools: [Option<ClassPool>; 2] = [None, None];
        let mut class_failures: [Vec<u64>; 2] = [Vec::new(), Vec::new()];
        if let Some(hw) = &cfg.infra.hw_classes {
            for (i, kind) in [ResourceKind::Training, ResourceKind::Compute]
                .iter()
                .enumerate()
            {
                if let Some(classes) = cfg.infra.hw_classes_for(*kind) {
                    class_pools[i] = Some(ClassPool::new(classes, build_placer(&hw.placer)?));
                    class_failures[i] = vec![0; classes.len()];
                }
            }
        }
        let mut db = TsStore::new();
        if let Some(ret) = &cfg.retention {
            db.set_retention(ret.resolution);
        }
        let h = SeriesHandles::intern(&mut db);

        // event-trace capture: an injected sink wins and forces capture
        let capture = cfg.capture_trace || sink_override.is_some();
        let mut sink: Box<dyn TraceSink> = match sink_override {
            Some(s) => s,
            None if capture => Box::new(MemorySink::new()),
            None => Box::new(NullSink),
        };

        // prime the calendar
        let mut cal: Calendar<Event> = Calendar::new();
        let first_gap = arrival.next_interarrival(0.0, cfg.interarrival_factor, &mut rng_arrival);
        if capture {
            sink.record(&TraceEvent {
                t: 0.0,
                kind: TraceEventKind::ArrivalGapDrawn { gap: first_gap },
            });
        }
        cal.schedule(first_gap, Event::Arrival);
        cal.schedule(cfg.sample_interval, Event::Monitor);
        if cfg.runtime_view.enabled {
            cal.schedule(cfg.runtime_view.detector_interval, Event::Drift);
        }
        // failure injection: prime each configured cluster's first
        // failure (training before compute — draw order is part of the
        // determinism contract)
        for kind in [ResourceKind::Training, ResourceKind::Compute] {
            if let Some(fc) = cfg.infra.failure_for(kind) {
                let gap = fc.mtbf.sample(&mut rng_failure).max(0.0);
                if gap <= cfg.horizon {
                    cal.schedule(gap, Event::SlotFailed(kind));
                }
            }
        }
        // per-class failure priming comes *after* every cluster-level
        // draw (training classes then compute classes, config order),
        // so configs without class failure knobs keep the failure
        // stream — and their digests — byte-identical
        for kind in [ResourceKind::Training, ResourceKind::Compute] {
            if let Some(classes) = cfg.infra.hw_classes_for(kind) {
                for (ci, hc) in classes.iter().enumerate() {
                    if let Some(fc) = &hc.failures {
                        let gap = fc.mtbf.sample(&mut rng_failure).max(0.0);
                        if gap <= cfg.horizon {
                            cal.schedule(gap, Event::ClassFailed(kind, ci as u32));
                        }
                    }
                }
            }
        }

        // `cfg` is moved into the struct below before `meter` is built,
        // so lift the knob out first.
        let cfg_meter = cfg.meter;
        Ok(Simulation {
            cfg,
            params,
            backend,
            cal,
            training,
            compute,
            class_pools,
            class_failures,
            trigger,
            slab: Vec::new(),
            free: Vec::new(),
            deployed: Vec::new(),
            db,
            h,
            asset_synth,
            pipe_synth,
            train_pools,
            eval_pool,
            arrival,
            compression,
            rng_arrival,
            rng_noise,
            rng_drift,
            rng_failure,
            rng_fault,
            retry,
            c: Counters {
                peak_rss: rss_mb(),
                ..Counters::default()
            },
            meter: SimMeter::new(cfg_meter),
            capture,
            sink,
            grant_buf: Vec::new(),
        })
    }

    /// Drain the calendar up to the horizon; single-threaded,
    /// deterministic per seed.
    pub(super) fn run(mut self, started: std::time::Instant) -> Result<ExperimentResult> {
        while let Some((t, ev)) = self.cal.pop() {
            if t > self.cfg.horizon {
                break;
            }
            self.c.events += 1;
            // Meter probe: one branch when off, so unmetered runs keep
            // their hot loop (and their digests) untouched.
            let probe = if self.meter.enabled() {
                Some((kind_index(&ev), std::time::Instant::now()))
            } else {
                None
            };
            match ev {
                Event::Arrival => self.on_arrival(t)?,
                Event::TaskDone(pid) => self.on_task_done(t, pid)?,
                Event::Monitor => self.on_monitor(t),
                Event::Drift => self.on_drift(t),
                Event::RetrainLaunch(slot) => self.on_retrain_launch(t, slot)?,
                Event::SlotFailed(kind) => self.on_slot_failed(t, kind)?,
                Event::SlotRepaired(kind, downtime) => self.on_slot_repaired(t, kind, downtime),
                Event::ClassFailed(kind, ci) => self.on_class_failed(t, kind, ci)?,
                Event::ClassRepaired(kind, ci, downtime) => {
                    self.on_class_repaired(t, kind, ci, downtime)
                }
                Event::TaskFault(pid) => self.on_task_fault(t, pid)?,
                Event::TaskTimeout(pid) => self.on_task_timeout(t, pid)?,
                Event::TaskRetry(pid) => self.on_task_retry(pid)?,
            }
            if let Some((k, t0)) = probe {
                self.meter
                    .record_event(k, t0.elapsed().as_nanos() as u64, self.cal.backing_len());
            }
        }
        self.finish(started)
    }

    /// Index of `kind`'s entry in `class_pools` / `class_failures`.
    fn pool_idx(kind: ResourceKind) -> usize {
        match kind {
            ResourceKind::Training => 0,
            ResourceKind::Compute => 1,
        }
    }

    /// Place a just-granted task of `pid` onto `kind`'s hardware
    /// classes via the configured placer and return the job's speed
    /// factor (the slowest allocated class). Without `hw_classes` this
    /// is a no-op returning 1.0 — and since `x / 1.0 == x` bit-exactly,
    /// the classless service-time path is unperturbed.
    fn place_task(&mut self, t: SimTime, pid: u32, kind: ResourceKind, job: &JobCtx) -> f64 {
        let Some(pool) = self.class_pools[Self::pool_idx(kind)].as_mut() else {
            return 1.0;
        };
        let st = self.slab[pid as usize].as_mut().expect("live pipeline");
        debug_assert!(st.allocation.is_empty(), "task placed twice");
        let fw = st.tasks.get(st.cur).framework;
        let mut alloc = std::mem::take(&mut st.allocation);
        alloc.clear();
        let speed = pool.place(t, job, fw.map(|f| f.name()), &mut alloc);
        st.allocation = alloc;
        self.c.placements += 1;
        speed
    }

    /// Free `pid`'s class allocation back to its pool (no-op without
    /// `hw_classes`, or when the task never got placed).
    fn unplace(&mut self, t: SimTime, pid: u32, kind: ResourceKind) {
        let Some(pool) = self.class_pools[Self::pool_idx(kind)].as_mut() else {
            return;
        };
        let st = self.slab[pid as usize].as_mut().expect("live pipeline");
        pool.release(t, &st.allocation);
        st.allocation.clear();
    }

    /// Emit one `TaskPlaced` record per allocated class of `pid`'s
    /// current task — immediately after the grant's `TaskStarted`, per
    /// the format-v5 spec. Capture-gated; no-op without `hw_classes`.
    fn emit_placed(&mut self, t: SimTime, pid: u32, kind: ResourceKind) {
        if !self.capture || self.class_pools[Self::pool_idx(kind)].is_none() {
            return;
        }
        let (task, alloc) = {
            let st = self.slab[pid as usize].as_ref().expect("live pipeline");
            (st.tasks.get(st.cur).task, st.allocation.clone())
        };
        for (class, slots) in alloc {
            self.sink.record(&TraceEvent {
                t,
                kind: TraceEventKind::TaskPlaced {
                    pid,
                    task,
                    resource: kind,
                    class,
                    slots,
                },
            });
        }
    }

    /// Slab-allocate a pipeline, reusing freed slots.
    fn alloc_pid(&mut self, st: PipelineState) -> u32 {
        if let Some(pid) = self.free.pop() {
            self.slab[pid as usize] = Some(st);
            pid
        } else {
            self.slab.push(Some(st));
            (self.slab.len() - 1) as u32
        }
    }

    /// A user pipeline arrives: synthesize it, schedule the next
    /// arrival, and start its first task.
    fn on_arrival(&mut self, t: SimTime) -> Result<()> {
        self.c.arrived += 1;
        self.db.append(self.h.arrivals, t, 1.0);
        // next arrival
        let stop = self.cfg.max_pipelines.map_or(false, |m| self.c.arrived >= m);
        if !stop {
            let gap = self.arrival.next_interarrival(
                t,
                self.cfg.interarrival_factor,
                &mut self.rng_arrival,
            );
            if self.capture {
                self.sink.record(&TraceEvent {
                    t,
                    kind: TraceEventKind::ArrivalGapDrawn { gap },
                });
            }
            if t + gap <= self.cfg.horizon {
                self.cal.schedule(gap, Event::Arrival);
            } else {
                self.c.arrivals_stopped = true;
            }
        } else {
            self.c.arrivals_stopped = true;
        }
        // new pipeline
        let tasks = self.pipe_synth.generate_nodes();
        let fw = tasks
            .as_slice()
            .iter()
            .find_map(|n| n.framework)
            .unwrap_or(Framework::SparkML);
        let (asset, preproc_t) = self.asset_synth.next()?;
        let st = PipelineState {
            tasks,
            cur: 0,
            framework: fw,
            asset,
            preproc_t,
            train_t: 60.0,
            metrics: ModelMetrics::default(),
            model_bytes: 1e7,
            arrived_at: t,
            total_wait: 0.0,
            pending_exec: 0.0,
            pending_read: 0.0,
            pending_write: 0.0,
            done_handle: None,
            done_at: 0.0,
            remaining_service: None,
            attempt_start: 0.0,
            allocation: Vec::new(),
            attempt: 1,
            fault_handle: None,
            timeout_handle: None,
            retrain_of: None,
            // user-assigned priority class 1..=10
            priority: 1.0 + self.rng_noise.below(10) as f64,
        };
        let (n_tasks, priority) = (st.tasks.len() as u8, st.priority);
        let pid = self.alloc_pid(st);
        self.c.live += 1;
        if self.capture {
            self.sink.record(&TraceEvent {
                t,
                kind: TraceEventKind::PipelineArrival {
                    pid,
                    framework: fw,
                    n_tasks,
                    priority,
                    retrain_of: None,
                },
            });
        }
        self.start_task(pid)
    }

    /// Sample the exec duration for the current task of pipeline `pid`
    /// (formerly the `sample_exec!` macro). Draw order is part of the
    /// determinism contract.
    fn sample_exec(&mut self, pid: u32) -> Result<f64> {
        let (task, fw_tag, fw_default, preproc_t, train_t) = {
            let st = self.slab[pid as usize].as_ref().expect("live pipeline");
            let node = st.tasks.get(st.cur);
            (node.task, node.framework, st.framework, st.preproc_t, st.train_t)
        };
        Ok(match task {
            TaskType::Preprocess => preproc_t,
            TaskType::Train => {
                let fw = fw_tag.unwrap_or(fw_default);
                self.train_pools[fw.index()].next()?.exp().max(0.1)
            }
            TaskType::Evaluate => self.eval_pool.next()?.exp().max(0.05),
            // compression costs roughly a training run (section V-A2d)
            TaskType::Compress => (train_t * (1.0 + 0.05 * self.rng_noise.normal())).max(0.1),
            TaskType::Harden => (train_t * (1.5 + 0.2 * self.rng_noise.normal())).max(0.1),
            TaskType::Deploy => (5.0 * (0.3 * self.rng_noise.normal()).exp()).max(0.5),
        })
    }

    /// Prepare pending durations for the current task of `pid`, build
    /// its [`JobCtx`], and request the owning resource — the scheduler
    /// decides admission and queue position (formerly `start_task!`).
    fn start_task(&mut self, pid: u32) -> Result<()> {
        self.start_task_inner(pid, false)
    }

    /// [`Simulation::start_task`] with the retry path made explicit:
    /// `retry` re-submissions carry the restart flag (so
    /// `restart_first` schedulers compose with task-level retries the
    /// way they do with slot-failure restarts) and bypass admission
    /// control — a retried task is already inside the system.
    fn start_task_inner(&mut self, pid: u32, retry: bool) -> Result<()> {
        let t_now = self.cal.now();
        // admission control: a pipeline's *first* task is shed when the
        // owning cluster's queue sits at the configured cap. The check
        // runs before any sampling, so sheds draw no RNG and cap-free
        // runs keep every stream byte-identical.
        if !retry {
            if let Some(depth) = self.shed_depth(pid) {
                self.shed_pipeline(t_now, pid, depth);
                return Ok(());
            }
        }
        let exec = self.sample_exec(pid)?;
        let store = self.cfg.infra.store;
        let (task, fw_tag, read_t, write_t, read_wire, write_wire, job) = {
            let st = self.slab[pid as usize].as_mut().expect("live pipeline");
            let node = st.tasks.get(st.cur);
            let task = node.task;
            if task == TaskType::Train {
                st.train_t = exec;
            }
            let (read_b, write_b) = TaskExecutor::payload_bytes(task, &st.asset, st.model_bytes);
            st.pending_exec = exec;
            st.pending_read = store.read_time(read_b);
            st.pending_write = store.write_time(write_b);
            let total = st.pending_read + st.pending_exec + st.pending_write;
            let mut job = JobCtx::new(total, st.priority, st.arrived_at)
                .with_slots(self.cfg.infra.task_slots(task));
            if retry {
                job = job.after_restart();
            }
            (
                task,
                node.framework,
                st.pending_read,
                st.pending_write,
                store.wire_bytes(read_b),
                store.wire_bytes(write_b),
                job,
            )
        };
        self.c.wire_read += read_wire;
        self.c.wire_write += write_wire;
        if self.cfg.record_traces {
            self.db.append(self.h.traffic_r, t_now, read_wire);
            self.db.append(self.h.traffic_w, t_now, write_wire);
        }
        let kind = ResourceKind::for_task(task);
        let acquired = {
            let res = match kind {
                ResourceKind::Training => &mut self.training,
                ResourceKind::Compute => &mut self.compute,
            };
            res.request(t_now, pid, job)
        };
        match acquired {
            AcquireResult::Acquired => {
                // the grant is the placement point: the chosen class's
                // speed scales the exec component (I/O is unaffected)
                let speed = self.place_task(t_now, pid, kind, &job);
                let exec_s = exec / speed;
                let total_s = read_t + exec_s + write_t;
                if self.capture {
                    self.sink.record(&TraceEvent {
                        t: t_now,
                        kind: TraceEventKind::TaskStarted {
                            pid,
                            task,
                            framework: fw_tag,
                            exec: exec_s,
                            read: read_t,
                            write: write_t,
                        },
                    });
                }
                self.emit_placed(t_now, pid, kind);
                let h = self.cal.schedule(total_s, Event::TaskDone(pid));
                let st = self.slab[pid as usize].as_mut().expect("live pipeline");
                st.pending_exec = exec_s;
                st.done_handle = Some(h);
                st.done_at = t_now + total_s;
                st.attempt_start = t_now;
                self.arm_fault_events(t_now, pid, kind);
            }
            AcquireResult::Queued => {
                if self.capture {
                    self.sink.record(&TraceEvent {
                        t: t_now,
                        kind: TraceEventKind::TaskQueued {
                            pid,
                            task,
                            resource: kind,
                        },
                    });
                }
            }
            AcquireResult::Preempted { victim } => {
                // the scheduler evicted `victim` and already re-queued it
                // with its remaining service; void its completion event
                // and remember the remainder for the re-grant
                let (vh, vtask, remaining) = {
                    let vst = self.slab[victim as usize]
                        .as_mut()
                        .expect("preemption victim is live");
                    let vh = vst
                        .done_handle
                        .take()
                        .expect("preemption victim had a scheduled completion");
                    let remaining = (vst.done_at - t_now).max(0.0);
                    vst.remaining_service = Some(remaining);
                    (vh, vst.tasks.get(vst.cur).task, remaining)
                };
                let cancelled = self.cal.cancel(vh);
                debug_assert!(cancelled, "victim completion was pending");
                self.cancel_fault_events(victim);
                self.c.preemptions += 1;
                // the victim's class slots free up before the preemptor
                // places into them
                self.unplace(t_now, victim, kind);
                let speed = self.place_task(t_now, pid, kind, &job);
                let exec_s = exec / speed;
                let total_s = read_t + exec_s + write_t;
                if self.capture {
                    self.sink.record(&TraceEvent {
                        t: t_now,
                        kind: TraceEventKind::TaskPreempted {
                            pid: victim,
                            task: vtask,
                            resource: kind,
                            by: pid,
                            remaining,
                        },
                    });
                    self.sink.record(&TraceEvent {
                        t: t_now,
                        kind: TraceEventKind::TaskRequeued {
                            pid: victim,
                            task: vtask,
                            resource: kind,
                        },
                    });
                    // the preemptor starts in the vacated slots
                    self.sink.record(&TraceEvent {
                        t: t_now,
                        kind: TraceEventKind::TaskStarted {
                            pid,
                            task,
                            framework: fw_tag,
                            exec: exec_s,
                            read: read_t,
                            write: write_t,
                        },
                    });
                }
                self.emit_placed(t_now, pid, kind);
                let h = self.cal.schedule(total_s, Event::TaskDone(pid));
                let st = self.slab[pid as usize].as_mut().expect("live pipeline");
                st.pending_exec = exec_s;
                st.done_handle = Some(h);
                st.done_at = t_now + total_s;
                st.attempt_start = t_now;
                self.arm_fault_events(t_now, pid, kind);
            }
        }
        Ok(())
    }

    /// A task finished: release the slot (granting the scheduler's next
    /// waiter), record traces, apply model-metric effects, then advance
    /// the pipeline or complete it.
    fn on_task_done(&mut self, t: SimTime, pid: u32) -> Result<()> {
        self.c.tasks_executed += 1;
        // any armed fault/timeout for this attempt dies unfired
        self.cancel_fault_events(pid);
        // release + grant next waiters (several when a wide training job
        // frees room for multiple narrow tasks)
        let (task, fw_tag, exec_dur, kind, service) = {
            let st = self.slab[pid as usize].as_mut().expect("live");
            st.done_handle = None; // this completion just fired
            let node = st.tasks.get(st.cur);
            (
                node.task,
                node.framework,
                st.pending_exec,
                ResourceKind::for_task(node.task),
                st.pending_read + st.pending_exec + st.pending_write,
            )
        };
        // goodput numerator: the task's nominal service contributed to
        // the outcome (failure-lost tails are tallied separately)
        self.c.useful_work += service;
        if self.capture {
            self.sink.record(&TraceEvent {
                t,
                kind: TraceEventKind::TaskDone {
                    pid,
                    task,
                    framework: fw_tag,
                    exec: exec_dur,
                },
            });
        }
        // class slots free before the cluster release, so waiters
        // granted into the freed capacity can place into them
        self.unplace(t, pid, kind);
        let slots = self.cfg.infra.task_slots(task);
        let mut grants = std::mem::take(&mut self.grant_buf);
        grants.clear();
        match kind {
            ResourceKind::Training => self.training.release_all(t, &pid, slots, &mut grants),
            ResourceKind::Compute => self.compute.release_all(t, &pid, slots, &mut grants),
        };
        self.grant_buf = grants;
        self.apply_grants(t, kind);
        if self.cfg.record_traces {
            let slot = &mut self.h.exec[task.index()][fw_tag.map_or(0, |f| f.index() + 1)];
            let h = match *slot {
                Some(h) => h,
                None => {
                    // cold miss: ≤ 36 times per run
                    let mut key = SeriesKey::new(series::TASK_EXEC).tag("task", task.name());
                    if let Some(fw) = fw_tag {
                        key = key.tag("framework", fw.name());
                    }
                    let h = self.db.handle(key);
                    *slot = Some(h);
                    h
                }
            };
            self.db.append(h, t, exec_dur);
        }

        let truncated = self.apply_task_effects(t, pid, task);

        // advance or complete
        let done = {
            let st = self.slab[pid as usize].as_mut().expect("live");
            st.cur += 1;
            st.attempt = 1; // the next task starts its own attempt count
            truncated || st.cur >= st.tasks.len()
        };
        if done {
            self.finish_pipeline(t, pid, truncated);
            Ok(())
        } else {
            self.start_task(pid)
        }
    }

    /// Start every granted waiter in `self.grant_buf`: consume its
    /// remaining service (or the full read+exec+write), record the
    /// wait, emit the grant/start traces, and schedule its completion.
    /// Shared by task completion, slot failure (the victim's released
    /// slots may admit queued work), and slot repair.
    fn apply_grants(&mut self, t: SimTime, kind: ResourceKind) {
        let mut grants = std::mem::take(&mut self.grant_buf);
        for g in grants.drain(..) {
            let (resumed, nominal, pri, arr, slots) = {
                let w = self.slab[g.token as usize].as_mut().expect("queued pipeline");
                w.total_wait += g.waited;
                (
                    // a preempted or failed task resumes with its
                    // remaining service (incl. any failure-lost tail)
                    w.remaining_service.take(),
                    w.pending_read + w.pending_exec + w.pending_write,
                    w.priority,
                    w.arrived_at,
                    self.cfg.infra.task_slots(w.tasks.get(w.cur).task),
                )
            };
            // the grant is the placement point. Fresh grants run at the
            // placed class's speed; resumed remainders are wall-clock
            // service already, so re-placement never re-scales them.
            let job = JobCtx::new(resumed.unwrap_or(nominal), pri, arr).with_slots(slots);
            let speed = self.place_task(t, g.token, kind, &job);
            let (total, node, g_exec, g_read, g_write) = {
                let w = self.slab[g.token as usize].as_mut().expect("queued pipeline");
                let total = match resumed {
                    Some(rem) => rem,
                    None => {
                        w.pending_exec /= speed;
                        w.pending_read + w.pending_exec + w.pending_write
                    }
                };
                w.done_at = t + total;
                w.attempt_start = t;
                let node = w.tasks.get(w.cur);
                (total, node, w.pending_exec, w.pending_read, w.pending_write)
            };
            if self.cfg.record_traces {
                let h = match kind {
                    ResourceKind::Training => self.h.wait_t,
                    ResourceKind::Compute => self.h.wait_c,
                };
                self.db.append(h, t, g.waited);
            }
            if self.capture {
                self.sink.record(&TraceEvent {
                    t,
                    kind: TraceEventKind::TaskGranted {
                        pid: g.token,
                        task: node.task,
                        resource: kind,
                        waited: g.waited,
                    },
                });
                // the grant is also the task's service start: emit the
                // paired TaskStarted so queued tasks carry their
                // exec/read/write components like immediate starts do
                self.sink.record(&TraceEvent {
                    t,
                    kind: TraceEventKind::TaskStarted {
                        pid: g.token,
                        task: node.task,
                        framework: node.framework,
                        exec: g_exec,
                        read: g_read,
                        write: g_write,
                    },
                });
            }
            self.emit_placed(t, g.token, kind);
            let h = self.cal.schedule(total, Event::TaskDone(g.token));
            self.slab[g.token as usize]
                .as_mut()
                .expect("queued pipeline")
                .done_handle = Some(h);
            self.arm_fault_events(t, g.token, kind);
        }
        self.grant_buf = grants;
    }

    /// Arm the per-attempt fault and timeout events for `pid`'s task
    /// that just entered service on `kind`. No-op without a fault
    /// config for the cluster. When a fault-time distribution is set,
    /// exactly one sample is drawn per attempt — the stream position
    /// never depends on whether the fault lands inside the attempt
    /// (the MTBF pattern) — and `TaskFault` is scheduled only when it
    /// strikes before the completion. Timeouts draw nothing.
    fn arm_fault_events(&mut self, t: SimTime, pid: u32, kind: ResourceKind) {
        let Some(fc) = self.cfg.infra.fault_for(kind) else {
            return;
        };
        let (fault_time, timeout) = (fc.fault_time.clone(), fc.timeout);
        let done_at = self.slab[pid as usize]
            .as_ref()
            .expect("live pipeline")
            .done_at;
        let fault_h = fault_time.and_then(|d| {
            let gap = d.sample(&mut self.rng_fault).max(0.0);
            (t + gap < done_at).then(|| self.cal.schedule(gap, Event::TaskFault(pid)))
        });
        let timeout_h = (timeout > 0.0 && t + timeout < done_at)
            .then(|| self.cal.schedule(timeout, Event::TaskTimeout(pid)));
        let st = self.slab[pid as usize].as_mut().expect("live pipeline");
        st.fault_handle = fault_h;
        st.timeout_handle = timeout_h;
    }

    /// Cancel whatever fault/timeout events are still armed for `pid`'s
    /// in-flight attempt — called on normal completion, preemption,
    /// slot failure, and when the paired fault event fires first.
    fn cancel_fault_events(&mut self, pid: u32) {
        let (fh, th) = {
            let st = self.slab[pid as usize].as_mut().expect("live pipeline");
            (st.fault_handle.take(), st.timeout_handle.take())
        };
        if let Some(h) = fh {
            self.cal.cancel(h);
        }
        if let Some(h) = th {
            self.cal.cancel(h);
        }
    }

    /// Admission check for `pid`'s next task: `Some(queue depth)` when
    /// this is the pipeline's first task and the owning cluster's queue
    /// already sits at its configured `queue_cap` (0 = uncapped).
    fn shed_depth(&self, pid: u32) -> Option<usize> {
        let st = self.slab[pid as usize].as_ref().expect("live pipeline");
        if st.cur != 0 {
            return None; // mid-pipeline tasks are always admitted
        }
        let kind = ResourceKind::for_task(st.tasks.get(st.cur).task);
        let cap = self.cfg.infra.fault_for(kind).map_or(0, |fc| fc.queue_cap);
        if cap == 0 {
            return None;
        }
        let depth = match kind {
            ResourceKind::Training => self.training.queued(),
            ResourceKind::Compute => self.compute.queued(),
        };
        (depth as u64 >= cap).then_some(depth)
    }

    /// Terminal shed: the overloaded cluster turns the arrival away at
    /// admission, before it enters the queue.
    fn shed_pipeline(&mut self, t: SimTime, pid: u32, depth: usize) {
        let st = self.slab[pid as usize].take().expect("live pipeline");
        self.free.push(pid);
        self.c.live -= 1;
        self.c.shed += 1;
        if self.capture {
            let task = st.tasks.get(0).task;
            self.sink.record(&TraceEvent {
                t,
                kind: TraceEventKind::TaskShed {
                    pid,
                    task,
                    resource: ResourceKind::for_task(task),
                    queue_depth: depth as u32,
                },
            });
        }
        if let Some(slot) = st.retrain_of {
            // shed retraining: allow future triggers
            self.deployed[slot as usize].retraining = false;
        }
    }

    /// A task-level transient fault lands on `pid`'s in-flight attempt.
    fn on_task_fault(&mut self, t: SimTime, pid: u32) -> Result<()> {
        self.c.task_faults += 1;
        self.slab[pid as usize]
            .as_mut()
            .expect("live pipeline")
            .fault_handle = None; // this fault just fired
        self.cancel_fault_events(pid); // the paired timeout dies with it
        self.fail_attempt(t, pid, false)
    }

    /// `pid`'s in-flight attempt ran past the cluster's per-attempt
    /// timeout.
    fn on_task_timeout(&mut self, t: SimTime, pid: u32) -> Result<()> {
        self.c.task_timeouts += 1;
        self.slab[pid as usize]
            .as_mut()
            .expect("live pipeline")
            .timeout_handle = None; // this timeout just fired
        self.cancel_fault_events(pid); // the paired fault dies with it
        self.fail_attempt(t, pid, true)
    }

    /// Shared fault/timeout teardown: void the completion, charge the
    /// wasted attempt progress, free the slots (queued work may be
    /// granted into them), then consult the retry policy — a backoff
    /// re-queue through the calendar, or a terminal abandon.
    fn fail_attempt(&mut self, t: SimTime, pid: u32, timed_out: bool) -> Result<()> {
        let (dh, task, kind, slots, attempt, elapsed, arrived_at, priority) = {
            let st = self.slab[pid as usize].as_mut().expect("live pipeline");
            let dh = st
                .done_handle
                .take()
                .expect("faulted attempt had a scheduled completion");
            let task = st.tasks.get(st.cur).task;
            let elapsed = (t - st.attempt_start).max(0.0);
            // the attempt is void: a retry resamples its service from
            // scratch, so no remainder carries over
            st.remaining_service = None;
            (
                dh,
                task,
                ResourceKind::for_task(task),
                self.cfg.infra.task_slots(task),
                st.attempt,
                elapsed,
                st.arrived_at,
                st.priority,
            )
        };
        let cancelled = self.cal.cancel(dh);
        debug_assert!(cancelled, "faulted completion was pending");
        self.c.wasted_work += elapsed;
        if self.capture {
            let kind_ev = if timed_out {
                TraceEventKind::TaskTimedOut {
                    pid,
                    task,
                    resource: kind,
                    elapsed,
                }
            } else {
                TraceEventKind::TaskFailed {
                    pid,
                    task,
                    resource: kind,
                    attempt,
                    elapsed,
                }
            };
            self.sink.record(&TraceEvent { t, kind: kind_ev });
        }
        // the attempt's slots free up; queued work may start in them
        self.unplace(t, pid, kind);
        let mut grants = std::mem::take(&mut self.grant_buf);
        grants.clear();
        match kind {
            ResourceKind::Training => self.training.release_all(t, &pid, slots, &mut grants),
            ResourceKind::Compute => self.compute.release_all(t, &pid, slots, &mut grants),
        };
        self.grant_buf = grants;
        self.apply_grants(t, kind);
        // the policy decides; deadline slack mirrors the EDF
        // scheduler's `arrived_at + slack × priority class` deadline
        let queue_depth = match kind {
            ResourceKind::Training => self.training.queued(),
            ResourceKind::Compute => self.compute.queued(),
        };
        let ctx = RetryCtx {
            attempt,
            elapsed: t - arrived_at,
            deadline_slack: (arrived_at + DEADLINE_SLACK * priority) - t,
            queue_depth,
        };
        match self.retry.decide(&ctx) {
            RetryDecision::Retry { delay } => {
                let delay = delay.max(0.0);
                self.c.retries += 1;
                if self.capture {
                    self.sink.record(&TraceEvent {
                        t,
                        kind: TraceEventKind::TaskRetried {
                            pid,
                            task,
                            resource: kind,
                            attempt,
                            delay,
                        },
                    });
                }
                self.slab[pid as usize]
                    .as_mut()
                    .expect("live pipeline")
                    .attempt += 1;
                self.cal.schedule(delay, Event::TaskRetry(pid));
            }
            RetryDecision::Abandon => self.abandon_pipeline(t, pid, attempt),
        }
        Ok(())
    }

    /// Terminal abandon: the retry policy gave up on `pid`'s task, so
    /// the whole pipeline leaves the system without completing.
    /// Conservation becomes
    /// `arrived == completed + abandoned + shed + in_flight`.
    fn abandon_pipeline(&mut self, t: SimTime, pid: u32, attempts: u32) {
        let st = self.slab[pid as usize].take().expect("live pipeline");
        self.free.push(pid);
        self.c.live -= 1;
        self.c.abandoned += 1;
        if self.capture {
            self.sink.record(&TraceEvent {
                t,
                kind: TraceEventKind::PipelineAbandoned {
                    pid,
                    attempts,
                    makespan: t - st.arrived_at,
                },
            });
        }
        if let Some(slot) = st.retrain_of {
            // abandoned retraining: allow future triggers
            self.deployed[slot as usize].retraining = false;
        }
    }

    /// A retry backoff expired: re-submit `pid`'s current task with the
    /// restart flag set.
    fn on_task_retry(&mut self, pid: u32) -> Result<()> {
        self.start_task_inner(pid, true)
    }

    /// Failure injection: one slot on `kind`'s cluster dies. The failed
    /// slot is drawn uniformly over the *effective* (still-online)
    /// slots — busy slots take down the task running there, idle ones
    /// just shrink capacity until repair. Draw order per failure is
    /// part of the determinism contract: placement (when any slot is
    /// up), then MTTR (when the failure lands), then the next MTBF gap
    /// (always, so the stream position never depends on what was hit).
    fn on_slot_failed(&mut self, t: SimTime, kind: ResourceKind) -> Result<()> {
        let fc = self
            .cfg
            .infra
            .failure_for(kind)
            .expect("slot-failure events are only scheduled with a failure config")
            .clone();
        let (eff, busy) = {
            let res = match kind {
                ResourceKind::Training => &self.training,
                ResourceKind::Compute => &self.compute,
            };
            (res.effective_capacity(), res.in_use())
        };
        if eff > 0 {
            let u = self.rng_failure.below(eff);
            // map a busy placement to the pipeline occupying that slot:
            // walk the slab in pid order accumulating each running
            // task's slot width (slot-proportional blast radius)
            let mut victim: Option<u32> = None;
            if u < busy {
                let mut acc = 0usize;
                for (i, slot) in self.slab.iter().enumerate() {
                    if let Some(st) = slot {
                        if st.done_handle.is_some() {
                            let task = st.tasks.get(st.cur).task;
                            if ResourceKind::for_task(task) == kind {
                                acc += self.cfg.infra.task_slots(task) as usize;
                                if acc > u {
                                    victim = Some(i as u32);
                                    break;
                                }
                            }
                        }
                    }
                }
                debug_assert!(victim.is_some(), "busy slots imply a running owner");
            }
            // capacity shrinks *before* the victim's slots release, so
            // re-grant decisions already see the reduced cluster
            match kind {
                ResourceKind::Training => self.training.fail_slot(),
                ResourceKind::Compute => self.compute.fail_slot(),
            }
            // with hardware classes, the failed slot is attributed to a
            // class so placement capacity shrinks in the same ledger: a
            // busy hit takes a slot of the victim's (first) class, an
            // idle hit the first class with a free slot. The repair
            // event carries the class so recovery restores it.
            let class_hit = if self.class_pools[Self::pool_idx(kind)].is_some() {
                let pi = Self::pool_idx(kind);
                let ci = match victim {
                    Some(vpid) => self.slab[vpid as usize]
                        .as_ref()
                        .expect("failure victim is live")
                        .allocation
                        .first()
                        .map(|&(c, _)| c)
                        .unwrap_or(0),
                    None => {
                        let pool = self.class_pools[pi].as_ref().expect("checked above");
                        pool.classes.iter().position(|c| c.free() > 0).unwrap_or(0) as u32
                    }
                };
                let pool = self.class_pools[pi].as_mut().expect("checked above");
                pool.fail_slot(ci as usize);
                self.class_failures[pi][ci as usize] += 1;
                Some(ci)
            } else {
                None
            };
            let offline = match kind {
                ResourceKind::Training => self.training.offline(),
                ResourceKind::Compute => self.compute.offline(),
            } as u32;
            self.c.failures += 1;
            if self.capture {
                self.sink.record(&TraceEvent {
                    t,
                    kind: TraceEventKind::SlotFailed {
                        resource: kind,
                        offline,
                    },
                });
            }
            if let Some(vpid) = victim {
                self.fail_running_task(t, vpid, kind, &fc);
            }
            let mttr = fc.mttr.sample(&mut self.rng_failure).max(0.0);
            self.c.downtimes.push(mttr);
            let repair = match class_hit {
                Some(ci) => Event::ClassRepaired(kind, ci, mttr),
                None => Event::SlotRepaired(kind, mttr),
            };
            self.cal.schedule(mttr, repair);
        }
        // next failure on this cluster; like the other periodic events,
        // stop once the system has fully drained so max_pipelines runs
        // still terminate before the horizon
        let gap = fc.mtbf.sample(&mut self.rng_failure).max(0.0);
        let drained = self.c.arrivals_stopped && self.c.live == 0 && self.deployed.is_empty();
        if !drained && t + gap <= self.cfg.horizon {
            self.cal.schedule(gap, Event::SlotFailed(kind));
        }
        Ok(())
    }

    /// Blast radius of a busy-slot failure: cancel the victim's
    /// completion, charge the checkpoint/restart cost model, release
    /// its slots (queued work may be granted into the survivors), and
    /// re-queue it with the restart flag set so failure-aware
    /// schedulers can prioritize it.
    fn fail_running_task(
        &mut self,
        t: SimTime,
        pid: u32,
        kind: ResourceKind,
        fc: &ClusterFailureConfig,
    ) {
        let (vh, task, slots, new_rem, preserved, lost, priority, arrived_at) = {
            let st = self.slab[pid as usize].as_mut().expect("failure victim is live");
            let vh = st
                .done_handle
                .take()
                .expect("failure victim had a scheduled completion");
            let task = st.tasks.get(st.cur).task;
            let elapsed = (t - st.attempt_start).max(0.0);
            let work_left = (st.done_at - t).max(0.0);
            let ci = fc.checkpoint_interval;
            // the attempt progress since the last checkpoint boundary is
            // lost — the whole attempt when checkpointing is off — and
            // the restart cost is paid on top in both modes
            let lost_tail = if ci > 0.0 {
                elapsed - (elapsed / ci).floor() * ci
            } else {
                elapsed
            };
            let lost = lost_tail + fc.restart_cost;
            let new_rem = work_left + lost;
            st.remaining_service = Some(new_rem);
            (
                vh,
                task,
                self.cfg.infra.task_slots(task),
                new_rem,
                elapsed - lost_tail,
                lost,
                st.priority,
                st.arrived_at,
            )
        };
        let cancelled = self.cal.cancel(vh);
        debug_assert!(cancelled, "victim completion was pending");
        self.cancel_fault_events(pid);
        self.c.lost_work += lost;
        if self.capture {
            self.sink.record(&TraceEvent {
                t,
                kind: TraceEventKind::TaskCheckpointed {
                    pid,
                    task,
                    preserved,
                    lost,
                },
            });
        }
        // release the victim's slots under the already-reduced capacity
        // (class slots first, so re-granted waiters can place there)
        self.unplace(t, pid, kind);
        let mut grants = std::mem::take(&mut self.grant_buf);
        grants.clear();
        match kind {
            ResourceKind::Training => self.training.release_all(t, &pid, slots, &mut grants),
            ResourceKind::Compute => self.compute.release_all(t, &pid, slots, &mut grants),
        };
        self.grant_buf = grants;
        self.apply_grants(t, kind);
        // re-queue the victim with its restart remainder
        let job = JobCtx::new(new_rem, priority, arrived_at)
            .with_slots(slots)
            .after_restart();
        let acquired = match kind {
            ResourceKind::Training => self.training.request(t, pid, job),
            ResourceKind::Compute => self.compute.request(t, pid, job),
        };
        if self.capture {
            self.sink.record(&TraceEvent {
                t,
                kind: TraceEventKind::TaskRestarted {
                    pid,
                    task,
                    resource: kind,
                    remaining: new_rem,
                },
            });
        }
        match acquired {
            AcquireResult::Acquired => {
                // room left on the shrunken cluster: restart immediately.
                // The remainder is wall-clock (already-scaled) service,
                // so the fresh placement's speed never re-scales it.
                self.place_task(t, pid, kind, &job);
                self.emit_placed(t, pid, kind);
                let h = self.cal.schedule(new_rem, Event::TaskDone(pid));
                let st = self.slab[pid as usize].as_mut().expect("failure victim is live");
                st.remaining_service = None;
                st.done_handle = Some(h);
                st.done_at = t + new_rem;
                st.attempt_start = t;
                self.arm_fault_events(t, pid, kind);
            }
            AcquireResult::Queued => {
                // remaining_service stays set; consumed at the grant
            }
            AcquireResult::Preempted { victim } => {
                // the restarted job evicted a lower-priority task (the
                // scheduler already re-queued it) — mirrors the
                // preemption arm of start_task
                let (wh, vtask, remaining) = {
                    let vst = self.slab[victim as usize]
                        .as_mut()
                        .expect("preemption victim is live");
                    let wh = vst
                        .done_handle
                        .take()
                        .expect("preemption victim had a scheduled completion");
                    let remaining = (vst.done_at - t).max(0.0);
                    vst.remaining_service = Some(remaining);
                    (wh, vst.tasks.get(vst.cur).task, remaining)
                };
                let cancelled = self.cal.cancel(wh);
                debug_assert!(cancelled, "victim completion was pending");
                self.cancel_fault_events(victim);
                self.c.preemptions += 1;
                // evicted class slots free up, then the restart places
                self.unplace(t, victim, kind);
                self.place_task(t, pid, kind, &job);
                if self.capture {
                    self.sink.record(&TraceEvent {
                        t,
                        kind: TraceEventKind::TaskPreempted {
                            pid: victim,
                            task: vtask,
                            resource: kind,
                            by: pid,
                            remaining,
                        },
                    });
                    self.sink.record(&TraceEvent {
                        t,
                        kind: TraceEventKind::TaskRequeued {
                            pid: victim,
                            task: vtask,
                            resource: kind,
                        },
                    });
                }
                self.emit_placed(t, pid, kind);
                let h = self.cal.schedule(new_rem, Event::TaskDone(pid));
                let st = self.slab[pid as usize].as_mut().expect("failure victim is live");
                st.remaining_service = None;
                st.done_handle = Some(h);
                st.done_at = t + new_rem;
                st.attempt_start = t;
                self.arm_fault_events(t, pid, kind);
            }
        }
    }

    /// A failed slot on `kind`'s cluster comes back: restore capacity
    /// and grant queued tasks into the recovered slot in scheduler
    /// order.
    fn on_slot_repaired(&mut self, t: SimTime, kind: ResourceKind, downtime: f64) {
        let mut grants = std::mem::take(&mut self.grant_buf);
        grants.clear();
        let offline = match kind {
            ResourceKind::Training => {
                self.training.repair_slot(t, &mut grants);
                self.training.offline()
            }
            ResourceKind::Compute => {
                self.compute.repair_slot(t, &mut grants);
                self.compute.offline()
            }
        } as u32;
        self.grant_buf = grants;
        self.c.repairs += 1;
        if self.capture {
            self.sink.record(&TraceEvent {
                t,
                kind: TraceEventKind::SlotRepaired {
                    resource: kind,
                    offline,
                    downtime,
                },
            });
        }
        self.apply_grants(t, kind);
    }

    /// Per-class failure injection: one slot of hardware class `ci` on
    /// `kind`'s cluster dies. Mirrors [`Simulation::on_slot_failed`],
    /// except the placement draw is uniform over the *class's* online
    /// slots and the blast radius only reaches tasks with slots
    /// allocated in that class — other classes keep running, bounding
    /// the blast radius to one failure domain.
    fn on_class_failed(&mut self, t: SimTime, kind: ResourceKind, ci: u32) -> Result<()> {
        let fc = self
            .cfg
            .infra
            .hw_classes_for(kind)
            .and_then(|cs| cs.get(ci as usize))
            .and_then(|hc| hc.failures.clone())
            .expect("class-failure events are only scheduled with a class failure config");
        let pi = Self::pool_idx(kind);
        let (online, busy) = {
            let pool = self.class_pools[pi].as_ref().expect("class events imply a pool");
            let c = &pool.classes[ci as usize];
            (c.online(), c.in_use)
        };
        if online > 0 {
            let u = self.rng_failure.below(online);
            // map a busy placement to the pipeline occupying it: walk
            // the slab in pid order accumulating each running task's
            // slots allocated *in this class*
            let mut victim: Option<u32> = None;
            if u < busy {
                let mut acc = 0usize;
                for (i, slot) in self.slab.iter().enumerate() {
                    if let Some(st) = slot {
                        if st.done_handle.is_some()
                            && ResourceKind::for_task(st.tasks.get(st.cur).task) == kind
                        {
                            let width: u32 = st
                                .allocation
                                .iter()
                                .filter(|&&(c, _)| c == ci)
                                .map(|&(_, n)| n)
                                .sum();
                            if width > 0 {
                                acc += width as usize;
                                if acc > u {
                                    victim = Some(i as u32);
                                    break;
                                }
                            }
                        }
                    }
                }
                debug_assert!(victim.is_some(), "busy class slots imply a running owner");
            }
            // both ledgers shrink before the victim's slots release
            match kind {
                ResourceKind::Training => self.training.fail_slot(),
                ResourceKind::Compute => self.compute.fail_slot(),
            }
            self.class_pools[pi]
                .as_mut()
                .expect("class events imply a pool")
                .fail_slot(ci as usize);
            self.class_failures[pi][ci as usize] += 1;
            let offline = match kind {
                ResourceKind::Training => self.training.offline(),
                ResourceKind::Compute => self.compute.offline(),
            } as u32;
            self.c.failures += 1;
            if self.capture {
                self.sink.record(&TraceEvent {
                    t,
                    kind: TraceEventKind::SlotFailed {
                        resource: kind,
                        offline,
                    },
                });
            }
            if let Some(vpid) = victim {
                self.fail_running_task(t, vpid, kind, &fc);
            }
            let mttr = fc.mttr.sample(&mut self.rng_failure).max(0.0);
            self.c.downtimes.push(mttr);
            self.cal.schedule(mttr, Event::ClassRepaired(kind, ci, mttr));
        }
        // next failure of this class; same drain rule as the cluster-
        // level stream, and the gap is always drawn so the stream
        // position never depends on what was hit
        let gap = fc.mtbf.sample(&mut self.rng_failure).max(0.0);
        let drained = self.c.arrivals_stopped && self.c.live == 0 && self.deployed.is_empty();
        if !drained && t + gap <= self.cfg.horizon {
            self.cal.schedule(gap, Event::ClassFailed(kind, ci));
        }
        Ok(())
    }

    /// A failed slot of class `ci` comes back: restore the class ledger
    /// first, so queued tasks granted by the cluster-level repair can
    /// place into the recovered slot, then run the shared repair path.
    fn on_class_repaired(&mut self, t: SimTime, kind: ResourceKind, ci: u32, downtime: f64) {
        self.class_pools[Self::pool_idx(kind)]
            .as_mut()
            .expect("class events imply a pool")
            .repair_slot(ci as usize);
        self.on_slot_repaired(t, kind, downtime);
    }

    /// Task-specific model-metric effects; returns whether the quality
    /// gate truncated the pipeline.
    fn apply_task_effects(&mut self, t: SimTime, pid: u32, task: TaskType) -> bool {
        let mut truncated = false;
        // (pid, performance) to emit as a ModelMetricUpdate trace event
        let mut metric_update = None;
        let st = self.slab[pid as usize].as_mut().expect("live");
        match task {
            TaskType::Train => {
                let laws = &self.params.model_laws;
                st.metrics.performance =
                    (laws.perf_mean + laws.perf_sd * self.rng_noise.normal()).clamp(0.05, 0.999);
                st.metrics.size_mb =
                    (laws.size_ln_mean + laws.size_ln_sd * self.rng_noise.normal()).exp();
                st.metrics.inference_ms = (laws.inference_ln_mean
                    + laws.inference_ln_sd * self.rng_noise.normal())
                .exp();
                st.metrics.clever_score = self.rng_noise.uniform() * laws.clever_max;
                st.metrics.confidence =
                    st.metrics.performance * (0.9 + 0.1 * self.rng_noise.uniform());
                st.model_bytes = st.metrics.size_mb * 1e6;
                metric_update = Some(st.metrics.performance);
            }
            TaskType::Compress => {
                let prune = 0.2 + 0.6 * self.rng_noise.uniform();
                st.metrics = self.compression.apply(prune, &st.metrics);
                st.model_bytes = st.metrics.size_mb * 1e6;
                metric_update = Some(st.metrics.performance);
            }
            TaskType::Harden => {
                st.metrics.clever_score = (st.metrics.clever_score * 1.5).min(5.0);
                st.metrics.performance *= 0.99;
                metric_update = Some(st.metrics.performance);
            }
            TaskType::Evaluate => {
                // quality gate: pipelines whose model fails are aborted
                // (Fig 3's gates)
                if st.metrics.performance < 0.55 {
                    truncated = true;
                }
            }
            TaskType::Deploy => {
                if self.cfg.runtime_view.enabled {
                    let mut deployed_slot = None;
                    if let Some(slot) = st.retrain_of {
                        self.deployed[slot as usize].redeploy(t, st.metrics.performance);
                        deployed_slot = Some((slot, self.deployed[slot as usize].version));
                    } else if self.deployed.len() < self.cfg.runtime_view.max_models {
                        self.deployed.push(DeployedModel::new(
                            self.c.models_deployed,
                            st.framework,
                            st.metrics.performance,
                            t,
                            1,
                        ));
                        deployed_slot = Some((self.deployed.len() as u32 - 1, 1));
                    }
                    self.c.models_deployed += 1;
                    if self.capture {
                        if let Some((slot, version)) = deployed_slot {
                            self.sink.record(&TraceEvent {
                                t,
                                kind: TraceEventKind::ModelDeployed {
                                    slot,
                                    performance: st.metrics.performance,
                                    version,
                                },
                            });
                        }
                    }
                }
            }
            TaskType::Preprocess => {}
        }
        if self.capture {
            if let Some(performance) = metric_update {
                self.sink.record(&TraceEvent {
                    t,
                    kind: TraceEventKind::ModelMetricUpdate {
                        pid,
                        task,
                        performance,
                    },
                });
            }
        }
        truncated
    }

    /// Free the pipeline's slab slot and record completion outcomes.
    fn finish_pipeline(&mut self, t: SimTime, pid: u32, truncated: bool) {
        let st = self.slab[pid as usize].take().expect("live");
        self.free.push(pid);
        self.c.live -= 1;
        self.c.completed += 1;
        if truncated {
            self.c.gate_failures += 1;
        }
        // SLO attainment: completed within the EDF deadline. Priority-0
        // retrains get one slack class — a zero-width deadline would
        // make them unmeetable by definition.
        if t <= st.arrived_at + DEADLINE_SLACK * st.priority.max(1.0) {
            self.c.slo_met += 1;
        }
        self.db.append(self.h.completions, t, t - st.arrived_at);
        self.db.append(self.h.pipeline_wait, t, st.total_wait);
        if self.capture {
            self.sink.record(&TraceEvent {
                t,
                kind: TraceEventKind::PipelineDone {
                    pid,
                    makespan: t - st.arrived_at,
                    total_wait: st.total_wait,
                    truncated,
                },
            });
        }
        if let (Some(slot), true) = (st.retrain_of, truncated) {
            // failed retraining: allow future triggers
            self.deployed[slot as usize].retraining = false;
        }
    }

    /// Periodic utilization/queue sampling.
    fn on_monitor(&mut self, t: SimTime) {
        self.db.append(
            self.h.util_t,
            t,
            self.training.in_use() as f64 / self.training.capacity() as f64,
        );
        self.db.append(
            self.h.util_c,
            t,
            self.compute.in_use() as f64 / self.compute.capacity() as f64,
        );
        self.db.append(self.h.q_t, t, self.training.queued() as f64);
        self.db.append(self.h.q_c, t, self.compute.queued() as f64);
        if !self.deployed.is_empty() {
            let mean: f64 = self.deployed.iter().map(|m| m.performance).sum::<f64>()
                / self.deployed.len() as f64;
            self.db.append(self.h.model_perf, t, mean);
        }
        let rss = rss_mb();
        if rss > self.c.peak_rss {
            self.c.peak_rss = rss;
        }
        // stop sampling once the system has fully drained — otherwise a
        // max_pipelines run with a far horizon would tick forever. The
        // condition matches `on_drift`'s: while models remain deployed,
        // retraining launches can revive the system, so sampling must
        // continue or the utilization/queue/model_perf series would
        // under-report the retraining load (ROADMAP open item; digest
        // version bumped to 2 for this).
        let drained = self.c.arrivals_stopped && self.c.live == 0 && self.deployed.is_empty();
        if !drained && t + self.cfg.sample_interval <= self.cfg.horizon {
            self.cal.schedule(self.cfg.sample_interval, Event::Monitor);
        }
    }

    /// Run-time view detector sweep: advance each deployed model's drift
    /// process, then ask the retraining trigger strategy to decide.
    fn on_drift(&mut self, t: SimTime) {
        let rv = &self.cfg.runtime_view;
        for slot in 0..self.deployed.len() {
            let m = &mut self.deployed[slot];
            m.tick(
                t,
                rv.decay_per_day,
                rv.sudden_drift_prob,
                rv.sudden_drift_drop,
                &mut self.rng_drift,
            );
            if m.retraining {
                continue;
            }
            if let Some(delay) = self.trigger.decide(&m.trigger_ctx(t)) {
                m.retraining = true;
                if self.capture {
                    let (drift, performance) = (m.drift, m.performance);
                    self.sink.record(&TraceEvent {
                        t,
                        kind: TraceEventKind::RetrainTriggered {
                            slot: slot as u32,
                            drift,
                            performance,
                            delay,
                        },
                    });
                }
                self.cal.schedule(delay, Event::RetrainLaunch(slot as u32));
            }
        }
        let drained = self.c.arrivals_stopped && self.c.live == 0 && self.deployed.is_empty();
        if !drained && t + rv.detector_interval <= self.cfg.horizon {
            self.cal.schedule(rv.detector_interval, Event::Drift);
        }
    }

    /// A triggered retraining launches: inject a train–evaluate–deploy
    /// pipeline at platform priority 0.
    fn on_retrain_launch(&mut self, t: SimTime, slot: u32) -> Result<()> {
        self.c.retrains += 1;
        self.db.append(self.h.retrains, t, 1.0);
        if self.capture {
            self.sink.record(&TraceEvent {
                t,
                kind: TraceEventKind::RetrainLaunched { slot },
            });
        }
        let fw = self.deployed[slot as usize].framework;
        let (asset, preproc_t) = self.asset_synth.next()?;
        // retraining pipeline: train – evaluate – deploy
        let st = PipelineState {
            tasks: TaskList::from_slice(&[
                TaskNode::with_framework(TaskType::Train, fw),
                TaskNode::new(TaskType::Evaluate),
                TaskNode::new(TaskType::Deploy),
            ]),
            cur: 0,
            framework: fw,
            asset,
            preproc_t,
            train_t: 60.0,
            metrics: ModelMetrics::default(),
            model_bytes: 1e7,
            arrived_at: t,
            total_wait: 0.0,
            pending_exec: 0.0,
            pending_read: 0.0,
            pending_write: 0.0,
            done_handle: None,
            done_at: 0.0,
            remaining_service: None,
            attempt_start: 0.0,
            allocation: Vec::new(),
            attempt: 1,
            fault_handle: None,
            timeout_handle: None,
            retrain_of: Some(slot),
            priority: 0.0, // retrains jump the queue
        };
        self.c.arrived += 1;
        self.db.append(self.h.arrivals, t, 1.0);
        let n_tasks = st.tasks.len() as u8;
        let pid = self.alloc_pid(st);
        self.c.live += 1;
        if self.capture {
            self.sink.record(&TraceEvent {
                t,
                kind: TraceEventKind::PipelineArrival {
                    pid,
                    framework: fw,
                    n_tasks,
                    priority: 0.0,
                    retrain_of: Some(slot),
                },
            });
        }
        self.start_task(pid)
    }

    /// Assemble the [`ExperimentResult`] from the final world state.
    /// Fails only when a streaming sink cannot finalize its output
    /// ([`TraceSink::finish`] — e.g. the footer write hit a full disk).
    fn finish(mut self, started: std::time::Instant) -> Result<ExperimentResult> {
        let horizon_covered = self.cal.now().min(self.cfg.horizon);
        let final_perf = if self.deployed.is_empty() {
            0.0
        } else {
            self.deployed.iter().map(|m| m.performance).sum::<f64>() / self.deployed.len() as f64
        };
        let pool_refills = self.train_pools.iter().map(|p| p.refills).sum::<u64>()
            + self.eval_pool.refills;
        let scheduler = self.cfg.infra.scheduler_label();
        let trigger = self.cfg.trigger_label();
        // reliability analytics: goodput is the fraction of delivered
        // service that contributed to outcomes; recovery percentiles
        // summarize the MTTR samples of landed failures
        let goodput = if self.c.lost_work > 0.0 {
            self.c.useful_work / (self.c.useful_work + self.c.lost_work)
        } else {
            1.0
        };
        let mut downtimes = std::mem::take(&mut self.c.downtimes);
        downtimes.sort_by(|a, b| a.partial_cmp(b).expect("downtimes are finite"));
        let recovery_p50 = pct(&downtimes, 0.50);
        let recovery_p95 = pct(&downtimes, 0.95);
        // hardware-class accounting: settle busy-time integrals at the
        // covered horizon, then fold per-class busy seconds into dollar
        // cost and label per-class utilization / failure counts as
        // "<cluster>/<class>" in [training, compute] x config order
        let mut cost = 0.0;
        let mut class_util: Vec<(String, f64)> = Vec::new();
        let mut class_failures: Vec<(String, u64)> = Vec::new();
        for (pi, kind) in [ResourceKind::Training, ResourceKind::Compute].iter().enumerate() {
            if let Some(pool) = self.class_pools[pi].as_mut() {
                pool.settle(horizon_covered);
                cost += pool.cost();
                for (ci, c) in pool.classes.iter().enumerate() {
                    let label = format!("{}/{}", kind.name(), c.cfg.name);
                    class_util.push((label.clone(), pool.utilization(ci, horizon_covered)));
                    class_failures.push((label, self.class_failures[pi][ci]));
                }
            }
        }
        let placer = self.cfg.infra.placer_label().unwrap_or_default();
        let retry = self.cfg.infra.retry_label().unwrap_or_default();
        // SLO attainment over completed pipelines; 0 with none completed
        let deadline_attainment = if self.c.completed > 0 {
            self.c.slo_met as f64 / self.c.completed as f64
        } else {
            0.0
        };
        // the stream is complete: streaming sinks finalize (string-table
        // + meta footer, flush) before the result is assembled
        self.sink.finish()?;
        // everything in the trace meta is config-derived
        // (ExperimentConfig::trace_meta — shared with streaming sinks),
        // so two captures of the same (config, seed) produce
        // byte-identical trace files
        let trace = self.capture.then(|| Trace {
            meta: self.cfg.trace_meta(),
            events: self.sink.drain(),
        });
        // fold the meter readings into a self-contained report (string
        // labels only, so exporters need no simulator types); built
        // before the result literal because `self.db` moves into it
        let meter = self.meter.enabled().then(|| MeterReport {
            events_by_kind: EVENT_KINDS
                .iter()
                .zip(self.meter.events_by_kind())
                .map(|(k, &n)| (k.to_string(), n))
                .collect(),
            wall_ns_by_kind: EVENT_KINDS
                .iter()
                .zip(self.meter.wall_ns_by_kind())
                .map(|(k, &n)| (k.to_string(), n))
                .collect(),
            calendar_scheduled: self.cal.scheduled_total(),
            calendar_cancelled: self.cal.cancelled_total(),
            calendar_compactions: self.cal.compactions_total(),
            calendar_depth_hwm: self.meter.depth_hwm(),
            heap_rebuilds: vec![
                ("training".into(), self.training.index_rebuilds()),
                ("compute".into(), self.compute.index_rebuilds()),
            ],
            requests: vec![
                ("training".into(), self.training.total_requests),
                ("compute".into(), self.compute.total_requests),
            ],
            queued: vec![
                ("training".into(), self.training.total_queued),
                ("compute".into(), self.compute.total_queued),
            ],
            grants: vec![
                ("training".into(), self.training.wait_stats.count),
                ("compute".into(), self.compute.wait_stats.count),
            ],
            preemptions: self.c.preemptions,
            placements: self.c.placements,
            rng_draws: vec![
                ("arrival".into(), self.rng_arrival.draws()),
                ("noise".into(), self.rng_noise.draws()),
                ("drift".into(), self.rng_drift.draws()),
                ("failure".into(), self.rng_failure.draws()),
                ("fault".into(), self.rng_fault.draws()),
            ],
            alloc_events: self.meter.alloc_events(),
        });
        Ok(ExperimentResult {
            name: self.cfg.name,
            seed: self.cfg.seed,
            horizon: horizon_covered,
            arrived: self.c.arrived,
            completed: self.c.completed,
            in_flight: self.c.live,
            tasks_executed: self.c.tasks_executed,
            gate_failures: self.c.gate_failures,
            preemptions: self.c.preemptions,
            failures: self.c.failures,
            repairs: self.c.repairs,
            lost_work: self.c.lost_work,
            goodput,
            recovery_p50,
            recovery_p95,
            task_faults: self.c.task_faults,
            task_timeouts: self.c.task_timeouts,
            retries: self.c.retries,
            abandoned: self.c.abandoned,
            shed: self.c.shed,
            wasted_work: self.c.wasted_work,
            deadline_attainment,
            retrains_triggered: self.c.retrains,
            models_deployed: self.c.models_deployed,
            events_processed: self.c.events,
            util_training: self.training.utilization(horizon_covered),
            util_compute: self.compute.utilization(horizon_covered),
            wait_training: self.training.wait_stats.clone(),
            wait_compute: self.compute.wait_stats.clone(),
            avg_queue_training: self.training.avg_queue_len(horizon_covered),
            avg_queue_compute: self.compute.avg_queue_len(horizon_covered),
            final_mean_performance: final_perf,
            wire_read_bytes: self.c.wire_read,
            wire_write_bytes: self.c.wire_write,
            wall_secs: started.elapsed().as_secs_f64(),
            peak_rss_mb: self.c.peak_rss,
            sampler_backend: self.backend.name().into(),
            pool_refills,
            cost,
            class_util,
            class_failures,
            scheduler,
            trigger,
            placer,
            retry,
            trace,
            meter,
            tsdb: self.db,
        })
    }
}

/// Nearest-rank percentile of an ascending-sorted sample; 0 when empty.
fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

/// Pad a fitted mixture to exactly K1 components (the AOT sampler's fixed
/// shape); extra components get -inf-ish weight. Mixtures that already
/// have the right shape (the common case: every fit produces K1
/// components) are shared, not copied.
fn pad_gmm(g: &Arc<Gmm1>) -> Arc<Gmm1> {
    if g.k() == K1 {
        return g.clone();
    }
    let mut out = Gmm1 {
        logw: vec![-60.0; K1],
        mu: vec![0.0; K1],
        logsd: vec![0.0; K1],
    };
    for i in 0..g.k().min(K1) {
        out.logw[i] = g.logw[i];
        out.mu[i] = g.mu[i];
        out.logsd[i] = g.logsd[i];
    }
    Arc::new(out)
}
