//! The discrete-event experiment runner — PipeSim's simulator core
//! (paper section V-B) on the Rust DES substrate.
//!
//! Each pipeline execution is a small state machine over the calendar:
//! arrival → per task: request resource (queue if saturated) →
//! read → exec → write → release → next task → completion. Durations come
//! from the fitted statistical models, batch-sampled through the AOT
//! artifacts. The optional run-time view ages deployed models and feeds
//! retraining pipelines back into the arrival stream (Fig 7).

use std::sync::Arc;

use crate::arrivals::ArrivalModel;
use crate::des::{AcquireResult, Calendar, Resource, SimTime};
use crate::error::Result;
use crate::model::pipeline::TaskNode;
use crate::model::{
    CompressionModel, DataAsset, Framework, ModelMetrics, ResourceKind, TaskExecutor, TaskType,
};
use crate::runtime::pool::{Backend, SamplePool1};
use crate::runtime::{Runtime, K1};
use crate::stats::gmm::Gmm1;
use crate::stats::rng::Pcg64;
use crate::synth::{AssetSynthesizer, PipelineSynthesizer, TaskList};
use crate::tsdb::{SeriesHandle, SeriesKey, TsStore};

use super::config::{ArrivalSpec, ExperimentConfig};
use super::params::SimParams;
use super::result::{rss_mb, series, ExperimentResult};
use super::triggers::DeployedModel;

/// Calendar events.
#[derive(Clone, Copy, Debug)]
enum Event {
    /// Next pipeline arrival (self-rescheduling).
    Arrival,
    /// Task of pipeline `pid` finished (exec + write done).
    TaskDone(u32),
    /// Periodic utilization/queue sampling.
    Monitor,
    /// Run-time view detector sweep.
    Drift,
    /// Launch a (possibly deferred) retraining for deployed-model slot.
    RetrainLaunch(u32),
}

/// Per-pipeline execution state (slab-allocated, freed on completion so
/// memory scales with *concurrent*, not total, pipelines).
struct PipelineState {
    tasks: TaskList,
    cur: usize,
    framework: Framework,
    asset: DataAsset,
    preproc_t: f64,
    /// Last sampled training duration (drives compress/harden cost).
    train_t: f64,
    metrics: ModelMetrics,
    model_bytes: f64,
    arrived_at: SimTime,
    total_wait: SimTime,
    /// Sampled exec duration for the task awaiting a resource grant.
    pending_exec: f64,
    pending_read: f64,
    pending_write: f64,
    /// Deployed-model slot to refresh when this (retraining) run deploys.
    retrain_of: Option<u32>,
    /// User priority (lower = more important; Fig 4's "model
    /// prioritization"). Retraining pipelines get priority 0.
    priority: f64,
}

/// An experiment: config + fitted parameters (+ optional PJRT runtime).
///
/// Parameters and runtime are `Arc`-shared: constructing an experiment
/// from an existing `Arc<SimParams>` copies two pointers, so a parameter
/// sweep can stamp out thousands of runs without re-cloning the fitted
/// models (the former per-experiment clone storm).
pub struct Experiment {
    cfg: ExperimentConfig,
    params: Arc<SimParams>,
    runtime: Option<Arc<Runtime>>,
}

impl Experiment {
    pub fn new(cfg: ExperimentConfig, params: impl Into<Arc<SimParams>>) -> Self {
        Experiment {
            cfg,
            params: params.into(),
            runtime: None,
        }
    }

    /// Use the AOT artifacts for all simulation-time sampling.
    pub fn with_runtime(mut self, rt: Option<Arc<Runtime>>) -> Self {
        self.runtime = rt;
        self
    }

    /// Run to completion; single-threaded, deterministic per seed.
    pub fn run(self) -> Result<ExperimentResult> {
        let started = std::time::Instant::now();
        let Experiment {
            cfg,
            params,
            runtime,
        } = self;
        cfg.validate()?;
        let params: &SimParams = &params;
        let backend = match &runtime {
            Some(rt) => Backend::Runtime(rt.clone()),
            None => Backend::Cpu,
        };

        let mut root = Pcg64::new(cfg.seed);
        let mut rng_arrival = root.substream(1);
        let rng_pipe = root.substream(2);
        let mut rng_asset = root.substream(3);
        let mut rng_noise = root.substream(4);
        let mut rng_drift = root.substream(5);

        // --- samplers (all mixture handles are Arc clones — no deep
        // copies of fitted parameters per experiment) ------------------
        let mut asset_synth = AssetSynthesizer::new(
            backend.clone(),
            params.asset_gmm.clone(),
            params.preproc_curve,
            params.preproc_noise,
            &mut rng_asset,
        );
        let mut pipe_synth = PipelineSynthesizer::new(cfg.synth, rng_pipe);
        let mut train_pools: Vec<SamplePool1> = Framework::ALL
            .iter()
            .map(|fw| {
                SamplePool1::new(
                    backend.clone(),
                    pad_gmm(params.train_gmm_shared(*fw)),
                    root.substream(0x100 + fw.index() as u64),
                )
            })
            .collect();
        let mut eval_pool = SamplePool1::new(
            backend.clone(),
            pad_gmm(&params.eval_log_gmm),
            root.substream(0x200),
        );
        let mut arrival = match cfg.arrival {
            ArrivalSpec::Random => params.arrival_random.clone(),
            ArrivalSpec::Profile => params.arrival_profile.clone(),
            ArrivalSpec::Replay => params.arrival_replay.clone(),
            ArrivalSpec::Poisson { mean_interarrival } => {
                ArrivalModel::Poisson { mean_interarrival }
            }
        };
        let compression = CompressionModel::from_table1();

        // --- world ----------------------------------------------------
        let mut cal: Calendar<Event> = Calendar::new();
        let mut training: Resource<u32> =
            Resource::with_discipline("training", cfg.infra.training_capacity, cfg.infra.discipline);
        let mut compute: Resource<u32> =
            Resource::with_discipline("compute", cfg.infra.compute_capacity, cfg.infra.discipline);
        let mut slab: Vec<Option<PipelineState>> = Vec::new();
        let mut free: Vec<u32> = Vec::new();
        let mut deployed: Vec<DeployedModel> = Vec::new();
        let mut db = TsStore::new();

        // interned hot-path series
        let h_arrivals = db.handle(SeriesKey::new(series::ARRIVALS));
        let h_completions = db.handle(SeriesKey::new(series::COMPLETIONS));
        let h_pipeline_wait = db.handle(SeriesKey::new(series::PIPELINE_WAIT));
        let h_util_t = db.handle(SeriesKey::new(series::UTILIZATION).tag("resource", "training"));
        let h_util_c = db.handle(SeriesKey::new(series::UTILIZATION).tag("resource", "compute"));
        let h_q_t = db.handle(SeriesKey::new(series::QUEUE_LEN).tag("resource", "training"));
        let h_q_c = db.handle(SeriesKey::new(series::QUEUE_LEN).tag("resource", "compute"));
        let h_wait_t = db.handle(SeriesKey::new(series::TASK_WAIT).tag("resource", "training"));
        let h_wait_c = db.handle(SeriesKey::new(series::TASK_WAIT).tag("resource", "compute"));
        let h_traffic_r = db.handle(SeriesKey::new(series::TRAFFIC).tag("dir", "read"));
        let h_traffic_w = db.handle(SeriesKey::new(series::TRAFFIC).tag("dir", "write"));
        let h_model_perf = db.handle(SeriesKey::new(series::MODEL_PERF));
        let h_retrains = db.handle(SeriesKey::new(series::RETRAINS));
        // task exec series per (task, framework): a flat array indexed by
        // (task, framework+1) — the per-event path never hashes anything,
        // and the tag strings intern into the store's symbol table once
        const N_FW: usize = Framework::ALL.len() + 1; // +1 = untagged
        let mut h_exec: [[Option<SeriesHandle>; N_FW]; TaskType::ALL.len()] =
            [[None; N_FW]; TaskType::ALL.len()];

        // --- counters ---------------------------------------------------
        let mut arrived: u64 = 0;
        let mut live: u64 = 0; // pipelines in flight (slab occupancy)
        let mut arrivals_stopped = false;
        let mut completed: u64 = 0;
        let mut tasks_executed: u64 = 0;
        let mut gate_failures: u64 = 0;
        let mut retrains: u64 = 0;
        let mut models_deployed: u64 = 0;
        let mut events: u64 = 0;
        let mut wire_read = 0.0f64;
        let mut wire_write = 0.0f64;
        let mut peak_rss = rss_mb();

        // helpers -------------------------------------------------------
        macro_rules! resource_for {
            ($kind:expr) => {
                match $kind {
                    ResourceKind::Training => &mut training,
                    ResourceKind::Compute => &mut compute,
                }
            };
        }

        macro_rules! alloc_pid {
            ($st:expr) => {{
                if let Some(pid) = free.pop() {
                    slab[pid as usize] = Some($st);
                    pid
                } else {
                    slab.push(Some($st));
                    (slab.len() - 1) as u32
                }
            }};
        }

        // sample the exec duration for the current task of `st`
        macro_rules! sample_exec {
            ($st:expr) => {{
                let task = $st.tasks.get($st.cur).task;
                match task {
                    TaskType::Preprocess => $st.preproc_t,
                    TaskType::Train => {
                        let fw = $st.tasks.get($st.cur).framework.unwrap_or($st.framework);
                        let d = train_pools[fw.index()].next()?.exp().max(0.1);
                        $st.train_t = d;
                        d
                    }
                    TaskType::Evaluate => eval_pool.next()?.exp().max(0.05),
                    // compression costs roughly a training run (section V-A2d)
                    TaskType::Compress => {
                        ($st.train_t * (1.0 + 0.05 * rng_noise.normal())).max(0.1)
                    }
                    TaskType::Harden => {
                        ($st.train_t * (1.5 + 0.2 * rng_noise.normal())).max(0.1)
                    }
                    TaskType::Deploy => (5.0 * (0.3 * rng_noise.normal()).exp()).max(0.5),
                }
            }};
        }

        // prepare pending durations and request the resource
        macro_rules! start_task {
            ($pid:expr) => {{
                let t_now = cal.now();
                let st = slab[$pid as usize].as_mut().expect("live pipeline");
                let node = st.tasks.get(st.cur);
                let exec = sample_exec!(st);
                let (read_b, write_b) =
                    TaskExecutor::payload_bytes(node.task, &st.asset, st.model_bytes);
                st.pending_exec = exec;
                st.pending_read = cfg.infra.store.read_time(read_b);
                st.pending_write = cfg.infra.store.write_time(write_b);
                wire_read += cfg.infra.store.wire_bytes(read_b);
                wire_write += cfg.infra.store.wire_bytes(write_b);
                if cfg.record_traces {
                    db.append(h_traffic_r, t_now, cfg.infra.store.wire_bytes(read_b));
                    db.append(h_traffic_w, t_now, cfg.infra.store.wire_bytes(write_b));
                }
                let kind = ResourceKind::for_task(node.task);
                let total = st.pending_read + st.pending_exec + st.pending_write;
                // the waiter key depends on the operational strategy:
                // SJF orders by expected occupancy, Priority by the
                // pipeline's user priority
                let key = match cfg.infra.discipline {
                    crate::des::resource::Discipline::ShortestJobFirst => total,
                    crate::des::resource::Discipline::Priority => st.priority,
                    crate::des::resource::Discipline::Fifo => 0.0,
                };
                let res = resource_for!(kind);
                match res.request(t_now, $pid, key) {
                    AcquireResult::Acquired => {
                        cal.schedule(total, Event::TaskDone($pid));
                    }
                    AcquireResult::Queued => {}
                }
            }};
        }

        // --- prime the calendar ---------------------------------------
        let first_gap = arrival.next_interarrival(0.0, cfg.interarrival_factor, &mut rng_arrival);
        cal.schedule(first_gap, Event::Arrival);
        cal.schedule(cfg.sample_interval, Event::Monitor);
        if cfg.runtime_view.enabled {
            cal.schedule(cfg.runtime_view.detector_interval, Event::Drift);
        }

        // --- main loop --------------------------------------------------
        while let Some((t, ev)) = cal.pop() {
            if t > cfg.horizon {
                break;
            }
            events += 1;
            match ev {
                Event::Arrival => {
                    arrived += 1;
                    db.append(h_arrivals, t, 1.0);
                    // next arrival
                    let stop = cfg.max_pipelines.map_or(false, |m| arrived >= m);
                    if !stop {
                        let gap = arrival.next_interarrival(
                            t,
                            cfg.interarrival_factor,
                            &mut rng_arrival,
                        );
                        if t + gap <= cfg.horizon {
                            cal.schedule(gap, Event::Arrival);
                        } else {
                            arrivals_stopped = true;
                        }
                    } else {
                        arrivals_stopped = true;
                    }
                    // new pipeline
                    let tasks = pipe_synth.generate_nodes();
                    let fw = tasks
                        .as_slice()
                        .iter()
                        .find_map(|n| n.framework)
                        .unwrap_or(Framework::SparkML);
                    let (asset, preproc_t) = asset_synth.next()?;
                    let st = PipelineState {
                        tasks,
                        cur: 0,
                        framework: fw,
                        asset,
                        preproc_t,
                        train_t: 60.0,
                        metrics: ModelMetrics::default(),
                        model_bytes: 1e7,
                        arrived_at: t,
                        total_wait: 0.0,
                        pending_exec: 0.0,
                        pending_read: 0.0,
                        pending_write: 0.0,
                        retrain_of: None,
                        // user-assigned priority class 1..=10
                        priority: 1.0 + rng_noise.below(10) as f64,
                    };
                    let pid = alloc_pid!(st);
                    live += 1;
                    start_task!(pid);
                }

                Event::TaskDone(pid) => {
                    tasks_executed += 1;
                    // release + grant next waiter
                    let (task, fw_tag, exec_dur, kind) = {
                        let st = slab[pid as usize].as_ref().expect("live");
                        let node = st.tasks.get(st.cur);
                        (
                            node.task,
                            node.framework,
                            st.pending_exec,
                            ResourceKind::for_task(node.task),
                        )
                    };
                    let granted = {
                        let res = resource_for!(kind);
                        res.release(t)
                    };
                    if let Some(g) = granted {
                        let w = slab[g.token as usize].as_mut().expect("queued pipeline");
                        w.total_wait += g.waited;
                        if cfg.record_traces {
                            let h = match kind {
                                ResourceKind::Training => h_wait_t,
                                ResourceKind::Compute => h_wait_c,
                            };
                            db.append(h, t, g.waited);
                        }
                        let total = w.pending_read + w.pending_exec + w.pending_write;
                        cal.schedule(total, Event::TaskDone(g.token));
                    }
                    if cfg.record_traces {
                        let slot =
                            &mut h_exec[task.index()][fw_tag.map_or(0, |f| f.index() + 1)];
                        let h = match *slot {
                            Some(h) => h,
                            None => {
                                // cold miss: ≤ 36 times per run
                                let mut key =
                                    SeriesKey::new(series::TASK_EXEC).tag("task", task.name());
                                if let Some(fw) = fw_tag {
                                    key = key.tag("framework", fw.name());
                                }
                                let h = db.handle(key);
                                *slot = Some(h);
                                h
                            }
                        };
                        db.append(h, t, exec_dur);
                    }

                    // task-specific model-metric effects
                    let mut truncated = false;
                    {
                        let st = slab[pid as usize].as_mut().expect("live");
                        match task {
                            TaskType::Train => {
                                let laws = &params.model_laws;
                                st.metrics.performance = (laws.perf_mean
                                    + laws.perf_sd * rng_noise.normal())
                                .clamp(0.05, 0.999);
                                st.metrics.size_mb = (laws.size_ln_mean
                                    + laws.size_ln_sd * rng_noise.normal())
                                .exp();
                                st.metrics.inference_ms = (laws.inference_ln_mean
                                    + laws.inference_ln_sd * rng_noise.normal())
                                .exp();
                                st.metrics.clever_score =
                                    rng_noise.uniform() * laws.clever_max;
                                st.metrics.confidence = st.metrics.performance
                                    * (0.9 + 0.1 * rng_noise.uniform());
                                st.model_bytes = st.metrics.size_mb * 1e6;
                            }
                            TaskType::Compress => {
                                let prune = 0.2 + 0.6 * rng_noise.uniform();
                                st.metrics = compression.apply(prune, &st.metrics);
                                st.model_bytes = st.metrics.size_mb * 1e6;
                            }
                            TaskType::Harden => {
                                st.metrics.clever_score =
                                    (st.metrics.clever_score * 1.5).min(5.0);
                                st.metrics.performance *= 0.99;
                            }
                            TaskType::Evaluate => {
                                // quality gate: pipelines whose model fails
                                // are aborted (Fig 3's gates)
                                if st.metrics.performance < 0.55 {
                                    truncated = true;
                                }
                            }
                            TaskType::Deploy => {
                                if cfg.runtime_view.enabled {
                                    if let Some(slot) = st.retrain_of {
                                        deployed[slot as usize]
                                            .redeploy(t, st.metrics.performance);
                                    } else if deployed.len() < cfg.runtime_view.max_models {
                                        deployed.push(DeployedModel::new(
                                            models_deployed,
                                            st.framework,
                                            st.metrics.performance,
                                            t,
                                            1,
                                        ));
                                    }
                                    models_deployed += 1;
                                }
                            }
                            TaskType::Preprocess => {}
                        }
                    }

                    // advance or complete
                    let done = {
                        let st = slab[pid as usize].as_mut().expect("live");
                        st.cur += 1;
                        truncated || st.cur >= st.tasks.len()
                    };
                    if done {
                        let st = slab[pid as usize].take().expect("live");
                        free.push(pid);
                        live -= 1;
                        completed += 1;
                        if truncated {
                            gate_failures += 1;
                        }
                        db.append(h_completions, t, t - st.arrived_at);
                        db.append(h_pipeline_wait, t, st.total_wait);
                        if let (Some(slot), true) = (st.retrain_of, truncated) {
                            // failed retraining: allow future triggers
                            deployed[slot as usize].retraining = false;
                        }
                    } else {
                        start_task!(pid);
                    }
                }

                Event::Monitor => {
                    db.append(h_util_t, t, training.in_use() as f64 / training.capacity() as f64);
                    db.append(h_util_c, t, compute.in_use() as f64 / compute.capacity() as f64);
                    db.append(h_q_t, t, training.queued() as f64);
                    db.append(h_q_c, t, compute.queued() as f64);
                    if !deployed.is_empty() {
                        let mean: f64 = deployed.iter().map(|m| m.performance).sum::<f64>()
                            / deployed.len() as f64;
                        db.append(h_model_perf, t, mean);
                    }
                    let rss = rss_mb();
                    if rss > peak_rss {
                        peak_rss = rss;
                    }
                    // stop sampling once the system has fully drained —
                    // otherwise a max_pipelines run with a far horizon
                    // would tick forever
                    let drained = arrivals_stopped && live == 0;
                    if !drained && t + cfg.sample_interval <= cfg.horizon {
                        cal.schedule(cfg.sample_interval, Event::Monitor);
                    }
                }

                Event::Drift => {
                    let rv = &cfg.runtime_view;
                    for slot in 0..deployed.len() {
                        let m = &mut deployed[slot];
                        m.tick(
                            t,
                            rv.decay_per_day,
                            rv.sudden_drift_prob,
                            rv.sudden_drift_drop,
                            &mut rng_drift,
                        );
                        if m.retraining {
                            continue;
                        }
                        if let Some(delay) = rv.trigger.decide(t, m.drift) {
                            m.retraining = true;
                            cal.schedule(delay, Event::RetrainLaunch(slot as u32));
                        }
                    }
                    let drained = arrivals_stopped && live == 0 && deployed.is_empty();
                    if !drained && t + rv.detector_interval <= cfg.horizon {
                        cal.schedule(rv.detector_interval, Event::Drift);
                    }
                }

                Event::RetrainLaunch(slot) => {
                    retrains += 1;
                    db.append(h_retrains, t, 1.0);
                    let fw = deployed[slot as usize].framework;
                    let (asset, preproc_t) = asset_synth.next()?;
                    // retraining pipeline: train – evaluate – deploy
                    let st = PipelineState {
                        tasks: TaskList::from_slice(&[
                            TaskNode::with_framework(TaskType::Train, fw),
                            TaskNode::new(TaskType::Evaluate),
                            TaskNode::new(TaskType::Deploy),
                        ]),
                        cur: 0,
                        framework: fw,
                        asset,
                        preproc_t,
                        train_t: 60.0,
                        metrics: ModelMetrics::default(),
                        model_bytes: 1e7,
                        arrived_at: t,
                        total_wait: 0.0,
                        pending_exec: 0.0,
                        pending_read: 0.0,
                        pending_write: 0.0,
                        retrain_of: Some(slot),
                        priority: 0.0, // retrains jump the queue
                    };
                    arrived += 1;
                    db.append(h_arrivals, t, 1.0);
                    let pid = alloc_pid!(st);
                    live += 1;
                    start_task!(pid);
                }
            }
        }

        let horizon_covered = cal.now().min(cfg.horizon);
        let final_perf = if deployed.is_empty() {
            0.0
        } else {
            deployed.iter().map(|m| m.performance).sum::<f64>() / deployed.len() as f64
        };
        let pool_refills = train_pools.iter().map(|p| p.refills).sum::<u64>() + eval_pool.refills;
        Ok(ExperimentResult {
            name: cfg.name,
            seed: cfg.seed,
            horizon: horizon_covered,
            arrived,
            completed,
            tasks_executed,
            gate_failures,
            retrains_triggered: retrains,
            models_deployed,
            events_processed: events,
            util_training: training.utilization(horizon_covered),
            util_compute: compute.utilization(horizon_covered),
            wait_training: training.wait_stats.clone(),
            wait_compute: compute.wait_stats.clone(),
            avg_queue_training: training.avg_queue_len(horizon_covered),
            avg_queue_compute: compute.avg_queue_len(horizon_covered),
            final_mean_performance: final_perf,
            wire_read_bytes: wire_read,
            wire_write_bytes: wire_write,
            wall_secs: started.elapsed().as_secs_f64(),
            peak_rss_mb: peak_rss,
            sampler_backend: backend.name().into(),
            pool_refills,
            tsdb: db,
        })
    }
}

/// Pad a fitted mixture to exactly K1 components (the AOT sampler's fixed
/// shape); extra components get -inf-ish weight. Mixtures that already
/// have the right shape (the common case: every fit produces K1
/// components) are shared, not copied.
fn pad_gmm(g: &Arc<Gmm1>) -> Arc<Gmm1> {
    if g.k() == K1 {
        return g.clone();
    }
    let mut out = Gmm1 {
        logw: vec![-60.0; K1],
        mu: vec![0.0; K1],
        logsd: vec![0.0; K1],
    };
    for i in 0..g.k().min(K1) {
        out.logw[i] = g.logw[i];
        out.mu[i] = g.mu[i];
        out.logsd[i] = g.logsd[i];
    }
    Arc::new(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::RuntimeViewConfig;
    use crate::coordinator::fit_params;
    use crate::coordinator::TriggerPolicy;
    use crate::des::DAY;
    use crate::empirical::GroundTruth;

    fn quick_params() -> SimParams {
        let db = GroundTruth::new(21).generate_weeks(3);
        fit_params(&db, None).unwrap()
    }

    fn run_with(cfg: ExperimentConfig) -> ExperimentResult {
        Experiment::new(cfg, quick_params()).run().unwrap()
    }

    #[test]
    fn one_day_run_completes_pipelines() {
        let cfg = ExperimentConfig {
            horizon: DAY,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 120.0,
            },
            ..Default::default()
        };
        let r = run_with(cfg);
        assert!(r.arrived > 400, "arrived {}", r.arrived);
        // most pipelines finish within the day at this load
        assert!(r.completed as f64 > 0.85 * r.arrived as f64,
            "completed {} of {}", r.completed, r.arrived);
        assert!(r.tasks_executed > r.completed);
        assert!(r.util_training > 0.0 && r.util_training <= 1.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = ExperimentConfig {
            horizon: DAY / 2.0,
            seed: 99,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 100.0,
            },
            ..Default::default()
        };
        let a = run_with(cfg.clone());
        let b = run_with(cfg);
        assert_eq!(a.arrived, b.arrived);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.events_processed, b.events_processed);
        assert!((a.util_training - b.util_training).abs() < 1e-12);
    }

    #[test]
    fn saturation_builds_queues() {
        let mut cfg = ExperimentConfig {
            horizon: DAY,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 20.0,
            },
            ..Default::default()
        };
        cfg.infra.training_capacity = 2;
        let r = run_with(cfg);
        assert!(
            r.util_training > 0.9,
            "training saturated: {}",
            r.util_training
        );
        assert!(r.wait_training.mean() > 0.0);
        assert!(r.avg_queue_training > 0.5, "{}", r.avg_queue_training);
    }

    #[test]
    fn conservation_arrived_completed_inflight() {
        let cfg = ExperimentConfig {
            horizon: DAY / 4.0,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 60.0,
            },
            ..Default::default()
        };
        let r = run_with(cfg);
        assert!(r.completed <= r.arrived);
        // whatever didn't complete is still queued/running: bounded
        assert!(r.arrived - r.completed < 2000);
    }

    #[test]
    fn runtime_view_triggers_retrains() {
        let cfg = ExperimentConfig {
            horizon: 7.0 * DAY,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 600.0,
            },
            runtime_view: RuntimeViewConfig {
                enabled: true,
                detector_interval: 3600.0,
                decay_per_day: 0.05,
                sudden_drift_prob: 0.05,
                sudden_drift_drop: 0.1,
                trigger: TriggerPolicy::DriftThreshold { threshold: 0.04 },
                max_models: 500,
            },
            ..Default::default()
        };
        let r = run_with(cfg);
        assert!(r.models_deployed > 10, "deployed {}", r.models_deployed);
        assert!(r.retrains_triggered > 5, "retrains {}", r.retrains_triggered);
        assert!(r.final_mean_performance > 0.3);
    }

    #[test]
    fn never_policy_lets_models_decay() {
        let mk = |policy| ExperimentConfig {
            horizon: 10.0 * DAY,
            seed: 5,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 900.0,
            },
            runtime_view: RuntimeViewConfig {
                enabled: true,
                detector_interval: 3600.0,
                decay_per_day: 0.03,
                sudden_drift_prob: 0.02,
                sudden_drift_drop: 0.1,
                trigger: policy,
                max_models: 300,
            },
            ..Default::default()
        };
        let never = run_with(mk(TriggerPolicy::Never));
        let eager = run_with(mk(TriggerPolicy::DriftThreshold { threshold: 0.03 }));
        assert_eq!(never.retrains_triggered, 0);
        assert!(
            eager.final_mean_performance > never.final_mean_performance + 0.05,
            "retraining must preserve performance: {} vs {}",
            eager.final_mean_performance,
            never.final_mean_performance
        );
    }

    #[test]
    fn max_pipelines_caps_arrivals() {
        let cfg = ExperimentConfig {
            horizon: 30.0 * DAY,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 10.0,
            },
            max_pipelines: Some(500),
            ..Default::default()
        };
        let r = run_with(cfg);
        assert_eq!(r.arrived, 500);
    }

    #[test]
    fn traces_recorded_when_enabled() {
        let cfg = ExperimentConfig {
            horizon: DAY / 2.0,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 120.0,
            },
            ..Default::default()
        };
        let r = run_with(cfg);
        assert!(!r.tsdb.find(series::TASK_EXEC).is_empty());
        assert!(!r.tsdb.find(series::ARRIVALS).is_empty());
        assert!(!r.tsdb.find(series::UTILIZATION).is_empty());
        // train exec series tagged by framework
        let train_series = r.tsdb.find_tagged(series::TASK_EXEC, "task", "train");
        assert!(!train_series.is_empty());
    }

    #[test]
    fn trace_recording_off_shrinks_store() {
        let mk = |record| ExperimentConfig {
            horizon: DAY / 2.0,
            record_traces: record,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 60.0,
            },
            ..Default::default()
        };
        let with = run_with(mk(true));
        let without = run_with(mk(false));
        assert!(without.tsdb.num_points() < with.tsdb.num_points() / 2);
    }
}
