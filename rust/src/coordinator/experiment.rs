//! The experiment entry point: config + fitted parameters (+ optional
//! PJRT runtime) → one deterministic run of the decomposed `Simulation`
//! core (`coordinator/simulation.rs`, paper section V-B).
//!
//! Each pipeline execution is a small state machine over the calendar:
//! arrival → per task: request resource (queue if saturated) →
//! read → exec → write → release → next task → completion. Durations come
//! from the fitted statistical models, batch-sampled through the AOT
//! artifacts. The optional run-time view ages deployed models and feeds
//! retraining pipelines back into the arrival stream (Fig 7). Which job a
//! saturated cluster runs next, and when a drifted model retrains, are
//! pluggable strategies — see `des::sched` and `coordinator::strategy`.

use std::sync::Arc;

use crate::arrivals::ArrivalModel;
use crate::error::Result;
use crate::runtime::Runtime;
use crate::trace::TraceSink;

use super::config::ExperimentConfig;
use super::params::SimParams;
use super::result::ExperimentResult;
use super::simulation::Simulation;

/// An experiment: config + fitted parameters (+ optional PJRT runtime).
///
/// Parameters and runtime are `Arc`-shared: constructing an experiment
/// from an existing `Arc<SimParams>` copies two pointers, so a parameter
/// sweep can stamp out thousands of runs without re-cloning the fitted
/// models (the former per-experiment clone storm).
pub struct Experiment {
    cfg: ExperimentConfig,
    params: Arc<SimParams>,
    runtime: Option<Arc<Runtime>>,
    arrival: Option<ArrivalModel>,
    sink: Option<Box<dyn TraceSink>>,
}

impl Experiment {
    pub fn new(cfg: ExperimentConfig, params: impl Into<Arc<SimParams>>) -> Self {
        Experiment {
            cfg,
            params: params.into(),
            runtime: None,
            arrival: None,
            sink: None,
        }
    }

    /// Use the AOT artifacts for all simulation-time sampling.
    pub fn with_runtime(mut self, rt: Option<Arc<Runtime>>) -> Self {
        self.runtime = rt;
        self
    }

    /// Override the arrival process, ignoring `cfg.arrival`. The
    /// trace-replay path (`trace::TraceWorkload`) uses this to feed
    /// recorded gaps back through `ArrivalModel::Replay`.
    pub fn with_arrival(mut self, model: ArrivalModel) -> Self {
        self.arrival = Some(model);
        self
    }

    /// Inject a caller-supplied [`TraceSink`]: every simulation event is
    /// recorded into it regardless of `cfg.capture_trace`, replacing the
    /// built-in `MemorySink`/`NullSink` choice. This is the streaming
    /// seam — a sink that writes incrementally and drains empty keeps a
    /// year-scale capture out of memory; the result's `trace` then
    /// carries the run metadata with no buffered events. Capture remains
    /// a pure observer: the outcome digest is unchanged.
    pub fn with_sink(mut self, sink: Box<dyn TraceSink>) -> Self {
        self.sink = Some(sink);
        self
    }

    /// Run to completion; single-threaded, deterministic per seed.
    pub fn run(self) -> Result<ExperimentResult> {
        let started = std::time::Instant::now();
        self.cfg.validate()?;
        Simulation::new(self.cfg, self.params, self.runtime, self.arrival, self.sink)?
            .run(started)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::{ArrivalSpec, RuntimeViewConfig};
    use crate::coordinator::fit_params;
    use crate::coordinator::result::series;
    use crate::coordinator::strategy::{scheduler_names, StrategySpec};
    use crate::des::DAY;
    use crate::empirical::GroundTruth;
    use crate::model::{ClusterFailureConfig, FailureModel, FaultModel, TaskFaultConfig};

    fn quick_params() -> SimParams {
        let db = GroundTruth::new(21).generate_weeks(3);
        fit_params(&db, None).unwrap()
    }

    fn run_with(cfg: ExperimentConfig) -> ExperimentResult {
        Experiment::new(cfg, quick_params()).run().unwrap()
    }

    #[test]
    fn one_day_run_completes_pipelines() {
        let cfg = ExperimentConfig {
            horizon: DAY,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 120.0,
            },
            ..Default::default()
        };
        let r = run_with(cfg);
        assert!(r.arrived > 400, "arrived {}", r.arrived);
        // most pipelines finish within the day at this load
        assert!(r.completed as f64 > 0.85 * r.arrived as f64,
            "completed {} of {}", r.completed, r.arrived);
        assert!(r.tasks_executed > r.completed);
        assert!(r.util_training > 0.0 && r.util_training <= 1.0);
        assert_eq!(r.arrived, r.completed + r.in_flight);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = ExperimentConfig {
            horizon: DAY / 2.0,
            seed: 99,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 100.0,
            },
            ..Default::default()
        };
        let a = run_with(cfg.clone());
        let b = run_with(cfg);
        assert_eq!(a.arrived, b.arrived);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.events_processed, b.events_processed);
        assert!((a.util_training - b.util_training).abs() < 1e-12);
        assert_eq!(a.digest(), b.digest());
    }

    #[test]
    fn saturation_builds_queues() {
        let mut cfg = ExperimentConfig {
            horizon: DAY,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 20.0,
            },
            ..Default::default()
        };
        cfg.infra.training_capacity = 2;
        let r = run_with(cfg);
        assert!(
            r.util_training > 0.9,
            "training saturated: {}",
            r.util_training
        );
        assert!(r.wait_training.mean() > 0.0);
        assert!(r.avg_queue_training > 0.5, "{}", r.avg_queue_training);
    }

    #[test]
    fn conservation_arrived_completed_inflight() {
        let cfg = ExperimentConfig {
            horizon: DAY / 4.0,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 60.0,
            },
            ..Default::default()
        };
        let r = run_with(cfg);
        assert!(r.completed <= r.arrived);
        assert_eq!(r.arrived, r.completed + r.in_flight);
        // whatever didn't complete is still queued/running: bounded
        assert!(r.arrived - r.completed < 2000);
    }

    #[test]
    fn new_schedulers_change_outcomes_under_saturation() {
        // the richer-context strategies must be selectable by name and
        // actually reorder work once queues form
        let run = |sched: StrategySpec| {
            let mut cfg = ExperimentConfig {
                name: "sched".into(),
                seed: 12,
                horizon: DAY,
                arrival: ArrivalSpec::Poisson {
                    mean_interarrival: 25.0,
                },
                record_traces: false,
                ..Default::default()
            };
            cfg.infra.training_capacity = 2;
            cfg.infra.scheduler = sched;
            run_with(cfg)
        };
        let fifo = run(StrategySpec::new("fifo"));
        assert!(fifo.wait_training.mean() > 0.0, "must saturate");
        let mut digests = vec![fifo.digest()];
        for name in ["edf", "weighted_fair"] {
            let r = run(StrategySpec::new(name));
            assert!(r.completed > 0, "{name} completed nothing");
            assert_eq!(r.arrived, r.completed + r.in_flight, "{name}");
            digests.push(r.digest());
        }
        digests.sort();
        digests.dedup();
        assert_eq!(digests.len(), 3, "schedulers must differ under saturation");
    }

    fn saturated_cfg(name: &str, sched: StrategySpec) -> ExperimentConfig {
        let mut cfg = ExperimentConfig {
            name: name.into(),
            seed: 12,
            horizon: DAY,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 25.0,
            },
            record_traces: false,
            ..Default::default()
        };
        cfg.infra.training_capacity = 2;
        cfg.infra.scheduler = sched;
        cfg
    }

    fn failing_cfg(name: &str, mtbf: f64, ckpt: f64, restart: f64) -> ExperimentConfig {
        let mut cfg = saturated_cfg(name, StrategySpec::new("priority"));
        cfg.infra.failures = Some(FailureModel {
            training: Some(
                ClusterFailureConfig::exponential(mtbf, 600.0).with_checkpointing(ckpt, restart),
            ),
            compute: None,
        });
        cfg
    }

    #[test]
    fn unreachable_mtbf_is_byte_identical_to_failure_free() {
        // digest-compat oracle: the failure subsystem must be a pure
        // superset — with a failure model attached but an MTBF far past
        // the horizon, no failure event ever schedules and the run IS
        // the failure-free simulation, bit for bit
        let plain = run_with(saturated_cfg("fail", StrategySpec::new("priority")));
        let gated = run_with(failing_cfg("fail", 1e30, 600.0, 30.0));
        assert!(plain.wait_training.mean() > 0.0, "must saturate");
        assert_eq!(gated.failures, 0);
        assert_eq!(gated.lost_work, 0.0);
        assert_eq!(gated.goodput, 1.0);
        assert_eq!(plain.digest(), gated.digest());
    }

    #[test]
    fn failure_injection_loses_work_and_conserves() {
        let r = run_with(failing_cfg("fail", 3600.0, 600.0, 30.0));
        assert!(r.failures > 0, "a day at 1h MTBF must fail: {}", r.failures);
        assert!(r.repairs > 0, "10min MTTR must repair within the day");
        assert!(r.lost_work > 0.0, "saturated slots must lose in-flight work");
        assert!(r.goodput > 0.0 && r.goodput < 1.0, "goodput {}", r.goodput);
        assert!(r.recovery_p50 > 0.0 && r.recovery_p95 >= r.recovery_p50);
        // interrupted pipelines restart and still complete: conservation
        assert_eq!(r.arrived, r.completed + r.in_flight);
        assert!(r.completed > 0);
        let again = run_with(failing_cfg("fail", 3600.0, 600.0, 30.0));
        assert_eq!(r.digest(), again.digest(), "failure runs must stay deterministic");
    }

    #[test]
    fn checkpointing_bounds_lost_work() {
        // without checkpoints a failure forfeits the whole attempt; with
        // a tight interval only the tail since the last checkpoint (plus
        // the restart cost) is lost, so total lost work must drop
        let off = run_with(failing_cfg("ckpt", 1800.0, 0.0, 0.0));
        let on = run_with(failing_cfg("ckpt", 1800.0, 10.0, 0.0));
        assert!(off.lost_work > 0.0 && on.lost_work > 0.0);
        assert!(
            on.lost_work < off.lost_work,
            "checkpointing must reduce lost work: {} vs {}",
            on.lost_work,
            off.lost_work
        );
        assert!(on.goodput > off.goodput, "{} vs {}", on.goodput, off.goodput);
    }

    fn faulty_cfg(name: &str, mean_time_to_fault: f64, retry: StrategySpec) -> ExperimentConfig {
        let mut cfg = saturated_cfg(name, StrategySpec::new("priority"));
        let mut faults = FaultModel::uniform(TaskFaultConfig::transient(mean_time_to_fault));
        faults.retry = retry;
        cfg.infra.faults = Some(faults);
        cfg
    }

    #[test]
    fn unreachable_fault_rate_is_byte_identical_to_fault_free() {
        // digest-compat oracle for the task-fault subsystem: with a
        // fault model attached but a mean time-to-fault far past any
        // task duration, every armed fault lands after its task's
        // completion, no fault event ever fires, and the run IS the
        // fault-free simulation, bit for bit — the fault RNG substream
        // draws but never perturbs the outcome
        let plain = run_with(saturated_cfg("fault", StrategySpec::new("priority")));
        let gated = run_with(faulty_cfg("fault", 1e30, StrategySpec::new("always")));
        assert!(plain.wait_training.mean() > 0.0, "must saturate");
        assert_eq!(gated.task_faults, 0);
        assert_eq!(gated.retries, 0);
        assert_eq!(gated.abandoned, 0);
        assert_eq!(gated.wasted_work, 0.0);
        assert_eq!(plain.digest(), gated.digest());
    }

    #[test]
    fn task_faults_retry_and_conserve() {
        let r = run_with(faulty_cfg("fault", 1800.0, StrategySpec::new("always")));
        assert!(r.task_faults > 0, "a day at 30min MTTF must fault: {}", r.task_faults);
        assert_eq!(r.retries, r.task_faults, "always retries every fault");
        assert!(r.wasted_work > 0.0, "faulted attempts must waste work");
        assert_eq!(r.abandoned, 0, "always never abandons");
        assert_eq!(r.arrived, r.completed + r.abandoned + r.shed + r.in_flight);
        assert!(r.completed > 0);
        let again = run_with(faulty_cfg("fault", 1800.0, StrategySpec::new("always")));
        assert_eq!(r.digest(), again.digest(), "fault runs must stay deterministic");
    }

    #[test]
    fn bounded_retries_abandon_and_policies_diverge() {
        let capped = run_with(faulty_cfg(
            "fault",
            900.0,
            StrategySpec::new("fixed").with("max_attempts", 2.0),
        ));
        assert!(capped.abandoned > 0, "2 attempts at 15min MTTF must abandon");
        assert_eq!(
            capped.arrived,
            capped.completed + capped.abandoned + capped.shed + capped.in_flight
        );
        let always = run_with(faulty_cfg("fault", 900.0, StrategySpec::new("always")));
        assert_ne!(capped.digest(), always.digest(), "retry policy never engaged");
        assert!(capped.retry.starts_with("fixed"), "{}", capped.retry);
    }

    #[test]
    fn timeouts_cancel_long_attempts() {
        let mut cfg = saturated_cfg("timeout", StrategySpec::new("priority"));
        // no transient faults — only a per-attempt timeout under long
        // training runs, so every timeout comes from the timer; the
        // bounded policy guarantees the run drains even for tasks whose
        // every resampled attempt would blow the budget
        cfg.infra.faults = Some(FaultModel {
            training: Some(TaskFaultConfig::default().with_timeout(900.0)),
            compute: None,
            retry: StrategySpec::new("fixed").with("max_attempts", 3.0),
        });
        let r = run_with(cfg);
        assert!(r.task_timeouts > 0, "15min cap must time out long trains");
        assert_eq!(r.task_faults, 0, "no transient fault source configured");
        assert_eq!(r.arrived, r.completed + r.abandoned + r.shed + r.in_flight);
    }

    #[test]
    fn queue_caps_shed_overload() {
        let mk = |cap: u64| {
            let mut cfg = saturated_cfg("shed", StrategySpec::new("priority"));
            cfg.arrival = ArrivalSpec::Poisson {
                mean_interarrival: 15.0,
            };
            if cap > 0 {
                cfg.infra.faults = Some(FaultModel {
                    training: Some(TaskFaultConfig::default().with_queue_cap(cap)),
                    compute: None,
                    retry: StrategySpec::new("always"),
                });
            }
            run_with(cfg)
        };
        let capped = mk(8);
        assert!(capped.shed > 0, "sustained overload over cap 8 must shed");
        assert_eq!(
            capped.arrived,
            capped.completed + capped.abandoned + capped.shed + capped.in_flight
        );
        // admission control trades completed work for shorter queues
        let open = mk(0);
        assert_eq!(open.shed, 0);
        assert!(
            capped.avg_queue_training < open.avg_queue_training,
            "shedding must shorten the queue: {} vs {}",
            capped.avg_queue_training,
            open.avg_queue_training
        );
    }
        // the failure-aware strategy's boost only applies to restarted
        // jobs; with failures off it IS the priority discipline
        let plain = run_with(saturated_cfg("rf", StrategySpec::new("priority")));
        let rf = run_with(saturated_cfg("rf", StrategySpec::new("restart_first")));
        assert!(plain.wait_training.mean() > 0.0, "must saturate");
        assert_eq!(plain.digest(), rf.digest());
    }

    #[test]
    fn restart_first_reorders_under_failures() {
        let mk = |sched: &str| {
            let mut cfg = failing_cfg("rf-fail", 1800.0, 600.0, 30.0);
            cfg.infra.scheduler = StrategySpec::new(sched);
            run_with(cfg)
        };
        let prio = mk("priority");
        let rf = mk("restart_first");
        assert!(rf.failures > 0, "must fail to exercise the boost");
        assert_eq!(rf.arrived, rf.completed + rf.in_flight);
        assert_ne!(prio.digest(), rf.digest(), "restart boost never engaged");
    }

    #[test]
    fn preemptive_priority_with_impossible_gap_is_byte_identical_to_priority() {
        // digest-compat oracle: the preemption machinery (running-set
        // tracking, re-decision hooks, release_all) must be a pure
        // superset — when no preemption can ever fire, the strategy IS
        // the plain priority discipline, bit for bit
        let plain = run_with(saturated_cfg("oracle", StrategySpec::new("priority")));
        let gapped = run_with(saturated_cfg(
            "oracle",
            StrategySpec::new("preemptive_priority").with("min_class_gap", 1e9),
        ));
        assert!(plain.wait_training.mean() > 0.0, "must saturate");
        assert_eq!(gapped.preemptions, 0);
        assert_eq!(plain.digest(), gapped.digest());
    }

    #[test]
    fn easy_backfill_with_unit_jobs_is_byte_identical_to_fifo() {
        // with every job one slot wide the head of the queue always
        // fits, so EASY backfill degenerates to FCFS — and must be
        // byte-identical to fifo (the grant-path refactor oracle)
        let fifo = run_with(saturated_cfg("oracle", StrategySpec::new("fifo")));
        let easy = run_with(saturated_cfg("oracle", StrategySpec::new("easy_backfill")));
        assert!(fifo.wait_training.mean() > 0.0, "must saturate");
        assert_eq!(fifo.digest(), easy.digest());
    }

    #[test]
    fn preemptive_priority_preempts_and_conserves_under_saturation() {
        let r = run_with(saturated_cfg("preempt", StrategySpec::new("preemptive_priority")));
        assert!(r.preemptions > 0, "saturated mixed-class load must preempt");
        // work conservation: preempted tasks resume and complete
        assert_eq!(r.arrived, r.completed + r.in_flight);
        assert!(r.completed > 0);
        // preemption reorders work, so outcomes differ from plain priority
        let plain = run_with(saturated_cfg("preempt", StrategySpec::new("priority")));
        assert_ne!(r.digest(), plain.digest());
    }

    #[test]
    fn wide_training_jobs_run_under_every_scheduler() {
        // train_slots > 1 exercises head-of-line blocking, multi-grant
        // releases, and (for easy_backfill) real backfill in the full
        // simulation; conservation must hold throughout
        for name in ["fifo", "easy_backfill", "priority", "preemptive_priority"] {
            let mut cfg = saturated_cfg(&format!("wide-{name}"), StrategySpec::new(name));
            cfg.infra.training_capacity = 4;
            cfg.infra.train_slots = 2;
            let r = run_with(cfg);
            assert!(r.completed > 0, "{name}");
            assert_eq!(r.arrived, r.completed + r.in_flight, "{name}");
        }
    }

    #[test]
    fn easy_backfill_engages_with_wide_trains() {
        // capacity 4 with 3-slot trains leaves one stranded slot behind
        // every blocked train head — a day of saturated load must hit
        // backfill opportunities, so outcomes diverge from plain FIFO
        // while conservation keeps holding
        let run = |sched: &str| {
            let mut cfg = saturated_cfg("wide", StrategySpec::new(sched));
            cfg.infra.training_capacity = 4;
            cfg.infra.train_slots = 3;
            run_with(cfg)
        };
        let fifo = run("fifo");
        let easy = run("easy_backfill");
        assert!(fifo.wait_training.mean() > 0.0, "must saturate");
        assert_eq!(easy.arrived, easy.completed + easy.in_flight);
        assert_ne!(
            easy.digest(),
            fifo.digest(),
            "backfill never engaged despite head-of-line blocking"
        );
        // backfill fills slots FIFO leaves stranded; allow a small band
        // because the workloads diverge after the first backfill
        assert!(
            easy.util_training > fifo.util_training - 0.05,
            "backfill wastes slots: {} vs {}",
            easy.util_training,
            fifo.util_training
        );
    }

    #[test]
    fn per_resource_scheduler_split_is_a_pure_superset() {
        // digest oracles for the scheduler split: an explicit override
        // equal to the shared spec is byte-identical to no override, and
        // overriding only the compute cluster leaves training untouched
        // while actually changing outcomes once compute queues form
        let mk = |tr: Option<&str>, co: Option<&str>| {
            let mut cfg = saturated_cfg("split", StrategySpec::new("fifo"));
            // saturate compute too so its discipline matters
            cfg.infra.compute_capacity = 3;
            cfg.infra.scheduler_training = tr.map(StrategySpec::new);
            cfg.infra.scheduler_compute = co.map(StrategySpec::new);
            run_with(cfg)
        };
        let shared = mk(None, None);
        assert!(shared.wait_compute.mean() > 0.0, "compute must queue");
        let explicit = mk(Some("fifo"), Some("fifo"));
        assert_eq!(
            shared.digest(),
            explicit.digest(),
            "explicit fifo override must be byte-identical to the shared spec"
        );
        let split = mk(None, Some("sjf"));
        assert_ne!(
            shared.digest(),
            split.digest(),
            "compute override never engaged"
        );
        assert_eq!(split.arrived, split.completed + split.in_flight);
        // the result label is self-describing about the split
        assert_eq!(shared.scheduler, "fifo");
        assert_eq!(split.scheduler, "training=fifo|compute=sjf");
    }

    #[test]
    fn every_registered_scheduler_runs_the_default_workload() {
        for name in scheduler_names() {
            let mut cfg = ExperimentConfig {
                name: format!("reg-{name}"),
                horizon: DAY / 6.0,
                arrival: ArrivalSpec::Poisson {
                    mean_interarrival: 90.0,
                },
                record_traces: false,
                ..Default::default()
            };
            cfg.infra.scheduler = StrategySpec::new(&name);
            let r = run_with(cfg);
            assert!(r.completed > 0, "{name}");
        }
    }

    #[test]
    fn runtime_view_triggers_retrains() {
        let cfg = ExperimentConfig {
            horizon: 7.0 * DAY,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 600.0,
            },
            runtime_view: RuntimeViewConfig {
                enabled: true,
                detector_interval: 3600.0,
                decay_per_day: 0.05,
                sudden_drift_prob: 0.05,
                sudden_drift_drop: 0.1,
                trigger: StrategySpec::new("drift_threshold").with("threshold", 0.04),
                max_models: 500,
            },
            ..Default::default()
        };
        let r = run_with(cfg);
        assert!(r.models_deployed > 10, "deployed {}", r.models_deployed);
        assert!(r.retrains_triggered > 5, "retrains {}", r.retrains_triggered);
        assert!(r.final_mean_performance > 0.3);
    }

    #[test]
    fn never_policy_lets_models_decay() {
        let mk = |trigger: StrategySpec| ExperimentConfig {
            horizon: 10.0 * DAY,
            seed: 5,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 900.0,
            },
            runtime_view: RuntimeViewConfig {
                enabled: true,
                detector_interval: 3600.0,
                decay_per_day: 0.03,
                sudden_drift_prob: 0.02,
                sudden_drift_drop: 0.1,
                trigger,
                max_models: 300,
            },
            ..Default::default()
        };
        let never = run_with(mk(StrategySpec::new("never")));
        let eager = run_with(mk(StrategySpec::new("drift_threshold").with("threshold", 0.03)));
        assert_eq!(never.retrains_triggered, 0);
        assert!(
            eager.final_mean_performance > never.final_mean_performance + 0.05,
            "retraining must preserve performance: {} vs {}",
            eager.final_mean_performance,
            never.final_mean_performance
        );
    }

    #[test]
    fn performance_floor_trigger_keeps_quality_above_drift_free_baseline() {
        let mk = |trigger: StrategySpec| ExperimentConfig {
            horizon: 10.0 * DAY,
            seed: 5,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 900.0,
            },
            runtime_view: RuntimeViewConfig {
                enabled: true,
                detector_interval: 3600.0,
                decay_per_day: 0.03,
                sudden_drift_prob: 0.02,
                sudden_drift_drop: 0.1,
                trigger,
                max_models: 300,
            },
            ..Default::default()
        };
        let floor = run_with(mk(StrategySpec::new("performance_floor").with("floor", 0.75)));
        let never = run_with(mk(StrategySpec::new("never")));
        assert!(floor.retrains_triggered > 0);
        assert!(
            floor.final_mean_performance > never.final_mean_performance,
            "{} vs {}",
            floor.final_mean_performance,
            never.final_mean_performance
        );
    }

    #[test]
    fn max_pipelines_caps_arrivals() {
        let cfg = ExperimentConfig {
            horizon: 30.0 * DAY,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 10.0,
            },
            max_pipelines: Some(500),
            ..Default::default()
        };
        let r = run_with(cfg);
        assert_eq!(r.arrived, 500);
    }

    #[test]
    fn traces_recorded_when_enabled() {
        let cfg = ExperimentConfig {
            horizon: DAY / 2.0,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 120.0,
            },
            ..Default::default()
        };
        let r = run_with(cfg);
        assert!(!r.tsdb.find(series::TASK_EXEC).is_empty());
        assert!(!r.tsdb.find(series::ARRIVALS).is_empty());
        assert!(!r.tsdb.find(series::UTILIZATION).is_empty());
        // train exec series tagged by framework
        let train_series = r.tsdb.find_tagged(series::TASK_EXEC, "task", "train");
        assert!(!train_series.is_empty());
    }

    #[test]
    fn trace_recording_off_shrinks_store() {
        let mk = |record| ExperimentConfig {
            horizon: DAY / 2.0,
            record_traces: record,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 60.0,
            },
            ..Default::default()
        };
        let with = run_with(mk(true));
        let without = run_with(mk(false));
        assert!(without.tsdb.num_points() < with.tsdb.num_points() / 2);
    }
}
