//! Binary cache of fitted [`SimParams`] — the trace codec's byte
//! vocabulary (`util::binio`) applied to the fitted-parameter bundle.
//!
//! JSON parsing of `sim_params.json` dominates sweep startup for tiny
//! cells (ROADMAP follow-up): the profile alone is 168 fitted
//! distributions and the replay trace thousands of gaps, all re-parsed
//! from ASCII floats on every CLI invocation. The binary form
//! (`fit --out params.bin`) loads with zero float formatting/parsing and
//! is bit-exact, so a run started from either encoding produces the same
//! digest. `SimParams::load` auto-detects the format by magic.

use std::sync::Arc;

use crate::arrivals::{ArrivalModel, ArrivalProfile, ReplayTrace};
use crate::error::{Error, Result};
use crate::model::Framework;
use crate::stats::dist::{Dist, ExpWeibull, Exponential, LogNormal, Normal, Pareto, Weibull};
use crate::stats::gmm::{Gmm1, Gmm3};
use crate::stats::ExpCurve;
use crate::util::binio::{ByteReader, ByteWriter};

use super::params::{ModelLaws, SimParams};

/// File magic: **P**ipe**S**im **P**arameter **B**undle.
pub const MAGIC: &[u8; 4] = b"PSPB";
/// Current binary format version.
pub const FORMAT_VERSION: u16 = 1;

/// Does this byte prefix identify a binary parameter bundle?
pub fn is_binary(bytes: &[u8]) -> bool {
    bytes.len() >= MAGIC.len() && &bytes[..MAGIC.len()] == MAGIC
}

/// Serialize fitted parameters to the binary cache format.
pub fn encode(p: &SimParams) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.header(MAGIC, FORMAT_VERSION);
    gmm3(&mut w, &p.asset_gmm);
    w.varint(p.train_log_gmm.len() as u64);
    for g in &p.train_log_gmm {
        gmm1(&mut w, g);
    }
    gmm1(&mut w, &p.eval_log_gmm);
    w.f64(p.preproc_curve.a);
    w.f64(p.preproc_curve.b);
    w.f64(p.preproc_curve.c);
    w.f64(p.preproc_noise.mu);
    w.f64(p.preproc_noise.sigma);
    arrival(&mut w, &p.arrival_random);
    arrival(&mut w, &p.arrival_profile);
    arrival(&mut w, &p.arrival_replay);
    w.f64(p.mean_interarrival);
    for v in [
        p.model_laws.perf_mean,
        p.model_laws.perf_sd,
        p.model_laws.size_ln_mean,
        p.model_laws.size_ln_sd,
        p.model_laws.inference_ln_mean,
        p.model_laws.inference_ln_sd,
        p.model_laws.clever_max,
    ] {
        w.f64(v);
    }
    w.into_bytes()
}

/// Parse a binary parameter bundle.
pub fn decode(bytes: &[u8]) -> Result<SimParams> {
    let mut r = ByteReader::new(bytes);
    r.check_header(MAGIC, FORMAT_VERSION, "params")?;
    let asset_gmm = Arc::new(read_gmm3(&mut r)?);
    // every length prefix below is validated against the remaining
    // input (len_prefix_for), so corrupt counts cannot force oversized
    // allocations before the data itself fails to parse
    let n_train = r.len_prefix_for(1)?;
    if n_train != Framework::ALL.len() {
        // the simulator indexes this by Framework::index — a short list
        // would panic at sample time, not at load time
        return Err(Error::Other(format!(
            "params: {n_train} train mixtures, expected {}",
            Framework::ALL.len()
        )));
    }
    let mut train_log_gmm = Vec::with_capacity(n_train);
    for _ in 0..n_train {
        train_log_gmm.push(Arc::new(read_gmm1(&mut r)?));
    }
    let eval_log_gmm = Arc::new(read_gmm1(&mut r)?);
    let preproc_curve = ExpCurve {
        a: finite(&mut r)?,
        b: finite(&mut r)?,
        c: finite(&mut r)?,
    };
    let preproc_noise = LogNormal::new(finite(&mut r)?, positive(&mut r)?);
    let arrival_random = read_arrival(&mut r)?;
    let arrival_profile = read_arrival(&mut r)?;
    let arrival_replay = read_arrival(&mut r)?;
    let mean_interarrival = positive(&mut r)?;
    let model_laws = ModelLaws {
        perf_mean: finite(&mut r)?,
        perf_sd: finite(&mut r)?,
        size_ln_mean: finite(&mut r)?,
        size_ln_sd: finite(&mut r)?,
        inference_ln_mean: finite(&mut r)?,
        inference_ln_sd: finite(&mut r)?,
        clever_max: finite(&mut r)?,
    };
    r.expect_eof("params")?;
    Ok(SimParams {
        asset_gmm,
        train_log_gmm,
        eval_log_gmm,
        preproc_curve,
        preproc_noise,
        arrival_random,
        arrival_profile,
        arrival_replay,
        mean_interarrival,
        model_laws,
    })
}

fn gmm1(w: &mut ByteWriter, g: &Gmm1) {
    w.varint(g.logw.len() as u64);
    for &v in g.logw.iter().chain(&g.mu).chain(&g.logsd) {
        w.f64(v);
    }
}

fn read_gmm1(r: &mut ByteReader) -> Result<Gmm1> {
    // 3 columns x 8 bytes per component
    let k = r.len_prefix_for(24)?;
    if k == 0 {
        // sampling an empty mixture would panic, so reject at load time
        return Err(Error::Other("params: empty gmm1 mixture".into()));
    }
    let col = |r: &mut ByteReader| -> Result<Vec<f64>> { (0..k).map(|_| finite(r)).collect() };
    Ok(Gmm1 {
        logw: col(r)?,
        mu: col(r)?,
        logsd: col(r)?,
    })
}

fn gmm3(w: &mut ByteWriter, g: &Gmm3) {
    w.varint(g.logw.len() as u64);
    for &v in &g.logw {
        w.f64(v);
    }
    for row in &g.mu {
        for &v in row {
            w.f64(v);
        }
    }
    for m in g.cchol.iter().chain(&g.pchol) {
        for row in m {
            for &v in row {
                w.f64(v);
            }
        }
    }
}

fn read_gmm3(r: &mut ByteReader) -> Result<Gmm3> {
    // (1 + 3 + 9 + 9) f64s per component
    let k = r.len_prefix_for(176)?;
    if k == 0 {
        return Err(Error::Other("params: empty gmm3 mixture".into()));
    }
    let logw: Vec<f64> = (0..k).map(|_| finite(r)).collect::<Result<_>>()?;
    let mut mu = Vec::with_capacity(k);
    for _ in 0..k {
        mu.push([finite(r)?, finite(r)?, finite(r)?]);
    }
    let mat33 = |r: &mut ByteReader| -> Result<Vec<[[f64; 3]; 3]>> {
        (0..k)
            .map(|_| {
                Ok([
                    [finite(r)?, finite(r)?, finite(r)?],
                    [finite(r)?, finite(r)?, finite(r)?],
                    [finite(r)?, finite(r)?, finite(r)?],
                ])
            })
            .collect()
    };
    Ok(Gmm3 {
        logw,
        mu,
        cchol: mat33(r)?,
        pchol: mat33(r)?,
    })
}

// Distribution family tags; append-only (format versioning rule).
const DIST_NORMAL: u8 = 0;
const DIST_LOGNORMAL: u8 = 1;
const DIST_EXPONENTIAL: u8 = 2;
const DIST_WEIBULL: u8 = 3;
const DIST_EXPWEIBULL: u8 = 4;
const DIST_PARETO: u8 = 5;

fn dist(w: &mut ByteWriter, d: &Dist) {
    match d {
        Dist::Normal(d) => {
            w.u8(DIST_NORMAL);
            w.f64(d.mu);
            w.f64(d.sigma);
        }
        Dist::LogNormal(d) => {
            w.u8(DIST_LOGNORMAL);
            w.f64(d.mu);
            w.f64(d.sigma);
        }
        Dist::Exponential(d) => {
            w.u8(DIST_EXPONENTIAL);
            w.f64(d.lambda);
        }
        Dist::Weibull(d) => {
            w.u8(DIST_WEIBULL);
            w.f64(d.k);
            w.f64(d.lambda);
        }
        Dist::ExpWeibull(d) => {
            w.u8(DIST_EXPWEIBULL);
            w.f64(d.alpha);
            w.f64(d.k);
            w.f64(d.lambda);
        }
        Dist::Pareto(d) => {
            w.u8(DIST_PARETO);
            w.f64(d.xm);
            w.f64(d.alpha);
        }
    }
}

/// A finite value (location parameters may be any finite float).
fn finite(r: &mut ByteReader) -> Result<f64> {
    let v = r.f64()?;
    if !v.is_finite() {
        return Err(Error::Other(format!("params: non-finite value {v}")));
    }
    Ok(v)
}

/// A strictly positive finite value (scale/shape parameters) — the dist
/// constructors `assert!` on these, so corrupt bytes must be rejected
/// here to keep decode error-returning rather than panicking.
fn positive(r: &mut ByteReader) -> Result<f64> {
    let v = finite(r)?;
    if v <= 0.0 {
        return Err(Error::Other(format!("params: non-positive scale/shape {v}")));
    }
    Ok(v)
}

fn read_dist(r: &mut ByteReader) -> Result<Dist> {
    Ok(match r.u8()? {
        DIST_NORMAL => Dist::Normal(Normal::new(finite(r)?, positive(r)?)),
        DIST_LOGNORMAL => Dist::LogNormal(LogNormal::new(finite(r)?, positive(r)?)),
        DIST_EXPONENTIAL => Dist::Exponential(Exponential::new(positive(r)?)),
        DIST_WEIBULL => Dist::Weibull(Weibull::new(positive(r)?, positive(r)?)),
        DIST_EXPWEIBULL => Dist::ExpWeibull(ExpWeibull::new(positive(r)?, positive(r)?, positive(r)?)),
        DIST_PARETO => Dist::Pareto(Pareto::new(positive(r)?, positive(r)?)),
        tag => return Err(Error::Other(format!("params: unknown dist tag {tag}"))),
    })
}

// Arrival-model tags.
const ARR_RANDOM: u8 = 0;
const ARR_PROFILE: u8 = 1;
const ARR_POISSON: u8 = 2;
const ARR_REPLAY: u8 = 3;

fn arrival(w: &mut ByteWriter, m: &ArrivalModel) {
    match m {
        ArrivalModel::Random(d) => {
            w.u8(ARR_RANDOM);
            dist(w, d);
        }
        ArrivalModel::Profile(p) => {
            w.u8(ARR_PROFILE);
            w.varint(p.clusters.len() as u64);
            for d in &p.clusters {
                dist(w, d);
            }
            w.varint(p.sse.len() as u64);
            for &v in &p.sse {
                w.f64(v);
            }
        }
        ArrivalModel::Poisson { mean_interarrival } => {
            w.u8(ARR_POISSON);
            w.f64(*mean_interarrival);
        }
        ArrivalModel::Replay(trace) => {
            w.u8(ARR_REPLAY);
            w.varint(trace.gaps.len() as u64);
            for &g in trace.gaps.iter() {
                w.f64(g);
            }
        }
    }
}

fn read_arrival(r: &mut ByteReader) -> Result<ArrivalModel> {
    Ok(match r.u8()? {
        ARR_RANDOM => ArrivalModel::Random(read_dist(r)?),
        ARR_PROFILE => {
            // smallest family record: tag + one f64 parameter
            let n = r.len_prefix_for(9)?;
            let clusters: Vec<Dist> = (0..n).map(|_| read_dist(r)).collect::<Result<_>>()?;
            let n_sse = r.len_prefix_for(8)?;
            let sse: Vec<f64> = (0..n_sse).map(|_| finite(r)).collect::<Result<_>>()?;
            if clusters.len() != 168 {
                return Err(Error::Other(format!(
                    "params: profile has {} clusters, expected 168",
                    clusters.len()
                )));
            }
            ArrivalModel::Profile(Arc::new(ArrivalProfile { clusters, sse }))
        }
        ARR_POISSON => ArrivalModel::Poisson {
            mean_interarrival: r.f64()?,
        },
        ARR_REPLAY => {
            let n = r.len_prefix_for(8)?;
            let gaps: Vec<f64> = (0..n).map(|_| positive(r)).collect::<Result<_>>()?;
            if gaps.is_empty() {
                return Err(Error::Other("params: empty replay trace".into()));
            }
            ArrivalModel::Replay(ReplayTrace::new(gaps))
        }
        tag => return Err(Error::Other(format!("params: unknown arrival tag {tag}"))),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::fit_params;
    use crate::empirical::GroundTruth;

    fn fitted() -> SimParams {
        let db = GroundTruth::new(19).generate_weeks(2);
        fit_params(&db, None).unwrap()
    }

    #[test]
    fn binary_roundtrip_is_bit_exact() {
        let p = fitted();
        let bytes = encode(&p);
        assert!(is_binary(&bytes));
        let back = decode(&bytes).unwrap();
        assert_eq!(back.asset_gmm.logw, p.asset_gmm.logw);
        assert_eq!(back.asset_gmm.pchol, p.asset_gmm.pchol);
        assert_eq!(back.train_log_gmm.len(), p.train_log_gmm.len());
        for (a, b) in back.train_log_gmm.iter().zip(&p.train_log_gmm) {
            assert_eq!(a.mu, b.mu);
            assert_eq!(a.logsd, b.logsd);
        }
        assert_eq!(back.preproc_curve.b.to_bits(), p.preproc_curve.b.to_bits());
        assert_eq!(
            back.mean_interarrival.to_bits(),
            p.mean_interarrival.to_bits()
        );
        // profile clusters survive family + parameter intact
        let (ArrivalModel::Profile(a), ArrivalModel::Profile(b)) =
            (&back.arrival_profile, &p.arrival_profile)
        else {
            panic!("profile models expected");
        };
        assert_eq!(a.clusters.len(), 168);
        assert_eq!(a.clusters, b.clusters);
        assert_eq!(a.sse, b.sse);
        // encoding is deterministic
        assert_eq!(encode(&back), bytes);
    }

    #[test]
    fn corrupt_dist_params_error_instead_of_panicking() {
        // the dist constructors assert on their arguments; decode must
        // reject bad values as Err, never abort
        let mut w = ByteWriter::new();
        w.u8(DIST_NORMAL);
        w.f64(0.0);
        w.f64(-1.0); // sigma <= 0
        let bytes = w.into_bytes();
        assert!(read_dist(&mut ByteReader::new(&bytes)).is_err());
        let mut w = ByteWriter::new();
        w.u8(DIST_EXPONENTIAL);
        w.f64(f64::NAN);
        let bytes = w.into_bytes();
        assert!(read_dist(&mut ByteReader::new(&bytes)).is_err());
        let mut w = ByteWriter::new();
        w.u8(DIST_PARETO);
        w.f64(f64::INFINITY);
        w.f64(1.5);
        let bytes = w.into_bytes();
        assert!(read_dist(&mut ByteReader::new(&bytes)).is_err());
    }

    #[test]
    fn rejects_corrupt_bundles() {
        let p = fitted();
        let bytes = encode(&p);
        assert!(decode(&bytes[..bytes.len() / 2]).is_err());
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(decode(&bad).is_err());
        assert!(!is_binary(&bad));
        let mut bad = bytes.clone();
        bad[4] = 99;
        assert!(decode(&bad).is_err());
        let mut bad = bytes;
        bad.push(7);
        assert!(decode(&bad).is_err());
    }
}
