//! Sharded sweeps: deterministic grid partitioning, the per-shard
//! binary manifest (`.psm`, magic `PSSM`), and the merge that
//! reassembles N shard artifacts into one sweep-shaped result.
//!
//! ## Determinism contract
//!
//! Every shard enumerates the *full* (config, seed) grid and runs only
//! its stride (`index % count == shard`), so global cell indices, group
//! names, and per-cell output filenames are shard-invariant. The merge
//! then restores single-process semantics exactly:
//!
//! * **per-cell digests** are byte-identical to the single-process
//!   sweep (they ride through the manifest verbatim);
//! * **group mean/std/CI** are *bit*-identical: merging reorders
//!   floating-point accumulation, so instead of summing partial group
//!   summaries, the merge reassembles the per-cell records in global
//!   cell order and re-runs the same [`aggregate_cells`] the
//!   single-process path uses — same values, same add order, same bits;
//! * **quantiles** come from the per-shard t-digest sketches merged via
//!   the order-insensitive `TDigest::merge_from` (PR 8) — approximate
//!   within the documented rank-error bound, by design.
//!
//! [`merge_shards`] rejects overlapping, missing, or mismatched shards
//! with named errors; a hole in the grid can never be silently averaged
//! over.

use std::fmt;
use std::path::Path;

use crate::error::{Error, Result};
use crate::stats::sketch::{FixedHistogram, TDigest};
use crate::stats::Summary;
use crate::util::binio::{ByteReader, ByteWriter};

use super::result::ExperimentResult;

/// Which stride of the grid this process runs: shard `index` of
/// `count` owns every cell whose global index `i` satisfies
/// `i % count == index`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    pub index: usize,
    pub count: usize,
}

impl ShardSpec {
    pub fn new(index: usize, count: usize) -> Result<Self> {
        if count == 0 {
            return Err(Error::Config("shard: count must be >= 1".into()));
        }
        if index >= count {
            return Err(Error::Config(format!(
                "shard: index {index} out of range for {count} shards (use 0..{count})"
            )));
        }
        Ok(ShardSpec { index, count })
    }

    /// Parse the CLI form `k/N`, e.g. `--shard 0/4`.
    pub fn parse(s: &str) -> Result<Self> {
        let bad = || Error::Config(format!("shard: expected k/N (e.g. 0/4), got '{s}'"));
        let (k, n) = s.split_once('/').ok_or_else(bad)?;
        let index: usize = k.trim().parse().map_err(|_| bad())?;
        let count: usize = n.trim().parse().map_err(|_| bad())?;
        ShardSpec::new(index, count)
    }

    /// Does this shard own global cell index `i`?
    pub fn owns(&self, i: usize) -> bool {
        i % self.count == self.index
    }

    /// A 1-shard spec covers the whole grid.
    pub fn is_full(&self) -> bool {
        self.count == 1
    }
}

impl fmt::Display for ShardSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}/{}", self.index, self.count)
    }
}

/// Number of aggregated metrics per group (the
/// [`CellRecord::metric_values`] tuple).
pub(crate) const METRICS: usize = 16;

/// Everything the merge needs from one finished cell, detached from the
/// heavyweight [`ExperimentResult`] (no tsdb, no trace): the aggregate
/// inputs bit-exact, the CSV row inputs, and the cell's digest.
#[derive(Clone, Debug)]
pub struct CellRecord {
    /// Global cell index in the full grid — shard-invariant.
    pub index: usize,
    pub name: String,
    pub seed: u64,
    pub arrived: u64,
    pub completed: u64,
    pub in_flight: u64,
    pub tasks_executed: u64,
    pub events_processed: u64,
    pub gate_failures: u64,
    pub retrains_triggered: u64,
    pub failures: u64,
    /// Full task-wait summary (not just the mean) so group-level wait
    /// statistics merge exactly via [`Summary::merge_from`].
    pub wait_training: Summary,
    pub util_training: f64,
    pub util_compute: f64,
    pub avg_queue_training: f64,
    pub final_mean_performance: f64,
    pub lost_work: f64,
    pub goodput: f64,
    pub cost: f64,
    pub wall_secs: f64,
    pub peak_rss_points: u64,
    /// `ExperimentResult::digest()` — the byte-exact merge oracle.
    pub digest: String,
}

impl CellRecord {
    pub fn from_result(index: usize, r: &ExperimentResult) -> Self {
        CellRecord {
            index,
            name: r.name.clone(),
            seed: r.seed,
            arrived: r.arrived,
            completed: r.completed,
            in_flight: r.in_flight,
            tasks_executed: r.tasks_executed,
            events_processed: r.events_processed,
            gate_failures: r.gate_failures,
            retrains_triggered: r.retrains_triggered,
            failures: r.failures,
            wait_training: r.wait_training.clone(),
            util_training: r.util_training,
            util_compute: r.util_compute,
            avg_queue_training: r.avg_queue_training,
            final_mean_performance: r.final_mean_performance,
            lost_work: r.lost_work,
            goodput: r.goodput,
            cost: r.cost,
            wall_secs: r.wall_secs,
            peak_rss_points: r.tsdb.resident_points() as u64,
            digest: r.digest(),
        }
    }

    /// The metrics aggregated across replications, in table order.
    pub(crate) fn metric_values(&self) -> [(&'static str, f64); METRICS] {
        [
            ("arrived", self.arrived as f64),
            ("completed", self.completed as f64),
            ("in_flight", self.in_flight as f64),
            ("tasks_executed", self.tasks_executed as f64),
            ("events_processed", self.events_processed as f64),
            ("gate_failures", self.gate_failures as f64),
            ("retrains_triggered", self.retrains_triggered as f64),
            ("util_training", self.util_training),
            ("util_compute", self.util_compute),
            ("mean_wait_training_s", self.wait_training.mean()),
            ("avg_queue_training", self.avg_queue_training),
            ("final_mean_performance", self.final_mean_performance),
            ("failures", self.failures as f64),
            ("lost_work_s", self.lost_work),
            ("goodput", self.goodput),
            ("cost", self.cost),
        ]
    }

    fn write_to(&self, w: &mut ByteWriter) {
        w.varint(self.index as u64);
        w.str(&self.name);
        w.varint(self.seed);
        for v in [
            self.arrived,
            self.completed,
            self.in_flight,
            self.tasks_executed,
            self.events_processed,
            self.gate_failures,
            self.retrains_triggered,
            self.failures,
            self.peak_rss_points,
        ] {
            w.varint(v);
        }
        w.varint(self.wait_training.count);
        for v in [
            self.wait_training.sum,
            self.wait_training.sum_sq,
            self.wait_training.min,
            self.wait_training.max,
            self.util_training,
            self.util_compute,
            self.avg_queue_training,
            self.final_mean_performance,
            self.lost_work,
            self.goodput,
            self.cost,
            self.wall_secs,
        ] {
            w.f64(v);
        }
        w.str(&self.digest);
    }

    fn read_from(r: &mut ByteReader) -> Result<CellRecord> {
        let index = r.len_prefix()?;
        let name = r.str()?;
        let seed = r.varint()?;
        let mut ints = [0u64; 9];
        for v in ints.iter_mut() {
            *v = r.varint()?;
        }
        let wait_count = r.varint()?;
        let mut floats = [0f64; 12];
        for v in floats.iter_mut() {
            *v = r.f64()?;
        }
        let digest = r.str()?;
        Ok(CellRecord {
            index,
            name,
            seed,
            arrived: ints[0],
            completed: ints[1],
            in_flight: ints[2],
            tasks_executed: ints[3],
            events_processed: ints[4],
            gate_failures: ints[5],
            retrains_triggered: ints[6],
            failures: ints[7],
            peak_rss_points: ints[8],
            wait_training: Summary {
                count: wait_count,
                sum: floats[0],
                sum_sq: floats[1],
                min: floats[2],
                max: floats[3],
            },
            util_training: floats[4],
            util_compute: floats[5],
            avg_queue_training: floats[6],
            final_mean_performance: floats[7],
            lost_work: floats[8],
            goodput: floats[9],
            cost: floats[10],
            wall_secs: floats[11],
            digest,
        })
    }
}

/// Cross-replication statistics for one metric of one group.
#[derive(Clone, Debug)]
pub struct MetricStats {
    pub name: &'static str,
    pub n: usize,
    pub mean: f64,
    pub std_dev: f64,
    /// Half-width of the 95% confidence interval of the mean
    /// (Student-t for small n, normal beyond).
    pub ci95: f64,
    pub min: f64,
    pub max: f64,
    /// Sketch-backed median across replications (t-digest; exact-rank
    /// error within the documented bound).
    pub p50: f64,
    /// Sketch-backed 95th percentile across replications.
    pub p95: f64,
}

/// All replications sharing one config name.
#[derive(Clone, Debug)]
pub struct GroupStats {
    pub name: String,
    /// Global cell indices, ascending. For an unsharded sweep these are
    /// also indices into `SweepResult::results`.
    pub cells: Vec<usize>,
    pub metrics: Vec<MetricStats>,
    /// Exact task-wait summary: every member cell's `wait_training`
    /// merged via [`Summary::merge_from`] in global cell order, so the
    /// merged N-shard value is bit-identical to the single-process one.
    pub wait: Summary,
    /// Per-metric t-digest over the replication values (same order as
    /// `metrics`); what `sweep-merge` combines across shards.
    pub sketches: Vec<TDigest>,
}

/// Group per-cell records by config name (first-appearance order) and
/// aggregate. This single function is the statistics path for *both*
/// the single-process sweep and the N-shard merge — feeding it the same
/// records in the same global order is what makes merged group stats
/// bit-identical, not merely close.
pub(crate) fn aggregate_cells(cells: &[CellRecord]) -> Vec<GroupStats> {
    let mut order: Vec<String> = Vec::new();
    let mut index: std::collections::HashMap<&str, Vec<usize>> = std::collections::HashMap::new();
    for (pos, c) in cells.iter().enumerate() {
        let slot = index.entry(c.name.as_str()).or_default();
        if slot.is_empty() {
            order.push(c.name.clone());
        }
        slot.push(pos);
    }
    order
        .into_iter()
        .map(|name| {
            let positions = index[name.as_str()].clone();
            let mut summaries = vec![Summary::new(); METRICS];
            let mut sketches: Vec<TDigest> = (0..METRICS).map(|_| TDigest::default()).collect();
            let mut names = [""; METRICS];
            let mut wait = Summary::new();
            for &p in &positions {
                for (m, (mname, v)) in cells[p].metric_values().into_iter().enumerate() {
                    names[m] = mname;
                    summaries[m].add(v);
                    sketches[m].add(v);
                }
                wait.merge_from(&cells[p].wait_training);
            }
            let metrics = summaries
                .into_iter()
                .enumerate()
                .map(|(m, s)| {
                    let n = s.count as usize;
                    let sd = s.std_dev();
                    MetricStats {
                        name: names[m],
                        n,
                        mean: s.mean(),
                        std_dev: sd,
                        ci95: if n > 1 {
                            t_critical_95(n - 1) * sd / (n as f64).sqrt()
                        } else {
                            0.0
                        },
                        min: s.min,
                        max: s.max,
                        p50: sketches[m].quantile(0.5),
                        p95: sketches[m].quantile(0.95),
                    }
                })
                .collect();
            GroupStats {
                name,
                cells: positions.into_iter().map(|p| cells[p].index).collect(),
                metrics,
                wait,
                sketches,
            }
        })
        .collect()
}

/// Two-sided 95% Student-t critical value for `df` degrees of freedom
/// (exact table through 30, normal approximation beyond).
pub(crate) fn t_critical_95(df: usize) -> f64 {
    const TABLE: [f64; 30] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228, 2.201, 2.179,
        2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086, 2.080, 2.074, 2.069, 2.064,
        2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
    ];
    if df == 0 {
        return f64::INFINITY;
    }
    if df <= TABLE.len() {
        TABLE[df - 1]
    } else {
        1.96
    }
}

/// RFC 4180: quote a CSV field iff it contains a comma, quote, or line
/// break; embedded quotes double. Group names are built from strategy
/// labels and hw-class specs and absolutely can contain commas.
pub(crate) fn csv_field(s: &str) -> std::borrow::Cow<'_, str> {
    if !s.contains([',', '"', '\n', '\r']) {
        return std::borrow::Cow::Borrowed(s);
    }
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for ch in s.chars() {
        if ch == '"' {
            out.push('"');
        }
        out.push(ch);
    }
    out.push('"');
    std::borrow::Cow::Owned(out)
}

/// Header shared by `sweep --export` and `sweep-merge --export`.
pub(crate) const CSV_HEADER: &str = "cell,name,seed,arrived,completed,tasks_executed,\
events_processed,util_training,util_compute,mean_wait_training_s,avg_queue_training,\
final_mean_performance,failures,lost_work_s,goodput,cost,wall_secs,wall_time_ms,\
peak_rss_points,digest\n";

/// One CSV row per cell. The `cell` column is the *global* grid index,
/// so shard CSVs concatenate into exactly the single-process export.
pub(crate) fn cells_to_csv(cells: &[CellRecord]) -> String {
    use std::fmt::Write;
    let mut s = String::from(CSV_HEADER);
    for c in cells {
        let _ = writeln!(
            s,
            "{},{},{},{},{},{},{},{:.6},{:.6},{:.3},{:.3},{:.4},{},{:.3},{:.6},{:.4},{:.4},{:.3},{},{}",
            c.index,
            csv_field(&c.name),
            c.seed,
            c.arrived,
            c.completed,
            c.tasks_executed,
            c.events_processed,
            c.util_training,
            c.util_compute,
            c.wait_training.mean(),
            c.avg_queue_training,
            c.final_mean_performance,
            c.failures,
            c.lost_work,
            c.goodput,
            c.cost,
            c.wall_secs,
            c.wall_secs * 1000.0,
            c.peak_rss_points,
            csv_field(&c.digest)
        );
    }
    s
}

/// Group table body shared by `SweepResult::table` and
/// `MergedSweep::table`.
pub(crate) fn render_group_lines(s: &mut String, groups: &[GroupStats]) {
    use std::fmt::Write;
    for g in groups {
        let _ = writeln!(s, "group '{}' (n={})", g.name, g.cells.len());
        for m in &g.metrics {
            let _ = writeln!(
                s,
                "  {:<24} {:>14.4} ± {:<10.4} [{:.4}, {:.4}]  p50 {:.4}  p95 {:.4}",
                m.name, m.mean, m.ci95, m.min, m.max, m.p50, m.p95
            );
        }
    }
}

/// Fixed configuration of the per-cell wall-time histogram carried by
/// every shard manifest: constant so shard histograms always merge
/// exactly (0–60 s in 250 ms bins; slower cells land in the overflow
/// bucket and still count).
const WALL_HIST_LO_MS: f64 = 0.0;
const WALL_HIST_HI_MS: f64 = 60_000.0;
const WALL_HIST_BINS: usize = 240;

fn new_wall_hist() -> FixedHistogram {
    FixedHistogram::new(WALL_HIST_LO_MS, WALL_HIST_HI_MS, WALL_HIST_BINS)
}

const MANIFEST_MAGIC: &[u8; 4] = b"PSSM";
const MANIFEST_VERSION: u16 = 1;

/// The per-shard artifact: which stride of which grid this process ran,
/// its per-cell records (digests included), per-group metric sketches
/// for mergeable quantiles, and the per-cell wall-time histogram.
/// Serialized as the `.psm` binary format (magic `PSSM`, version 1) via
/// `util/binio`.
#[derive(Clone, Debug)]
pub struct ShardManifest {
    pub shard: ShardSpec,
    /// Length of the *full* grid every shard enumerated.
    pub grid_len: usize,
    /// This shard's cells, ascending global index.
    pub cells: Vec<CellRecord>,
    /// Per group (first-appearance order): one t-digest per metric,
    /// built shard-locally; `sweep-merge` combines them with the
    /// order-insensitive `TDigest::merge_from`.
    pub group_sketches: Vec<(String, Vec<TDigest>)>,
    /// Per-cell wall-time milliseconds (exact integer merge across
    /// shards — fixed configuration, see `WALL_HIST_*`).
    pub wall_hist: FixedHistogram,
}

impl ShardManifest {
    /// Build the artifact for one finished shard run.
    pub fn from_cells(shard: ShardSpec, grid_len: usize, cells: Vec<CellRecord>) -> Self {
        let mut wall_hist = new_wall_hist();
        let mut order: Vec<String> = Vec::new();
        let mut sketches: std::collections::HashMap<String, Vec<TDigest>> =
            std::collections::HashMap::new();
        for c in &cells {
            wall_hist.add(c.wall_secs * 1000.0);
            let slot = sketches.entry(c.name.clone()).or_insert_with(|| {
                order.push(c.name.clone());
                (0..METRICS).map(|_| TDigest::default()).collect()
            });
            for (m, (_, v)) in c.metric_values().into_iter().enumerate() {
                slot[m].add(v);
            }
        }
        let group_sketches = order
            .into_iter()
            .map(|name| {
                let sk = sketches.remove(&name).expect("group registered above");
                (name, sk)
            })
            .collect();
        ShardManifest {
            shard,
            grid_len,
            cells,
            group_sketches,
            wall_hist,
        }
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.header(MANIFEST_MAGIC, MANIFEST_VERSION);
        w.varint(self.shard.index as u64);
        w.varint(self.shard.count as u64);
        w.varint(self.grid_len as u64);
        w.varint(self.cells.len() as u64);
        for c in &self.cells {
            c.write_to(&mut w);
        }
        w.varint(self.group_sketches.len() as u64);
        for (name, sketches) in &self.group_sketches {
            w.str(name);
            debug_assert_eq!(sketches.len(), METRICS);
            for sk in sketches {
                sk.write_to(&mut w);
            }
        }
        self.wall_hist.write_to(&mut w);
        w.into_bytes()
    }

    /// Decode + validate: shard spec in range, cells strictly ascending
    /// and owned by the shard's stride, indices inside the grid. A
    /// manifest that passes cannot corrupt a merge silently.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let mut r = ByteReader::new(bytes);
        r.check_header(MANIFEST_MAGIC, MANIFEST_VERSION, "shard manifest")?;
        let shard = ShardSpec::new(r.len_prefix()?, r.len_prefix()?)?;
        let grid_len = r.len_prefix()?;
        if grid_len == 0 {
            return Err(Error::Other("shard manifest: empty grid".into()));
        }
        // every cell record costs well over 32 bytes on the wire
        let n_cells = r.len_prefix_for(32)?;
        let mut cells = Vec::with_capacity(n_cells);
        let mut prev: Option<usize> = None;
        for _ in 0..n_cells {
            let c = CellRecord::read_from(&mut r)?;
            if c.index >= grid_len {
                return Err(Error::Other(format!(
                    "shard manifest: cell {} outside grid of {grid_len}",
                    c.index
                )));
            }
            if !shard.owns(c.index) {
                return Err(Error::Other(format!(
                    "shard manifest: cell {} does not belong to shard {shard}",
                    c.index
                )));
            }
            if prev.is_some_and(|p| c.index <= p) {
                return Err(Error::Other(
                    "shard manifest: cells out of order".into(),
                ));
            }
            prev = Some(c.index);
            cells.push(c);
        }
        let n_groups = r.len_prefix_for(1)?;
        let mut group_sketches = Vec::with_capacity(n_groups);
        for _ in 0..n_groups {
            let name = r.str()?;
            let mut sketches = Vec::with_capacity(METRICS);
            for _ in 0..METRICS {
                sketches.push(TDigest::read_from(&mut r)?);
            }
            group_sketches.push((name, sketches));
        }
        let wall_hist = FixedHistogram::read_from(&mut r)?;
        r.expect_eof("shard manifest")?;
        Ok(ShardManifest {
            shard,
            grid_len,
            cells,
            group_sketches,
            wall_hist,
        })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path, self.to_bytes())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let bytes = std::fs::read(&path).map_err(|e| {
            Error::Other(format!(
                "shard manifest {}: {e}",
                path.as_ref().display()
            ))
        })?;
        Self::from_bytes(&bytes)
    }
}

/// A full grid reassembled from shard manifests: the same reporting
/// surface as `SweepResult` (digests, group tables, per-cell CSV) plus
/// the merged wall-time histogram.
pub struct MergedSweep {
    /// How many shards the grid was split into.
    pub shards: usize,
    pub grid_len: usize,
    /// Every cell of the grid, global order.
    pub cells: Vec<CellRecord>,
    /// Recomputed in global cell order (bit-identical to the
    /// single-process sweep); quantiles overridden from the merged
    /// shard sketches.
    pub groups: Vec<GroupStats>,
    pub wall_hist: FixedHistogram,
}

impl MergedSweep {
    /// Deterministic per-cell digests, global order — byte-identical to
    /// the single-process sweep of the same grid.
    pub fn digests(&self) -> Vec<String> {
        self.cells.iter().map(|c| c.digest.clone()).collect()
    }

    pub fn events_total(&self) -> u64 {
        self.cells.iter().map(|c| c.events_processed).sum()
    }

    /// Per-cell CSV, identical in shape (and in every deterministic
    /// column) to `SweepResult::to_csv` of the unsharded sweep.
    pub fn to_csv(&self) -> String {
        cells_to_csv(&self.cells)
    }

    /// Human-readable aggregate table (same group body as
    /// `SweepResult::table`).
    pub fn table(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "sweep-merge: {} cells from {} shards, {} groups, {} events total",
            self.cells.len(),
            self.shards,
            self.groups.len(),
            self.events_total()
        );
        let _ = writeln!(
            s,
            "cell wall ms: p50 {:.1}  p95 {:.1}  p99 {:.1}",
            self.wall_hist.quantile(0.5),
            self.wall_hist.quantile(0.95),
            self.wall_hist.quantile(0.99)
        );
        render_group_lines(&mut s, &self.groups);
        s
    }
}

/// Combine N shard manifests back into one grid. Rejects incompatible
/// layouts, overlapping shards, and missing shards/cells by name —
/// merging must be all-or-nothing.
pub fn merge_shards(mut manifests: Vec<ShardManifest>) -> Result<MergedSweep> {
    let fail = |m: String| Err(Error::Config(format!("sweep-merge: {m}")));
    if manifests.is_empty() {
        return fail("no shard manifests".into());
    }
    let count = manifests[0].shard.count;
    let grid_len = manifests[0].grid_len;
    for m in &manifests {
        if m.shard.count != count {
            return fail(format!(
                "shard layout mismatch: {} vs {}",
                manifests[0].shard, m.shard
            ));
        }
        if m.grid_len != grid_len {
            return fail(format!(
                "grid length mismatch: {} vs {} cells",
                grid_len, m.grid_len
            ));
        }
    }
    manifests.sort_by_key(|m| m.shard.index);
    for pair in manifests.windows(2) {
        if pair[0].shard.index == pair[1].shard.index {
            return fail(format!(
                "overlapping shards: {} supplied twice",
                pair[0].shard
            ));
        }
    }
    if manifests.len() != count {
        for k in 0..count {
            if !manifests.iter().any(|m| m.shard.index == k) {
                return fail(format!("missing shard {k}/{count}"));
            }
        }
    }

    // Reassemble the grid in global cell order. Each manifest is
    // already validated (ascending, stride-owned), so a k-way merge by
    // index reproduces the single-process ordering exactly.
    let mut cells: Vec<CellRecord> = Vec::with_capacity(grid_len);
    {
        let mut cursors: Vec<std::iter::Peekable<std::vec::IntoIter<CellRecord>>> = manifests
            .iter_mut()
            .map(|m| std::mem::take(&mut m.cells).into_iter().peekable())
            .collect();
        for i in 0..grid_len {
            let c = cursors[i % count]
                .next()
                .filter(|c| c.index == i)
                .ok_or_else(|| {
                    Error::Config(format!(
                        "sweep-merge: missing cell {i} (shard {}/{count} incomplete)",
                        i % count
                    ))
                })?;
            cells.push(c);
        }
        for (k, mut cur) in cursors.into_iter().enumerate() {
            if let Some(extra) = cur.next() {
                return fail(format!(
                    "duplicate cell {} in shard {k}/{count}",
                    extra.index
                ));
            }
        }
    }

    // Exact statistics: same records, same global order, same function
    // as the single-process path => bit-identical mean/std/CI.
    let mut groups = aggregate_cells(&cells);

    // Approximate statistics: merge the per-shard sketches (shard-index
    // order; TDigest::merge_from is order-insensitive within the rank
    // bound) and override the group quantiles with the merged view.
    for g in groups.iter_mut() {
        let mut merged: Vec<TDigest> = (0..METRICS).map(|_| TDigest::default()).collect();
        for m in &manifests {
            if let Some((_, sk)) = m.group_sketches.iter().find(|(n, _)| n == &g.name) {
                for (dst, src) in merged.iter_mut().zip(sk) {
                    dst.merge_from(src);
                }
            }
        }
        if merged[0].count() != g.cells.len() as u64 {
            return fail(format!(
                "group '{}': sketches cover {} cells, grid has {}",
                g.name,
                merged[0].count(),
                g.cells.len()
            ));
        }
        for (ms, sk) in g.metrics.iter_mut().zip(&merged) {
            ms.p50 = sk.quantile(0.5);
            ms.p95 = sk.quantile(0.95);
        }
        g.sketches = merged;
    }

    let mut wall_hist = new_wall_hist();
    for m in &manifests {
        if !wall_hist.merge_from(&m.wall_hist) {
            return fail(format!(
                "shard {} wall-time histogram configuration disagrees",
                m.shard
            ));
        }
    }

    Ok(MergedSweep {
        shards: count,
        grid_len,
        cells,
        groups,
        wall_hist,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell(index: usize, name: &str, seed: u64) -> CellRecord {
        let mut wait = Summary::new();
        wait.add(1.5 * (seed as f64 + 1.0));
        wait.add(0.5 * (index as f64 + 1.0));
        CellRecord {
            index,
            name: name.into(),
            seed,
            arrived: 100 + index as u64,
            completed: 90 + seed,
            in_flight: 10,
            tasks_executed: 300,
            events_processed: 1000 + 7 * index as u64,
            gate_failures: 1,
            retrains_triggered: 2,
            failures: 0,
            wait_training: wait,
            util_training: 0.5 + 0.01 * index as f64,
            util_compute: 0.25,
            avg_queue_training: 0.1 * seed as f64,
            final_mean_performance: 0.9,
            lost_work: 0.0,
            goodput: 1.0,
            cost: 12.5 + index as f64,
            wall_secs: 0.001 * (index as f64 + 1.0),
            peak_rss_points: 42,
            digest: format!("v2;name={name};seed={seed};cell={index}"),
        }
    }

    fn grid(n: usize) -> Vec<CellRecord> {
        (0..n)
            .map(|i| cell(i, if i % 2 == 0 { "even" } else { "odd" }, i as u64 * 3))
            .collect()
    }

    #[test]
    fn spec_parse_and_ownership() {
        let s = ShardSpec::parse("1/3").unwrap();
        assert_eq!((s.index, s.count), (1, 3));
        assert_eq!(s.to_string(), "1/3");
        assert!(!s.is_full());
        assert!(ShardSpec::parse("0/1").unwrap().is_full());
        let owned: Vec<usize> = (0..10).filter(|&i| s.owns(i)).collect();
        assert_eq!(owned, vec![1, 4, 7]);
        // the strides of all shards partition the grid exactly
        for n in 1..=5usize {
            let mut seen = vec![0u32; 17];
            for k in 0..n {
                let sp = ShardSpec::new(k, n).unwrap();
                for (i, s) in seen.iter_mut().enumerate() {
                    *s += u32::from(sp.owns(i));
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "n={n}: {seen:?}");
        }
        for bad in ["", "3", "a/b", "1/0", "3/3", "4/2", "-1/2"] {
            assert!(ShardSpec::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn manifest_roundtrips_bit_exact() {
        let all = grid(11);
        let spec = ShardSpec::new(2, 3).unwrap();
        let mine: Vec<CellRecord> = all.iter().filter(|c| spec.owns(c.index)).cloned().collect();
        let m = ShardManifest::from_cells(spec, 11, mine);
        let bytes = m.to_bytes();
        let back = ShardManifest::from_bytes(&bytes).unwrap();
        assert_eq!(back.shard, spec);
        assert_eq!(back.grid_len, 11);
        assert_eq!(back.cells.len(), m.cells.len());
        for (a, b) in m.cells.iter().zip(&back.cells) {
            assert_eq!(a.index, b.index);
            assert_eq!(a.digest, b.digest);
            assert_eq!(a.wait_training.sum.to_bits(), b.wait_training.sum.to_bits());
            assert_eq!(a.cost.to_bits(), b.cost.to_bits());
            assert_eq!(a.peak_rss_points, b.peak_rss_points);
        }
        assert_eq!(back.group_sketches.len(), m.group_sketches.len());
        assert_eq!(back.wall_hist.count(), m.wall_hist.count());
        // and the encoding is deterministic
        assert_eq!(bytes, back.to_bytes());
    }

    #[test]
    fn manifest_rejects_corruption() {
        let all = grid(6);
        let spec = ShardSpec::new(0, 2).unwrap();
        let mine: Vec<CellRecord> = all.iter().filter(|c| spec.owns(c.index)).cloned().collect();
        let good = ShardManifest::from_cells(spec, 6, mine.clone()).to_bytes();
        assert!(ShardManifest::from_bytes(&good).is_ok());
        // magic
        let mut bad = good.clone();
        bad[0] = b'X';
        let err = ShardManifest::from_bytes(&bad).unwrap_err();
        assert!(err.to_string().contains("shard manifest"), "{err}");
        // truncation
        assert!(ShardManifest::from_bytes(&good[..good.len() - 4]).is_err());
        // trailing bytes
        let mut long = good.clone();
        long.push(0);
        assert!(ShardManifest::from_bytes(&long).is_err());
        // a cell the shard does not own
        let foreign = ShardManifest::from_cells(spec, 6, vec![mine[0].clone(), all[1].clone()]);
        let err = ShardManifest::from_bytes(&foreign.to_bytes()).unwrap_err();
        assert!(err.to_string().contains("does not belong"), "{err}");
        // out-of-order cells
        let swapped =
            ShardManifest::from_cells(spec, 6, vec![mine[1].clone(), mine[0].clone()]);
        let err = ShardManifest::from_bytes(&swapped.to_bytes()).unwrap_err();
        assert!(err.to_string().contains("out of order"), "{err}");
        // cell outside the declared grid
        let outside = ShardManifest::from_cells(spec, 3, vec![all[4].clone()]);
        let err = ShardManifest::from_bytes(&outside.to_bytes()).unwrap_err();
        assert!(err.to_string().contains("outside grid"), "{err}");
    }

    #[test]
    fn merge_is_bit_identical_to_direct_aggregation() {
        let all = grid(13);
        let direct = aggregate_cells(&all);
        for n in [1usize, 2, 3, 5] {
            let manifests: Vec<ShardManifest> = (0..n)
                .map(|k| {
                    let spec = ShardSpec::new(k, n).unwrap();
                    let mine: Vec<CellRecord> =
                        all.iter().filter(|c| spec.owns(c.index)).cloned().collect();
                    // through the wire format, like the real tool
                    ShardManifest::from_bytes(
                        &ShardManifest::from_cells(spec, all.len(), mine).to_bytes(),
                    )
                    .unwrap()
                })
                .collect();
            let merged = merge_shards(manifests).unwrap();
            assert_eq!(merged.shards, n);
            assert_eq!(merged.cells.len(), all.len());
            for (a, b) in all.iter().zip(&merged.cells) {
                assert_eq!(a.index, b.index);
                assert_eq!(a.digest, b.digest);
            }
            assert_eq!(merged.to_csv(), cells_to_csv(&all));
            assert_eq!(direct.len(), merged.groups.len());
            for (d, m) in direct.iter().zip(&merged.groups) {
                assert_eq!(d.name, m.name);
                assert_eq!(d.cells, m.cells);
                assert_eq!(d.wait.count, m.wait.count);
                assert_eq!(d.wait.sum.to_bits(), m.wait.sum.to_bits());
                assert_eq!(d.wait.sum_sq.to_bits(), m.wait.sum_sq.to_bits());
                for (dm, mm) in d.metrics.iter().zip(&m.metrics) {
                    assert_eq!(dm.mean.to_bits(), mm.mean.to_bits(), "{}", dm.name);
                    assert_eq!(dm.std_dev.to_bits(), mm.std_dev.to_bits());
                    assert_eq!(dm.ci95.to_bits(), mm.ci95.to_bits());
                    assert_eq!(dm.min.to_bits(), mm.min.to_bits());
                    assert_eq!(dm.max.to_bits(), mm.max.to_bits());
                    // sketch-backed quantiles stay inside the value range
                    assert!(mm.p50 >= mm.min - 1e-9 && mm.p50 <= mm.max + 1e-9);
                    assert!(mm.p95 >= mm.min - 1e-9 && mm.p95 <= mm.max + 1e-9);
                }
            }
            assert_eq!(merged.wall_hist.count(), all.len() as u64);
            assert!(merged.table().contains("group 'even'"));
        }
    }

    #[test]
    fn merge_rejects_overlap_missing_and_mismatch() {
        let all = grid(9);
        let mk = |k: usize, n: usize, grid_len: usize| {
            let spec = ShardSpec::new(k, n).unwrap();
            let mine: Vec<CellRecord> = all
                .iter()
                .filter(|c| spec.owns(c.index) && c.index < grid_len)
                .cloned()
                .collect();
            ShardManifest::from_cells(spec, grid_len, mine)
        };
        let err = merge_shards(vec![]).unwrap_err();
        assert!(err.to_string().contains("no shard manifests"), "{err}");
        let err = merge_shards(vec![mk(0, 3, 9), mk(1, 3, 9)]).unwrap_err();
        assert!(err.to_string().contains("missing shard 2/3"), "{err}");
        let err = merge_shards(vec![mk(0, 3, 9), mk(1, 3, 9), mk(1, 3, 9)]).unwrap_err();
        assert!(err.to_string().contains("overlapping shards: 1/3"), "{err}");
        let err = merge_shards(vec![mk(0, 2, 9), mk(1, 3, 9)]).unwrap_err();
        assert!(err.to_string().contains("shard layout mismatch"), "{err}");
        let err = merge_shards(vec![mk(0, 2, 9), mk(1, 2, 7)]).unwrap_err();
        assert!(err.to_string().contains("grid length mismatch"), "{err}");
        // a shard that ran only part of its stride is caught cell-wise
        let mut partial = mk(1, 3, 9);
        partial.cells.pop();
        let err = merge_shards(vec![mk(0, 3, 9), partial, mk(2, 3, 9)]).unwrap_err();
        assert!(err.to_string().contains("missing cell 7"), "{err}");
        // the happy path still merges
        assert!(merge_shards(vec![mk(0, 3, 9), mk(1, 3, 9), mk(2, 3, 9)]).is_ok());
    }

    #[test]
    fn csv_field_quotes_per_rfc4180() {
        assert_eq!(csv_field("plain"), "plain");
        assert_eq!(csv_field("has,comma"), "\"has,comma\"");
        assert_eq!(csv_field("say \"hi\""), "\"say \"\"hi\"\"\"");
        assert_eq!(csv_field("line\nbreak"), "\"line\nbreak\"");
        let csv = cells_to_csv(&[cell(0, "cap=4,fac=1.5", 7)]);
        let row = csv.lines().nth(1).unwrap();
        assert!(row.contains("\"cap=4,fac=1.5\""), "{row}");
        assert!(csv.starts_with(CSV_HEADER));
    }

    #[test]
    fn aggregate_exposes_wait_and_quantiles() {
        let all = grid(8);
        let groups = aggregate_cells(&all);
        assert_eq!(groups.len(), 2);
        let even = &groups[0];
        assert_eq!(even.name, "even");
        assert_eq!(even.cells, vec![0, 2, 4, 6]);
        // wait merges every member cell's summary (2 samples per cell)
        assert_eq!(even.wait.count, 8);
        assert!(even.wait.max >= even.wait.min);
        assert_eq!(even.sketches.len(), METRICS);
        let arrived = even.metrics.iter().find(|m| m.name == "arrived").unwrap();
        assert_eq!(arrived.n, 4);
        assert!(arrived.p50 >= arrived.min && arrived.p50 <= arrived.max);
        assert!(arrived.p95 >= arrived.p50);
    }
}
