//! Parallel experiment sweep / replication engine.
//!
//! PipeSim's value is running *many* stochastic experiment variants
//! (scheduling and retraining strategies, arrival intensities, cluster
//! allocations, replication seeds) against one fitted model set.
//! Strategies are data (`StrategySpec`), so they are a sweep axis like
//! any other: vary `cfg.infra.scheduler` / `cfg.runtime_view.trigger`
//! across cells (the CLI's `sweep --schedulers`/`--triggers` does
//! exactly that). Each cell of a sweep
//! is an independent, deterministically seeded `Experiment`, which makes
//! the workload embarrassingly parallel: this engine fans the cells over
//! a `std::thread::scope` worker pool and collects results in the exact
//! order the cells were added — the output is byte-identical no matter
//! how many workers ran it (see `ExperimentResult::digest`).
//!
//! Shared inputs (`SimParams`, the optional PJRT `Runtime`) cross thread
//! boundaries behind `Arc`s; per-run mutable state (RNG streams, replay
//! cursors, the trace store) lives inside each worker's experiment.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::runtime::Runtime;
use crate::stats::Summary;
use crate::trace::TraceSink;

use super::config::ExperimentConfig;
use super::experiment::Experiment;
use super::params::SimParams;
use super::result::ExperimentResult;

/// Per-cell [`TraceSink`] constructor: invoked with the cell's input
/// index and config just before the cell runs (on the worker thread),
/// and the returned sink is injected via `Experiment::with_sink` —
/// capture is forced on for that cell, and a streaming sink (e.g.
/// `trace::StreamingPstSink`) keeps the capture out of memory, which is
/// what makes `sweep --trace-dir` memory-flat instead of buffering
/// every cell's trace until the sweep ends.
pub type CellSinkFactory =
    Box<dyn Fn(usize, &ExperimentConfig) -> Result<Box<dyn TraceSink>> + Send + Sync>;

/// Per-cell completion hook: invoked on the worker thread with the
/// cell's input index, config, and finished result — before the result
/// is handed back for ordering. This is how `sweep --metrics-dir`
/// writes one OpenMetrics file per cell without buffering every cell's
/// export until the sweep ends; a hook error fails that cell's run.
pub type CellHook = Box<dyn Fn(usize, &ExperimentConfig, &ExperimentResult) -> Result<()> + Send + Sync>;

/// A sweep under construction: shared inputs + the cell grid.
pub struct Sweep {
    params: Arc<SimParams>,
    runtime: Option<Arc<Runtime>>,
    cells: Vec<ExperimentConfig>,
    jobs: usize,
    sink_factory: Option<CellSinkFactory>,
    cell_hook: Option<CellHook>,
}

impl Sweep {
    pub fn new(params: impl Into<Arc<SimParams>>) -> Self {
        Sweep {
            params: params.into(),
            runtime: None,
            cells: Vec::new(),
            jobs: 0,
            sink_factory: None,
            cell_hook: None,
        }
    }

    /// Use the AOT artifacts for all cells' simulation-time sampling.
    pub fn with_runtime(mut self, rt: Option<Arc<Runtime>>) -> Self {
        self.runtime = rt;
        self
    }

    /// Construct a [`TraceSink`] per cell (see [`CellSinkFactory`]).
    /// Capture is then on for every cell regardless of
    /// `capture_trace`; a factory error fails that cell's run.
    pub fn with_cell_sinks(mut self, factory: CellSinkFactory) -> Self {
        self.sink_factory = Some(factory);
        self
    }

    /// Run a [`CellHook`] after each cell completes (see its docs).
    pub fn with_cell_hook(mut self, hook: CellHook) -> Self {
        self.cell_hook = Some(hook);
        self
    }

    /// Worker count. `0` (the default) means one per available core.
    pub fn jobs(mut self, jobs: usize) -> Self {
        self.jobs = jobs;
        self
    }

    /// Append one cell. Cells sharing a config `name` are treated as
    /// replications of each other when aggregating statistics.
    pub fn add(&mut self, cfg: ExperimentConfig) -> &mut Self {
        self.cells.push(cfg);
        self
    }

    /// Append `n` replications of `base` with seeds `seed0..seed0+n`.
    pub fn add_replications(&mut self, base: &ExperimentConfig, seed0: u64, n: usize) -> &mut Self {
        for i in 0..n as u64 {
            let mut cfg = base.clone();
            cfg.seed = seed0 + i;
            self.cells.push(cfg);
        }
        self
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// Run every cell to completion and aggregate. The i-th entry of
    /// `SweepResult::results` is always the i-th added cell, and each
    /// cell's outcome is bit-identical across any `jobs` value.
    pub fn run(self) -> Result<SweepResult> {
        let started = std::time::Instant::now();
        let Sweep {
            params,
            runtime,
            cells,
            jobs,
            sink_factory,
            cell_hook,
        } = self;
        if cells.is_empty() {
            return Err(Error::Config("sweep: no cells to run".into()));
        }
        for cfg in &cells {
            cfg.validate()?;
        }
        let jobs = effective_jobs(jobs, cells.len());

        // Work-stealing by atomic cursor: workers claim the next cell
        // index and tag results with it, so completion order (which IS
        // scheduling-dependent) never leaks into the output order.
        let next = AtomicUsize::new(0);
        let per_worker: Vec<Vec<(usize, Result<ExperimentResult>)>> =
            std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(jobs);
                for _ in 0..jobs {
                    let params = &params;
                    let runtime = &runtime;
                    let cells = &cells;
                    let next = &next;
                    let sink_factory = &sink_factory;
                    let cell_hook = &cell_hook;
                    handles.push(scope.spawn(move || {
                        let mut out = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= cells.len() {
                                break;
                            }
                            let exp = Experiment::new(cells[i].clone(), params.clone())
                                .with_runtime(runtime.clone());
                            // a per-cell sink (streamed captures) is
                            // built on the worker, next to its run
                            let r = match sink_factory.as_ref().map(|f| f(i, &cells[i])) {
                                None => exp.run(),
                                Some(Ok(sink)) => exp.with_sink(sink).run(),
                                Some(Err(e)) => Err(e),
                            };
                            // per-cell exports happen here, on the
                            // worker, while the result is still warm
                            let r = r.and_then(|res| {
                                if let Some(hook) = cell_hook.as_ref() {
                                    hook(i, &cells[i], &res)?;
                                }
                                Ok(res)
                            });
                            out.push((i, r));
                        }
                        out
                    }));
                }
                handles
                    .into_iter()
                    .map(|h| h.join().expect("sweep worker panicked"))
                    .collect()
            });

        let mut slots: Vec<Option<ExperimentResult>> = (0..cells.len()).map(|_| None).collect();
        for (i, r) in per_worker.into_iter().flatten() {
            slots[i] = Some(r?);
        }
        let results: Vec<ExperimentResult> = slots
            .into_iter()
            .map(|s| s.expect("sweep: unclaimed cell"))
            .collect();

        let groups = aggregate_groups(&results);
        Ok(SweepResult {
            results,
            groups,
            jobs,
            wall_secs: started.elapsed().as_secs_f64(),
        })
    }
}

/// Resolve the worker count: explicit `jobs`, else one per core, never
/// more than there are cells.
pub fn effective_jobs(jobs: usize, cells: usize) -> usize {
    let auto = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let j = if jobs == 0 { auto } else { jobs };
    j.clamp(1, cells.max(1))
}

/// Cross-replication statistics for one metric of one group.
#[derive(Clone, Debug)]
pub struct MetricStats {
    pub name: &'static str,
    pub n: usize,
    pub mean: f64,
    pub std_dev: f64,
    /// Half-width of the 95% confidence interval of the mean
    /// (Student-t for small n, normal beyond).
    pub ci95: f64,
    pub min: f64,
    pub max: f64,
}

/// All replications sharing one config name.
#[derive(Clone, Debug)]
pub struct GroupStats {
    pub name: String,
    /// Indices into `SweepResult::results`, input order.
    pub cells: Vec<usize>,
    pub metrics: Vec<MetricStats>,
}

/// Outcome of a sweep: per-cell results in input order + aggregates.
pub struct SweepResult {
    pub results: Vec<ExperimentResult>,
    /// Groups in order of first appearance.
    pub groups: Vec<GroupStats>,
    pub jobs: usize,
    pub wall_secs: f64,
}

impl SweepResult {
    /// Deterministic per-cell digests, input order — the parallelism
    /// invariant: identical across any `jobs` value.
    pub fn digests(&self) -> Vec<String> {
        self.results.iter().map(|r| r.digest()).collect()
    }

    /// Total simulated events across all cells.
    pub fn events_total(&self) -> u64 {
        self.results.iter().map(|r| r.events_processed).sum()
    }

    /// Aggregate events/sec over the sweep's wall-clock.
    pub fn events_per_sec(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            return 0.0;
        }
        self.events_total() as f64 / self.wall_secs
    }

    /// Human-readable aggregate table (mean ± 95% CI per group).
    pub fn table(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "sweep: {} cells, {} groups, {} jobs, {:.2}s wall, {:.0} events/s aggregate",
            self.results.len(),
            self.groups.len(),
            self.jobs,
            self.wall_secs,
            self.events_per_sec()
        );
        for g in &self.groups {
            let _ = writeln!(s, "group '{}' (n={})", g.name, g.cells.len());
            for m in &g.metrics {
                let _ = writeln!(
                    s,
                    "  {:<24} {:>14.4} ± {:<10.4} [{:.4}, {:.4}]",
                    m.name, m.mean, m.ci95, m.min, m.max
                );
            }
        }
        s
    }

    /// Per-cell CSV: one row per cell, input order.
    pub fn to_csv(&self) -> String {
        use std::fmt::Write;
        let mut s = String::from(
            "cell,name,seed,arrived,completed,tasks_executed,events_processed,\
             util_training,util_compute,mean_wait_training_s,avg_queue_training,\
             final_mean_performance,failures,lost_work_s,goodput,cost,wall_secs,\
             wall_time_ms,peak_rss_points\n",
        );
        for (i, r) in self.results.iter().enumerate() {
            let _ = writeln!(
                s,
                "{i},{},{},{},{},{},{},{:.6},{:.6},{:.3},{:.3},{:.4},{},{:.3},{:.6},{:.4},{:.4},{:.3},{}",
                r.name,
                r.seed,
                r.arrived,
                r.completed,
                r.tasks_executed,
                r.events_processed,
                r.util_training,
                r.util_compute,
                r.wait_training.mean(),
                r.avg_queue_training,
                r.final_mean_performance,
                r.failures,
                r.lost_work,
                r.goodput,
                r.cost,
                r.wall_secs,
                r.wall_secs * 1000.0,
                r.tsdb.resident_points()
            );
        }
        s
    }
}

/// The metrics aggregated across replications.
fn metric_values(r: &ExperimentResult) -> [(&'static str, f64); 16] {
    [
        ("arrived", r.arrived as f64),
        ("completed", r.completed as f64),
        ("in_flight", r.in_flight as f64),
        ("tasks_executed", r.tasks_executed as f64),
        ("events_processed", r.events_processed as f64),
        ("gate_failures", r.gate_failures as f64),
        ("retrains_triggered", r.retrains_triggered as f64),
        ("util_training", r.util_training),
        ("util_compute", r.util_compute),
        ("mean_wait_training_s", r.wait_training.mean()),
        ("avg_queue_training", r.avg_queue_training),
        ("final_mean_performance", r.final_mean_performance),
        ("failures", r.failures as f64),
        ("lost_work_s", r.lost_work),
        ("goodput", r.goodput),
        ("cost", r.cost),
    ]
}

fn aggregate_groups(results: &[ExperimentResult]) -> Vec<GroupStats> {
    let mut order: Vec<String> = Vec::new();
    let mut cells_by_name: std::collections::HashMap<&str, Vec<usize>> =
        std::collections::HashMap::new();
    for (i, r) in results.iter().enumerate() {
        let slot = cells_by_name.entry(r.name.as_str()).or_default();
        if slot.is_empty() {
            order.push(r.name.clone());
        }
        slot.push(i);
    }
    order
        .into_iter()
        .map(|name| {
            let cells = cells_by_name[name.as_str()].clone();
            let n_metrics = metric_values(&results[cells[0]]).len();
            let mut summaries = vec![Summary::new(); n_metrics];
            let mut names = vec![""; n_metrics];
            for &i in &cells {
                for (m, (mname, v)) in metric_values(&results[i]).into_iter().enumerate() {
                    names[m] = mname;
                    summaries[m].add(v);
                }
            }
            let metrics = summaries
                .into_iter()
                .enumerate()
                .map(|(m, s)| {
                    let n = s.count as usize;
                    let sd = s.std_dev();
                    MetricStats {
                        name: names[m],
                        n,
                        mean: s.mean(),
                        std_dev: sd,
                        ci95: if n > 1 {
                            t_critical_95(n - 1) * sd / (n as f64).sqrt()
                        } else {
                            0.0
                        },
                        min: s.min,
                        max: s.max,
                    }
                })
                .collect();
            GroupStats {
                name,
                cells,
                metrics,
            }
        })
        .collect()
}

/// Two-sided 95% Student-t critical value for `df` degrees of freedom
/// (exact table through 30, normal approximation beyond).
fn t_critical_95(df: usize) -> f64 {
    const TABLE: [f64; 30] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228, 2.201, 2.179,
        2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086, 2.080, 2.074, 2.069, 2.064,
        2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
    ];
    if df == 0 {
        return f64::INFINITY;
    }
    if df <= TABLE.len() {
        TABLE[df - 1]
    } else {
        1.96
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{fit_params, ArrivalSpec};
    use crate::empirical::GroundTruth;

    fn quick_params() -> SimParams {
        let db = GroundTruth::new(31).generate_weeks(2);
        fit_params(&db, None).unwrap()
    }

    fn small_cfg(name: &str, seed: u64) -> ExperimentConfig {
        ExperimentConfig {
            name: name.into(),
            seed,
            horizon: 6.0 * 3600.0,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 90.0,
            },
            record_traces: false,
            sample_interval: 600.0,
            ..Default::default()
        }
    }

    /// Shared inputs must be shareable across worker threads.
    #[test]
    fn shared_inputs_are_send_sync() {
        fn check<T: Send + Sync>() {}
        check::<SimParams>();
        check::<ExperimentConfig>();
        check::<Runtime>();
        check::<crate::runtime::pool::Backend>();
        fn check_send<T: Send>() {}
        check_send::<ExperimentResult>();
        check_send::<crate::error::Error>();
    }

    #[test]
    fn results_come_back_in_input_order() {
        let params = Arc::new(quick_params());
        let mut sweep = Sweep::new(params).jobs(3);
        for seed in [9u64, 1, 7, 3, 5] {
            sweep.add(small_cfg(&format!("cell-{seed}"), seed));
        }
        let out = sweep.run().unwrap();
        let seeds: Vec<u64> = out.results.iter().map(|r| r.seed).collect();
        assert_eq!(seeds, vec![9, 1, 7, 3, 5]);
        assert_eq!(out.results[2].name, "cell-7");
    }

    #[test]
    fn parallel_and_serial_runs_are_byte_identical() {
        let params = Arc::new(quick_params());
        let build = |jobs| {
            let mut sweep = Sweep::new(params.clone()).jobs(jobs);
            sweep.add_replications(&small_cfg("rep", 0), 100, 6);
            sweep.add(small_cfg("solo", 42));
            sweep.run().unwrap()
        };
        let serial = build(1);
        let parallel = build(4);
        assert_eq!(serial.digests(), parallel.digests());
        assert_eq!(serial.jobs, 1);
        assert!(parallel.jobs >= 1);
    }

    #[test]
    fn groups_aggregate_replications() {
        let params = Arc::new(quick_params());
        let mut sweep = Sweep::new(params).jobs(2);
        sweep.add_replications(&small_cfg("a", 0), 1, 4);
        sweep.add_replications(&small_cfg("b", 0), 50, 2);
        let out = sweep.run().unwrap();
        assert_eq!(out.groups.len(), 2);
        assert_eq!(out.groups[0].name, "a");
        assert_eq!(out.groups[0].cells, vec![0, 1, 2, 3]);
        assert_eq!(out.groups[1].cells, vec![4, 5]);
        let arrived = out.groups[0]
            .metrics
            .iter()
            .find(|m| m.name == "arrived")
            .unwrap();
        assert_eq!(arrived.n, 4);
        assert!(arrived.min <= arrived.mean && arrived.mean <= arrived.max);
        assert!(arrived.ci95 >= 0.0);
        assert!(arrived.mean > 50.0, "6h at 90s gaps: {}", arrived.mean);
        // reliability metrics aggregate too; failure-free cells report
        // perfect goodput and zero losses
        let goodput = out.groups[0]
            .metrics
            .iter()
            .find(|m| m.name == "goodput")
            .unwrap();
        assert_eq!(goodput.mean, 1.0);
        let lost = out.groups[0]
            .metrics
            .iter()
            .find(|m| m.name == "lost_work_s")
            .unwrap();
        assert_eq!(lost.max, 0.0);
        // table + csv render without panicking and carry the group names
        assert!(out.table().contains("group 'a'"));
        assert!(out.to_csv().lines().count() == 7);
        assert!(out.to_csv().starts_with("cell,name,seed,"));
        assert!(out.to_csv().contains("goodput"));
        // runtime-cost columns ride at the end of every row
        let csv = out.to_csv();
        let header = csv.lines().next().unwrap();
        assert!(header.ends_with("wall_time_ms,peak_rss_points"));
        let first = csv.lines().nth(1).unwrap();
        assert_eq!(first.split(',').count(), header.split(',').count());
    }

    #[test]
    fn empty_sweep_is_an_error() {
        let params = Arc::new(quick_params());
        assert!(Sweep::new(params).run().is_err());
    }

    #[test]
    fn cell_sink_factory_runs_per_cell_and_stays_digest_neutral() {
        use std::sync::atomic::AtomicU64;

        use crate::trace::{TraceEvent, TraceSink};

        struct Counting {
            events: Arc<AtomicU64>,
        }
        impl TraceSink for Counting {
            fn record(&mut self, _ev: &TraceEvent) {
                self.events.fetch_add(1, Ordering::Relaxed);
            }
        }
        let params = Arc::new(quick_params());
        let cells_seen = Arc::new(AtomicUsize::new(0));
        let events = Arc::new(AtomicU64::new(0));
        let build = |with_sinks: bool| {
            let mut sweep = Sweep::new(params.clone()).jobs(2);
            if with_sinks {
                let cells_seen = cells_seen.clone();
                let events = events.clone();
                sweep = sweep.with_cell_sinks(Box::new(move |_i, _cfg| {
                    cells_seen.fetch_add(1, Ordering::Relaxed);
                    let sink: Box<dyn TraceSink> = Box::new(Counting {
                        events: events.clone(),
                    });
                    Ok(sink)
                }));
            }
            sweep.add_replications(&small_cfg("sinks", 0), 10, 3);
            sweep.run().unwrap()
        };
        let plain = build(false);
        let sunk = build(true);
        assert_eq!(cells_seen.load(Ordering::Relaxed), 3, "one sink per cell");
        assert!(events.load(Ordering::Relaxed) > 1000, "sinks saw the streams");
        // injected sinks are pure observers
        assert_eq!(plain.digests(), sunk.digests());
        // streaming-style sinks drain empty: meta only, no buffered events
        assert!(sunk
            .results
            .iter()
            .all(|r| r.trace.as_ref().is_some_and(|t| t.is_empty())));
        // a factory error fails the sweep, not the process
        let mut sweep = Sweep::new(params.clone()).jobs(1);
        sweep.add(small_cfg("bad", 1));
        let out = sweep
            .with_cell_sinks(Box::new(|_i, _cfg| {
                Err(crate::error::Error::Config("no sink for you".into()))
            }))
            .run();
        assert!(out.is_err());
    }

    #[test]
    fn cell_hook_fires_per_cell_and_errors_fail_the_sweep() {
        let params = Arc::new(quick_params());
        let seen = Arc::new(AtomicUsize::new(0));
        let mut sweep = Sweep::new(params.clone()).jobs(2);
        sweep.add_replications(&small_cfg("hooked", 0), 20, 3);
        let seen2 = seen.clone();
        let out = sweep
            .with_cell_hook(Box::new(move |i, cfg, r| {
                assert!(i < 3);
                assert_eq!(cfg.name, "hooked");
                assert_eq!(cfg.seed, r.seed);
                seen2.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }))
            .run()
            .unwrap();
        assert_eq!(seen.load(Ordering::Relaxed), 3, "one hook call per cell");
        assert_eq!(out.results.len(), 3);
        // a hook error surfaces as the sweep's error
        let mut sweep = Sweep::new(params).jobs(1);
        sweep.add(small_cfg("bad-hook", 1));
        let out = sweep
            .with_cell_hook(Box::new(|_i, _cfg, _r| {
                Err(crate::error::Error::Config("hook says no".into()))
            }))
            .run();
        assert!(out.is_err());
    }

    #[test]
    fn effective_jobs_clamps() {
        assert_eq!(effective_jobs(8, 3), 3);
        assert_eq!(effective_jobs(2, 100), 2);
        assert!(effective_jobs(0, 100) >= 1);
        assert_eq!(effective_jobs(1, 0), 1);
    }

    #[test]
    fn t_table_sane() {
        assert!(t_critical_95(1) > 12.0);
        assert!((t_critical_95(29) - 2.045).abs() < 1e-9);
        assert_eq!(t_critical_95(1000), 1.96);
    }
}
