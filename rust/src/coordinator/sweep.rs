//! Parallel experiment sweep / replication engine.
//!
//! PipeSim's value is running *many* stochastic experiment variants
//! (scheduling and retraining strategies, arrival intensities, cluster
//! allocations, replication seeds) against one fitted model set.
//! Strategies are data (`StrategySpec`), so they are a sweep axis like
//! any other: vary `cfg.infra.scheduler` / `cfg.runtime_view.trigger`
//! across cells (the CLI's `sweep --schedulers`/`--triggers` does
//! exactly that). Each cell of a sweep
//! is an independent, deterministically seeded `Experiment`, which makes
//! the workload embarrassingly parallel: this engine fans the cells over
//! a `std::thread::scope` worker pool and collects results in the exact
//! order the cells were added — the output is byte-identical no matter
//! how many workers ran it (see `ExperimentResult::digest`).
//!
//! The same determinism extends *across processes*: [`Sweep::shard`]
//! restricts a run to the `i % N == k` stride of the grid while keeping
//! global cell indices (and therefore group names, digests, and
//! per-cell output filenames) shard-invariant, and the resulting
//! [`ShardManifest`]s merge back into the single-process outcome via
//! [`super::shard::merge_shards`].
//!
//! Failure isolation: each cell's body (sink construction, the run,
//! the completion hook) executes under `catch_unwind`, so one panicking
//! or failing cell cannot poison the worker pool — the sweep reports
//! every failed cell with its (index, name, seed) attached instead of
//! discarding the grid.
//!
//! Shared inputs (`SimParams`, the optional PJRT `Runtime`) cross thread
//! boundaries behind `Arc`s; per-run mutable state (RNG streams, replay
//! cursors, the trace store) lives inside each worker's experiment.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::runtime::Runtime;
use crate::trace::TraceSink;

use super::config::ExperimentConfig;
use super::experiment::Experiment;
use super::params::SimParams;
use super::result::ExperimentResult;
use super::shard::{
    aggregate_cells, cells_to_csv, render_group_lines, CellRecord, GroupStats, ShardManifest,
    ShardSpec,
};

/// Per-cell [`TraceSink`] constructor: invoked with the cell's global
/// grid index and config just before the cell runs (on the worker
/// thread), and the returned sink is injected via `Experiment::with_sink`
/// — capture is forced on for that cell, and a streaming sink (e.g.
/// `trace::StreamingPstSink`) keeps the capture out of memory, which is
/// what makes `sweep --trace-dir` memory-flat instead of buffering
/// every cell's trace until the sweep ends. Under [`Sweep::shard`] the
/// index is still the *global* one, so per-cell filenames derived from
/// it are shard-invariant.
pub type CellSinkFactory =
    Box<dyn Fn(usize, &ExperimentConfig) -> Result<Box<dyn TraceSink>> + Send + Sync>;

/// Per-cell completion hook: invoked on the worker thread with the
/// cell's global grid index, config, and finished result — before the
/// result is handed back for ordering. This is how `sweep --metrics-dir`
/// writes one OpenMetrics file per cell without buffering every cell's
/// export until the sweep ends; a hook error (or panic) fails that
/// cell's run, attributed, without taking down the sweep.
pub type CellHook = Box<dyn Fn(usize, &ExperimentConfig, &ExperimentResult) -> Result<()> + Send + Sync>;

/// A sweep under construction: shared inputs + the cell grid.
pub struct Sweep {
    params: Arc<SimParams>,
    runtime: Option<Arc<Runtime>>,
    cells: Vec<ExperimentConfig>,
    jobs: usize,
    sink_factory: Option<CellSinkFactory>,
    cell_hook: Option<CellHook>,
    shard: Option<ShardSpec>,
}

impl Sweep {
    pub fn new(params: impl Into<Arc<SimParams>>) -> Self {
        Sweep {
            params: params.into(),
            runtime: None,
            cells: Vec::new(),
            jobs: 0,
            sink_factory: None,
            cell_hook: None,
            shard: None,
        }
    }

    /// Use the AOT artifacts for all cells' simulation-time sampling.
    pub fn with_runtime(mut self, rt: Option<Arc<Runtime>>) -> Self {
        self.runtime = rt;
        self
    }

    /// Construct a [`TraceSink`] per cell (see [`CellSinkFactory`]).
    /// Capture is then on for every cell regardless of
    /// `capture_trace`; a factory error fails that cell's run.
    pub fn with_cell_sinks(mut self, factory: CellSinkFactory) -> Self {
        self.sink_factory = Some(factory);
        self
    }

    /// Run a [`CellHook`] after each cell completes (see its docs).
    pub fn with_cell_hook(mut self, hook: CellHook) -> Self {
        self.cell_hook = Some(hook);
        self
    }

    /// Worker count. `0` (the default) means one per available core.
    pub fn jobs(mut self, jobs: usize) -> Self {
        self.jobs = jobs;
        self
    }

    /// Run only this process's stride of the grid (`None` = the whole
    /// grid). The full grid must still be added — sharding selects
    /// cells by global index, it does not renumber them.
    pub fn shard(mut self, shard: Option<ShardSpec>) -> Self {
        self.shard = shard;
        self
    }

    /// Append one cell. Cells sharing a config `name` are treated as
    /// replications of each other when aggregating statistics.
    pub fn add(&mut self, cfg: ExperimentConfig) -> &mut Self {
        self.cells.push(cfg);
        self
    }

    /// Append `n` replications of `base` with seeds `seed0..seed0+n`.
    pub fn add_replications(&mut self, base: &ExperimentConfig, seed0: u64, n: usize) -> &mut Self {
        for i in 0..n as u64 {
            let mut cfg = base.clone();
            cfg.seed = seed0 + i;
            self.cells.push(cfg);
        }
        self
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// Run every owned cell to completion and aggregate. The i-th entry
    /// of `SweepResult::results` is always the i-th *owned* cell in
    /// grid order (the whole grid when unsharded), and each cell's
    /// outcome is bit-identical across any `jobs` value. Cells that
    /// fail — by error or by panic — are collected and reported
    /// together with their (global index, name, seed); one bad cell no
    /// longer discards the grid silently.
    pub fn run(self) -> Result<SweepResult> {
        let started = std::time::Instant::now();
        let Sweep {
            params,
            runtime,
            cells,
            jobs,
            sink_factory,
            cell_hook,
            shard,
        } = self;
        if cells.is_empty() {
            return Err(Error::Config("sweep: no cells to run".into()));
        }
        for cfg in &cells {
            cfg.validate()?;
        }
        let grid_len = cells.len();
        // The stride this process owns. Global indices survive into
        // results, sinks, hooks, and the manifest — shard-invariance.
        let owned: Vec<usize> = match shard {
            Some(s) => (0..grid_len).filter(|&i| s.owns(i)).collect(),
            None => (0..grid_len).collect(),
        };
        let jobs = effective_jobs(jobs, owned.len());

        // Work-stealing by atomic cursor: workers claim the next owned
        // position and tag results with it, so completion order (which
        // IS scheduling-dependent) never leaks into the output order.
        let next = AtomicUsize::new(0);
        let per_worker: Vec<Vec<(usize, Result<ExperimentResult>)>> =
            std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(jobs);
                for _ in 0..jobs {
                    let params = &params;
                    let runtime = &runtime;
                    let cells = &cells;
                    let owned = &owned;
                    let next = &next;
                    let sink_factory = &sink_factory;
                    let cell_hook = &cell_hook;
                    handles.push(scope.spawn(move || {
                        let mut out = Vec::new();
                        loop {
                            let pos = next.fetch_add(1, Ordering::Relaxed);
                            if pos >= owned.len() {
                                break;
                            }
                            let i = owned[pos];
                            let r =
                                run_cell(i, &cells[i], params, runtime, sink_factory, cell_hook);
                            out.push((pos, r));
                        }
                        out
                    }));
                }
                handles
                    .into_iter()
                    // cell bodies are panic-isolated in run_cell, so a
                    // worker can only die to an engine bug — fatal
                    .map(|h| h.join().expect("sweep worker panicked outside a cell body"))
                    .collect()
            });

        let mut slots: Vec<Option<Result<ExperimentResult>>> =
            (0..owned.len()).map(|_| None).collect();
        for (pos, r) in per_worker.into_iter().flatten() {
            slots[pos] = Some(r);
        }
        let mut results = Vec::with_capacity(owned.len());
        let mut failed: Vec<String> = Vec::new();
        for (pos, slot) in slots.into_iter().enumerate() {
            let i = owned[pos];
            match slot.expect("sweep: unclaimed cell") {
                Ok(r) => results.push(r),
                Err(e) => failed.push(format!(
                    "cell {i} '{}' seed {}: {e}",
                    cells[i].name, cells[i].seed
                )),
            }
        }
        if !failed.is_empty() {
            let shown = 8.min(failed.len());
            let mut msg = format!("sweep: {} of {} cells failed", failed.len(), owned.len());
            for line in failed.iter().take(shown) {
                msg.push_str("\n  ");
                msg.push_str(line);
            }
            if failed.len() > shown {
                msg.push_str(&format!("\n  ... and {} more", failed.len() - shown));
            }
            return Err(Error::Other(msg));
        }

        let cell_records: Vec<CellRecord> = owned
            .iter()
            .zip(&results)
            .map(|(&i, r)| CellRecord::from_result(i, r))
            .collect();
        let groups = aggregate_cells(&cell_records);
        Ok(SweepResult {
            results,
            cells: cell_records,
            groups,
            jobs,
            wall_secs: started.elapsed().as_secs_f64(),
            shard,
            grid_len,
        })
    }
}

/// One cell, panic-isolated: sink construction, the experiment run,
/// and the completion hook all execute under `catch_unwind`, so a
/// panicking cell becomes that cell's `Err` (later attributed with its
/// global index, name, and seed) instead of poisoning the worker pool.
fn run_cell(
    i: usize,
    cfg: &ExperimentConfig,
    params: &Arc<SimParams>,
    runtime: &Option<Arc<Runtime>>,
    sink_factory: &Option<CellSinkFactory>,
    cell_hook: &Option<CellHook>,
) -> Result<ExperimentResult> {
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let exp = Experiment::new(cfg.clone(), params.clone()).with_runtime(runtime.clone());
        // a per-cell sink (streamed captures) is built on the worker,
        // next to its run
        let r = match sink_factory.as_ref().map(|f| f(i, cfg)) {
            None => exp.run(),
            Some(Ok(sink)) => exp.with_sink(sink).run(),
            Some(Err(e)) => Err(e),
        };
        // per-cell exports happen here, on the worker, while the
        // result is still warm
        r.and_then(|res| {
            if let Some(hook) = cell_hook.as_ref() {
                hook(i, cfg, &res)?;
            }
            Ok(res)
        })
    }))
    .unwrap_or_else(|payload| {
        Err(Error::Other(format!(
            "panicked: {}",
            panic_message(payload.as_ref())
        )))
    })
}

/// Best-effort text of a panic payload (`&str` / `String` payloads,
/// which is what `panic!` produces; anything else gets a placeholder).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "<non-string panic payload>"
    }
}

/// Resolve the worker count: explicit `jobs`, else one per core, never
/// more than there are cells.
pub fn effective_jobs(jobs: usize, cells: usize) -> usize {
    let auto = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let j = if jobs == 0 { auto } else { jobs };
    j.clamp(1, cells.max(1))
}

/// Outcome of a sweep: per-cell results in grid order + aggregates.
/// Under [`Sweep::shard`] only the owned stride is present; its
/// [`SweepResult::manifest`] is the artifact `sweep-merge` combines.
pub struct SweepResult {
    /// Full per-cell results (tsdb, traces, meter...), owned-cell grid
    /// order.
    pub results: Vec<ExperimentResult>,
    /// The compact per-cell records (same order) that flow into CSV,
    /// aggregation, and the shard manifest; `cells[k].index` is the
    /// global grid index.
    pub cells: Vec<CellRecord>,
    /// Groups in order of first appearance.
    pub groups: Vec<GroupStats>,
    pub jobs: usize,
    pub wall_secs: f64,
    /// Which stride this run covered (`None` = the whole grid).
    pub shard: Option<ShardSpec>,
    /// Length of the full grid (== `results.len()` when unsharded).
    pub grid_len: usize,
}

impl SweepResult {
    /// Deterministic per-cell digests, owned-cell grid order — the
    /// parallelism invariant: identical across any `jobs` value.
    pub fn digests(&self) -> Vec<String> {
        self.results.iter().map(|r| r.digest()).collect()
    }

    /// Total simulated events across all cells.
    pub fn events_total(&self) -> u64 {
        self.results.iter().map(|r| r.events_processed).sum()
    }

    /// Aggregate events/sec over the sweep's wall-clock.
    pub fn events_per_sec(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            return 0.0;
        }
        self.events_total() as f64 / self.wall_secs
    }

    /// The shard artifact for this run: per-cell records + group metric
    /// sketches + the wall-time histogram, ready for `sweep-merge`. An
    /// unsharded run produces the (only) shard of a 1-shard layout.
    pub fn manifest(&self) -> ShardManifest {
        let shard = self.shard.unwrap_or(ShardSpec { index: 0, count: 1 });
        ShardManifest::from_cells(shard, self.grid_len, self.cells.clone())
    }

    /// Human-readable aggregate table (mean ± 95% CI per group).
    pub fn table(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let _ = write!(
            s,
            "sweep: {} cells, {} groups, {} jobs, {:.2}s wall, {:.0} events/s aggregate",
            self.results.len(),
            self.groups.len(),
            self.jobs,
            self.wall_secs,
            self.events_per_sec()
        );
        if let Some(sp) = self.shard {
            let _ = write!(s, " [shard {sp}: {} of {} cells]", self.results.len(), self.grid_len);
        }
        s.push('\n');
        render_group_lines(&mut s, &self.groups);
        s
    }

    /// Per-cell CSV: one row per owned cell, grid order; the `cell`
    /// column is the global grid index and the final column is the
    /// cell's digest. Names quote per RFC 4180 (strategy and hw-class
    /// labels can contain commas).
    pub fn to_csv(&self) -> String {
        cells_to_csv(&self.cells)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{fit_params, ArrivalSpec};
    use crate::empirical::GroundTruth;

    fn quick_params() -> SimParams {
        let db = GroundTruth::new(31).generate_weeks(2);
        fit_params(&db, None).unwrap()
    }

    fn small_cfg(name: &str, seed: u64) -> ExperimentConfig {
        ExperimentConfig {
            name: name.into(),
            seed,
            horizon: 6.0 * 3600.0,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 90.0,
            },
            record_traces: false,
            sample_interval: 600.0,
            ..Default::default()
        }
    }

    /// Shared inputs must be shareable across worker threads.
    #[test]
    fn shared_inputs_are_send_sync() {
        fn check<T: Send + Sync>() {}
        check::<SimParams>();
        check::<ExperimentConfig>();
        check::<Runtime>();
        check::<crate::runtime::pool::Backend>();
        fn check_send<T: Send>() {}
        check_send::<ExperimentResult>();
        check_send::<crate::error::Error>();
    }

    #[test]
    fn results_come_back_in_input_order() {
        let params = Arc::new(quick_params());
        let mut sweep = Sweep::new(params).jobs(3);
        for seed in [9u64, 1, 7, 3, 5] {
            sweep.add(small_cfg(&format!("cell-{seed}"), seed));
        }
        let out = sweep.run().unwrap();
        let seeds: Vec<u64> = out.results.iter().map(|r| r.seed).collect();
        assert_eq!(seeds, vec![9, 1, 7, 3, 5]);
        assert_eq!(out.results[2].name, "cell-7");
        let indices: Vec<usize> = out.cells.iter().map(|c| c.index).collect();
        assert_eq!(indices, vec![0, 1, 2, 3, 4]);
        assert_eq!(out.grid_len, 5);
        assert!(out.shard.is_none());
    }

    #[test]
    fn parallel_and_serial_runs_are_byte_identical() {
        let params = Arc::new(quick_params());
        let build = |jobs| {
            let mut sweep = Sweep::new(params.clone()).jobs(jobs);
            sweep.add_replications(&small_cfg("rep", 0), 100, 6);
            sweep.add(small_cfg("solo", 42));
            sweep.run().unwrap()
        };
        let serial = build(1);
        let parallel = build(4);
        assert_eq!(serial.digests(), parallel.digests());
        assert_eq!(serial.jobs, 1);
        assert!(parallel.jobs >= 1);
    }

    #[test]
    fn sharded_run_keeps_global_indices_and_filenames() {
        let params = Arc::new(quick_params());
        let spec = ShardSpec::new(1, 3).unwrap();
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let seen2 = seen.clone();
        let mut sweep = Sweep::new(params.clone())
            .jobs(2)
            .shard(Some(spec))
            .with_cell_hook(Box::new(move |i, cfg, r| {
                seen2.lock().unwrap().push((i, cfg.seed, r.seed));
                Ok(())
            }));
        sweep.add_replications(&small_cfg("sh", 0), 10, 7);
        let out = sweep.run().unwrap();
        // shard 1/3 of 7 cells owns global indices 1, 4
        let indices: Vec<usize> = out.cells.iter().map(|c| c.index).collect();
        assert_eq!(indices, vec![1, 4]);
        assert_eq!(out.grid_len, 7);
        assert_eq!(out.results.len(), 2);
        assert_eq!(out.results[0].seed, 11);
        assert_eq!(out.results[1].seed, 14);
        // hooks observed the *global* indices (shard-invariant names)
        let mut hooked = seen.lock().unwrap().clone();
        hooked.sort_unstable();
        assert_eq!(hooked, vec![(1, 11, 11), (4, 14, 14)]);
        // the shard's digests are the matching slice of the full run's
        let mut full = Sweep::new(params).jobs(2);
        full.add_replications(&small_cfg("sh", 0), 10, 7);
        let full = full.run().unwrap();
        let full_digests = full.digests();
        assert_eq!(out.digests(), vec![full_digests[1].clone(), full_digests[4].clone()]);
        assert!(out.table().contains("[shard 1/3: 2 of 7 cells]"));
        // a stride with no cells is a valid (empty) shard
        let spec = ShardSpec::new(4, 5).unwrap();
        let mut sweep = Sweep::new(Arc::new(quick_params())).shard(Some(spec));
        sweep.add_replications(&small_cfg("sh", 0), 10, 3);
        let out = sweep.run().unwrap();
        assert!(out.results.is_empty());
        assert_eq!(out.grid_len, 3);
        assert!(out.manifest().cells.is_empty());
    }

    #[test]
    fn groups_aggregate_replications() {
        let params = Arc::new(quick_params());
        let mut sweep = Sweep::new(params).jobs(2);
        sweep.add_replications(&small_cfg("a", 0), 1, 4);
        sweep.add_replications(&small_cfg("b", 0), 50, 2);
        let out = sweep.run().unwrap();
        assert_eq!(out.groups.len(), 2);
        assert_eq!(out.groups[0].name, "a");
        assert_eq!(out.groups[0].cells, vec![0, 1, 2, 3]);
        assert_eq!(out.groups[1].cells, vec![4, 5]);
        let arrived = out.groups[0]
            .metrics
            .iter()
            .find(|m| m.name == "arrived")
            .unwrap();
        assert_eq!(arrived.n, 4);
        assert!(arrived.min <= arrived.mean && arrived.mean <= arrived.max);
        assert!(arrived.ci95 >= 0.0);
        assert!(arrived.mean > 50.0, "6h at 90s gaps: {}", arrived.mean);
        // sketch-backed quantiles ride along and respect the range
        assert!(arrived.p50 >= arrived.min && arrived.p50 <= arrived.max);
        assert!(arrived.p95 >= arrived.p50);
        // the exact group wait summary merges every member cell's
        let wait_total: u64 = out.results[..4].iter().map(|r| r.wait_training.count).sum();
        assert_eq!(out.groups[0].wait.count, wait_total);
        // reliability metrics aggregate too; failure-free cells report
        // perfect goodput and zero losses
        let goodput = out.groups[0]
            .metrics
            .iter()
            .find(|m| m.name == "goodput")
            .unwrap();
        assert_eq!(goodput.mean, 1.0);
        let lost = out.groups[0]
            .metrics
            .iter()
            .find(|m| m.name == "lost_work_s")
            .unwrap();
        assert_eq!(lost.max, 0.0);
        // table + csv render without panicking and carry the group names
        assert!(out.table().contains("group 'a'"));
        assert!(out.to_csv().lines().count() == 7);
        assert!(out.to_csv().starts_with("cell,name,seed,"));
        assert!(out.to_csv().contains("goodput"));
        // runtime-cost and digest columns ride at the end of every row
        let csv = out.to_csv();
        let header = csv.lines().next().unwrap();
        assert!(header.ends_with("wall_time_ms,peak_rss_points,digest"));
        let first = csv.lines().nth(1).unwrap();
        assert_eq!(first.split(',').count(), header.split(',').count());
        // ...and the digest column is the real digest
        assert!(first.ends_with(&out.results[0].digest()), "{first}");
    }

    #[test]
    fn csv_quotes_comma_bearing_group_names() {
        let params = Arc::new(quick_params());
        let mut sweep = Sweep::new(params).jobs(1);
        sweep.add(small_cfg("cap=4,fac=1.5,\"hot\"", 3));
        let out = sweep.run().unwrap();
        let csv = out.to_csv();
        let header = csv.lines().next().unwrap();
        let row = csv.lines().nth(1).unwrap();
        // RFC 4180: the name field arrives quoted with doubled quotes,
        // so a compliant parser sees exactly as many fields as columns
        assert!(row.contains("\"cap=4,fac=1.5,\"\"hot\"\"\""), "{row}");
        let parse = |line: &str| {
            let mut fields = 1usize;
            let mut in_quotes = false;
            for ch in line.chars() {
                match ch {
                    '"' => in_quotes = !in_quotes,
                    ',' if !in_quotes => fields += 1,
                    _ => {}
                }
            }
            assert!(!in_quotes, "unbalanced quotes: {line}");
            fields
        };
        assert_eq!(parse(row), parse(header), "{row}");
    }

    #[test]
    fn failing_cells_are_attributed_not_fatal() {
        let params = Arc::new(quick_params());
        // error path: the hook rejects one specific cell
        let mut sweep = Sweep::new(params.clone()).jobs(2);
        sweep.add_replications(&small_cfg("att", 0), 7, 5);
        let err = sweep
            .with_cell_hook(Box::new(|i, _cfg, _r| {
                if i == 3 {
                    Err(Error::Config("disk full".into()))
                } else {
                    Ok(())
                }
            }))
            .run()
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("1 of 5 cells failed"), "{msg}");
        assert!(msg.contains("cell 3 'att' seed 10"), "{msg}");
        assert!(msg.contains("disk full"), "{msg}");

        // panic path, property-tested across worker counts: a
        // deliberately panicking cell hook becomes that cell's error,
        // with the index attached, and never poisons the process
        for jobs in 1..=3 {
            let mut sweep = Sweep::new(params.clone()).jobs(jobs);
            sweep.add_replications(&small_cfg("boom", 0), 1, 4);
            let err = sweep
                .with_cell_hook(Box::new(|i, _cfg, _r| {
                    if i == 2 {
                        panic!("cell hook exploded");
                    }
                    Ok(())
                }))
                .run()
                .unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains("1 of 4 cells failed"), "jobs={jobs}: {msg}");
            assert!(msg.contains("cell 2 'boom' seed 3"), "jobs={jobs}: {msg}");
            assert!(msg.contains("panicked: cell hook exploded"), "jobs={jobs}: {msg}");
        }

        // every failed cell is listed (with truncation past 8)
        let mut sweep = Sweep::new(params).jobs(3);
        sweep.add_replications(&small_cfg("all-bad", 0), 0, 11);
        let err = sweep
            .with_cell_hook(Box::new(|_i, _cfg, _r| {
                Err(Error::Config("nope".into()))
            }))
            .run()
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("11 of 11 cells failed"), "{msg}");
        assert!(msg.contains("... and 3 more"), "{msg}");
    }

    #[test]
    fn empty_sweep_is_an_error() {
        let params = Arc::new(quick_params());
        assert!(Sweep::new(params).run().is_err());
    }

    #[test]
    fn cell_sink_factory_runs_per_cell_and_stays_digest_neutral() {
        use std::sync::atomic::AtomicU64;

        use crate::trace::{TraceEvent, TraceSink};

        struct Counting {
            events: Arc<AtomicU64>,
        }
        impl TraceSink for Counting {
            fn record(&mut self, _ev: &TraceEvent) {
                self.events.fetch_add(1, Ordering::Relaxed);
            }
        }
        let params = Arc::new(quick_params());
        let cells_seen = Arc::new(AtomicUsize::new(0));
        let events = Arc::new(AtomicU64::new(0));
        let build = |with_sinks: bool| {
            let mut sweep = Sweep::new(params.clone()).jobs(2);
            if with_sinks {
                let cells_seen = cells_seen.clone();
                let events = events.clone();
                sweep = sweep.with_cell_sinks(Box::new(move |_i, _cfg| {
                    cells_seen.fetch_add(1, Ordering::Relaxed);
                    let sink: Box<dyn TraceSink> = Box::new(Counting {
                        events: events.clone(),
                    });
                    Ok(sink)
                }));
            }
            sweep.add_replications(&small_cfg("sinks", 0), 10, 3);
            sweep.run().unwrap()
        };
        let plain = build(false);
        let sunk = build(true);
        assert_eq!(cells_seen.load(Ordering::Relaxed), 3, "one sink per cell");
        assert!(events.load(Ordering::Relaxed) > 1000, "sinks saw the streams");
        // injected sinks are pure observers
        assert_eq!(plain.digests(), sunk.digests());
        // streaming-style sinks drain empty: meta only, no buffered events
        assert!(sunk
            .results
            .iter()
            .all(|r| r.trace.as_ref().is_some_and(|t| t.is_empty())));
        // a factory error fails the sweep with the cell attributed
        let mut sweep = Sweep::new(params.clone()).jobs(1);
        sweep.add(small_cfg("bad", 1));
        let err = sweep
            .with_cell_sinks(Box::new(|_i, _cfg| {
                Err(crate::error::Error::Config("no sink for you".into()))
            }))
            .run()
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("cell 0 'bad' seed 1"), "{msg}");
        assert!(msg.contains("no sink for you"), "{msg}");
    }

    #[test]
    fn cell_hook_fires_per_cell_and_errors_fail_the_sweep() {
        let params = Arc::new(quick_params());
        let seen = Arc::new(AtomicUsize::new(0));
        let mut sweep = Sweep::new(params.clone()).jobs(2);
        sweep.add_replications(&small_cfg("hooked", 0), 20, 3);
        let seen2 = seen.clone();
        let out = sweep
            .with_cell_hook(Box::new(move |i, cfg, r| {
                assert!(i < 3);
                assert_eq!(cfg.name, "hooked");
                assert_eq!(cfg.seed, r.seed);
                seen2.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }))
            .run()
            .unwrap();
        assert_eq!(seen.load(Ordering::Relaxed), 3, "one hook call per cell");
        assert_eq!(out.results.len(), 3);
        // a hook error surfaces as the sweep's error
        let mut sweep = Sweep::new(params).jobs(1);
        sweep.add(small_cfg("bad-hook", 1));
        let out = sweep
            .with_cell_hook(Box::new(|_i, _cfg, _r| {
                Err(crate::error::Error::Config("hook says no".into()))
            }))
            .run();
        assert!(out.is_err());
    }

    #[test]
    fn manifest_of_unsharded_run_is_the_single_shard() {
        let params = Arc::new(quick_params());
        let mut sweep = Sweep::new(params).jobs(2);
        sweep.add_replications(&small_cfg("m", 0), 5, 3);
        let out = sweep.run().unwrap();
        let m = out.manifest();
        assert_eq!(m.shard, ShardSpec { index: 0, count: 1 });
        assert_eq!(m.grid_len, 3);
        assert_eq!(m.cells.len(), 3);
        assert_eq!(m.wall_hist.count(), 3);
        let digests: Vec<String> = m.cells.iter().map(|c| c.digest.clone()).collect();
        assert_eq!(digests, out.digests());
        // and it survives the wire
        let back = ShardManifest::from_bytes(&m.to_bytes()).unwrap();
        assert_eq!(back.cells.len(), 3);
    }

    #[test]
    fn effective_jobs_clamps() {
        assert_eq!(effective_jobs(8, 3), 3);
        assert_eq!(effective_jobs(2, 100), 2);
        assert!(effective_jobs(0, 100) >= 1);
        assert_eq!(effective_jobs(1, 0), 1);
    }

    #[test]
    fn t_table_sane() {
        use super::super::shard::t_critical_95;
        assert!(t_critical_95(1) > 12.0);
        assert!((t_critical_95(29) - 2.045).abs() < 1e-9);
        assert_eq!(t_critical_95(1000), 1.96);
    }
}
