//! The experimentation coordinator: fitted simulation parameters, the
//! experiment configuration, the discrete-event experiment runner, and the
//! operational strategies (queue disciplines + retraining trigger
//! policies) the paper's framework exists to evaluate.

pub mod config;
pub mod experiment;
pub mod params;
pub mod result;
pub mod sweep;
pub mod triggers;

pub use config::{ArrivalSpec, ExperimentConfig, RuntimeViewConfig};
pub use experiment::Experiment;
pub use params::{fit_params, fit_params_with_report, FitReport, SimParams};
pub use result::ExperimentResult;
pub use sweep::{GroupStats, MetricStats, Sweep, SweepResult};
pub use triggers::TriggerPolicy;
