//! The experimentation coordinator: fitted simulation parameters, the
//! experiment configuration, the decomposed discrete-event simulation
//! core, and the pluggable operational strategies (schedulers +
//! retraining triggers) the paper's framework exists to evaluate.

pub mod config;
pub mod experiment;
pub mod params;
pub mod params_bin;
pub mod result;
pub mod shard;
mod simulation;
pub mod strategy;
pub mod sweep;
pub mod triggers;

pub use config::{ArrivalSpec, ExperimentConfig, RetentionConfig, RuntimeViewConfig};
pub use experiment::Experiment;
pub use params::{fit_params, fit_params_with_report, FitReport, SimParams};
pub use result::ExperimentResult;
pub use shard::{
    merge_shards, CellRecord, GroupStats, MergedSweep, MetricStats, ShardManifest, ShardSpec,
};
pub use strategy::{
    build_placer, build_retry_policy, build_scheduler, build_trigger, placer_names,
    register_placer, register_retry_policy, register_scheduler, register_trigger,
    retry_policy_names, scheduler_names, trigger_names, StrategySpec,
};
pub use sweep::{Sweep, SweepResult};
pub use triggers::{RetrainTrigger, TriggerCtx};
