//! Experiment results: counters, summaries, and the trace store.

use crate::obs::MeterReport;
use crate::stats::Summary;
use crate::trace::Trace;
use crate::tsdb::TsStore;

/// Version prefix of [`ExperimentResult::digest`] strings. Bump whenever
/// a behavioral fix legitimately changes deterministic outcomes, so
/// digests from different behavior generations can never be confused
/// for a nondeterminism bug.
///
/// History:
/// * v1 (implicit, unprefixed) — through the monitor that stopped
///   sampling at `arrivals_stopped && live == 0`.
/// * v2 — the monitor keeps sampling while models remain deployed
///   (matching `on_drift`'s drained condition), so runtime-view series
///   cover the retraining load; tsdb point counts changed.
pub const DIGEST_VERSION: u32 = 2;

/// Canonical series names recorded by the experiment runner.
pub mod series {
    /// Resource slot utilization sample, tag `resource`.
    pub const UTILIZATION: &str = "utilization";
    /// Queue length sample, tag `resource`.
    pub const QUEUE_LEN: &str = "queue_len";
    /// Task execution (compute) duration, tags `task` (+ `framework`).
    pub const TASK_EXEC: &str = "task_exec";
    /// Time spent queued for a resource, tag `resource`.
    pub const TASK_WAIT: &str = "task_wait";
    /// Pipeline arrival marker (value 1).
    pub const ARRIVALS: &str = "arrivals";
    /// Pipeline completion marker (value = makespan seconds).
    pub const COMPLETIONS: &str = "completions";
    /// Total queueing wait accumulated by a completed pipeline.
    pub const PIPELINE_WAIT: &str = "pipeline_wait";
    /// Store wire traffic bytes, tag `dir` = read|write.
    pub const TRAFFIC: &str = "traffic";
    /// Mean performance over deployed models (monitor tick).
    pub const MODEL_PERF: &str = "model_perf_mean";
    /// Retraining launches (value 1).
    pub const RETRAINS: &str = "retrains";
}

/// Everything an experiment run produces.
pub struct ExperimentResult {
    pub name: String,
    pub seed: u64,
    /// Simulated horizon actually covered (seconds).
    pub horizon: f64,
    /// The trace store (series listed in [`series`]).
    pub tsdb: TsStore,
    // counters
    pub arrived: u64,
    pub completed: u64,
    /// Pipelines still queued/executing when the run ended — the
    /// conservation invariant `arrived == completed + in_flight` holds
    /// for every scheduler. Derivable, so deliberately not part of
    /// [`ExperimentResult::digest`] (digests stay comparable across
    /// versions).
    pub in_flight: u64,
    pub tasks_executed: u64,
    pub gate_failures: u64,
    /// Running tasks evicted by a preemptive scheduler (each later
    /// resumes with its remaining service and completes exactly once).
    /// Zero for non-preemptive strategies. Like `in_flight`, deliberately
    /// not part of [`ExperimentResult::digest`]: pre-existing strategies
    /// must keep byte-identical digests across the preemption-capable
    /// refactor, and for them this is identically zero.
    pub preemptions: u64,
    /// Slot failures injected (landed) over the run. Like `preemptions`,
    /// identically zero without a `FailureModel` and therefore kept out
    /// of [`ExperimentResult::digest`] — failure-off configs must keep
    /// byte-identical digests across the failure-injection release.
    pub failures: u64,
    /// Failed slots brought back online (≤ `failures`; repairs pending
    /// at the horizon never land).
    pub repairs: u64,
    /// Service seconds destroyed by failures: un-checkpointed attempt
    /// tails plus restart costs. Out of the digest (zero when failures
    /// are off).
    pub lost_work: f64,
    /// useful / (useful + lost) service seconds — exactly 1.0 when no
    /// work was lost. Out of the digest.
    pub goodput: f64,
    /// Median of the per-failure repair times (0 with no failures).
    pub recovery_p50: f64,
    /// 95th percentile of the per-failure repair times.
    pub recovery_p95: f64,
    /// Transient task faults injected (landed) over the run. Like
    /// `failures`, identically zero without a `FaultModel` and therefore
    /// kept out of [`ExperimentResult::digest`] — fault-off configs must
    /// keep byte-identical digests across the task-fault release.
    pub task_faults: u64,
    /// Attempts killed by the per-attempt timeout. Out of the digest
    /// (zero when faults are off).
    pub task_timeouts: u64,
    /// Retry attempts scheduled by the retry policy (each fault/timeout
    /// the policy answered with `Retry`). Out of the digest.
    pub retries: u64,
    /// Pipelines terminally abandoned by the retry policy — the
    /// conservation invariant becomes
    /// `arrived == completed + abandoned + shed + in_flight`.
    /// Out of the digest.
    pub abandoned: u64,
    /// Pipelines shed at admission (arrival queue over `queue_cap`).
    /// Out of the digest.
    pub shed: u64,
    /// Service seconds burned by attempts that faulted or timed out
    /// (the whole attempt's progress is wasted — task faults have no
    /// checkpointing). Out of the digest (zero when faults are off).
    pub wasted_work: f64,
    /// Fraction of completed pipelines that finished within their EDF
    /// deadline (`arrived_at + slack_per_class * priority`) — the SLO
    /// attainment headline. Exactly 1.0 degenerates to "all on time";
    /// 0.0 with no completions. Out of the digest.
    pub deadline_attainment: f64,
    /// Dollar cost of the run: per-class busy slot-seconds times each
    /// class's `cost_per_slot_hour`, summed over both clusters. Exactly
    /// 0.0 without hardware classes (or with all-zero cost knobs), so
    /// like `preemptions`/`failures` it stays out of
    /// [`ExperimentResult::digest`] — classless configs must keep
    /// byte-identical digests across the heterogeneous-hardware release.
    pub cost: f64,
    /// Per-class busy-time utilization labeled `"<cluster>/<class>"`
    /// in [training, compute] x config order. Empty without hardware
    /// classes; out of the digest.
    pub class_util: Vec<(String, f64)>,
    /// Slot failures attributed to each class (same labels/order as
    /// `class_util`). Empty without hardware classes; out of the digest.
    pub class_failures: Vec<(String, u64)>,
    pub retrains_triggered: u64,
    pub models_deployed: u64,
    pub events_processed: u64,
    // resource outcomes
    pub util_training: f64,
    pub util_compute: f64,
    pub wait_training: Summary,
    pub wait_compute: Summary,
    pub avg_queue_training: f64,
    pub avg_queue_compute: f64,
    // model quality (runtime view)
    pub final_mean_performance: f64,
    // traffic
    pub wire_read_bytes: f64,
    pub wire_write_bytes: f64,
    // engine accounting
    pub wall_secs: f64,
    pub peak_rss_mb: f64,
    pub sampler_backend: String,
    pub pool_refills: u64,
    /// Resolved scheduler strategy label (`StrategySpec::label`), so
    /// exported reports are self-describing.
    pub scheduler: String,
    /// Resolved retraining-trigger label, or `"off"` when the runtime
    /// view is disabled.
    pub trigger: String,
    /// Resolved placement strategy label, or `""` when the config has
    /// no hardware classes. Descriptive, so out of the digest like
    /// `scheduler`/`trigger`.
    pub placer: String,
    /// Resolved retry-policy label, or `""` when the config has no
    /// fault model. Descriptive, so out of the digest like `placer`.
    pub retry: String,
    /// The captured event trace when `cfg.capture_trace` was set.
    /// Derivable run description, deliberately not part of the digest.
    pub trace: Option<Trace>,
    /// The simulator self-profile when `cfg.meter` was set. Pure
    /// engine accounting (like `wall_secs`/`peak_rss_mb`), deliberately
    /// not part of the digest: meter-on and meter-off runs of the same
    /// `(config, seed)` must produce byte-identical digests.
    pub meter: Option<MeterReport>,
}

impl ExperimentResult {
    /// events/sec of simulated execution.
    pub fn events_per_sec(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            return 0.0;
        }
        self.events_processed as f64 / self.wall_secs
    }

    /// Wall-clock microseconds per simulated pipeline (Fig 13 headline).
    pub fn us_per_pipeline(&self) -> f64 {
        if self.arrived == 0 {
            return 0.0;
        }
        self.wall_secs * 1e6 / self.arrived as f64
    }

    /// Canonical digest of every *deterministic* outcome of the run —
    /// floats rendered as exact IEEE-754 bit patterns, wall-clock and RSS
    /// excluded. Two runs of the same (config, seed) must produce
    /// byte-identical digests regardless of thread count, machine, or
    /// load; the sweep engine, the determinism property tests, and the
    /// trace capture→replay round-trip compare these strings directly.
    /// The leading `v<N>;` marker is [`DIGEST_VERSION`].
    pub fn digest(&self) -> String {
        use std::fmt::Write;
        let mut s = String::with_capacity(256);
        let _ = write!(
            s,
            "v{DIGEST_VERSION};\
             name={};seed={};horizon={:016x};arrived={};completed={};tasks={};gates={};\
             retrains={};deployed={};events={}",
            self.name,
            self.seed,
            self.horizon.to_bits(),
            self.arrived,
            self.completed,
            self.tasks_executed,
            self.gate_failures,
            self.retrains_triggered,
            self.models_deployed,
            self.events_processed,
        );
        for (tag, v) in [
            ("ut", self.util_training),
            ("uc", self.util_compute),
            ("wt_sum", self.wait_training.sum),
            ("wt_max", if self.wait_training.count > 0 { self.wait_training.max } else { 0.0 }),
            ("wc_sum", self.wait_compute.sum),
            ("wc_max", if self.wait_compute.count > 0 { self.wait_compute.max } else { 0.0 }),
            ("qt", self.avg_queue_training),
            ("qc", self.avg_queue_compute),
            ("perf", self.final_mean_performance),
            ("rd", self.wire_read_bytes),
            ("wr", self.wire_write_bytes),
        ] {
            let _ = write!(s, ";{tag}={:016x}", v.to_bits());
        }
        let _ = write!(
            s,
            ";tsdb={}x{}",
            self.tsdb.num_series(),
            self.tsdb.num_points()
        );
        s
    }

    /// Human-readable run summary (the dashboard's stat panel, Fig 11).
    pub fn summary(&self) -> String {
        let mut s = String::new();
        use std::fmt::Write;
        let _ = writeln!(s, "experiment '{}' (seed {})", self.name, self.seed);
        let _ = writeln!(
            s,
            "  horizon          {:.2} days ({:.0} s)",
            self.horizon / 86400.0,
            self.horizon
        );
        let _ = writeln!(
            s,
            "  pipelines        arrived {}  completed {}  gate-failed {}  in-flight {}",
            self.arrived, self.completed, self.gate_failures, self.in_flight
        );
        let _ = writeln!(
            s,
            "  tasks            {} executed, {} events total",
            self.tasks_executed, self.events_processed
        );
        if self.preemptions > 0 {
            let _ = writeln!(s, "  preemptions      {}", self.preemptions);
        }
        // the reliability block renders whenever ANY reliability counter
        // is nonzero — a fault-only (or shed-only) run must not print an
        // all-reliable report just because no *slot* ever failed
        let reliability = self.failures > 0
            || self.task_faults > 0
            || self.task_timeouts > 0
            || self.shed > 0
            || self.abandoned > 0;
        if reliability {
            let _ = writeln!(
                s,
                "  failures         {} ({} repaired)  lost work {:.0}s  goodput {:.4}",
                self.failures, self.repairs, self.lost_work, self.goodput
            );
            if self.failures > 0 {
                let _ = writeln!(
                    s,
                    "  recovery time    p50 {:.0}s  p95 {:.0}s",
                    self.recovery_p50, self.recovery_p95
                );
            }
            if self.task_faults > 0 || self.task_timeouts > 0 {
                let _ = writeln!(
                    s,
                    "  task faults      {} transient, {} timed out  wasted work {:.0}s",
                    self.task_faults, self.task_timeouts, self.wasted_work
                );
            }
            let _ = writeln!(
                s,
                "  outcomes         {} retries | {} abandoned | {} shed | SLO attainment {:.4}",
                self.retries, self.abandoned, self.shed, self.deadline_attainment
            );
        }
        let _ = writeln!(
            s,
            "  utilization      training {:.1}%  compute {:.1}%",
            100.0 * self.util_training,
            100.0 * self.util_compute
        );
        let _ = writeln!(
            s,
            "  queue wait       training mean {:.1}s max {:.1}s | compute mean {:.1}s max {:.1}s",
            self.wait_training.mean(),
            if self.wait_training.count > 0 { self.wait_training.max } else { 0.0 },
            self.wait_compute.mean(),
            if self.wait_compute.count > 0 { self.wait_compute.max } else { 0.0 },
        );
        let _ = writeln!(
            s,
            "  avg queue len    training {:.2}  compute {:.2}",
            self.avg_queue_training, self.avg_queue_compute
        );
        let mut strategies = format!(
            "scheduler {} | trigger {}",
            self.scheduler, self.trigger
        );
        if !self.placer.is_empty() {
            let _ = write!(strategies, " | placer {}", self.placer);
        }
        if !self.retry.is_empty() {
            let _ = write!(strategies, " | retry {}", self.retry);
        }
        let _ = writeln!(s, "  strategies       {strategies}");
        if !self.class_util.is_empty() {
            let _ = writeln!(s, "  cost             ${:.2}", self.cost);
            for (label, util) in &self.class_util {
                let fails = self
                    .class_failures
                    .iter()
                    .find(|(l, _)| l == label)
                    .map(|&(_, n)| n)
                    .unwrap_or(0);
                let _ = writeln!(
                    s,
                    "  class {:<16} util {:.1}%  failures {}",
                    label,
                    100.0 * util,
                    fails
                );
            }
        }
        let _ = writeln!(
            s,
            "  traffic          read {:.2} GB  write {:.2} GB (incl. TCP overhead)",
            self.wire_read_bytes / 1e9,
            self.wire_write_bytes / 1e9
        );
        if self.models_deployed > 0 {
            let _ = writeln!(
                s,
                "  runtime view     {} deployed, {} retrains, mean p(M) {:.3}",
                self.models_deployed, self.retrains_triggered, self.final_mean_performance
            );
        }
        let _ = writeln!(
            s,
            "  engine           {:.2}s wall, {:.0} events/s, {:.1} µs/pipeline, {} sampler ({} refills), peak RSS {:.0} MB",
            self.wall_secs,
            self.events_per_sec(),
            self.us_per_pipeline(),
            self.sampler_backend,
            self.pool_refills,
            self.peak_rss_mb
        );
        s
    }
}

/// Current resident set size of this process in MB (Linux), 0 elsewhere.
pub fn rss_mb() -> f64 {
    if let Ok(status) = std::fs::read_to_string("/proc/self/status") {
        for line in status.lines() {
            if let Some(rest) = line.strip_prefix("VmRSS:") {
                let kb: f64 = rest
                    .trim()
                    .trim_end_matches("kB")
                    .trim()
                    .parse()
                    .unwrap_or(0.0);
                return kb / 1024.0;
            }
        }
    }
    0.0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empty_result() -> ExperimentResult {
        ExperimentResult {
            name: "t".into(),
            seed: 1,
            horizon: 86400.0,
            tsdb: TsStore::new(),
            arrived: 100,
            completed: 90,
            in_flight: 10,
            tasks_executed: 300,
            gate_failures: 2,
            preemptions: 0,
            failures: 0,
            repairs: 0,
            lost_work: 0.0,
            goodput: 1.0,
            recovery_p50: 0.0,
            recovery_p95: 0.0,
            task_faults: 0,
            task_timeouts: 0,
            retries: 0,
            abandoned: 0,
            shed: 0,
            wasted_work: 0.0,
            deadline_attainment: 1.0,
            cost: 0.0,
            class_util: Vec::new(),
            class_failures: Vec::new(),
            retrains_triggered: 0,
            models_deployed: 0,
            events_processed: 1000,
            util_training: 0.5,
            util_compute: 0.25,
            wait_training: Summary::new(),
            wait_compute: Summary::new(),
            avg_queue_training: 0.1,
            avg_queue_compute: 0.0,
            final_mean_performance: 0.0,
            wire_read_bytes: 1e9,
            wire_write_bytes: 5e8,
            wall_secs: 0.5,
            peak_rss_mb: 100.0,
            sampler_backend: "cpu".into(),
            pool_refills: 3,
            scheduler: "fifo".into(),
            trigger: "off".into(),
            placer: String::new(),
            retry: String::new(),
            trace: None,
            meter: None,
        }
    }

    #[test]
    fn rates() {
        let r = empty_result();
        assert_eq!(r.events_per_sec(), 2000.0);
        assert_eq!(r.us_per_pipeline(), 5000.0);
    }

    #[test]
    fn summary_contains_key_stats() {
        let s = empty_result().summary();
        assert!(s.contains("arrived 100"));
        assert!(s.contains("training 50.0%"));
        assert!(s.contains("µs/pipeline"));
        // resolved strategy labels make the report self-describing
        assert!(s.contains("scheduler fifo"));
        assert!(s.contains("trigger off"));
        // failure lines only appear when failures landed
        assert!(!s.contains("goodput"));
        let mut r = empty_result();
        r.failures = 2;
        r.repairs = 1;
        r.lost_work = 500.0;
        r.goodput = 0.95;
        r.recovery_p50 = 300.0;
        r.recovery_p95 = 900.0;
        let s = r.summary();
        assert!(s.contains("failures         2 (1 repaired)"), "{s}");
        assert!(s.contains("goodput 0.9500"), "{s}");
        assert!(s.contains("p50 300s"), "{s}");
        // the reliability block renders for fault-only runs too (no
        // slot failures at all) — the pre-fix gate keyed only on
        // self.failures and would have printed nothing
        let mut r = empty_result();
        r.task_faults = 5;
        r.task_timeouts = 1;
        r.retries = 4;
        r.abandoned = 2;
        r.wasted_work = 120.0;
        r.deadline_attainment = 0.875;
        let s = r.summary();
        assert!(s.contains("task faults      5 transient, 1 timed out"), "{s}");
        assert!(s.contains("wasted work 120s"), "{s}");
        assert!(s.contains("4 retries | 2 abandoned | 0 shed"), "{s}");
        assert!(s.contains("SLO attainment 0.8750"), "{s}");
        assert!(!s.contains("recovery time"), "no slot failures: no recovery line");
        // shed-only runs render the block as well
        let mut r = empty_result();
        r.shed = 7;
        let s = r.summary();
        assert!(s.contains("7 shed"), "{s}");
        // retry label joins the strategies line when set
        let mut r = empty_result();
        r.retry = "exp_backoff:max_attempts=4".into();
        let s = r.summary();
        assert!(s.contains("| retry exp_backoff:max_attempts=4"), "{s}");
        // cost/class lines only appear with hardware classes configured
        let mut r = empty_result();
        r.placer = "fastest_fit".into();
        r.cost = 42.5;
        r.class_util = vec![("training/a100".into(), 0.75)];
        r.class_failures = vec![("training/a100".into(), 1)];
        let s = r.summary();
        assert!(s.contains("placer fastest_fit"), "{s}");
        assert!(s.contains("cost             $42.50"), "{s}");
        assert!(s.contains("training/a100"), "{s}");
        assert!(s.contains("util 75.0%  failures 1"), "{s}");
    }

    #[test]
    fn digest_ignores_wall_clock_but_sees_outcomes() {
        let a = empty_result();
        let mut b = empty_result();
        b.wall_secs = 99.0;
        b.peak_rss_mb = 7.0;
        assert_eq!(a.digest(), b.digest());
        // in_flight is derivable (arrived - completed): kept out of the
        // digest so same-version digest strings remain comparable
        assert!(!a.digest().contains("in_flight"));
        // preemptions stays out too: identically zero for pre-existing
        // strategies, whose digests must not move across the refactor
        let mut p = empty_result();
        p.preemptions = 3;
        assert_eq!(a.digest(), p.digest());
        // reliability counters follow the same rule: identically
        // zero/1.0 without a FailureModel, so failure-off configs keep
        // their pre-failure-release digests byte-identical
        let mut f = empty_result();
        f.failures = 4;
        f.repairs = 3;
        f.lost_work = 1234.5;
        f.goodput = 0.91;
        f.recovery_p50 = 600.0;
        f.recovery_p95 = 1800.0;
        assert_eq!(a.digest(), f.digest());
        // the task-fault/SLO counters follow the same rule: identically
        // zero without a FaultModel, so fault-off configs keep their
        // pre-task-fault-release digests byte-identical
        let mut t = empty_result();
        t.task_faults = 9;
        t.task_timeouts = 2;
        t.retries = 7;
        t.abandoned = 1;
        t.shed = 3;
        t.wasted_work = 456.7;
        t.deadline_attainment = 0.5;
        assert_eq!(a.digest(), t.digest());
        // cost accounting too: identically zero/empty without hardware
        // classes, so classless digests survive the placement release
        let mut h = empty_result();
        h.cost = 123.45;
        h.class_util = vec![("training/a100".into(), 0.5)];
        h.class_failures = vec![("training/a100".into(), 2)];
        assert_eq!(a.digest(), h.digest());
        // the self-profiling meter is engine accounting, same rule as
        // wall_secs/peak_rss_mb: meter-on runs keep meter-off digests
        let mut m = empty_result();
        m.meter = Some(MeterReport {
            events_by_kind: vec![("arrival".into(), 100)],
            calendar_scheduled: 500,
            ..Default::default()
        });
        assert_eq!(a.digest(), m.digest());
        let mut c = empty_result();
        c.completed += 1;
        assert_ne!(a.digest(), c.digest());
        let mut d = empty_result();
        d.util_training += 1e-15;
        assert_ne!(a.digest(), d.digest(), "digest must be bit-exact");
    }

    #[test]
    fn digest_carries_behavior_version() {
        // digest-compat: the v2 bump marks the monitor drained-condition
        // fix — digests from different behavior generations must never
        // compare equal by accident
        let d = empty_result().digest();
        assert!(d.starts_with(&format!("v{DIGEST_VERSION};name=")), "{d}");
        assert_eq!(DIGEST_VERSION, 2);
    }

    #[test]
    fn strategy_labels_and_trace_stay_out_of_digest() {
        let a = empty_result();
        let mut b = empty_result();
        b.scheduler = "edf:slack_per_class=900".into();
        b.trigger = "periodic:interval=3600".into();
        b.placer = "cheapest_fit".into();
        b.retry = "deadline_aware".into();
        b.trace = Some(Trace {
            meta: crate::trace::TraceMeta {
                name: "t".into(),
                seed: 1,
                horizon: 86400.0,
                config_json: String::new(),
                extra: Vec::new(),
            },
            events: Vec::new(),
        });
        // labels/trace describe the run; the digest captures outcomes
        assert_eq!(a.digest(), b.digest());
    }

    #[test]
    fn rss_readable_on_linux() {
        let mb = rss_mb();
        if cfg!(target_os = "linux") {
            assert!(mb > 1.0, "rss {mb}");
        }
    }
}
