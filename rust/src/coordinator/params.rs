//! Fitted simulation parameters — the "modeled system" of Fig 5.
//!
//! `fit_params` is PipeSim's data-acquisition pipeline (paper section
//! V-A): it queries the analytics DB, fits every statistical model the
//! simulator samples from, and packages them as a serializable
//! [`SimParams`]. The mixture fits run through the AOT EM artifacts when
//! a [`Runtime`] is supplied (the production path) and fall back to the
//! identical pure-Rust EM otherwise.

use std::sync::Arc;

use crate::arrivals::ArrivalModel;
use crate::coordinator::config::ArrivalSpec;
use crate::empirical::AnalyticsDb;
use crate::error::{Error, Result};
use crate::model::Framework;
use crate::runtime::{fit_gmm1, fit_gmm3, Runtime, K1, K3};
use crate::stats::dist::LogNormal;
use crate::stats::fit::{fit_exp_curve, fit_lognormal};
use crate::stats::gmm::{Gmm1, Gmm3};
use crate::stats::rng::Pcg64;
use crate::stats::ExpCurve;

/// Materialization laws for trained-model metrics (section V-B b: "sample
/// from the distribution of performance values historically observed").
#[derive(Clone, Debug)]
pub struct ModelLaws {
    /// Mean/σ of the initial composite performance p(M).
    pub perf_mean: f64,
    pub perf_sd: f64,
    /// ln-space mean/σ of model size in MB.
    pub size_ln_mean: f64,
    pub size_ln_sd: f64,
    /// ln-space mean/σ of inference latency in ms.
    pub inference_ln_mean: f64,
    pub inference_ln_sd: f64,
    /// CLEVER score range.
    pub clever_max: f64,
}

impl Default for ModelLaws {
    fn default() -> Self {
        ModelLaws {
            perf_mean: 0.82,
            perf_sd: 0.07,
            size_ln_mean: 42.5f64.ln(), // GoogleNet-class median, Table I
            size_ln_sd: 0.9,
            inference_ln_mean: 128f64.ln(),
            inference_ln_sd: 0.5,
            clever_max: 2.0,
        }
    }
}

/// Everything the simulator samples from.
///
/// The fitted mixtures live behind `Arc`s: an `Experiment` (or a whole
/// sweep's worth of them) borrows the shared fits instead of deep-copying
/// kilobytes of mixture parameters per run.
#[derive(Clone, Debug)]
pub struct SimParams {
    /// 50-component full-covariance mixture over ln(rows, cols, bytes).
    pub asset_gmm: Arc<Gmm3>,
    /// Per-framework K1-component mixtures over ln(train seconds).
    pub train_log_gmm: Vec<Arc<Gmm1>>,
    /// Mixture over ln(evaluate seconds).
    pub eval_log_gmm: Arc<Gmm1>,
    /// Preprocess duration curve f(x) = a·bˣ + c over x = ln(rows·cols).
    pub preproc_curve: ExpCurve,
    /// Additive log-normal noise around the curve.
    pub preproc_noise: LogNormal,
    /// Global interarrival fit (Fig 12b "random").
    pub arrival_random: ArrivalModel,
    /// 168-cluster hour-of-week profile (Fig 12b/c "realistic").
    pub arrival_profile: ArrivalModel,
    /// Literal recorded-trace replay (zero modeling error baseline).
    pub arrival_replay: ArrivalModel,
    /// Mean interarrival seconds observed in the DB.
    pub mean_interarrival: f64,
    /// Model-metric materialization laws.
    pub model_laws: ModelLaws,
}

/// Fit diagnostics surfaced to the CLI / EXPERIMENTS.md.
#[derive(Clone, Debug, Default)]
pub struct FitReport {
    pub backend: String,
    pub asset_rows: usize,
    pub asset_loglik: f64,
    pub asset_iters: usize,
    pub train_rows: Vec<(String, usize)>,
    pub preproc_curve: Option<ExpCurve>,
    pub profile_families: Vec<(String, usize)>,
    pub wall_secs: f64,
}

impl SimParams {
    /// Persist the fitted parameters. A `.bin` extension selects the
    /// compact binary cache (`coordinator::params_bin` — loads without
    /// any float parsing, which dominates sweep startup for tiny cells);
    /// anything else writes JSON.
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        let is_bin = path
            .extension()
            .and_then(|e| e.to_str())
            .is_some_and(|e| e.eq_ignore_ascii_case("bin"));
        if is_bin {
            std::fs::write(path, super::params_bin::encode(self))?;
            Ok(())
        } else {
            use crate::util::jsonio::JsonIo;
            self.save_json(path)
        }
    }

    /// Load fitted parameters, auto-detecting the encoding by content:
    /// the binary cache's magic wins, anything else parses as JSON.
    pub fn load(path: &std::path::Path) -> Result<Self> {
        let bytes = std::fs::read(path)?;
        if super::params_bin::is_binary(&bytes) {
            return super::params_bin::decode(&bytes);
        }
        let text = String::from_utf8(bytes)
            .map_err(|_| Error::Other(format!("params {}: not utf-8 JSON", path.display())))?;
        use crate::util::jsonio::JsonIo;
        Self::from_json(&crate::util::Json::parse(&text)?)
    }

    /// Resolve an arrival spec against these fitted models — the single
    /// place an [`ArrivalSpec`] becomes a live [`ArrivalModel`] (the
    /// simulation core and the trace analytics both go through here).
    pub fn resolve_arrival(&self, spec: ArrivalSpec) -> ArrivalModel {
        match spec {
            ArrivalSpec::Random => self.arrival_random.clone(),
            ArrivalSpec::Profile => self.arrival_profile.clone(),
            ArrivalSpec::Replay => self.arrival_replay.clone(),
            ArrivalSpec::Poisson { mean_interarrival } => {
                ArrivalModel::Poisson { mean_interarrival }
            }
        }
    }

    pub fn train_gmm(&self, fw: Framework) -> &Gmm1 {
        &self.train_log_gmm[fw.index()]
    }

    /// Shared handle to a framework's train mixture (clone-free pools).
    pub fn train_gmm_shared(&self, fw: Framework) -> &Arc<Gmm1> {
        &self.train_log_gmm[fw.index()]
    }
}

/// Fit all simulation parameters from the analytics database.
///
/// `runtime`: pass the loaded PJRT runtime to fit through the AOT EM
/// artifacts; `None` uses the pure-Rust EM baseline.
pub fn fit_params(db: &AnalyticsDb, runtime: Option<Arc<Runtime>>) -> Result<SimParams> {
    fit_params_with_report(db, runtime).map(|(p, _)| p)
}

/// Like [`fit_params`] but also returns fit diagnostics.
pub fn fit_params_with_report(
    db: &AnalyticsDb,
    runtime: Option<Arc<Runtime>>,
) -> Result<(SimParams, FitReport)> {
    let started = std::time::Instant::now();
    let mut rng = Pcg64::new(0x5EED_F177);
    let mut report = FitReport {
        backend: runtime.as_ref().map_or("cpu", |_| "pjrt").to_string(),
        ..Default::default()
    };

    // --- asset mixture (section V-A1, Fig 8) -------------------------
    let log_assets = db.asset_log_matrix();
    if log_assets.len() < K3 {
        return Err(Error::Stats(format!(
            "fit_params: only {} plausible assets",
            log_assets.len()
        )));
    }
    report.asset_rows = log_assets.len();
    let asset_gmm = match &runtime {
        Some(rt) => {
            let (g, ll, iters) = fit_gmm3(rt, &log_assets, &mut rng, 60, 1e-6)?;
            report.asset_loglik = ll;
            report.asset_iters = iters;
            g
        }
        None => {
            let (g, ll) = crate::runtime::fitter::fit_gmm3_cpu(&log_assets, K3, &mut rng, 60, 1e-6)?;
            report.asset_loglik = ll;
            g
        }
    };

    // --- per-framework train duration mixtures (section V-A2b, Fig 9b)
    let mut train_log_gmm = Vec::with_capacity(Framework::ALL.len());
    for fw in Framework::ALL {
        let durs: Vec<f64> = db
            .durations_for(fw)
            .into_iter()
            .filter(|&d| d > 0.0)
            .map(|d| d.ln())
            .collect();
        report.train_rows.push((fw.to_string(), durs.len()));
        let g = fit_log_mixture(&durs, &runtime, &mut rng)?;
        train_log_gmm.push(Arc::new(g));
    }

    // --- evaluation durations (section V-A2c) ------------------------
    let eval_logs: Vec<f64> = db
        .eval_durations()
        .into_iter()
        .filter(|&d| d > 0.0)
        .map(|d| d.ln())
        .collect();
    let eval_log_gmm = fit_log_mixture(&eval_logs, &runtime, &mut rng)?;

    // --- preprocess curve + noise (section V-A2a, Fig 9a) ------------
    let (xs, ys) = db.preproc_pairs();
    if xs.len() < 16 {
        return Err(Error::Stats("fit_params: too few preprocess traces".into()));
    }
    let preproc_curve = fit_exp_curve(&xs, &ys)?;
    let residuals: Vec<f64> = xs
        .iter()
        .zip(&ys)
        .map(|(&x, &y)| y - preproc_curve.eval(x))
        .filter(|&r| r > 1e-6)
        .collect();
    let preproc_noise = if residuals.len() > 32 {
        fit_lognormal(&residuals)?
    } else {
        LogNormal::new(-1.0, 0.15)
    };
    report.preproc_curve = Some(preproc_curve);

    // --- arrivals (section V-A3, Figs 10/12) --------------------------
    let arrival_random = ArrivalModel::fit_random(db)?;
    let arrival_profile = ArrivalModel::fit_profile(db, &mut rng)?;
    if let ArrivalModel::Profile(p) = &arrival_profile {
        report.profile_families = p.family_histogram();
    }
    let arrival_replay = ArrivalModel::from_trace(db)?;
    let gaps = db.interarrivals();
    let mean_interarrival = crate::stats::mean(&gaps).max(1e-3);

    report.wall_secs = started.elapsed().as_secs_f64();
    Ok((
        SimParams {
            asset_gmm: Arc::new(asset_gmm),
            train_log_gmm,
            eval_log_gmm: Arc::new(eval_log_gmm),
            preproc_curve,
            preproc_noise,
            arrival_random,
            arrival_profile,
            arrival_replay,
            mean_interarrival,
            model_laws: ModelLaws::default(),
        },
        report,
    ))
}

fn fit_log_mixture(
    logs: &[f64],
    runtime: &Option<Arc<Runtime>>,
    rng: &mut Pcg64,
) -> Result<Gmm1> {
    if logs.len() < K1 {
        // degenerate stratum: single flat component around the mean
        let m = crate::stats::mean(logs);
        return Ok(Gmm1 {
            logw: vec![0.0],
            mu: vec![if m.is_finite() { m } else { 3.0 }],
            logsd: vec![0.0],
        });
    }
    match runtime {
        Some(rt) => {
            let (g, _, _) = fit_gmm1(rt, logs, rng, 80, 1e-7)?;
            Ok(g)
        }
        None => {
            let (g, _) = crate::runtime::fitter::fit_gmm1_cpu(logs, K1, rng, 80, 1e-7);
            Ok(g)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::empirical::GroundTruth;

    fn fitted() -> SimParams {
        let db = GroundTruth::new(3).generate_weeks(4);
        fit_params(&db, None).unwrap()
    }

    #[test]
    fn fit_recovers_duration_medians() {
        let p = fitted();
        let mut rng = Pcg64::new(1);
        // sample train durations for SparkML and TF and compare medians
        let mut med = |fw: Framework| {
            let g = p.train_gmm(fw);
            let mut xs: Vec<f64> = (0..20_000).map(|_| g.sample(&mut rng).exp()).collect();
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            xs[xs.len() / 2]
        };
        let spark = med(Framework::SparkML);
        let tf = med(Framework::TensorFlow);
        assert!((5.0..20.0).contains(&spark), "spark median {spark}");
        assert!((100.0..320.0).contains(&tf), "tf median {tf}");
    }

    #[test]
    fn fit_recovers_preproc_curve() {
        let p = fitted();
        // ground truth: a=0.018 b=1.330 c=2.156
        assert!((p.preproc_curve.b - 1.330).abs() < 0.02, "b={}", p.preproc_curve.b);
        assert!((p.preproc_curve.c - 2.156).abs() < 0.4, "c={}", p.preproc_curve.c);
    }

    #[test]
    fn fit_interarrival_mean_close_to_db() {
        let db = GroundTruth::new(5).generate_weeks(4);
        let mut p = fit_params(&db, None).unwrap();
        let want = crate::stats::mean(&db.interarrivals());
        assert!((p.mean_interarrival - want).abs() / want < 1e-9);
        // sampled interarrivals from the random model within 25%
        let mut rng = Pcg64::new(2);
        let sim: f64 = (0..20_000)
            .map(|_| p.arrival_random.next_interarrival(0.0, 1.0, &mut rng))
            .sum::<f64>()
            / 20_000.0;
        assert!((sim - want).abs() / want < 0.25, "sim {sim} want {want}");
    }

    #[test]
    fn params_roundtrip_json() {
        let p = fitted();
        let path = std::env::temp_dir().join("pipesim_params_test.json");
        p.save(&path).unwrap();
        let back = SimParams::load(&path).unwrap();
        assert_eq!(back.train_log_gmm.len(), 5);
        assert!((back.preproc_curve.b - p.preproc_curve.b).abs() < 1e-12);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn params_roundtrip_binary_autodetected() {
        // `.bin` selects the binary cache; `load` detects it by magic
        let p = fitted();
        let dir = std::env::temp_dir();
        let bin = dir.join("pipesim_params_test_cache.bin");
        let json = dir.join("pipesim_params_test_cache.json");
        p.save(&bin).unwrap();
        p.save(&json).unwrap();
        let back = SimParams::load(&bin).unwrap();
        // bit-exact, not approximate: a run from either encoding digests
        // identically
        assert_eq!(back.preproc_curve.b.to_bits(), p.preproc_curve.b.to_bits());
        assert_eq!(back.eval_log_gmm.mu, p.eval_log_gmm.mu);
        let bin_len = std::fs::metadata(&bin).unwrap().len();
        let json_len = std::fs::metadata(&json).unwrap().len();
        assert!(
            bin_len < json_len,
            "binary cache ({bin_len} B) should undercut JSON ({json_len} B)"
        );
        std::fs::remove_file(bin).ok();
        std::fs::remove_file(json).ok();
    }

    #[test]
    fn report_populated() {
        let db = GroundTruth::new(6).generate_weeks(4);
        let (_, report) = fit_params_with_report(&db, None).unwrap();
        assert_eq!(report.backend, "cpu");
        assert!(report.asset_rows > 500);
        assert_eq!(report.train_rows.len(), 5);
        assert_eq!(
            report.profile_families.iter().map(|(_, c)| c).sum::<usize>(),
            168
        );
    }
}
