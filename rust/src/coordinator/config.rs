//! Experiment configuration (TOML-loadable).

use crate::des::DAY;
use crate::error::Result;
use crate::model::{InfraConfig, ResourceKind};
use crate::synth::SynthConfig;
use crate::trace::TraceMeta;

use super::strategy::{
    build_placer, build_retry_policy, build_scheduler, build_trigger, StrategySpec,
};

/// Which arrival process drives the experiment.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalSpec {
    /// The fitted global interarrival distribution.
    Random,
    /// The fitted 168-cluster hour-of-week profile.
    Profile,
    /// Flat exponential interarrivals (Fig 13 scalability runs).
    Poisson { mean_interarrival: f64 },
    /// Replay the recorded empirical arrival trace verbatim.
    Replay,
}

/// Run-time view configuration (drift detection + automated retraining,
/// paper section IV-A2 / Fig 7).
#[derive(Clone, Debug, PartialEq)]
pub struct RuntimeViewConfig {
    pub enabled: bool,
    /// Detector evaluation period, seconds.
    pub detector_interval: f64,
    /// Mean performance decay per day (gradual drift).
    pub decay_per_day: f64,
    /// Probability per detector tick of a sudden concept drift.
    pub sudden_drift_prob: f64,
    /// Performance drop on a sudden drift event.
    pub sudden_drift_drop: f64,
    /// Retraining trigger strategy (built from the registry in
    /// `coordinator::strategy`).
    pub trigger: StrategySpec,
    /// Cap on concurrently monitored models (memory bound).
    pub max_models: usize,
}

impl Default for RuntimeViewConfig {
    fn default() -> Self {
        RuntimeViewConfig {
            enabled: false,
            detector_interval: 6.0 * 3600.0,
            decay_per_day: 0.004,
            sudden_drift_prob: 0.01,
            sudden_drift_drop: 0.08,
            trigger: StrategySpec::new("drift_threshold").with("threshold", 0.05),
            max_models: 2000,
        }
    }
}

/// Full experiment definition (the paper's "experiment and its
/// parameters", section IV).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Experiment name (labels outputs).
    pub name: String,
    /// RNG seed — every run is reproducible from this.
    pub seed: u64,
    /// Simulated horizon in seconds.
    pub horizon: f64,
    pub arrival: ArrivalSpec,
    /// Interarrival scale factor (>1 = lighter load), section VI-B.
    pub interarrival_factor: f64,
    pub infra: InfraConfig,
    pub synth: SynthConfig,
    /// Monitor sampling period (utilization/queue series), seconds.
    pub sample_interval: f64,
    /// Record per-task duration/wait series into the tsdb.
    pub record_traces: bool,
    /// Capture the event-level trace (`trace::Trace`) into the result.
    /// Off by default: the `NullSink` keeps the event path allocation-free.
    pub capture_trace: bool,
    pub runtime_view: RuntimeViewConfig,
    /// Stop after this many pipeline arrivals (None = horizon only).
    pub max_pipelines: Option<u64>,
    /// Downsampled tsdb retention: when set, series points roll into
    /// fixed-resolution windows of `(count, sum, min, max, sketch)`
    /// instead of raw columns, so memory stays flat over the run
    /// length. `None` (the default) stores every point raw and is
    /// byte-identical to pre-retention behavior.
    pub retention: Option<RetentionConfig>,
    /// Enable the simulator self-profiling meter
    /// ([`crate::obs::SimMeter`]): per-kind event counts and wall time,
    /// calendar depth, heap rebuilds, RNG draws. Off by default
    /// (zero-cost); the report lands in `ExperimentResult::meter` and
    /// never affects the digest.
    pub meter: bool,
}

/// Downsampled retention policy for the run's tsdb.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetentionConfig {
    /// Window resolution in seconds (points within one window roll
    /// into a single streaming-aggregate bucket).
    pub resolution: f64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "default".into(),
            seed: 1,
            horizon: 3.0 * DAY,
            arrival: ArrivalSpec::Profile,
            interarrival_factor: 1.0,
            infra: InfraConfig::default(),
            synth: SynthConfig::default(),
            sample_interval: 300.0,
            record_traces: true,
            capture_trace: false,
            runtime_view: RuntimeViewConfig::default(),
            max_pipelines: None,
            retention: None,
            meter: false,
        }
    }
}

impl ExperimentConfig {
    pub fn from_json_text(text: &str) -> Result<Self> {
        use crate::util::jsonio::JsonIo;
        Self::from_json(&crate::util::Json::parse(text)?)
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        Self::from_json_text(&std::fs::read_to_string(path)?)
    }

    pub fn to_json_text(&self) -> String {
        use crate::util::jsonio::JsonIo;
        self.to_json().to_string()
    }

    pub fn validate(&self) -> Result<()> {
        if self.horizon <= 0.0 {
            return Err(crate::error::Error::Config("horizon must be > 0".into()));
        }
        if self.interarrival_factor <= 0.0 {
            return Err(crate::error::Error::Config(
                "interarrival_factor must be > 0".into(),
            ));
        }
        if self.sample_interval <= 0.0 {
            return Err(crate::error::Error::Config(
                "sample_interval must be > 0".into(),
            ));
        }
        if let Some(ret) = &self.retention {
            if !ret.resolution.is_finite() || ret.resolution <= 0.0 {
                return Err(crate::error::Error::Config(format!(
                    "retention resolution must be finite and > 0, got {}",
                    ret.resolution
                )));
            }
        }
        if self.infra.training_capacity == 0 || self.infra.compute_capacity == 0 {
            // a zero-capacity resource queues jobs forever: the run would
            // silently never complete any work
            return Err(crate::error::Error::Config(
                "infra capacities must be >= 1".into(),
            ));
        }
        if self.infra.train_slots == 0 || self.infra.train_slots > self.infra.training_capacity {
            // a training job wider than the cluster could never be
            // granted — it would queue forever
            return Err(crate::error::Error::Config(format!(
                "train_slots must be in 1..={} (the training capacity), got {}",
                self.infra.training_capacity, self.infra.train_slots
            )));
        }
        let share_sum: f64 = self.synth.framework_shares.iter().sum();
        if (share_sum - 1.0).abs() > 1e-6 {
            return Err(crate::error::Error::Config(format!(
                "framework shares sum to {share_sum}, expected 1"
            )));
        }
        // failure-model knobs must be sane before any failure event is
        // scheduled (distribution parameters are validated at
        // construction by the Dist constructors themselves)
        if let Some(fm) = &self.infra.failures {
            for (cluster, fc) in [("training", &fm.training), ("compute", &fm.compute)] {
                if let Some(fc) = fc {
                    if !fc.checkpoint_interval.is_finite() || fc.checkpoint_interval < 0.0 {
                        return Err(crate::error::Error::Config(format!(
                            "{cluster} checkpoint_interval must be finite and >= 0 \
                             (0 disables checkpointing), got {}",
                            fc.checkpoint_interval
                        )));
                    }
                    if !fc.restart_cost.is_finite() || fc.restart_cost < 0.0 {
                        return Err(crate::error::Error::Config(format!(
                            "{cluster} restart_cost must be finite and >= 0, got {}",
                            fc.restart_cost
                        )));
                    }
                }
            }
        }
        // task-fault knobs must be sane before any fault event is
        // scheduled (fault-time distribution parameters are validated at
        // construction by the Dist constructors themselves)
        if let Some(fm) = &self.infra.faults {
            for (cluster, fc) in [("training", &fm.training), ("compute", &fm.compute)] {
                if let Some(fc) = fc {
                    if !fc.timeout.is_finite() || fc.timeout < 0.0 {
                        return Err(crate::error::Error::Config(format!(
                            "{cluster} fault timeout must be finite and >= 0 \
                             (0 disables timeouts), got {}",
                            fc.timeout
                        )));
                    }
                }
            }
        }
        // hardware classes: per-cluster slot counts must sum to the
        // cluster capacity (a mismatch would desynchronize class
        // accounting from the resource), names must be unique, and the
        // speed/cost knobs must be finite and usable
        if let Some(hw) = &self.infra.hw_classes {
            for (cluster, classes, capacity) in [
                ("training", &hw.training, self.infra.training_capacity),
                ("compute", &hw.compute, self.infra.compute_capacity),
            ] {
                if classes.is_empty() {
                    continue;
                }
                let sum: usize = classes.iter().map(|c| c.slots).sum();
                if sum != capacity {
                    return Err(crate::error::Error::Config(format!(
                        "{cluster} hw_classes slots sum to {sum}, \
                         expected the cluster capacity {capacity}"
                    )));
                }
                for (i, c) in classes.iter().enumerate() {
                    if c.name.is_empty() {
                        return Err(crate::error::Error::Config(format!(
                            "{cluster} hw_classes[{i}]: class name must not be empty"
                        )));
                    }
                    if classes[..i].iter().any(|o| o.name == c.name) {
                        return Err(crate::error::Error::Config(format!(
                            "{cluster} hw_classes: duplicate class name '{}'",
                            c.name
                        )));
                    }
                    if c.slots == 0 {
                        return Err(crate::error::Error::Config(format!(
                            "{cluster} hw class '{}': slots must be >= 1",
                            c.name
                        )));
                    }
                    if !c.speed.is_finite() || c.speed <= 0.0 {
                        return Err(crate::error::Error::Config(format!(
                            "{cluster} hw class '{}': speed must be finite and > 0, got {}",
                            c.name, c.speed
                        )));
                    }
                    if !c.cost_per_sec.is_finite() || c.cost_per_sec < 0.0 {
                        return Err(crate::error::Error::Config(format!(
                            "{cluster} hw class '{}': cost_per_sec must be finite \
                             and >= 0, got {}",
                            c.name, c.cost_per_sec
                        )));
                    }
                    for (fw, s) in &c.fw_speed {
                        if !s.is_finite() || *s <= 0.0 {
                            return Err(crate::error::Error::Config(format!(
                                "{cluster} hw class '{}': fw_speed[{fw}] must be \
                                 finite and > 0, got {s}",
                                c.name
                            )));
                        }
                    }
                }
            }
        }
        // strategies must resolve in the registry (unknown names and
        // typoed params fail here, before any work is done) — the shared
        // scheduler spec and both per-cluster overrides all resolve
        build_scheduler(&self.infra.scheduler)?;
        build_scheduler(self.infra.scheduler_for(ResourceKind::Training))?;
        build_scheduler(self.infra.scheduler_for(ResourceKind::Compute))?;
        build_trigger(&self.runtime_view.trigger)?;
        if let Some(hw) = &self.infra.hw_classes {
            build_placer(&hw.placer)?;
        }
        if let Some(fm) = &self.infra.faults {
            build_retry_policy(&fm.retry)?;
        }
        Ok(())
    }

    /// Resolved retraining-trigger label for reports and trace metadata
    /// (`"off"` when the runtime view is disabled).
    pub fn trigger_label(&self) -> String {
        if self.runtime_view.enabled {
            self.runtime_view.trigger.label()
        } else {
            "off".to_string()
        }
    }

    /// The [`TraceMeta`] a capture of this config produces. Everything
    /// here is config-derived, so two captures of the same
    /// `(config, seed)` carry byte-identical metadata — the in-memory
    /// capture path and file-backed streaming sinks
    /// (`trace::StreamingPstSink`) both label traces through this one
    /// constructor and can never diverge.
    pub fn trace_meta(&self) -> TraceMeta {
        let mut extra = vec![
            ("scheduler".to_string(), self.infra.scheduler_label()),
            ("trigger".to_string(), self.trigger_label()),
        ];
        // only hw-class configs carry a placer entry, so pre-existing
        // captures stay byte-identical
        if let Some(placer) = self.infra.placer_label() {
            extra.push(("placer".to_string(), placer));
        }
        // same rule for the retry policy: only fault-model configs
        // carry the entry
        if let Some(retry) = self.infra.retry_label() {
            extra.push(("retry".to_string(), retry));
        }
        TraceMeta {
            name: self.name.clone(),
            seed: self.seed,
            horizon: self.horizon,
            config_json: self.to_json_text(),
            extra,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let cfg = ExperimentConfig {
            name: "rt".into(),
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 44.0,
            },
            ..Default::default()
        };
        let text = cfg.to_json_text();
        let back = ExperimentConfig::from_json_text(&text).unwrap();
        assert_eq!(back.name, "rt");
        assert_eq!(
            back.arrival,
            ArrivalSpec::Poisson {
                mean_interarrival: 44.0
            }
        );
    }

    #[test]
    fn rejects_bad_configs() {
        let mut cfg = ExperimentConfig::default();
        cfg.horizon = -1.0;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.interarrival_factor = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.synth.framework_shares = [1.0, 1.0, 0.0, 0.0, 0.0];
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_oversized_train_slots() {
        let mut cfg = ExperimentConfig::default();
        cfg.infra.train_slots = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.infra.training_capacity = 4;
        cfg.infra.train_slots = 5;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.infra.training_capacity = 4;
        cfg.infra.train_slots = 4;
        cfg.validate().unwrap();
        // the knob round-trips through JSON, and old configs without it
        // parse as unit-slot
        let text = cfg.to_json_text();
        let back = ExperimentConfig::from_json_text(&text).unwrap();
        assert_eq!(back.infra.train_slots, 4);
        let mut j = crate::util::Json::parse(&text).unwrap();
        if let crate::util::Json::Obj(fields) = &mut j {
            let infra = fields
                .iter_mut()
                .find(|(k, _)| k == "infra")
                .map(|(_, v)| v)
                .unwrap();
            if let crate::util::Json::Obj(infra_fields) = infra {
                infra_fields.retain(|(k, _)| k != "train_slots");
            }
        }
        let back = ExperimentConfig::from_json_text(&j.to_string()).unwrap();
        assert_eq!(back.infra.train_slots, 1);
    }

    #[test]
    fn new_scheduler_specs_roundtrip_json() {
        for spec in [
            StrategySpec::new("preemptive_priority").with("min_class_gap", 2.0),
            StrategySpec::new("easy_backfill"),
        ] {
            let mut cfg = ExperimentConfig::default();
            cfg.infra.scheduler = spec.clone();
            cfg.validate().unwrap();
            let back = ExperimentConfig::from_json_text(&cfg.to_json_text()).unwrap();
            assert_eq!(back.infra.scheduler, spec);
        }
        // unknown param still rejected
        let mut cfg = ExperimentConfig::default();
        cfg.infra.scheduler = StrategySpec::new("easy_backfill").with("window", 1.0);
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn per_resource_scheduler_specs_roundtrip_and_validate() {
        let mut cfg = ExperimentConfig::default();
        cfg.infra.scheduler_training = Some(StrategySpec::new("easy_backfill"));
        cfg.infra.scheduler_compute = Some(StrategySpec::new("sjf"));
        cfg.validate().unwrap();
        let back = ExperimentConfig::from_json_text(&cfg.to_json_text()).unwrap();
        assert_eq!(back.infra.scheduler_training, cfg.infra.scheduler_training);
        assert_eq!(back.infra.scheduler_compute, cfg.infra.scheduler_compute);
        // a bad override fails validation even though the shared spec is
        // fine — resolution covers what each cluster will actually run
        let mut cfg = ExperimentConfig::default();
        cfg.infra.scheduler_training = Some(StrategySpec::new("no_such"));
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.infra.scheduler_compute = Some(StrategySpec::new("edf").with("typo", 1.0));
        assert!(cfg.validate().is_err());
        // configs predating the split parse with no overrides
        let plain = ExperimentConfig::default().to_json_text();
        let back = ExperimentConfig::from_json_text(&plain).unwrap();
        assert_eq!(back.infra.scheduler_training, None);
        assert_eq!(back.infra.scheduler_compute, None);
    }

    #[test]
    fn retention_and_meter_knobs_roundtrip_and_validate() {
        let mut cfg = ExperimentConfig::default();
        cfg.retention = Some(RetentionConfig { resolution: 600.0 });
        cfg.meter = true;
        cfg.validate().unwrap();
        let back = ExperimentConfig::from_json_text(&cfg.to_json_text()).unwrap();
        assert_eq!(back.retention, cfg.retention);
        assert!(back.meter);
        // bad resolutions rejected up front
        cfg.retention = Some(RetentionConfig { resolution: 0.0 });
        assert!(cfg.validate().is_err());
        cfg.retention = Some(RetentionConfig {
            resolution: f64::NAN,
        });
        assert!(cfg.validate().is_err());
        // unset knobs are omitted from JSON, so pre-existing configs
        // and trace metadata stay byte-identical
        let plain = ExperimentConfig::default().to_json_text();
        assert!(!plain.contains("retention"), "{plain}");
        assert!(!plain.contains("meter"), "{plain}");
        let back = ExperimentConfig::from_json_text(&plain).unwrap();
        assert_eq!(back.retention, None);
        assert!(!back.meter);
    }

    #[test]
    fn trace_meta_is_config_derived_and_labelled() {
        let mut cfg = ExperimentConfig::default();
        cfg.name = "meta".into();
        cfg.seed = 9;
        let m = cfg.trace_meta();
        assert_eq!(m.name, "meta");
        assert_eq!(m.seed, 9);
        assert_eq!(m.horizon, cfg.horizon);
        assert_eq!(m.get("scheduler"), Some("fifo"));
        assert_eq!(m.get("trigger"), Some("off"), "runtime view disabled");
        assert_eq!(
            ExperimentConfig::from_json_text(&m.config_json).unwrap().seed,
            9,
            "embedded config replays"
        );
        cfg.runtime_view.enabled = true;
        cfg.infra.scheduler_training = Some(StrategySpec::new("priority"));
        let m = cfg.trace_meta();
        assert_eq!(
            m.get("scheduler"),
            Some("training=priority|compute=fifo")
        );
        assert_eq!(m.get("trigger"), Some("drift_threshold:threshold=0.05"));
    }

    #[test]
    fn rejects_zero_capacity_resources() {
        // a zero-capacity cluster would queue jobs forever
        let mut cfg = ExperimentConfig::default();
        cfg.infra.training_capacity = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.infra.compute_capacity = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn failure_model_roundtrips_and_validates_knobs() {
        use crate::model::{ClusterFailureConfig, FailureModel};
        let mut cfg = ExperimentConfig::default();
        cfg.infra.failures = Some(FailureModel {
            training: Some(
                ClusterFailureConfig::exponential(86_400.0, 1_800.0)
                    .with_checkpointing(600.0, 30.0),
            ),
            compute: None,
        });
        cfg.validate().unwrap();
        let back = ExperimentConfig::from_json_text(&cfg.to_json_text()).unwrap();
        assert_eq!(back.infra.failures, cfg.infra.failures);
        // bad knobs are rejected up front
        let mut bad = cfg.clone();
        bad.infra.failures.as_mut().unwrap().training.as_mut().unwrap().checkpoint_interval =
            -1.0;
        assert!(bad.validate().is_err());
        let mut bad = cfg.clone();
        bad.infra.failures.as_mut().unwrap().training.as_mut().unwrap().restart_cost =
            f64::INFINITY;
        assert!(bad.validate().is_err());
        // configs predating the failure model parse with failures off
        let plain = ExperimentConfig::default().to_json_text();
        assert!(!plain.contains("failures"));
        let back = ExperimentConfig::from_json_text(&plain).unwrap();
        assert_eq!(back.infra.failures, None);
    }

    #[test]
    fn fault_model_roundtrips_and_validates_knobs() {
        use crate::model::{FaultModel, TaskFaultConfig};
        let mut cfg = ExperimentConfig::default();
        cfg.infra.faults = Some(FaultModel {
            training: Some(
                TaskFaultConfig::transient(7_200.0)
                    .with_timeout(3_600.0)
                    .with_queue_cap(16),
            ),
            compute: None,
            retry: StrategySpec::new("exp_backoff").with("max_attempts", 4.0),
        });
        cfg.validate().unwrap();
        let back = ExperimentConfig::from_json_text(&cfg.to_json_text()).unwrap();
        assert_eq!(back.infra.faults, cfg.infra.faults);
        // bad knobs are rejected up front, with the cluster named
        let mut bad = cfg.clone();
        bad.infra.faults.as_mut().unwrap().training.as_mut().unwrap().timeout = -1.0;
        let err = bad.validate().unwrap_err();
        assert!(err.to_string().contains("training fault timeout"), "{err}");
        let mut bad = cfg.clone();
        bad.infra.faults.as_mut().unwrap().training.as_mut().unwrap().timeout = f64::NAN;
        assert!(bad.validate().is_err());
        // unknown retry policy / typoed param rejected through the registry
        let mut bad = cfg.clone();
        bad.infra.faults.as_mut().unwrap().retry = StrategySpec::new("no_such_retry");
        let err = bad.validate().unwrap_err();
        assert!(err.to_string().contains("unknown retry policy"), "{err}");
        let mut bad = cfg.clone();
        bad.infra.faults.as_mut().unwrap().retry = StrategySpec::new("fixed").with("typo", 1.0);
        assert!(bad.validate().is_err());
        // configs predating the fault model parse with faults off
        let plain = ExperimentConfig::default().to_json_text();
        assert!(!plain.contains("faults"));
        let back = ExperimentConfig::from_json_text(&plain).unwrap();
        assert_eq!(back.infra.faults, None);
    }

    #[test]
    fn trace_meta_retry_entry_only_with_faults() {
        use crate::model::{FaultModel, TaskFaultConfig};
        let cfg = ExperimentConfig::default();
        assert_eq!(cfg.trace_meta().get("retry"), None);
        let mut cfg = ExperimentConfig::default();
        cfg.infra.faults = Some(FaultModel {
            training: Some(TaskFaultConfig::transient(3_600.0)),
            compute: None,
            retry: StrategySpec::new("deadline_aware"),
        });
        assert_eq!(cfg.trace_meta().get("retry"), Some("deadline_aware"));
    }

    #[test]
    fn hw_class_configs_validate_slots_names_and_knobs() {
        use crate::model::{HwClass, HwClasses};
        let two_class = |a: HwClass, b: HwClass| {
            let mut cfg = ExperimentConfig::default();
            cfg.infra.training_capacity = 6;
            cfg.infra.hw_classes = Some(HwClasses {
                training: vec![a, b],
                compute: Vec::new(),
                placer: StrategySpec::new("fastest_fit"),
            });
            cfg
        };
        let good = two_class(
            HwClass::new("a100", 2).with_speed(2.0).with_cost(3.0),
            HwClass::new("v100", 4),
        );
        good.validate().unwrap();
        let back = ExperimentConfig::from_json_text(&good.to_json_text()).unwrap();
        assert_eq!(back.infra.hw_classes, good.infra.hw_classes);
        // slots must sum to the cluster capacity
        let bad = two_class(HwClass::new("a", 2), HwClass::new("b", 3));
        let err = bad.validate().unwrap_err();
        assert!(err.to_string().contains("sum to 5"), "{err}");
        // duplicate names rejected
        let bad = two_class(HwClass::new("a", 2), HwClass::new("a", 4));
        let err = bad.validate().unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
        // non-finite / non-positive knobs rejected
        let bad = two_class(HwClass::new("a", 2).with_speed(f64::NAN), HwClass::new("b", 4));
        assert!(bad.validate().is_err());
        let bad = two_class(HwClass::new("a", 2).with_speed(0.0), HwClass::new("b", 4));
        assert!(bad.validate().is_err());
        let bad = two_class(
            HwClass::new("a", 2).with_cost(f64::INFINITY),
            HwClass::new("b", 4),
        );
        assert!(bad.validate().is_err());
        let bad = two_class(
            HwClass::new("a", 2).with_fw_speed("tensorflow", -1.0),
            HwClass::new("b", 4),
        );
        assert!(bad.validate().is_err());
        // unknown placer rejected through the registry
        let mut bad = good.clone();
        bad.infra.hw_classes.as_mut().unwrap().placer = StrategySpec::new("no_such_placer");
        assert!(bad.validate().is_err());
        // classless configs are untouched by the new checks
        let plain = ExperimentConfig::default().to_json_text();
        assert!(!plain.contains("hw_classes"));
        assert_eq!(
            ExperimentConfig::from_json_text(&plain).unwrap().infra.hw_classes,
            None
        );
    }

    #[test]
    fn trace_meta_placer_entry_only_with_classes() {
        use crate::model::{HwClass, HwClasses};
        let cfg = ExperimentConfig::default();
        assert_eq!(cfg.trace_meta().get("placer"), None);
        let mut cfg = ExperimentConfig::default();
        cfg.infra.training_capacity = 4;
        cfg.infra.hw_classes = Some(HwClasses {
            training: vec![HwClass::new("gpu", 4)],
            compute: Vec::new(),
            placer: StrategySpec::new("spread"),
        });
        assert_eq!(cfg.trace_meta().get("placer"), Some("spread"));
    }

    #[test]
    fn rejects_unknown_strategies() {
        let mut cfg = ExperimentConfig::default();
        cfg.infra.scheduler = StrategySpec::new("no_such_scheduler");
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.runtime_view.trigger = StrategySpec::new("no_such_trigger");
        assert!(cfg.validate().is_err());
        // known name, typoed parameter key
        let mut cfg = ExperimentConfig::default();
        cfg.infra.scheduler = StrategySpec::new("edf").with("slack", 10.0);
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn json_example_parses() {
        let text = r#"{
            "name": "peak-load",
            "seed": 7,
            "horizon": 259200.0,
            "arrival": {"mode": "profile"},
            "interarrival_factor": 0.5,
            "infra": {
                "training_capacity": 6,
                "compute_capacity": 12,
                "discipline": "fifo",
                "store": {"read_bw": 4e8, "write_bw": 2.5e8,
                           "latency": 0.05, "tcp_overhead": 1.06}
            },
            "synth": {
                "framework_shares": [0.63, 0.32, 0.03, 0.01, 0.01],
                "p_preprocess": 0.55, "p_evaluate": 0.7, "p_compress": 0.1,
                "p_harden": 0.05, "p_reevaluate": 0.8, "p_transfer": 0.05,
                "p_deploy": 0.8
            },
            "sample_interval": 300.0,
            "record_traces": true,
            "runtime_view": {
                "enabled": true,
                "detector_interval": 21600.0,
                "decay_per_day": 0.004,
                "sudden_drift_prob": 0.01,
                "sudden_drift_drop": 0.08,
                "trigger": {"policy": "drift_threshold", "threshold": 0.05},
                "max_models": 500
            }
        }"#;
        let cfg = ExperimentConfig::from_json_text(text).unwrap();
        cfg.validate().unwrap();
        assert_eq!(cfg.infra.training_capacity, 6);
        // the legacy "discipline"/"policy" forms map onto strategy specs
        assert_eq!(cfg.infra.scheduler, StrategySpec::new("fifo"));
        assert_eq!(
            cfg.runtime_view.trigger,
            StrategySpec::new("drift_threshold").with("threshold", 0.05)
        );
        assert!(cfg.runtime_view.enabled);
        assert_eq!(cfg.max_pipelines, None);
    }

    #[test]
    fn strategy_spec_json_selects_new_schedulers() {
        // new strategies are selectable purely from JSON config
        let text = r#"{
            "name": "edf-run", "seed": 1, "horizon": 3600.0,
            "arrival": {"mode": "poisson", "mean_interarrival": 60.0},
            "interarrival_factor": 1.0,
            "infra": {
                "training_capacity": 4, "compute_capacity": 8,
                "scheduler": {"name": "edf", "params": {"slack_per_class": 900}},
                "store": {"read_bw": 4e8, "write_bw": 2.5e8,
                           "latency": 0.05, "tcp_overhead": 1.06}
            },
            "synth": {
                "framework_shares": [0.63, 0.32, 0.03, 0.01, 0.01],
                "p_preprocess": 0.55, "p_evaluate": 0.7, "p_compress": 0.1,
                "p_harden": 0.05, "p_reevaluate": 0.8, "p_transfer": 0.05,
                "p_deploy": 0.8
            },
            "sample_interval": 300.0,
            "record_traces": false,
            "runtime_view": {
                "enabled": true,
                "detector_interval": 21600.0,
                "decay_per_day": 0.004,
                "sudden_drift_prob": 0.01,
                "sudden_drift_drop": 0.08,
                "trigger": {"name": "performance_floor", "params": {"floor": 0.72}},
                "max_models": 100
            }
        }"#;
        let cfg = ExperimentConfig::from_json_text(text).unwrap();
        cfg.validate().unwrap();
        assert_eq!(
            cfg.infra.scheduler,
            StrategySpec::new("edf").with("slack_per_class", 900.0)
        );
        assert_eq!(
            cfg.runtime_view.trigger,
            StrategySpec::new("performance_floor").with("floor", 0.72)
        );
    }
}
