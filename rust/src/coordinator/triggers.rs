//! Run-time view: deployed models, drift processes, detectors, and the
//! retraining trigger policies (paper sections III-A, IV-A2, Fig 7).
//!
//! A deployed model's performance p(M) degrades over time — gradual decay
//! plus sudden concept-drift events (Fig 2). A detector evaluates each
//! model periodically; when the configured trigger rule fires, a
//! retraining pipeline is scheduled. The *policy* deciding when to fire
//! is the operational strategy under study (Fig 4): retrain eagerly, on a
//! drift threshold, or deferred into predicted low-load hours.

use crate::des::SimTime;
use crate::empirical::db::hour_of_week;
use crate::empirical::GroundTruth;
use crate::stats::rng::Pcg64;

/// When does a drifting model get retrained?
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TriggerPolicy {
    /// Retrain at every detector tick (the wasteful baseline the paper's
    /// section III-B warns about).
    Eager,
    /// Retrain when the drift metric exceeds a threshold (Fig 7's rule).
    DriftThreshold { threshold: f64 },
    /// Drift threshold + defer the launch into the next predicted
    /// low-load hour (uses the arrival-profile intensity forecast).
    OffPeak {
        threshold: f64,
        /// Launch only in hours with forecast intensity below this.
        max_intensity: f64,
    },
    /// Never retrain (ablation lower bound).
    Never,
}

impl TriggerPolicy {
    /// Decide at detector time `t`: `None` = don't retrain, `Some(delay)`
    /// = schedule the retraining pipeline after `delay` seconds.
    pub fn decide(&self, t: SimTime, drift: f64) -> Option<SimTime> {
        match *self {
            TriggerPolicy::Eager => Some(0.0),
            TriggerPolicy::Never => None,
            TriggerPolicy::DriftThreshold { threshold } => {
                (drift >= threshold).then_some(0.0)
            }
            TriggerPolicy::OffPeak {
                threshold,
                max_intensity,
            } => {
                if drift < threshold {
                    return None;
                }
                Some(delay_to_off_peak(t, max_intensity))
            }
        }
    }
}

/// Seconds until the next hour whose forecast arrival intensity is below
/// `max_intensity` (0 if the current hour already is).
pub fn delay_to_off_peak(t: SimTime, max_intensity: f64) -> SimTime {
    for ahead in 0..168 {
        let how = (hour_of_week(t) + ahead) % 168;
        if GroundTruth::intensity(how) <= max_intensity {
            if ahead == 0 {
                return 0.0;
            }
            // start of that hour
            let hour_start = (t / 3600.0).floor() * 3600.0 + ahead as f64 * 3600.0;
            return hour_start - t;
        }
    }
    0.0 // no hour qualifies: fire now rather than starve
}

/// A deployed model being monitored by the run-time view.
#[derive(Clone, Debug)]
pub struct DeployedModel {
    pub model_id: u64,
    pub framework: crate::model::Framework,
    /// Performance at deployment.
    pub initial_performance: f64,
    /// Current composite performance p(M).
    pub performance: f64,
    /// Accumulated drift metric (detector output).
    pub drift: f64,
    pub deployed_at: SimTime,
    pub last_tick: SimTime,
    /// Version in the retraining lineage.
    pub version: u32,
    /// Is a retraining for this model already in flight?
    pub retraining: bool,
}

impl DeployedModel {
    pub fn new(
        model_id: u64,
        framework: crate::model::Framework,
        performance: f64,
        t: SimTime,
        version: u32,
    ) -> Self {
        DeployedModel {
            model_id,
            framework,
            initial_performance: performance,
            performance,
            drift: 0.0,
            deployed_at: t,
            last_tick: t,
            version,
            retraining: false,
        }
    }

    /// Advance the drift process to time `t` (one detector tick):
    /// gradual decay + stochastic sudden drops + detector noise.
    pub fn tick(
        &mut self,
        t: SimTime,
        decay_per_day: f64,
        sudden_prob: f64,
        sudden_drop: f64,
        rng: &mut Pcg64,
    ) {
        let dt_days = (t - self.last_tick) / 86_400.0;
        self.last_tick = t;
        let mut drop = decay_per_day * dt_days;
        if rng.uniform() < sudden_prob {
            drop += sudden_drop * (0.5 + rng.uniform());
        }
        self.performance = (self.performance - drop).max(0.0);
        // detector measures staleness with a little observation noise
        let staleness = (self.initial_performance - self.performance).max(0.0);
        self.drift = (staleness + 0.005 * rng.normal()).max(0.0);
    }

    /// Refresh after a completed retraining deployment.
    pub fn redeploy(&mut self, t: SimTime, performance: f64) {
        self.version += 1;
        self.initial_performance = performance;
        self.performance = performance;
        self.drift = 0.0;
        self.deployed_at = t;
        self.last_tick = t;
        self.retraining = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Framework;

    #[test]
    fn eager_always_fires() {
        assert_eq!(TriggerPolicy::Eager.decide(0.0, 0.0), Some(0.0));
    }

    #[test]
    fn never_never_fires() {
        assert_eq!(TriggerPolicy::Never.decide(0.0, 9.9), None);
    }

    #[test]
    fn threshold_gates_on_drift() {
        let p = TriggerPolicy::DriftThreshold { threshold: 0.05 };
        assert_eq!(p.decide(0.0, 0.01), None);
        assert_eq!(p.decide(0.0, 0.08), Some(0.0));
    }

    #[test]
    fn off_peak_defers_to_quiet_hours() {
        let p = TriggerPolicy::OffPeak {
            threshold: 0.05,
            max_intensity: 0.5,
        };
        // Monday 16:00 is the peak -> must defer
        let t_peak = 16.0 * 3600.0;
        let delay = p.decide(t_peak, 0.10).unwrap();
        assert!(delay > 0.0, "must defer from the peak hour");
        // landing hour must be quiet
        let landing = hour_of_week(t_peak + delay);
        assert!(GroundTruth::intensity(landing) <= 0.5);
        // Monday 03:00 is already quiet -> immediate
        assert_eq!(p.decide(3.0 * 3600.0, 0.10), Some(0.0));
    }

    #[test]
    fn drift_process_decays_performance() {
        let mut m = DeployedModel::new(1, Framework::TensorFlow, 0.9, 0.0, 1);
        let mut rng = Pcg64::new(1);
        // 30 days of 6-hour ticks with no sudden drift
        for i in 1..=120 {
            m.tick(i as f64 * 21_600.0, 0.004, 0.0, 0.0, &mut rng);
        }
        let expected = 0.9 - 0.004 * 30.0;
        assert!((m.performance - expected).abs() < 1e-9);
        assert!(m.drift > 0.05, "drift metric accumulated: {}", m.drift);
    }

    #[test]
    fn sudden_drift_drops_fast() {
        let mut m = DeployedModel::new(1, Framework::SparkML, 0.9, 0.0, 1);
        let mut rng = Pcg64::new(2);
        m.tick(3600.0, 0.0, 1.0, 0.1, &mut rng); // forced sudden event
        assert!(m.performance < 0.86);
    }

    #[test]
    fn redeploy_resets() {
        let mut m = DeployedModel::new(1, Framework::SparkML, 0.9, 0.0, 1);
        let mut rng = Pcg64::new(3);
        m.tick(86_400.0, 0.05, 0.0, 0.0, &mut rng);
        m.redeploy(100_000.0, 0.88);
        assert_eq!(m.version, 2);
        assert_eq!(m.performance, 0.88);
        assert_eq!(m.drift, 0.0);
        assert!(!m.retraining);
    }
}
