//! Run-time view: deployed models, drift processes, detectors, and the
//! retraining trigger strategies (paper sections III-A, IV-A2, Fig 7).
//!
//! A deployed model's performance p(M) degrades over time — gradual decay
//! plus sudden concept-drift events (Fig 2). A detector evaluates each
//! model periodically; when the configured trigger rule fires, a
//! retraining pipeline is scheduled. The *policy* deciding when to fire
//! is the operational strategy under study (Fig 4) — it is a pluggable
//! [`RetrainTrigger`] trait, registered by name in
//! [`super::strategy`] and selectable from JSON config, the sweep grid,
//! and the CLI without recompiling.

use crate::des::SimTime;
use crate::empirical::db::hour_of_week;
use crate::empirical::GroundTruth;
use crate::stats::rng::Pcg64;

/// Everything a trigger decision may inspect about one deployed model at
/// a detector tick.
#[derive(Clone, Copy, Debug)]
pub struct TriggerCtx {
    /// Current simulation time.
    pub now: SimTime,
    /// Accumulated drift metric (detector output).
    pub drift: f64,
    /// Current composite performance p(M).
    pub performance: f64,
    /// Performance at (re)deployment.
    pub initial_performance: f64,
    /// When this version was deployed.
    pub deployed_at: SimTime,
    /// Version in the retraining lineage.
    pub version: u32,
}

/// When does a drifting model get retrained?
///
/// Implementations may be stateful (`&mut self`); each simulation run
/// owns its trigger exclusively. Decisions must be deterministic — a pure
/// function of internal state and the [`TriggerCtx`].
pub trait RetrainTrigger: Send {
    /// Registry/display name of the strategy.
    fn name(&self) -> &'static str;

    /// Decide at a detector tick: `None` = don't retrain, `Some(delay)`
    /// = schedule the retraining pipeline after `delay` seconds.
    fn decide(&mut self, ctx: &TriggerCtx) -> Option<SimTime>;
}

/// Retrain at every detector tick (the wasteful baseline the paper's
/// section III-B warns about).
#[derive(Clone, Copy, Debug, Default)]
pub struct Eager;

impl RetrainTrigger for Eager {
    fn name(&self) -> &'static str {
        "eager"
    }
    fn decide(&mut self, _ctx: &TriggerCtx) -> Option<SimTime> {
        Some(0.0)
    }
}

/// Never retrain (ablation lower bound).
#[derive(Clone, Copy, Debug, Default)]
pub struct Never;

impl RetrainTrigger for Never {
    fn name(&self) -> &'static str {
        "never"
    }
    fn decide(&mut self, _ctx: &TriggerCtx) -> Option<SimTime> {
        None
    }
}

/// Retrain when the drift metric exceeds a threshold (Fig 7's rule).
#[derive(Clone, Copy, Debug)]
pub struct DriftThreshold {
    pub threshold: f64,
}

impl RetrainTrigger for DriftThreshold {
    fn name(&self) -> &'static str {
        "drift_threshold"
    }
    fn decide(&mut self, ctx: &TriggerCtx) -> Option<SimTime> {
        (ctx.drift >= self.threshold).then_some(0.0)
    }
}

/// Drift threshold + defer the launch into the next predicted low-load
/// hour (uses the arrival-profile intensity forecast).
#[derive(Clone, Copy, Debug)]
pub struct OffPeak {
    pub threshold: f64,
    /// Launch only in hours with forecast intensity below this.
    pub max_intensity: f64,
}

impl RetrainTrigger for OffPeak {
    fn name(&self) -> &'static str {
        "off_peak"
    }
    fn decide(&mut self, ctx: &TriggerCtx) -> Option<SimTime> {
        if ctx.drift < self.threshold {
            return None;
        }
        Some(delay_to_off_peak(ctx.now, self.max_intensity))
    }
}

/// Retrain when absolute performance falls below a floor — an SLO-style
/// rule the drift-based policies cannot express: a model deployed at
/// mediocre quality trips it immediately, while a strong model tolerates
/// a lot of drift before breaching.
#[derive(Clone, Copy, Debug)]
pub struct PerformanceFloor {
    pub floor: f64,
}

impl RetrainTrigger for PerformanceFloor {
    fn name(&self) -> &'static str {
        "performance_floor"
    }
    fn decide(&mut self, ctx: &TriggerCtx) -> Option<SimTime> {
        (ctx.performance < self.floor).then_some(0.0)
    }
}

/// Retrain on a fixed cadence since (re)deployment, regardless of drift
/// (the calendar-driven policy many production platforms actually run).
#[derive(Clone, Copy, Debug)]
pub struct Periodic {
    /// Seconds between retrains of one model.
    pub interval: f64,
}

impl RetrainTrigger for Periodic {
    fn name(&self) -> &'static str {
        "periodic"
    }
    fn decide(&mut self, ctx: &TriggerCtx) -> Option<SimTime> {
        (ctx.now - ctx.deployed_at >= self.interval).then_some(0.0)
    }
}

/// Seconds until the next hour whose forecast arrival intensity is below
/// `max_intensity` (0 if the current hour already is).
pub fn delay_to_off_peak(t: SimTime, max_intensity: f64) -> SimTime {
    for ahead in 0..168 {
        let how = (hour_of_week(t) + ahead) % 168;
        if GroundTruth::intensity(how) <= max_intensity {
            if ahead == 0 {
                return 0.0;
            }
            // start of that hour
            let hour_start = (t / 3600.0).floor() * 3600.0 + ahead as f64 * 3600.0;
            return hour_start - t;
        }
    }
    0.0 // no hour qualifies: fire now rather than starve
}

/// A deployed model being monitored by the run-time view.
#[derive(Clone, Debug)]
pub struct DeployedModel {
    pub model_id: u64,
    pub framework: crate::model::Framework,
    /// Performance at deployment.
    pub initial_performance: f64,
    /// Current composite performance p(M).
    pub performance: f64,
    /// Accumulated drift metric (detector output).
    pub drift: f64,
    pub deployed_at: SimTime,
    pub last_tick: SimTime,
    /// Version in the retraining lineage.
    pub version: u32,
    /// Is a retraining for this model already in flight?
    pub retraining: bool,
}

impl DeployedModel {
    pub fn new(
        model_id: u64,
        framework: crate::model::Framework,
        performance: f64,
        t: SimTime,
        version: u32,
    ) -> Self {
        DeployedModel {
            model_id,
            framework,
            initial_performance: performance,
            performance,
            drift: 0.0,
            deployed_at: t,
            last_tick: t,
            version,
            retraining: false,
        }
    }

    /// The trigger's view of this model at detector time `t`.
    pub fn trigger_ctx(&self, t: SimTime) -> TriggerCtx {
        TriggerCtx {
            now: t,
            drift: self.drift,
            performance: self.performance,
            initial_performance: self.initial_performance,
            deployed_at: self.deployed_at,
            version: self.version,
        }
    }

    /// Advance the drift process to time `t` (one detector tick):
    /// gradual decay + stochastic sudden drops + detector noise.
    pub fn tick(
        &mut self,
        t: SimTime,
        decay_per_day: f64,
        sudden_prob: f64,
        sudden_drop: f64,
        rng: &mut Pcg64,
    ) {
        let dt_days = (t - self.last_tick) / 86_400.0;
        self.last_tick = t;
        let mut drop = decay_per_day * dt_days;
        if rng.uniform() < sudden_prob {
            drop += sudden_drop * (0.5 + rng.uniform());
        }
        self.performance = (self.performance - drop).max(0.0);
        // detector measures staleness with a little observation noise
        let staleness = (self.initial_performance - self.performance).max(0.0);
        self.drift = (staleness + 0.005 * rng.normal()).max(0.0);
    }

    /// Refresh after a completed retraining deployment.
    pub fn redeploy(&mut self, t: SimTime, performance: f64) {
        self.version += 1;
        self.initial_performance = performance;
        self.performance = performance;
        self.drift = 0.0;
        self.deployed_at = t;
        self.last_tick = t;
        self.retraining = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Framework;

    fn ctx(now: SimTime, drift: f64) -> TriggerCtx {
        TriggerCtx {
            now,
            drift,
            performance: 0.8,
            initial_performance: 0.9,
            deployed_at: 0.0,
            version: 1,
        }
    }

    #[test]
    fn eager_always_fires() {
        assert_eq!(Eager.decide(&ctx(0.0, 0.0)), Some(0.0));
    }

    #[test]
    fn never_never_fires() {
        assert_eq!(Never.decide(&ctx(0.0, 9.9)), None);
    }

    #[test]
    fn threshold_gates_on_drift() {
        let mut p = DriftThreshold { threshold: 0.05 };
        assert_eq!(p.decide(&ctx(0.0, 0.01)), None);
        assert_eq!(p.decide(&ctx(0.0, 0.08)), Some(0.0));
    }

    #[test]
    fn off_peak_defers_to_quiet_hours() {
        let mut p = OffPeak {
            threshold: 0.05,
            max_intensity: 0.5,
        };
        // Monday 16:00 is the peak -> must defer
        let t_peak = 16.0 * 3600.0;
        let delay = p.decide(&ctx(t_peak, 0.10)).unwrap();
        assert!(delay > 0.0, "must defer from the peak hour");
        // landing hour must be quiet
        let landing = hour_of_week(t_peak + delay);
        assert!(GroundTruth::intensity(landing) <= 0.5);
        // Monday 03:00 is already quiet -> immediate
        assert_eq!(p.decide(&ctx(3.0 * 3600.0, 0.10)), Some(0.0));
    }

    #[test]
    fn performance_floor_ignores_drift() {
        let mut p = PerformanceFloor { floor: 0.7 };
        // drifted a lot but still above the floor: no retrain
        let mut c = ctx(0.0, 0.5);
        c.performance = 0.75;
        assert_eq!(p.decide(&c), None);
        // below the floor: retrain even with zero drift
        c.performance = 0.69;
        c.drift = 0.0;
        assert_eq!(p.decide(&c), Some(0.0));
    }

    #[test]
    fn periodic_fires_on_model_age() {
        let mut p = Periodic { interval: 1000.0 };
        let mut c = ctx(999.0, 0.0);
        assert_eq!(p.decide(&c), None);
        c.now = 1000.0;
        assert_eq!(p.decide(&c), Some(0.0));
        // a redeploy resets the clock via deployed_at
        c.deployed_at = 800.0;
        assert_eq!(p.decide(&c), None);
    }

    #[test]
    fn deployed_model_exposes_trigger_ctx() {
        let m = DeployedModel::new(1, Framework::SparkML, 0.9, 5.0, 3);
        let c = m.trigger_ctx(42.0);
        assert_eq!(c.now, 42.0);
        assert_eq!(c.deployed_at, 5.0);
        assert_eq!(c.version, 3);
        assert_eq!(c.performance, 0.9);
    }

    #[test]
    fn drift_process_decays_performance() {
        let mut m = DeployedModel::new(1, Framework::TensorFlow, 0.9, 0.0, 1);
        let mut rng = Pcg64::new(1);
        // 30 days of 6-hour ticks with no sudden drift
        for i in 1..=120 {
            m.tick(i as f64 * 21_600.0, 0.004, 0.0, 0.0, &mut rng);
        }
        let expected = 0.9 - 0.004 * 30.0;
        assert!((m.performance - expected).abs() < 1e-9);
        assert!(m.drift > 0.05, "drift metric accumulated: {}", m.drift);
    }

    #[test]
    fn sudden_drift_drops_fast() {
        let mut m = DeployedModel::new(1, Framework::SparkML, 0.9, 0.0, 1);
        let mut rng = Pcg64::new(2);
        m.tick(3600.0, 0.0, 1.0, 0.1, &mut rng); // forced sudden event
        assert!(m.performance < 0.86);
    }

    #[test]
    fn redeploy_resets() {
        let mut m = DeployedModel::new(1, Framework::SparkML, 0.9, 0.0, 1);
        let mut rng = Pcg64::new(3);
        m.tick(86_400.0, 0.05, 0.0, 0.0, &mut rng);
        m.redeploy(100_000.0, 0.88);
        assert_eq!(m.version, 2);
        assert_eq!(m.performance, 0.88);
        assert_eq!(m.drift, 0.0);
        assert!(!m.retraining);
    }
}
