//! Descriptive statistics: quantiles, ECDF, histograms, Q-Q data, KS/SSE.
//!
//! These back both the fitting pipeline (SSE model selection, section V-A3)
//! and the accuracy analytics (Q-Q plots of Fig 12).

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance; 0 for n < 2.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

/// Sample standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Sort a copy ascending (NaNs must not be present).
pub fn sorted(xs: &[f64]) -> Vec<f64> {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in data"));
    v
}

/// Linear-interpolated quantile of *sorted* data, p in [0,1] (type-7, the
/// numpy/R default).
pub fn quantile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&p));
    let h = p * (sorted.len() - 1) as f64;
    let lo = h.floor() as usize;
    let hi = h.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (h - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

/// Quantile of unsorted data.
pub fn quantile(xs: &[f64], p: f64) -> f64 {
    quantile_sorted(&sorted(xs), p)
}

/// `n` evenly spaced quantiles (excluding the exact 0/1 endpoints) — the
/// axes of a Q-Q plot.
pub fn quantiles(xs: &[f64], n: usize) -> Vec<f64> {
    let s = sorted(xs);
    (1..=n)
        .map(|i| quantile_sorted(&s, i as f64 / (n + 1) as f64))
        .collect()
}

/// Paired quantiles of two samples: the Q-Q plot of `a` (x-axis,
/// "empirical") against `b` (y-axis, "simulated").
pub fn qq_points(a: &[f64], b: &[f64], n: usize) -> Vec<(f64, f64)> {
    quantiles(a, n).into_iter().zip(quantiles(b, n)).collect()
}

/// Two-sample Kolmogorov–Smirnov distance.
pub fn ks_distance(a: &[f64], b: &[f64]) -> f64 {
    let sa = sorted(a);
    let sb = sorted(b);
    let (na, nb) = (sa.len() as f64, sb.len() as f64);
    let (mut i, mut j) = (0usize, 0usize);
    let mut d: f64 = 0.0;
    while i < sa.len() && j < sb.len() {
        let (fa, fb) = (i as f64 / na, j as f64 / nb);
        d = d.max((fa - fb).abs());
        if sa[i] <= sb[j] {
            i += 1;
        } else {
            j += 1;
        }
    }
    // account for the unconsumed tail of either sample
    d = d.max(((sa.len() as f64 / na) - (j as f64 / nb)).abs());
    d = d.max(((i as f64 / na) - (sb.len() as f64 / nb)).abs());
    d
}

/// Equal-width histogram over [lo, hi]; returns (bin_centers, counts).
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> (Vec<f64>, Vec<usize>) {
    assert!(bins > 0 && hi > lo);
    let w = (hi - lo) / bins as f64;
    let mut counts = vec![0usize; bins];
    for &x in xs {
        if x >= lo && x < hi {
            counts[((x - lo) / w) as usize] += 1;
        } else if (x - hi).abs() < 1e-12 {
            counts[bins - 1] += 1;
        }
    }
    let centers = (0..bins).map(|i| lo + (i as f64 + 0.5) * w).collect();
    (centers, counts)
}

/// Normalized histogram as an empirical density; returns (centers, density).
pub fn density_histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> (Vec<f64>, Vec<f64>) {
    let (centers, counts) = histogram(xs, lo, hi, bins);
    let w = (hi - lo) / bins as f64;
    let total: usize = counts.iter().sum();
    let dens = counts
        .iter()
        .map(|&c| {
            if total == 0 {
                0.0
            } else {
                c as f64 / (total as f64 * w)
            }
        })
        .collect();
    (centers, dens)
}

/// Sum of squared errors between an empirical density histogram and a
/// model pdf evaluated at bin centers — the paper's fit-selection
/// criterion for the 168 arrival clusters (section V-A3).
pub fn sse_against_pdf(xs: &[f64], pdf: impl Fn(f64) -> f64, bins: usize) -> f64 {
    if xs.is_empty() {
        return f64::INFINITY;
    }
    let s = sorted(xs);
    let lo = s[0];
    let hi = s[s.len() - 1];
    if hi <= lo {
        return f64::INFINITY;
    }
    let (centers, dens) = density_histogram(xs, lo, hi, bins);
    centers
        .iter()
        .zip(&dens)
        .map(|(&c, &d)| {
            let e = d - pdf(c);
            e * e
        })
        .sum()
}

/// Pearson correlation coefficient.
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (ma, mb) = (mean(a), mean(b));
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (&x, &y) in a.iter().zip(b) {
        cov += (x - ma) * (y - mb);
        va += (x - ma) * (x - ma);
        vb += (y - mb) * (y - mb);
    }
    if va == 0.0 || vb == 0.0 {
        0.0
    } else {
        cov / (va.sqrt() * vb.sqrt())
    }
}

/// Streaming mean/min/max/count accumulator (used by monitors and reports).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub count: u64,
    pub sum: f64,
    pub sum_sq: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary {
            count: 0,
            sum: 0.0,
            sum_sq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        self.sum_sq += x * x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Absorb another summary: counts and sums accumulate, extrema
    /// combine. `count`/`min`/`max` merge exactly in any order; the
    /// floating-point sums accumulate in call order, so replaying the
    /// same merge sequence is bit-identical (the sweep-shard merge
    /// contract: shards are re-merged in global cell order), while
    /// *different* merge orders agree only up to f64 rounding.
    pub fn merge_from(&mut self, other: &Summary) {
        if other.count == 0 {
            return;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.sum_sq += other.sum_sq;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        if self.count < 2 {
            return 0.0;
        }
        let m = self.mean();
        ((self.sum_sq / self.count as f64 - m * m).max(0.0)).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Pcg64;

    #[test]
    fn mean_var() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn quantile_interpolation() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(quantile(&xs, 0.5), 2.5);
    }

    #[test]
    fn qq_identical_samples_on_diagonal() {
        let mut rng = Pcg64::new(1);
        let xs: Vec<f64> = (0..10_000).map(|_| rng.normal()).collect();
        for (x, y) in qq_points(&xs, &xs, 20) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn ks_same_vs_shifted() {
        let mut rng = Pcg64::new(2);
        let a: Vec<f64> = (0..20_000).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..20_000).map(|_| rng.normal()).collect();
        let c: Vec<f64> = (0..20_000).map(|_| rng.normal() + 1.0).collect();
        assert!(ks_distance(&a, &b) < 0.02);
        assert!(ks_distance(&a, &c) > 0.3);
    }

    #[test]
    fn histogram_counts() {
        let xs = [0.5, 1.5, 1.6, 2.5, 3.0];
        let (centers, counts) = histogram(&xs, 0.0, 3.0, 3);
        assert_eq!(centers.len(), 3);
        assert_eq!(counts, vec![1, 2, 2]); // 3.0 lands in the last bin
    }

    #[test]
    fn density_integrates_to_one() {
        let mut rng = Pcg64::new(3);
        let xs: Vec<f64> = (0..50_000).map(|_| rng.normal()).collect();
        let (_, dens) = density_histogram(&xs, -5.0, 5.0, 100);
        let total: f64 = dens.iter().map(|d| d * 0.1).sum();
        assert!((total - 1.0).abs() < 0.01, "{total}");
    }

    #[test]
    fn sse_prefers_true_model() {
        use crate::stats::dist::{Distribution, LogNormal, Normal};
        let mut rng = Pcg64::new(4);
        let d = LogNormal::new(1.0, 0.5);
        let xs: Vec<f64> = (0..30_000).map(|_| d.sample(&mut rng)).collect();
        let sse_true = sse_against_pdf(&xs, |x| d.pdf(x), 50);
        let wrong = Normal::new(mean(&xs), std_dev(&xs));
        let sse_wrong = sse_against_pdf(&xs, |x| wrong.pdf(x), 50);
        assert!(sse_true < sse_wrong, "{sse_true} !< {sse_wrong}");
    }

    #[test]
    fn pearson_perfect_and_none() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-12);
        let c = [1.0, -1.0, 1.0, -1.0];
        assert!(pearson(&a, &c).abs() < 0.5);
    }

    #[test]
    fn summary_accumulator() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0] {
            s.add(x);
        }
        assert_eq!(s.count, 3);
        assert_eq!(s.mean(), 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.std_dev() - (2.0f64 / 3.0).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn summary_merge_matches_sequential_adds() {
        // merging per-point summaries in add order is bit-identical to
        // the sequential add() path — the shard-merge determinism anchor
        let xs = [3.25, -1.5, 7.0, 0.125, 42.0, -0.0];
        let mut seq = Summary::new();
        let mut merged = Summary::new();
        for &x in &xs {
            seq.add(x);
            let mut one = Summary::new();
            one.add(x);
            merged.merge_from(&one);
        }
        assert_eq!(seq.count, merged.count);
        assert_eq!(seq.sum.to_bits(), merged.sum.to_bits());
        assert_eq!(seq.sum_sq.to_bits(), merged.sum_sq.to_bits());
        assert_eq!(seq.min.to_bits(), merged.min.to_bits());
        assert_eq!(seq.max.to_bits(), merged.max.to_bits());
        // merging an empty summary is a no-op either way
        let before = merged.sum.to_bits();
        merged.merge_from(&Summary::new());
        assert_eq!(merged.sum.to_bits(), before);
        assert_eq!(merged.count, 6);
        let mut empty = Summary::new();
        empty.merge_from(&seq);
        assert_eq!(empty.count, seq.count);
        assert_eq!(empty.min, seq.min);
        assert_eq!(empty.max, seq.max);
    }
}
