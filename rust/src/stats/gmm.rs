//! Gaussian mixture models: parameter containers shared with the AOT
//! runtime, plus a pure-Rust EM fitter/sampler that serves as (a) the
//! CPU baseline the benches compare the PJRT path against and (b) the
//! fallback when `artifacts/` are not built (unit tests, CI).
//!
//! Shapes mirror the AOT modules: the 3-D mixture is full-covariance
//! (paper section V-A1, 50 components over log(rows, cols, bytes)); the
//! 1-D mixtures model log-durations (section V-A2b/c).

use super::rng::Pcg64;
use crate::error::{Error, Result};

pub const LOG_2PI: f64 = 1.837_877_066_409_345_3;

// ---------------------------------------------------------------------------
// 3-D full covariance mixture
// ---------------------------------------------------------------------------

/// Parameters of a K-component full-covariance 3-D Gaussian mixture.
///
/// `pchol` is the lower-triangular inverse of the covariance Cholesky
/// factor (so the precision is `pchol^T pchol`), matching the AOT kernel's
/// convention; `cchol` is the covariance Cholesky factor used for sampling.
#[derive(Clone, Debug)]
pub struct Gmm3 {
    pub logw: Vec<f64>,            // K
    pub mu: Vec<[f64; 3]>,         // K
    pub cchol: Vec<[[f64; 3]; 3]>, // K, lower
    pub pchol: Vec<[[f64; 3]; 3]>, // K, lower
}

/// Closed-form Cholesky of a 3x3 SPD matrix (lower factor).
pub fn chol3(a: &[[f64; 3]; 3]) -> Result<[[f64; 3]; 3]> {
    let l11 = a[0][0];
    if l11 <= 0.0 {
        return Err(Error::Stats("chol3: not SPD".into()));
    }
    let l11 = l11.sqrt();
    let l21 = a[1][0] / l11;
    let l31 = a[2][0] / l11;
    let d22 = a[1][1] - l21 * l21;
    if d22 <= 0.0 {
        return Err(Error::Stats("chol3: not SPD".into()));
    }
    let l22 = d22.sqrt();
    let l32 = (a[2][1] - l31 * l21) / l22;
    let d33 = a[2][2] - l31 * l31 - l32 * l32;
    if d33 <= 0.0 {
        return Err(Error::Stats("chol3: not SPD".into()));
    }
    Ok([
        [l11, 0.0, 0.0],
        [l21, l22, 0.0],
        [l31, l32, d33.sqrt()],
    ])
}

/// Closed-form inverse of a lower-triangular 3x3 matrix.
pub fn tril3_inv(l: &[[f64; 3]; 3]) -> [[f64; 3]; 3] {
    let i11 = 1.0 / l[0][0];
    let i22 = 1.0 / l[1][1];
    let i33 = 1.0 / l[2][2];
    let i21 = -l[1][0] * i11 * i22;
    let i31 = (l[1][0] * l[2][1] - l[1][1] * l[2][0]) * i11 * i22 * i33;
    let i32 = -l[2][1] * i22 * i33;
    [[i11, 0.0, 0.0], [i21, i22, 0.0], [i31, i32, i33]]
}

impl Gmm3 {
    pub fn k(&self) -> usize {
        self.logw.len()
    }

    /// k-means++ init (scikit-learn's default for `GaussianMixture`):
    /// means at k-means centers, spherical covariance from the data
    /// spread. Falls back to the same covariance logic as the random
    /// init, which EM then refines.
    pub fn init_from_data(x: &[[f64; 3]], k: usize, rng: &mut Pcg64) -> Self {
        assert!(x.len() >= k);
        // subsample for seeding cost on large inputs
        let seed_rows: Vec<Vec<f64>> = if x.len() > 4096 {
            rng.sample_indices(x.len(), 4096)
                .into_iter()
                .map(|i| x[i].to_vec())
                .collect()
        } else {
            x.iter().map(|r| r.to_vec()).collect()
        };
        let (centers, _) = super::kmeans::kmeans(&seed_rows, k, rng, 10);
        let mut g = Self::init_random(x, k, rng);
        for (m, c) in g.mu.iter_mut().zip(&centers) {
            *m = [c[0], c[1], c[2]];
        }
        g
    }

    /// Random-row init: means at k random rows, identity-ish covariance
    /// scaled to the data spread (the cheap baseline).
    pub fn init_random(x: &[[f64; 3]], k: usize, rng: &mut Pcg64) -> Self {
        assert!(x.len() >= k);
        let idx = rng.sample_indices(x.len(), k);
        let mut var = [0.0f64; 3];
        let mut m = [0.0f64; 3];
        for r in x {
            for d in 0..3 {
                m[d] += r[d];
            }
        }
        for d in 0..3 {
            m[d] /= x.len() as f64;
        }
        for r in x {
            for d in 0..3 {
                var[d] += (r[d] - m[d]) * (r[d] - m[d]);
            }
        }
        for d in 0..3 {
            var[d] = (var[d] / x.len() as f64).max(1e-3);
        }
        let logw = vec![-(k as f64).ln(); k];
        let mu: Vec<[f64; 3]> = idx.iter().map(|&i| x[i]).collect();
        let mut cchol = Vec::with_capacity(k);
        let mut pchol = Vec::with_capacity(k);
        for _ in 0..k {
            let mut c = [[0.0; 3]; 3];
            for d in 0..3 {
                c[d][d] = var[d].sqrt();
            }
            cchol.push(c);
            pchol.push(tril3_inv(&c));
        }
        Gmm3 { logw, mu, cchol, pchol }
    }

    /// Log joint density log w_k + log N(x | mu_k, Sigma_k) for one point.
    pub fn log_joint(&self, x: &[f64; 3]) -> Vec<f64> {
        (0..self.k())
            .map(|k| {
                let p = &self.pchol[k];
                let m = &self.mu[k];
                let d = [x[0] - m[0], x[1] - m[1], x[2] - m[2]];
                // y = pchol * d (lower-tri)
                let y0 = p[0][0] * d[0];
                let y1 = p[1][0] * d[0] + p[1][1] * d[1];
                let y2 = p[2][0] * d[0] + p[2][1] * d[1] + p[2][2] * d[2];
                let maha = y0 * y0 + y1 * y1 + y2 * y2;
                let logdet = p[0][0].abs().ln() + p[1][1].abs().ln() + p[2][2].abs().ln();
                self.logw[k] + logdet - 1.5 * LOG_2PI - 0.5 * maha
            })
            .collect()
    }

    /// Total log-likelihood of a dataset.
    pub fn loglik(&self, x: &[[f64; 3]]) -> f64 {
        x.iter()
            .map(|r| {
                let lp = self.log_joint(r);
                log_sum_exp(&lp)
            })
            .sum()
    }

    /// One EM iteration in pure Rust. Returns the pre-step log-likelihood.
    /// This is the CPU baseline mirroring the AOT `gmm_em_step3` artifact.
    pub fn em_step(&mut self, x: &[[f64; 3]]) -> Result<f64> {
        let n = x.len();
        let k = self.k();
        let mut nk = vec![1e-8f64; k];
        let mut sum_x = vec![[0.0f64; 3]; k];
        let mut sum_xx = vec![[[0.0f64; 3]; 3]; k];
        let mut total_ll = 0.0;
        let mut resp = vec![0.0f64; k];
        for r in x {
            let lp = self.log_joint(r);
            let lse = log_sum_exp(&lp);
            total_ll += lse;
            for j in 0..k {
                resp[j] = (lp[j] - lse).exp();
            }
            for j in 0..k {
                let w = resp[j];
                nk[j] += w;
                for d in 0..3 {
                    sum_x[j][d] += w * r[d];
                }
                for d in 0..3 {
                    for e in 0..=d {
                        sum_xx[j][d][e] += w * r[d] * r[e];
                    }
                }
            }
        }
        for j in 0..k {
            self.logw[j] = nk[j].ln() - (n as f64).ln();
            let mut mu = [0.0; 3];
            for d in 0..3 {
                mu[d] = sum_x[j][d] / nk[j];
            }
            self.mu[j] = mu;
            let mut cov = [[0.0; 3]; 3];
            for d in 0..3 {
                for e in 0..=d {
                    let c = sum_xx[j][d][e] / nk[j] - mu[d] * mu[e];
                    cov[d][e] = c;
                    cov[e][d] = c;
                }
                cov[d][d] += 1e-4; // regularizer, matches the AOT module
            }
            let c = chol3(&cov)?;
            self.cchol[j] = c;
            self.pchol[j] = tril3_inv(&c);
        }
        Ok(total_ll)
    }

    /// Fit by EM from a fresh init until the relative log-lik improvement
    /// drops below `tol` or `max_iter` is reached. Returns final loglik.
    pub fn fit(x: &[[f64; 3]], k: usize, rng: &mut Pcg64, max_iter: usize, tol: f64) -> Result<(Self, f64)> {
        let mut g = Self::init_from_data(x, k, rng);
        let mut prev = f64::NEG_INFINITY;
        let mut ll = prev;
        for _ in 0..max_iter {
            ll = g.em_step(x)?;
            if (ll - prev).abs() < tol * (1.0 + ll.abs()) {
                break;
            }
            prev = ll;
        }
        Ok((g, ll))
    }

    /// Draw one sample: pick a component, then mu + cchol * z.
    pub fn sample(&self, rng: &mut Pcg64) -> [f64; 3] {
        let w: Vec<f64> = self.logw.iter().map(|l| l.exp()).collect();
        let k = rng.categorical(&w);
        self.sample_component(k, rng)
    }

    /// Sample from a fixed component.
    pub fn sample_component(&self, k: usize, rng: &mut Pcg64) -> [f64; 3] {
        let z = [rng.normal(), rng.normal(), rng.normal()];
        let c = &self.cchol[k];
        let m = &self.mu[k];
        [
            m[0] + c[0][0] * z[0],
            m[1] + c[1][0] * z[0] + c[1][1] * z[1],
            m[2] + c[2][0] * z[0] + c[2][1] * z[1] + c[2][2] * z[2],
        ]
    }
}

// ---------------------------------------------------------------------------
// 1-D mixture
// ---------------------------------------------------------------------------

/// K-component 1-D Gaussian mixture (log-duration models).
#[derive(Clone, Debug)]
pub struct Gmm1 {
    pub logw: Vec<f64>,
    pub mu: Vec<f64>,
    pub logsd: Vec<f64>,
}

impl Gmm1 {
    pub fn k(&self) -> usize {
        self.logw.len()
    }

    pub fn init_from_data(x: &[f64], k: usize, rng: &mut Pcg64) -> Self {
        assert!(x.len() >= k);
        let idx = rng.sample_indices(x.len(), k);
        let sd = super::desc::std_dev(x).max(1e-3);
        Gmm1 {
            logw: vec![-(k as f64).ln(); k],
            mu: idx.iter().map(|&i| x[i]).collect(),
            logsd: vec![sd.ln(); k],
        }
    }

    pub fn log_joint(&self, x: f64) -> Vec<f64> {
        (0..self.k())
            .map(|k| {
                let z = (x - self.mu[k]) * (-self.logsd[k]).exp();
                self.logw[k] - self.logsd[k] - 0.5 * LOG_2PI - 0.5 * z * z
            })
            .collect()
    }

    pub fn loglik(&self, x: &[f64]) -> f64 {
        x.iter().map(|&v| log_sum_exp(&self.log_joint(v))).sum()
    }

    /// One EM iteration (CPU baseline of `gmm_em_step1`).
    pub fn em_step(&mut self, x: &[f64]) -> f64 {
        let n = x.len();
        let k = self.k();
        let mut nk = vec![1e-8f64; k];
        let mut s1 = vec![0.0f64; k];
        let mut s2 = vec![0.0f64; k];
        let mut total_ll = 0.0;
        for &v in x {
            let lp = self.log_joint(v);
            let lse = log_sum_exp(&lp);
            total_ll += lse;
            for j in 0..k {
                let w = (lp[j] - lse).exp();
                nk[j] += w;
                s1[j] += w * v;
                s2[j] += w * v * v;
            }
        }
        for j in 0..k {
            self.logw[j] = nk[j].ln() - (n as f64).ln();
            let mu = s1[j] / nk[j];
            self.mu[j] = mu;
            let var = (s2[j] / nk[j] - mu * mu).max(0.0) + 1e-4;
            self.logsd[j] = 0.5 * var.ln();
        }
        total_ll
    }

    pub fn fit(x: &[f64], k: usize, rng: &mut Pcg64, max_iter: usize, tol: f64) -> (Self, f64) {
        let mut g = Self::init_from_data(x, k, rng);
        let mut prev = f64::NEG_INFINITY;
        let mut ll = prev;
        for _ in 0..max_iter {
            ll = g.em_step(x);
            if (ll - prev).abs() < tol * (1.0 + ll.abs()) {
                break;
            }
            prev = ll;
        }
        (g, ll)
    }

    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        let w: Vec<f64> = self.logw.iter().map(|l| l.exp()).collect();
        let k = rng.categorical(&w);
        self.mu[k] + self.logsd[k].exp() * rng.normal()
    }

    /// Mixture mean.
    pub fn mean(&self) -> f64 {
        self.logw
            .iter()
            .zip(&self.mu)
            .map(|(lw, m)| lw.exp() * m)
            .sum()
    }
}

/// Numerically stable log(sum(exp(xs))).
pub fn log_sum_exp(xs: &[f64]) -> f64 {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if !m.is_finite() {
        return m;
    }
    m + xs.iter().map(|x| (x - m).exp()).sum::<f64>().ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn true_gmm3() -> Gmm3 {
        let c1 = [[1.0, 0.0, 0.0], [0.3, 0.8, 0.0], [0.1, -0.2, 0.6]];
        let c2 = [[0.5, 0.0, 0.0], [-0.2, 0.9, 0.0], [0.0, 0.3, 0.7]];
        Gmm3 {
            logw: vec![0.6f64.ln(), 0.4f64.ln()],
            mu: vec![[-3.0, 0.0, 2.0], [3.0, 4.0, -2.0]],
            pchol: vec![tril3_inv(&c1), tril3_inv(&c2)],
            cchol: vec![c1, c2],
        }
    }

    #[test]
    fn chol3_roundtrip() {
        let a = [[4.0, 2.0, 0.6], [2.0, 5.0, 1.0], [0.6, 1.0, 3.0]];
        let l = chol3(&a).unwrap();
        // L L^T == a
        for i in 0..3 {
            for j in 0..3 {
                let mut s = 0.0;
                for k in 0..3 {
                    s += l[i][k] * l[j][k];
                }
                assert!((s - a[i][j]).abs() < 1e-12, "({i},{j})");
            }
        }
        let inv = tril3_inv(&l);
        // inv * l == I
        for i in 0..3 {
            for j in 0..3 {
                let mut s = 0.0;
                for k in 0..3 {
                    s += inv[i][k] * l[k][j];
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((s - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn chol3_rejects_non_spd() {
        let a = [[1.0, 0.0, 0.0], [0.0, -1.0, 0.0], [0.0, 0.0, 1.0]];
        assert!(chol3(&a).is_err());
    }

    #[test]
    fn gmm3_em_recovers_means() {
        let truth = true_gmm3();
        let mut rng = Pcg64::new(1);
        let x: Vec<[f64; 3]> = (0..4000).map(|_| truth.sample(&mut rng)).collect();
        let (fit, _) = Gmm3::fit(&x, 2, &mut rng, 100, 1e-8).unwrap();
        // match components by nearest mean
        for (tm, tw) in truth.mu.iter().zip(&truth.logw) {
            let (j, dist) = fit
                .mu
                .iter()
                .enumerate()
                .map(|(j, m)| {
                    let d: f64 = (0..3).map(|d| (m[d] - tm[d]).powi(2)).sum();
                    (j, d.sqrt())
                })
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap();
            assert!(dist < 0.2, "mean {tm:?} off by {dist}");
            assert!((fit.logw[j].exp() - tw.exp()).abs() < 0.05);
        }
    }

    #[test]
    fn gmm3_em_monotone_loglik() {
        let truth = true_gmm3();
        let mut rng = Pcg64::new(2);
        let x: Vec<[f64; 3]> = (0..2000).map(|_| truth.sample(&mut rng)).collect();
        let mut g = Gmm3::init_from_data(&x, 4, &mut rng);
        let mut prev = f64::NEG_INFINITY;
        for i in 0..30 {
            let ll = g.em_step(&x).unwrap();
            if i > 1 {
                assert!(ll >= prev - 1e-6 * prev.abs(), "iter {i}: {ll} < {prev}");
            }
            prev = ll;
        }
    }

    #[test]
    fn gmm3_sample_moments() {
        let truth = true_gmm3();
        let mut rng = Pcg64::new(3);
        let n = 100_000;
        let mut m = [0.0f64; 3];
        for _ in 0..n {
            let s = truth.sample(&mut rng);
            for d in 0..3 {
                m[d] += s[d];
            }
        }
        for d in 0..3 {
            m[d] /= n as f64;
        }
        let want = [
            0.6 * -3.0 + 0.4 * 3.0,
            0.6 * 0.0 + 0.4 * 4.0,
            0.6 * 2.0 + 0.4 * -2.0,
        ];
        for d in 0..3 {
            assert!((m[d] - want[d]).abs() < 0.05, "dim {d}: {} vs {}", m[d], want[d]);
        }
    }

    #[test]
    fn gmm1_em_recovers_bimodal() {
        let mut rng = Pcg64::new(4);
        let x: Vec<f64> = (0..8000)
            .map(|i| {
                if i % 5 < 3 {
                    2.0 + 0.5 * rng.normal()
                } else {
                    7.0 + 1.0 * rng.normal()
                }
            })
            .collect();
        let (fit, _) = Gmm1::fit(&x, 2, &mut rng, 200, 1e-10);
        let mut mus = fit.mu.clone();
        mus.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((mus[0] - 2.0).abs() < 0.1, "{mus:?}");
        assert!((mus[1] - 7.0).abs() < 0.1, "{mus:?}");
    }

    #[test]
    fn gmm1_mean() {
        let g = Gmm1 {
            logw: vec![0.25f64.ln(), 0.75f64.ln()],
            mu: vec![0.0, 4.0],
            logsd: vec![0.0, 0.0],
        };
        assert!((g.mean() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn lse_stable() {
        assert!((log_sum_exp(&[0.0, 0.0]) - 2.0f64.ln()).abs() < 1e-12);
        assert!((log_sum_exp(&[-1000.0, -1000.0]) - (-1000.0 + 2.0f64.ln())).abs() < 1e-9);
        assert_eq!(log_sum_exp(&[f64::NEG_INFINITY]), f64::NEG_INFINITY);
    }
}
