//! Deterministic PCG64 random number generator.
//!
//! One seedable stream drives the entire simulation (both the Rust-side
//! draws and the uniforms/normals fed to the AOT artifacts), so every
//! experiment is reproducible from a single `seed` in the config.

/// PCG-XSL-RR 128/64 (the reference `pcg64` variant of O'Neill's PCG).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Cached second normal from the Box–Muller pair.
    cached_normal: Option<f64>,
    /// Raw 64-bit outputs drawn so far, including the two
    /// initialization draws (SimMeter accounting; never affects the
    /// stream itself).
    draws: u64,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed; distinct seeds give distinct streams.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator with an explicit stream selector, used to derive
    /// independent sub-streams (e.g. one per distribution pool).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
            cached_normal: None,
            draws: 0,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Derive an independent sub-stream keyed by `tag`.
    pub fn substream(&mut self, tag: u64) -> Pcg64 {
        Pcg64::with_stream(self.next_u64(), tag.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.draws += 1;
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Raw 64-bit outputs drawn from this generator so far (including
    /// the two initialization draws of [`Pcg64::with_stream`]).
    pub fn draws(&self) -> u64 {
        self.draws
    }

    /// Uniform in [0, 1) with 53-bit precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in (0, 1] — safe to take `ln` of.
    #[inline]
    pub fn uniform_pos(&mut self) -> f64 {
        1.0 - self.uniform()
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here:
        // the statistical bias for n << 2^64 is immaterial to the simulator.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (pairs cached).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        let u1 = self.uniform_pos();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (std::f64::consts::TAU * u2).sin_cos();
        self.cached_normal = Some(r * s);
        r * c
    }

    /// Exponential with rate `lambda`.
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.uniform_pos().ln() / lambda
    }

    /// Fill `buf` with uniforms in [0,1) as f32 (artifact input feed).
    pub fn fill_uniform_f32(&mut self, buf: &mut [f32]) {
        for v in buf.iter_mut() {
            *v = self.uniform() as f32;
        }
    }

    /// Fill `buf` with standard normals as f32 (artifact input feed).
    pub fn fill_normal_f32(&mut self, buf: &mut [f32]) {
        for v in buf.iter_mut() {
            *v = self.normal() as f32;
        }
    }

    /// Draw an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Sample `k` distinct indices from [0, n) (Floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut rng = Pcg64::new(1);
        let n = 100_000;
        let mut mean = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            mean += u;
        }
        mean /= n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(2);
        let n = 200_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            m += z;
            v += z * z;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.01, "mean={m}");
        assert!((v - 1.0).abs() < 0.02, "var={v}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg64::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(0.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn categorical_frequencies() {
        let mut rng = Pcg64::new(4);
        let w = [1.0, 2.0, 7.0];
        let mut counts = [0usize; 3];
        for _ in 0..100_000 {
            counts[rng.categorical(&w)] += 1;
        }
        assert!((counts[2] as f64 / 100_000.0 - 0.7).abs() < 0.01);
        assert!((counts[0] as f64 / 100_000.0 - 0.1).abs() < 0.01);
    }

    #[test]
    fn substreams_are_independent() {
        let mut root = Pcg64::new(5);
        let mut s1 = root.substream(1);
        let mut s2 = root.substream(2);
        let a: Vec<u64> = (0..16).map(|_| s1.next_u64()).collect();
        let b: Vec<u64> = (0..16).map(|_| s2.next_u64()).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::new(6);
        let idx = rng.sample_indices(100, 50);
        let set: std::collections::HashSet<_> = idx.iter().collect();
        assert_eq!(set.len(), 50);
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn draw_counter_tracks_outputs() {
        let mut rng = Pcg64::new(8);
        let init = rng.draws();
        assert_eq!(init, 2, "with_stream performs two init draws");
        for _ in 0..10 {
            rng.next_u64();
        }
        assert_eq!(rng.draws(), init + 10);
        // the counter never perturbs the stream
        let mut a = Pcg64::new(9);
        let mut b = Pcg64::new(9);
        a.draws(); // read-only
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_bounds() {
        let mut rng = Pcg64::new(7);
        for _ in 0..10_000 {
            assert!(rng.below(13) < 13);
        }
    }
}
