//! Continuous distributions used by the simulator and the fitting pipeline.
//!
//! Each distribution implements [`Distribution`]: sampling (inverse-CDF
//! where closed-form, otherwise transform methods), density, CDF and
//! quantile function. The set mirrors the paper: log-normal,
//! exponentiated Weibull and Pareto for interarrivals (section V-A3),
//! plus Normal/Exponential/Weibull as building blocks.

use super::rng::Pcg64;

/// Common interface over the parametric families.
pub trait Distribution {
    /// Draw one sample.
    fn sample(&self, rng: &mut Pcg64) -> f64;
    /// Probability density at `x`.
    fn pdf(&self, x: f64) -> f64;
    /// Cumulative distribution at `x`.
    fn cdf(&self, x: f64) -> f64;
    /// Quantile (inverse CDF) at `p` in (0,1).
    fn quantile(&self, p: f64) -> f64;
    /// Log-likelihood of a dataset.
    fn loglik(&self, xs: &[f64]) -> f64 {
        xs.iter().map(|&x| self.pdf(x).max(1e-300).ln()).sum()
    }
}

/// A closed enum over the families so fitted models can be stored/serialized.
#[derive(Clone, Debug, PartialEq)]
pub enum Dist {
    Normal(Normal),
    LogNormal(LogNormal),
    Exponential(Exponential),
    Weibull(Weibull),
    ExpWeibull(ExpWeibull),
    Pareto(Pareto),
}

impl Dist {
    /// Short family name (used in fit-selection reports).
    pub fn name(&self) -> &'static str {
        match self {
            Dist::Normal(_) => "normal",
            Dist::LogNormal(_) => "lognormal",
            Dist::Exponential(_) => "exponential",
            Dist::Weibull(_) => "weibull",
            Dist::ExpWeibull(_) => "expweibull",
            Dist::Pareto(_) => "pareto",
        }
    }
}

impl Distribution for Dist {
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        match self {
            Dist::Normal(d) => d.sample(rng),
            Dist::LogNormal(d) => d.sample(rng),
            Dist::Exponential(d) => d.sample(rng),
            Dist::Weibull(d) => d.sample(rng),
            Dist::ExpWeibull(d) => d.sample(rng),
            Dist::Pareto(d) => d.sample(rng),
        }
    }
    fn pdf(&self, x: f64) -> f64 {
        match self {
            Dist::Normal(d) => d.pdf(x),
            Dist::LogNormal(d) => d.pdf(x),
            Dist::Exponential(d) => d.pdf(x),
            Dist::Weibull(d) => d.pdf(x),
            Dist::ExpWeibull(d) => d.pdf(x),
            Dist::Pareto(d) => d.pdf(x),
        }
    }
    fn cdf(&self, x: f64) -> f64 {
        match self {
            Dist::Normal(d) => d.cdf(x),
            Dist::LogNormal(d) => d.cdf(x),
            Dist::Exponential(d) => d.cdf(x),
            Dist::Weibull(d) => d.cdf(x),
            Dist::ExpWeibull(d) => d.cdf(x),
            Dist::Pareto(d) => d.cdf(x),
        }
    }
    fn quantile(&self, p: f64) -> f64 {
        match self {
            Dist::Normal(d) => d.quantile(p),
            Dist::LogNormal(d) => d.quantile(p),
            Dist::Exponential(d) => d.quantile(p),
            Dist::Weibull(d) => d.quantile(p),
            Dist::ExpWeibull(d) => d.quantile(p),
            Dist::Pareto(d) => d.quantile(p),
        }
    }
}

// ---------------------------------------------------------------------------

/// N(mu, sigma^2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Normal {
    pub mu: f64,
    pub sigma: f64,
}

impl Normal {
    pub fn new(mu: f64, sigma: f64) -> Self {
        assert!(sigma > 0.0, "sigma must be positive");
        Normal { mu, sigma }
    }
}

impl Distribution for Normal {
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        self.mu + self.sigma * rng.normal()
    }
    fn pdf(&self, x: f64) -> f64 {
        let z = (x - self.mu) / self.sigma;
        (-0.5 * z * z).exp() / (self.sigma * (2.0 * std::f64::consts::PI).sqrt())
    }
    fn cdf(&self, x: f64) -> f64 {
        0.5 * erfc(-(x - self.mu) / (self.sigma * std::f64::consts::SQRT_2))
    }
    fn quantile(&self, p: f64) -> f64 {
        self.mu + self.sigma * std_normal_quantile(p)
    }
}

/// ln X ~ N(mu, sigma^2), X > 0.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LogNormal {
    pub mu: f64,
    pub sigma: f64,
}

impl LogNormal {
    pub fn new(mu: f64, sigma: f64) -> Self {
        assert!(sigma > 0.0);
        LogNormal { mu, sigma }
    }
    pub fn mean(&self) -> f64 {
        (self.mu + 0.5 * self.sigma * self.sigma).exp()
    }
    pub fn median(&self) -> f64 {
        self.mu.exp()
    }
}

impl Distribution for LogNormal {
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        (self.mu + self.sigma * rng.normal()).exp()
    }
    fn pdf(&self, x: f64) -> f64 {
        if x <= 0.0 {
            return 0.0;
        }
        let z = (x.ln() - self.mu) / self.sigma;
        (-0.5 * z * z).exp()
            / (x * self.sigma * (2.0 * std::f64::consts::PI).sqrt())
    }
    fn cdf(&self, x: f64) -> f64 {
        if x <= 0.0 {
            return 0.0;
        }
        0.5 * erfc(-(x.ln() - self.mu) / (self.sigma * std::f64::consts::SQRT_2))
    }
    fn quantile(&self, p: f64) -> f64 {
        (self.mu + self.sigma * std_normal_quantile(p)).exp()
    }
}

/// Exp(lambda): f(x) = lambda e^{-lambda x}.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Exponential {
    pub lambda: f64,
}

impl Exponential {
    pub fn new(lambda: f64) -> Self {
        assert!(lambda > 0.0);
        Exponential { lambda }
    }
}

impl Distribution for Exponential {
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        rng.exponential(self.lambda)
    }
    fn pdf(&self, x: f64) -> f64 {
        if x < 0.0 {
            0.0
        } else {
            self.lambda * (-self.lambda * x).exp()
        }
    }
    fn cdf(&self, x: f64) -> f64 {
        if x < 0.0 {
            0.0
        } else {
            1.0 - (-self.lambda * x).exp()
        }
    }
    fn quantile(&self, p: f64) -> f64 {
        -(1.0 - p).ln() / self.lambda
    }
}

/// Weibull(k, lambda): F(x) = 1 - exp(-(x/lambda)^k).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Weibull {
    /// shape
    pub k: f64,
    /// scale
    pub lambda: f64,
}

impl Weibull {
    pub fn new(k: f64, lambda: f64) -> Self {
        assert!(k > 0.0 && lambda > 0.0);
        Weibull { k, lambda }
    }
}

impl Distribution for Weibull {
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        self.lambda * (-rng.uniform_pos().ln()).powf(1.0 / self.k)
    }
    fn pdf(&self, x: f64) -> f64 {
        if x < 0.0 {
            return 0.0;
        }
        let z = x / self.lambda;
        (self.k / self.lambda) * z.powf(self.k - 1.0) * (-z.powf(self.k)).exp()
    }
    fn cdf(&self, x: f64) -> f64 {
        if x < 0.0 {
            0.0
        } else {
            1.0 - (-(x / self.lambda).powf(self.k)).exp()
        }
    }
    fn quantile(&self, p: f64) -> f64 {
        self.lambda * (-(1.0 - p).ln()).powf(1.0 / self.k)
    }
}

/// Exponentiated Weibull(alpha, k, lambda): F(x) = (1 - exp(-(x/lambda)^k))^alpha.
///
/// The family the paper found to fit pipeline interarrivals best
/// (section V-A3).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ExpWeibull {
    /// exponentiation (second shape)
    pub alpha: f64,
    /// Weibull shape
    pub k: f64,
    /// scale
    pub lambda: f64,
}

impl ExpWeibull {
    pub fn new(alpha: f64, k: f64, lambda: f64) -> Self {
        assert!(alpha > 0.0 && k > 0.0 && lambda > 0.0);
        ExpWeibull { alpha, k, lambda }
    }
}

impl Distribution for ExpWeibull {
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        self.quantile(rng.uniform())
    }
    fn pdf(&self, x: f64) -> f64 {
        if x <= 0.0 {
            return 0.0;
        }
        let z = x / self.lambda;
        let zk = z.powf(self.k);
        let e = (-zk).exp();
        let base = 1.0 - e;
        if base <= 0.0 {
            return 0.0;
        }
        self.alpha * (self.k / self.lambda) * z.powf(self.k - 1.0)
            * e
            * base.powf(self.alpha - 1.0)
    }
    fn cdf(&self, x: f64) -> f64 {
        if x <= 0.0 {
            0.0
        } else {
            (1.0 - (-(x / self.lambda).powf(self.k)).exp()).powf(self.alpha)
        }
    }
    fn quantile(&self, p: f64) -> f64 {
        // invert F: x = lambda * (-ln(1 - p^(1/alpha)))^(1/k)
        let inner = 1.0 - p.powf(1.0 / self.alpha);
        self.lambda * (-(inner.max(1e-300)).ln()).powf(1.0 / self.k)
    }
}

/// Pareto(xm, alpha) (Type I): F(x) = 1 - (xm/x)^alpha for x >= xm.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Pareto {
    /// scale (minimum)
    pub xm: f64,
    /// tail index
    pub alpha: f64,
}

impl Pareto {
    pub fn new(xm: f64, alpha: f64) -> Self {
        assert!(xm > 0.0 && alpha > 0.0);
        Pareto { xm, alpha }
    }
}

impl Distribution for Pareto {
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        self.xm / rng.uniform_pos().powf(1.0 / self.alpha)
    }
    fn pdf(&self, x: f64) -> f64 {
        if x < self.xm {
            0.0
        } else {
            self.alpha * self.xm.powf(self.alpha) / x.powf(self.alpha + 1.0)
        }
    }
    fn cdf(&self, x: f64) -> f64 {
        if x < self.xm {
            0.0
        } else {
            1.0 - (self.xm / x).powf(self.alpha)
        }
    }
    fn quantile(&self, p: f64) -> f64 {
        self.xm / (1.0 - p).powf(1.0 / self.alpha)
    }
}

// ---------------------------------------------------------------------------
// Special functions (no external deps).
// ---------------------------------------------------------------------------

/// Complementary error function (Numerical-Recipes rational approximation,
/// |rel err| < 1.2e-7 — plenty for CDF work here).
pub fn erfc(x: f64) -> f64 {
    let z = x.abs();
    let t = 1.0 / (1.0 + 0.5 * z);
    let ans = t
        * (-z * z - 1.26551223
            + t * (1.00002368
                + t * (0.37409196
                    + t * (0.09678418
                        + t * (-0.18628806
                            + t * (0.27886807
                                + t * (-1.13520398
                                    + t * (1.48851587
                                        + t * (-0.82215223 + t * 0.17087277)))))))))
        .exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

/// Standard normal quantile (Acklam's algorithm, |rel err| < 1.15e-9).
pub fn std_normal_quantile(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "quantile needs p in (0,1), got {p}");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_quantile_roundtrip(d: &dyn Distribution) {
        for &p in &[0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99] {
            let x = d.quantile(p);
            let back = d.cdf(x);
            assert!((back - p).abs() < 1e-6, "p={p} x={x} back={back}");
        }
    }

    fn check_sample_matches_cdf(d: &dyn Distribution, seed: u64) {
        // KS-style check: empirical CDF of 50k samples vs analytic CDF.
        let mut rng = Pcg64::new(seed);
        let mut xs: Vec<f64> = (0..50_000).map(|_| d.sample(&mut rng)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len() as f64;
        let mut dmax: f64 = 0.0;
        for (i, &x) in xs.iter().enumerate() {
            let emp = (i + 1) as f64 / n;
            dmax = dmax.max((emp - d.cdf(x)).abs());
        }
        // KS critical value at alpha=0.001 for n=50k is ~0.0087
        assert!(dmax < 0.012, "KS distance {dmax}");
    }

    #[test]
    fn normal_basics() {
        let d = Normal::new(2.0, 3.0);
        // erfc approximation is good to ~1.2e-7
        assert!((d.cdf(2.0) - 0.5).abs() < 1e-6);
        assert!((d.quantile(0.975) - (2.0 + 3.0 * 1.959964)).abs() < 1e-3);
        check_quantile_roundtrip(&d);
        check_sample_matches_cdf(&d, 10);
    }

    #[test]
    fn lognormal_basics() {
        let d = LogNormal::new(1.0, 0.5);
        assert!((d.median() - 1.0f64.exp()).abs() < 1e-9);
        assert!((d.mean() - (1.0 + 0.125f64).exp()).abs() < 1e-9);
        check_quantile_roundtrip(&d);
        check_sample_matches_cdf(&d, 11);
    }

    #[test]
    fn exponential_basics() {
        let d = Exponential::new(2.0);
        assert!((d.quantile(0.5) - 0.5f64.ln().abs() / 2.0).abs() < 1e-9);
        check_quantile_roundtrip(&d);
        check_sample_matches_cdf(&d, 12);
    }

    #[test]
    fn weibull_basics() {
        let d = Weibull::new(1.5, 10.0);
        check_quantile_roundtrip(&d);
        check_sample_matches_cdf(&d, 13);
        // k=1 degenerates to exponential
        let w = Weibull::new(1.0, 2.0);
        let e = Exponential::new(0.5);
        for &x in &[0.1, 1.0, 5.0] {
            assert!((w.cdf(x) - e.cdf(x)).abs() < 1e-12);
        }
    }

    #[test]
    fn expweibull_basics() {
        let d = ExpWeibull::new(2.5, 0.8, 30.0);
        check_quantile_roundtrip(&d);
        check_sample_matches_cdf(&d, 14);
        // alpha=1 degenerates to plain Weibull
        let ew = ExpWeibull::new(1.0, 1.3, 4.0);
        let w = Weibull::new(1.3, 4.0);
        for &x in &[0.5, 2.0, 8.0] {
            assert!((ew.cdf(x) - w.cdf(x)).abs() < 1e-12);
            assert!((ew.pdf(x) - w.pdf(x)).abs() < 1e-9);
        }
    }

    #[test]
    fn pareto_basics() {
        let d = Pareto::new(1.5, 2.5);
        assert_eq!(d.cdf(1.0), 0.0);
        check_quantile_roundtrip(&d);
        check_sample_matches_cdf(&d, 15);
    }

    #[test]
    fn pdf_integrates_to_one() {
        // trapezoid integration sanity for the exotic families
        let d = ExpWeibull::new(2.0, 1.2, 5.0);
        let mut total = 0.0;
        let (lo, hi, n) = (1e-6, 200.0, 400_000);
        let h = (hi - lo) / n as f64;
        for i in 0..n {
            let x = lo + (i as f64 + 0.5) * h;
            total += d.pdf(x) * h;
        }
        assert!((total - 1.0).abs() < 1e-3, "integral={total}");
    }

    #[test]
    fn erfc_reference_values() {
        assert!((erfc(0.0) - 1.0).abs() < 1e-7);
        assert!((erfc(1.0) - 0.157_299_2).abs() < 1e-6);
        assert!((erfc(-1.0) - 1.842_700_8).abs() < 1e-6);
    }

    #[test]
    fn normal_quantile_reference_values() {
        assert!(std_normal_quantile(0.5).abs() < 1e-9);
        assert!((std_normal_quantile(0.975) - 1.959_964).abs() < 1e-5);
        assert!((std_normal_quantile(0.025) + 1.959_964).abs() < 1e-5);
    }

    #[test]
    fn dist_enum_dispatch() {
        let mut rng = Pcg64::new(16);
        let d = Dist::LogNormal(LogNormal::new(0.0, 1.0));
        assert_eq!(d.name(), "lognormal");
        let x = d.sample(&mut rng);
        assert!(x > 0.0);
        assert!((d.cdf(d.quantile(0.3)) - 0.3).abs() < 1e-6);
    }
}
