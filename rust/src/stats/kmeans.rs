//! k-means++ seeding and Lloyd iterations — the GMM initializer.
//!
//! scikit-learn's `GaussianMixture` (which the paper uses, section V-A1)
//! initializes EM from k-means; random-row init needs many more EM
//! iterations and is prone to collapsed components on clustered data like
//! the asset mixture. This module provides the same initialization
//! quality for both the CPU and the AOT EM drivers.

use super::rng::Pcg64;

/// Squared Euclidean distance between D-dim points.
#[inline]
fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// k-means++ seeding: D^2-weighted center choices (Arthur & Vassilvitskii).
pub fn kmeanspp_seed(x: &[Vec<f64>], k: usize, rng: &mut Pcg64) -> Vec<Vec<f64>> {
    assert!(x.len() >= k && k > 0);
    let mut centers: Vec<Vec<f64>> = Vec::with_capacity(k);
    centers.push(x[rng.below(x.len())].clone());
    let mut d2: Vec<f64> = x.iter().map(|p| dist2(p, &centers[0])).collect();
    while centers.len() < k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 0.0 {
            // all remaining points coincide with a center: pick random
            x[rng.below(x.len())].clone()
        } else {
            let mut u = rng.uniform() * total;
            let mut pick = x.len() - 1;
            for (i, &w) in d2.iter().enumerate() {
                u -= w;
                if u <= 0.0 {
                    pick = i;
                    break;
                }
            }
            x[pick].clone()
        };
        for (i, p) in x.iter().enumerate() {
            let d = dist2(p, &next);
            if d < d2[i] {
                d2[i] = d;
            }
        }
        centers.push(next);
    }
    centers
}

/// Lloyd's algorithm from given centers. Returns (centers, assignment).
pub fn lloyd(
    x: &[Vec<f64>],
    mut centers: Vec<Vec<f64>>,
    max_iter: usize,
) -> (Vec<Vec<f64>>, Vec<usize>) {
    let k = centers.len();
    let d = centers[0].len();
    let mut assign = vec![0usize; x.len()];
    for _ in 0..max_iter {
        let mut moved = false;
        // assignment step
        for (i, p) in x.iter().enumerate() {
            let mut best = (f64::INFINITY, 0usize);
            for (c, center) in centers.iter().enumerate() {
                let dd = dist2(p, center);
                if dd < best.0 {
                    best = (dd, c);
                }
            }
            if assign[i] != best.1 {
                assign[i] = best.1;
                moved = true;
            }
        }
        if !moved {
            break;
        }
        // update step
        let mut sums = vec![vec![0.0f64; d]; k];
        let mut counts = vec![0usize; k];
        for (i, p) in x.iter().enumerate() {
            counts[assign[i]] += 1;
            for (s, &v) in sums[assign[i]].iter_mut().zip(p) {
                *s += v;
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                for s in sums[c].iter_mut() {
                    *s /= counts[c] as f64;
                }
                centers[c] = sums[c].clone();
            }
        }
    }
    (centers, assign)
}

/// k-means++ + Lloyd in one call.
pub fn kmeans(x: &[Vec<f64>], k: usize, rng: &mut Pcg64, max_iter: usize) -> (Vec<Vec<f64>>, Vec<usize>) {
    let seeds = kmeanspp_seed(x, k, rng);
    lloyd(x, seeds, max_iter)
}

/// Within-cluster sum of squares (inertia) — quality metric for tests.
pub fn inertia(x: &[Vec<f64>], centers: &[Vec<f64>], assign: &[usize]) -> f64 {
    x.iter()
        .zip(assign)
        .map(|(p, &a)| dist2(p, &centers[a]))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs(rng: &mut Pcg64, n_per: usize) -> Vec<Vec<f64>> {
        let centers = [[-5.0, 0.0], [5.0, 5.0], [0.0, -6.0]];
        let mut out = Vec::new();
        for c in &centers {
            for _ in 0..n_per {
                out.push(vec![c[0] + 0.5 * rng.normal(), c[1] + 0.5 * rng.normal()]);
            }
        }
        out
    }

    #[test]
    fn finds_well_separated_blobs() {
        let mut rng = Pcg64::new(1);
        let x = blobs(&mut rng, 300);
        let (centers, assign) = kmeans(&x, 3, &mut rng, 50);
        // every true blob center must be within 0.3 of a found center
        for truth in [[-5.0, 0.0], [5.0, 5.0], [0.0, -6.0]] {
            let best = centers
                .iter()
                .map(|c| dist2(c, &truth.to_vec()).sqrt())
                .fold(f64::INFINITY, f64::min);
            assert!(best < 0.3, "blob {truth:?} missed: {centers:?}");
        }
        let wcss = inertia(&x, &centers, &assign);
        assert!(wcss / (x.len() as f64) < 1.0, "inertia {wcss}");
    }

    #[test]
    fn kmeanspp_beats_random_seed_on_average() {
        let mut rng = Pcg64::new(2);
        let x = blobs(&mut rng, 200);
        let mut pp_wins = 0;
        for trial in 0..10 {
            let mut r1 = Pcg64::new(100 + trial);
            let seeds_pp = kmeanspp_seed(&x, 3, &mut r1);
            let (c1, a1) = lloyd(&x, seeds_pp, 30);
            let mut r2 = Pcg64::new(200 + trial);
            let seeds_rand: Vec<Vec<f64>> =
                (0..3).map(|_| x[r2.below(x.len())].clone()).collect();
            let (c2, a2) = lloyd(&x, seeds_rand, 30);
            if inertia(&x, &c1, &a1) <= inertia(&x, &c2, &a2) + 1e-9 {
                pp_wins += 1;
            }
        }
        assert!(pp_wins >= 7, "kmeans++ won only {pp_wins}/10");
    }

    #[test]
    fn handles_k_equals_n() {
        let mut rng = Pcg64::new(3);
        let x: Vec<Vec<f64>> = (0..5).map(|i| vec![i as f64, 0.0]).collect();
        let (centers, assign) = kmeans(&x, 5, &mut rng, 10);
        assert_eq!(centers.len(), 5);
        // perfect assignment: zero inertia
        assert!(inertia(&x, &centers, &assign) < 1e-18);
    }

    #[test]
    fn duplicate_points_no_panic() {
        let mut rng = Pcg64::new(4);
        let x = vec![vec![1.0, 1.0]; 50];
        let (centers, _) = kmeans(&x, 3, &mut rng, 10);
        assert_eq!(centers.len(), 3);
    }
}
