//! Statistical substrate: RNG, parametric distributions, descriptive
//! statistics, fitting (MLE / NLLS / SSE selection) and Gaussian mixtures.
//!
//! The paper leans on SciPy + scikit-learn for all of this (section V-A);
//! here it is native Rust, with the mixture EM additionally available as
//! an AOT-compiled JAX/Pallas artifact (see [`crate::runtime`]).

pub mod desc;
pub mod dist;
pub mod fit;
pub mod gmm;
pub mod kmeans;
pub mod rng;
pub mod sketch;

pub use desc::{mean, pearson, qq_points, quantile, quantiles, std_dev, Summary};
pub use sketch::{FixedHistogram, TDigest};
pub use dist::{Dist, Distribution, ExpWeibull, Exponential, LogNormal, Normal, Pareto, Weibull};
pub use fit::{fit_exp_curve, fit_expweibull, fit_lognormal, fit_pareto, select_best_fit, ExpCurve};
pub use gmm::{Gmm1, Gmm3};
pub use rng::Pcg64;
