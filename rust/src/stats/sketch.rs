//! Mergeable streaming summaries: a t-digest quantile sketch and a
//! fixed-bin histogram.
//!
//! Both structures hold O(1) memory regardless of how many points they
//! absorb, and both merge associatively (up to floating-point
//! accumulation), which is what makes downsampled tsdb windows
//! re-aggregatable across query windows and — the ROADMAP follow-up —
//! across sweep shards.
//!
//! ## Accuracy contract
//!
//! [`TDigest`] with compression `δ` keeps at most ~`2δ` centroids and
//! answers `quantile(q)` with a *rank* error bounded by roughly `1/δ`
//! in the middle of the distribution and tighter near the tails (the
//! k1 scale function concentrates centroids there). The property tests
//! in this module and in `tests/obs.rs` assert the conservative bound
//! used throughout the repo: for the default `δ = 100`, the estimate
//! lies between the exact empirical quantiles at `q ± 0.05`.
//!
//! [`FixedHistogram`] answers quantiles with value error bounded by one
//! bin width (plus clamping at the configured range edges).

use crate::error::{Error, Result};
use crate::util::binio::{ByteReader, ByteWriter};

/// One weighted centroid of a [`TDigest`].
#[derive(Clone, Copy, Debug)]
pub struct Centroid {
    pub mean: f64,
    pub weight: f64,
}

/// Mergeable t-digest quantile sketch (Dunning's merging variant with
/// the k1 scale function).
///
/// Points insert in sorted position (the centroid list is small —
/// at most ~`2δ` entries — so the memmove is cheap) and the list
/// compresses back under the scale-function limit whenever it
/// overflows. All operations are deterministic: the same sequence of
/// `add`/`merge_from` calls produces bit-identical state.
#[derive(Clone, Debug)]
pub struct TDigest {
    compression: f64,
    /// Sorted by mean, non-decreasing.
    centroids: Vec<Centroid>,
    count: u64,
    min: f64,
    max: f64,
}

/// Default compression for tsdb retention windows: ~200 centroids,
/// rank error well under the documented 0.05 test bound.
pub const DEFAULT_COMPRESSION: f64 = 100.0;

impl Default for TDigest {
    fn default() -> Self {
        TDigest::new(DEFAULT_COMPRESSION)
    }
}

impl TDigest {
    pub fn new(compression: f64) -> Self {
        assert!(compression >= 10.0, "compression must be >= 10");
        TDigest {
            compression,
            centroids: Vec::new(),
            count: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Smallest absorbed value (+inf when empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest absorbed value (-inf when empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn compression(&self) -> f64 {
        self.compression
    }

    pub fn centroid_count(&self) -> usize {
        self.centroids.len()
    }

    /// Approximate resident bytes: the centroid buffer plus the header.
    pub fn approx_bytes(&self) -> usize {
        self.centroids.capacity() * std::mem::size_of::<Centroid>() + 48
    }

    fn max_centroids(&self) -> usize {
        (2.0 * self.compression).ceil() as usize + 8
    }

    /// Absorb one point.
    pub fn add(&mut self, x: f64) {
        debug_assert!(x.is_finite(), "t-digest rejects non-finite values");
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
        self.count += 1;
        let pos = self.centroids.partition_point(|c| c.mean < x);
        self.centroids.insert(pos, Centroid { mean: x, weight: 1.0 });
        if self.centroids.len() > self.max_centroids() {
            self.compress();
        }
    }

    /// Absorb another sketch. Associative up to floating-point
    /// accumulation: `(a ⊕ b) ⊕ c` and `a ⊕ (b ⊕ c)` agree within the
    /// documented rank-error bound (property-tested). The result keeps
    /// `self`'s compression.
    pub fn merge_from(&mut self, other: &TDigest) {
        if other.count == 0 {
            return;
        }
        if other.min < self.min {
            self.min = other.min;
        }
        if other.max > self.max {
            self.max = other.max;
        }
        self.count += other.count;
        for c in &other.centroids {
            let pos = self.centroids.partition_point(|d| d.mean < c.mean);
            self.centroids.insert(pos, *c);
        }
        self.compress();
    }

    /// k1 scale function: concentrates centroid resolution at the tails.
    fn k1(q: f64, d: f64) -> f64 {
        d / (2.0 * std::f64::consts::PI) * (2.0 * q - 1.0).clamp(-1.0, 1.0).asin()
    }

    fn k1_inv(k: f64, d: f64) -> f64 {
        0.5 * ((2.0 * std::f64::consts::PI * k / d).sin() + 1.0)
    }

    /// One merging pass under the k1 weight limit; leaves ≤ ~2δ
    /// centroids.
    fn compress(&mut self) {
        if self.centroids.len() <= 1 {
            return;
        }
        let total: f64 = self.centroids.iter().map(|c| c.weight).sum();
        let d = self.compression;
        let mut out: Vec<Centroid> = Vec::with_capacity(self.compression as usize * 2);
        let mut iter = self.centroids.drain(..);
        let mut acc = iter.next().expect("len > 1");
        let mut w_before = 0.0f64;
        let mut q_limit = Self::k1_inv(Self::k1(0.0, d) + 1.0, d) * total;
        for c in iter {
            if w_before + acc.weight + c.weight <= q_limit {
                // merge c into acc (weighted mean stays within the run,
                // so the output list remains sorted)
                let w = acc.weight + c.weight;
                acc.mean = (acc.mean * acc.weight + c.mean * c.weight) / w;
                acc.weight = w;
            } else {
                w_before += acc.weight;
                q_limit = Self::k1_inv(Self::k1(w_before / total, d) + 1.0, d) * total;
                out.push(acc);
                acc = c;
            }
        }
        out.push(acc);
        self.centroids = out;
    }

    /// Estimate the `q`-quantile (q clamped to [0, 1]; NaN when empty).
    ///
    /// Anchored midpoint interpolation: centroid `i` with cumulative
    /// weight `C_i` before it represents rank `C_i + w_i/2`; the
    /// estimate interpolates linearly between successive centroid
    /// means, anchored at `min` (rank 0) and `max` (rank n).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let q = q.clamp(0.0, 1.0);
        if self.centroids.len() == 1 {
            return self.centroids[0].mean;
        }
        let total = self.count as f64;
        let target = q * total;
        let mut cum = 0.0f64;
        let mut prev_center = 0.0f64;
        let mut prev_mean = self.min;
        for (i, c) in self.centroids.iter().enumerate() {
            let center = cum + c.weight / 2.0;
            if target < center {
                let (lo_rank, lo_val) = if i == 0 {
                    (0.0, self.min)
                } else {
                    (prev_center, prev_mean)
                };
                let span = center - lo_rank;
                let frac = if span > 0.0 {
                    ((target - lo_rank) / span).clamp(0.0, 1.0)
                } else {
                    0.0
                };
                return lo_val + frac * (c.mean - lo_val);
            }
            cum += c.weight;
            prev_center = center;
            prev_mean = c.mean;
        }
        // past the last centroid's center: interpolate toward max
        let span = total - prev_center;
        let frac = if span > 0.0 {
            ((target - prev_center) / span).clamp(0.0, 1.0)
        } else {
            1.0
        };
        prev_mean + frac * (self.max - prev_mean)
    }

    /// Serialize into `w` with the repo's binio vocabulary (f64s as raw
    /// bit patterns, so state round-trips bit-exactly). No container
    /// header — the sketch is a field of larger formats (the shard
    /// manifest), which own magic/version.
    pub fn write_to(&self, w: &mut ByteWriter) {
        w.f64(self.compression);
        w.varint(self.count);
        w.f64(self.min);
        w.f64(self.max);
        w.varint(self.centroids.len() as u64);
        for c in &self.centroids {
            w.f64(c.mean);
            w.f64(c.weight);
        }
    }

    /// Inverse of [`TDigest::write_to`], hardened against corrupt input
    /// the way every PipeSim decoder is: invariants (sorted centroids,
    /// positive finite weights, count consistency) are validated, never
    /// assumed.
    pub fn read_from(r: &mut ByteReader) -> Result<TDigest> {
        let bad = |m: &str| Error::Other(format!("t-digest: {m}"));
        let compression = r.f64()?;
        if !compression.is_finite() || compression < 10.0 {
            return Err(bad("compression out of range"));
        }
        let count = r.varint()?;
        let min = r.f64()?;
        let max = r.f64()?;
        let n = r.len_prefix_for(16)?;
        let mut centroids = Vec::with_capacity(n);
        let mut prev = f64::NEG_INFINITY;
        let mut weight_sum = 0.0f64;
        for _ in 0..n {
            let mean = r.f64()?;
            let weight = r.f64()?;
            if !mean.is_finite() || !weight.is_finite() || weight <= 0.0 {
                return Err(bad("non-finite centroid"));
            }
            if mean < prev {
                return Err(bad("centroids not sorted"));
            }
            prev = mean;
            weight_sum += weight;
            centroids.push(Centroid { mean, weight });
        }
        if (count == 0) != centroids.is_empty() {
            return Err(bad("count/centroid mismatch"));
        }
        if count > 0 {
            if !(min.is_finite() && max.is_finite() && min <= max) {
                return Err(bad("min/max out of order"));
            }
            // weights are integer-valued accumulations; a drifted sum
            // means the payload was not produced by this writer
            if (weight_sum - count as f64).abs() > 1e-6 * (count as f64).max(1.0) {
                return Err(bad("weight sum disagrees with count"));
            }
        }
        Ok(TDigest {
            compression,
            centroids,
            count,
            min,
            max,
        })
    }
}

/// Fixed-range, fixed-bin histogram with underflow/overflow buckets.
///
/// Exact for `count`; quantiles carry value error of at most one bin
/// width inside `[lo, hi)` and clamp to the range edges outside it.
/// Merges exactly (integer counts) when the configurations match.
#[derive(Clone, Debug)]
pub struct FixedHistogram {
    lo: f64,
    hi: f64,
    counts: Vec<u64>,
    underflow: u64,
    overflow: u64,
    count: u64,
}

impl FixedHistogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(bins > 0 && hi > lo && lo.is_finite() && hi.is_finite());
        FixedHistogram {
            lo,
            hi,
            counts: vec![0; bins],
            underflow: 0,
            overflow: 0,
            count: 0,
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn bins(&self) -> usize {
        self.counts.len()
    }

    pub fn bin_width(&self) -> f64 {
        (self.hi - self.lo) / self.counts.len() as f64
    }

    pub fn bin_counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn underflow(&self) -> u64 {
        self.underflow
    }

    pub fn overflow(&self) -> u64 {
        self.overflow
    }

    pub fn add(&mut self, x: f64) {
        self.count += 1;
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let idx = (((x - self.lo) / (self.hi - self.lo)) * self.counts.len() as f64) as usize;
            self.counts[idx.min(self.counts.len() - 1)] += 1;
        }
    }

    /// Merge another histogram with the same `[lo, hi) × bins`
    /// configuration; returns false (and absorbs nothing) on mismatch.
    pub fn merge_from(&mut self, other: &FixedHistogram) -> bool {
        if other.lo != self.lo || other.hi != self.hi || other.counts.len() != self.counts.len() {
            return false;
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.underflow += other.underflow;
        self.overflow += other.overflow;
        self.count += other.count;
        true
    }

    /// Estimate the `q`-quantile by linear interpolation inside the
    /// containing bin (NaN when empty; clamps to `lo`/`hi` when the
    /// rank falls in the underflow/overflow buckets).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let target = q.clamp(0.0, 1.0) * self.count as f64;
        if target <= self.underflow as f64 {
            return self.lo;
        }
        let mut cum = self.underflow as f64;
        let w = self.bin_width();
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let next = cum + c as f64;
            if target <= next {
                let frac = (target - cum) / c as f64;
                return self.lo + (i as f64 + frac) * w;
            }
            cum = next;
        }
        self.hi
    }

    /// Serialize into `w` (binio vocabulary, headerless — see
    /// [`TDigest::write_to`]). Bin counts are varints: shard wall-time
    /// histograms are sparse, so this is much smaller than fixed-width.
    pub fn write_to(&self, w: &mut ByteWriter) {
        w.f64(self.lo);
        w.f64(self.hi);
        w.varint(self.counts.len() as u64);
        for &c in &self.counts {
            w.varint(c);
        }
        w.varint(self.underflow);
        w.varint(self.overflow);
        w.varint(self.count);
    }

    /// Inverse of [`FixedHistogram::write_to`]; validates range and
    /// count-conservation invariants on the way in.
    pub fn read_from(r: &mut ByteReader) -> Result<FixedHistogram> {
        let bad = |m: &str| Error::Other(format!("histogram: {m}"));
        let lo = r.f64()?;
        let hi = r.f64()?;
        if !(lo.is_finite() && hi.is_finite() && hi > lo) {
            return Err(bad("invalid range"));
        }
        let bins = r.len_prefix_for(1)?;
        if bins == 0 {
            return Err(bad("zero bins"));
        }
        let mut counts = Vec::with_capacity(bins);
        let mut in_range: u64 = 0;
        for _ in 0..bins {
            let c = r.varint()?;
            in_range = in_range
                .checked_add(c)
                .ok_or_else(|| bad("count overflow"))?;
            counts.push(c);
        }
        let underflow = r.varint()?;
        let overflow = r.varint()?;
        let count = r.varint()?;
        let total = in_range
            .checked_add(underflow)
            .and_then(|t| t.checked_add(overflow))
            .ok_or_else(|| bad("count overflow"))?;
        if total != count {
            return Err(bad("bin counts disagree with total"));
        }
        Ok(FixedHistogram {
            lo,
            hi,
            counts,
            underflow,
            overflow,
            count,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::desc::quantile_sorted;
    use crate::stats::rng::Pcg64;

    /// Assert a sketch estimate lies between the exact quantiles at
    /// `q ± eps` (the rank-error contract).
    fn assert_rank_close(sorted: &[f64], est: f64, q: f64, eps: f64) {
        let lo = quantile_sorted(sorted, (q - eps).max(0.0));
        let hi = quantile_sorted(sorted, (q + eps).min(1.0));
        let slack = 1e-9 * (1.0 + hi.abs() + lo.abs());
        assert!(
            est >= lo - slack && est <= hi + slack,
            "q={q}: est {est} outside [{lo}, {hi}]"
        );
    }

    fn sorted(xs: &[f64]) -> Vec<f64> {
        let mut v = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v
    }

    #[test]
    fn digest_quantiles_track_exact_uniform_and_lognormal() {
        let mut rng = Pcg64::new(11);
        for dist in 0..2 {
            let xs: Vec<f64> = (0..20_000)
                .map(|_| {
                    if dist == 0 {
                        rng.uniform() * 100.0
                    } else {
                        (rng.normal() * 1.5).exp()
                    }
                })
                .collect();
            let mut td = TDigest::new(100.0);
            for &x in &xs {
                td.add(x);
            }
            let s = sorted(&xs);
            for q in [0.0, 0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 1.0] {
                assert_rank_close(&s, td.quantile(q), q, 0.05);
            }
            assert_eq!(td.count(), xs.len() as u64);
            assert_eq!(td.min(), s[0]);
            assert_eq!(td.max(), *s.last().unwrap());
            assert!(td.centroid_count() <= 208, "{}", td.centroid_count());
        }
    }

    #[test]
    fn digest_extremes_and_small_inputs() {
        let mut td = TDigest::new(100.0);
        assert!(td.quantile(0.5).is_nan());
        td.add(7.0);
        assert_eq!(td.quantile(0.0), 7.0);
        assert_eq!(td.quantile(0.5), 7.0);
        assert_eq!(td.quantile(1.0), 7.0);
        td.add(9.0);
        assert_eq!(td.quantile(0.0), 7.0);
        assert_eq!(td.quantile(1.0), 9.0);
        let mid = td.quantile(0.5);
        assert!((7.0..=9.0).contains(&mid));
    }

    #[test]
    fn digest_merge_matches_single_sketch() {
        let mut rng = Pcg64::new(5);
        let xs: Vec<f64> = (0..12_000).map(|_| rng.normal() * 10.0 + 50.0).collect();
        let mut whole = TDigest::new(100.0);
        let mut parts: Vec<TDigest> = (0..4).map(|_| TDigest::new(100.0)).collect();
        for (i, &x) in xs.iter().enumerate() {
            whole.add(x);
            parts[i % 4].add(x);
        }
        let mut merged = TDigest::new(100.0);
        for p in &parts {
            merged.merge_from(p);
        }
        assert_eq!(merged.count(), whole.count());
        assert_eq!(merged.min(), whole.min());
        assert_eq!(merged.max(), whole.max());
        let s = sorted(&xs);
        for q in [0.05, 0.25, 0.5, 0.75, 0.95] {
            assert_rank_close(&s, merged.quantile(q), q, 0.05);
        }
    }

    #[test]
    fn digest_merge_is_associative_within_bound() {
        let mut rng = Pcg64::new(17);
        let chunks: Vec<Vec<f64>> = (0..3)
            .map(|_| (0..5_000).map(|_| rng.uniform() * 1000.0).collect())
            .collect();
        let all: Vec<f64> = chunks.iter().flatten().cloned().collect();
        let s = sorted(&all);
        let sketch = |xs: &[f64]| {
            let mut t = TDigest::new(100.0);
            for &x in xs {
                t.add(x);
            }
            t
        };
        let (a, b, c) = (sketch(&chunks[0]), sketch(&chunks[1]), sketch(&chunks[2]));
        // (a ⊕ b) ⊕ c
        let mut left = a.clone();
        left.merge_from(&b);
        left.merge_from(&c);
        // a ⊕ (b ⊕ c)
        let mut bc = b.clone();
        bc.merge_from(&c);
        let mut right = a.clone();
        right.merge_from(&bc);
        assert_eq!(left.count(), right.count());
        assert_eq!(left.min(), right.min());
        assert_eq!(left.max(), right.max());
        for q in [0.01, 0.1, 0.5, 0.9, 0.99] {
            assert_rank_close(&s, left.quantile(q), q, 0.05);
            assert_rank_close(&s, right.quantile(q), q, 0.05);
        }
    }

    #[test]
    fn digest_memory_stays_bounded() {
        let mut td = TDigest::new(100.0);
        let mut rng = Pcg64::new(3);
        for _ in 0..200_000 {
            td.add(rng.uniform());
        }
        assert!(td.centroid_count() <= 208);
        assert!(td.approx_bytes() < 16 * 1024, "{}", td.approx_bytes());
    }

    #[test]
    fn histogram_quantiles_within_bin_width() {
        let mut rng = Pcg64::new(9);
        let xs: Vec<f64> = (0..30_000).map(|_| rng.uniform() * 50.0).collect();
        let mut h = FixedHistogram::new(0.0, 50.0, 100);
        for &x in &xs {
            h.add(x);
        }
        let s = sorted(&xs);
        let w = h.bin_width();
        for q in [0.05, 0.25, 0.5, 0.75, 0.95] {
            let exact = quantile_sorted(&s, q);
            let est = h.quantile(q);
            assert!((est - exact).abs() <= w + 1e-9, "q={q}: {est} vs {exact}");
        }
        assert_eq!(h.count(), xs.len() as u64);
    }

    #[test]
    fn histogram_range_edges_and_merge() {
        let mut h = FixedHistogram::new(0.0, 10.0, 10);
        h.add(-5.0);
        h.add(15.0);
        h.add(5.0);
        assert_eq!(h.underflow(), 1);
        assert_eq!(h.overflow(), 1);
        assert_eq!(h.count(), 3);
        assert_eq!(h.quantile(0.0), 0.0); // underflow clamps to lo
        assert_eq!(h.quantile(1.0), 10.0); // overflow clamps to hi
        let mut other = FixedHistogram::new(0.0, 10.0, 10);
        other.add(5.0);
        assert!(h.merge_from(&other));
        assert_eq!(h.count(), 4);
        // mismatched configuration refuses to merge
        let bad = FixedHistogram::new(0.0, 20.0, 10);
        assert!(!h.merge_from(&bad));
        assert_eq!(h.count(), 4);
    }

    #[test]
    fn digest_serialization_roundtrips_bit_exact() {
        let mut rng = Pcg64::new(21);
        let mut td = TDigest::new(100.0);
        for _ in 0..5_000 {
            td.add(rng.normal() * 3.0 - 1.0);
        }
        let mut w = ByteWriter::new();
        td.write_to(&mut w);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        let back = TDigest::read_from(&mut r).unwrap();
        assert!(r.is_empty());
        assert_eq!(back.count(), td.count());
        assert_eq!(back.min().to_bits(), td.min().to_bits());
        assert_eq!(back.max().to_bits(), td.max().to_bits());
        assert_eq!(back.centroid_count(), td.centroid_count());
        for q in [0.0, 0.1, 0.5, 0.9, 1.0] {
            assert_eq!(back.quantile(q).to_bits(), td.quantile(q).to_bits());
        }
        // empty sketch round-trips too (min/max are infinities)
        let empty = TDigest::new(50.0);
        let mut w = ByteWriter::new();
        empty.write_to(&mut w);
        let bytes = w.into_bytes();
        let back = TDigest::read_from(&mut ByteReader::new(&bytes)).unwrap();
        assert!(back.is_empty());
        assert_eq!(back.compression(), 50.0);
    }

    #[test]
    fn digest_deserialization_rejects_corrupt_payloads() {
        let mut td = TDigest::new(100.0);
        for x in [3.0, 1.0, 2.0] {
            td.add(x);
        }
        let mut w = ByteWriter::new();
        td.write_to(&mut w);
        let good = w.into_bytes();
        assert!(TDigest::read_from(&mut ByteReader::new(&good)).is_ok());
        // truncation fails cleanly
        assert!(TDigest::read_from(&mut ByteReader::new(&good[..good.len() - 3])).is_err());
        // bad compression
        let mut w = ByteWriter::new();
        w.f64(1.0);
        assert!(TDigest::read_from(&mut ByteReader::new(&w.into_bytes())).is_err());
        // unsorted centroids
        let mut w = ByteWriter::new();
        w.f64(100.0);
        w.varint(2);
        w.f64(1.0);
        w.f64(9.0);
        w.varint(2);
        w.f64(9.0);
        w.f64(1.0);
        w.f64(1.0);
        w.f64(1.0);
        let err = TDigest::read_from(&mut ByteReader::new(&w.into_bytes())).unwrap_err();
        assert!(err.to_string().contains("not sorted"), "{err}");
        // weight sum disagreeing with count
        let mut w = ByteWriter::new();
        w.f64(100.0);
        w.varint(5);
        w.f64(1.0);
        w.f64(2.0);
        w.varint(1);
        w.f64(1.5);
        w.f64(2.0);
        let err = TDigest::read_from(&mut ByteReader::new(&w.into_bytes())).unwrap_err();
        assert!(err.to_string().contains("weight sum"), "{err}");
    }

    #[test]
    fn histogram_serialization_roundtrips_and_rejects_corruption() {
        let mut h = FixedHistogram::new(0.0, 100.0, 40);
        let mut rng = Pcg64::new(8);
        for _ in 0..2_000 {
            h.add(rng.uniform() * 120.0 - 10.0);
        }
        let mut w = ByteWriter::new();
        h.write_to(&mut w);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        let back = FixedHistogram::read_from(&mut r).unwrap();
        assert!(r.is_empty());
        assert_eq!(back.count(), h.count());
        assert_eq!(back.bin_counts(), h.bin_counts());
        assert_eq!(back.underflow(), h.underflow());
        assert_eq!(back.overflow(), h.overflow());
        for q in [0.05, 0.5, 0.95] {
            assert_eq!(back.quantile(q).to_bits(), h.quantile(q).to_bits());
        }
        // inconsistent total is rejected
        let mut w = ByteWriter::new();
        w.f64(0.0);
        w.f64(10.0);
        w.varint(2);
        w.varint(3);
        w.varint(4);
        w.varint(0);
        w.varint(0);
        w.varint(99);
        let err = FixedHistogram::read_from(&mut ByteReader::new(&w.into_bytes())).unwrap_err();
        assert!(err.to_string().contains("disagree"), "{err}");
        // inverted range is rejected
        let mut w = ByteWriter::new();
        w.f64(10.0);
        w.f64(0.0);
        assert!(FixedHistogram::read_from(&mut ByteReader::new(&w.into_bytes())).is_err());
    }
}
