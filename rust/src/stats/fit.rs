//! Distribution fitting: closed-form MLEs, Nelder–Mead MLE for the
//! exponentiated Weibull, nonlinear least squares for the preprocess
//! duration curve, and the paper's SSE-based family selection.

use super::desc::{mean, sse_against_pdf, std_dev};
use super::dist::{Dist, Distribution, ExpWeibull, Exponential, LogNormal, Normal, Pareto, Weibull};
use crate::error::{Error, Result};

// ---------------------------------------------------------------------------
// Nelder–Mead simplex minimizer (dependency-free).
// ---------------------------------------------------------------------------

/// Minimize `f` over R^n starting from `x0` with initial step `step`.
/// Returns (argmin, min). Standard coefficients, adaptive-free.
pub fn nelder_mead(
    f: impl Fn(&[f64]) -> f64,
    x0: &[f64],
    step: f64,
    max_iter: usize,
    tol: f64,
) -> (Vec<f64>, f64) {
    let n = x0.len();
    let (alpha, gamma, rho, sigma) = (1.0, 2.0, 0.5, 0.5);
    // initial simplex
    let mut simplex: Vec<(Vec<f64>, f64)> = Vec::with_capacity(n + 1);
    simplex.push((x0.to_vec(), f(x0)));
    for i in 0..n {
        let mut x = x0.to_vec();
        x[i] += if x[i].abs() > 1e-12 { step * x[i].abs() } else { step };
        let fx = f(&x);
        simplex.push((x, fx));
    }

    for _ in 0..max_iter {
        simplex.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        if (simplex[n].1 - simplex[0].1).abs() < tol * (1.0 + simplex[0].1.abs()) {
            break;
        }
        // centroid of all but worst
        let mut c = vec![0.0; n];
        for (x, _) in &simplex[..n] {
            for (ci, xi) in c.iter_mut().zip(x) {
                *ci += xi / n as f64;
            }
        }
        let worst = simplex[n].clone();
        let refl: Vec<f64> = c.iter().zip(&worst.0).map(|(ci, wi)| ci + alpha * (ci - wi)).collect();
        let f_refl = f(&refl);
        if f_refl < simplex[0].1 {
            // expand
            let exp: Vec<f64> = c.iter().zip(&refl).map(|(ci, ri)| ci + gamma * (ri - ci)).collect();
            let f_exp = f(&exp);
            simplex[n] = if f_exp < f_refl { (exp, f_exp) } else { (refl, f_refl) };
        } else if f_refl < simplex[n - 1].1 {
            simplex[n] = (refl, f_refl);
        } else {
            // contract
            let con: Vec<f64> = c.iter().zip(&worst.0).map(|(ci, wi)| ci + rho * (wi - ci)).collect();
            let f_con = f(&con);
            if f_con < worst.1 {
                simplex[n] = (con, f_con);
            } else {
                // shrink toward best
                let best = simplex[0].0.clone();
                for entry in simplex.iter_mut().skip(1) {
                    let x: Vec<f64> = best
                        .iter()
                        .zip(&entry.0)
                        .map(|(bi, xi)| bi + sigma * (xi - bi))
                        .collect();
                    let fx = f(&x);
                    *entry = (x, fx);
                }
            }
        }
    }
    simplex.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    simplex[0].clone()
}

// ---------------------------------------------------------------------------
// Per-family fitters.
// ---------------------------------------------------------------------------

/// MLE for Normal: sample mean / std.
pub fn fit_normal(xs: &[f64]) -> Result<Normal> {
    if xs.len() < 2 {
        return Err(Error::Stats("fit_normal: need >= 2 points".into()));
    }
    let s = std_dev(xs).max(1e-12);
    Ok(Normal::new(mean(xs), s))
}

/// MLE for LogNormal: Normal MLE on ln(x).
pub fn fit_lognormal(xs: &[f64]) -> Result<LogNormal> {
    if xs.iter().any(|&x| x <= 0.0) {
        return Err(Error::Stats("fit_lognormal: non-positive data".into()));
    }
    let logs: Vec<f64> = xs.iter().map(|x| x.ln()).collect();
    let n = fit_normal(&logs)?;
    Ok(LogNormal::new(n.mu, n.sigma))
}

/// MLE for Exponential: 1 / mean.
pub fn fit_exponential(xs: &[f64]) -> Result<Exponential> {
    let m = mean(xs);
    if m <= 0.0 {
        return Err(Error::Stats("fit_exponential: non-positive mean".into()));
    }
    Ok(Exponential::new(1.0 / m))
}

/// MLE for Pareto with xm = min(x).
pub fn fit_pareto(xs: &[f64]) -> Result<Pareto> {
    let xm = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    if !(xm > 0.0) {
        return Err(Error::Stats("fit_pareto: need positive data".into()));
    }
    let s: f64 = xs.iter().map(|&x| (x / xm).ln()).sum();
    if s <= 0.0 {
        return Err(Error::Stats("fit_pareto: degenerate data".into()));
    }
    Ok(Pareto::new(xm, xs.len() as f64 / s))
}

/// MLE for Weibull via Nelder–Mead on (ln k, ln lambda).
pub fn fit_weibull(xs: &[f64]) -> Result<Weibull> {
    if xs.iter().any(|&x| x <= 0.0) || xs.len() < 8 {
        return Err(Error::Stats("fit_weibull: need >=8 positive points".into()));
    }
    let m = mean(xs);
    let nll = |p: &[f64]| {
        let d = Weibull::new(p[0].exp(), p[1].exp());
        -d.loglik(xs)
    };
    let (p, _) = nelder_mead(nll, &[0.0, m.max(1e-9).ln()], 0.5, 400, 1e-10);
    Ok(Weibull::new(p[0].exp(), p[1].exp()))
}

/// MLE for the exponentiated Weibull via Nelder–Mead on
/// (ln alpha, ln k, ln lambda), multi-start to dodge local optima.
pub fn fit_expweibull(xs: &[f64]) -> Result<ExpWeibull> {
    if xs.iter().any(|&x| x <= 0.0) || xs.len() < 16 {
        return Err(Error::Stats("fit_expweibull: need >=16 positive points".into()));
    }
    let m = mean(xs).max(1e-9);
    let nll = |p: &[f64]| {
        if p.iter().any(|v| v.abs() > 12.0) {
            return f64::INFINITY; // keep parameters in a sane range
        }
        let d = ExpWeibull::new(p[0].exp(), p[1].exp(), p[2].exp());
        -d.loglik(xs)
    };
    let starts = [
        [0.0, 0.0, m.ln()],
        [1.0, -0.5, m.ln()],
        [-0.7, 0.5, m.ln() - 0.7],
    ];
    let mut best: Option<(Vec<f64>, f64)> = None;
    for s in &starts {
        let (p, v) = nelder_mead(&nll, s, 0.4, 600, 1e-10);
        if best.as_ref().map_or(true, |b| v < b.1) {
            best = Some((p, v));
        }
    }
    let (p, v) = best.unwrap();
    if !v.is_finite() {
        return Err(Error::Stats("fit_expweibull: diverged".into()));
    }
    Ok(ExpWeibull::new(p[0].exp(), p[1].exp(), p[2].exp()))
}

// ---------------------------------------------------------------------------
// SSE family selection (paper section V-A3: per-cluster best of
// {lognormal, expweibull, pareto}).
// ---------------------------------------------------------------------------

/// Fit every candidate family and return (best_fit, its SSE), selecting by
/// SSE between the empirical density histogram and the fitted pdf.
pub fn select_best_fit(xs: &[f64], bins: usize) -> Result<(Dist, f64)> {
    let mut candidates: Vec<Dist> = Vec::new();
    if let Ok(d) = fit_lognormal(xs) {
        candidates.push(Dist::LogNormal(d));
    }
    if let Ok(d) = fit_expweibull(xs) {
        candidates.push(Dist::ExpWeibull(d));
    }
    if let Ok(d) = fit_pareto(xs) {
        candidates.push(Dist::Pareto(d));
    }
    if candidates.is_empty() {
        return Err(Error::Stats("select_best_fit: no family fit".into()));
    }
    let mut best: Option<(Dist, f64)> = None;
    for d in candidates {
        let sse = sse_against_pdf(xs, |x| d.pdf(x), bins);
        if best.as_ref().map_or(true, |b| sse < b.1) {
            best = Some((d, sse));
        }
    }
    Ok(best.unwrap())
}

// ---------------------------------------------------------------------------
// Nonlinear least squares for the preprocess curve f(x) = a*b^x + c
// (paper section V-A2a, Fig 9a).
// ---------------------------------------------------------------------------

/// Parameters of f(x) = a * b^x + c.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ExpCurve {
    pub a: f64,
    pub b: f64,
    pub c: f64,
}

impl ExpCurve {
    pub fn eval(&self, x: f64) -> f64 {
        self.a * self.b.powf(x) + self.c
    }
}

/// Fit f(x)=a*b^x+c by Nelder–Mead on the residual SSE, grid-initialized
/// over b (the curve is linear in (a, c) given b, solved in closed form).
pub fn fit_exp_curve(xs: &[f64], ys: &[f64]) -> Result<ExpCurve> {
    if xs.len() != ys.len() || xs.len() < 4 {
        return Err(Error::Stats("fit_exp_curve: need >=4 paired points".into()));
    }
    // Given b, minimize over (a, c) by 2x2 least squares on [b^x, 1].
    let solve_ac = |b: f64| -> (f64, f64, f64) {
        let n = xs.len() as f64;
        let (mut s_t, mut s_tt, mut s_y, mut s_ty) = (0.0, 0.0, 0.0, 0.0);
        for (&x, &y) in xs.iter().zip(ys) {
            let t = b.powf(x);
            s_t += t;
            s_tt += t * t;
            s_y += y;
            s_ty += t * y;
        }
        let det = n * s_tt - s_t * s_t;
        if det.abs() < 1e-12 {
            return (0.0, 0.0, f64::INFINITY);
        }
        let a = (n * s_ty - s_t * s_y) / det;
        let c = (s_y - a * s_t) / n;
        let sse: f64 = xs
            .iter()
            .zip(ys)
            .map(|(&x, &y)| {
                let e = a * b.powf(x) + c - y;
                e * e
            })
            .sum();
        (a, c, sse)
    };

    // grid over b then refine with golden-section
    let mut best_b = 1.1;
    let mut best_sse = f64::INFINITY;
    let mut b = 1.01;
    while b < 3.0 {
        let (_, _, sse) = solve_ac(b);
        if sse < best_sse {
            best_sse = sse;
            best_b = b;
        }
        b += 0.01;
    }
    // golden-section refine in [best_b - 0.02, best_b + 0.02]
    let (mut lo, mut hi) = ((best_b - 0.02).max(1.001), best_b + 0.02);
    let phi = (5f64.sqrt() - 1.0) / 2.0;
    for _ in 0..60 {
        let m1 = hi - phi * (hi - lo);
        let m2 = lo + phi * (hi - lo);
        if solve_ac(m1).2 < solve_ac(m2).2 {
            hi = m2;
        } else {
            lo = m1;
        }
    }
    let bb = 0.5 * (lo + hi);
    let (a, c, sse) = solve_ac(bb);
    if !sse.is_finite() {
        return Err(Error::Stats("fit_exp_curve: singular".into()));
    }
    Ok(ExpCurve { a, b: bb, c })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Pcg64;

    #[test]
    fn nelder_mead_rosenbrock() {
        let f = |p: &[f64]| {
            let (x, y) = (p[0], p[1]);
            (1.0 - x).powi(2) + 100.0 * (y - x * x).powi(2)
        };
        let (p, v) = nelder_mead(f, &[-1.2, 1.0], 0.5, 2000, 1e-14);
        assert!(v < 1e-6, "v={v}");
        assert!((p[0] - 1.0).abs() < 1e-2 && (p[1] - 1.0).abs() < 1e-2);
    }

    #[test]
    fn fit_lognormal_roundtrip() {
        let mut rng = Pcg64::new(1);
        let d = LogNormal::new(3.2, 0.8);
        let xs: Vec<f64> = (0..50_000).map(|_| d.sample(&mut rng)).collect();
        let f = fit_lognormal(&xs).unwrap();
        assert!((f.mu - 3.2).abs() < 0.02, "mu={}", f.mu);
        assert!((f.sigma - 0.8).abs() < 0.02, "sigma={}", f.sigma);
    }

    #[test]
    fn fit_exponential_roundtrip() {
        let mut rng = Pcg64::new(2);
        let d = Exponential::new(0.25);
        let xs: Vec<f64> = (0..50_000).map(|_| d.sample(&mut rng)).collect();
        let f = fit_exponential(&xs).unwrap();
        assert!((f.lambda - 0.25).abs() < 0.01);
    }

    #[test]
    fn fit_pareto_roundtrip() {
        let mut rng = Pcg64::new(3);
        let d = Pareto::new(2.0, 1.8);
        let xs: Vec<f64> = (0..50_000).map(|_| d.sample(&mut rng)).collect();
        let f = fit_pareto(&xs).unwrap();
        assert!((f.xm - 2.0).abs() < 0.01, "xm={}", f.xm);
        assert!((f.alpha - 1.8).abs() < 0.05, "alpha={}", f.alpha);
    }

    #[test]
    fn fit_weibull_roundtrip() {
        let mut rng = Pcg64::new(4);
        let d = Weibull::new(1.7, 12.0);
        let xs: Vec<f64> = (0..30_000).map(|_| d.sample(&mut rng)).collect();
        let f = fit_weibull(&xs).unwrap();
        assert!((f.k - 1.7).abs() < 0.05, "k={}", f.k);
        assert!((f.lambda - 12.0).abs() < 0.3, "lambda={}", f.lambda);
    }

    #[test]
    fn fit_expweibull_recovers_shape() {
        let mut rng = Pcg64::new(5);
        let d = ExpWeibull::new(2.0, 0.9, 40.0);
        let xs: Vec<f64> = (0..30_000).map(|_| d.sample(&mut rng)).collect();
        let f = fit_expweibull(&xs).unwrap();
        // the (alpha, k, lambda) surface is fairly flat; check the implied
        // distribution matches rather than raw parameters.
        for &p in &[0.1, 0.25, 0.5, 0.75, 0.9] {
            let (qd, qf) = (d.quantile(p), f.quantile(p));
            assert!(
                (qd - qf).abs() / qd < 0.08,
                "p={p}: true q={qd} fit q={qf} ({f:?})"
            );
        }
    }

    #[test]
    fn select_best_prefers_true_family() {
        let mut rng = Pcg64::new(6);
        let d = LogNormal::new(2.0, 1.0);
        let xs: Vec<f64> = (0..40_000).map(|_| d.sample(&mut rng)).collect();
        let (best, _) = select_best_fit(&xs, 60).unwrap();
        assert_eq!(best.name(), "lognormal");

        let d2 = Pareto::new(1.0, 1.2);
        let xs2: Vec<f64> = (0..40_000).map(|_| d2.sample(&mut rng)).collect();
        let (best2, _) = select_best_fit(&xs2, 60).unwrap();
        assert_eq!(best2.name(), "pareto");
    }

    #[test]
    fn exp_curve_recovers_paper_params() {
        // the paper's production fit: a=0.018, b=1.330, c=2.156
        let truth = ExpCurve { a: 0.018, b: 1.330, c: 2.156 };
        let mut rng = Pcg64::new(7);
        let xs: Vec<f64> = (0..2000).map(|_| rng.uniform_range(2.0, 18.0)).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|&x| truth.eval(x) + 0.02 * rng.normal())
            .collect();
        let fit = fit_exp_curve(&xs, &ys).unwrap();
        assert!((fit.b - 1.330).abs() < 0.01, "b={}", fit.b);
        assert!((fit.a - 0.018).abs() < 0.005, "a={}", fit.a);
        assert!((fit.c - 2.156).abs() < 0.1, "c={}", fit.c);
    }

    #[test]
    fn exp_curve_eval() {
        let c = ExpCurve { a: 2.0, b: 2.0, c: 1.0 };
        assert_eq!(c.eval(3.0), 17.0);
    }

    #[test]
    fn fitters_reject_bad_input() {
        assert!(fit_lognormal(&[1.0, -2.0]).is_err());
        assert!(fit_normal(&[1.0]).is_err());
        assert!(fit_exp_curve(&[1.0], &[1.0]).is_err());
        assert!(fit_pareto(&[0.0, 1.0]).is_err());
    }
}
