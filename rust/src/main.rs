//! PipeSim CLI — generate the empirical substrate, fit simulation
//! parameters, run experiments, and regenerate every figure/table of the
//! paper's evaluation.
//!
//! Subcommands:
//!   gen-empirical  --weeks N --seed S --out DB.json
//!   fit            --db DB.json --out PARAMS.json [--cpu]
//!   simulate       --params PARAMS.json [--config CFG.json] [--days D]
//!                  [--arrival random|profile|poisson:SECS] [--seed S]
//!                  [--scheduler SPEC] [--trigger SPEC] [--retry SPEC]
//!                  [--retention SECS] [--metrics FILE]
//!                  [--cpu] [--export CSV]
//!                  — --retry sets the task-fault retry policy (the
//!                  fault model itself comes from the config file's
//!                  `faults` block)
//!                  — --retention rolls the run's time series into
//!                  fixed windows of that many seconds (bounded memory,
//!                  sketched quantiles) instead of keeping raw points;
//!                  --metrics turns the self-profiling meter on and
//!                  writes the run's metrics to FILE (OpenMetrics text,
//!                  or JSON when FILE ends in .json)
//!   sweep          --params PARAMS.json [--config CFG.json] [--days D]
//!                  [--arrival MODE] [--seeds N] [--seed0 S] [--jobs N]
//!                  [--capacities 2,4,8] [--factors 0.5,1,2]
//!                  [--schedulers fifo,sjf,edf:slack_per_class=900]
//!                  [--schedulers-training LIST] [--schedulers-compute LIST]
//!                  [--triggers never,drift_threshold:threshold=0.05]
//!                  [--mtbf 3600,14400,inf] [--mttr 600]
//!                  [--checkpoint-intervals 0,600,3600]
//!                  [--fault-rates 3600,inf] [--retries always,exp_backoff]
//!                  [--queue-caps 0,64]
//!                  [--hw-classes a100:2:2.0:0.004+k80:6:1.0:0.001,v100:8]
//!                  [--placers fastest_fit,cheapest_fit,pack,spread]
//!                  [--traces] [--trace-dir DIR] [--retention SECS]
//!                  [--metrics-dir DIR] [--cpu] [--export CSV]
//!                  [--shard k/N] [--manifest FILE]
//!                  — parallel replication/grid engine over capacities ×
//!                  load factors × operational strategies × reliability ×
//!                  hardware classes (per-cell tsdb recording off unless
//!                  --traces; --trace-dir streams one binary event trace
//!                  per cell to disk as it runs, so captures stay
//!                  memory-flat; --metrics-dir meters every cell and
//!                  streams one OpenMetrics file per cell from the
//!                  worker that ran it, and --retention bounds each
//!                  cell's tsdb via windowed downsampling; the
//!                  per-cluster scheduler lists override
//!                  the shared --schedulers axis for the training/compute
//!                  cluster respectively; --mtbf injects exponential slot
//!                  failures on the training cluster with mean repair
//!                  --mttr, 'inf' = failures off, and
//!                  --checkpoint-intervals varies the checkpoint period
//!                  of every failing cluster; --fault-rates injects
//!                  transient *task* faults on both clusters with the
//!                  given mean time-to-fault in seconds ('inf' = faults
//!                  off), --retries varies the retry policy consulted
//!                  after each fault/timeout, and --queue-caps varies
//!                  the training cluster's admission-control bound
//!                  (0 = shedding off); --hw-classes variants are
//!                  comma-separated training-cluster class mixes, classes
//!                  '+'-joined with fields name:slots[:speed[:cost_per_sec]],
//!                  and --placers varies the placement strategy over them;
//!                  --shard k/N runs only every N-th cell of the exact
//!                  same grid — global cell indices, names, and output
//!                  filenames are shard-invariant — and writes a binary
//!                  shard manifest, default sweep-shard-K-of-N.psm, that
//!                  sweep-merge later combines; --manifest overrides the
//!                  manifest path and also writes one for a full run)
//!   sweep-merge    --shards A.psm,B.psm,... [--dir DIR] [--export CSV]
//!                  [--metrics FILE] — combine the N shard manifests of
//!                  one sweep back into the single-process surface:
//!                  per-cell digests byte-identical and group mean/CI
//!                  tables bit-identical to an unsharded run, quantiles
//!                  sketch-merged, plus a Pareto-front report over
//!                  (capacity, wait, utilization, cost); rejects
//!                  overlapping, missing, or mismatched shards
//!   trace export   --params PARAMS.json [--config CFG.json] [--days D]
//!                  [--arrival MODE] [--seed S] [--scheduler SPEC]
//!                  [--out T.pst] [--jsonl T.jsonl] [--cpu] — run with
//!                  event capture on and write the binary trace
//!   trace stats    --in T.pst [--params PARAMS.json] — summary
//!                  statistics, streamed record-by-record so year-scale
//!                  files never materialize in memory (+ Q-Q vs the
//!                  fits when params given)
//!   trace replay   --in T.pst --params PARAMS.json [--cpu] — re-drive
//!                  the simulation from the recorded arrival gaps,
//!                  streamed record-by-record off the file (year-scale
//!                  captures replay without materializing the event
//!                  list); byte-identical digest given the capture's
//!                  params
//!   figures        --fig 8|9a|9b|10|11|12|table1|all [--out-dir DIR]
//!   table1
//!   qq             --db DB.json --params PARAMS.json [--days D] [--cpu]
//!   scale          --params PARAMS.json --counts 1000,10000 [--cpu]
//!
//! Strategy SPECs are `name` or `name:key=value:key=value`; names come
//! from the strategy registry (`pipesim::coordinator::scheduler_names`).
//! `fit --out params.bin` writes the binary parameter cache instead of
//! JSON; `simulate`/`sweep`/`trace` auto-detect either format.

use std::path::PathBuf;
use std::sync::Arc;

use pipesim::analytics::{
    figures, pareto_front, render_dashboard, render_pareto, trace_qq_file, TraceSummary,
};
use pipesim::coordinator::{
    fit_params_with_report, merge_shards, ArrivalSpec, Experiment, ExperimentConfig,
    RetentionConfig, ShardManifest, ShardSpec, SimParams, StrategySpec, Sweep,
};
use pipesim::des::DAY;
use pipesim::empirical::{AnalyticsDb, GroundTruth};
use pipesim::error::Error;
use pipesim::model::{
    ClusterFailureConfig, FailureModel, FaultModel, HwClass, HwClasses, TaskFaultConfig,
};
use pipesim::obs::{render_metrics_json, render_openmetrics, render_sweep_openmetrics};
use pipesim::runtime::Runtime;
use pipesim::trace::{StreamingPstSink, TraceScanner, TraceWorkload};
use pipesim::util::Args;
use pipesim::Result;

const USAGE: &str = "usage: pipesim \
     <gen-empirical|fit|simulate|sweep|sweep-merge|trace|figures|table1|qq|scale> [--options]
       pipesim trace <export|stats|replay> [--options]
run `pipesim <subcommand> --help` semantics: see README.md";

fn load_runtime(cpu: bool) -> Option<Arc<Runtime>> {
    if cpu {
        return None;
    }
    match Runtime::load_default() {
        Some(rt) => {
            eprintln!("runtime: PJRT artifacts loaded");
            Some(Arc::new(rt))
        }
        None => {
            eprintln!("runtime: artifacts not found, using CPU sampler fallback");
            None
        }
    }
}

/// Filesystem-safe version of a sweep cell name (strategy labels contain
/// `:` and `=`).
fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '.' { c } else { '_' })
        .collect()
}

fn parse_arrival(s: &str) -> Result<ArrivalSpec> {
    match s {
        "random" => Ok(ArrivalSpec::Random),
        "profile" => Ok(ArrivalSpec::Profile),
        "replay" => Ok(ArrivalSpec::Replay),
        other => {
            if let Some(rest) = other.strip_prefix("poisson:") {
                Ok(ArrivalSpec::Poisson {
                    mean_interarrival: rest.parse()?,
                })
            } else {
                Err(Error::Config(format!("unknown arrival mode {other}")))
            }
        }
    }
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let sub = args.subcommand.clone().unwrap_or_default();
    // only the grouped `trace` subcommand takes a second positional
    if sub != "trace" {
        if let Some(action) = &args.action {
            eprintln!("unexpected argument '{action}'\n{USAGE}");
            std::process::exit(2);
        }
    }
    match sub.as_str() {
        "gen-empirical" => {
            let weeks: u32 = args.get_parse("weeks", 8)?;
            let seed: u64 = args.get_parse("seed", 42)?;
            let out = PathBuf::from(args.get("out", "empirical_db.json"));
            args.reject_unknown()?;
            let db = GroundTruth::new(seed).generate_weeks(weeks);
            println!("{}", db.summary());
            db.save(&out)?;
            println!("wrote {}", out.display());
        }

        "fit" => {
            let db_path = PathBuf::from(args.get("db", "empirical_db.json"));
            let out = PathBuf::from(args.get("out", "sim_params.json"));
            let cpu = args.flag("cpu");
            args.reject_unknown()?;
            let db = AnalyticsDb::load(&db_path)?;
            println!("{}", db.summary());
            let rt = load_runtime(cpu);
            let (params, report) = fit_params_with_report(&db, rt)?;
            println!(
                "fit ({} backend): {} assets (loglik {:.0}, {} EM iters), curve a={:.4} b={:.4} c={:.3}, {:.2}s",
                report.backend,
                report.asset_rows,
                report.asset_loglik,
                report.asset_iters,
                params.preproc_curve.a,
                params.preproc_curve.b,
                params.preproc_curve.c,
                report.wall_secs
            );
            for (fam, n) in &report.profile_families {
                println!("  arrival profile: {n:>4} clusters -> {fam}");
            }
            params.save(&out)?;
            println!("wrote {}", out.display());
        }

        "simulate" => {
            let params = SimParams::load(&PathBuf::from(args.get("params", "sim_params.json")))?;
            let mut cfg = match args.get_opt("config") {
                Some(p) => ExperimentConfig::load(&PathBuf::from(p))?,
                None => ExperimentConfig::default(),
            };
            if let Some(d) = args.get_parse_opt::<f64>("days")? {
                cfg.horizon = d * DAY;
            }
            if let Some(a) = args.get_opt("arrival") {
                cfg.arrival = parse_arrival(&a)?;
            }
            if let Some(s) = args.get_parse_opt::<u64>("seed")? {
                cfg.seed = s;
            }
            if let Some(s) = args.get_opt("scheduler") {
                cfg.infra.scheduler = StrategySpec::parse(&s)?;
            }
            if let Some(s) = args.get_opt("trigger") {
                cfg.runtime_view.trigger = StrategySpec::parse(&s)?;
                if !cfg.runtime_view.enabled {
                    eprintln!("trigger: enabling the runtime view (defaults)");
                    cfg.runtime_view.enabled = true;
                }
            }
            if let Some(s) = args.get_opt("retry") {
                // the policy rides on the fault model; without a
                // `faults` block in the config it can never be consulted,
                // so materialize an (inert) model to carry it
                cfg.infra
                    .faults
                    .get_or_insert_with(FaultModel::default)
                    .retry = StrategySpec::parse(&s)?;
            }
            if let Some(r) = args.get_parse_opt::<f64>("retention")? {
                cfg.retention = Some(RetentionConfig { resolution: r });
            }
            // --metrics implies the meter: an export with all-zero
            // self-profiling families would be worse than an error
            let metrics = args.get_opt("metrics");
            if metrics.is_some() {
                cfg.meter = true;
            }
            let cpu = args.flag("cpu");
            let export = args.get_opt("export");
            args.reject_unknown()?;
            let rt = load_runtime(cpu);
            let result = Experiment::new(cfg, params).with_runtime(rt).run()?;
            println!("{}", render_dashboard(&result, 72));
            if let Some(path) = export {
                let mut f = std::io::BufWriter::new(std::fs::File::create(&path)?);
                result.tsdb.export_csv(&mut f)?;
                println!("traces -> {path}");
            }
            if let Some(path) = metrics {
                let text = if path.ends_with(".json") {
                    render_metrics_json(&result)
                } else {
                    render_openmetrics(&result)
                };
                std::fs::write(&path, text)?;
                println!("metrics -> {path}");
            }
        }

        "sweep" => {
            let params = SimParams::load(&PathBuf::from(args.get("params", "sim_params.json")))?;
            let mut base = match args.get_opt("config") {
                Some(p) => ExperimentConfig::load(&PathBuf::from(p))?,
                None => ExperimentConfig::default(),
            };
            if let Some(d) = args.get_parse_opt::<f64>("days")? {
                base.horizon = d * DAY;
            }
            if let Some(a) = args.get_opt("arrival") {
                base.arrival = parse_arrival(&a)?;
            }
            let seeds: usize = args.get_parse("seeds", 8)?;
            let seed0: u64 = args.get_parse("seed0", 1)?;
            let jobs: usize = args.get_parse("jobs", 0)?;
            let capacities = args.get_opt("capacities");
            let factors = args.get_opt("factors");
            let schedulers = args.get_opt("schedulers");
            let schedulers_training = args.get_opt("schedulers-training");
            let schedulers_compute = args.get_opt("schedulers-compute");
            let triggers = args.get_opt("triggers");
            let mtbf = args.get_opt("mtbf");
            let mttr: f64 = args.get_parse("mttr", 600.0)?;
            let checkpoint_intervals = args.get_opt("checkpoint-intervals");
            let fault_rates = args.get_opt("fault-rates");
            let retries = args.get_opt("retries");
            let queue_caps = args.get_opt("queue-caps");
            let hw_classes = args.get_opt("hw-classes");
            let placers = args.get_opt("placers");
            let cpu = args.flag("cpu");
            // traces off by default: a sweep keeps every cell's result in
            // memory until aggregation, and nothing downstream reads the
            // per-cell trace stores unless the user asks for them
            base.record_traces = args.flag("traces");
            // --trace-dir: stream every cell's event-level trace to its
            // own .pst file while the cell runs (StreamingPstSink per
            // cell — the capture never accumulates in memory)
            let trace_dir = args.get_opt("trace-dir").map(PathBuf::from);
            if let Some(r) = args.get_parse_opt::<f64>("retention")? {
                base.retention = Some(RetentionConfig { resolution: r });
            }
            // --metrics-dir: meter every cell and stream one OpenMetrics
            // file per cell from the worker thread that ran it
            let metrics_dir = args.get_opt("metrics-dir").map(PathBuf::from);
            if metrics_dir.is_some() {
                base.meter = true;
            }
            let export = args.get_opt("export");
            // --shard k/N: enumerate the identical grid but run only
            // the cells whose global index i satisfies i % N == k; the
            // manifest written at the end is sweep-merge's input
            let shard = match args.get_opt("shard") {
                Some(s) => Some(ShardSpec::parse(&s)?),
                None => None,
            };
            let manifest_path = match (args.get_opt("manifest"), shard) {
                (Some(p), _) => Some(PathBuf::from(p)),
                (None, Some(s)) => {
                    Some(format!("sweep-shard-{}-of-{}.psm", s.index, s.count).into())
                }
                (None, None) => None,
            };
            args.reject_unknown()?;

            // the grid: base × training capacities × interarrival factors,
            // each cell replicated `seeds` times
            let caps: Vec<Option<usize>> = match &capacities {
                Some(list) => list
                    .split(',')
                    .map(|v| {
                        let c: usize = v.trim().parse()?;
                        if c == 0 {
                            return Err(Error::Config(
                                "--capacities: capacity must be >= 1".into(),
                            ));
                        }
                        Ok(Some(c))
                    })
                    .collect::<Result<_>>()?,
                None => vec![None],
            };
            let facs: Vec<Option<f64>> = match &factors {
                Some(list) => list
                    .split(',')
                    .map(|v| v.trim().parse::<f64>().map(Some).map_err(Error::from))
                    .collect::<Result<_>>()?,
                None => vec![None],
            };
            // operational strategies are sweep axes like capacity/load:
            // a spec list is `name[:key=value...]` items, comma-separated
            let spec_axis = |list: &Option<String>| -> Result<Vec<Option<StrategySpec>>> {
                match list {
                    Some(list) => list
                        .split(',')
                        .map(|v| StrategySpec::parse(v.trim()).map(Some))
                        .collect(),
                    None => Ok(vec![None]),
                }
            };
            let scheds = spec_axis(&schedulers)?;
            // per-cluster scheduler axes (override the shared spec for
            // one cluster only — `infra.scheduler_training/_compute`)
            let scheds_t = spec_axis(&schedulers_training)?;
            let scheds_c = spec_axis(&schedulers_compute)?;
            let trigs = spec_axis(&triggers)?;
            // reliability axes: mean-time-between-failures values in
            // seconds ('inf' = a perfectly reliable cell, i.e. failures
            // off) × checkpoint periods in seconds of task progress
            if mttr <= 0.0 {
                return Err(Error::Config("--mttr: mean must be > 0".into()));
            }
            let mtbfs: Vec<Option<f64>> = match &mtbf {
                Some(list) => list
                    .split(',')
                    .map(|v| {
                        let v = v.trim();
                        if v == "inf" {
                            return Ok(Some(f64::INFINITY));
                        }
                        let m: f64 = v.parse()?;
                        if m <= 0.0 {
                            return Err(Error::Config(
                                "--mtbf: mean must be > 0 seconds (or 'inf')".into(),
                            ));
                        }
                        Ok(Some(m))
                    })
                    .collect::<Result<_>>()?,
                None => vec![None],
            };
            let ckpts: Vec<Option<f64>> = match &checkpoint_intervals {
                Some(list) => list
                    .split(',')
                    .map(|v| {
                        let c: f64 = v.trim().parse()?;
                        if c < 0.0 || !c.is_finite() {
                            return Err(Error::Config(
                                "--checkpoint-intervals: period must be finite and >= 0".into(),
                            ));
                        }
                        Ok(Some(c))
                    })
                    .collect::<Result<_>>()?,
                None => vec![None],
            };
            // task-fault axes: mean time-to-transient-fault in seconds
            // ('inf' = a fault-free cell) × retry policies × training
            // admission-control queue caps (0 = shedding off)
            let faults_axis: Vec<Option<f64>> = match &fault_rates {
                Some(list) => list
                    .split(',')
                    .map(|v| {
                        let v = v.trim();
                        if v == "inf" {
                            return Ok(Some(f64::INFINITY));
                        }
                        let m: f64 = v.parse()?;
                        if m <= 0.0 {
                            return Err(Error::Config(
                                "--fault-rates: mean must be > 0 seconds (or 'inf')".into(),
                            ));
                        }
                        Ok(Some(m))
                    })
                    .collect::<Result<_>>()?,
                None => vec![None],
            };
            let retry_axis = spec_axis(&retries)?;
            let caps_axis: Vec<Option<u64>> = match &queue_caps {
                Some(list) => list
                    .split(',')
                    .map(|v| v.trim().parse::<u64>().map(Some).map_err(Error::from))
                    .collect::<Result<_>>()?,
                None => vec![None],
            };
            // hardware-class axes: each --hw-classes variant is a
            // training-cluster class mix (classes joined by '+', fields
            // name:slots[:speed[:cost_per_sec]]); --placers varies the
            // placement strategy over whatever classes are configured
            let hw_axis: Vec<Option<Vec<HwClass>>> = match &hw_classes {
                Some(list) => list
                    .split(',')
                    .map(|variant| {
                        let mut classes = Vec::new();
                        for spec in variant.trim().split('+') {
                            let parts: Vec<&str> = spec.trim().split(':').collect();
                            if parts.len() < 2 || parts.len() > 4 || parts[0].is_empty() {
                                return Err(Error::Config(format!(
                                    "--hw-classes: '{spec}' is not name:slots[:speed[:cost_per_sec]]"
                                )));
                            }
                            let slots: usize = parts[1].parse()?;
                            let mut hc = HwClass::new(parts[0], slots);
                            if let Some(s) = parts.get(2) {
                                hc = hc.with_speed(s.parse()?);
                            }
                            if let Some(c) = parts.get(3) {
                                hc = hc.with_cost(c.parse()?);
                            }
                            classes.push(hc);
                        }
                        Ok(Some(classes))
                    })
                    .collect::<Result<_>>()?,
                None => vec![None],
            };
            let placer_axis = spec_axis(&placers)?;
            if placers.is_some() && hw_classes.is_none() && base.infra.hw_classes.is_none() {
                return Err(Error::Config(
                    "--placers: requires hardware classes (--hw-classes or hw_classes in the config)"
                        .into(),
                ));
            }
            if triggers.is_some() && !base.runtime_view.enabled {
                eprintln!("triggers: enabling the runtime view (defaults)");
                base.runtime_view.enabled = true;
            }
            let rt = load_runtime(cpu);
            let mut sweep = Sweep::new(params).with_runtime(rt).jobs(jobs).shard(shard);
            // the grid is the cartesian product of the axes, built by a
            // fold: each axis multiplies the current cell list by its
            // variants, each variant a labeled config edit (None = keep
            // the base value, no label suffix). Earlier axes vary
            // slowest — the same cell order the old nested loops
            // produced. Adding an axis is one `axes.push`.
            type Edit = Box<dyn Fn(&mut ExperimentConfig, &mut String)>;
            fn axis<T: Clone + 'static>(
                variants: &[Option<T>],
                apply: impl Fn(&T, &mut ExperimentConfig, &mut String) + Copy + 'static,
            ) -> Vec<Edit> {
                variants
                    .iter()
                    .map(|v| -> Edit {
                        let v = v.clone();
                        Box::new(move |cfg, name| {
                            if let Some(v) = &v {
                                apply(v, cfg, name);
                            }
                        })
                    })
                    .collect()
            }
            let axes: Vec<Vec<Edit>> = vec![
                axis(&caps, |c, cfg, name| {
                    cfg.infra.training_capacity = *c;
                    name.push_str(&format!("-cap{c}"));
                }),
                axis(&facs, |f, cfg, name| {
                    cfg.interarrival_factor = *f;
                    name.push_str(&format!("-x{f}"));
                }),
                axis(&scheds, |s, cfg, name| {
                    cfg.infra.scheduler = s.clone();
                    name.push_str(&format!("-{}", s.label()));
                }),
                axis(&scheds_t, |s, cfg, name| {
                    cfg.infra.scheduler_training = Some(s.clone());
                    name.push_str(&format!("-tr:{}", s.label()));
                }),
                axis(&scheds_c, |s, cfg, name| {
                    cfg.infra.scheduler_compute = Some(s.clone());
                    name.push_str(&format!("-co:{}", s.label()));
                }),
                axis(&trigs, |tr, cfg, name| {
                    cfg.runtime_view.trigger = tr.clone();
                    name.push_str(&format!("-trig:{}", tr.label()));
                }),
                // --mtbf varies failure pressure on the training cluster
                // (the saturating one); a config-file failure model keeps
                // its checkpoint/restart knobs, only the MTBF is swept.
                // 'inf' clears the whole model so the cell is the exact
                // failure-free baseline (digest-identical to no subsystem)
                axis(&mtbfs, move |m, cfg, name| {
                    if m.is_infinite() {
                        cfg.infra.failures = None;
                        name.push_str("-mtbf:inf");
                    } else {
                        let fresh = ClusterFailureConfig::exponential(*m, mttr);
                        let fm = cfg.infra.failures.get_or_insert_with(FailureModel::default);
                        fm.training = Some(match fm.training.take() {
                            Some(old) => ClusterFailureConfig {
                                mtbf: fresh.mtbf,
                                ..old
                            },
                            None => fresh,
                        });
                        name.push_str(&format!("-mtbf{m}"));
                    }
                }),
                // --checkpoint-intervals retunes every failing cluster;
                // a no-op (label only) on cells without a failure model
                axis(&ckpts, |ci, cfg, name| {
                    if let Some(fm) = &mut cfg.infra.failures {
                        for fc in [&mut fm.training, &mut fm.compute] {
                            if let Some(fc) = fc {
                                fc.checkpoint_interval = *ci;
                            }
                        }
                    }
                    name.push_str(&format!("-ckpt{ci}"));
                }),
                // --fault-rates varies transient *task* faults on both
                // clusters; a config-file fault model keeps its timeout/
                // queue-cap/retry knobs, only the fault-time distribution
                // is swept. 'inf' clears the fault-time on every cluster,
                // making the cell the exact fault-free baseline (an inert
                // fault config is digest-identical to none at all)
                axis(&faults_axis, |m, cfg, name| {
                    if m.is_infinite() {
                        if let Some(fm) = &mut cfg.infra.faults {
                            for fc in [&mut fm.training, &mut fm.compute] {
                                if let Some(fc) = fc {
                                    fc.fault_time = None;
                                }
                            }
                        }
                        name.push_str("-fault:inf");
                    } else {
                        let fresh = TaskFaultConfig::transient(*m);
                        let fm = cfg.infra.faults.get_or_insert_with(FaultModel::default);
                        for fc in [&mut fm.training, &mut fm.compute] {
                            let base = fc.take().unwrap_or_default();
                            *fc = Some(TaskFaultConfig {
                                fault_time: fresh.fault_time.clone(),
                                ..base
                            });
                        }
                        name.push_str(&format!("-fault{m}"));
                    }
                }),
                // --retries varies the policy consulted after each task
                // fault/timeout; it rides on the fault model, so a cell
                // without one gets an inert carrier (label still applies
                // for grid-shape invariance)
                axis(&retry_axis, |s, cfg, name| {
                    cfg.infra.faults.get_or_insert_with(FaultModel::default).retry = s.clone();
                    name.push_str(&format!("-re:{}", s.label()));
                }),
                // --queue-caps varies the training cluster's admission-
                // control bound (the saturating cluster, like --mtbf);
                // 0 turns shedding off
                axis(&caps_axis, |q, cfg, name| {
                    let fm = cfg.infra.faults.get_or_insert_with(FaultModel::default);
                    let base = fm.training.take().unwrap_or_default();
                    fm.training = Some(TaskFaultConfig {
                        queue_cap: *q,
                        ..base
                    });
                    name.push_str(&format!("-qcap{q}"));
                }),
                // --hw-classes replaces the training cluster's class mix
                // (capacity follows the slot sum so the cell is
                // apples-to-apples with a homogeneous pool of the same
                // size); applied before --placers so the placer axis
                // always finds classes to act on
                axis(&hw_axis, |classes, cfg, name| {
                    let total: usize = classes.iter().map(|c| c.slots).sum();
                    let hw = cfg.infra.hw_classes.get_or_insert_with(HwClasses::default);
                    hw.training = classes.clone();
                    cfg.infra.training_capacity = total;
                    let label = classes
                        .iter()
                        .map(|c| format!("{}{}", c.name, c.slots))
                        .collect::<Vec<_>>()
                        .join("+");
                    name.push_str(&format!("-hw:{label}"));
                }),
                axis(&placer_axis, |p, cfg, name| {
                    if let Some(hw) = &mut cfg.infra.hw_classes {
                        hw.placer = p.clone();
                    }
                    name.push_str(&format!("-pl:{}", p.label()));
                }),
            ];
            let mut grid = vec![(base.clone(), base.name.clone())];
            for variants in &axes {
                let mut next = Vec::with_capacity(grid.len() * variants.len());
                for (cfg, name) in &grid {
                    for edit in variants {
                        let mut cfg = cfg.clone();
                        let mut name = name.clone();
                        edit(&mut cfg, &mut name);
                        next.push((cfg, name));
                    }
                }
                grid = next;
            }
            let groups = grid.len();
            for (mut cfg, name) in grid {
                cfg.name = name;
                sweep.add_replications(&cfg, seed0, seeds);
            }
            let cell_count = sweep.len();
            match shard {
                Some(sp) => eprintln!(
                    "sweep: {cell_count} cells ({groups} groups x {seeds} seeds), shard {sp}"
                ),
                None => eprintln!("sweep: {cell_count} cells ({groups} groups x {seeds} seeds)"),
            }
            if let Some(dir) = &trace_dir {
                // one streaming sink per cell: each cell's events go
                // straight to its .pst file from the worker thread, so
                // a year-scale sweep capture never lives in memory
                std::fs::create_dir_all(dir)?;
                let dir = dir.clone();
                sweep = sweep.with_cell_sinks(Box::new(move |i, cfg| {
                    let file = dir
                        .join(format!("cell{i:04}-{}-s{}.pst", sanitize(&cfg.name), cfg.seed));
                    let sink: Box<dyn pipesim::trace::TraceSink> =
                        Box::new(StreamingPstSink::create(file, &cfg.trace_meta())?);
                    Ok(sink)
                }));
            }
            if let Some(dir) = &metrics_dir {
                std::fs::create_dir_all(dir)?;
                let dir = dir.clone();
                sweep = sweep.with_cell_hook(Box::new(move |i, cfg, r| {
                    let file = dir
                        .join(format!("cell{i:04}-{}-s{}.om", sanitize(&cfg.name), cfg.seed));
                    std::fs::write(file, render_openmetrics(r))?;
                    Ok(())
                }));
            }
            let out = sweep.run()?;
            print!("{}", out.table());
            if let Some(path) = export {
                std::fs::write(&path, out.to_csv())?;
                println!("cells -> {path}");
            }
            if let Some(path) = &manifest_path {
                out.manifest().save(path)?;
                println!("shard manifest ({} cells) -> {}", out.cells.len(), path.display());
            }
            if let Some(dir) = &trace_dir {
                println!("{cell_count} event traces (streamed) -> {}", dir.display());
            }
            if let Some(dir) = &metrics_dir {
                println!("{cell_count} metrics files -> {}", dir.display());
            }
        }

        // combine the shard manifests of one sweep (run with --shard
        // k/N across hosts) back into the single-process result surface
        "sweep-merge" => {
            let shards = args.get_opt("shards");
            let dir = args.get_opt("dir").map(PathBuf::from);
            let export = args.get_opt("export");
            let metrics = args.get_opt("metrics");
            args.reject_unknown()?;
            let mut paths: Vec<PathBuf> = Vec::new();
            if let Some(list) = &shards {
                paths.extend(list.split(',').map(|p| PathBuf::from(p.trim())));
            }
            if let Some(dir) = &dir {
                // scan the directory for *.psm, name-sorted so the
                // invocation is reproducible (merge order is irrelevant
                // to the output anyway — manifests sort by shard index)
                let mut found: Vec<PathBuf> = std::fs::read_dir(dir)?
                    .filter_map(|e| e.ok().map(|e| e.path()))
                    .filter(|p| p.extension().is_some_and(|x| x == "psm"))
                    .collect();
                found.sort();
                paths.extend(found);
            }
            if paths.is_empty() {
                return Err(Error::Config(
                    "sweep-merge: no shard manifests (--shards a.psm,b.psm and/or --dir DIR)"
                        .into(),
                ));
            }
            let manifests = paths
                .iter()
                .map(ShardManifest::load)
                .collect::<Result<Vec<_>>>()?;
            let merged = merge_shards(manifests)?;
            print!("{}", merged.table());
            print!("{}", render_pareto(&pareto_front(&merged.groups)));
            if let Some(path) = export {
                std::fs::write(&path, merged.to_csv())?;
                println!("cells -> {path}");
            }
            if let Some(path) = metrics {
                std::fs::write(&path, render_sweep_openmetrics(&merged))?;
                println!("metrics -> {path}");
            }
        }

        "trace" => match args.action.as_deref().unwrap_or("") {
            // run a simulation with event capture on; write the binary
            // trace (and optionally a JSON-lines mirror)
            "export" => {
                let params =
                    SimParams::load(&PathBuf::from(args.get("params", "sim_params.json")))?;
                let mut cfg = match args.get_opt("config") {
                    Some(p) => ExperimentConfig::load(&PathBuf::from(p))?,
                    None => ExperimentConfig::default(),
                };
                if let Some(d) = args.get_parse_opt::<f64>("days")? {
                    cfg.horizon = d * DAY;
                }
                if let Some(a) = args.get_opt("arrival") {
                    cfg.arrival = parse_arrival(&a)?;
                }
                if let Some(s) = args.get_parse_opt::<u64>("seed")? {
                    cfg.seed = s;
                }
                if let Some(s) = args.get_opt("scheduler") {
                    cfg.infra.scheduler = StrategySpec::parse(&s)?;
                }
                cfg.capture_trace = true;
                let out = PathBuf::from(args.get("out", "trace.pst"));
                let jsonl = args.get_opt("jsonl");
                let cpu = args.flag("cpu");
                args.reject_unknown()?;
                let rt = load_runtime(cpu);
                let mut result = Experiment::new(cfg, params).with_runtime(rt).run()?;
                let trace = result.trace.take().expect("capture_trace was set");
                trace.save(&out)?;
                println!(
                    "trace: {} events, {} arrivals -> {}",
                    trace.len(),
                    result.arrived,
                    out.display()
                );
                if let Some(path) = jsonl {
                    // stream the mirror off the .pst just written — the
                    // jsonl text never materializes as one giant String
                    let mut w = std::io::BufWriter::new(std::fs::File::create(&path)?);
                    TraceScanner::open(&out)?.write_jsonl(&mut w)?;
                    println!("jsonl -> {path}");
                }
                println!("digest: {}", result.digest());
            }

            // summary statistics (+ accuracy vs the fits with --params)
            "stats" => {
                let input = PathBuf::from(args.get("in", "trace.pst"));
                let params_path = args.get_opt("params");
                let jsonl = args.get_opt("jsonl");
                args.reject_unknown()?;
                // every path here streams through TraceScanner record
                // by record — the summary, the Q-Q (which keeps only
                // the sampled strata), and the JSON-lines mirror — so
                // year-scale streamed captures analyze on machines that
                // could never hold the event Vec
                let (meta, summary) = TraceSummary::from_file(&input)?;
                println!(
                    "trace '{}' (seed {}), scheduler {}, trigger {}",
                    meta.name,
                    meta.seed,
                    meta.get("scheduler").unwrap_or("?"),
                    meta.get("trigger").unwrap_or("?"),
                );
                print!("{}", summary.render());
                if let Some(p) = params_path {
                    let params = SimParams::load(&PathBuf::from(p))?;
                    for q in trace_qq_file(&input, &params, 20_000, 60, 1)? {
                        println!("{}", q.verdict());
                    }
                }
                if let Some(path) = jsonl {
                    let mut w = std::io::BufWriter::new(std::fs::File::create(&path)?);
                    TraceScanner::open(&input)?.write_jsonl(&mut w)?;
                    println!("jsonl -> {path}");
                }
            }

            // re-drive the simulation from the recorded arrival gaps,
            // scanned record-by-record — the event Vec of a year-scale
            // capture never materializes, only the gap sequence does
            "replay" => {
                let input = PathBuf::from(args.get("in", "trace.pst"));
                let params =
                    SimParams::load(&PathBuf::from(args.get("params", "sim_params.json")))?;
                let cpu = args.flag("cpu");
                args.reject_unknown()?;
                let workload = TraceWorkload::from_file(&input)?;
                let rt = load_runtime(cpu);
                let result = workload.run(params, rt)?;
                println!("{}", render_dashboard(&result, 72));
                println!("digest: {}", result.digest());
            }

            other => {
                eprintln!("trace: unknown action '{other}' (export|stats|replay)\n{USAGE}");
                std::process::exit(2);
            }
        },

        "figures" => {
            let fig = args.get("fig", "all");
            let db = AnalyticsDb::load(&PathBuf::from(args.get("db", "empirical_db.json")))?;
            let params = SimParams::load(&PathBuf::from(args.get("params", "sim_params.json")))?;
            let out_dir = PathBuf::from(args.get("out-dir", "figures"));
            let cpu = args.flag("cpu");
            args.reject_unknown()?;
            std::fs::create_dir_all(&out_dir)?;
            let rt = load_runtime(cpu);
            let write = |name: &str, data: String| -> Result<()> {
                let path = out_dir.join(name);
                std::fs::write(&path, data)?;
                println!("wrote {}", path.display());
                Ok(())
            };
            let want = |k: &str| fig == "all" || fig == k;
            if want("8") {
                write("fig8_assets.csv", figures::fig8_assets(&db, &params, 9821, 8))?;
            }
            if want("9a") {
                write("fig9a_preproc.csv", figures::fig9a_preproc(&db, &params, 4000))?;
            }
            if want("9b") {
                write("fig9b_train.csv", figures::fig9b_train(&db, &params, 50_000, 9))?;
            }
            if want("10") {
                write("fig10_arrivals.csv", figures::fig10_arrivals(&db))?;
            }
            if want("11") || want("12") {
                // one 4-week profile-driven run feeds Figs 11 + 12
                let cfg = ExperimentConfig {
                    name: "figures".into(),
                    horizon: 28.0 * DAY,
                    arrival: ArrivalSpec::Profile,
                    ..Default::default()
                };
                let r = Experiment::new(cfg, params.clone())
                    .with_runtime(rt.clone())
                    .run()?;
                if want("11") {
                    write("fig11_dashboard.csv", figures::fig11_dashboard(&r, 3600.0))?;
                }
                if want("12") {
                    let mut csv = String::from("series,empirical_q,simulated_q\n");
                    for q in figures::fig12a_qq(&db, &r, 60) {
                        println!("{}", q.verdict());
                        csv.push_str(&q.to_csv());
                    }
                    if let Some(q) = figures::fig12b_qq(&db, &r, "profile", 60) {
                        println!("{}", q.verdict());
                        csv.push_str(&q.to_csv());
                    }
                    // plus a random-arrival run for the second 12b panel
                    let cfg2 = ExperimentConfig {
                        name: "figures-random".into(),
                        horizon: 28.0 * DAY,
                        arrival: ArrivalSpec::Random,
                        ..Default::default()
                    };
                    let r2 = Experiment::new(cfg2, params.clone())
                        .with_runtime(rt.clone())
                        .run()?;
                    if let Some(q) = figures::fig12b_qq(&db, &r2, "random", 60) {
                        println!("{}", q.verdict());
                        csv.push_str(&q.to_csv());
                    }
                    write("fig12ab_qq.csv", csv)?;
                    write("fig12c_profile.csv", figures::fig12c_profile(&db, &r))?;
                }
            }
            if want("table1") {
                write("table1_compression.csv", figures::table1())?;
            }
        }

        "table1" => {
            args.reject_unknown()?;
            print!("{}", figures::table1());
        }

        "qq" => {
            let db = AnalyticsDb::load(&PathBuf::from(args.get("db", "empirical_db.json")))?;
            let params = SimParams::load(&PathBuf::from(args.get("params", "sim_params.json")))?;
            let days: f64 = args.get_parse("days", 28.0)?;
            let cpu = args.flag("cpu");
            args.reject_unknown()?;
            let rt = load_runtime(cpu);
            let cfg = ExperimentConfig {
                name: "qq".into(),
                horizon: days * DAY,
                arrival: ArrivalSpec::Profile,
                ..Default::default()
            };
            let r = Experiment::new(cfg, params).with_runtime(rt).run()?;
            println!("simulated {} pipelines over {days} days", r.arrived);
            for q in figures::fig12a_qq(&db, &r, 60) {
                println!("{}", q.verdict());
            }
            if let Some(q) = figures::fig12b_qq(&db, &r, "profile", 60) {
                println!("{}", q.verdict());
            }
        }

        "scale" => {
            let params = SimParams::load(&PathBuf::from(args.get("params", "sim_params.json")))?;
            let counts = args.get("counts", "1000,5000,10000,50000,100000");
            let mean_interarrival: f64 = args.get_parse("mean-interarrival", 44.0)?;
            let cpu = args.flag("cpu");
            args.reject_unknown()?;
            let rt = load_runtime(cpu);
            println!("pipelines,wall_secs,us_per_pipeline,events_per_sec,peak_rss_mb");
            for count in counts.split(',') {
                let n: u64 = count.trim().parse()?;
                let cfg = ExperimentConfig {
                    name: format!("scale-{n}"),
                    horizon: f64::MAX / 4.0,
                    arrival: ArrivalSpec::Poisson { mean_interarrival },
                    max_pipelines: Some(n),
                    record_traces: false,
                    sample_interval: 3600.0,
                    ..Default::default()
                };
                let r = Experiment::new(cfg, params.clone())
                    .with_runtime(rt.clone())
                    .run()?;
                println!(
                    "{n},{:.3},{:.2},{:.0},{:.1}",
                    r.wall_secs,
                    r.us_per_pipeline(),
                    r.events_per_sec(),
                    r.peak_rss_mb
                );
            }
        }

        _ => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}
