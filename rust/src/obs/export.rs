//! Metric exporters: OpenMetrics text and JSON renderings of an
//! [`ExperimentResult`].
//!
//! Four metric families, all `pipesim_`-prefixed:
//! * **outcome** — the run's headline counters and gauges (arrivals,
//!   utilization, waits, traffic, wall time);
//! * **ledger** — reliability and cost accounting (failures, lost
//!   work, recovery quantiles, per-class utilization and dollars);
//! * **series** — per-tsdb-series aggregates (`count/sum/min/max/
//!   p50/p95`), computed exactly from raw columns or sketch-merged
//!   from retention windows;
//! * **meter** — the [`super::SimMeter`] self-profile, emitted only
//!   when the run carried one.
//!
//! OpenMetrics conventions: counter families are declared without the
//! `_total` suffix and sampled with it; label values are escaped; the
//! exposition ends with `# EOF`.

use crate::coordinator::{ExperimentResult, MergedSweep};
use crate::stats::desc::{quantile_sorted, sorted};
use crate::tsdb::{SeriesHandle, TsStore};
use crate::util::Json;

/// Escape a label value per the OpenMetrics exposition format.
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// OpenMetrics text builder: `# TYPE` headers plus escaped samples.
struct Om {
    out: String,
}

impl Om {
    fn new() -> Self {
        Om {
            out: String::with_capacity(4096),
        }
    }

    /// Declare a metric family (counter families: name WITHOUT `_total`).
    fn family(&mut self, name: &str, mtype: &str, help: &str) {
        self.out.push_str("# TYPE pipesim_");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(mtype);
        self.out.push('\n');
        if !help.is_empty() {
            self.out.push_str("# HELP pipesim_");
            self.out.push_str(name);
            self.out.push(' ');
            self.out.push_str(help);
            self.out.push('\n');
        }
    }

    /// Emit one sample line (counter samples: name WITH `_total`).
    fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str("pipesim_");
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(k);
                self.out.push_str("=\"");
                self.out.push_str(&esc(v));
                self.out.push('"');
            }
            self.out.push('}');
        }
        self.out.push(' ');
        self.out.push_str(&format!("{value}"));
        self.out.push('\n');
    }

    fn counter(&mut self, name: &str, help: &str, value: f64) {
        self.family(name, "counter", help);
        self.sample(&format!("{name}_total"), &[], value);
    }

    fn gauge(&mut self, name: &str, help: &str, value: f64) {
        self.family(name, "gauge", help);
        self.sample(name, &[], value);
    }

    fn finish(mut self) -> String {
        self.out.push_str("# EOF\n");
        self.out
    }
}

/// Per-series aggregate answered from either representation: exact
/// from raw columns, or streaming-aggregate + sketch-merged from
/// retention windows. `None` for series with no points.
struct SeriesStats {
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    p50: f64,
    p95: f64,
}

fn series_stats(db: &TsStore, h: SeriesHandle) -> Option<SeriesStats> {
    if let Some(w) = db.downsampled(h) {
        let bs = w.buckets();
        let first = bs.first()?;
        let mut sketch = first.sketch.clone();
        let (mut count, mut sum, mut min, mut max) =
            (first.count, first.sum, first.min, first.max);
        for b in &bs[1..] {
            count += b.count;
            sum += b.sum;
            min = min.min(b.min);
            max = max.max(b.max);
            sketch.merge_from(&b.sketch);
        }
        return Some(SeriesStats {
            count,
            sum,
            min,
            max,
            p50: sketch.quantile(0.5),
            p95: sketch.quantile(0.95),
        });
    }
    let s = db.series(h);
    if s.is_empty() {
        return None;
    }
    let v = sorted(&s.values);
    Some(SeriesStats {
        count: v.len() as u64,
        sum: v.iter().sum(),
        min: v[0],
        max: v[v.len() - 1],
        p50: quantile_sorted(&v, 0.5),
        p95: quantile_sorted(&v, 0.95),
    })
}

/// Render an [`ExperimentResult`] as OpenMetrics exposition text.
pub fn render_openmetrics(r: &ExperimentResult) -> String {
    let mut om = Om::new();

    // ---- run info ------------------------------------------------------
    let seed = r.seed.to_string();
    om.family("run", "gauge", "run descriptor (labels carry identity)");
    om.sample(
        "run_info",
        &[
            ("name", r.name.as_str()),
            ("seed", seed.as_str()),
            ("scheduler", r.scheduler.as_str()),
            ("trigger", r.trigger.as_str()),
            ("placer", r.placer.as_str()),
            ("sampler", r.sampler_backend.as_str()),
        ],
        1.0,
    );

    // ---- outcome -------------------------------------------------------
    om.gauge("horizon_seconds", "simulated horizon covered", r.horizon);
    om.counter("pipelines_arrived", "pipelines arrived", r.arrived as f64);
    om.counter(
        "pipelines_completed",
        "pipelines completed",
        r.completed as f64,
    );
    om.gauge(
        "pipelines_in_flight",
        "pipelines still queued/executing at the horizon",
        r.in_flight as f64,
    );
    om.counter("tasks_executed", "tasks executed", r.tasks_executed as f64);
    om.counter("gate_failures", "quality-gate failures", r.gate_failures as f64);
    om.counter(
        "preemptions",
        "running tasks evicted by a preemptive scheduler",
        r.preemptions as f64,
    );
    om.counter(
        "retrains",
        "retraining launches",
        r.retrains_triggered as f64,
    );
    om.counter("models_deployed", "models deployed", r.models_deployed as f64);
    om.counter(
        "events",
        "simulation events processed",
        r.events_processed as f64,
    );
    om.family("utilization", "gauge", "resource slot utilization");
    om.sample("utilization", &[("resource", "training")], r.util_training);
    om.sample("utilization", &[("resource", "compute")], r.util_compute);
    om.family("queue_len_avg", "gauge", "time-averaged queue length");
    om.sample(
        "queue_len_avg",
        &[("resource", "training")],
        r.avg_queue_training,
    );
    om.sample(
        "queue_len_avg",
        &[("resource", "compute")],
        r.avg_queue_compute,
    );
    om.family("wait_seconds", "summary", "task queueing wait");
    for (res, s) in [("training", &r.wait_training), ("compute", &r.wait_compute)] {
        om.sample("wait_seconds_count", &[("resource", res)], s.count as f64);
        om.sample("wait_seconds_sum", &[("resource", res)], s.sum);
    }
    om.family("wait_seconds_max", "gauge", "max task queueing wait");
    for (res, s) in [("training", &r.wait_training), ("compute", &r.wait_compute)] {
        let max = if s.count > 0 { s.max } else { 0.0 };
        om.sample("wait_seconds_max", &[("resource", res)], max);
    }
    om.gauge(
        "final_mean_performance",
        "mean performance over deployed models at the horizon",
        r.final_mean_performance,
    );
    om.family("wire_bytes", "counter", "store wire traffic incl. TCP overhead");
    om.sample("wire_bytes_total", &[("dir", "read")], r.wire_read_bytes);
    om.sample("wire_bytes_total", &[("dir", "write")], r.wire_write_bytes);
    om.gauge("wall_seconds", "engine wall-clock time", r.wall_secs);
    om.gauge("peak_rss_mb", "peak resident set size", r.peak_rss_mb);

    // ---- ledger (reliability + cost) -----------------------------------
    om.counter("failures", "slot failures injected", r.failures as f64);
    om.counter("repairs", "failed slots brought back online", r.repairs as f64);
    om.gauge(
        "lost_work_seconds",
        "service seconds destroyed by failures",
        r.lost_work,
    );
    om.gauge(
        "goodput_ratio",
        "useful / (useful + lost) service seconds",
        r.goodput,
    );
    om.family("recovery_seconds", "gauge", "per-failure repair time quantiles");
    om.sample("recovery_seconds", &[("quantile", "0.5")], r.recovery_p50);
    om.sample("recovery_seconds", &[("quantile", "0.95")], r.recovery_p95);
    om.gauge("cost_dollars", "dollar cost of the run", r.cost);
    if !r.class_util.is_empty() {
        om.family("class_utilization", "gauge", "per-class busy-time utilization");
        for (label, util) in &r.class_util {
            om.sample("class_utilization", &[("class", label)], *util);
        }
    }
    if !r.class_failures.is_empty() {
        om.family("class_failures", "counter", "slot failures per hardware class");
        for (label, n) in &r.class_failures {
            om.sample("class_failures_total", &[("class", label)], *n as f64);
        }
    }

    // ---- series --------------------------------------------------------
    for (stat, help) in [
        ("count", "points observed"),
        ("sum", "sum of observed values"),
        ("min", "min observed value"),
        ("max", "max observed value"),
        ("p50", "median (exact raw / sketch-merged downsampled)"),
        ("p95", "95th percentile (exact raw / sketch-merged downsampled)"),
    ] {
        om.family(&format!("series_{stat}"), "gauge", help);
        for h in r.tsdb.handles() {
            let Some(s) = series_stats(&r.tsdb, h) else {
                continue;
            };
            let key = r.tsdb.key(h);
            let mut labels: Vec<(&str, &str)> =
                vec![("series", key.measurement.as_str())];
            for (k, v) in &key.tags {
                labels.push((k.as_str(), v.as_str()));
            }
            let v = match stat {
                "count" => s.count as f64,
                "sum" => s.sum,
                "min" => s.min,
                "max" => s.max,
                "p50" => s.p50,
                _ => s.p95,
            };
            om.sample(&format!("series_{stat}"), &labels, v);
        }
    }

    // ---- meter ---------------------------------------------------------
    if let Some(m) = &r.meter {
        om.family("meter_events", "counter", "events dispatched per kind");
        for (kind, n) in &m.events_by_kind {
            om.sample("meter_events_total", &[("kind", kind)], *n as f64);
        }
        om.family(
            "meter_wall_seconds",
            "gauge",
            "handler wall time per event kind",
        );
        for (kind, ns) in &m.wall_ns_by_kind {
            om.sample(
                "meter_wall_seconds",
                &[("kind", kind)],
                *ns as f64 / 1e9,
            );
        }
        om.counter(
            "meter_calendar_scheduled",
            "calendar events scheduled",
            m.calendar_scheduled as f64,
        );
        om.counter(
            "meter_calendar_cancelled",
            "calendar events cancelled",
            m.calendar_cancelled as f64,
        );
        om.counter(
            "meter_calendar_compactions",
            "calendar tombstone compactions",
            m.calendar_compactions as f64,
        );
        om.gauge(
            "meter_calendar_depth_hwm",
            "calendar backing-heap high-water mark",
            m.calendar_depth_hwm as f64,
        );
        om.family(
            "meter_heap_rebuilds",
            "counter",
            "waiter-heap stale-entry rebuilds",
        );
        for (res, n) in &m.heap_rebuilds {
            om.sample("meter_heap_rebuilds_total", &[("resource", res)], *n as f64);
        }
        om.family("meter_requests", "counter", "resource slot requests");
        for (res, n) in &m.requests {
            om.sample("meter_requests_total", &[("resource", res)], *n as f64);
        }
        om.family("meter_queued", "counter", "requests that had to queue");
        for (res, n) in &m.queued {
            om.sample("meter_queued_total", &[("resource", res)], *n as f64);
        }
        om.family(
            "meter_grants",
            "counter",
            "jobs started on the resource (immediate + queued)",
        );
        for (res, n) in &m.grants {
            om.sample("meter_grants_total", &[("resource", res)], *n as f64);
        }
        om.counter(
            "meter_preemptions",
            "running tasks evicted",
            m.preemptions as f64,
        );
        om.counter(
            "meter_placements",
            "placement decisions taken",
            m.placements as f64,
        );
        om.family("meter_rng_draws", "counter", "raw 64-bit draws per substream");
        for (sub, n) in &m.rng_draws {
            om.sample("meter_rng_draws_total", &[("substream", sub)], *n as f64);
        }
        om.counter(
            "meter_allocations",
            "allocation events during the run (0 without the counting allocator)",
            m.alloc_events as f64,
        );
    }

    om.finish()
}

/// Render a [`MergedSweep`] (the `sweep-merge` surface — also what an
/// unsharded sweep's manifest merges to) as OpenMetrics exposition
/// text: sweep-level gauges, per-group replication counts, and one
/// sample per `(group, metric, stat)` with `stat` ranging over
/// `mean/std_dev/ci95/min/max/p50/p95`.
pub fn render_sweep_openmetrics(m: &MergedSweep) -> String {
    let mut om = Om::new();
    om.gauge("sweep_cells", "cells in the merged sweep", m.cells.len() as f64);
    om.gauge("sweep_shards", "shard manifests merged", m.shards as f64);
    om.counter(
        "sweep_events",
        "simulation events processed across all cells",
        m.events_total() as f64,
    );
    om.family("sweep_group_cells", "gauge", "replications per config group");
    for g in &m.groups {
        om.sample(
            "sweep_group_cells",
            &[("group", g.name.as_str())],
            g.cells.len() as f64,
        );
    }
    om.family(
        "sweep_metric",
        "gauge",
        "per-group metric statistic (mean/std_dev/ci95/min/max/p50/p95)",
    );
    for g in &m.groups {
        for ms in &g.metrics {
            for (stat, v) in [
                ("mean", ms.mean),
                ("std_dev", ms.std_dev),
                ("ci95", ms.ci95),
                ("min", ms.min),
                ("max", ms.max),
                ("p50", ms.p50),
                ("p95", ms.p95),
            ] {
                om.sample(
                    "sweep_metric",
                    &[("group", g.name.as_str()), ("metric", ms.name), ("stat", stat)],
                    v,
                );
            }
        }
    }
    om.family(
        "sweep_cell_wall_ms",
        "gauge",
        "cell wall-time quantiles, milliseconds (histogram-derived)",
    );
    for q in ["0.5", "0.95", "0.99"] {
        let quant: f64 = q.parse().expect("literal quantile");
        om.sample(
            "sweep_cell_wall_ms",
            &[("quantile", q)],
            m.wall_hist.quantile(quant),
        );
    }
    om.finish()
}

/// Render an [`ExperimentResult`] as a JSON metrics document with the
/// same coverage as [`render_openmetrics`] (`run`/`outcome`/`ledger`/
/// `series`/`meter` sections; `meter` is `null` when the run carried
/// no meter).
pub fn render_metrics_json(r: &ExperimentResult) -> String {
    fn pairs_u64(v: &[(String, u64)]) -> Json {
        Json::obj(
            v.iter()
                .map(|(k, n)| (k.as_str(), Json::Num(*n as f64)))
                .collect(),
        )
    }
    let run = Json::obj(vec![
        ("name", Json::Str(r.name.clone())),
        ("seed", Json::Num(r.seed as f64)),
        ("scheduler", Json::Str(r.scheduler.clone())),
        ("trigger", Json::Str(r.trigger.clone())),
        ("placer", Json::Str(r.placer.clone())),
        ("sampler", Json::Str(r.sampler_backend.clone())),
    ]);
    let outcome = Json::obj(vec![
        ("horizon_seconds", Json::Num(r.horizon)),
        ("arrived", Json::Num(r.arrived as f64)),
        ("completed", Json::Num(r.completed as f64)),
        ("in_flight", Json::Num(r.in_flight as f64)),
        ("tasks_executed", Json::Num(r.tasks_executed as f64)),
        ("gate_failures", Json::Num(r.gate_failures as f64)),
        ("preemptions", Json::Num(r.preemptions as f64)),
        ("retrains", Json::Num(r.retrains_triggered as f64)),
        ("models_deployed", Json::Num(r.models_deployed as f64)),
        ("events", Json::Num(r.events_processed as f64)),
        ("util_training", Json::Num(r.util_training)),
        ("util_compute", Json::Num(r.util_compute)),
        ("wait_training_count", Json::Num(r.wait_training.count as f64)),
        ("wait_training_sum", Json::Num(r.wait_training.sum)),
        ("wait_compute_count", Json::Num(r.wait_compute.count as f64)),
        ("wait_compute_sum", Json::Num(r.wait_compute.sum)),
        ("avg_queue_training", Json::Num(r.avg_queue_training)),
        ("avg_queue_compute", Json::Num(r.avg_queue_compute)),
        (
            "final_mean_performance",
            Json::Num(r.final_mean_performance),
        ),
        ("wire_read_bytes", Json::Num(r.wire_read_bytes)),
        ("wire_write_bytes", Json::Num(r.wire_write_bytes)),
        ("wall_seconds", Json::Num(r.wall_secs)),
        ("peak_rss_mb", Json::Num(r.peak_rss_mb)),
    ]);
    let ledger = Json::obj(vec![
        ("failures", Json::Num(r.failures as f64)),
        ("repairs", Json::Num(r.repairs as f64)),
        ("lost_work_seconds", Json::Num(r.lost_work)),
        ("goodput_ratio", Json::Num(r.goodput)),
        ("recovery_p50", Json::Num(r.recovery_p50)),
        ("recovery_p95", Json::Num(r.recovery_p95)),
        ("cost_dollars", Json::Num(r.cost)),
        (
            "class_utilization",
            Json::obj(
                r.class_util
                    .iter()
                    .map(|(k, v)| (k.as_str(), Json::Num(*v)))
                    .collect(),
            ),
        ),
        ("class_failures", pairs_u64(&r.class_failures)),
    ]);
    let mut series = Json::Arr(Vec::new());
    if let Json::Arr(items) = &mut series {
        for h in r.tsdb.handles() {
            let Some(s) = series_stats(&r.tsdb, h) else {
                continue;
            };
            items.push(Json::obj(vec![
                ("key", Json::Str(r.tsdb.key(h).to_string())),
                ("count", Json::Num(s.count as f64)),
                ("sum", Json::Num(s.sum)),
                ("min", Json::Num(s.min)),
                ("max", Json::Num(s.max)),
                ("p50", Json::Num(s.p50)),
                ("p95", Json::Num(s.p95)),
            ]));
        }
    }
    let meter = match &r.meter {
        None => Json::Null,
        Some(m) => Json::obj(vec![
            ("events_by_kind", pairs_u64(&m.events_by_kind)),
            ("wall_ns_by_kind", pairs_u64(&m.wall_ns_by_kind)),
            ("calendar_scheduled", Json::Num(m.calendar_scheduled as f64)),
            ("calendar_cancelled", Json::Num(m.calendar_cancelled as f64)),
            (
                "calendar_compactions",
                Json::Num(m.calendar_compactions as f64),
            ),
            ("calendar_depth_hwm", Json::Num(m.calendar_depth_hwm as f64)),
            ("heap_rebuilds", pairs_u64(&m.heap_rebuilds)),
            ("requests", pairs_u64(&m.requests)),
            ("queued", pairs_u64(&m.queued)),
            ("grants", pairs_u64(&m.grants)),
            ("preemptions", Json::Num(m.preemptions as f64)),
            ("placements", Json::Num(m.placements as f64)),
            ("rng_draws", pairs_u64(&m.rng_draws)),
            ("alloc_events", Json::Num(m.alloc_events as f64)),
        ]),
    };
    Json::obj(vec![
        ("run", run),
        ("outcome", outcome),
        ("ledger", ledger),
        ("series", series),
        ("meter", meter),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::MeterReport;
    use crate::stats::Summary;
    use crate::tsdb::SeriesKey;

    fn result_with_series() -> ExperimentResult {
        let mut db = TsStore::new();
        let h = db.handle(SeriesKey::new("utilization").tag("resource", "training"));
        for i in 0..10 {
            db.append(h, i as f64, i as f64);
        }
        db.handle(SeriesKey::new("empty")); // no points → skipped
        ExperimentResult {
            name: "exp".into(),
            seed: 7,
            horizon: 3600.0,
            tsdb: db,
            arrived: 10,
            completed: 9,
            in_flight: 1,
            tasks_executed: 30,
            gate_failures: 1,
            preemptions: 0,
            failures: 2,
            repairs: 1,
            lost_work: 120.0,
            goodput: 0.98,
            recovery_p50: 60.0,
            recovery_p95: 300.0,
            cost: 12.5,
            class_util: vec![("training/a100".into(), 0.8)],
            class_failures: vec![("training/a100".into(), 2)],
            retrains_triggered: 1,
            models_deployed: 1,
            events_processed: 500,
            util_training: 0.5,
            util_compute: 0.25,
            wait_training: Summary::new(),
            wait_compute: Summary::new(),
            avg_queue_training: 0.1,
            avg_queue_compute: 0.0,
            final_mean_performance: 0.9,
            wire_read_bytes: 1e6,
            wire_write_bytes: 2e6,
            wall_secs: 0.1,
            peak_rss_mb: 50.0,
            sampler_backend: "cpu".into(),
            pool_refills: 0,
            scheduler: "fifo".into(),
            trigger: "off".into(),
            placer: String::new(),
            trace: None,
            meter: None,
        }
    }

    #[test]
    fn openmetrics_has_all_families_and_eof() {
        let r = result_with_series();
        let text = render_openmetrics(&r);
        assert!(text.ends_with("# EOF\n"), "{text}");
        // counter declared without _total, sampled with it
        assert!(text.contains("# TYPE pipesim_pipelines_arrived counter"));
        assert!(text.contains("pipesim_pipelines_arrived_total 10"));
        // ledger
        assert!(text.contains("pipesim_failures_total 2"));
        assert!(text.contains("pipesim_recovery_seconds{quantile=\"0.95\"} 300"));
        assert!(text.contains("pipesim_class_utilization{class=\"training/a100\"} 0.8"));
        // series: tags become labels, exact raw aggregates
        assert!(
            text.contains(
                "pipesim_series_count{series=\"utilization\",resource=\"training\"} 10"
            ),
            "{text}"
        );
        assert!(text.contains("pipesim_series_sum{series=\"utilization\",resource=\"training\"} 45"));
        // empty series skipped
        assert!(!text.contains("series=\"empty\""));
        // no meter → no meter family
        assert!(!text.contains("pipesim_meter_"));
    }

    #[test]
    fn openmetrics_emits_meter_when_present() {
        let mut r = result_with_series();
        r.meter = Some(MeterReport {
            events_by_kind: vec![("arrival".into(), 10)],
            wall_ns_by_kind: vec![("arrival".into(), 2_000_000_000)],
            calendar_scheduled: 42,
            calendar_depth_hwm: 9,
            heap_rebuilds: vec![("training".into(), 1)],
            requests: vec![("training".into(), 30)],
            queued: vec![("training".into(), 5)],
            grants: vec![("training".into(), 30)],
            rng_draws: vec![("arrival".into(), 100)],
            alloc_events: 1234,
            ..Default::default()
        });
        let text = render_openmetrics(&r);
        assert!(text.contains("pipesim_meter_events_total{kind=\"arrival\"} 10"));
        assert!(text.contains("pipesim_meter_wall_seconds{kind=\"arrival\"} 2"));
        assert!(text.contains("pipesim_meter_calendar_scheduled_total 42"));
        assert!(text.contains("pipesim_meter_calendar_depth_hwm 9"));
        assert!(text.contains("pipesim_meter_grants_total{resource=\"training\"} 30"));
        assert!(text.contains("pipesim_meter_rng_draws_total{substream=\"arrival\"} 100"));
        assert!(text.contains("pipesim_meter_allocations_total 1234"));
    }

    #[test]
    fn openmetrics_downsampled_series_use_sketches() {
        let mut r = result_with_series();
        let mut db = TsStore::new();
        db.set_retention(5.0);
        let h = db.handle(SeriesKey::new("m"));
        for i in 0..100 {
            db.append(h, i as f64 * 0.1, i as f64);
        }
        r.tsdb = db;
        let text = render_openmetrics(&r);
        assert!(text.contains("pipesim_series_count{series=\"m\"} 100"));
        assert!(text.contains("pipesim_series_sum{series=\"m\"} 4950"));
        assert!(text.contains("pipesim_series_min{series=\"m\"} 0"));
        assert!(text.contains("pipesim_series_max{series=\"m\"} 99"));
        // sketch-merged median of 0..=99 lands near 49.5
        let p50_line = text
            .lines()
            .find(|l| l.starts_with("pipesim_series_p50{series=\"m\"}"))
            .expect("p50 sample");
        let p50: f64 = p50_line.rsplit(' ').next().unwrap().parse().unwrap();
        assert!((p50 - 49.5).abs() <= 5.0, "{p50_line}");
    }

    #[test]
    fn label_values_are_escaped() {
        let mut r = result_with_series();
        r.name = "we\"ird\\name\nline".into();
        let text = render_openmetrics(&r);
        assert!(
            text.contains(r#"name="we\"ird\\name\nline""#),
            "{text}"
        );
    }

    #[test]
    fn sweep_renderer_emits_group_metric_samples() {
        use crate::coordinator::{merge_shards, CellRecord, ShardManifest, ShardSpec};
        let cell = |i: usize, name: &str| {
            let mut wait = Summary::new();
            wait.add(1.0 + i as f64);
            CellRecord {
                index: i,
                name: name.into(),
                seed: i as u64,
                arrived: 10 + i as u64,
                completed: 9,
                in_flight: 1,
                tasks_executed: 30,
                events_processed: 500,
                gate_failures: 0,
                retrains_triggered: 0,
                failures: 0,
                wait_training: wait,
                util_training: 0.5,
                util_compute: 0.25,
                avg_queue_training: 0.1,
                final_mean_performance: 0.9,
                lost_work: 0.0,
                goodput: 1.0,
                cost: 2.5,
                wall_secs: 0.02,
                peak_rss_points: 100,
                digest: format!("v2;cell={i}"),
            }
        };
        let cells = vec![cell(0, "cap=4"), cell(1, "cap=4"), cell(2, "cap=8")];
        let spec = ShardSpec { index: 0, count: 1 };
        let merged = merge_shards(vec![ShardManifest::from_cells(spec, 3, cells)]).unwrap();
        let text = render_sweep_openmetrics(&merged);
        assert!(text.ends_with("# EOF\n"), "{text}");
        assert!(text.contains("pipesim_sweep_cells 3"));
        assert!(text.contains("pipesim_sweep_shards 1"));
        assert!(text.contains("pipesim_sweep_events_total 1500"));
        assert!(text.contains("pipesim_sweep_group_cells{group=\"cap=4\"} 2"));
        assert!(text.contains(
            "pipesim_sweep_metric{group=\"cap=4\",metric=\"arrived\",stat=\"mean\"} 10.5"
        ));
        assert!(text.contains(
            "pipesim_sweep_metric{group=\"cap=8\",metric=\"cost\",stat=\"p95\"} 2.5"
        ));
        assert!(text.contains("pipesim_sweep_cell_wall_ms{quantile=\"0.95\"}"));
    }

    #[test]
    fn json_renderer_covers_sections() {
        let mut r = result_with_series();
        r.meter = Some(MeterReport::default());
        let text = render_metrics_json(&r);
        let doc = Json::parse(&text).expect("valid json");
        assert_eq!(doc.req("outcome").unwrap().f("arrived").unwrap(), 10.0);
        assert_eq!(doc.req("ledger").unwrap().f("failures").unwrap(), 2.0);
        let series = doc.req("series").unwrap().as_arr().unwrap();
        assert_eq!(series.len(), 1); // empty series skipped
        assert!(!matches!(doc.req("meter").unwrap(), Json::Null));
        // meter-less run serializes meter: null
        let r2 = result_with_series();
        let doc2 = Json::parse(&render_metrics_json(&r2)).unwrap();
        assert!(matches!(doc2.get("meter"), Some(Json::Null)));
    }
}
