//! Simulator self-observability: the opt-in [`SimMeter`] profiling
//! hooks and the OpenMetrics/JSON exporters ([`export`]).
//!
//! The same discipline the simulator applies to the platform it models
//! — ground everything in measured profiles — applied to the simulator
//! itself: event-loop timing per event kind, calendar depth and
//! compactions, waiter-heap rebuilds, grants/preemptions/placements,
//! RNG draws per substream, and allocation counts. All of it is
//! **out of the digest** (the established `in_flight`/`cost` pattern):
//! meter-on and meter-off runs of the same `(config, seed)` produce
//! byte-identical digests, and meter-off adds a single predictable
//! branch per event.

pub mod export;

pub use export::{render_metrics_json, render_openmetrics, render_sweep_openmetrics};

/// Event kinds of the simulation loop, in `Event` discriminant order.
/// The simulation maps its event enum to these indices — `obs` stays
/// independent of the coordinator's types on the hot path.
pub const EVENT_KINDS: [&str; 12] = [
    "arrival",
    "task_done",
    "monitor",
    "drift",
    "retrain_launch",
    "slot_failed",
    "slot_repaired",
    "class_failed",
    "class_repaired",
    "task_fault",
    "task_timeout",
    "task_retry",
];

/// Hot-path self-profiling accumulator, owned by the simulation.
///
/// Zero-cost-when-off: every hook is guarded by [`SimMeter::enabled`],
/// so a disabled meter costs one well-predicted branch per event and
/// touches no clocks or counters. When enabled, the loop records per-
/// kind event counts and wall time, and samples the calendar's backing
/// depth to a high-water mark.
#[derive(Clone, Debug)]
pub struct SimMeter {
    enabled: bool,
    events: [u64; EVENT_KINDS.len()],
    wall_ns: [u64; EVENT_KINDS.len()],
    depth_hwm: u64,
    /// Allocation-event counter at construction
    /// ([`crate::util::alloc::allocs`]); 0 when the counting allocator
    /// is not installed in this binary.
    alloc_start: u64,
}

impl SimMeter {
    pub fn new(enabled: bool) -> Self {
        SimMeter {
            enabled,
            events: [0; EVENT_KINDS.len()],
            wall_ns: [0; EVENT_KINDS.len()],
            depth_hwm: 0,
            alloc_start: if enabled {
                crate::util::alloc::allocs()
            } else {
                0
            },
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Record one dispatched event: kind index (into [`EVENT_KINDS`]),
    /// handler wall time, and the calendar backing depth at dispatch.
    /// Caller guards with [`SimMeter::enabled`].
    #[inline]
    pub fn record_event(&mut self, kind: usize, ns: u64, depth: usize) {
        self.events[kind] += 1;
        self.wall_ns[kind] += ns;
        if depth as u64 > self.depth_hwm {
            self.depth_hwm = depth as u64;
        }
    }

    pub fn events_by_kind(&self) -> &[u64; EVENT_KINDS.len()] {
        &self.events
    }

    pub fn wall_ns_by_kind(&self) -> &[u64; EVENT_KINDS.len()] {
        &self.wall_ns
    }

    pub fn depth_hwm(&self) -> u64 {
        self.depth_hwm
    }

    /// Allocation events since the meter was constructed (0 when the
    /// counting allocator is not installed — see
    /// [`crate::util::alloc`]).
    pub fn alloc_events(&self) -> u64 {
        if !self.enabled {
            return 0;
        }
        crate::util::alloc::allocs().saturating_sub(self.alloc_start)
    }
}

/// The meter's end-of-run report, attached to
/// `ExperimentResult::meter` when the config opts in. Out of the
/// digest; all labels are resolved strings so exporters need no
/// simulator types.
#[derive(Clone, Debug, Default)]
pub struct MeterReport {
    /// Events dispatched per kind, [`EVENT_KINDS`] order.
    pub events_by_kind: Vec<(String, u64)>,
    /// Handler wall nanoseconds per kind, same order.
    pub wall_ns_by_kind: Vec<(String, u64)>,
    // calendar
    pub calendar_scheduled: u64,
    pub calendar_cancelled: u64,
    pub calendar_compactions: u64,
    /// High-water mark of the calendar's backing heap (incl. pending
    /// tombstones), sampled at every dispatch.
    pub calendar_depth_hwm: u64,
    // per-resource, labeled "training"/"compute"
    pub heap_rebuilds: Vec<(String, u64)>,
    pub requests: Vec<(String, u64)>,
    pub queued: Vec<(String, u64)>,
    /// Grants = jobs that started on the resource (immediate + queued).
    pub grants: Vec<(String, u64)>,
    pub preemptions: u64,
    /// Placement decisions taken by the `Placer` (0 without hardware
    /// classes).
    pub placements: u64,
    /// Raw 64-bit draws per RNG substream, labeled by substream name.
    pub rng_draws: Vec<(String, u64)>,
    /// Allocation events during the run (0 when the counting allocator
    /// is not installed in the binary).
    pub alloc_events: u64,
}

impl MeterReport {
    /// Total handler wall time across all event kinds, in seconds.
    pub fn loop_wall_secs(&self) -> f64 {
        self.wall_ns_by_kind.iter().map(|&(_, ns)| ns).sum::<u64>() as f64 / 1e9
    }

    /// Total events dispatched across all kinds.
    pub fn total_events(&self) -> u64 {
        self.events_by_kind.iter().map(|&(_, n)| n).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_meter_is_inert() {
        let m = SimMeter::new(false);
        assert!(!m.enabled());
        assert_eq!(m.alloc_events(), 0);
        assert_eq!(m.depth_hwm(), 0);
    }

    #[test]
    fn record_accumulates_per_kind() {
        let mut m = SimMeter::new(true);
        m.record_event(0, 100, 5);
        m.record_event(0, 50, 3);
        m.record_event(2, 7, 12);
        assert_eq!(m.events_by_kind()[0], 2);
        assert_eq!(m.events_by_kind()[2], 1);
        assert_eq!(m.wall_ns_by_kind()[0], 150);
        assert_eq!(m.depth_hwm(), 12);
    }

    #[test]
    fn report_totals() {
        let r = MeterReport {
            events_by_kind: vec![("arrival".into(), 10), ("monitor".into(), 5)],
            wall_ns_by_kind: vec![("arrival".into(), 1_000_000_000), ("monitor".into(), 500)],
            ..Default::default()
        };
        assert_eq!(r.total_events(), 15);
        assert!((r.loop_wall_secs() - 1.0000000005).abs() < 1e-12);
    }
}
