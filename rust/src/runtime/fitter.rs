//! EM fit drivers over the AOT artifacts: Rust owns the outer loop
//! (init, convergence, restarts), PJRT executes the per-iteration math
//! (the Pallas E-step kernel + fused M-step).

use super::client::{Runtime, D, K1, K3, N_FIT};
use crate::error::Result;
use crate::stats::gmm::{Gmm1, Gmm3};
use crate::stats::rng::Pcg64;

/// Resample `data` to exactly N_FIT rows (subsample without replacement
/// when larger, bootstrap when smaller) and flatten to f32.
fn prepare3(data: &[[f64; 3]], rng: &mut Pcg64) -> (Vec<[f64; 3]>, Vec<f32>) {
    let rows: Vec<[f64; 3]> = if data.len() >= N_FIT {
        rng.sample_indices(data.len(), N_FIT)
            .into_iter()
            .map(|i| data[i])
            .collect()
    } else {
        (0..N_FIT).map(|_| data[rng.below(data.len())]).collect()
    };
    let flat = rows
        .iter()
        .flat_map(|r| r.iter().map(|&v| v as f32))
        .collect();
    (rows, flat)
}

fn prepare1(data: &[f64], rng: &mut Pcg64) -> (Vec<f64>, Vec<f32>) {
    let rows: Vec<f64> = if data.len() >= N_FIT {
        rng.sample_indices(data.len(), N_FIT)
            .into_iter()
            .map(|i| data[i])
            .collect()
    } else {
        (0..N_FIT).map(|_| data[rng.below(data.len())]).collect()
    };
    let flat = rows.iter().map(|&v| v as f32).collect();
    (rows, flat)
}

/// Fit the K3-component full-covariance 3-D mixture on `data` via the
/// `gmm_em_step3` artifact. Returns (model, final loglik, iterations).
pub fn fit_gmm3(
    rt: &Runtime,
    data: &[[f64; 3]],
    rng: &mut Pcg64,
    max_iter: usize,
    tol: f64,
) -> Result<(Gmm3, f64, usize)> {
    assert!(data.len() >= K3, "need at least K3 rows");
    let (rows, flat) = prepare3(data, rng);
    let mut g = Gmm3::init_from_data(&rows, K3, rng);
    // upload X once; only the (small) parameters move per iteration
    let x_lit = rt.em_data3(&flat)?;
    let mut prev = f64::NEG_INFINITY;
    let mut ll = prev;
    let mut iters = 0;
    for i in 0..max_iter {
        ll = rt.em_step3_lit(&x_lit, &mut g)?;
        iters = i + 1;
        if (ll - prev).abs() < tol * (1.0 + ll.abs()) {
            break;
        }
        prev = ll;
    }
    Ok((g, ll, iters))
}

/// Fit a K1-component 1-D mixture via the `gmm_em_step1` artifact.
pub fn fit_gmm1(
    rt: &Runtime,
    data: &[f64],
    rng: &mut Pcg64,
    max_iter: usize,
    tol: f64,
) -> Result<(Gmm1, f64, usize)> {
    assert!(data.len() >= K1, "need at least K1 points");
    let (rows, flat) = prepare1(data, rng);
    let mut g = Gmm1::init_from_data(&rows, K1, rng);
    let mut prev = f64::NEG_INFINITY;
    let mut ll = prev;
    let mut iters = 0;
    for i in 0..max_iter {
        ll = rt.em_step1(&flat, &mut g)?;
        iters = i + 1;
        if (ll - prev).abs() < tol * (1.0 + ll.abs()) {
            break;
        }
        prev = ll;
    }
    Ok((g, ll, iters))
}

/// CPU-baseline counterparts with identical drivers (bench comparisons
/// and artifact-free operation).
pub fn fit_gmm3_cpu(
    data: &[[f64; 3]],
    k: usize,
    rng: &mut Pcg64,
    max_iter: usize,
    tol: f64,
) -> Result<(Gmm3, f64)> {
    let (rows, _) = prepare3(data, rng);
    Gmm3::fit(&rows, k, rng, max_iter, tol)
}

pub fn fit_gmm1_cpu(
    data: &[f64],
    k: usize,
    rng: &mut Pcg64,
    max_iter: usize,
    tol: f64,
) -> (Gmm1, f64) {
    let (rows, _) = prepare1(data, rng);
    Gmm1::fit(&rows, k, rng, max_iter, tol)
}

#[allow(unused)]
fn _shape_guards() {
    // compile-time reminder that prepare* target the AOT shapes
    let _ = N_FIT * D;
    let _ = K1;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prepare_pads_and_subsamples() {
        let mut rng = Pcg64::new(1);
        let small = vec![[1.0, 2.0, 3.0]; 100];
        let (rows, flat) = prepare3(&small, &mut rng);
        assert_eq!(rows.len(), N_FIT);
        assert_eq!(flat.len(), N_FIT * 3);
        let big = vec![[0.0, 0.0, 0.0]; 20_000];
        let (rows, _) = prepare3(&big, &mut rng);
        assert_eq!(rows.len(), N_FIT);
    }

    #[test]
    fn runtime_fit_recovers_structure() {
        let Some(rt) = Runtime::load_default() else { return };
        let mut rng = Pcg64::new(2);
        // two well-separated blobs
        let data: Vec<[f64; 3]> = (0..6000)
            .map(|i| {
                if i % 3 == 0 {
                    [5.0 + 0.3 * rng.normal(), 5.0 + 0.3 * rng.normal(), 0.3 * rng.normal()]
                } else {
                    [-2.0 + 0.4 * rng.normal(), 1.0 + 0.4 * rng.normal(), 3.0 + 0.4 * rng.normal()]
                }
            })
            .collect();
        let (g, ll, iters) = fit_gmm3(&rt, &data, &mut rng, 40, 1e-6).unwrap();
        assert!(ll.is_finite());
        assert!(iters >= 2);
        // effective means: weighted average must sit between the blobs
        let mix_mean: f64 = g
            .logw
            .iter()
            .zip(&g.mu)
            .map(|(lw, m)| lw.exp() * m[0])
            .sum();
        let want = (1.0 / 3.0) * 5.0 + (2.0 / 3.0) * -2.0;
        assert!((mix_mean - want).abs() < 0.3, "{mix_mean} vs {want}");
    }

    #[test]
    fn runtime_fit1_recovers_bimodal() {
        let Some(rt) = Runtime::load_default() else { return };
        let mut rng = Pcg64::new(3);
        let data: Vec<f64> = (0..N_FIT)
            .map(|i| if i % 2 == 0 { 1.0 + 0.3 * rng.normal() } else { 6.0 + 0.5 * rng.normal() })
            .collect();
        let (g, ll, _) = fit_gmm1(&rt, &data, &mut rng, 60, 1e-7).unwrap();
        assert!(ll.is_finite());
        assert!((g.mean() - 3.5).abs() < 0.2, "mean {}", g.mean());
    }

    #[test]
    fn cpu_fallback_works_without_artifacts() {
        let mut rng = Pcg64::new(4);
        let data: Vec<f64> = (0..2000).map(|_| rng.normal() * 2.0).collect();
        let (g, ll) = fit_gmm1_cpu(&data, 4, &mut rng, 50, 1e-8);
        assert!(ll.is_finite());
        assert!((g.mean() - 0.0).abs() < 0.2);
    }
}
