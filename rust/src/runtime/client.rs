//! PJRT client wrapper and typed executors for the AOT modules.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::stats::gmm::{Gmm1, Gmm3};

// AOT shapes — must match python/compile/model.py (checked against
// artifacts/manifest.json at load time).
pub const N_FIT: usize = 8192;
pub const N_SAMPLE: usize = 4096;
pub const D: usize = 3;
pub const K3: usize = 50;
pub const K1: usize = 8;

/// Names of the HLO modules the runtime loads.
const MODULES: [&str; 5] = [
    "gmm_em_step3",
    "gmm_em_step1",
    "gmm_sample3",
    "gmm_sample1",
    "preproc_duration",
];

/// The loaded runtime: one compiled executable per artifact.
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    em_step3: xla::PjRtLoadedExecutable,
    em_step1: xla::PjRtLoadedExecutable,
    sample3: xla::PjRtLoadedExecutable,
    sample1: xla::PjRtLoadedExecutable,
    preproc: xla::PjRtLoadedExecutable,
    /// Executions performed (perf accounting). Atomic so a single loaded
    /// runtime can be shared (`Arc<Runtime>`) across sweep workers.
    pub exec_count: std::sync::atomic::AtomicU64,
}

fn f32_literal(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    Ok(lit.reshape(dims)?)
}

impl Runtime {
    /// Load and compile all artifacts from `dir` (default `artifacts/`).
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        if manifest_path.exists() {
            let manifest = crate::util::Json::load(&manifest_path)?;
            let shapes = manifest.req("shapes")?;
            for (name, want) in [
                ("N_FIT", N_FIT),
                ("N_SAMPLE", N_SAMPLE),
                ("D", D),
                ("K3", K3),
                ("K1", K1),
            ] {
                let got = shapes.get(name).and_then(|v| v.as_usize().ok()).unwrap_or(0);
                if got != want {
                    return Err(Error::Config(format!(
                        "artifact manifest {name}={got}, runtime built for {want}; re-run `make artifacts`"
                    )));
                }
            }
        }
        let client = xla::PjRtClient::cpu()?;
        let mut exes = Vec::with_capacity(MODULES.len());
        for name in MODULES {
            let path = dir.join(format!("{name}.hlo.txt"));
            if !path.exists() {
                return Err(Error::Config(format!(
                    "missing artifact {}; run `make artifacts`",
                    path.display()
                )));
            }
            let proto = xla::HloModuleProto::from_text_file(&path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            exes.push(client.compile(&comp)?);
        }
        let mut it = exes.into_iter();
        Ok(Runtime {
            client,
            em_step3: it.next().unwrap(),
            em_step1: it.next().unwrap(),
            sample3: it.next().unwrap(),
            sample1: it.next().unwrap(),
            preproc: it.next().unwrap(),
            exec_count: std::sync::atomic::AtomicU64::new(0),
        })
    }

    /// Default artifact location relative to the repo root / cwd.
    pub fn default_dir() -> PathBuf {
        // honor PIPESIM_ARTIFACTS, else ./artifacts
        std::env::var("PIPESIM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Try loading from the default dir; None if artifacts are not built.
    pub fn load_default() -> Option<Runtime> {
        let dir = Self::default_dir();
        Runtime::load(&dir).ok()
    }

    fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        self.exec_count
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let result = exe.execute::<L>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    // ---------------------------------------------------------------
    // gmm_em_step3: (X[N,3], logw[50], mu[50,3], pchol[50,3,3])
    //            -> (logw', mu', cchol', pchol', loglik)
    // ---------------------------------------------------------------

    /// Pre-build the data literal for [`Runtime::em_step3_lit`] so the
    /// fit loop uploads X once instead of per iteration.
    pub fn em_data3(&self, x: &[f32]) -> Result<xla::Literal> {
        assert_eq!(x.len(), N_FIT * D);
        f32_literal(x, &[N_FIT as i64, D as i64])
    }

    /// One EM step for the 3-D asset mixture. `x` is row-major [N_FIT*3].
    /// Updates `g` in place and returns the pre-step log-likelihood.
    pub fn em_step3(&self, x: &[f32], g: &mut Gmm3) -> Result<f64> {
        let x_lit = self.em_data3(x)?;
        self.em_step3_lit(&x_lit, g)
    }

    /// EM step against a pre-built data literal (hot fit loop).
    pub fn em_step3_lit(&self, x_lit: &xla::Literal, g: &mut Gmm3) -> Result<f64> {
        assert_eq!(g.k(), K3);
        let logw: Vec<f32> = g.logw.iter().map(|&v| v as f32).collect();
        let mu: Vec<f32> = g.mu.iter().flat_map(|m| m.iter().map(|&v| v as f32)).collect();
        let pchol: Vec<f32> = g
            .pchol
            .iter()
            .flat_map(|m| m.iter().flatten().map(|&v| v as f32))
            .collect();
        let logw_lit = f32_literal(&logw, &[K3 as i64])?;
        let mu_lit = f32_literal(&mu, &[K3 as i64, D as i64])?;
        let pchol_lit = f32_literal(&pchol, &[K3 as i64, D as i64, D as i64])?;
        let outs = self.run(
            &self.em_step3,
            &[x_lit, &logw_lit, &mu_lit, &pchol_lit],
        )?;
        if outs.len() != 5 {
            return Err(Error::Other(format!("em_step3: {} outputs", outs.len())));
        }
        let new_logw = outs[0].to_vec::<f32>()?;
        let new_mu = outs[1].to_vec::<f32>()?;
        let new_cchol = outs[2].to_vec::<f32>()?;
        let new_pchol = outs[3].to_vec::<f32>()?;
        let ll = outs[4].to_vec::<f32>()?[0] as f64;
        for k in 0..K3 {
            g.logw[k] = new_logw[k] as f64;
            for d in 0..D {
                g.mu[k][d] = new_mu[k * D + d] as f64;
                for e in 0..D {
                    g.cchol[k][d][e] = new_cchol[(k * D + d) * D + e] as f64;
                    g.pchol[k][d][e] = new_pchol[(k * D + d) * D + e] as f64;
                }
            }
        }
        Ok(ll)
    }

    // ---------------------------------------------------------------
    // gmm_em_step1: (x[N], logw[8], mu[8], logsd[8]) -> (.., loglik)
    // ---------------------------------------------------------------

    /// One EM step for a 1-D duration mixture.
    pub fn em_step1(&self, x: &[f32], g: &mut Gmm1) -> Result<f64> {
        assert_eq!(x.len(), N_FIT);
        assert_eq!(g.k(), K1);
        let logw: Vec<f32> = g.logw.iter().map(|&v| v as f32).collect();
        let mu: Vec<f32> = g.mu.iter().map(|&v| v as f32).collect();
        let logsd: Vec<f32> = g.logsd.iter().map(|&v| v as f32).collect();
        let outs = self.run(
            &self.em_step1,
            &[
                f32_literal(x, &[N_FIT as i64])?,
                f32_literal(&logw, &[K1 as i64])?,
                f32_literal(&mu, &[K1 as i64])?,
                f32_literal(&logsd, &[K1 as i64])?,
            ],
        )?;
        if outs.len() != 4 {
            return Err(Error::Other(format!("em_step1: {} outputs", outs.len())));
        }
        let new_logw = outs[0].to_vec::<f32>()?;
        let new_mu = outs[1].to_vec::<f32>()?;
        let new_logsd = outs[2].to_vec::<f32>()?;
        let ll = outs[3].to_vec::<f32>()?[0] as f64;
        for k in 0..K1 {
            g.logw[k] = new_logw[k] as f64;
            g.mu[k] = new_mu[k] as f64;
            g.logsd[k] = new_logsd[k] as f64;
        }
        Ok(ll)
    }

    // ---------------------------------------------------------------
    // gmm_sample3: (logw, mu, cchol, u[N], z[N,3]) -> s[N,3]
    // ---------------------------------------------------------------

    /// Batch-sample N_SAMPLE points from the 3-D mixture. `u`/`z` are the
    /// Rust-generated uniforms and normals. Returns row-major [N*3].
    pub fn sample3(&self, g: &Gmm3, u: &[f32], z: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(u.len(), N_SAMPLE);
        assert_eq!(z.len(), N_SAMPLE * D);
        assert_eq!(g.k(), K3);
        let logw: Vec<f32> = g.logw.iter().map(|&v| v as f32).collect();
        let mu: Vec<f32> = g.mu.iter().flat_map(|m| m.iter().map(|&v| v as f32)).collect();
        let cchol: Vec<f32> = g
            .cchol
            .iter()
            .flat_map(|m| m.iter().flatten().map(|&v| v as f32))
            .collect();
        let outs = self.run(
            &self.sample3,
            &[
                f32_literal(&logw, &[K3 as i64])?,
                f32_literal(&mu, &[K3 as i64, D as i64])?,
                f32_literal(&cchol, &[K3 as i64, D as i64, D as i64])?,
                f32_literal(u, &[N_SAMPLE as i64])?,
                f32_literal(z, &[N_SAMPLE as i64, D as i64])?,
            ],
        )?;
        Ok(outs[0].to_vec::<f32>()?)
    }

    // ---------------------------------------------------------------
    // gmm_sample1: (logw, mu, logsd, u[N], z[N]) -> s[N]
    // ---------------------------------------------------------------

    /// Batch-sample N_SAMPLE points from a 1-D mixture.
    pub fn sample1(&self, g: &Gmm1, u: &[f32], z: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(u.len(), N_SAMPLE);
        assert_eq!(z.len(), N_SAMPLE);
        assert_eq!(g.k(), K1);
        let logw: Vec<f32> = g.logw.iter().map(|&v| v as f32).collect();
        let mu: Vec<f32> = g.mu.iter().map(|&v| v as f32).collect();
        let logsd: Vec<f32> = g.logsd.iter().map(|&v| v as f32).collect();
        let outs = self.run(
            &self.sample1,
            &[
                f32_literal(&logw, &[K1 as i64])?,
                f32_literal(&mu, &[K1 as i64])?,
                f32_literal(&logsd, &[K1 as i64])?,
                f32_literal(u, &[N_SAMPLE as i64])?,
                f32_literal(z, &[N_SAMPLE as i64])?,
            ],
        )?;
        Ok(outs[0].to_vec::<f32>()?)
    }

    // ---------------------------------------------------------------
    // preproc_duration: (logsize[N], abc[3], noise[2], z[N]) -> t[N]
    // ---------------------------------------------------------------

    /// Batch preprocess durations for N_SAMPLE log-sizes.
    pub fn preproc_duration(
        &self,
        logsize: &[f32],
        abc: [f32; 3],
        noise: [f32; 2],
        z: &[f32],
    ) -> Result<Vec<f32>> {
        assert_eq!(logsize.len(), N_SAMPLE);
        assert_eq!(z.len(), N_SAMPLE);
        let outs = self.run(
            &self.preproc,
            &[
                f32_literal(logsize, &[N_SAMPLE as i64])?,
                f32_literal(&abc, &[3])?,
                f32_literal(&noise, &[2])?,
                f32_literal(z, &[N_SAMPLE as i64])?,
            ],
        )?;
        Ok(outs[0].to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    //! These tests require built artifacts; they skip gracefully when
    //! `artifacts/` is absent (plain `cargo test` before `make artifacts`).
    use super::*;
    use crate::stats::rng::Pcg64;

    fn runtime() -> Option<Runtime> {
        Runtime::load_default()
    }

    fn toy_gmm3() -> Gmm3 {
        // K3 components but only 2 carry weight — easy moment checks
        let mut logw = vec![-50.0f64; K3];
        logw[0] = 0.7f64.ln();
        logw[1] = 0.3f64.ln();
        let mut mu = vec![[0.0; 3]; K3];
        mu[0] = [-2.0, 0.0, 1.0];
        mu[1] = [3.0, 1.0, -1.0];
        let eye = [[1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [0.0, 0.0, 1.0]];
        Gmm3 {
            logw,
            mu,
            cchol: vec![eye; K3],
            pchol: vec![eye; K3],
        }
    }

    #[test]
    fn sample3_moments_match() {
        let Some(rt) = runtime() else { return };
        let g = toy_gmm3();
        let mut rng = Pcg64::new(1);
        let mut mean = [0.0f64; 3];
        let rounds = 8;
        for _ in 0..rounds {
            let mut u = vec![0f32; N_SAMPLE];
            let mut z = vec![0f32; N_SAMPLE * D];
            rng.fill_uniform_f32(&mut u);
            rng.fill_normal_f32(&mut z);
            let s = rt.sample3(&g, &u, &z).unwrap();
            for row in s.chunks(3) {
                for d in 0..3 {
                    mean[d] += row[d] as f64;
                }
            }
        }
        let n = (rounds * N_SAMPLE) as f64;
        let want = [0.7 * -2.0 + 0.3 * 3.0, 0.3, 0.7 - 0.3];
        for d in 0..3 {
            let got = mean[d] / n;
            assert!((got - want[d]).abs() < 0.05, "dim {d}: {got} vs {}", want[d]);
        }
    }

    #[test]
    fn sample1_moments_match() {
        let Some(rt) = runtime() else { return };
        let mut logw = vec![-50.0f64; K1];
        logw[0] = 0.5f64.ln();
        logw[1] = 0.5f64.ln();
        let mut mu = vec![0.0f64; K1];
        mu[0] = -1.0;
        mu[1] = 5.0;
        let g = Gmm1 {
            logw,
            mu,
            logsd: vec![0.0; K1],
        };
        let mut rng = Pcg64::new(2);
        let mut u = vec![0f32; N_SAMPLE];
        let mut z = vec![0f32; N_SAMPLE];
        rng.fill_uniform_f32(&mut u);
        rng.fill_normal_f32(&mut z);
        let s = rt.sample1(&g, &u, &z).unwrap();
        let mean: f64 = s.iter().map(|&v| v as f64).sum::<f64>() / s.len() as f64;
        assert!((mean - 2.0).abs() < 0.1, "{mean}");
    }

    #[test]
    fn em_step3_agrees_with_cpu_baseline() {
        let Some(rt) = runtime() else { return };
        // generate data from a simple mixture
        let truth = toy_gmm3();
        let mut rng = Pcg64::new(3);
        let x3: Vec<[f64; 3]> = (0..N_FIT).map(|_| truth.sample(&mut rng)).collect();
        let x_flat: Vec<f32> = x3.iter().flat_map(|r| r.iter().map(|&v| v as f32)).collect();

        let mut g_rt = Gmm3::init_from_data(&x3, K3, &mut Pcg64::new(4));
        let mut g_cpu = g_rt.clone();
        let ll_rt = rt.em_step3(&x_flat, &mut g_rt).unwrap();
        let ll_cpu = g_cpu.em_step(&x3).unwrap();
        // f32 vs f64 path: relative tolerance
        assert!(
            (ll_rt - ll_cpu).abs() / ll_cpu.abs() < 1e-3,
            "loglik {ll_rt} vs {ll_cpu}"
        );
        for k in 0..K3 {
            for d in 0..3 {
                assert!(
                    (g_rt.mu[k][d] - g_cpu.mu[k][d]).abs() < 2e-2,
                    "mu[{k}][{d}]: {} vs {}",
                    g_rt.mu[k][d],
                    g_cpu.mu[k][d]
                );
            }
        }
    }

    #[test]
    fn em_step1_agrees_with_cpu_baseline() {
        let Some(rt) = runtime() else { return };
        let mut rng = Pcg64::new(5);
        let x: Vec<f64> = (0..N_FIT)
            .map(|i| if i % 2 == 0 { rng.normal() } else { 4.0 + rng.normal() })
            .collect();
        let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let mut g_rt = Gmm1::init_from_data(&x, K1, &mut Pcg64::new(6));
        let mut g_cpu = g_rt.clone();
        let ll_rt = rt.em_step1(&xf, &mut g_rt).unwrap();
        let ll_cpu = g_cpu.em_step(&x);
        assert!((ll_rt - ll_cpu).abs() / ll_cpu.abs() < 1e-3);
        for k in 0..K1 {
            assert!((g_rt.mu[k] - g_cpu.mu[k]).abs() < 2e-2);
        }
    }

    #[test]
    fn preproc_duration_matches_formula() {
        let Some(rt) = runtime() else { return };
        let logsize: Vec<f32> = (0..N_SAMPLE).map(|i| 2.0 + (i as f32) * 0.003).collect();
        let z = vec![0f32; N_SAMPLE];
        let t = rt
            .preproc_duration(&logsize, [0.018, 1.330, 2.156], [-1.0, 0.15], &z)
            .unwrap();
        for (i, (&x, &got)) in logsize.iter().zip(&t).enumerate() {
            let want = 0.018 * 1.330f32.powf(x) + 2.156 + (-1.0f32).exp();
            assert!((got - want).abs() / want < 1e-3, "i={i}: {got} vs {want}");
        }
    }
}
