//! Batched sample pools: amortize one PJRT execution over thousands of
//! simulator draws.
//!
//! The simulator consumes samples one at a time (per pipeline arrival /
//! task start), but PJRT executions have per-call overhead. Pools draw
//! N_SAMPLE samples per artifact execution and hand them out
//! incrementally — ≈1 execution per 4096 draws on the hot path. Every
//! pool also has a pure-Rust fallback so the whole system runs (slower,
//! identical distributions) without built artifacts.

use std::sync::Arc;

use super::client::{Runtime, D, N_SAMPLE};
use crate::error::Result;
use crate::stats::dist::{Distribution, LogNormal};
use crate::stats::gmm::{Gmm1, Gmm3};
use crate::stats::rng::Pcg64;
use crate::stats::ExpCurve;

/// Which engine draws the batches.
///
/// `Arc`-shared so one loaded runtime serves every worker of a parallel
/// sweep; cloning a backend is a pointer bump.
#[derive(Clone)]
pub enum Backend {
    /// AOT artifacts over PJRT (the production path).
    Runtime(Arc<Runtime>),
    /// Pure Rust (artifact-free fallback / baseline).
    Cpu,
}

impl Backend {
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Runtime(_) => "pjrt",
            Backend::Cpu => "cpu",
        }
    }
}

/// Pool over the 3-D asset mixture (`gmm_sample3`).
pub struct SamplePool3 {
    backend: Backend,
    gmm: Arc<Gmm3>,
    rng: Pcg64,
    buf: Vec<[f64; 3]>,
    pos: usize,
    /// Batches drawn (perf accounting).
    pub refills: u64,
}

impl SamplePool3 {
    pub fn new(backend: Backend, gmm: impl Into<Arc<Gmm3>>, rng: Pcg64) -> Self {
        SamplePool3 {
            backend,
            gmm: gmm.into(),
            rng,
            buf: Vec::new(),
            pos: 0,
            refills: 0,
        }
    }

    fn refill(&mut self) -> Result<()> {
        self.refills += 1;
        self.buf.clear();
        self.pos = 0;
        match &self.backend {
            Backend::Runtime(rt) => {
                let mut u = vec![0f32; N_SAMPLE];
                let mut z = vec![0f32; N_SAMPLE * D];
                self.rng.fill_uniform_f32(&mut u);
                self.rng.fill_normal_f32(&mut z);
                let s = rt.sample3(&self.gmm, &u, &z)?;
                self.buf
                    .extend(s.chunks(3).map(|r| [r[0] as f64, r[1] as f64, r[2] as f64]));
            }
            Backend::Cpu => {
                for _ in 0..N_SAMPLE {
                    self.buf.push(self.gmm.sample(&mut self.rng));
                }
            }
        }
        Ok(())
    }

    /// Next 3-D sample (log-space).
    pub fn next(&mut self) -> Result<[f64; 3]> {
        if self.pos >= self.buf.len() {
            self.refill()?;
        }
        let s = self.buf[self.pos];
        self.pos += 1;
        Ok(s)
    }
}

/// Pool over a 1-D mixture (`gmm_sample1`) — per-framework train
/// durations, evaluate durations (all in log-space).
pub struct SamplePool1 {
    backend: Backend,
    gmm: Arc<Gmm1>,
    rng: Pcg64,
    buf: Vec<f64>,
    pos: usize,
    pub refills: u64,
}

impl SamplePool1 {
    pub fn new(backend: Backend, gmm: impl Into<Arc<Gmm1>>, rng: Pcg64) -> Self {
        SamplePool1 {
            backend,
            gmm: gmm.into(),
            rng,
            buf: Vec::new(),
            pos: 0,
            refills: 0,
        }
    }

    fn refill(&mut self) -> Result<()> {
        self.refills += 1;
        self.buf.clear();
        self.pos = 0;
        match &self.backend {
            Backend::Runtime(rt) => {
                let mut u = vec![0f32; N_SAMPLE];
                let mut z = vec![0f32; N_SAMPLE];
                self.rng.fill_uniform_f32(&mut u);
                self.rng.fill_normal_f32(&mut z);
                let s = rt.sample1(&self.gmm, &u, &z)?;
                self.buf.extend(s.iter().map(|&v| v as f64));
            }
            Backend::Cpu => {
                for _ in 0..N_SAMPLE {
                    self.buf.push(self.gmm.sample(&mut self.rng));
                }
            }
        }
        Ok(())
    }

    pub fn next(&mut self) -> Result<f64> {
        if self.pos >= self.buf.len() {
            self.refill()?;
        }
        let s = self.buf[self.pos];
        self.pos += 1;
        Ok(s)
    }
}

/// Batch evaluator of the preprocess duration model
/// (`preproc_duration`): durations for a slab of asset log-sizes.
pub struct PreprocDurationPool {
    backend: Backend,
    pub curve: ExpCurve,
    pub noise: LogNormal,
    rng: Pcg64,
    pub calls: u64,
}

impl PreprocDurationPool {
    pub fn new(backend: Backend, curve: ExpCurve, noise: LogNormal, rng: Pcg64) -> Self {
        PreprocDurationPool {
            backend,
            curve,
            noise,
            rng,
            calls: 0,
        }
    }

    /// Durations for each log-size (vectorized; input length arbitrary —
    /// chunked/padded to the artifact batch internally).
    pub fn durations(&mut self, logsizes: &[f64]) -> Result<Vec<f64>> {
        match &self.backend {
            Backend::Runtime(rt) => {
                let mut out = Vec::with_capacity(logsizes.len());
                for chunk in logsizes.chunks(N_SAMPLE) {
                    self.calls += 1;
                    let mut ls = vec![0f32; N_SAMPLE];
                    for (dst, &src) in ls.iter_mut().zip(chunk) {
                        *dst = src as f32;
                    }
                    let mut z = vec![0f32; N_SAMPLE];
                    self.rng.fill_normal_f32(&mut z);
                    let t = rt.preproc_duration(
                        &ls,
                        [self.curve.a as f32, self.curve.b as f32, self.curve.c as f32],
                        [self.noise.mu as f32, self.noise.sigma as f32],
                        &z,
                    )?;
                    out.extend(t[..chunk.len()].iter().map(|&v| v as f64));
                }
                Ok(out)
            }
            Backend::Cpu => {
                self.calls += 1;
                Ok(logsizes
                    .iter()
                    .map(|&x| self.curve.eval(x) + self.noise.sample(&mut self.rng))
                    .collect())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_gmm1() -> Gmm1 {
        Gmm1 {
            logw: vec![0.5f64.ln(), 0.5f64.ln()],
            mu: vec![0.0, 10.0],
            logsd: vec![0.0, 0.0],
        }
    }

    #[test]
    fn cpu_pool1_statistics() {
        let mut pool = SamplePool1::new(Backend::Cpu, toy_gmm1(), Pcg64::new(1));
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| pool.next().unwrap()).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.1, "{mean}");
        assert!(pool.refills >= (n / N_SAMPLE) as u64);
    }

    #[test]
    fn cpu_pool3_statistics() {
        let eye = [[1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [0.0, 0.0, 1.0]];
        let g = Gmm3 {
            logw: vec![0.0],
            mu: vec![[1.0, 2.0, 3.0]],
            cchol: vec![eye],
            pchol: vec![eye],
        };
        let mut pool = SamplePool3::new(Backend::Cpu, g, Pcg64::new(2));
        let n = 20_000;
        let mut mean = [0.0f64; 3];
        for _ in 0..n {
            let s = pool.next().unwrap();
            for d in 0..3 {
                mean[d] += s[d];
            }
        }
        for (d, want) in [1.0, 2.0, 3.0].iter().enumerate() {
            let got = mean[d] / n as f64;
            assert!((got - want).abs() < 0.05, "dim {d}: {got}");
        }
    }

    #[test]
    fn preproc_cpu_matches_curve() {
        let curve = ExpCurve { a: 0.018, b: 1.330, c: 2.156 };
        let mut pool = PreprocDurationPool::new(
            Backend::Cpu,
            curve,
            LogNormal::new(-1.0, 0.15),
            Pcg64::new(3),
        );
        let xs = vec![5.0, 10.0, 15.0];
        let t = pool.durations(&xs).unwrap();
        for (&x, &d) in xs.iter().zip(&t) {
            assert!(d > curve.eval(x), "noise is positive lognormal");
            assert!(d < curve.eval(x) + 2.0);
        }
    }

    #[test]
    fn runtime_pools_match_cpu_distribution() {
        let Some(rt) = Runtime::load_default() else { return };
        let rt = Arc::new(rt);
        // pad toy mixture to K1 components
        let mut logw = vec![-60.0f64; super::super::client::K1];
        logw[0] = 0.0;
        let mut mu = vec![0.0f64; super::super::client::K1];
        mu[0] = 3.0;
        let g = Gmm1 { logw, mu, logsd: vec![0.0; super::super::client::K1] };
        let mut pjrt = SamplePool1::new(Backend::Runtime(rt), g.clone(), Pcg64::new(4));
        let mut cpu = SamplePool1::new(Backend::Cpu, g, Pcg64::new(5));
        let n = 2 * N_SAMPLE;
        let a: Vec<f64> = (0..n).map(|_| pjrt.next().unwrap()).collect();
        let b: Vec<f64> = (0..n).map(|_| cpu.next().unwrap()).collect();
        let ks = crate::stats::desc::ks_distance(&a, &b);
        assert!(ks < 0.03, "KS {ks}");
    }
}
