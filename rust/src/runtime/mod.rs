//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, produced
//! once by `make artifacts` from the JAX/Pallas build path) and executes
//! them on the CPU PJRT client from the simulation hot path.
//!
//! Python never runs here: HLO text is parsed by XLA's own parser
//! (`HloModuleProto::from_text_file`), compiled once per module, and the
//! executables are then pure functions fed with f32 buffers and
//! Rust-generated randomness.

pub mod client;
pub mod fitter;
pub mod pool;

pub use client::{Runtime, D, K1, K3, N_FIT, N_SAMPLE};
pub use fitter::{fit_gmm1, fit_gmm3};
pub use pool::{PreprocDurationPool, SamplePool1, SamplePool3};
