//! Pipeline arrival processes (paper sections IV-C2, V-A3).
//!
//! Two modes, exactly as evaluated in Fig 12b/c:
//! * **Random**: one interarrival distribution for the whole trace — the
//!   paper found the exponentiated Weibull fits best.
//! * **Realistic profile**: interarrivals clustered by hour-of-week (168
//!   clusters); each cluster fitted with {log-normal, exp-Weibull,
//!   Pareto} and the best SSE fit selected; at simulation time the
//!   sampler draws from the cluster of the current simulated hour.
//!
//! Both support the paper's *interarrival factor* to scale load up/down.

use crate::empirical::AnalyticsDb;
use crate::error::{Error, Result};
use crate::stats::dist::{Dist, Distribution};
use crate::stats::fit::{fit_expweibull, select_best_fit};
use crate::stats::rng::Pcg64;

/// Subsample cap per cluster fit (keeps 52-week fits fast without
/// hurting fidelity: >2000 points gain little for 2-3 param families).
const CLUSTER_FIT_CAP: usize = 2000;

/// An arrival process: produces the next interarrival gap given the
/// current simulation time.
///
/// The heavyweight members (168-cluster profile, recorded replay trace)
/// sit behind `Arc`s, so cloning a model out of a shared `SimParams` is
/// pointer-cheap and thread-safe — the parallel sweep engine hands one
/// fitted model set to every worker. Mutable per-run state (the replay
/// cursor) lives in the clone, never in the shared data.
#[derive(Clone, Debug)]
pub enum ArrivalModel {
    /// Single fitted distribution (paper: exp-Weibull).
    Random(Dist),
    /// 168 per-hour-of-week fitted distributions (shared, immutable).
    Profile(std::sync::Arc<ArrivalProfile>),
    /// Fixed mean interarrival (exponential) — scalability experiments
    /// (Fig 13 uses a flat 44 s interarrival).
    Poisson { mean_interarrival: f64 },
    /// Literal trace replay: the recorded interarrival sequence from the
    /// analytics DB, cycled when exhausted. The purest "trace-driven"
    /// mode — zero modeling error, at the cost of no extrapolation.
    Replay(ReplayTrace),
}

/// Recorded interarrival gaps (shared) with a per-clone replay cursor.
#[derive(Clone, Debug)]
pub struct ReplayTrace {
    pub gaps: std::sync::Arc<Vec<f64>>,
    cursor: usize,
}

impl ReplayTrace {
    pub fn new(gaps: Vec<f64>) -> Self {
        assert!(!gaps.is_empty(), "replay trace must be non-empty");
        ReplayTrace {
            gaps: std::sync::Arc::new(gaps),
            cursor: 0,
        }
    }

    fn next(&mut self) -> f64 {
        let i = self.cursor;
        self.cursor = (i + 1) % self.gaps.len();
        self.gaps[i]
    }
}

impl ArrivalModel {
    /// Draw the next interarrival at simulated time `t`, scaled by
    /// `factor` (>1 = fewer arrivals, the paper's interarrival factor).
    /// `&mut` because replay advances its cursor; the other modes only
    /// consume RNG state.
    pub fn next_interarrival(&mut self, t: f64, factor: f64, rng: &mut Pcg64) -> f64 {
        let gap = match self {
            ArrivalModel::Random(d) => d.sample(rng),
            ArrivalModel::Profile(p) => p.sample(t, rng),
            ArrivalModel::Poisson { mean_interarrival } => {
                rng.exponential(1.0 / mean_interarrival)
            }
            ArrivalModel::Replay(trace) => trace.next(),
        };
        (gap * factor).max(1e-3)
    }

    /// Build a replay model from the analytics DB's recorded arrivals.
    pub fn from_trace(db: &AnalyticsDb) -> Result<Self> {
        let gaps: Vec<f64> = db
            .interarrivals()
            .into_iter()
            .filter(|&g| g > 0.0)
            .collect();
        if gaps.is_empty() {
            return Err(Error::Stats("from_trace: empty trace".into()));
        }
        Ok(ArrivalModel::Replay(ReplayTrace::new(gaps)))
    }

    /// Fit the random (global) model: exp-Weibull on all interarrivals.
    pub fn fit_random(db: &AnalyticsDb) -> Result<Self> {
        let gaps: Vec<f64> = db
            .interarrivals()
            .into_iter()
            .filter(|&g| g > 0.0)
            .collect();
        if gaps.len() < 100 {
            return Err(Error::Stats("fit_random: too few interarrivals".into()));
        }
        let d = fit_expweibull(&gaps)?;
        Ok(ArrivalModel::Random(Dist::ExpWeibull(d)))
    }

    /// Fit the realistic 168-cluster profile.
    pub fn fit_profile(db: &AnalyticsDb, rng: &mut Pcg64) -> Result<Self> {
        Ok(ArrivalModel::Profile(std::sync::Arc::new(
            ArrivalProfile::fit(db, rng)?,
        )))
    }
}

/// The 168-cluster hour-of-week interarrival profile.
#[derive(Clone, Debug)]
pub struct ArrivalProfile {
    /// Best-fit distribution per hour-of-week cluster.
    pub clusters: Vec<Dist>,
    /// SSE of the selected fit (diagnostics / reporting).
    pub sse: Vec<f64>,
}

impl ArrivalProfile {
    /// Cluster interarrivals by the hour-of-week of the gap's start, fit
    /// the three candidate families per cluster, select by SSE
    /// (section V-A3 verbatim).
    pub fn fit(db: &AnalyticsDb, rng: &mut Pcg64) -> Result<Self> {
        let mut by_hour = db.interarrivals_by_hour_of_week();
        let mut clusters = Vec::with_capacity(168);
        let mut sses = Vec::with_capacity(168);
        // global fallback for sparse clusters
        let all: Vec<f64> = db.interarrivals().into_iter().filter(|&g| g > 0.0).collect();
        if all.len() < 100 {
            return Err(Error::Stats("fit_profile: too few interarrivals".into()));
        }
        let (global, global_sse) = select_best_fit(&all, 40)?;
        for cluster in by_hour.iter_mut() {
            cluster.retain(|&g| g > 0.0);
            if cluster.len() < 32 {
                clusters.push(global.clone());
                sses.push(global_sse);
                continue;
            }
            if cluster.len() > CLUSTER_FIT_CAP {
                rng.shuffle(cluster);
                cluster.truncate(CLUSTER_FIT_CAP);
            }
            match select_best_fit(cluster, 30) {
                Ok((d, sse)) => {
                    clusters.push(d);
                    sses.push(sse);
                }
                Err(_) => {
                    clusters.push(global.clone());
                    sses.push(global_sse);
                }
            }
        }
        Ok(ArrivalProfile {
            clusters,
            sse: sses,
        })
    }

    /// Sample an interarrival from the cluster of simulated time `t`.
    pub fn sample(&self, t: f64, rng: &mut Pcg64) -> f64 {
        let how = crate::empirical::db::hour_of_week(t);
        self.clusters[how].sample(rng)
    }

    /// Count of clusters per selected family (reporting).
    pub fn family_histogram(&self) -> Vec<(String, usize)> {
        let mut counts = std::collections::BTreeMap::new();
        for d in &self.clusters {
            *counts.entry(d.name().to_string()).or_insert(0usize) += 1;
        }
        counts.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::HOUR;
    use crate::empirical::GroundTruth;

    fn db() -> AnalyticsDb {
        GroundTruth::new(11).generate_weeks(6)
    }

    #[test]
    fn random_model_fits_and_samples() {
        let db = db();
        let mut m = ArrivalModel::fit_random(&db).unwrap();
        let mut rng = Pcg64::new(1);
        let gaps: Vec<f64> = (0..20_000)
            .map(|_| m.next_interarrival(0.0, 1.0, &mut rng))
            .collect();
        let sim_mean = crate::stats::mean(&gaps);
        let emp_mean = crate::stats::mean(&db.interarrivals());
        // global exp-Weibull should land within 25% of the empirical mean
        assert!(
            (sim_mean - emp_mean).abs() / emp_mean < 0.25,
            "sim {sim_mean} vs emp {emp_mean}"
        );
    }

    #[test]
    fn profile_fits_all_clusters() {
        let db = db();
        let mut rng = Pcg64::new(2);
        let p = match ArrivalModel::fit_profile(&db, &mut rng).unwrap() {
            ArrivalModel::Profile(p) => p,
            _ => unreachable!(),
        };
        assert_eq!(p.clusters.len(), 168);
        // peak hour (weekday 16:00) must have shorter interarrivals than
        // the quietest night hour
        let mut rng2 = Pcg64::new(3);
        let peak: f64 = (0..4000)
            .map(|_| p.sample(16.0 * HOUR, &mut rng2))
            .sum::<f64>()
            / 4000.0;
        let night: f64 = (0..4000)
            .map(|_| p.sample(3.0 * HOUR, &mut rng2))
            .sum::<f64>()
            / 4000.0;
        assert!(peak < night, "peak {peak} !< night {night}");
    }

    #[test]
    fn interarrival_factor_scales() {
        let mut m = ArrivalModel::Poisson {
            mean_interarrival: 10.0,
        };
        let mut rng = Pcg64::new(4);
        let g1: f64 = (0..20_000).map(|_| m.next_interarrival(0.0, 1.0, &mut rng)).sum();
        let g2: f64 = (0..20_000).map(|_| m.next_interarrival(0.0, 2.0, &mut rng)).sum();
        assert!((g2 / g1 - 2.0).abs() < 0.1);
    }

    #[test]
    fn family_histogram_covers_all() {
        let db = db();
        let mut rng = Pcg64::new(5);
        let p = ArrivalProfile::fit(&db, &mut rng).unwrap();
        let total: usize = p.family_histogram().iter().map(|(_, c)| c).sum();
        assert_eq!(total, 168);
    }

    #[test]
    fn replay_reproduces_trace_exactly() {
        let db = db();
        let mut m = ArrivalModel::from_trace(&db).unwrap();
        let mut rng = Pcg64::new(9);
        let want: Vec<f64> = db.interarrivals().into_iter().filter(|&g| g > 0.0).collect();
        for (i, &w) in want.iter().take(500).enumerate() {
            let got = m.next_interarrival(0.0, 1.0, &mut rng);
            assert!((got - w.max(1e-3)).abs() < 1e-12, "gap {i}");
        }
    }

    #[test]
    fn replay_cycles_when_exhausted() {
        let trace = ReplayTrace::new(vec![1.0, 2.0, 3.0]);
        let mut m = ArrivalModel::Replay(trace);
        let mut rng = Pcg64::new(10);
        let gaps: Vec<f64> = (0..7).map(|_| m.next_interarrival(0.0, 1.0, &mut rng)).collect();
        assert_eq!(gaps, vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0, 1.0]);
    }

    #[test]
    fn poisson_mean() {
        let mut m = ArrivalModel::Poisson {
            mean_interarrival: 44.0,
        };
        let mut rng = Pcg64::new(6);
        let mean: f64 = (0..50_000)
            .map(|_| m.next_interarrival(0.0, 1.0, &mut rng))
            .sum::<f64>()
            / 50_000.0;
        assert!((mean - 44.0).abs() < 1.0, "{mean}");
    }
}
