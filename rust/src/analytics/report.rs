//! Experiment comparison — the statistical-analysis-tool side of Fig 5:
//! put N experiment results side by side and quantify the deltas that
//! operational-strategy studies care about (wait, utilization, throughput,
//! model quality, retraining cost).

use crate::coordinator::ExperimentResult;

/// One comparable metric extracted from a result.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Metric {
    UtilTraining,
    UtilCompute,
    MeanWaitTraining,
    MaxWaitTraining,
    AvgQueueTraining,
    CompletionRate,
    Throughput,
    MeanModelPerformance,
    Retrains,
    WirePerPipelineMb,
    Failures,
    LostWork,
    Goodput,
    Cost,
}

impl Metric {
    pub const ALL: [Metric; 14] = [
        Metric::UtilTraining,
        Metric::UtilCompute,
        Metric::MeanWaitTraining,
        Metric::MaxWaitTraining,
        Metric::AvgQueueTraining,
        Metric::CompletionRate,
        Metric::Throughput,
        Metric::MeanModelPerformance,
        Metric::Retrains,
        Metric::WirePerPipelineMb,
        Metric::Failures,
        Metric::LostWork,
        Metric::Goodput,
        Metric::Cost,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Metric::UtilTraining => "util_training",
            Metric::UtilCompute => "util_compute",
            Metric::MeanWaitTraining => "mean_wait_training_s",
            Metric::MaxWaitTraining => "max_wait_training_s",
            Metric::AvgQueueTraining => "avg_queue_training",
            Metric::CompletionRate => "completion_rate",
            Metric::Throughput => "pipelines_per_sim_hour",
            Metric::MeanModelPerformance => "mean_model_perf",
            Metric::Retrains => "retrains",
            Metric::WirePerPipelineMb => "wire_mb_per_pipeline",
            Metric::Failures => "failures",
            Metric::LostWork => "lost_work_s",
            Metric::Goodput => "goodput",
            Metric::Cost => "cost",
        }
    }

    /// Extract the metric from a result.
    pub fn of(&self, r: &ExperimentResult) -> f64 {
        match self {
            Metric::UtilTraining => r.util_training,
            Metric::UtilCompute => r.util_compute,
            Metric::MeanWaitTraining => r.wait_training.mean(),
            Metric::MaxWaitTraining => {
                if r.wait_training.count > 0 {
                    r.wait_training.max
                } else {
                    0.0
                }
            }
            Metric::AvgQueueTraining => r.avg_queue_training,
            Metric::CompletionRate => {
                if r.arrived == 0 {
                    0.0
                } else {
                    r.completed as f64 / r.arrived as f64
                }
            }
            Metric::Throughput => {
                if r.horizon <= 0.0 {
                    0.0
                } else {
                    r.completed as f64 / (r.horizon / 3600.0)
                }
            }
            Metric::MeanModelPerformance => r.final_mean_performance,
            Metric::Retrains => r.retrains_triggered as f64,
            Metric::WirePerPipelineMb => {
                if r.arrived == 0 {
                    0.0
                } else {
                    (r.wire_read_bytes + r.wire_write_bytes) / 1e6 / r.arrived as f64
                }
            }
            Metric::Failures => r.failures as f64,
            Metric::LostWork => r.lost_work,
            Metric::Goodput => r.goodput,
            Metric::Cost => r.cost,
        }
    }
}

/// Side-by-side comparison of experiment results (first = baseline).
pub struct Comparison<'a> {
    pub results: Vec<&'a ExperimentResult>,
}

impl<'a> Comparison<'a> {
    pub fn new(results: Vec<&'a ExperimentResult>) -> Self {
        assert!(!results.is_empty());
        Comparison { results }
    }

    /// Relative change of `metric` for result `i` vs the baseline (0).
    pub fn delta(&self, metric: Metric, i: usize) -> f64 {
        let base = metric.of(self.results[0]);
        let v = metric.of(self.results[i]);
        if base.abs() < 1e-12 {
            if v.abs() < 1e-12 {
                0.0
            } else {
                f64::INFINITY
            }
        } else {
            v / base - 1.0
        }
    }

    /// Markdown-style table: rows = metrics, cols = experiments, deltas
    /// vs the baseline in parentheses. The resolved scheduler/trigger
    /// strategy labels lead the table so exported comparisons are
    /// self-describing.
    pub fn render(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        let _ = write!(out, "{:<26}", "metric");
        for r in &self.results {
            let _ = write!(out, " {:>22}", truncate(&r.name, 22));
        }
        out.push('\n');
        let _ = write!(out, "{:<26}", "scheduler");
        for r in &self.results {
            let _ = write!(out, " {:>22}", truncate(&r.scheduler, 22));
        }
        out.push('\n');
        let _ = write!(out, "{:<26}", "trigger");
        for r in &self.results {
            let _ = write!(out, " {:>22}", truncate(&r.trigger, 22));
        }
        out.push('\n');
        for m in Metric::ALL {
            // skip all-zero rows (e.g. runtime view off)
            if self.results.iter().all(|r| m.of(r).abs() < 1e-12) {
                continue;
            }
            let _ = write!(out, "{:<26}", m.name());
            for (i, r) in self.results.iter().enumerate() {
                let v = m.of(r);
                if i == 0 {
                    let _ = write!(out, " {v:>22.3}");
                } else {
                    let d = self.delta(m, i);
                    let _ = write!(out, " {:>13.3} ({:>+6.1}%)", v, 100.0 * d);
                }
            }
            out.push('\n');
        }
        out
    }

    /// CSV form: metric, then one column per experiment. The first two
    /// data rows carry the resolved strategy labels.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("metric");
        for r in &self.results {
            out.push(',');
            out.push_str(&r.name);
        }
        out.push('\n');
        out.push_str("scheduler");
        for r in &self.results {
            out.push(',');
            out.push_str(&r.scheduler);
        }
        out.push('\n');
        out.push_str("trigger");
        for r in &self.results {
            out.push(',');
            out.push_str(&r.trigger);
        }
        out.push('\n');
        for m in Metric::ALL {
            out.push_str(m.name());
            for r in &self.results {
                out.push_str(&format!(",{}", m.of(r)));
            }
            out.push('\n');
        }
        out
    }
}

fn truncate(s: &str, n: usize) -> &str {
    if s.len() <= n {
        s
    } else {
        &s[..n]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{fit_params, ArrivalSpec, Experiment, ExperimentConfig, StrategySpec};
    use crate::des::DAY;
    use crate::empirical::GroundTruth;

    fn two_results() -> (ExperimentResult, ExperimentResult) {
        let db = GroundTruth::new(55).generate_weeks(2);
        let params = fit_params(&db, None).unwrap();
        let mk = |name: &str| {
            let mut cfg = ExperimentConfig {
                name: name.into(),
                seed: 3,
                horizon: 2.0 * DAY,
                arrival: ArrivalSpec::Poisson {
                    mean_interarrival: 40.0,
                },
                record_traces: false,
                ..Default::default()
            };
            cfg.infra.training_capacity = 3;
            cfg.infra.scheduler = StrategySpec::new(name);
            Experiment::new(cfg, params.clone()).run().unwrap()
        };
        (mk("fifo"), mk("sjf"))
    }

    #[test]
    fn comparison_quantifies_sjf_gain() {
        let (fifo, sjf) = two_results();
        let cmp = Comparison::new(vec![&fifo, &sjf]);
        // SJF must reduce the mean training wait vs FIFO baseline
        let d = cmp.delta(Metric::MeanWaitTraining, 1);
        assert!(d < -0.2, "SJF wait delta {d}");
        let table = cmp.render();
        assert!(table.contains("mean_wait_training_s"));
        assert!(table.contains("fifo") && table.contains("sjf"));
        // the active strategies are spelled out, not just the cell names
        assert!(table.contains("scheduler"));
        assert!(table.contains("trigger"));
    }

    #[test]
    fn csv_has_all_metrics_and_strategy_labels() {
        let (a, b) = two_results();
        let cmp = Comparison::new(vec![&a, &b]);
        let csv = cmp.to_csv();
        // header + scheduler row + trigger row + one row per metric
        assert_eq!(csv.lines().count(), Metric::ALL.len() + 3);
        assert!(csv.starts_with("metric,fifo,sjf"));
        assert!(csv.contains("scheduler,fifo,sjf"));
        assert!(csv.contains("trigger,off,off"));
    }

    #[test]
    fn delta_against_zero_baseline() {
        let (a, _) = two_results();
        let cmp = Comparison::new(vec![&a]);
        // retrains are zero with runtime view off
        assert_eq!(Metric::Retrains.of(&a), 0.0);
        assert_eq!(cmp.delta(Metric::Retrains, 0), 0.0);
    }

    #[test]
    fn metric_extraction_sane() {
        let (a, _) = two_results();
        assert!(Metric::UtilTraining.of(&a) > 0.0);
        assert!(Metric::CompletionRate.of(&a) <= 1.0);
        assert!(Metric::Throughput.of(&a) > 0.0);
        assert!(Metric::WirePerPipelineMb.of(&a) > 0.0);
        // failure-free runs: perfect goodput, nothing lost, no failures
        assert_eq!(Metric::Failures.of(&a), 0.0);
        assert_eq!(Metric::LostWork.of(&a), 0.0);
        assert_eq!(Metric::Goodput.of(&a), 1.0);
    }

    #[test]
    fn reliability_rows_render_only_when_nonzero() {
        let (a, b) = two_results();
        let cmp = Comparison::new(vec![&a, &b]);
        let table = cmp.render();
        // goodput is 1.0 even without failures, so it renders; the
        // all-zero failures/lost-work rows are suppressed
        assert!(table.contains("goodput"));
        assert!(!table.contains("failures"));
        assert!(!table.contains("lost_work_s"));
        // csv keeps every metric regardless (machine-readable form)
        assert!(cmp.to_csv().contains("lost_work_s,0,0"));
    }
}
