//! Analytics: the exploratory dashboard (Fig 11), the statistical
//! accuracy analysis (Fig 12), trace summary/accuracy statistics, and
//! the figure-data emitters.

pub mod dashboard;
pub mod figures;
pub mod qq;
pub mod report;
pub mod trace_stats;

pub use dashboard::render_dashboard;
pub use qq::{qq_report, QqSeries};
pub use report::{Comparison, Metric};
pub use trace_stats::{trace_qq, trace_qq_file, TraceSummary};
