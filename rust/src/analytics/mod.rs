//! Analytics: the exploratory dashboard (Fig 11), the statistical
//! accuracy analysis (Fig 12), trace summary/accuracy statistics, the
//! figure-data emitters, and the Pareto-front capacity-planning report
//! over merged sweep groups.

pub mod dashboard;
pub mod figures;
pub mod pareto;
pub mod qq;
pub mod report;
pub mod trace_stats;

pub use dashboard::render_dashboard;
pub use pareto::{pareto_front, render_pareto, ParetoPoint};
pub use qq::{qq_report, QqSeries};
pub use report::{Comparison, Metric};
pub use trace_stats::{trace_qq, trace_qq_file, TraceSummary};
