//! Pareto-front analysis over sweep groups: which configurations are
//! worth considering at all?
//!
//! A capacity-planning sweep (the paper's section V use case) trades
//! throughput against latency and dollars. Once `sweep-merge` has the
//! per-group means, the planner's question is not "which single config
//! wins" — there is no single winner across objectives — but "which
//! configs are *dominated*": beaten or matched on every objective and
//! strictly beaten on at least one by some other group. Those can be
//! discarded; the survivors form the Pareto front.
//!
//! Objectives (fixed, matching the capacity-planning report):
//! * **capacity** — mean `completed` pipelines, maximize;
//! * **wait** — mean `mean_wait_training_s`, minimize;
//! * **utilization** — mean `util_training`, maximize;
//! * **cost** — mean `cost` dollars, minimize.

use std::fmt::Write as _;

use crate::coordinator::GroupStats;

/// One sweep group projected onto the planning objectives.
#[derive(Clone, Debug)]
pub struct ParetoPoint {
    /// Group (config) name.
    pub group: String,
    /// Mean completed pipelines (maximize).
    pub capacity: f64,
    /// Mean training wait, seconds (minimize).
    pub wait: f64,
    /// Mean training utilization (maximize).
    pub utilization: f64,
    /// Mean dollar cost (minimize).
    pub cost: f64,
    /// Whether some other group dominates this one.
    pub dominated: bool,
}

impl ParetoPoint {
    /// `true` when `other` is at least as good on every objective and
    /// strictly better on at least one.
    fn dominated_by(&self, other: &ParetoPoint) -> bool {
        let geq = other.capacity >= self.capacity
            && other.wait <= self.wait
            && other.utilization >= self.utilization
            && other.cost <= self.cost;
        let strict = other.capacity > self.capacity
            || other.wait < self.wait
            || other.utilization > self.utilization
            || other.cost < self.cost;
        geq && strict
    }
}

fn metric_mean(g: &GroupStats, name: &str) -> f64 {
    g.metrics
        .iter()
        .find(|m| m.name == name)
        .map(|m| m.mean)
        .unwrap_or(f64::NAN)
}

/// Project every group onto the objectives and mark domination.
/// O(n²) pairwise — sweeps have tens to hundreds of groups, not
/// millions. NaN objectives (a metric missing from the group table)
/// make a point incomparable: it neither dominates nor is dominated.
pub fn pareto_front(groups: &[GroupStats]) -> Vec<ParetoPoint> {
    let mut points: Vec<ParetoPoint> = groups
        .iter()
        .map(|g| ParetoPoint {
            group: g.name.clone(),
            capacity: metric_mean(g, "completed"),
            wait: metric_mean(g, "mean_wait_training_s"),
            utilization: metric_mean(g, "util_training"),
            cost: metric_mean(g, "cost"),
            dominated: false,
        })
        .collect();
    // self-comparison is harmless: domination requires a strict win
    let flags: Vec<bool> = points
        .iter()
        .map(|p| points.iter().any(|other| p.dominated_by(other)))
        .collect();
    for (p, dominated) in points.iter_mut().zip(flags) {
        p.dominated = dominated;
    }
    points
}

/// Render the Pareto report: the front first (input order preserved
/// within each section), then the dominated groups.
pub fn render_pareto(points: &[ParetoPoint]) -> String {
    let mut s = String::new();
    let front = points.iter().filter(|p| !p.dominated).count();
    let _ = writeln!(
        s,
        "pareto front over (capacity ^, wait v, utilization ^, cost v): \
         {front} of {} groups",
        points.len()
    );
    let _ = writeln!(
        s,
        "  {:<28} {:>12} {:>12} {:>12} {:>12}",
        "group", "capacity", "wait_s", "util", "cost"
    );
    for dominated in [false, true] {
        if dominated && front < points.len() {
            let _ = writeln!(s, "dominated:");
        }
        for p in points.iter().filter(|p| p.dominated == dominated) {
            let _ = writeln!(
                s,
                "  {:<28} {:>12.2} {:>12.3} {:>12.4} {:>12.2}",
                p.group, p.capacity, p.wait, p.utilization, p.cost
            );
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CellRecord;

    fn group(name: &str, completed: u64, wait: f64, util: f64, cost: f64) -> GroupStats {
        let mut c = CellRecord {
            index: 0,
            name: name.into(),
            seed: 1,
            arrived: completed,
            completed,
            in_flight: 0,
            tasks_executed: 0,
            events_processed: 0,
            gate_failures: 0,
            retrains_triggered: 0,
            failures: 0,
            wait_training: crate::stats::Summary::new(),
            util_training: util,
            util_compute: 0.0,
            avg_queue_training: 0.0,
            final_mean_performance: 0.0,
            lost_work: 0.0,
            goodput: 1.0,
            cost,
            wall_secs: 0.0,
            peak_rss_points: 0,
            digest: String::new(),
        };
        c.wait_training.add(wait);
        crate::coordinator::shard::aggregate_cells(&[c])
            .pop()
            .expect("one group")
    }

    #[test]
    fn dominated_groups_are_marked() {
        // b strictly beats a everywhere; c trades cost for capacity, so
        // both b and c sit on the front
        let groups = vec![
            group("a", 80, 5.0, 0.5, 100.0),
            group("b", 100, 4.0, 0.6, 90.0),
            group("c", 60, 4.5, 0.55, 40.0),
        ];
        let points = pareto_front(&groups);
        assert!(points[0].dominated, "a is beaten by b on all four");
        assert!(!points[1].dominated);
        assert!(!points[2].dominated);
        let report = render_pareto(&points);
        assert!(report.contains("2 of 3 groups"), "{report}");
        assert!(report.contains("dominated:"), "{report}");
        // the dominated section lists a after the front
        let a_pos = report.find("\n  a ").expect("a row");
        let dom_pos = report.find("dominated:").expect("section");
        assert!(a_pos > dom_pos, "{report}");
    }

    #[test]
    fn equal_points_do_not_dominate_each_other() {
        let groups = vec![
            group("x", 50, 1.0, 0.5, 10.0),
            group("y", 50, 1.0, 0.5, 10.0),
        ];
        let points = pareto_front(&groups);
        assert!(!points[0].dominated && !points[1].dominated);
    }

    #[test]
    fn missing_metrics_stay_incomparable() {
        let mut g = group("partial", 10, 1.0, 0.5, 5.0);
        g.metrics.retain(|m| m.name != "cost");
        let groups = vec![g, group("full", 100, 0.5, 0.9, 1.0)];
        let points = pareto_front(&groups);
        assert!(points[0].capacity.is_finite());
        assert!(points[0].cost.is_nan());
        // NaN comparisons are false, so neither direction dominates
        assert!(!points[0].dominated && !points[1].dominated);
    }
}
