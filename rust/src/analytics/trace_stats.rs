//! Trace analytics: per-trace summary statistics and Q-Q accuracy checks
//! of a captured trace against the fitted distributions — the "ad-hoc
//! exploration as well as statistical analysis" the paper runs on its
//! synthetic traces (section IV-C), applied to the event-level
//! `trace::Trace` artifact.

use crate::arrivals::ArrivalModel;
use crate::coordinator::{ExperimentConfig, SimParams};
use crate::model::{Framework, TaskType};
use crate::stats::rng::Pcg64;
use crate::stats::Summary;
use crate::trace::{Trace, TraceEvent, TraceEventKind, TraceMeta, TraceScanner};

use super::qq::{qq_report, QqSeries};

/// Retry-histogram buckets: retries of attempts 1..=7 plus an "8+"
/// tail — fixed size, so the streamed scan stays O(1) in trace length.
pub const RETRY_HIST_BUCKETS: usize = 8;

/// Aggregate statistics of one trace.
#[derive(Clone, Debug)]
pub struct TraceSummary {
    /// Total events in the trace.
    pub events: usize,
    /// `[first, last]` event time, seconds.
    pub span: (f64, f64),
    /// User pipeline arrivals (retraining launches excluded).
    pub arrivals: u64,
    /// Retraining pipeline arrivals.
    pub retrain_arrivals: u64,
    /// Pipelines that left the system.
    pub completions: u64,
    /// Completions aborted by the quality gate.
    pub gate_failures: u64,
    /// Tasks finished.
    pub tasks_done: u64,
    /// Tasks that had to queue for a cluster slot.
    pub tasks_queued: u64,
    /// Running tasks evicted by a preemptive scheduler.
    pub tasks_preempted: u64,
    /// Hardware-class placement records (one per allocated class; zero
    /// for traces captured without `hw_classes`).
    pub tasks_placed: u64,
    /// Task attempts lost to transient faults (format v6; zero for
    /// traces captured without a fault model).
    pub tasks_failed: u64,
    /// Task attempts that ran past the per-attempt timeout.
    pub tasks_timed_out: u64,
    /// Retry re-submissions issued by the retry policy.
    pub tasks_retried: u64,
    /// Arrivals turned away by admission control (`queue_cap`).
    pub tasks_shed: u64,
    /// Pipelines the retry policy gave up on.
    pub abandoned: u64,
    /// Retries by attempt number: bucket `i` counts retries of attempt
    /// `i + 1`; the last bucket absorbs attempts
    /// >= [`RETRY_HIST_BUCKETS`].
    pub retry_histogram: [u64; RETRY_HIST_BUCKETS],
    /// Trigger firings.
    pub retrains_triggered: u64,
    /// Runtime-view (re)deployments into *monitored* slots. Deploys past
    /// `runtime_view.max_models` count in `ExperimentResult::models_deployed`
    /// but appear in the trace only as deploy-task completions, so this
    /// can legitimately trail that counter.
    pub deployments: u64,
    /// Interarrival gaps drawn.
    pub interarrival: Summary,
    /// Pipeline makespans.
    pub makespan: Summary,
    /// Pipeline total queueing waits.
    pub pipeline_wait: Summary,
    /// Per-grant queueing waits.
    pub grant_wait: Summary,
    /// Exec durations per task type, indexed by `TaskType::index`.
    pub exec_by_task: Vec<Summary>,
}

impl Default for TraceSummary {
    fn default() -> Self {
        Self::new()
    }
}

impl TraceSummary {
    /// An empty accumulator. Feed events with [`TraceSummary::add`] —
    /// the push-style API exists so the streamed scanner can summarize
    /// year-scale `.pst` files without materializing the event `Vec`.
    pub fn new() -> Self {
        TraceSummary {
            events: 0,
            span: (0.0, 0.0),
            arrivals: 0,
            retrain_arrivals: 0,
            completions: 0,
            gate_failures: 0,
            tasks_done: 0,
            tasks_queued: 0,
            tasks_preempted: 0,
            tasks_placed: 0,
            tasks_failed: 0,
            tasks_timed_out: 0,
            tasks_retried: 0,
            tasks_shed: 0,
            abandoned: 0,
            retry_histogram: [0; RETRY_HIST_BUCKETS],
            retrains_triggered: 0,
            deployments: 0,
            interarrival: Summary::new(),
            makespan: Summary::new(),
            pipeline_wait: Summary::new(),
            grant_wait: Summary::new(),
            exec_by_task: vec![Summary::new(); TaskType::ALL.len()],
        }
    }

    /// Fold one event in. Events must arrive in time order (the order
    /// any `.pst` file stores them); the span tracks first/last stamps.
    pub fn add(&mut self, ev: &crate::trace::TraceEvent) {
        if self.events == 0 {
            self.span = (ev.t, ev.t);
        } else {
            self.span.1 = ev.t;
        }
        self.events += 1;
        match ev.kind {
            TraceEventKind::ArrivalGapDrawn { gap } => self.interarrival.add(gap),
            TraceEventKind::PipelineArrival { retrain_of, .. } => {
                if retrain_of.is_some() {
                    self.retrain_arrivals += 1;
                } else {
                    self.arrivals += 1;
                }
            }
            TraceEventKind::TaskQueued { .. } => self.tasks_queued += 1,
            TraceEventKind::TaskPreempted { .. } => self.tasks_preempted += 1,
            TraceEventKind::TaskRequeued { .. } => {}
            TraceEventKind::TaskStarted { .. } => {}
            TraceEventKind::TaskPlaced { .. } => self.tasks_placed += 1,
            TraceEventKind::TaskGranted { waited, .. } => self.grant_wait.add(waited),
            TraceEventKind::TaskDone { task, exec, .. } => {
                self.tasks_done += 1;
                self.exec_by_task[task.index()].add(exec);
            }
            TraceEventKind::ModelMetricUpdate { .. } => {}
            TraceEventKind::PipelineDone {
                makespan,
                total_wait,
                truncated,
                ..
            } => {
                self.completions += 1;
                if truncated {
                    self.gate_failures += 1;
                }
                self.makespan.add(makespan);
                self.pipeline_wait.add(total_wait);
            }
            TraceEventKind::RetrainTriggered { .. } => self.retrains_triggered += 1,
            TraceEventKind::RetrainLaunched { .. } => {}
            TraceEventKind::ModelDeployed { .. } => self.deployments += 1,
            TraceEventKind::TaskFailed { .. } => self.tasks_failed += 1,
            TraceEventKind::TaskRetried { attempt, .. } => {
                self.tasks_retried += 1;
                let bucket = (attempt as usize).clamp(1, RETRY_HIST_BUCKETS) - 1;
                self.retry_histogram[bucket] += 1;
            }
            TraceEventKind::TaskTimedOut { .. } => self.tasks_timed_out += 1,
            TraceEventKind::TaskShed { .. } => self.tasks_shed += 1,
            TraceEventKind::PipelineAbandoned { .. } => self.abandoned += 1,
            TraceEventKind::SlotFailed { .. }
            | TraceEventKind::SlotRepaired { .. }
            | TraceEventKind::TaskCheckpointed { .. }
            | TraceEventKind::TaskRestarted { .. } => {}
        }
    }

    /// Scan a materialized trace once and aggregate.
    pub fn from_trace(trace: &Trace) -> Self {
        let mut s = TraceSummary::new();
        for ev in &trace.events {
            s.add(ev);
        }
        s
    }

    /// Summarize a `.pst` file record-by-record through
    /// [`TraceScanner`](crate::trace::TraceScanner) — memory stays O(1)
    /// in trace length, so year-scale streamed captures can be
    /// summarized on machines that could never hold their event `Vec`.
    /// Returns the file's metadata alongside the summary.
    pub fn from_file(path: impl AsRef<std::path::Path>) -> crate::Result<(TraceMeta, Self)> {
        let mut scan = crate::trace::TraceScanner::open(path)?;
        let meta = scan.meta().clone();
        let mut s = TraceSummary::new();
        for ev in &mut scan {
            s.add(&ev?);
        }
        Ok((meta, s))
    }

    /// Human-readable stats block for `pipesim trace stats`.
    pub fn render(&self) -> String {
        use std::fmt::Write;
        let fmt = |s: &Summary| {
            if s.count == 0 {
                "n=0".to_string()
            } else {
                format!(
                    "n={} mean={:.2}s min={:.2}s max={:.2}s",
                    s.count,
                    s.mean(),
                    s.min,
                    s.max
                )
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "trace: {} events over [{:.0}s, {:.0}s] ({:.2} days)",
            self.events,
            self.span.0,
            self.span.1,
            (self.span.1 - self.span.0) / 86_400.0
        );
        let _ = writeln!(
            out,
            "  pipelines        {} arrived (+{} retrains), {} completed, {} gate-failed",
            self.arrivals, self.retrain_arrivals, self.completions, self.gate_failures
        );
        let _ = writeln!(
            out,
            "  tasks            {} done, {} queued at a saturated cluster",
            self.tasks_done, self.tasks_queued
        );
        if self.tasks_preempted > 0 {
            let _ = writeln!(out, "  preemptions      {}", self.tasks_preempted);
        }
        if self.tasks_placed > 0 {
            let _ = writeln!(out, "  placements       {}", self.tasks_placed);
        }
        if self.abandoned > 0 || self.tasks_shed > 0 {
            let _ = writeln!(
                out,
                "  outcomes         {} completed | {} abandoned | {} shed",
                self.completions, self.abandoned, self.tasks_shed
            );
        }
        if self.tasks_failed > 0 || self.tasks_timed_out > 0 {
            let _ = writeln!(
                out,
                "  task faults      {} transient, {} timed out, {} retried",
                self.tasks_failed, self.tasks_timed_out, self.tasks_retried
            );
        }
        if self.tasks_retried > 0 {
            let mut hist = String::new();
            for (i, &n) in self.retry_histogram.iter().enumerate() {
                if n > 0 {
                    let tail = if i + 1 == RETRY_HIST_BUCKETS { "+" } else { "" };
                    let _ = write!(hist, " attempt{}{}:{}", i + 1, tail, n);
                }
            }
            let _ = writeln!(out, "  retry histogram {hist}");
        }
        let _ = writeln!(out, "  interarrival     {}", fmt(&self.interarrival));
        let _ = writeln!(out, "  makespan         {}", fmt(&self.makespan));
        let _ = writeln!(out, "  pipeline wait    {}", fmt(&self.pipeline_wait));
        let _ = writeln!(out, "  grant wait       {}", fmt(&self.grant_wait));
        for task in TaskType::ALL {
            let s = &self.exec_by_task[task.index()];
            if s.count > 0 {
                let _ = writeln!(out, "  exec {:<12} {}", task.name(), fmt(s));
            }
        }
        if self.retrains_triggered > 0 || self.deployments > 0 {
            let _ = writeln!(
                out,
                "  runtime view     {} retrains triggered, {} deployments",
                self.retrains_triggered, self.deployments
            );
        }
        out
    }
}

/// Minimum observed points for a Q-Q stratum to be reported.
const MIN_STRATUM: usize = 30;

/// The arrival model (and interarrival factor) the captured run
/// actually drew from, resolved from the trace's embedded config —
/// comparing profile/poisson captures against the global random fit
/// would report spurious mismatches. Traces without a parseable config
/// fall back to the random fit at factor 1.
fn arrival_reference(config_json: &str, params: &SimParams) -> (ArrivalModel, f64) {
    if let Ok(cfg) = ExperimentConfig::from_json_text(config_json) {
        (params.resolve_arrival(cfg.arrival), cfg.interarrival_factor)
    } else {
        (params.arrival_random.clone(), 1.0)
    }
}

/// One-pass observation collector for the Q-Q strata: only the sampled
/// values survive (interarrival draws, per-framework train durations,
/// evaluate durations), so the Q-Q can run off a [`TraceScanner`]
/// without the full event `Vec` — memory is bounded by the *observed*
/// strata, not the trace length.
struct QqObservations {
    /// `(draw time, gap)` per interarrival draw — the profile model is
    /// time-of-week dependent, so the re-sampling needs the times too.
    gaps: Vec<(f64, f64)>,
    /// Train exec durations, indexed by `Framework::index`.
    train_by_fw: Vec<Vec<f64>>,
    eval: Vec<f64>,
}

impl QqObservations {
    fn new() -> Self {
        QqObservations {
            gaps: Vec::new(),
            train_by_fw: vec![Vec::new(); Framework::ALL.len()],
            eval: Vec::new(),
        }
    }

    fn record(&mut self, ev: &TraceEvent) {
        match ev.kind {
            TraceEventKind::ArrivalGapDrawn { gap } => self.gaps.push((ev.t, gap)),
            TraceEventKind::TaskDone {
                task: TaskType::Train,
                framework: Some(f),
                exec,
                ..
            } => self.train_by_fw[f.index()].push(exec),
            TraceEventKind::TaskDone {
                task: TaskType::Evaluate,
                exec,
                ..
            } => self.eval.push(exec),
            _ => {}
        }
    }
}

/// Q-Q the trace's observed interarrivals and task durations against the
/// fitted distributions in `params` (sampled `n_samples` times with
/// `seed`). Interarrivals compare against the arrival model named by the
/// trace's embedded config, re-sampled at the recorded draw times (the
/// profile model is time-of-week dependent) with the captured
/// interarrival factor re-applied. Returns one [`QqSeries`] per
/// sufficiently populated stratum — near-diagonal plots mean the
/// captured run is faithful to its fits.
pub fn trace_qq(
    trace: &Trace,
    params: &SimParams,
    n_samples: usize,
    n_q: usize,
    seed: u64,
) -> Vec<QqSeries> {
    let mut obs = QqObservations::new();
    for ev in &trace.events {
        obs.record(ev);
    }
    qq_from_observations(&obs, &trace.meta.config_json, params, n_samples, n_q, seed)
}

/// Streamed [`trace_qq`]: collect the strata in one [`TraceScanner`]
/// pass over the file, never materializing the event `Vec` — same
/// reports, same sampling order, so the output is identical to
/// `trace_qq(&Trace::load(path)?, ...)`.
pub fn trace_qq_file(
    path: &std::path::Path,
    params: &SimParams,
    n_samples: usize,
    n_q: usize,
    seed: u64,
) -> crate::Result<Vec<QqSeries>> {
    let mut scan = TraceScanner::open(path)?;
    let config_json = scan.meta().config_json.clone();
    let mut obs = QqObservations::new();
    for ev in &mut scan {
        obs.record(&ev?);
    }
    Ok(qq_from_observations(
        &obs,
        &config_json,
        params,
        n_samples,
        n_q,
        seed,
    ))
}

fn qq_from_observations(
    obs: &QqObservations,
    config_json: &str,
    params: &SimParams,
    n_samples: usize,
    n_q: usize,
    seed: u64,
) -> Vec<QqSeries> {
    let mut rng = Pcg64::new(seed);
    let mut out = Vec::new();

    // interarrivals vs the model the capture drew from
    if obs.gaps.len() >= MIN_STRATUM {
        let (mut model, factor) = arrival_reference(config_json, params);
        let sim: Vec<f64> = (0..n_samples)
            .map(|i| {
                let (t, _) = obs.gaps[i % obs.gaps.len()];
                model.next_interarrival(t, factor, &mut rng)
            })
            .collect();
        let gaps: Vec<f64> = obs.gaps.iter().map(|&(_, g)| g).collect();
        out.push(qq_report("interarrival/fit", &gaps, &sim, n_q));
    }

    // train durations per framework vs the fitted log-mixtures
    for fw in Framework::ALL {
        let observed = &obs.train_by_fw[fw.index()];
        if observed.len() >= MIN_STRATUM {
            let g = params.train_gmm(fw);
            let sim: Vec<f64> = (0..n_samples)
                .map(|_| g.sample(&mut rng).exp().max(0.1))
                .collect();
            out.push(qq_report(format!("train/{fw}/fit"), observed, &sim, n_q));
        }
    }

    // evaluate durations vs the fitted mixture
    if obs.eval.len() >= MIN_STRATUM {
        let sim: Vec<f64> = (0..n_samples)
            .map(|_| params.eval_log_gmm.sample(&mut rng).exp().max(0.05))
            .collect();
        out.push(qq_report("evaluate/fit", &obs.eval, &sim, n_q));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{fit_params, ArrivalSpec, Experiment, ExperimentConfig};
    use crate::des::DAY;
    use crate::empirical::GroundTruth;

    fn captured() -> (SimParams, Trace) {
        let db = GroundTruth::new(61).generate_weeks(2);
        let params = fit_params(&db, None).unwrap();
        let cfg = ExperimentConfig {
            name: "trace-stats".into(),
            seed: 3,
            horizon: 2.0 * DAY,
            arrival: ArrivalSpec::Random,
            capture_trace: true,
            ..Default::default()
        };
        let mut r = Experiment::new(cfg, params.clone()).run().unwrap();
        (params, r.trace.take().expect("capture on"))
    }

    #[test]
    fn summary_counts_match_event_stream() {
        let (_, trace) = captured();
        let s = TraceSummary::from_trace(&trace);
        assert_eq!(s.events, trace.len());
        assert!(s.arrivals > 300, "arrivals {}", s.arrivals);
        assert!(s.completions > 0 && s.completions <= s.arrivals + s.retrain_arrivals);
        assert!(s.tasks_done > s.completions);
        assert_eq!(s.interarrival.count, s.arrivals + 1);
        assert!(s.makespan.mean() > 0.0);
        // exec stats populated for the universal task types
        assert!(s.exec_by_task[TaskType::Train.index()].count > 0);
        let text = s.render();
        assert!(text.contains("pipelines"));
        assert!(text.contains("exec train"));
    }

    #[test]
    fn fault_outcomes_and_retry_histogram_stream_identically() {
        use crate::model::ResourceKind;
        let e = |t, kind| TraceEvent { t, kind };
        let mut events = vec![e(0.0, TraceEventKind::ArrivalGapDrawn { gap: 1.0 })];
        for a in 1..=10u32 {
            events.push(e(
                a as f64,
                TraceEventKind::TaskFailed {
                    pid: a,
                    task: TaskType::Train,
                    resource: ResourceKind::Training,
                    attempt: a,
                    elapsed: 5.0,
                },
            ));
            events.push(e(
                a as f64,
                TraceEventKind::TaskRetried {
                    pid: a,
                    task: TaskType::Train,
                    resource: ResourceKind::Training,
                    attempt: a,
                    delay: 1.0,
                },
            ));
        }
        events.push(e(
            20.0,
            TraceEventKind::TaskTimedOut {
                pid: 1,
                task: TaskType::Evaluate,
                resource: ResourceKind::Compute,
                elapsed: 30.0,
            },
        ));
        events.push(e(
            21.0,
            TraceEventKind::TaskShed {
                pid: 2,
                task: TaskType::Preprocess,
                resource: ResourceKind::Compute,
                queue_depth: 9,
            },
        ));
        events.push(e(
            22.0,
            TraceEventKind::PipelineAbandoned {
                pid: 1,
                attempts: 4,
                makespan: 22.0,
            },
        ));
        let trace = Trace {
            meta: TraceMeta {
                name: "faults".into(),
                seed: 1,
                horizon: 100.0,
                config_json: String::new(),
                extra: Vec::new(),
            },
            events,
        };
        let s = TraceSummary::from_trace(&trace);
        assert_eq!(s.tasks_failed, 10);
        assert_eq!(s.tasks_retried, 10);
        assert_eq!(s.tasks_timed_out, 1);
        assert_eq!(s.tasks_shed, 1);
        assert_eq!(s.abandoned, 1);
        // attempts 1..=7 land in their own buckets; 8, 9, 10 in the tail
        assert_eq!(&s.retry_histogram[..7], &[1, 1, 1, 1, 1, 1, 1]);
        assert_eq!(s.retry_histogram[7], 3);
        let text = s.render();
        assert!(text.contains("1 abandoned | 1 shed"), "{text}");
        assert!(
            text.contains("10 transient, 1 timed out, 10 retried"),
            "{text}"
        );
        assert!(text.contains("attempt8+:3"), "{text}");
        // the streamed scanner folds the v6 records identically
        let path = std::env::temp_dir().join(format!(
            "pipesim_stats_faults_{}.pst",
            std::process::id()
        ));
        trace.save(&path).unwrap();
        let (_, streamed) = TraceSummary::from_file(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(streamed.tasks_failed, s.tasks_failed);
        assert_eq!(streamed.retry_histogram, s.retry_histogram);
        assert_eq!(streamed.abandoned, s.abandoned);
    }

    #[test]
    fn from_file_agrees_with_from_trace() {
        // the streamed scanner and the materializing loader must
        // produce the identical summary for the same capture
        let (_, trace) = captured();
        let path = std::env::temp_dir().join(format!(
            "pipesim_stats_scan_{}.pst",
            std::process::id()
        ));
        trace.save(&path).unwrap();
        let (meta, streamed) = TraceSummary::from_file(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(meta, trace.meta);
        let buffered = TraceSummary::from_trace(&trace);
        assert_eq!(streamed.events, buffered.events);
        assert_eq!(streamed.arrivals, buffered.arrivals);
        assert_eq!(streamed.completions, buffered.completions);
        assert_eq!(streamed.tasks_done, buffered.tasks_done);
        assert_eq!(streamed.span, buffered.span);
        assert_eq!(streamed.makespan.sum.to_bits(), buffered.makespan.sum.to_bits());
        assert_eq!(streamed.grant_wait.count, buffered.grant_wait.count);
    }

    #[test]
    fn streamed_qq_matches_the_buffered_qq() {
        let (params, trace) = captured();
        let path = std::env::temp_dir().join(format!(
            "pipesim_qq_scan_{}.pst",
            std::process::id()
        ));
        trace.save(&path).unwrap();
        let streamed = trace_qq_file(&path, &params, 5_000, 30, 7).unwrap();
        std::fs::remove_file(&path).ok();
        let buffered = trace_qq(&trace, &params, 5_000, 30, 7);
        assert_eq!(streamed.len(), buffered.len());
        for (a, b) in streamed.iter().zip(&buffered) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.ks.to_bits(), b.ks.to_bits(), "{}", a.name);
            assert_eq!(
                a.quantile_corr.to_bits(),
                b.quantile_corr.to_bits(),
                "{}",
                a.name
            );
        }
    }

    #[test]
    fn qq_resolves_the_captured_arrival_model() {
        // a poisson capture must be compared against poisson, not the
        // fitted global random model — otherwise the verdict reports a
        // spurious mismatch for a perfectly faithful capture
        let db = GroundTruth::new(62).generate_weeks(2);
        let params = fit_params(&db, None).unwrap();
        let cfg = ExperimentConfig {
            name: "qq-poisson".into(),
            seed: 8,
            horizon: DAY,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 120.0,
            },
            capture_trace: true,
            ..Default::default()
        };
        let mut r = Experiment::new(cfg, params.clone()).run().unwrap();
        let trace = r.trace.take().unwrap();
        let qq = trace_qq(&trace, &params, 20_000, 40, 9);
        let ia = qq.iter().find(|q| q.name == "interarrival/fit").unwrap();
        assert!(ia.quantile_corr > 0.95, "{}", ia.verdict());
        assert!(ia.ks < 0.1, "{}", ia.verdict());
    }

    #[test]
    fn qq_against_fits_is_near_diagonal() {
        // the capture came from these very fits, so the Q-Q must be tight
        let (params, trace) = captured();
        let qq = trace_qq(&trace, &params, 20_000, 40, 7);
        assert!(qq.len() >= 3, "strata: {}", qq.len());
        let ia = qq.iter().find(|q| q.name == "interarrival/fit").unwrap();
        assert!(ia.quantile_corr > 0.95, "{}", ia.verdict());
        let train = qq
            .iter()
            .find(|q| q.name.starts_with("train/sparkml"))
            .expect("sparkml stratum");
        assert!(train.quantile_corr > 0.95, "{}", train.verdict());
    }
}
