//! Simulation-accuracy analysis: Q-Q data of simulated vs empirical
//! distributions (Fig 12a/b) plus summary statistics (KS distance,
//! quantile correlation).

use crate::stats::desc::{ks_distance, pearson, qq_points};

/// One Q-Q comparison: a named stratum (task type, framework, arrival
/// mode) with paired quantiles of empirical (x) vs simulated (y) data.
#[derive(Clone, Debug)]
pub struct QqSeries {
    pub name: String,
    /// (empirical quantile, simulated quantile) pairs.
    pub points: Vec<(f64, f64)>,
    /// Two-sample KS distance.
    pub ks: f64,
    /// Pearson correlation of the paired quantiles (1.0 = perfect).
    pub quantile_corr: f64,
    /// Mean relative quantile error |q_sim - q_emp| / q_emp.
    pub mean_rel_err: f64,
    pub n_empirical: usize,
    pub n_simulated: usize,
}

/// Build a Q-Q comparison between empirical and simulated samples.
pub fn qq_report(name: impl Into<String>, empirical: &[f64], simulated: &[f64], n_q: usize) -> QqSeries {
    let points = qq_points(empirical, simulated, n_q);
    let xs: Vec<f64> = points.iter().map(|p| p.0).collect();
    let ys: Vec<f64> = points.iter().map(|p| p.1).collect();
    let mean_rel_err = points
        .iter()
        .filter(|(x, _)| x.abs() > 1e-12)
        .map(|(x, y)| ((y - x) / x).abs())
        .sum::<f64>()
        / points.len().max(1) as f64;
    QqSeries {
        name: name.into(),
        ks: ks_distance(empirical, simulated),
        quantile_corr: pearson(&xs, &ys),
        mean_rel_err,
        n_empirical: empirical.len(),
        n_simulated: simulated.len(),
        points,
    }
}

impl QqSeries {
    /// CSV rows: `name,empirical_q,simulated_q`.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        for (x, y) in &self.points {
            out.push_str(&format!("{},{x},{y}\n", self.name));
        }
        out
    }

    /// One-line verdict used in reports.
    pub fn verdict(&self) -> String {
        format!(
            "{:<24} n_emp={:<7} n_sim={:<7} KS={:.4} q-corr={:.4} rel-err={:.1}%",
            self.name,
            self.n_empirical,
            self.n_simulated,
            self.ks,
            self.quantile_corr,
            100.0 * self.mean_rel_err
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::dist::{Distribution, LogNormal};
    use crate::stats::rng::Pcg64;

    #[test]
    fn identical_distributions_near_diagonal() {
        let mut rng = Pcg64::new(1);
        let d = LogNormal::new(2.0, 0.8);
        let a: Vec<f64> = (0..30_000).map(|_| d.sample(&mut rng)).collect();
        let b: Vec<f64> = (0..30_000).map(|_| d.sample(&mut rng)).collect();
        let q = qq_report("same", &a, &b, 50);
        assert!(q.ks < 0.02, "ks {}", q.ks);
        assert!(q.quantile_corr > 0.999);
        assert!(q.mean_rel_err < 0.05, "rel err {}", q.mean_rel_err);
    }

    #[test]
    fn shifted_distribution_detected() {
        let mut rng = Pcg64::new(2);
        let a: Vec<f64> = (0..20_000).map(|_| LogNormal::new(2.0, 0.8).sample(&mut rng)).collect();
        let b: Vec<f64> = (0..20_000).map(|_| LogNormal::new(2.5, 0.8).sample(&mut rng)).collect();
        let q = qq_report("shifted", &a, &b, 50);
        assert!(q.ks > 0.2);
        assert!(q.mean_rel_err > 0.3);
    }

    #[test]
    fn csv_format() {
        let q = qq_report("x", &[1.0, 2.0, 3.0], &[1.0, 2.0, 3.0], 3);
        let csv = q.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("x,"));
    }
}
