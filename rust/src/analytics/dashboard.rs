//! Terminal rendering of the experiment dashboard (the paper's Grafana
//! front-end, Fig 11): parameter panel, task statistics, utilization /
//! arrival / wait-time timelines as sparklines.

use crate::coordinator::result::series;
use crate::coordinator::ExperimentResult;
use crate::tsdb::Agg;

const SPARK_CHARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];

/// Render a sequence of optional values as a unicode sparkline.
pub fn sparkline(values: &[Option<f64>]) -> String {
    let present: Vec<f64> = values.iter().flatten().cloned().collect();
    if present.is_empty() {
        return String::from("(no data)");
    }
    let lo = present.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = present.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    values
        .iter()
        .map(|v| match v {
            None => ' ',
            Some(x) => {
                let idx = (((x - lo) / span) * 7.0).round() as usize;
                SPARK_CHARS[idx.min(7)]
            }
        })
        .collect()
}

/// Full dashboard text for an experiment result.
pub fn render_dashboard(r: &ExperimentResult, windows: usize) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(out, "┌─ PipeSim experiment dashboard ─ {}", r.name);
    out.push_str(&indent(&r.summary()));
    let width = r.horizon / windows as f64;

    let mut timeline = |title: &str, measurement: &str, tag: Option<(&str, &str)>, agg: Agg| {
        let handles = match tag {
            Some((k, v)) => r.tsdb.find_tagged(measurement, k, v),
            None => r.tsdb.find(measurement),
        };
        if handles.is_empty() {
            return;
        }
        // merge all matching series into one windowed line
        let mut merged: Vec<Option<f64>> = vec![None; windows];
        for h in handles {
            let w = r.tsdb.window(h, 0.0, r.horizon, width, agg);
            for (i, wa) in w.iter().enumerate().take(windows) {
                if let Some(v) = wa.value {
                    merged[i] = Some(merged[i].unwrap_or(0.0) + v);
                }
            }
        }
        let vals: Vec<f64> = merged.iter().flatten().cloned().collect();
        let (lo, hi) = if vals.is_empty() {
            (0.0, 0.0)
        } else {
            (
                vals.iter().cloned().fold(f64::INFINITY, f64::min),
                vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
            )
        };
        let _ = writeln!(
            out,
            "│ {:<28} {}  [{:.2} … {:.2}]",
            title,
            sparkline(&merged),
            lo,
            hi
        );
    };

    timeline("training utilization", series::UTILIZATION, Some(("resource", "training")), Agg::Mean);
    timeline("compute utilization", series::UTILIZATION, Some(("resource", "compute")), Agg::Mean);
    timeline("training queue length", series::QUEUE_LEN, Some(("resource", "training")), Agg::Mean);
    timeline("pipeline arrivals", series::ARRIVALS, None, Agg::Count);
    timeline("pipeline wait (s)", series::PIPELINE_WAIT, None, Agg::Mean);
    timeline("wire traffic (bytes)", series::TRAFFIC, None, Agg::Sum);
    timeline("mean model perf", series::MODEL_PERF, None, Agg::Mean);
    out.push_str("└─\n");
    out
}

fn indent(s: &str) -> String {
    s.lines()
        .map(|l| format!("│ {l}\n"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparkline_shapes() {
        let vals: Vec<Option<f64>> = vec![Some(0.0), Some(0.5), Some(1.0), None];
        let s = sparkline(&vals);
        assert_eq!(s.chars().count(), 4);
        assert!(s.starts_with('▁'));
        assert!(s.contains('█'));
        assert!(s.ends_with(' '));
    }

    #[test]
    fn sparkline_empty() {
        assert_eq!(sparkline(&[None, None]), "(no data)");
    }

    #[test]
    fn sparkline_constant() {
        let s = sparkline(&[Some(5.0), Some(5.0)]);
        assert_eq!(s.chars().count(), 2);
    }
}
