//! Figure-data emitters: every table/figure of the paper's evaluation
//! regenerated as CSV (the plots are one `plot <csv>` away; the *data*
//! is what the reproduction asserts on).

use crate::coordinator::result::series;
use crate::coordinator::{ExperimentResult, SimParams};
use crate::empirical::AnalyticsDb;
use crate::model::{CompressionModel, Framework};
use crate::stats::rng::Pcg64;
use crate::tsdb::Agg;

use super::qq::{qq_report, QqSeries};

/// Fig 8: empirical vs synthesized asset observations in log space.
/// Columns: `source,ln_rows,ln_cols,ln_bytes`.
pub fn fig8_assets(db: &AnalyticsDb, params: &SimParams, n_synth: usize, seed: u64) -> String {
    let mut out = String::from("source,ln_rows,ln_cols,ln_bytes\n");
    for row in db.asset_log_matrix() {
        out.push_str(&format!("empirical,{},{},{}\n", row[0], row[1], row[2]));
    }
    let mut rng = Pcg64::new(seed);
    for _ in 0..n_synth {
        let s = params.asset_gmm.sample(&mut rng);
        out.push_str(&format!("synthesized,{},{},{}\n", s[0], s[1], s[2]));
    }
    out
}

/// Fig 9a: preprocess compute time vs ln(rows·cols), empirical scatter +
/// the fitted curve. Columns: `kind,x,y`.
pub fn fig9a_preproc(db: &AnalyticsDb, params: &SimParams, max_points: usize) -> String {
    let mut out = String::from("kind,x,y\n");
    let (xs, ys) = db.preproc_pairs();
    let stride = (xs.len() / max_points.max(1)).max(1);
    for i in (0..xs.len()).step_by(stride) {
        out.push_str(&format!("observed,{},{}\n", xs[i], ys[i]));
    }
    let (lo, hi) = xs.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &x| {
        (l.min(x), h.max(x))
    });
    let mut x = lo;
    while x <= hi {
        out.push_str(&format!("fitted,{},{}\n", x, params.preproc_curve.eval(x)));
        x += (hi - lo) / 200.0;
    }
    out
}

/// Fig 9b: training-duration samples per framework, empirical vs the
/// fitted mixture (below the 99th percentile, as the paper plots).
/// Columns: `source,framework,duration_s`.
pub fn fig9b_train(db: &AnalyticsDb, params: &SimParams, n_synth: usize, seed: u64) -> String {
    let mut out = String::from("source,framework,duration_s\n");
    let mut rng = Pcg64::new(seed);
    for fw in [Framework::SparkML, Framework::TensorFlow] {
        let mut emp = db.durations_for(fw);
        emp.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p99 = crate::stats::desc::quantile_sorted(&emp, 0.99);
        for d in emp.iter().filter(|&&d| d <= p99) {
            out.push_str(&format!("empirical,{fw},{d}\n"));
        }
        let g = params.train_gmm(fw);
        for _ in 0..n_synth {
            let d = g.sample(&mut rng).exp();
            if d <= p99 {
                out.push_str(&format!("simulated,{fw},{d}\n"));
            }
        }
    }
    out
}

/// Fig 10: average arrivals per hour by hour-of-week.
/// Columns: `hour_of_week,day,hour,arrivals_per_hour`.
pub fn fig10_arrivals(db: &AnalyticsDb) -> String {
    const DAYS: [&str; 7] = ["mon", "tue", "wed", "thu", "fri", "sat", "sun"];
    let mut out = String::from("hour_of_week,day,hour,arrivals_per_hour\n");
    for (how, rate) in db.arrivals_per_hour_of_week().iter().enumerate() {
        out.push_str(&format!("{how},{},{},{rate}\n", DAYS[how / 24], how % 24));
    }
    out
}

/// Fig 11: the dashboard's windowed series of one experiment.
/// Columns: `series,window_start_s,value`.
pub fn fig11_dashboard(r: &ExperimentResult, window: f64) -> String {
    let mut out = String::from("series,window_start_s,value\n");
    let mut emit = |label: &str, measurement: &str, tag: Option<(&str, &str)>, agg: Agg| {
        let handles = match tag {
            Some((k, v)) => r.tsdb.find_tagged(measurement, k, v),
            None => r.tsdb.find(measurement),
        };
        for h in handles {
            for w in r.tsdb.window(h, 0.0, r.horizon, window, agg) {
                if let Some(v) = w.value {
                    out.push_str(&format!("{label},{},{v}\n", w.start));
                }
            }
        }
    };
    emit("util_training", series::UTILIZATION, Some(("resource", "training")), Agg::Mean);
    emit("util_compute", series::UTILIZATION, Some(("resource", "compute")), Agg::Mean);
    emit("queue_training", series::QUEUE_LEN, Some(("resource", "training")), Agg::Mean);
    emit("queue_compute", series::QUEUE_LEN, Some(("resource", "compute")), Agg::Mean);
    emit("arrivals_per_window", series::ARRIVALS, None, Agg::Count);
    emit("pipeline_wait_mean", series::PIPELINE_WAIT, None, Agg::Mean);
    emit("traffic_read", series::TRAFFIC, Some(("dir", "read")), Agg::Sum);
    emit("traffic_write", series::TRAFFIC, Some(("dir", "write")), Agg::Sum);
    emit("model_perf", series::MODEL_PERF, None, Agg::Mean);
    out
}

/// Extract simulated exec durations for a task stratum from a result.
pub fn simulated_durations(
    r: &ExperimentResult,
    task: &str,
    framework: Option<&str>,
) -> Vec<f64> {
    let mut out = Vec::new();
    for h in r.tsdb.find_tagged(series::TASK_EXEC, "task", task) {
        if let Some(fw) = framework {
            if r.tsdb.key(h).tag_value("framework") != Some(fw) {
                continue;
            }
        }
        out.extend_from_slice(r.tsdb.values(h));
    }
    out
}

/// Simulated interarrivals from the arrivals marker series.
pub fn simulated_interarrivals(r: &ExperimentResult) -> Vec<f64> {
    let mut times: Vec<f64> = Vec::new();
    for h in r.tsdb.find(series::ARRIVALS) {
        times.extend_from_slice(&r.tsdb.series(h).times);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times.windows(2).map(|w| w[1] - w[0]).collect()
}

/// Fig 12a: Q-Q of task durations — preprocess, train × framework,
/// evaluate — simulated (from an experiment run) vs empirical (DB).
pub fn fig12a_qq(db: &AnalyticsDb, r: &ExperimentResult, n_q: usize) -> Vec<QqSeries> {
    let mut out = Vec::new();
    let (_, pre_emp) = db.preproc_pairs();
    let pre_sim = simulated_durations(r, "preprocess", None);
    if !pre_emp.is_empty() && !pre_sim.is_empty() {
        out.push(qq_report("preprocess", &pre_emp, &pre_sim, n_q));
    }
    for fw in [
        Framework::SparkML,
        Framework::TensorFlow,
        Framework::PyTorch,
        Framework::Caffe,
    ] {
        let emp = db.durations_for(fw);
        let sim = simulated_durations(r, "train", Some(fw.name()));
        if emp.len() > 50 && sim.len() > 50 {
            out.push(qq_report(format!("train/{fw}"), &emp, &sim, n_q));
        }
    }
    let ev_emp = db.eval_durations();
    let ev_sim = simulated_durations(r, "evaluate", None);
    if !ev_emp.is_empty() && !ev_sim.is_empty() {
        out.push(qq_report("evaluate", &ev_emp, &ev_sim, n_q));
    }
    out
}

/// Fig 12b: Q-Q of interarrivals (one result per arrival mode).
pub fn fig12b_qq(db: &AnalyticsDb, r: &ExperimentResult, label: &str, n_q: usize) -> Option<QqSeries> {
    let emp = db.interarrivals();
    let sim = simulated_interarrivals(r);
    if emp.len() > 100 && sim.len() > 100 {
        Some(qq_report(format!("interarrival/{label}"), &emp, &sim, n_q))
    } else {
        None
    }
}

/// Fig 12c: simulated vs empirical average arrivals per hour-of-week.
/// Columns: `hour_of_week,empirical,simulated`.
pub fn fig12c_profile(db: &AnalyticsDb, r: &ExperimentResult) -> String {
    let emp = db.arrivals_per_hour_of_week();
    // bucket simulated arrival times by hour-of-week
    let mut sim = [0.0f64; 168];
    let mut times: Vec<f64> = Vec::new();
    for h in r.tsdb.find(series::ARRIVALS) {
        times.extend_from_slice(&r.tsdb.series(h).times);
    }
    for &t in &times {
        sim[crate::empirical::db::hour_of_week(t)] += 1.0;
    }
    let weeks = (r.horizon / crate::des::WEEK).max(1e-9);
    for s in sim.iter_mut() {
        *s /= weeks;
    }
    let mut out = String::from("hour_of_week,empirical,simulated\n");
    for how in 0..168 {
        out.push_str(&format!("{how},{},{}\n", emp[how], sim[how]));
    }
    out
}

/// Table I: the calibration data and the regenerated table side by side.
pub fn table1() -> String {
    let model = CompressionModel::from_table1();
    let regen = model.regenerate_table1();
    let mut out = String::from(
        "prune_pct,gn_acc_paper,gn_acc_model,rn50_acc_paper,rn50_acc_model,\
gn_mb_paper,gn_mb_model,rn50_mb_paper,rn50_mb_model,\
gn_ms_paper,gn_ms_model,rn50_ms_paper,rn50_ms_model\n",
    );
    for (p, m) in crate::model::compression::TABLE1.iter().zip(&regen) {
        out.push_str(&format!(
            "{},{},{:.1},{},{:.1},{},{:.1},{},{:.1},{},{:.0},{},{:.0}\n",
            p.prune_pct,
            p.gn_accuracy,
            m.gn_accuracy,
            p.rn50_accuracy,
            m.rn50_accuracy,
            p.gn_size_mb,
            m.gn_size_mb,
            p.rn50_size_mb,
            m.rn50_size_mb,
            p.gn_inference_ms,
            m.gn_inference_ms,
            p.rn50_inference_ms,
            m.rn50_inference_ms,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{fit_params, ArrivalSpec, Experiment, ExperimentConfig};
    use crate::des::DAY;
    use crate::empirical::GroundTruth;

    fn setup() -> (AnalyticsDb, SimParams, ExperimentResult) {
        let db = GroundTruth::new(31).generate_weeks(3);
        let params = fit_params(&db, None).unwrap();
        let cfg = ExperimentConfig {
            horizon: 2.0 * DAY,
            arrival: ArrivalSpec::Random,
            ..Default::default()
        };
        let r = Experiment::new(cfg, params.clone()).run().unwrap();
        (db, params, r)
    }

    #[test]
    fn all_figures_emit() {
        let (db, params, r) = setup();
        assert!(fig8_assets(&db, &params, 500, 1).lines().count() > 500);
        assert!(fig9a_preproc(&db, &params, 500).contains("fitted,"));
        assert!(fig9b_train(&db, &params, 500, 2).contains("tensorflow"));
        assert_eq!(fig10_arrivals(&db).lines().count(), 169);
        assert!(fig11_dashboard(&r, 3600.0).contains("util_training"));
        let qq = fig12a_qq(&db, &r, 40);
        assert!(qq.len() >= 3, "got {} strata", qq.len());
        assert!(fig12b_qq(&db, &r, "random", 40).is_some());
        assert_eq!(fig12c_profile(&db, &r).lines().count(), 169);
        assert!(table1().contains("80"));
    }

    #[test]
    fn qq_train_accuracy_reasonable() {
        // the paper's train Q-Q is near-diagonal; require q-corr > 0.95
        let (db, _, r) = setup();
        let qq = fig12a_qq(&db, &r, 40);
        let train = qq
            .iter()
            .find(|q| q.name.starts_with("train/sparkml"))
            .expect("sparkml stratum");
        assert!(train.quantile_corr > 0.95, "{}", train.verdict());
    }

    #[test]
    fn simulated_interarrivals_extracted() {
        let (_, _, r) = setup();
        let gaps = simulated_interarrivals(&r);
        assert!(gaps.len() as u64 == r.arrived - 1);
        assert!(gaps.iter().all(|&g| g >= 0.0));
    }
}
