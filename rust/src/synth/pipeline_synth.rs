//! Stochastic pipeline synthesizer (paper section IV-B1).
//!
//! Generates plausible pipelines: the task sequence follows the
//! prototypical structures of Fig 1, optional steps carry (possibly
//! conditional) probabilities, and task characteristics (training
//! framework) follow configurable frequencies — defaulting to the
//! production mix the paper reports.

use crate::model::{Framework, Pipeline, TaskType};
use crate::model::pipeline::TaskNode;
use crate::stats::rng::Pcg64;

/// Synthesis probabilities. Every optional step has an inclusion
/// probability; conditional ones depend on the state of the pipeline
/// being generated (e.g. a re-evaluation only after compress/harden).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SynthConfig {
    /// Framework mix (must sum to 1 across Framework::ALL order).
    pub framework_shares: [f64; 5],
    /// P(pipeline has a data-preprocessing step).
    pub p_preprocess: f64,
    /// P(evaluation step after training).
    pub p_evaluate: f64,
    /// P(model compression step) — conditional on having evaluated.
    pub p_compress: f64,
    /// P(robustness hardening step).
    pub p_harden: f64,
    /// P(re-evaluation | compress or harden present).
    pub p_reevaluate: f64,
    /// P(transfer-learning second training step), Fig 1(3).
    pub p_transfer: f64,
    /// P(deployment step at the end).
    pub p_deploy: f64,
}

impl Default for SynthConfig {
    fn default() -> Self {
        SynthConfig {
            framework_shares: [0.63, 0.32, 0.03, 0.01, 0.01],
            p_preprocess: 0.55,
            p_evaluate: 0.70,
            p_compress: 0.10,
            p_harden: 0.05,
            p_reevaluate: 0.80,
            p_transfer: 0.05,
            p_deploy: 0.80,
        }
    }
}

impl SynthConfig {
    /// Shift the framework mix (the "TensorFlow trending" experiment the
    /// paper motivates in section V-A2b). `tf_share` takes from SparkML.
    pub fn with_tensorflow_share(mut self, tf_share: f64) -> Self {
        let tf_share = tf_share.clamp(0.0, 0.95);
        let others: f64 = self.framework_shares[2..].iter().sum();
        self.framework_shares[1] = tf_share;
        self.framework_shares[0] = (1.0 - tf_share - others).max(0.0);
        self
    }
}

/// Draws pipelines from the configured distribution.
pub struct PipelineSynthesizer {
    pub cfg: SynthConfig,
    rng: Pcg64,
    pub generated: u64,
}

impl PipelineSynthesizer {
    pub fn new(cfg: SynthConfig, rng: Pcg64) -> Self {
        PipelineSynthesizer {
            cfg,
            rng,
            generated: 0,
        }
    }

    /// Sample a framework from the configured mix.
    pub fn sample_framework(&mut self) -> Framework {
        let idx = self.rng.categorical(&self.cfg.framework_shares);
        Framework::ALL[idx]
    }

    /// Generate one plausible pipeline.
    pub fn generate(&mut self) -> Pipeline {
        let nodes = self.generate_nodes();
        Pipeline::linear(nodes.as_slice().to_vec())
    }

    /// Hot-path variant: the task sequence without digraph construction
    /// (the simulator executes sequentially; building edge vectors per
    /// arrival costs an allocation for nothing — see EXPERIMENTS.md §Perf).
    pub fn generate_nodes(&mut self) -> TaskList {
        self.generated += 1;
        let fw = self.sample_framework();
        let mut nodes = TaskList::new();
        if self.rng.uniform() < self.cfg.p_preprocess {
            nodes.push(TaskNode::new(TaskType::Preprocess));
        }
        nodes.push(TaskNode::with_framework(TaskType::Train, fw));
        if self.rng.uniform() < self.cfg.p_transfer {
            // hierarchical: fine-tune on top of the base model, Fig 1(3)
            nodes.push(TaskNode::with_framework(TaskType::Train, fw));
        }
        let evaluated = self.rng.uniform() < self.cfg.p_evaluate;
        if evaluated {
            nodes.push(TaskNode::new(TaskType::Evaluate));
        }
        // compression is observed on evaluated (quality-gated) pipelines
        let mut post = false;
        if evaluated && self.rng.uniform() < self.cfg.p_compress {
            nodes.push(TaskNode::with_framework(TaskType::Compress, fw));
            post = true;
        }
        if self.rng.uniform() < self.cfg.p_harden {
            nodes.push(TaskNode::with_framework(TaskType::Harden, fw));
            post = true;
        }
        if post && self.rng.uniform() < self.cfg.p_reevaluate {
            nodes.push(TaskNode::new(TaskType::Evaluate));
        }
        if self.rng.uniform() < self.cfg.p_deploy {
            nodes.push(TaskNode::new(TaskType::Deploy));
        }
        nodes
    }
}

/// Inline fixed-capacity task sequence (max 8 tasks: preprocess, 2x train,
/// evaluate, compress, harden, re-evaluate, deploy) — allocation-free on
/// the arrival hot path.
#[derive(Clone, Copy, Debug)]
pub struct TaskList {
    items: [TaskNode; 8],
    len: u8,
}

impl TaskList {
    pub fn new() -> Self {
        TaskList {
            items: [TaskNode::new(TaskType::Train); 8],
            len: 0,
        }
    }

    #[inline]
    pub fn push(&mut self, node: TaskNode) {
        assert!((self.len as usize) < 8, "pipeline longer than 8 tasks");
        self.items[self.len as usize] = node;
        self.len += 1;
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn get(&self, i: usize) -> TaskNode {
        debug_assert!(i < self.len as usize);
        self.items[i]
    }

    pub fn as_slice(&self) -> &[TaskNode] {
        &self.items[..self.len as usize]
    }

    /// Build from a slice (retraining pipelines).
    pub fn from_slice(nodes: &[TaskNode]) -> Self {
        let mut l = TaskList::new();
        for &n in nodes {
            l.push(n);
        }
        l
    }
}

impl Default for TaskList {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_generated_pipelines_are_valid() {
        let mut synth = PipelineSynthesizer::new(SynthConfig::default(), Pcg64::new(1));
        for _ in 0..5000 {
            let p = synth.generate();
            p.validate()
                .unwrap_or_else(|e| panic!("invalid pipeline {}: {e}", p.signature()));
        }
    }

    #[test]
    fn framework_mix_matches_config() {
        let mut synth = PipelineSynthesizer::new(SynthConfig::default(), Pcg64::new(2));
        let mut counts = [0usize; 5];
        let n = 50_000;
        for _ in 0..n {
            counts[synth.sample_framework().index()] += 1;
        }
        for (i, f) in Framework::ALL.iter().enumerate() {
            let got = counts[i] as f64 / n as f64;
            assert!(
                (got - f.paper_share()).abs() < 0.01,
                "{f}: {got} vs {}",
                f.paper_share()
            );
        }
    }

    #[test]
    fn optional_step_frequencies() {
        let mut synth = PipelineSynthesizer::new(SynthConfig::default(), Pcg64::new(3));
        let n = 20_000;
        let mut with_pre = 0;
        let mut with_eval = 0;
        for _ in 0..n {
            let p = synth.generate();
            if p.has_task(TaskType::Preprocess) {
                with_pre += 1;
            }
            if p.has_task(TaskType::Evaluate) {
                with_eval += 1;
            }
        }
        assert!((with_pre as f64 / n as f64 - 0.55).abs() < 0.02);
        // evaluate appears via p_evaluate and re-evaluate
        assert!(with_eval as f64 / n as f64 > 0.65);
    }

    #[test]
    fn compression_conditional_on_evaluation() {
        let cfg = SynthConfig {
            p_evaluate: 0.0,
            p_compress: 1.0,
            ..Default::default()
        };
        let mut synth = PipelineSynthesizer::new(cfg, Pcg64::new(4));
        for _ in 0..2000 {
            let p = synth.generate();
            assert!(!p.has_task(TaskType::Compress), "compress without evaluate");
        }
    }

    #[test]
    fn tensorflow_trend_shifts_mix() {
        let cfg = SynthConfig::default().with_tensorflow_share(0.80);
        let mut synth = PipelineSynthesizer::new(cfg, Pcg64::new(5));
        let n = 20_000;
        let mut tf = 0;
        for _ in 0..n {
            if synth.sample_framework() == Framework::TensorFlow {
                tf += 1;
            }
        }
        assert!((tf as f64 / n as f64 - 0.80).abs() < 0.02);
        let total: f64 = synth.cfg.framework_shares.iter().sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn every_pipeline_trains() {
        let mut synth = PipelineSynthesizer::new(SynthConfig::default(), Pcg64::new(6));
        for _ in 0..1000 {
            assert!(synth.generate().has_task(TaskType::Train));
        }
    }
}
