//! Pipeline & data synthesizers (paper section IV-B).

pub mod asset_synth;
pub mod pipeline_synth;

pub use asset_synth::AssetSynthesizer;
pub use pipeline_synth::{PipelineSynthesizer, SynthConfig, TaskList};
