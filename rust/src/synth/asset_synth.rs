//! Synthetic data assets (paper section IV-B2 / V-A1).
//!
//! Samples (ln rows, ln cols, ln bytes) from the fitted 50-component
//! Gaussian mixture, transforms back from log space, and rejects
//! out-of-bound values — exactly the paper's procedure. Each refill also
//! batch-computes the asset's preprocess duration through the
//! `preproc_duration` artifact, so the simulator's per-arrival cost is an
//! array lookup.

use crate::error::Result;
use crate::model::DataAsset;
use crate::runtime::pool::{Backend, PreprocDurationPool, SamplePool3};
use crate::stats::dist::LogNormal;
use crate::stats::gmm::Gmm3;
use crate::stats::rng::Pcg64;
use crate::stats::ExpCurve;

/// Plausibility bounds for the back-transformed samples (the paper's
/// "reject out-of-bound values", aligned with its >=50 rows / >=2 cols
/// filter).
const MIN_ROWS: f64 = 50.0;
const MAX_ROWS: f64 = 1e9;
const MIN_COLS: f64 = 2.0;
const MAX_COLS: f64 = 1e5;
const MIN_BYTES: f64 = 64.0;
const MAX_BYTES: f64 = 1e13;

/// Streams (asset, preprocess-duration) pairs. The mixture is taken as
/// an `Arc` so per-experiment construction shares, not copies, the fit.
pub struct AssetSynthesizer {
    pool: SamplePool3,
    durations: PreprocDurationPool,
    buf: Vec<(DataAsset, f64)>,
    pos: usize,
    /// Samples rejected by the plausibility bounds (diagnostics).
    pub rejected: u64,
    pub produced: u64,
}

impl AssetSynthesizer {
    pub fn new(
        backend: Backend,
        gmm: impl Into<std::sync::Arc<Gmm3>>,
        curve: ExpCurve,
        noise: LogNormal,
        rng: &mut Pcg64,
    ) -> Self {
        AssetSynthesizer {
            pool: SamplePool3::new(backend.clone(), gmm, rng.substream(0x01)),
            durations: PreprocDurationPool::new(backend, curve, noise, rng.substream(0x02)),
            buf: Vec::new(),
            pos: 0,
            rejected: 0,
            produced: 0,
        }
    }

    fn refill(&mut self) -> Result<()> {
        self.buf.clear();
        self.pos = 0;
        let target = 1024;
        let mut assets = Vec::with_capacity(target);
        let mut guard = 0;
        while assets.len() < target {
            let s = self.pool.next()?;
            guard += 1;
            if guard > target * 64 {
                // mixture collapsed to implausible region: accept clamped
                assets.push(clamp_asset(s));
                self.rejected += 1;
                continue;
            }
            let rows = s[0].exp();
            let cols = s[1].exp();
            let bytes = s[2].exp();
            if (MIN_ROWS..=MAX_ROWS).contains(&rows)
                && (MIN_COLS..=MAX_COLS).contains(&cols)
                && (MIN_BYTES..=MAX_BYTES).contains(&bytes)
            {
                assets.push(DataAsset::new(rows.round(), cols.round(), bytes));
            } else {
                self.rejected += 1;
            }
        }
        let logsizes: Vec<f64> = assets.iter().map(|a| a.log_size()).collect();
        let durs = self.durations.durations(&logsizes)?;
        self.buf.extend(assets.into_iter().zip(durs));
        Ok(())
    }

    /// Next synthetic asset with its preprocess compute duration.
    pub fn next(&mut self) -> Result<(DataAsset, f64)> {
        if self.pos >= self.buf.len() {
            self.refill()?;
        }
        let out = self.buf[self.pos];
        self.pos += 1;
        self.produced += 1;
        Ok(out)
    }
}

fn clamp_asset(s: [f64; 3]) -> DataAsset {
    DataAsset::new(
        s[0].exp().clamp(MIN_ROWS, MAX_ROWS).round(),
        s[1].exp().clamp(MIN_COLS, MAX_COLS).round(),
        s[2].exp().clamp(MIN_BYTES, MAX_BYTES),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_gmm() -> Gmm3 {
        // one component centered at plausible log values
        let c = [[0.5, 0.0, 0.0], [0.1, 0.4, 0.0], [0.2, 0.1, 0.5]];
        Gmm3 {
            logw: vec![0.0],
            mu: vec![[8.0, 3.0, 12.0]], // ~3000 rows, ~20 cols, ~160 KB
            pchol: vec![crate::stats::gmm::tril3_inv(&c)],
            cchol: vec![c],
        }
    }

    #[test]
    fn produces_plausible_assets() {
        let mut rng = Pcg64::new(1);
        let mut synth = AssetSynthesizer::new(
            Backend::Cpu,
            toy_gmm(),
            ExpCurve { a: 0.018, b: 1.330, c: 2.156 },
            LogNormal::new(-1.0, 0.15),
            &mut rng,
        );
        for _ in 0..3000 {
            let (a, t) = synth.next().unwrap();
            assert!(a.rows >= MIN_ROWS && a.cols >= MIN_COLS);
            assert!(a.is_plausible());
            assert!(t > 2.0, "duration above asymptote");
        }
        assert_eq!(synth.produced, 3000);
    }

    #[test]
    fn durations_grow_with_size() {
        let mut rng = Pcg64::new(2);
        let mut synth = AssetSynthesizer::new(
            Backend::Cpu,
            toy_gmm(),
            ExpCurve { a: 0.018, b: 1.330, c: 2.156 },
            LogNormal::new(-1.0, 0.15),
            &mut rng,
        );
        let mut pairs: Vec<(f64, f64)> = (0..4000)
            .map(|_| {
                let (a, t) = synth.next().unwrap();
                (a.log_size(), t)
            })
            .collect();
        pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let n = pairs.len();
        let lo: f64 = pairs[..n / 4].iter().map(|p| p.1).sum::<f64>() / (n / 4) as f64;
        let hi: f64 = pairs[3 * n / 4..].iter().map(|p| p.1).sum::<f64>() / (n - 3 * n / 4) as f64;
        assert!(hi > lo, "{hi} !> {lo}");
    }

    #[test]
    fn rejection_counted_for_wild_mixture() {
        // component centered far out of bounds -> heavy rejection
        let c = [[1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [0.0, 0.0, 1.0]];
        let g = Gmm3 {
            logw: vec![0.5f64.ln(), 0.5f64.ln()],
            mu: vec![[8.0, 3.0, 12.0], [0.0, 0.0, 0.0]], // 2nd: rows ~1 -> rejected
            pchol: vec![crate::stats::gmm::tril3_inv(&c); 2],
            cchol: vec![c; 2],
        };
        let mut rng = Pcg64::new(3);
        let mut synth = AssetSynthesizer::new(
            Backend::Cpu,
            g,
            ExpCurve { a: 0.018, b: 1.330, c: 2.156 },
            LogNormal::new(-1.0, 0.15),
            &mut rng,
        );
        for _ in 0..500 {
            synth.next().unwrap();
        }
        assert!(synth.rejected > 100, "rejected={}", synth.rejected);
    }
}
