//! Instrumentation primitives: time-weighted averages and counters.
//!
//! A `TimeWeighted` monitor tracks a piecewise-constant signal (queue
//! length, jobs in use) and integrates it over simulated time, which is
//! what resource utilization and average queue length are defined over.

use super::SimTime;

/// Integrates a piecewise-constant signal over simulated time.
#[derive(Clone, Debug)]
pub struct TimeWeighted {
    last_t: SimTime,
    value: f64,
    integral: f64,
    pub max: f64,
}

impl TimeWeighted {
    pub fn new(t0: SimTime, v0: f64) -> Self {
        TimeWeighted {
            last_t: t0,
            value: v0,
            integral: 0.0,
            max: v0,
        }
    }

    /// Advance to time `t` with the value unchanged, then set a new value.
    pub fn set(&mut self, t: SimTime, v: f64) {
        debug_assert!(t >= self.last_t);
        self.integral += self.value * (t - self.last_t);
        self.last_t = t;
        self.value = v;
        if v > self.max {
            self.max = v;
        }
    }

    /// Add `dv` to the current value at time `t`.
    pub fn add(&mut self, t: SimTime, dv: f64) {
        let v = self.value + dv;
        self.set(t, v);
    }

    /// Current value.
    pub fn value(&self) -> f64 {
        self.value
    }

    /// Integral of the signal from t0 to `t`.
    pub fn integral_at(&self, t: SimTime) -> f64 {
        debug_assert!(t >= self.last_t);
        self.integral + self.value * (t - self.last_t)
    }

    /// Time-weighted mean over [t0, t].
    pub fn mean_at(&self, t: SimTime, t0: SimTime) -> f64 {
        let span = t - t0;
        if span <= 0.0 {
            0.0
        } else {
            self.integral_at(t) / span
        }
    }
}

/// A plain monotonically increasing event counter.
#[derive(Clone, Debug, Default)]
pub struct Counter {
    pub count: u64,
}

impl Counter {
    pub fn inc(&mut self) {
        self.count += 1;
    }
    pub fn add(&mut self, n: u64) {
        self.count += n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn integrates_step_function() {
        let mut m = TimeWeighted::new(0.0, 0.0);
        m.set(10.0, 2.0); // 0 for [0,10)
        m.set(20.0, 5.0); // 2 for [10,20)
        // integral at 30: 0*10 + 2*10 + 5*10 = 70
        assert_eq!(m.integral_at(30.0), 70.0);
        assert!((m.mean_at(30.0, 0.0) - 70.0 / 30.0).abs() < 1e-12);
        assert_eq!(m.max, 5.0);
    }

    #[test]
    fn add_tracks_deltas() {
        let mut m = TimeWeighted::new(0.0, 1.0);
        m.add(5.0, 2.0);
        assert_eq!(m.value(), 3.0);
        m.add(10.0, -3.0);
        assert_eq!(m.value(), 0.0);
        // 1*5 + 3*5 = 20
        assert_eq!(m.integral_at(10.0), 20.0);
    }

    #[test]
    fn counter() {
        let mut c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.count, 5);
    }
}
