//! Retry policies: the strategy consulted after a task attempt faults
//! or times out (the fourth pluggable strategy family, alongside
//! schedulers, retrain triggers, and placers).
//!
//! A policy sees a compact [`RetryCtx`] snapshot — which attempt just
//! failed, how long the pipeline has been in flight, how much slack is
//! left before its EDF deadline, and how deep the cluster's wait queue
//! is — and answers [`RetryDecision::Retry`] with a backoff delay or
//! [`RetryDecision::Abandon`]. Policies must be deterministic: the
//! simulation's byte-exact digest oracle covers retry schedules, so a
//! policy that randomized its backoff would need its own substream.

use super::SimTime;

/// Snapshot handed to a [`RetryPolicy`] after an attempt fails.
#[derive(Clone, Copy, Debug)]
pub struct RetryCtx {
    /// 1-based index of the attempt that just failed (`1` = the first
    /// try failed).
    pub attempt: u32,
    /// Time since the pipeline arrived, seconds.
    pub elapsed: SimTime,
    /// Seconds until the pipeline's EDF deadline; negative once the
    /// deadline has already passed.
    pub deadline_slack: SimTime,
    /// Jobs currently waiting on the failed task's cluster.
    pub queue_depth: usize,
}

/// What to do with the failed task.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RetryDecision {
    /// Re-queue the task after `delay` seconds of backoff (`0.0` =
    /// immediately).
    Retry { delay: SimTime },
    /// Give up: the whole pipeline terminates with the abandoned
    /// outcome.
    Abandon,
}

/// Pluggable post-fault strategy. Implementations are registered in
/// `coordinator::strategy` and selected by name via `StrategySpec`.
pub trait RetryPolicy: Send {
    /// Decide the fate of a failed attempt.
    fn decide(&mut self, ctx: &RetryCtx) -> RetryDecision;

    /// Registry name, for labels and reports.
    fn name(&self) -> &'static str;
}

/// `always`: retry forever with a fixed delay (default 0). The
/// simplest policy — and the one that shows why timeouts and caps
/// matter, since a permanently-faulting task retries until the horizon.
#[derive(Clone, Copy, Debug)]
pub struct AlwaysRetry {
    pub delay: SimTime,
}

impl AlwaysRetry {
    pub fn new(delay: SimTime) -> Self {
        AlwaysRetry { delay }
    }
}

impl RetryPolicy for AlwaysRetry {
    fn decide(&mut self, _ctx: &RetryCtx) -> RetryDecision {
        RetryDecision::Retry { delay: self.delay }
    }
    fn name(&self) -> &'static str {
        "always"
    }
}

/// `fixed`: at most `max_attempts` total attempts, each retried after
/// a constant `delay`.
#[derive(Clone, Copy, Debug)]
pub struct FixedRetry {
    pub max_attempts: u32,
    pub delay: SimTime,
}

impl FixedRetry {
    pub fn new(max_attempts: u32, delay: SimTime) -> Self {
        FixedRetry {
            max_attempts: max_attempts.max(1),
            delay,
        }
    }
}

impl RetryPolicy for FixedRetry {
    fn decide(&mut self, ctx: &RetryCtx) -> RetryDecision {
        if ctx.attempt >= self.max_attempts {
            RetryDecision::Abandon
        } else {
            RetryDecision::Retry { delay: self.delay }
        }
    }
    fn name(&self) -> &'static str {
        "fixed"
    }
}

/// Deterministic exponential backoff: `base * 2^(attempt-1)`, capped.
fn backoff(base: SimTime, cap: SimTime, attempt: u32) -> SimTime {
    // attempt is 1-based; saturate the shift so huge attempt counts
    // don't overflow into garbage
    let exp = (attempt.saturating_sub(1)).min(62);
    (base * (1u64 << exp) as f64).min(cap)
}

/// `exp_backoff`: exponential backoff (`base`, doubling per attempt,
/// capped at `cap`) with a hard attempt budget.
#[derive(Clone, Copy, Debug)]
pub struct ExpBackoffRetry {
    pub base: SimTime,
    pub cap: SimTime,
    pub max_attempts: u32,
}

impl ExpBackoffRetry {
    pub fn new(base: SimTime, cap: SimTime, max_attempts: u32) -> Self {
        ExpBackoffRetry {
            base: base.max(0.0),
            cap: cap.max(0.0),
            max_attempts: max_attempts.max(1),
        }
    }
}

impl RetryPolicy for ExpBackoffRetry {
    fn decide(&mut self, ctx: &RetryCtx) -> RetryDecision {
        if ctx.attempt >= self.max_attempts {
            RetryDecision::Abandon
        } else {
            RetryDecision::Retry {
                delay: backoff(self.base, self.cap, ctx.attempt),
            }
        }
    }
    fn name(&self) -> &'static str {
        "exp_backoff"
    }
}

/// `deadline_aware`: exponential backoff that gives up as soon as
/// another attempt cannot plausibly finish before the pipeline's EDF
/// deadline. The next attempt's span is estimated from history as
/// `elapsed / attempt` (mean time per attempt so far, queueing
/// included); if `backoff + estimate` exceeds the remaining slack the
/// pipeline is abandoned immediately rather than burning cluster time
/// on a result that will arrive too late.
#[derive(Clone, Copy, Debug)]
pub struct DeadlineAwareRetry {
    pub base: SimTime,
    pub cap: SimTime,
}

impl DeadlineAwareRetry {
    pub fn new(base: SimTime, cap: SimTime) -> Self {
        DeadlineAwareRetry {
            base: base.max(0.0),
            cap: cap.max(0.0),
        }
    }
}

impl RetryPolicy for DeadlineAwareRetry {
    fn decide(&mut self, ctx: &RetryCtx) -> RetryDecision {
        let delay = backoff(self.base, self.cap, ctx.attempt);
        let per_attempt = ctx.elapsed / ctx.attempt.max(1) as f64;
        if delay + per_attempt > ctx.deadline_slack {
            RetryDecision::Abandon
        } else {
            RetryDecision::Retry { delay }
        }
    }
    fn name(&self) -> &'static str {
        "deadline_aware"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(attempt: u32, elapsed: f64, slack: f64) -> RetryCtx {
        RetryCtx {
            attempt,
            elapsed,
            deadline_slack: slack,
            queue_depth: 0,
        }
    }

    #[test]
    fn always_retries_forever() {
        let mut p = AlwaysRetry::new(5.0);
        for attempt in [1, 10, 1000] {
            assert_eq!(
                p.decide(&ctx(attempt, 1e6, -1e6)),
                RetryDecision::Retry { delay: 5.0 }
            );
        }
    }

    #[test]
    fn fixed_caps_attempts() {
        let mut p = FixedRetry::new(3, 2.0);
        assert_eq!(p.decide(&ctx(1, 0.0, 0.0)), RetryDecision::Retry { delay: 2.0 });
        assert_eq!(p.decide(&ctx(2, 0.0, 0.0)), RetryDecision::Retry { delay: 2.0 });
        assert_eq!(p.decide(&ctx(3, 0.0, 0.0)), RetryDecision::Abandon);
        // degenerate budget still allows the first attempt to fail hard
        let mut p = FixedRetry::new(0, 2.0);
        assert_eq!(p.decide(&ctx(1, 0.0, 0.0)), RetryDecision::Abandon);
    }

    #[test]
    fn exp_backoff_doubles_and_caps() {
        let mut p = ExpBackoffRetry::new(10.0, 35.0, 10);
        assert_eq!(p.decide(&ctx(1, 0.0, 0.0)), RetryDecision::Retry { delay: 10.0 });
        assert_eq!(p.decide(&ctx(2, 0.0, 0.0)), RetryDecision::Retry { delay: 20.0 });
        assert_eq!(p.decide(&ctx(3, 0.0, 0.0)), RetryDecision::Retry { delay: 35.0 });
        assert_eq!(p.decide(&ctx(9, 0.0, 0.0)), RetryDecision::Retry { delay: 35.0 });
        assert_eq!(p.decide(&ctx(10, 0.0, 0.0)), RetryDecision::Abandon);
        // saturating shift: absurd attempt numbers stay finite
        assert!(backoff(1.0, f64::MAX, u32::MAX).is_finite());
    }

    #[test]
    fn deadline_aware_gives_up_when_slack_runs_out() {
        let mut p = DeadlineAwareRetry::new(10.0, 3600.0);
        // attempt 1 took 100s; slack 500s → 10 + 100 fits
        assert_eq!(
            p.decide(&ctx(1, 100.0, 500.0)),
            RetryDecision::Retry { delay: 10.0 }
        );
        // slack 50s → 10 + 100 does not fit
        assert_eq!(p.decide(&ctx(1, 100.0, 50.0)), RetryDecision::Abandon);
        // past the deadline entirely
        assert_eq!(p.decide(&ctx(2, 100.0, -1.0)), RetryDecision::Abandon);
    }
}
