//! Pluggable scheduling strategies for [`Resource`](super::Resource).
//!
//! The paper's framework exists to "devise and evaluate operational
//! strategies" (sections IV, V-B, Fig 4) — which job a saturated cluster
//! admits or grants next is exactly such a strategy. This module makes it
//! a first-class extension point: [`Resource`](super::Resource) delegates
//! every admission and waiter-ordering decision to a boxed [`Scheduler`],
//! and the classic disciplines (FIFO, priority, shortest-job-first) are
//! just the built-in implementations.
//!
//! ## Contract
//!
//! Decisions must be **deterministic**: a scheduler may keep internal
//! state, but its output must be a pure function of that state and the
//! [`SchedCtx`] it is handed — no wall clock, no unseeded randomness.
//! Every experiment outcome digest depends on it (see
//! `ExperimentResult::digest`).
//!
//! Waiter ordering is decided **at enqueue time**: [`Scheduler::queue_key`]
//! is called once when a job queues, and the resource grants waiters in
//! ascending `(key, enqueue sequence)` order. Re-ordering jobs after they
//! queued (preemption, backfill) needs calendar event cancellation, which
//! the DES core does not support yet (see ROADMAP).

use super::SimTime;

/// Per-job facts a scheduler may weigh.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct JobCtx {
    /// Expected slot occupancy of the task: read + exec + write, seconds.
    pub expected_occupancy: f64,
    /// Priority class (lower = more important; 0 is reserved for
    /// platform-initiated work such as retraining pipelines).
    pub priority: f64,
    /// When the owning pipeline arrived in the system.
    pub arrived_at: SimTime,
}

impl JobCtx {
    pub fn new(expected_occupancy: f64, priority: f64, arrived_at: SimTime) -> Self {
        JobCtx {
            expected_occupancy,
            priority,
            arrived_at,
        }
    }
}

/// Snapshot handed to every scheduling decision: the requesting job plus
/// the resource's current state (full queue visibility).
#[derive(Clone, Copy, Debug)]
pub struct SchedCtx {
    /// Current simulation time.
    pub now: SimTime,
    /// The job the decision is about.
    pub job: JobCtx,
    /// Slots currently busy.
    pub in_use: usize,
    /// Total slot capacity.
    pub capacity: usize,
    /// Waiters currently queued.
    pub queued: usize,
}

/// An operational scheduling strategy for one resource.
///
/// Implementations may be stateful (`&mut self`); each
/// [`Resource`](super::Resource) owns its scheduler exclusively, so state
/// is per-resource and per-run. Strategies are registered by name in
/// `coordinator::strategy` and selectable from JSON config, the sweep
/// grid, and the CLI without recompiling.
pub trait Scheduler: Send {
    /// Registry/display name of the strategy.
    fn name(&self) -> &'static str;

    /// May this job start immediately? Called only when a slot is free.
    /// Returning `false` queues the job even though capacity is
    /// available (e.g. to reserve headroom for a higher class).
    ///
    /// Safety valve: a fully idle resource (`in_use == 0`) always admits
    /// — the resource enforces this and skips the call, because nothing
    /// would ever be released to grant the queued job (deadlock).
    fn admit(&mut self, _ctx: &SchedCtx) -> bool {
        true
    }

    /// Ordering key for a job that must queue: waiters are granted in
    /// ascending `(key, enqueue sequence)` order, so ties fall back to
    /// FIFO. Must not return NaN.
    fn queue_key(&mut self, ctx: &SchedCtx) -> f64;
}

/// First-in first-out (SimPy's default; the paper's baseline).
#[derive(Clone, Copy, Debug, Default)]
pub struct Fifo;

impl Scheduler for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }
    fn queue_key(&mut self, _ctx: &SchedCtx) -> f64 {
        0.0
    }
}

/// Lowest priority value first (Fig 4's "model prioritization");
/// ties FIFO.
#[derive(Clone, Copy, Debug, Default)]
pub struct Priority;

impl Scheduler for Priority {
    fn name(&self) -> &'static str {
        "priority"
    }
    fn queue_key(&mut self, ctx: &SchedCtx) -> f64 {
        ctx.job.priority
    }
}

/// Shortest expected occupancy first; ties FIFO.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShortestJobFirst;

impl Scheduler for ShortestJobFirst {
    fn name(&self) -> &'static str {
        "sjf"
    }
    fn queue_key(&mut self, ctx: &SchedCtx) -> f64 {
        ctx.job.expected_occupancy
    }
}

/// Earliest-deadline-first: each pipeline carries an implicit deadline
/// `arrival + slack_per_class × priority class`, and waiters are granted
/// in deadline order. Tighter classes (lower priority value) get earlier
/// deadlines; retraining pipelines (class 0) are due immediately.
///
/// Needs the richer [`SchedCtx`]: it trades off *arrival time* against
/// *priority*, which neither the FIFO nor the pure priority discipline
/// can express.
#[derive(Clone, Copy, Debug)]
pub struct EarliestDeadlineFirst {
    /// Deadline slack granted per priority class, seconds.
    pub slack_per_class: f64,
}

impl Default for EarliestDeadlineFirst {
    fn default() -> Self {
        EarliestDeadlineFirst {
            slack_per_class: 1800.0,
        }
    }
}

impl Scheduler for EarliestDeadlineFirst {
    fn name(&self) -> &'static str {
        "edf"
    }
    fn queue_key(&mut self, ctx: &SchedCtx) -> f64 {
        ctx.job.arrived_at + self.slack_per_class * ctx.job.priority
    }
}

/// Weighted-fair queueing across priority classes (start-time fair
/// queueing approximation): each class accumulates virtual service time
/// proportional to `class^weight_power × occupancy`, so class 1 receives
/// roughly `c×` the throughput share of class `c` under saturation while
/// no class starves.
///
/// Stateful: per-class virtual finish times, anchored to the current
/// simulation time so long-idle classes cannot bank unbounded credit.
/// Needs the richer [`SchedCtx`]: it combines *expected occupancy*,
/// *priority class*, and the clock.
#[derive(Clone, Debug)]
pub struct WeightedFair {
    /// Exponent on the class value when converting it to a virtual-time
    /// cost (1.0 = share inversely proportional to the class value).
    pub weight_power: f64,
    /// Virtual finish time per priority class.
    vft: Vec<f64>,
}

impl WeightedFair {
    pub fn new(weight_power: f64) -> Self {
        WeightedFair {
            weight_power,
            vft: Vec::new(),
        }
    }
}

impl Default for WeightedFair {
    fn default() -> Self {
        WeightedFair::new(1.0)
    }
}

impl Scheduler for WeightedFair {
    fn name(&self) -> &'static str {
        "weighted_fair"
    }
    fn queue_key(&mut self, ctx: &SchedCtx) -> f64 {
        let class = ctx.job.priority.clamp(0.0, 63.0) as usize;
        if self.vft.len() <= class {
            self.vft.resize(class + 1, 0.0);
        }
        // cost per second of occupancy: class value (min 0.5 so class 0
        // still advances) raised to the configured power
        let cost = ctx.job.priority.max(0.5).powf(self.weight_power);
        let start = self.vft[class].max(ctx.now);
        self.vft[class] = start + ctx.job.expected_occupancy * cost;
        self.vft[class]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(occ: f64, pri: f64, arrived: f64, now: f64) -> SchedCtx {
        SchedCtx {
            now,
            job: JobCtx::new(occ, pri, arrived),
            in_use: 1,
            capacity: 1,
            queued: 0,
        }
    }

    #[test]
    fn builtin_keys_reproduce_legacy_discipline_rule() {
        // the pre-trait simulator computed: fifo -> 0, priority -> the
        // pipeline priority, sjf -> expected occupancy. The trait impls
        // must be bit-identical for digests to match across the refactor.
        let c = ctx(42.5, 3.0, 10.0, 11.0);
        assert_eq!(Fifo.queue_key(&c), 0.0);
        assert_eq!(Priority.queue_key(&c), 3.0);
        assert_eq!(ShortestJobFirst.queue_key(&c), 42.5);
    }

    #[test]
    fn default_admission_is_work_conserving() {
        let c = ctx(1.0, 5.0, 0.0, 0.0);
        assert!(Fifo.admit(&c));
        assert!(Priority.admit(&c));
        assert!(WeightedFair::default().admit(&c));
    }

    #[test]
    fn edf_orders_by_arrival_plus_class_slack() {
        let mut edf = EarliestDeadlineFirst {
            slack_per_class: 100.0,
        };
        // late but urgent beats early but lax
        let urgent = edf.queue_key(&ctx(1.0, 1.0, 500.0, 600.0)); // due 600
        let lax = edf.queue_key(&ctx(1.0, 9.0, 0.0, 600.0)); // due 900
        assert!(urgent < lax);
        // retrains (class 0) are due at arrival
        assert_eq!(edf.queue_key(&ctx(1.0, 0.0, 123.0, 600.0)), 123.0);
    }

    #[test]
    fn weighted_fair_charges_heavier_classes_more() {
        let mut wf = WeightedFair::default();
        let k1a = wf.queue_key(&ctx(10.0, 1.0, 0.0, 0.0));
        let k1b = wf.queue_key(&ctx(10.0, 1.0, 0.0, 0.0));
        let k9 = wf.queue_key(&ctx(10.0, 9.0, 0.0, 0.0));
        // class 1 advances 10s of virtual time per job, class 9 90s
        assert_eq!(k1a, 10.0);
        assert_eq!(k1b, 20.0);
        assert_eq!(k9, 90.0);
        // so two more class-9 jobs would overtake nothing: keys monotone
        assert!(k1b < k9);
    }

    #[test]
    fn weighted_fair_anchors_idle_classes_to_now() {
        let mut wf = WeightedFair::default();
        let early = wf.queue_key(&ctx(5.0, 2.0, 0.0, 0.0)); // vft[2] = 10
        assert_eq!(early, 10.0);
        // much later, the class's stale credit must not let it jump the
        // queue arbitrarily: start is max(vft, now)
        let late = wf.queue_key(&ctx(5.0, 2.0, 0.0, 1000.0));
        assert_eq!(late, 1010.0);
    }

    #[test]
    fn weighted_fair_is_deterministic_per_state() {
        let mut a = WeightedFair::new(2.0);
        let mut b = WeightedFair::new(2.0);
        for i in 0..100 {
            let c = ctx(1.0 + i as f64, (i % 7) as f64, i as f64, i as f64);
            assert_eq!(a.queue_key(&c), b.queue_key(&c));
        }
    }
}
