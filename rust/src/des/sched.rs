//! Pluggable scheduling strategies for [`Resource`](super::Resource).
//!
//! The paper's framework exists to "devise and evaluate operational
//! strategies" (sections IV, V-B, Fig 4) — which job a saturated cluster
//! admits or grants next is exactly such a strategy. This module makes it
//! a first-class extension point: [`Resource`](super::Resource) delegates
//! every admission, waiter-ordering, grant, and preemption decision to a
//! boxed [`Scheduler`], and the classic disciplines (FIFO, priority,
//! shortest-job-first) are just the built-in implementations.
//!
//! ## Two tiers of strategy
//!
//! *Key-based* strategies decide ordering **at enqueue time**:
//! [`Scheduler::queue_key`] is called once when a job queues, and the
//! resource grants waiters in ascending `(key, enqueue sequence)` order.
//! Fifo/Priority/SJF/EDF/WeightedFair live here; they never pay for the
//! machinery below.
//!
//! *Re-decision* strategies (opting in via [`Scheduler::needs_view`])
//! additionally get the two re-decision hooks, each with full visibility
//! of the wait queue and the running set through [`SchedView`]:
//!
//! * [`Scheduler::on_enqueue`] fires when a job cannot start on request;
//!   it may queue the job (default), admit it anyway (backfill into
//!   reserved/idle capacity), or **preempt** a running job — the victim
//!   is re-queued with its remaining service and its scheduled
//!   completion event is cancelled by the simulation (see
//!   [`Calendar::cancel`](super::calendar::Calendar::cancel)).
//! * [`Scheduler::on_release`] fires when slots free up; it picks which
//!   waiters start, in what order — the seam for backfill policies that
//!   overtake a blocked head-of-queue without delaying it.
//!
//! [`PreemptivePriority`] (higher class evicts the lowest-class running
//! task) and [`EasyBackfill`] (FCFS with head-of-queue reservation and
//! EASY-style backfill) are the built-in re-decision strategies.
//!
//! ## The `QueueKey` ordering contract
//!
//! The grant rule for key-based strategies is a single total order:
//! ascending [`QueueKey`] — the scheduler-assigned `key` compared by
//! `f64::total_cmp` (so every float, NaN included, has a defined rank),
//! tie-broken by the enqueue sequence number, which is unique per
//! resource and makes the order *strict*. [`QueueKey`]'s `Ord` impl IS
//! the digest-critical rule: [`earlier_waiter`], [`default_grants`],
//! and the resource's indexed waiter heap (the O(log n) fast path for
//! `!needs_view()` strategies) all compare through it, so the
//! linear-scan reference and the heap can never disagree on which
//! waiter is granted next. Keys are assigned once, at enqueue time, and
//! never change while a job waits — that immutability is what lets the
//! heap cache them.
//!
//! ## Contract
//!
//! Decisions must be **deterministic**: a scheduler may keep internal
//! state, but its output must be a pure function of that state and the
//! [`SchedCtx`] / [`SchedView`] it is handed — no wall clock, no
//! unseeded randomness, no iteration over anything with nondeterministic
//! order (the view slices are deterministically ordered; `HashMap`
//! iteration is not). Every experiment outcome digest depends on it (see
//! `ExperimentResult::digest`), and the re-decision hooks are inside the
//! determinism boundary: `on_enqueue`/`on_release` run at
//! deterministically-ordered calendar events and see deterministic
//! views, so the same `(config, seed)` replays the same decisions.

use super::SimTime;

/// Per-job facts a scheduler may weigh.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct JobCtx {
    /// Expected slot occupancy of the task: read + exec + write, seconds.
    pub expected_occupancy: f64,
    /// Priority class (lower = more important; 0 is reserved for
    /// platform-initiated work such as retraining pipelines).
    pub priority: f64,
    /// When the owning pipeline arrived in the system.
    pub arrived_at: SimTime,
    /// Slots the job occupies while running (1 for every task unless the
    /// experiment widens training jobs via `InfraConfig::train_slots`).
    pub slots: u32,
    /// True when the job is re-queued after a slot failure interrupted a
    /// prior attempt (it has already lost work once). Failure-aware
    /// strategies such as [`RestartFirst`] weigh this; every built-in
    /// discipline ignores it.
    pub restarted: bool,
}

impl JobCtx {
    pub fn new(expected_occupancy: f64, priority: f64, arrived_at: SimTime) -> Self {
        JobCtx {
            expected_occupancy,
            priority,
            arrived_at,
            slots: 1,
            restarted: false,
        }
    }

    /// Builder: a job occupying `slots` slots while running.
    pub fn with_slots(mut self, slots: u32) -> Self {
        debug_assert!(slots >= 1, "jobs occupy at least one slot");
        self.slots = slots;
        self
    }

    /// Builder: mark the job as a failure-restart victim.
    pub fn after_restart(mut self) -> Self {
        self.restarted = true;
        self
    }
}

/// Snapshot handed to every scheduling decision: the requesting job plus
/// the resource's current aggregate state.
#[derive(Clone, Copy, Debug)]
pub struct SchedCtx {
    /// Current simulation time.
    pub now: SimTime,
    /// The job the decision is about.
    pub job: JobCtx,
    /// Slots currently busy.
    pub in_use: usize,
    /// Total slot capacity.
    pub capacity: usize,
    /// Waiters currently queued.
    pub queued: usize,
}

/// A waiter's rank under the canonical grant order: the
/// scheduler-assigned `key` (primary, compared by `f64::total_cmp`) with
/// the enqueue sequence number as the FIFO tie-break. `seq` is unique
/// per resource, so the order is total *and strict* — no two waiters
/// ever compare equal, which is what makes grant order deterministic
/// and lets the resource's indexed heap reproduce the linear-scan rule
/// byte-for-byte.
#[derive(Clone, Copy, Debug)]
pub struct QueueKey {
    /// Ordering key assigned by [`Scheduler::queue_key`] at enqueue.
    pub key: f64,
    /// Enqueue sequence number (ascending = FCFS order).
    pub seq: u64,
}

impl Ord for QueueKey {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key
            .total_cmp(&other.key)
            .then(self.seq.cmp(&other.seq))
    }
}

impl PartialOrd for QueueKey {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl PartialEq for QueueKey {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for QueueKey {}

/// One queued job as seen by the re-decision hooks.
#[derive(Clone, Copy, Debug)]
pub struct WaiterView {
    pub job: JobCtx,
    /// The ordering key `queue_key` assigned at enqueue.
    pub key: f64,
    /// When the job entered the queue (re-set on re-queue after
    /// preemption).
    pub enq_t: SimTime,
    /// Enqueue sequence number: ascending `seq` is FCFS order. Unique
    /// within a resource.
    pub seq: u64,
}

impl WaiterView {
    /// This waiter's rank under the canonical grant order.
    #[inline]
    pub fn queue_key(&self) -> QueueKey {
        QueueKey {
            key: self.key,
            seq: self.seq,
        }
    }
}

/// One running job as seen by the re-decision hooks. Only maintained for
/// schedulers that opt in via [`Scheduler::needs_view`].
#[derive(Clone, Copy, Debug)]
pub struct RunningView {
    pub job: JobCtx,
    /// When the job was granted its slots.
    pub started_at: SimTime,
    /// Projected completion: `started_at + expected_occupancy` (for a
    /// resumed preempted job, the occupancy is its remaining service).
    pub expected_done: SimTime,
    /// Grant sequence number identifying this running job (the victim id
    /// in [`EnqueueAction::Preempt`]). Unique within a resource.
    pub seq: u64,
}

/// Full queue + running-set visibility for the re-decision hooks.
///
/// `waiters` is in arbitrary storage order — use [`WaiterView::seq`] for
/// FCFS order and [`WaiterView::key`] for the key discipline; both
/// orders are deterministic. `running` is empty unless the scheduler
/// opted in via [`Scheduler::needs_view`].
#[derive(Clone, Copy, Debug)]
pub struct SchedView<'a> {
    /// Current simulation time.
    pub now: SimTime,
    /// Slots currently free (`capacity - in_use`).
    pub free: usize,
    /// Total slot capacity.
    pub capacity: usize,
    pub waiters: &'a [WaiterView],
    pub running: &'a [RunningView],
}

/// What to do with a job that could not start on request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EnqueueAction {
    /// Enqueue the job (the default; ordered by `queue_key`).
    Queue,
    /// Start it immediately anyway — it must fit the free slots. The
    /// backfill path for jobs an admission policy would otherwise hold
    /// back.
    Admit,
    /// Evict the running job identified by [`RunningView::seq`], hand its
    /// slots to the requester, and re-queue the victim with its
    /// remaining service. The victim's slots plus the free pool must
    /// cover the requester.
    Preempt { victim_seq: u64 },
}

/// An operational scheduling strategy for one resource.
///
/// Implementations may be stateful (`&mut self`); each
/// [`Resource`](super::Resource) owns its scheduler exclusively, so state
/// is per-resource and per-run. Strategies are registered by name in
/// `coordinator::strategy` and selectable from JSON config, the sweep
/// grid, and the CLI without recompiling.
pub trait Scheduler: Send {
    /// Registry/display name of the strategy.
    fn name(&self) -> &'static str;

    /// May this job start immediately? Called only when its slots fit
    /// the free capacity. Returning `false` routes the job through
    /// [`Scheduler::on_enqueue`] even though capacity is available
    /// (e.g. to reserve headroom, or to forbid overtaking a non-empty
    /// queue).
    ///
    /// Safety valve: a fully idle resource (`in_use == 0`) always admits
    /// — the resource enforces this and skips the call, because nothing
    /// would ever be released to grant the queued job (deadlock).
    fn admit(&mut self, _ctx: &SchedCtx) -> bool {
        true
    }

    /// Ordering key for a job that must queue: the default grant path
    /// picks waiters in ascending `(key, enqueue sequence)` order, so
    /// ties fall back to FIFO. Must not return NaN. Also called to place
    /// a preempted victim back in the queue (with its remaining service
    /// as the expected occupancy).
    fn queue_key(&mut self, ctx: &SchedCtx) -> f64;

    /// Opt into the re-decision hooks. When `true`, the resource tracks
    /// its running set, builds a [`SchedView`] for every re-decision,
    /// and routes grants through [`Scheduler::on_release`] and blocked
    /// requests through [`Scheduler::on_enqueue`]. When `false` (the
    /// default), neither hook is ever called and the resource keeps the
    /// exact pre-hook fast path — key-based strategies pay nothing.
    fn needs_view(&self) -> bool {
        false
    }

    /// Re-decision for a job that could not start on request (capacity
    /// short, or [`Scheduler::admit`] refused). Only called when
    /// [`Scheduler::needs_view`] is `true`. The view does *not* yet
    /// contain the requesting job.
    fn on_enqueue(&mut self, _ctx: &SchedCtx, _view: &SchedView) -> EnqueueAction {
        EnqueueAction::Queue
    }

    /// Pick the waiters to grant after slots freed up. Push indices into
    /// `view.waiters` onto `grants`, in grant order; each granted job
    /// must fit the slots still free at its turn, and indices must be
    /// unique. Only called when [`Scheduler::needs_view`] is `true`; the
    /// default reproduces the built-in `(key, seq)` selection via
    /// [`default_grants`].
    fn on_release(&mut self, view: &SchedView, grants: &mut Vec<usize>) {
        default_grants(view, grants);
    }
}

/// The one canonical waiter ordering: ascending [`QueueKey`]. Every
/// built-in grant decision — [`default_grants`], the resource's indexed
/// waiter heap, and the unit-width `release` fast path — goes through
/// this comparison, so the digest-critical tie-break rule exists
/// exactly once (it is [`QueueKey`]'s `Ord`).
#[inline]
pub fn earlier_waiter(a: &WaiterView, b: &WaiterView) -> bool {
    a.queue_key() < b.queue_key()
}

/// The built-in grant rule: repeatedly grant the [`QueueKey`]-minimal
/// waiter while it fits the free slots, stopping at the first best
/// waiter that does not fit (head-of-line blocking — overtaking a
/// blocked head is a policy decision, not a default).
///
/// This is the **linear-scan reference** for the grant order: O(n) per
/// grant, but definitionally correct. Re-decision schedulers that do
/// not override [`Scheduler::on_release`] run it directly; key-based
/// schedulers take the resource's indexed-heap fast path, whose output
/// is property-tested byte-identical to this scan.
pub fn default_grants(view: &SchedView, grants: &mut Vec<usize>) {
    let mut free = view.free;
    loop {
        let mut best: Option<usize> = None;
        for (i, w) in view.waiters.iter().enumerate() {
            if grants.contains(&i) {
                continue;
            }
            if best.is_none_or(|b| earlier_waiter(w, &view.waiters[b])) {
                best = Some(i);
            }
        }
        match best {
            Some(i) if view.waiters[i].job.slots as usize <= free => {
                free -= view.waiters[i].job.slots as usize;
                grants.push(i);
            }
            _ => break,
        }
    }
}

/// First-in first-out (SimPy's default; the paper's baseline).
///
/// Strict FCFS: a job may not overtake a non-empty queue even when slots
/// are free (only reachable with multi-slot jobs — with unit-slot jobs a
/// non-empty queue implies a full resource, so the admission rule is
/// vacuous and grant order is unchanged).
#[derive(Clone, Copy, Debug, Default)]
pub struct Fifo;

impl Scheduler for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }
    fn admit(&mut self, ctx: &SchedCtx) -> bool {
        ctx.queued == 0
    }
    fn queue_key(&mut self, _ctx: &SchedCtx) -> f64 {
        0.0
    }
}

/// Lowest priority value first (Fig 4's "model prioritization");
/// ties FIFO.
#[derive(Clone, Copy, Debug, Default)]
pub struct Priority;

impl Scheduler for Priority {
    fn name(&self) -> &'static str {
        "priority"
    }
    fn queue_key(&mut self, ctx: &SchedCtx) -> f64 {
        ctx.job.priority
    }
}

/// Shortest expected occupancy first; ties FIFO.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShortestJobFirst;

impl Scheduler for ShortestJobFirst {
    fn name(&self) -> &'static str {
        "sjf"
    }
    fn queue_key(&mut self, ctx: &SchedCtx) -> f64 {
        ctx.job.expected_occupancy
    }
}

/// Earliest-deadline-first: each pipeline carries an implicit deadline
/// `arrival + slack_per_class × priority class`, and waiters are granted
/// in deadline order. Tighter classes (lower priority value) get earlier
/// deadlines; retraining pipelines (class 0) are due immediately.
///
/// Needs the richer [`SchedCtx`]: it trades off *arrival time* against
/// *priority*, which neither the FIFO nor the pure priority discipline
/// can express.
#[derive(Clone, Copy, Debug)]
pub struct EarliestDeadlineFirst {
    /// Deadline slack granted per priority class, seconds.
    pub slack_per_class: f64,
}

impl Default for EarliestDeadlineFirst {
    fn default() -> Self {
        EarliestDeadlineFirst {
            slack_per_class: 1800.0,
        }
    }
}

impl Scheduler for EarliestDeadlineFirst {
    fn name(&self) -> &'static str {
        "edf"
    }
    fn queue_key(&mut self, ctx: &SchedCtx) -> f64 {
        ctx.job.arrived_at + self.slack_per_class * ctx.job.priority
    }
}

/// Weighted-fair queueing across priority classes (start-time fair
/// queueing approximation): each class accumulates virtual service time
/// proportional to `class^weight_power × occupancy`, so class 1 receives
/// roughly `c×` the throughput share of class `c` under saturation while
/// no class starves.
///
/// Stateful: per-class virtual finish times, anchored to the current
/// simulation time so long-idle classes cannot bank unbounded credit.
/// Needs the richer [`SchedCtx`]: it combines *expected occupancy*,
/// *priority class*, and the clock.
#[derive(Clone, Debug)]
pub struct WeightedFair {
    /// Exponent on the class value when converting it to a virtual-time
    /// cost (1.0 = share inversely proportional to the class value).
    pub weight_power: f64,
    /// Virtual finish time per priority class.
    vft: Vec<f64>,
}

impl WeightedFair {
    pub fn new(weight_power: f64) -> Self {
        WeightedFair {
            weight_power,
            vft: Vec::new(),
        }
    }
}

impl Default for WeightedFair {
    fn default() -> Self {
        WeightedFair::new(1.0)
    }
}

impl Scheduler for WeightedFair {
    fn name(&self) -> &'static str {
        "weighted_fair"
    }
    fn queue_key(&mut self, ctx: &SchedCtx) -> f64 {
        let class = ctx.job.priority.clamp(0.0, 63.0) as usize;
        if self.vft.len() <= class {
            self.vft.resize(class + 1, 0.0);
        }
        // cost per second of occupancy: class value (min 0.5 so class 0
        // still advances) raised to the configured power
        let cost = ctx.job.priority.max(0.5).powf(self.weight_power);
        let start = self.vft[class].max(ctx.now);
        self.vft[class] = start + ctx.job.expected_occupancy * cost;
        self.vft[class]
    }
}

/// Failure-aware priority discipline: jobs restarting after a slot
/// failure jump ahead of same-class fresh work. Rationale: a restarted
/// job has already burned cluster time once (its lost tail plus the
/// restart cost is sunk), so finishing it first minimizes the work at
/// risk from the *next* failure — the longer an interrupted job lingers
/// in the queue, the more attempts it is exposed to. Ordering is the
/// plain priority key minus a fixed class boost for restart victims, so
/// with failures off (no job ever restarted) it is byte-identical to
/// `priority` — the digest oracle the tests lean on.
#[derive(Clone, Copy, Debug)]
pub struct RestartFirst {
    /// Priority-class advantage a restart victim receives. The default
    /// (1e6) outranks every realistic class spread, making restarts an
    /// absolute front-of-queue band; small values (e.g. 1.0) just nudge
    /// victims one class up.
    pub restart_boost: f64,
}

impl Default for RestartFirst {
    fn default() -> Self {
        RestartFirst {
            restart_boost: 1e6,
        }
    }
}

impl Scheduler for RestartFirst {
    fn name(&self) -> &'static str {
        "restart_first"
    }
    fn queue_key(&mut self, ctx: &SchedCtx) -> f64 {
        if ctx.job.restarted {
            ctx.job.priority - self.restart_boost
        } else {
            ctx.job.priority
        }
    }
}

/// Preemptive priority: a saturated cluster evicts its lowest-class
/// running task when a sufficiently more important job arrives. The
/// victim's completion event is cancelled and it re-queues with its
/// remaining service (resuming where it stopped, not restarting), placed
/// by its priority class like any other waiter. Queue order is the
/// plain priority discipline, so with preemption impossible (e.g.
/// `min_class_gap` larger than any class spread) it degenerates to
/// `priority` exactly — a digest-level oracle the tests lean on.
///
/// Victim choice is deterministic: the running job with the *highest*
/// priority value, ties broken toward the most recently started (oldest
/// work is preserved). Preemption requires
/// `victim.class - newcomer.class >= min_class_gap`, so same-class work
/// never thrashes.
#[derive(Clone, Copy, Debug)]
pub struct PreemptivePriority {
    /// Minimum class advantage (victim class − newcomer class) required
    /// to evict. 1 = any strictly more important job preempts.
    pub min_class_gap: f64,
}

impl Default for PreemptivePriority {
    fn default() -> Self {
        PreemptivePriority { min_class_gap: 1.0 }
    }
}

impl Scheduler for PreemptivePriority {
    fn name(&self) -> &'static str {
        "preemptive_priority"
    }
    fn queue_key(&mut self, ctx: &SchedCtx) -> f64 {
        ctx.job.priority
    }
    fn needs_view(&self) -> bool {
        true
    }
    fn on_enqueue(&mut self, ctx: &SchedCtx, view: &SchedView) -> EnqueueAction {
        let mut victim: Option<&RunningView> = None;
        for r in view.running {
            let worse = match victim {
                None => true,
                Some(v) => match r.job.priority.total_cmp(&v.job.priority) {
                    std::cmp::Ordering::Greater => true,
                    std::cmp::Ordering::Less => false,
                    std::cmp::Ordering::Equal => r.seq > v.seq,
                },
            };
            if worse {
                victim = Some(r);
            }
        }
        match victim {
            Some(v)
                if v.job.priority - ctx.job.priority >= self.min_class_gap
                    && view.free + v.job.slots as usize >= ctx.job.slots as usize =>
            {
                EnqueueAction::Preempt { victim_seq: v.seq }
            }
            _ => EnqueueAction::Queue,
        }
    }
}

/// EASY backfill: strict FCFS with a reservation for the head of the
/// queue. When the head cannot start (not enough free slots — only
/// possible with multi-slot jobs, see `InfraConfig::train_slots`), its
/// reservation time is projected from the running jobs' expected
/// completions, and later waiters may overtake it only if they fit the
/// free slots *and* finish within the reservation window — so with
/// faithful occupancy estimates the head's grant time is never delayed
/// relative to plain FIFO (the invariant the tests enforce).
///
/// With unit-slot jobs only, the head always fits and this is
/// byte-identical to `fifo` — the digest-level oracle for the grant-path
/// refactor.
#[derive(Clone, Debug, Default)]
pub struct EasyBackfill {
    /// Scratch: waiter indices in FCFS order (reused across calls).
    order: Vec<usize>,
    /// Scratch: projected (completion, slots) frees (reused).
    frees: Vec<(f64, u32)>,
    /// Scratch: completions of jobs granted within one decision.
    granted_frees: Vec<(f64, u32)>,
}

impl EasyBackfill {
    /// Earliest time the free pool reaches `need` slots, projecting the
    /// running jobs' expected completions — plus `granted`, the
    /// `(completion, slots)` of jobs started earlier in this same
    /// decision, which may return their slots before any running job
    /// does — onto `free` currently-idle slots (overdue completions
    /// count as due now). Omitting the just-granted jobs would
    /// over-estimate the reservation and let a backfill overstay it,
    /// delaying the head.
    fn reservation(
        &mut self,
        view: &SchedView,
        free: usize,
        need: usize,
        granted: &[(f64, u32)],
    ) -> f64 {
        self.frees.clear();
        for r in view.running {
            self.frees.push((r.expected_done.max(view.now), r.job.slots));
        }
        self.frees.extend_from_slice(granted);
        self.frees.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut acc = free;
        for &(t, slots) in &self.frees {
            acc += slots as usize;
            if acc >= need {
                return t;
            }
        }
        // capacity itself cannot cover the job — unreachable for
        // validated configs; an infinite window disables backfill limits
        f64::INFINITY
    }
}

impl Scheduler for EasyBackfill {
    fn name(&self) -> &'static str {
        "easy_backfill"
    }
    fn admit(&mut self, ctx: &SchedCtx) -> bool {
        // strict FCFS: never overtake a non-empty queue on request;
        // overtaking is on_enqueue's backfill decision
        ctx.queued == 0
    }
    fn queue_key(&mut self, _ctx: &SchedCtx) -> f64 {
        0.0
    }
    fn needs_view(&self) -> bool {
        true
    }
    fn on_enqueue(&mut self, ctx: &SchedCtx, view: &SchedView) -> EnqueueAction {
        // arriving while the queue is non-empty but slots are free: the
        // job may backfill if it fits and finishes within the head's
        // reservation window
        if ctx.job.slots as usize > view.free || view.waiters.is_empty() {
            return EnqueueAction::Queue;
        }
        let head = view
            .waiters
            .iter()
            .min_by_key(|w| w.seq)
            .expect("non-empty");
        let r = self.reservation(view, view.free, head.job.slots as usize, &[]);
        if view.now + ctx.job.expected_occupancy <= r {
            EnqueueAction::Admit
        } else {
            EnqueueAction::Queue
        }
    }
    fn on_release(&mut self, view: &SchedView, grants: &mut Vec<usize>) {
        self.order.clear();
        self.order.extend(0..view.waiters.len());
        self.order.sort_unstable_by_key(|&i| view.waiters[i].seq);
        let mut free = view.free;
        // FCFS grants until the head no longer fits
        let mut k = 0;
        while k < self.order.len() {
            let w = &view.waiters[self.order[k]];
            if w.job.slots as usize <= free {
                free -= w.job.slots as usize;
                grants.push(self.order[k]);
                k += 1;
            } else {
                break;
            }
        }
        if k >= self.order.len() || free == 0 {
            return;
        }
        // the head is blocked: reserve its start, then backfill later
        // waiters that fit the free slots and the reservation window.
        // The reservation must see the jobs granted above too — they
        // start now and may return their slots before any running job
        // does, so projecting from the running set alone would place R
        // too late and let a backfill overstay the head's true start.
        // R is fixed for the whole pass: each backfill admitted here
        // finishes by R and only borrows slots the head cannot use, so
        // at R the head's slots are all back and it starts on time.
        let mut gfrees = std::mem::take(&mut self.granted_frees);
        gfrees.clear();
        for &gi in grants.iter() {
            let w = &view.waiters[gi];
            gfrees.push((view.now + w.job.expected_occupancy, w.job.slots));
        }
        let head = &view.waiters[self.order[k]];
        let r = self.reservation(view, free, head.job.slots as usize, &gfrees);
        self.granted_frees = gfrees;
        let order = std::mem::take(&mut self.order);
        for &i in &order[k + 1..] {
            let w = &view.waiters[i];
            if w.job.slots as usize <= free && view.now + w.job.expected_occupancy <= r {
                free -= w.job.slots as usize;
                grants.push(i);
                if free == 0 {
                    break;
                }
            }
        }
        self.order = order;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(occ: f64, pri: f64, arrived: f64, now: f64) -> SchedCtx {
        SchedCtx {
            now,
            job: JobCtx::new(occ, pri, arrived),
            in_use: 1,
            capacity: 1,
            queued: 0,
        }
    }

    fn wv(occ: f64, pri: f64, slots: u32, key: f64, seq: u64) -> WaiterView {
        WaiterView {
            job: JobCtx::new(occ, pri, 0.0).with_slots(slots),
            key,
            enq_t: 0.0,
            seq,
        }
    }

    fn rv(occ: f64, pri: f64, slots: u32, started: f64, seq: u64) -> RunningView {
        RunningView {
            job: JobCtx::new(occ, pri, 0.0).with_slots(slots),
            started_at: started,
            expected_done: started + occ,
            seq,
        }
    }

    #[test]
    fn queue_key_orders_by_total_cmp_then_seq() {
        let qk = |key, seq| QueueKey { key, seq };
        // primary: the float key under total_cmp
        assert!(qk(1.0, 9) < qk(2.0, 0));
        assert!(qk(-0.0, 9) < qk(0.0, 0), "total_cmp: -0.0 < +0.0");
        assert!(qk(f64::NEG_INFINITY, 0) < qk(f64::MIN, 0));
        assert!(qk(f64::INFINITY, 0) < qk(f64::NAN, 0), "NaN ranks last");
        // tie-break: enqueue sequence (FCFS)
        assert!(qk(5.0, 1) < qk(5.0, 2));
        let same = qk(5.0, 1);
        assert_eq!(same, qk(5.0, 1));
        assert_ne!(same, qk(5.0, 2), "seq makes the order strict");
        // Ord/PartialOrd agree (the heap and earlier_waiter share one rule)
        assert_eq!(
            qk(3.0, 4).partial_cmp(&qk(3.0, 5)),
            Some(std::cmp::Ordering::Less)
        );
    }

    #[test]
    fn earlier_waiter_is_queue_key_order() {
        let a = wv(1.0, 1.0, 1, 2.0, 0);
        let b = wv(1.0, 1.0, 1, 2.0, 1);
        let c = wv(1.0, 1.0, 1, 1.0, 2);
        assert!(earlier_waiter(&a, &b), "key tie falls back to seq");
        assert!(!earlier_waiter(&b, &a));
        assert!(earlier_waiter(&c, &a), "lower key wins regardless of seq");
        assert_eq!(a.queue_key(), QueueKey { key: 2.0, seq: 0 });
    }

    #[test]
    fn builtin_keys_reproduce_legacy_discipline_rule() {
        // the pre-trait simulator computed: fifo -> 0, priority -> the
        // pipeline priority, sjf -> expected occupancy. The trait impls
        // must be bit-identical for digests to match across the refactor.
        let c = ctx(42.5, 3.0, 10.0, 11.0);
        assert_eq!(Fifo.queue_key(&c), 0.0);
        assert_eq!(Priority.queue_key(&c), 3.0);
        assert_eq!(ShortestJobFirst.queue_key(&c), 42.5);
    }

    #[test]
    fn default_admission_is_work_conserving() {
        let c = ctx(1.0, 5.0, 0.0, 0.0);
        assert!(Fifo.admit(&c));
        assert!(Priority.admit(&c));
        assert!(WeightedFair::default().admit(&c));
        // fifo refuses to overtake a non-empty queue (only observable
        // with multi-slot jobs; unit-slot queues imply a full resource)
        let mut c2 = c;
        c2.queued = 1;
        assert!(!Fifo.admit(&c2));
        assert!(Priority.admit(&c2));
    }

    #[test]
    fn key_based_schedulers_skip_the_view_machinery() {
        assert!(!Fifo.needs_view());
        assert!(!Priority.needs_view());
        assert!(!ShortestJobFirst.needs_view());
        assert!(!EarliestDeadlineFirst::default().needs_view());
        assert!(!WeightedFair::default().needs_view());
        assert!(PreemptivePriority::default().needs_view());
        assert!(EasyBackfill::default().needs_view());
    }

    #[test]
    fn edf_orders_by_arrival_plus_class_slack() {
        let mut edf = EarliestDeadlineFirst {
            slack_per_class: 100.0,
        };
        // late but urgent beats early but lax
        let urgent = edf.queue_key(&ctx(1.0, 1.0, 500.0, 600.0)); // due 600
        let lax = edf.queue_key(&ctx(1.0, 9.0, 0.0, 600.0)); // due 900
        assert!(urgent < lax);
        // retrains (class 0) are due at arrival
        assert_eq!(edf.queue_key(&ctx(1.0, 0.0, 123.0, 600.0)), 123.0);
    }

    #[test]
    fn weighted_fair_charges_heavier_classes_more() {
        let mut wf = WeightedFair::default();
        let k1a = wf.queue_key(&ctx(10.0, 1.0, 0.0, 0.0));
        let k1b = wf.queue_key(&ctx(10.0, 1.0, 0.0, 0.0));
        let k9 = wf.queue_key(&ctx(10.0, 9.0, 0.0, 0.0));
        // class 1 advances 10s of virtual time per job, class 9 90s
        assert_eq!(k1a, 10.0);
        assert_eq!(k1b, 20.0);
        assert_eq!(k9, 90.0);
        // so two more class-9 jobs would overtake nothing: keys monotone
        assert!(k1b < k9);
    }

    #[test]
    fn weighted_fair_anchors_idle_classes_to_now() {
        let mut wf = WeightedFair::default();
        let early = wf.queue_key(&ctx(5.0, 2.0, 0.0, 0.0)); // vft[2] = 10
        assert_eq!(early, 10.0);
        // much later, the class's stale credit must not let it jump the
        // queue arbitrarily: start is max(vft, now)
        let late = wf.queue_key(&ctx(5.0, 2.0, 0.0, 1000.0));
        assert_eq!(late, 1010.0);
    }

    #[test]
    fn weighted_fair_is_deterministic_per_state() {
        let mut a = WeightedFair::new(2.0);
        let mut b = WeightedFair::new(2.0);
        for i in 0..100 {
            let c = ctx(1.0 + i as f64, (i % 7) as f64, i as f64, i as f64);
            assert_eq!(a.queue_key(&c), b.queue_key(&c));
        }
    }

    #[test]
    fn restart_first_boosts_only_restart_victims() {
        let mut rf = RestartFirst::default();
        let fresh = ctx(1.0, 3.0, 0.0, 0.0);
        assert_eq!(rf.queue_key(&fresh), 3.0, "no restarts: identical to priority");
        let mut victim = fresh;
        victim.job = victim.job.after_restart();
        assert!(victim.job.restarted);
        assert!(rf.queue_key(&victim) < rf.queue_key(&ctx(1.0, 0.0, 0.0, 0.0)));
        // a gentle boost only nudges one class up
        let mut gentle = RestartFirst { restart_boost: 1.0 };
        assert_eq!(gentle.queue_key(&victim), 2.0);
        assert!(!rf.needs_view(), "key-based: no view machinery");
    }

    #[test]
    fn default_grants_pick_key_seq_minimum_until_blocked() {
        let waiters = [
            wv(1.0, 1.0, 1, 2.0, 0),
            wv(1.0, 1.0, 1, 1.0, 1),
            wv(1.0, 1.0, 2, 1.0, 2),
        ];
        let view = SchedView {
            now: 0.0,
            free: 2,
            capacity: 4,
            waiters: &waiters,
            running: &[],
        };
        let mut grants = Vec::new();
        default_grants(&view, &mut grants);
        // key 1.0/seq 1 first; then key 1.0/seq 2 needs 2 slots but only
        // 1 free -> head-of-line blocks (no skipping to key 2.0)
        assert_eq!(grants, vec![1]);
    }

    #[test]
    fn preemptive_priority_evicts_worst_running_class() {
        let mut p = PreemptivePriority::default();
        let running = [rv(100.0, 4.0, 1, 0.0, 0), rv(100.0, 9.0, 1, 0.0, 1)];
        let view = SchedView {
            now: 10.0,
            free: 0,
            capacity: 2,
            waiters: &[],
            running: &running,
        };
        // class 2 newcomer evicts the class-9 job
        let act = p.on_enqueue(&ctx(5.0, 2.0, 10.0, 10.0), &view);
        assert_eq!(act, EnqueueAction::Preempt { victim_seq: 1 });
        // class 9 newcomer evicts nothing (no strictly worse victim)
        let act = p.on_enqueue(&ctx(5.0, 9.0, 10.0, 10.0), &view);
        assert_eq!(act, EnqueueAction::Queue);
        // gap too small under a stricter config
        let mut strict = PreemptivePriority { min_class_gap: 10.0 };
        let act = strict.on_enqueue(&ctx(5.0, 2.0, 10.0, 10.0), &view);
        assert_eq!(act, EnqueueAction::Queue);
    }

    #[test]
    fn preemptive_priority_ties_prefer_most_recent_start() {
        let mut p = PreemptivePriority::default();
        let running = [rv(100.0, 9.0, 1, 0.0, 0), rv(100.0, 9.0, 1, 5.0, 3)];
        let view = SchedView {
            now: 10.0,
            free: 0,
            capacity: 2,
            waiters: &[],
            running: &running,
        };
        let act = p.on_enqueue(&ctx(5.0, 1.0, 10.0, 10.0), &view);
        assert_eq!(act, EnqueueAction::Preempt { victim_seq: 3 });
    }

    #[test]
    fn easy_backfill_reserves_head_and_backfills_the_window() {
        let mut e = EasyBackfill::default();
        // capacity 4, 3 busy via two running jobs; 1 free after release.
        // head needs 3 slots -> blocked; reservation = when the first
        // running job (done at t=50) frees its 2 slots: 1+2 >= 3 -> R=50.
        let running = [rv(40.0, 5.0, 2, 10.0, 0), rv(90.0, 5.0, 1, 10.0, 1)];
        let waiters = [
            wv(30.0, 5.0, 3, 0.0, 0), // blocked head (needs 3)
            wv(45.0, 5.0, 1, 0.0, 1), // too long: 10 + 45 > 50
            wv(35.0, 5.0, 1, 0.0, 2), // fits the window: 10 + 35 <= 50
        ];
        let view = SchedView {
            now: 10.0,
            free: 1,
            capacity: 4,
            waiters: &waiters,
            running: &running,
        };
        let mut grants = Vec::new();
        e.on_release(&view, &mut grants);
        assert_eq!(grants, vec![2], "only the window-fitting job backfills");
    }

    #[test]
    fn easy_backfill_reservation_counts_jobs_granted_in_the_same_pass() {
        // regression: the reservation must include completions of jobs
        // granted earlier in this very decision. Capacity 5, running
        // A(2 slots, done 100) and B(1 slot, done 10); 2 slots free.
        // FCFS grants g(1 slot, 5s) -> free 1; head needs 3. True
        // reservation: g returns at 5, B at 10 -> 3 slots at t=10.
        // Projecting from the running set alone would say R=100 and
        // wrongly backfill w(80s), delaying the head to t=80.
        let mut e = EasyBackfill::default();
        let running = [rv(100.0, 5.0, 2, 0.0, 0), rv(10.0, 5.0, 1, 0.0, 1)];
        let waiters = [
            wv(5.0, 5.0, 1, 0.0, 0),  // g: granted FCFS into a free slot
            wv(30.0, 5.0, 3, 0.0, 1), // blocked head (needs 3)
            wv(80.0, 5.0, 1, 0.0, 2), // w: fits R=100 but NOT R=10
        ];
        let view = SchedView {
            now: 0.0,
            free: 2,
            capacity: 5,
            waiters: &waiters,
            running: &running,
        };
        let mut grants = Vec::new();
        e.on_release(&view, &mut grants);
        assert_eq!(grants, vec![0], "w must not overstay the head's true start");
    }

    #[test]
    fn easy_backfill_is_fcfs_when_head_fits() {
        let mut e = EasyBackfill::default();
        let waiters = [wv(10.0, 9.0, 1, 0.0, 0), wv(1.0, 1.0, 1, 0.0, 1)];
        let view = SchedView {
            now: 0.0,
            free: 1,
            capacity: 2,
            waiters: &waiters,
            running: &[],
        };
        let mut grants = Vec::new();
        e.on_release(&view, &mut grants);
        // seq order, not priority or length
        assert_eq!(grants, vec![0]);
    }

    #[test]
    fn easy_backfill_admits_arrivals_inside_the_window() {
        let mut e = EasyBackfill::default();
        let running = [rv(40.0, 5.0, 2, 10.0, 0)]; // done at 50, frees 2
        let waiters = [wv(30.0, 5.0, 3, 0.0, 0)]; // head needs 3, 1 free
        let view = SchedView {
            now: 10.0,
            free: 1,
            capacity: 3,
            waiters: &waiters,
            running: &running,
        };
        // fits free=1 and finishes by R=50
        let act = e.on_enqueue(&ctx(30.0, 7.0, 10.0, 10.0), &view);
        assert_eq!(act, EnqueueAction::Admit);
        // would overrun the reservation
        let act = e.on_enqueue(&ctx(60.0, 7.0, 10.0, 10.0), &view);
        assert_eq!(act, EnqueueAction::Queue);
        // too wide for the free pool
        let c = SchedCtx {
            job: JobCtx::new(5.0, 7.0, 10.0).with_slots(2),
            ..ctx(5.0, 7.0, 10.0, 10.0)
        };
        assert_eq!(e.on_enqueue(&c, &view), EnqueueAction::Queue);
    }
}
