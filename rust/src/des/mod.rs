//! Discrete-event simulation core.
//!
//! The substrate replacing SimPy (paper section V-B): a calendar event
//! queue with deterministic FIFO tie-breaking, shared resources with job
//! capacity and wait queues (SimPy's `Resource` semantics), and
//! time-weighted monitors for utilization/queue statistics.
//!
//! The core is engine-agnostic: it knows nothing about pipelines. The
//! experiment runner in [`crate::coordinator`] drives the loop.

pub mod calendar;
pub mod monitor;
pub mod place;
pub mod resource;
pub mod retry;
pub mod sched;

pub use calendar::{Calendar, EventHandle};
pub use monitor::{Counter, TimeWeighted};
pub use place::{ClassPool, ClassView, PlaceCtx, Placer};
pub use resource::{AcquireResult, Granted, Resource};
pub use retry::{RetryCtx, RetryDecision, RetryPolicy};
pub use sched::{EnqueueAction, JobCtx, QueueKey, SchedCtx, SchedView, Scheduler};

/// Simulated time in seconds since experiment start.
pub type SimTime = f64;

/// Seconds in an hour/day/week — used throughout arrival profiles.
pub const HOUR: SimTime = 3600.0;
pub const DAY: SimTime = 24.0 * HOUR;
pub const WEEK: SimTime = 7.0 * DAY;
