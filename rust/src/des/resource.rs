//! Shared resources with job capacity and wait queues.
//!
//! Mirrors SimPy's `Resource` (the paper models every compute cluster as
//! one, section V-B a): a congestion point with a fixed number of job
//! slots. Requests beyond capacity queue up; on release the next waiters
//! are granted according to the resource's [`Scheduler`].
//!
//! Scheduling beyond FIFO is the hook for the paper's envisioned
//! pipeline schedulers (Fig 4): every admission, ordering, grant, and
//! preemption decision is delegated to a pluggable [`Scheduler`]
//! strategy (see [`super::sched`]), selectable by name from experiment
//! config. Jobs may occupy multiple slots ([`JobCtx::slots`]), which is
//! what gives backfill strategies a blocked head-of-queue to reserve
//! around; re-decision strategies ([`Scheduler::needs_view`]) can evict
//! running work ([`AcquireResult::Preempted`]) — the caller then cancels
//! the victim's completion event and the victim waits in queue with its
//! remaining service.

use super::monitor::TimeWeighted;
use super::sched::{
    default_grants, earlier_waiter, EnqueueAction, Fifo, JobCtx, RunningView, SchedCtx, SchedView,
    Scheduler, WaiterView,
};
use super::SimTime;
use crate::stats::Summary;

/// Result of a resource request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AcquireResult<T> {
    /// Enough slots were free; the job may start immediately.
    Acquired,
    /// The job could not start (capacity short or admission deferred);
    /// the token was enqueued and will be returned by a future
    /// release call.
    Queued,
    /// The job starts immediately by evicting `victim`, which has been
    /// re-queued with its remaining service. The caller must cancel the
    /// victim's scheduled completion event.
    Preempted { victim: T },
}

/// A granted waiter returned by a release call.
#[derive(Clone, Copy, Debug)]
pub struct Granted<T> {
    pub token: T,
    /// How long the job waited in queue (since its last enqueue — a
    /// preempted job re-enters the queue at preemption time).
    pub waited: SimTime,
}

/// A capacity-limited shared resource with queueing and instrumentation.
pub struct Resource<T> {
    pub name: String,
    capacity: usize,
    in_use: usize,
    scheduler: Box<dyn Scheduler>,
    /// Cached `scheduler.needs_view()`: when false the re-decision hooks
    /// are never called and the running set is not tracked.
    track_view: bool,
    // waiters as parallel arrays so the views form a contiguous slice
    // handed to the scheduler without copying (storage order arbitrary —
    // `WaiterView::seq` carries FCFS order)
    waiter_tok: Vec<T>,
    waiter_views: Vec<WaiterView>,
    // running set (only maintained when `track_view`)
    run_tok: Vec<T>,
    run_views: Vec<RunningView>,
    wseq: u64,
    rseq: u64,
    grant_scratch: Vec<usize>,
    // instrumentation
    pub busy: TimeWeighted,
    pub queue_len: TimeWeighted,
    pub wait_stats: Summary,
    pub total_requests: u64,
    pub total_queued: u64,
    /// Running jobs evicted by a preemptive strategy.
    pub total_preempted: u64,
}

impl<T> Resource<T> {
    /// A FIFO resource (SimPy's default).
    pub fn new(name: impl Into<String>, capacity: usize) -> Self {
        Self::with_scheduler(name, capacity, Box::new(Fifo))
    }

    /// A resource driven by the given scheduling strategy. The resource
    /// owns the scheduler exclusively, so stateful strategies are
    /// per-resource and per-run.
    pub fn with_scheduler(
        name: impl Into<String>,
        capacity: usize,
        scheduler: Box<dyn Scheduler>,
    ) -> Self {
        assert!(capacity > 0, "resource capacity must be positive");
        let track_view = scheduler.needs_view();
        Resource {
            name: name.into(),
            capacity,
            in_use: 0,
            scheduler,
            track_view,
            waiter_tok: Vec::new(),
            waiter_views: Vec::new(),
            run_tok: Vec::new(),
            run_views: Vec::new(),
            wseq: 0,
            rseq: 0,
            grant_scratch: Vec::new(),
            busy: TimeWeighted::new(0.0, 0.0),
            queue_len: TimeWeighted::new(0.0, 0.0),
            wait_stats: Summary::new(),
            total_requests: 0,
            total_queued: 0,
            total_preempted: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn in_use(&self) -> usize {
        self.in_use
    }

    pub fn queued(&self) -> usize {
        self.waiter_views.len()
    }

    /// Name of the scheduling strategy driving this resource.
    pub fn scheduler_name(&self) -> &'static str {
        self.scheduler.name()
    }

    fn ctx(&self, t: SimTime, job: JobCtx) -> SchedCtx {
        SchedCtx {
            now: t,
            job,
            in_use: self.in_use,
            capacity: self.capacity,
            queued: self.waiter_views.len(),
        }
    }

    /// Enqueue a job: the scheduler assigns its ordering key.
    fn enqueue(&mut self, t: SimTime, token: T, job: JobCtx) {
        let ctx = self.ctx(t, job);
        let key = self.scheduler.queue_key(&ctx);
        debug_assert!(!key.is_nan(), "NaN waiter key from {}", self.scheduler.name());
        self.waiter_tok.push(token);
        self.waiter_views.push(WaiterView {
            job,
            key,
            enq_t: t,
            seq: self.wseq,
        });
        self.wseq += 1;
        self.total_queued += 1;
        self.queue_len.set(t, self.waiter_views.len() as f64);
    }

    /// Start a job immediately: occupy its slots and (when tracked)
    /// record it in the running set.
    fn start_running(&mut self, t: SimTime, token: T, job: JobCtx) {
        self.in_use += job.slots as usize;
        debug_assert!(self.in_use <= self.capacity);
        if self.track_view {
            self.run_tok.push(token);
            self.run_views.push(RunningView {
                job,
                started_at: t,
                expected_done: t + job.expected_occupancy,
                seq: self.rseq,
            });
            self.rseq += 1;
        }
        self.busy.set(t, self.in_use as f64);
    }

    /// Fraction of total slot-time busy over [0, t].
    pub fn utilization(&self, t: SimTime) -> f64 {
        if t <= 0.0 {
            return 0.0;
        }
        self.busy.integral_at(t) / (t * self.capacity as f64)
    }

    /// Time-averaged queue length over [0, t].
    pub fn avg_queue_len(&self, t: SimTime) -> f64 {
        self.queue_len.mean_at(t, 0.0)
    }
}

impl<T: Copy> Resource<T> {
    /// Request `job.slots` slots at time `t` for a job described by
    /// `job`. The scheduler decides admission; when the job cannot start
    /// a re-decision scheduler may backfill it into free capacity or
    /// preempt running work ([`AcquireResult::Preempted`]); otherwise it
    /// queues under the scheduler's ordering key.
    pub fn request(&mut self, t: SimTime, token: T, job: JobCtx) -> AcquireResult<T> {
        self.total_requests += 1;
        debug_assert!(
            job.slots >= 1 && job.slots as usize <= self.capacity,
            "job of {} slots can never fit {} ({} capacity)",
            job.slots,
            self.name,
            self.capacity
        );
        let ctx = self.ctx(t, job);
        let fits = self.in_use + job.slots as usize <= self.capacity;
        // idle resources always admit (enforced here, not just documented):
        // with nothing running, nothing will ever be released to grant a
        // queued job, so a scheduler refusing at in_use == 0 would deadlock
        if fits && (self.in_use == 0 || self.scheduler.admit(&ctx)) {
            self.start_running(t, token, job);
            self.wait_stats.add(0.0);
            return AcquireResult::Acquired;
        }
        if self.track_view {
            let view = SchedView {
                now: t,
                free: self.capacity - self.in_use,
                capacity: self.capacity,
                waiters: &self.waiter_views,
                running: &self.run_views,
            };
            match self.scheduler.on_enqueue(&ctx, &view) {
                EnqueueAction::Queue => {}
                EnqueueAction::Admit => {
                    let admit_fits = self.in_use + job.slots as usize <= self.capacity;
                    debug_assert!(admit_fits, "{}: Admit for a job that does not fit", self.name);
                    if admit_fits {
                        self.start_running(t, token, job);
                        self.wait_stats.add(0.0);
                        return AcquireResult::Acquired;
                    }
                }
                EnqueueAction::Preempt { victim_seq } => {
                    if let Some(victim) = self.preempt(t, token, job, victim_seq) {
                        return AcquireResult::Preempted { victim };
                    }
                }
            }
        }
        self.enqueue(t, token, job);
        AcquireResult::Queued
    }

    /// Evict the running job with view-seq `victim_seq`, start `job` in
    /// its place, and re-queue the victim with its remaining service.
    /// Returns the victim token, or `None` when the decision is invalid
    /// (unknown victim, or the swap would not fit) — the job then queues.
    fn preempt(&mut self, t: SimTime, token: T, job: JobCtx, victim_seq: u64) -> Option<T> {
        let vi = self.run_views.iter().position(|r| r.seq == victim_seq)?;
        let v = self.run_views[vi];
        let swap_fits = self.capacity - self.in_use + v.job.slots as usize >= job.slots as usize;
        debug_assert!(swap_fits, "{}: preemption swap does not fit", self.name);
        if !swap_fits {
            return None;
        }
        let vtok = self.run_tok.swap_remove(vi);
        self.run_views.swap_remove(vi);
        self.in_use -= v.job.slots as usize;
        // the preemptor starts now; it never waited
        self.start_running(t, token, job);
        self.wait_stats.add(0.0);
        // the victim waits with its remaining service as the occupancy
        // (it resumes where it stopped); its queue position comes from
        // the scheduler's key like any other waiter
        let remaining = (v.expected_done - t).max(0.0);
        let vjob = JobCtx {
            expected_occupancy: remaining,
            ..v.job
        };
        self.enqueue(t, vtok, vjob);
        self.total_preempted += 1;
        Some(vtok)
    }

    /// Release one slot at time `t` — the unit-width convenience API
    /// (every job occupies one slot; re-decision schedulers must use
    /// [`Resource::release_all`], which identifies the releasing job).
    /// If waiters are queued, the scheduler's best `(key, seq)` waiter
    /// is granted *immediately* — the slot never goes idle — and
    /// returned so the caller can schedule its continuation.
    pub fn release(&mut self, t: SimTime) -> Option<Granted<T>> {
        debug_assert!(self.in_use > 0, "release on idle resource {}", self.name);
        debug_assert!(
            !self.track_view,
            "{}: re-decision schedulers release via release_all",
            self.name
        );
        match self.best_waiter() {
            Some(i) => {
                let g = self.take_waiter(t, i);
                self.queue_len.set(t, self.waiter_views.len() as f64);
                self.wait_stats.add(g.waited);
                // in_use unchanged: slot transfers to the waiter
                Some(g)
            }
            None => {
                self.in_use -= 1;
                self.busy.set(t, self.in_use as f64);
                None
            }
        }
    }

    /// Release the `slots` occupied by `token` at time `t` and grant
    /// waiters per the scheduler's decision — possibly several when a
    /// wide job frees room for multiple narrow ones, possibly none when
    /// the discipline holds slots for a blocked head-of-queue. Grants
    /// are appended to `out` in grant order.
    pub fn release_all(&mut self, t: SimTime, token: &T, slots: u32, out: &mut Vec<Granted<T>>)
    where
        T: PartialEq,
    {
        debug_assert!(
            self.in_use >= slots as usize,
            "release of {slots} slots on resource {} with {} in use",
            self.name,
            self.in_use
        );
        let in_use_before = self.in_use;
        self.in_use -= slots as usize;
        if self.track_view {
            let pos = self.run_tok.iter().position(|rt| rt == token);
            debug_assert!(pos.is_some(), "{}: released token not running", self.name);
            if let Some(i) = pos {
                debug_assert_eq!(self.run_views[i].job.slots, slots);
                self.run_tok.swap_remove(i);
                self.run_views.swap_remove(i);
            }
        }
        let mut granted_any = false;
        if !self.waiter_views.is_empty() {
            let mut grants = std::mem::take(&mut self.grant_scratch);
            grants.clear();
            let view = SchedView {
                now: t,
                free: self.capacity - self.in_use,
                capacity: self.capacity,
                waiters: &self.waiter_views,
                running: &self.run_views,
            };
            if self.track_view {
                self.scheduler.on_release(&view, &mut grants);
            } else {
                default_grants(&view, &mut grants);
            }
            granted_any = !grants.is_empty();
            self.apply_grants(t, &mut grants, out);
            self.grant_scratch = grants;
        }
        // touch the monitors only when the tracked value changed: the
        // piecewise integral is partition-sensitive in the last float
        // bit, and pre-existing schedulers' digests must stay
        // byte-identical to the single-grant release path
        if self.in_use != in_use_before {
            self.busy.set(t, self.in_use as f64);
        }
        if granted_any {
            self.queue_len.set(t, self.waiter_views.len() as f64);
        }
    }

    /// Validate and apply a grant selection: occupy slots, record stats,
    /// and remove the granted waiters. `grants` is consumed (re-sorted
    /// in place for the removal pass — its order is scratch afterward).
    fn apply_grants(&mut self, t: SimTime, grants: &mut Vec<usize>, out: &mut Vec<Granted<T>>) {
        let mut free = self.capacity - self.in_use;
        for (n, &i) in grants.iter().enumerate() {
            assert!(
                i < self.waiter_views.len() && !grants[..n].contains(&i),
                "{}: scheduler {} granted an invalid waiter index",
                self.name,
                self.scheduler.name()
            );
            let w = self.waiter_views[i];
            assert!(
                w.job.slots as usize <= free,
                "{}: scheduler {} granted a job that does not fit",
                self.name,
                self.scheduler.name()
            );
            free -= w.job.slots as usize;
            let g = Granted {
                token: self.waiter_tok[i],
                waited: t - w.enq_t,
            };
            self.wait_stats.add(g.waited);
            self.in_use += w.job.slots as usize;
            if self.track_view {
                self.run_tok.push(self.waiter_tok[i]);
                self.run_views.push(RunningView {
                    job: w.job,
                    started_at: t,
                    expected_done: t + w.job.expected_occupancy,
                    seq: self.rseq,
                });
                self.rseq += 1;
            }
            out.push(g);
        }
        // remove granted waiters, highest index first so the remaining
        // indices stay valid under swap_remove (in place: the event path
        // stays allocation-free)
        grants.sort_unstable_by(|a, b| b.cmp(a));
        for &i in grants.iter() {
            self.waiter_tok.swap_remove(i);
            self.waiter_views.swap_remove(i);
        }
    }

    /// Index of the `(key, seq)`-minimal waiter (the same
    /// [`earlier_waiter`] rule `default_grants` uses, so the unit-width
    /// `release` path and `release_all` can never diverge).
    fn best_waiter(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, w) in self.waiter_views.iter().enumerate() {
            if best.is_none_or(|b| earlier_waiter(w, &self.waiter_views[b])) {
                best = Some(i);
            }
        }
        best
    }

    fn take_waiter(&mut self, t: SimTime, i: usize) -> Granted<T> {
        let w = self.waiter_views.swap_remove(i);
        let token = self.waiter_tok.swap_remove(i);
        Granted {
            token,
            waited: t - w.enq_t,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::sched::{EasyBackfill, PreemptivePriority, Priority, ShortestJobFirst};

    fn job(key: f64) -> JobCtx {
        // tests drive ordering through a single knob: use the same value
        // for occupancy and priority so either discipline sees it
        JobCtx::new(key, key, 0.0)
    }

    fn release_one<'a>(
        r: &mut Resource<&'a str>,
        t: SimTime,
        token: &'a str,
        slots: u32,
    ) -> Vec<&'a str> {
        let mut out = Vec::new();
        r.release_all(t, &token, slots, &mut out);
        out.iter().map(|g| g.token).collect()
    }

    #[test]
    fn acquire_until_capacity_then_queue() {
        let mut r: Resource<u32> = Resource::new("train", 2);
        assert_eq!(r.request(0.0, 1, job(0.0)), AcquireResult::Acquired);
        assert_eq!(r.request(0.0, 2, job(0.0)), AcquireResult::Acquired);
        assert_eq!(r.request(1.0, 3, job(0.0)), AcquireResult::Queued);
        assert_eq!(r.in_use(), 2);
        assert_eq!(r.queued(), 1);
        assert_eq!(r.scheduler_name(), "fifo");
    }

    #[test]
    fn release_grants_fifo_order() {
        let mut r: Resource<u32> = Resource::new("train", 1);
        r.request(0.0, 1, job(0.0));
        r.request(1.0, 2, job(0.0));
        r.request(2.0, 3, job(0.0));
        let g = r.release(5.0).unwrap();
        assert_eq!(g.token, 2);
        assert_eq!(g.waited, 4.0);
        let g = r.release(9.0).unwrap();
        assert_eq!(g.token, 3);
        assert_eq!(g.waited, 7.0);
        assert!(r.release(10.0).is_none());
        assert_eq!(r.in_use(), 0);
    }

    #[test]
    fn release_all_matches_release_for_unit_jobs() {
        let run = |wide: bool| {
            let mut r: Resource<u32> = Resource::new("train", 2);
            r.request(0.0, 1, job(0.0));
            r.request(0.0, 2, job(0.0));
            r.request(1.0, 3, job(0.5));
            r.request(2.0, 4, job(0.25));
            let mut order = Vec::new();
            for t in [3.0, 4.0, 5.0, 6.0] {
                if wide {
                    let mut out = Vec::new();
                    r.release_all(t, &0, 1, &mut out);
                    order.extend(out.iter().map(|g| g.token));
                } else if let Some(g) = r.release(t) {
                    order.push(g.token);
                }
            }
            (order, r.wait_stats.sum, r.utilization(6.0))
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn priority_scheduler_orders_by_class() {
        let mut r: Resource<&str> = Resource::with_scheduler("t", 1, Box::new(Priority));
        r.request(0.0, "running", job(0.0));
        r.request(1.0, "low", job(10.0));
        r.request(2.0, "high", job(1.0));
        r.request(3.0, "mid", job(5.0));
        assert_eq!(r.release(4.0).unwrap().token, "high");
        assert_eq!(r.release(5.0).unwrap().token, "mid");
        assert_eq!(r.release(6.0).unwrap().token, "low");
    }

    #[test]
    fn priority_ties_fall_back_to_fifo() {
        let mut r: Resource<u32> = Resource::with_scheduler("t", 1, Box::new(Priority));
        r.request(0.0, 0, job(0.0));
        for i in 1..=5 {
            r.request(i as f64, i, job(7.0));
        }
        for i in 1..=5 {
            assert_eq!(r.release(10.0 + i as f64).unwrap().token, i);
        }
    }

    #[test]
    fn sjf_grants_shortest_expected_occupancy() {
        let mut r: Resource<&str> = Resource::with_scheduler("t", 1, Box::new(ShortestJobFirst));
        r.request(0.0, "running", job(0.0));
        r.request(1.0, "long", JobCtx::new(500.0, 1.0, 1.0));
        r.request(2.0, "short", JobCtx::new(5.0, 9.0, 2.0));
        assert_eq!(r.release(3.0).unwrap().token, "short");
        assert_eq!(r.release(4.0).unwrap().token, "long");
    }

    #[test]
    fn idle_resource_admits_even_if_scheduler_refuses() {
        // anti-deadlock rule is enforced by the mechanism, not the policy
        struct RefuseAll;
        impl Scheduler for RefuseAll {
            fn name(&self) -> &'static str {
                "refuse_all"
            }
            fn admit(&mut self, _ctx: &SchedCtx) -> bool {
                false
            }
            fn queue_key(&mut self, _ctx: &SchedCtx) -> f64 {
                0.0
            }
        }
        let mut r: Resource<u32> = Resource::with_scheduler("t", 2, Box::new(RefuseAll));
        assert_eq!(r.request(0.0, 1, job(0.0)), AcquireResult::Acquired);
        // non-idle: the policy's refusal now applies
        assert_eq!(r.request(1.0, 2, job(0.0)), AcquireResult::Queued);
        // the queued job is still granted on release, so no job is lost
        assert_eq!(r.release(2.0).unwrap().token, 2);
    }

    #[test]
    fn admission_policy_can_reserve_headroom() {
        // a scheduler that keeps the last slot free for class <= 1
        struct Headroom;
        impl Scheduler for Headroom {
            fn name(&self) -> &'static str {
                "headroom"
            }
            fn admit(&mut self, ctx: &SchedCtx) -> bool {
                ctx.job.priority <= 1.0 || ctx.in_use + 1 < ctx.capacity
            }
            fn queue_key(&mut self, ctx: &SchedCtx) -> f64 {
                ctx.job.priority
            }
        }
        let mut r: Resource<&str> = Resource::with_scheduler("t", 2, Box::new(Headroom));
        assert_eq!(r.request(0.0, "bulk1", job(5.0)), AcquireResult::Acquired);
        // second slot is reserved: bulk work queues even though it's free
        assert_eq!(r.request(1.0, "bulk2", job(5.0)), AcquireResult::Queued);
        assert_eq!(r.in_use(), 1);
        // but class-1 work takes it immediately
        assert_eq!(r.request(2.0, "vip", job(1.0)), AcquireResult::Acquired);
        assert_eq!(r.in_use(), 2);
        // a release hands the freed slot to the best waiter as usual
        assert_eq!(r.release(3.0).unwrap().token, "bulk2");
    }

    #[test]
    fn utilization_and_queue_stats() {
        let mut r: Resource<u32> = Resource::new("c", 2);
        r.request(0.0, 1, job(0.0)); // busy 1
        r.request(10.0, 2, job(0.0)); // busy 2
        r.release(20.0); // busy 1
        r.release(30.0); // busy 0
        // busy integral: 1*10 + 2*10 + 1*10 = 40 over 30s * 2 slots
        assert!((r.utilization(30.0) - 40.0 / 60.0).abs() < 1e-12);
    }

    #[test]
    fn slot_never_idle_when_queue_nonempty() {
        let mut r: Resource<u32> = Resource::new("c", 1);
        r.request(0.0, 1, job(0.0));
        r.request(0.0, 2, job(0.0));
        let g = r.release(3.0).unwrap();
        assert_eq!(g.token, 2);
        assert_eq!(r.in_use(), 1); // transferred, not freed
    }

    #[test]
    #[should_panic]
    fn zero_capacity_rejected() {
        let _: Resource<u32> = Resource::new("bad", 0);
    }

    // ---- multi-slot jobs ----

    #[test]
    fn wide_jobs_occupy_multiple_slots() {
        let mut r: Resource<&str> = Resource::new("t", 4);
        let wide = JobCtx::new(10.0, 1.0, 0.0).with_slots(3);
        assert_eq!(r.request(0.0, "wide", wide), AcquireResult::Acquired);
        assert_eq!(r.in_use(), 3);
        assert_eq!(r.request(1.0, "unit", job(0.0)), AcquireResult::Acquired);
        assert_eq!(r.in_use(), 4);
        // queue drains on the wide release: both slots go out again
        let wide2 = JobCtx::new(5.0, 1.0, 0.0).with_slots(2);
        assert_eq!(r.request(2.0, "w2", wide2), AcquireResult::Queued);
        assert_eq!(r.request(3.0, "u2", job(0.0)), AcquireResult::Queued);
        let granted = release_one(&mut r, 9.0, "wide", 3);
        assert_eq!(granted, vec!["w2", "u2"]);
        assert_eq!(r.in_use(), 4);
    }

    #[test]
    fn fifo_blocks_head_of_line_and_never_overtakes() {
        // strict FCFS: a free slot does not let later work overtake a
        // blocked wide head — neither at release nor at request time
        let mut r: Resource<&str> = Resource::new("t", 3);
        r.request(0.0, "a", job(0.0));
        r.request(0.0, "b", job(0.0));
        r.request(0.0, "c", job(0.0));
        let wide = JobCtx::new(10.0, 1.0, 0.0).with_slots(2);
        assert_eq!(r.request(1.0, "wide", wide), AcquireResult::Queued);
        // one slot frees: the wide head does not fit, nothing granted
        assert_eq!(release_one(&mut r, 2.0, "a", 1), Vec::<&str>::new());
        assert_eq!(r.in_use(), 2);
        // an arriving unit job may not grab the free slot past the head
        assert_eq!(r.request(3.0, "late", job(0.0)), AcquireResult::Queued);
        // second slot frees: the head fits and takes both
        assert_eq!(release_one(&mut r, 4.0, "b", 1), vec!["wide"]);
        assert_eq!(r.in_use(), 3);
        assert_eq!(release_one(&mut r, 5.0, "c", 1), vec!["late"]);
    }

    // ---- preemption ----

    #[test]
    fn preemptive_priority_evicts_and_requeues_victim() {
        let mut r: Resource<&str> =
            Resource::with_scheduler("t", 2, Box::new(PreemptivePriority::default()));
        r.request(0.0, "bulk9", JobCtx::new(100.0, 9.0, 0.0));
        r.request(0.0, "bulk5", JobCtx::new(100.0, 5.0, 0.0));
        // a class-1 arrival evicts the class-9 job, not the class-5 one
        match r.request(10.0, "vip", JobCtx::new(20.0, 1.0, 10.0)) {
            AcquireResult::Preempted { victim } => assert_eq!(victim, "bulk9"),
            other => panic!("expected preemption, got {other:?}"),
        }
        assert_eq!(r.in_use(), 2);
        assert_eq!(r.queued(), 1);
        assert_eq!(r.total_preempted, 1);
        // the victim resumes with its remaining 90s when a slot frees
        let granted = release_one(&mut r, 30.0, "vip", 1);
        assert_eq!(granted, vec!["bulk9"]);
        assert_eq!(granted.len(), 1);
    }

    #[test]
    fn preemption_respects_class_gap_and_never_thrashes_same_class() {
        let mut r: Resource<&str> =
            Resource::with_scheduler("t", 1, Box::new(PreemptivePriority::default()));
        r.request(0.0, "a", JobCtx::new(100.0, 4.0, 0.0));
        // same class queues instead of evicting
        assert_eq!(
            r.request(1.0, "b", JobCtx::new(10.0, 4.0, 1.0)),
            AcquireResult::Queued
        );
        // worse class queues
        assert_eq!(
            r.request(2.0, "c", JobCtx::new(10.0, 9.0, 2.0)),
            AcquireResult::Queued
        );
        assert_eq!(r.total_preempted, 0);
    }

    #[test]
    fn preempted_victim_keeps_remaining_service_not_full() {
        let mut r: Resource<&str> =
            Resource::with_scheduler("t", 1, Box::new(PreemptivePriority::default()));
        r.request(0.0, "victim", JobCtx::new(100.0, 9.0, 0.0));
        // preempt at t=60: 40s of service remain
        match r.request(60.0, "vip", JobCtx::new(10.0, 0.0, 60.0)) {
            AcquireResult::Preempted { victim } => assert_eq!(victim, "victim"),
            other => panic!("{other:?}"),
        }
        let mut out = Vec::new();
        r.release_all(70.0, &"vip", 1, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].token, "victim");
        assert_eq!(out[0].waited, 10.0, "wait counts from preemption time");
        // the running view carries the remaining 40s, not the full 100
        let mut out2 = Vec::new();
        r.release_all(110.0, &"victim", 1, &mut out2);
        assert!(out2.is_empty());
        assert_eq!(r.in_use(), 0);
    }

    // ---- EASY backfill ----

    #[test]
    fn easy_backfill_grants_window_fitting_job_past_blocked_head() {
        let mut r: Resource<&str> =
            Resource::with_scheduler("t", 3, Box::new(EasyBackfill::default()));
        // two running: one frees 2 slots at t=50, one runs to t=100
        r.request(0.0, "w2", JobCtx::new(50.0, 5.0, 0.0).with_slots(2));
        r.request(0.0, "long", JobCtx::new(100.0, 5.0, 0.0));
        // head needs 2 slots -> must wait for w2 at t=50
        assert_eq!(
            r.request(1.0, "head", JobCtx::new(30.0, 5.0, 1.0).with_slots(2)),
            AcquireResult::Queued
        );
        // a short unit job arrives: fits the window (10 + 35 <= 50)
        assert_eq!(
            r.request(10.0, "short", JobCtx::new(35.0, 5.0, 10.0)),
            AcquireResult::Queued,
            "no free slot yet, so it queues"
        );
        // long unit job that would overrun the reservation: also queued
        assert_eq!(
            r.request(11.0, "over", JobCtx::new(200.0, 5.0, 11.0)),
            AcquireResult::Queued
        );
        // nothing free yet; now w2 finishes at 50: head takes its 2 slots
        let granted = release_one(&mut r, 50.0, "w2", 2);
        assert_eq!(granted, vec!["head"]);
        assert_eq!(r.in_use(), 3);
    }

    #[test]
    fn easy_backfill_arrival_backfills_into_free_slot() {
        let mut r: Resource<&str> =
            Resource::with_scheduler("t", 3, Box::new(EasyBackfill::default()));
        r.request(0.0, "w2", JobCtx::new(50.0, 5.0, 0.0).with_slots(2));
        r.request(0.0, "u", JobCtx::new(20.0, 5.0, 0.0));
        assert_eq!(
            r.request(1.0, "head", JobCtx::new(30.0, 5.0, 1.0).with_slots(2)),
            AcquireResult::Queued
        );
        // u releases at 20: head (needs 2) still blocked, 1 slot free
        assert_eq!(release_one(&mut r, 20.0, "u", 1), Vec::<&str>::new());
        assert_eq!(r.in_use(), 2);
        // reservation: w2 frees 2 slots at t=50 -> R = 50. A 25s arrival
        // fits (20 + 25 <= 50) and backfills immediately...
        assert_eq!(
            r.request(20.0, "fill", JobCtx::new(25.0, 5.0, 20.0)),
            AcquireResult::Acquired
        );
        // ...while a 40s arrival would overrun R and queues
        assert_eq!(release_one(&mut r, 45.0, "fill", 1), Vec::<&str>::new());
        assert_eq!(
            r.request(45.5, "over", JobCtx::new(40.0, 5.0, 45.5)),
            AcquireResult::Queued
        );
        // head granted at its reservation; the freed room also lets the
        // queued job behind it start (plain FCFS once the head fits)
        assert_eq!(release_one(&mut r, 50.0, "w2", 2), vec!["head", "over"]);
    }

    #[test]
    fn easy_backfill_release_backfills_window_fitting_waiter() {
        let mut r: Resource<&str> =
            Resource::with_scheduler("t", 3, Box::new(EasyBackfill::default()));
        r.request(0.0, "w2", JobCtx::new(50.0, 5.0, 0.0).with_slots(2));
        r.request(0.0, "u", JobCtx::new(20.0, 5.0, 0.0));
        assert_eq!(
            r.request(1.0, "head", JobCtx::new(30.0, 5.0, 1.0).with_slots(2)),
            AcquireResult::Queued
        );
        // two waiters behind the head: one fits the window, one overruns
        assert_eq!(
            r.request(2.0, "fit", JobCtx::new(25.0, 5.0, 2.0)),
            AcquireResult::Queued
        );
        assert_eq!(
            r.request(3.0, "over", JobCtx::new(200.0, 5.0, 3.0)),
            AcquireResult::Queued
        );
        // u releases at 20: head blocked (R=50); "fit" backfills, "over"
        // stays behind the reservation
        assert_eq!(release_one(&mut r, 20.0, "u", 1), vec!["fit"]);
        assert_eq!(release_one(&mut r, 45.0, "fit", 1), Vec::<&str>::new());
        // at the reservation the head starts, and FCFS resumes for the
        // remaining waiter in the space left over
        assert_eq!(release_one(&mut r, 50.0, "w2", 2), vec!["head", "over"]);
        assert_eq!(r.queued(), 0);
    }
}
