//! Shared resources with job capacity and wait queues.
//!
//! Mirrors SimPy's `Resource` (the paper models every compute cluster as
//! one, section V-B a): a congestion point with a fixed number of job
//! slots. Requests beyond capacity queue up; on release the next waiters
//! are granted according to the resource's [`Scheduler`].
//!
//! Scheduling beyond FIFO is the hook for the paper's envisioned
//! pipeline schedulers (Fig 4): every admission, ordering, grant, and
//! preemption decision is delegated to a pluggable [`Scheduler`]
//! strategy (see [`super::sched`]), selectable by name from experiment
//! config. Jobs may occupy multiple slots ([`JobCtx::slots`]), which is
//! what gives backfill strategies a blocked head-of-queue to reserve
//! around; re-decision strategies ([`Scheduler::needs_view`]) can evict
//! running work ([`AcquireResult::Preempted`]) — the caller then cancels
//! the victim's completion event and the victim waits in queue with its
//! remaining service.
//!
//! ## The indexed waiter heap (O(log n) grants)
//!
//! Waiters live in parallel arrays so re-decision hooks get the full
//! queue as a contiguous [`SchedView`] slice. For every `!needs_view()`
//! scheduler the resource additionally maintains an **index min-heap**
//! over those arrays, keyed by each waiter's immutable
//! [`QueueKey`](super::sched::QueueKey): a grant is then a heap
//! peek/pop instead of a linear `(key, seq)` argmin scan, turning the
//! total grant cost of a persistently overloaded resource from O(Q²)
//! into O(Q log Q). Heap entries record the array slot they were pushed
//! for; `swap_remove` moves a waiter to a lower slot, so the mover gets
//! a fresh entry and the old one goes **stale** — detected lazily by
//! re-checking the slot's unique `seq` when the entry surfaces at the
//! top (the calendar's tombstone technique). Stale entries are bounded
//! by compaction: when they exceed half the backing heap the heap is
//! rebuilt from the live arrays in O(n), so amortized grant cost stays
//! logarithmic. Re-decision schedulers keep the pre-heap Vec path
//! untouched — their grant decisions need the whole queue anyway.
//!
//! The heap's grant order is **byte-identical** to the linear scan
//! ([`default_grants`](super::sched::default_grants), retained as the
//! reference): both compare through `QueueKey`'s total strict order,
//! property-tested across the registry in `rust/tests/props.rs`.

use super::monitor::TimeWeighted;
use super::sched::{
    EnqueueAction, Fifo, JobCtx, QueueKey, RunningView, SchedCtx, SchedView, Scheduler, WaiterView,
};
use super::SimTime;
use crate::stats::Summary;
use crate::util::heap4;

/// Result of a resource request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AcquireResult<T> {
    /// Enough slots were free; the job may start immediately.
    Acquired,
    /// The job could not start (capacity short or admission deferred);
    /// the token was enqueued and will be returned by a future
    /// release call.
    Queued,
    /// The job starts immediately by evicting `victim`, which has been
    /// re-queued with its remaining service. The caller must cancel the
    /// victim's scheduled completion event.
    Preempted { victim: T },
}

/// A granted waiter returned by a release call.
#[derive(Clone, Copy, Debug)]
pub struct Granted<T> {
    pub token: T,
    /// How long the job waited in queue (since its last enqueue — a
    /// preempted job re-enters the queue at preemption time).
    pub waited: SimTime,
}

/// Below this backing size compaction is never worthwhile (mirrors the
/// calendar's tombstone bound).
const COMPACT_MIN: usize = 64;

/// One entry of the waiter index heap: a waiter's [`QueueKey`] plus the
/// slot it occupied in the parallel waiter arrays when the entry was
/// pushed. The entry is *stale* once that slot no longer holds the
/// waiter (`seq` mismatch — seqs are unique, and a waiter's slot only
/// ever decreases under `swap_remove`, so at most one entry per waiter
/// is ever live).
#[derive(Clone, Copy, Debug)]
struct HeapSlot {
    key: QueueKey,
    slot: usize,
}

/// Strict order of the waiter index heap: ascending [`QueueKey`] — the
/// canonical grant rule, handed to the shared [`heap4`] primitives.
#[inline]
fn heap_less(a: &HeapSlot, b: &HeapSlot) -> bool {
    a.key < b.key
}

/// A capacity-limited shared resource with queueing and instrumentation.
pub struct Resource<T> {
    pub name: String,
    capacity: usize,
    /// Slots currently offline after an injected failure (see
    /// [`Resource::fail_slot`]). Every scheduling decision works against
    /// the *effective* capacity `capacity - offline`; always 0 when
    /// failure injection is off, so the arithmetic below reduces to the
    /// historical `capacity` expressions bit-for-bit.
    offline: usize,
    in_use: usize,
    scheduler: Box<dyn Scheduler>,
    /// Cached `scheduler.needs_view()`: when false the re-decision hooks
    /// are never called and the running set is not tracked.
    track_view: bool,
    // waiters as parallel arrays so the views form a contiguous slice
    // handed to the scheduler without copying (storage order arbitrary —
    // `WaiterView::seq` carries FCFS order)
    waiter_tok: Vec<T>,
    waiter_views: Vec<WaiterView>,
    /// Index min-heap over the waiter arrays, keyed by `QueueKey` — the
    /// O(log n) grant path. Maintained only when `!track_view`
    /// (re-decision schedulers re-rank the whole queue per decision, so
    /// a cached order cannot serve them); empty otherwise.
    heap: Vec<HeapSlot>,
    // running set (only maintained when `track_view`)
    run_tok: Vec<T>,
    run_views: Vec<RunningView>,
    wseq: u64,
    rseq: u64,
    grant_scratch: Vec<usize>,
    // instrumentation
    pub busy: TimeWeighted,
    pub queue_len: TimeWeighted,
    pub wait_stats: Summary,
    pub total_requests: u64,
    pub total_queued: u64,
    /// Running jobs evicted by a preemptive strategy.
    pub total_preempted: u64,
    /// Stale-entry rebuilds of the waiter index heap (SimMeter
    /// accounting).
    index_rebuilds: u64,
}

impl<T> Resource<T> {
    /// A FIFO resource (SimPy's default).
    pub fn new(name: impl Into<String>, capacity: usize) -> Self {
        Self::with_scheduler(name, capacity, Box::new(Fifo))
    }

    /// A resource driven by the given scheduling strategy. The resource
    /// owns the scheduler exclusively, so stateful strategies are
    /// per-resource and per-run.
    pub fn with_scheduler(
        name: impl Into<String>,
        capacity: usize,
        scheduler: Box<dyn Scheduler>,
    ) -> Self {
        assert!(capacity > 0, "resource capacity must be positive");
        let track_view = scheduler.needs_view();
        Resource {
            name: name.into(),
            capacity,
            offline: 0,
            in_use: 0,
            scheduler,
            track_view,
            waiter_tok: Vec::new(),
            waiter_views: Vec::new(),
            heap: Vec::new(),
            run_tok: Vec::new(),
            run_views: Vec::new(),
            wseq: 0,
            rseq: 0,
            grant_scratch: Vec::new(),
            busy: TimeWeighted::new(0.0, 0.0),
            queue_len: TimeWeighted::new(0.0, 0.0),
            wait_stats: Summary::new(),
            total_requests: 0,
            total_queued: 0,
            total_preempted: 0,
            index_rebuilds: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Slots currently offline after injected failures.
    pub fn offline(&self) -> usize {
        self.offline
    }

    /// Capacity available to the scheduler right now: nominal capacity
    /// minus failed slots.
    pub fn effective_capacity(&self) -> usize {
        self.capacity - self.offline
    }

    pub fn in_use(&self) -> usize {
        self.in_use
    }

    pub fn queued(&self) -> usize {
        self.waiter_views.len()
    }

    /// Name of the scheduling strategy driving this resource.
    pub fn scheduler_name(&self) -> &'static str {
        self.scheduler.name()
    }

    fn ctx(&self, t: SimTime, job: JobCtx) -> SchedCtx {
        SchedCtx {
            now: t,
            job,
            in_use: self.in_use,
            // strategies reason about what is schedulable, so they see
            // the effective capacity (identical to nominal without
            // failure injection)
            capacity: self.effective_capacity(),
            queued: self.waiter_views.len(),
        }
    }

    /// Enqueue a job: the scheduler assigns its ordering key.
    fn enqueue(&mut self, t: SimTime, token: T, job: JobCtx) {
        let ctx = self.ctx(t, job);
        let key = self.scheduler.queue_key(&ctx);
        debug_assert!(!key.is_nan(), "NaN waiter key from {}", self.scheduler.name());
        let seq = self.wseq;
        self.waiter_tok.push(token);
        self.waiter_views.push(WaiterView {
            job,
            key,
            enq_t: t,
            seq,
        });
        if !self.track_view {
            self.heap.push(HeapSlot {
                key: QueueKey { key, seq },
                slot: self.waiter_views.len() - 1,
            });
            let leaf = self.heap.len() - 1;
            heap4::sift_up(&mut self.heap, leaf, heap_less);
        }
        self.wseq += 1;
        self.total_queued += 1;
        self.queue_len.set(t, self.waiter_views.len() as f64);
    }

    // ---- waiter index heap (the !track_view grant fast path) ----

    /// Backing index-heap size including stale entries awaiting reap.
    /// Always 0 for re-decision (`needs_view`) schedulers. Exposed for
    /// the property tests and benches that pin the compaction bound.
    pub fn index_heap_len(&self) -> usize {
        self.heap.len()
    }

    /// Stale index-heap entries awaiting lazy reap. Bounded after every
    /// public operation at `max(index_heap_len / 2, 64)` — grants
    /// create staleness, `maybe_compact` re-establishes the bound.
    pub fn index_heap_stale(&self) -> usize {
        self.heap.len().saturating_sub(self.waiter_views.len())
    }

    /// Total stale-entry rebuilds of the waiter index heap so far.
    pub fn index_rebuilds(&self) -> u64 {
        self.index_rebuilds
    }

    /// True when `e` still names the waiter it was pushed for (seqs are
    /// unique per resource, so a slot match is exact).
    #[inline]
    fn heap_live(&self, e: &HeapSlot) -> bool {
        self.waiter_views
            .get(e.slot)
            .is_some_and(|w| w.seq == e.key.seq)
    }

    /// Reap stale entries off the top, then return the live minimum's
    /// array slot without removing its entry. `None` when no waiters.
    fn peek_min(&mut self) -> Option<usize> {
        loop {
            let e = *self.heap.first()?;
            if self.heap_live(&e) {
                return Some(e.slot);
            }
            self.heap_pop_top();
        }
    }

    /// Remove the top heap entry (caller has inspected it via
    /// [`Resource::peek_min`], so the heap is non-empty).
    fn heap_pop_top(&mut self) {
        heap4::pop_root(&mut self.heap, heap_less);
    }

    /// Pop the `QueueKey`-minimal live waiter's slot off the index heap.
    fn pop_min(&mut self) -> Option<usize> {
        let slot = self.peek_min()?;
        self.heap_pop_top();
        Some(slot)
    }

    /// After a `swap_remove` at array slot `i`: the former last waiter
    /// (if any) now occupies `i`, so its old heap entry is stale — push
    /// a fresh one. No-op for re-decision schedulers (no heap) and when
    /// `i` was the last slot.
    fn fix_moved_slot(&mut self, i: usize) {
        if self.track_view {
            return;
        }
        if let Some(w) = self.waiter_views.get(i) {
            let key = w.queue_key();
            self.heap.push(HeapSlot { key, slot: i });
            let leaf = self.heap.len() - 1;
            heap4::sift_up(&mut self.heap, leaf, heap_less);
        }
    }

    /// Rebuild the heap and re-check the stale bound. Called at the end
    /// of every grant-producing operation (never mid-grant, where
    /// granted-but-unremoved waiters would be re-indexed): when stale
    /// entries exceed half the backing heap, rebuild from the live
    /// arrays in O(n) — the calendar's bounded-tombstone rule.
    fn maybe_compact(&mut self) {
        let stale = self.index_heap_stale();
        if self.heap.len() > COMPACT_MIN && stale * 2 > self.heap.len() {
            self.index_rebuilds += 1;
            self.heap.clear();
            for (i, w) in self.waiter_views.iter().enumerate() {
                self.heap.push(HeapSlot {
                    key: w.queue_key(),
                    slot: i,
                });
            }
            heap4::heapify(&mut self.heap, heap_less);
        }
    }

    /// Start a job immediately: occupy its slots and (when tracked)
    /// record it in the running set.
    fn start_running(&mut self, t: SimTime, token: T, job: JobCtx) {
        self.in_use += job.slots as usize;
        debug_assert!(self.in_use <= self.capacity);
        if self.track_view {
            self.run_tok.push(token);
            self.run_views.push(RunningView {
                job,
                started_at: t,
                expected_done: t + job.expected_occupancy,
                seq: self.rseq,
            });
            self.rseq += 1;
        }
        self.busy.set(t, self.in_use as f64);
    }

    /// Take one slot offline (an injected failure). The caller is
    /// responsible for the blast radius: if the slot carried a running
    /// job, cancel its completion and re-queue it via
    /// [`Resource::release_all`] *after* this call, so the re-queue
    /// decision already sees the reduced effective capacity.
    pub fn fail_slot(&mut self) {
        debug_assert!(
            self.offline < self.capacity,
            "{}: every slot already offline",
            self.name
        );
        self.offline += 1;
    }

    /// Fraction of total slot-time busy over [0, t]. The denominator is
    /// the nominal capacity — offline slots still count as provisioned
    /// (failures *lower* reported utilization, they don't excuse it).
    pub fn utilization(&self, t: SimTime) -> f64 {
        if t <= 0.0 {
            return 0.0;
        }
        self.busy.integral_at(t) / (t * self.capacity as f64)
    }

    /// Time-averaged queue length over [0, t].
    pub fn avg_queue_len(&self, t: SimTime) -> f64 {
        self.queue_len.mean_at(t, 0.0)
    }
}

impl<T: Copy> Resource<T> {
    /// Request `job.slots` slots at time `t` for a job described by
    /// `job`. The scheduler decides admission; when the job cannot start
    /// a re-decision scheduler may backfill it into free capacity or
    /// preempt running work ([`AcquireResult::Preempted`]); otherwise it
    /// queues under the scheduler's ordering key.
    pub fn request(&mut self, t: SimTime, token: T, job: JobCtx) -> AcquireResult<T> {
        self.total_requests += 1;
        debug_assert!(
            job.slots >= 1 && job.slots as usize <= self.capacity,
            "job of {} slots can never fit {} ({} capacity)",
            job.slots,
            self.name,
            self.capacity
        );
        let ctx = self.ctx(t, job);
        let fits = self.in_use + job.slots as usize <= self.effective_capacity();
        // idle resources always admit (enforced here, not just documented):
        // with nothing running, nothing will ever be released to grant a
        // queued job, so a scheduler refusing at in_use == 0 would deadlock
        if fits && (self.in_use == 0 || self.scheduler.admit(&ctx)) {
            self.start_running(t, token, job);
            self.wait_stats.add(0.0);
            return AcquireResult::Acquired;
        }
        if self.track_view {
            let view = SchedView {
                now: t,
                free: self.effective_capacity().saturating_sub(self.in_use),
                capacity: self.effective_capacity(),
                waiters: &self.waiter_views,
                running: &self.run_views,
            };
            match self.scheduler.on_enqueue(&ctx, &view) {
                EnqueueAction::Queue => {}
                EnqueueAction::Admit => {
                    let admit_fits =
                        self.in_use + job.slots as usize <= self.effective_capacity();
                    debug_assert!(admit_fits, "{}: Admit for a job that does not fit", self.name);
                    if admit_fits {
                        self.start_running(t, token, job);
                        self.wait_stats.add(0.0);
                        return AcquireResult::Acquired;
                    }
                }
                EnqueueAction::Preempt { victim_seq } => {
                    if let Some(victim) = self.preempt(t, token, job, victim_seq) {
                        return AcquireResult::Preempted { victim };
                    }
                }
            }
        }
        self.enqueue(t, token, job);
        AcquireResult::Queued
    }

    /// Evict the running job with view-seq `victim_seq`, start `job` in
    /// its place, and re-queue the victim with its remaining service.
    /// Returns the victim token, or `None` when the decision is invalid
    /// (unknown victim, or the swap would not fit) — the job then queues.
    fn preempt(&mut self, t: SimTime, token: T, job: JobCtx, victim_seq: u64) -> Option<T> {
        let vi = self.run_views.iter().position(|r| r.seq == victim_seq)?;
        let v = self.run_views[vi];
        let swap_fits =
            self.effective_capacity() + v.job.slots as usize >= self.in_use + job.slots as usize;
        debug_assert!(swap_fits, "{}: preemption swap does not fit", self.name);
        if !swap_fits {
            return None;
        }
        let vtok = self.run_tok.swap_remove(vi);
        self.run_views.swap_remove(vi);
        self.in_use -= v.job.slots as usize;
        // the preemptor starts now; it never waited
        self.start_running(t, token, job);
        self.wait_stats.add(0.0);
        // the victim waits with its remaining service as the occupancy
        // (it resumes where it stopped); its queue position comes from
        // the scheduler's key like any other waiter
        let remaining = (v.expected_done - t).max(0.0);
        let vjob = JobCtx {
            expected_occupancy: remaining,
            ..v.job
        };
        self.enqueue(t, vtok, vjob);
        self.total_preempted += 1;
        Some(vtok)
    }

    /// Release one slot at time `t` — the unit-width convenience API
    /// (every job occupies one slot; re-decision schedulers must use
    /// [`Resource::release_all`], which identifies the releasing job).
    /// If waiters are queued, the scheduler's best `QueueKey` waiter is
    /// granted *immediately* — the slot never goes idle — and returned
    /// so the caller can schedule its continuation. The winner comes
    /// off the index heap in O(log n).
    pub fn release(&mut self, t: SimTime) -> Option<Granted<T>> {
        debug_assert!(self.in_use > 0, "release on idle resource {}", self.name);
        debug_assert!(
            !self.track_view,
            "{}: re-decision schedulers release via release_all",
            self.name
        );
        match self.pop_min() {
            Some(i) => {
                let g = self.take_waiter(t, i);
                self.maybe_compact();
                self.queue_len.set(t, self.waiter_views.len() as f64);
                self.wait_stats.add(g.waited);
                // in_use unchanged: slot transfers to the waiter
                Some(g)
            }
            None => {
                self.in_use -= 1;
                self.busy.set(t, self.in_use as f64);
                None
            }
        }
    }

    /// Release the `slots` occupied by `token` at time `t` and grant
    /// waiters per the scheduler's decision — possibly several when a
    /// wide job frees room for multiple narrow ones, possibly none when
    /// the discipline holds slots for a blocked head-of-queue. Grants
    /// are appended to `out` in grant order.
    pub fn release_all(&mut self, t: SimTime, token: &T, slots: u32, out: &mut Vec<Granted<T>>)
    where
        T: PartialEq,
    {
        debug_assert!(
            self.in_use >= slots as usize,
            "release of {slots} slots on resource {} with {} in use",
            self.name,
            self.in_use
        );
        let in_use_before = self.in_use;
        self.in_use -= slots as usize;
        if self.track_view {
            let pos = self.run_tok.iter().position(|rt| rt == token);
            debug_assert!(pos.is_some(), "{}: released token not running", self.name);
            if let Some(i) = pos {
                debug_assert_eq!(self.run_views[i].job.slots, slots);
                self.run_tok.swap_remove(i);
                self.run_views.swap_remove(i);
            }
        }
        let mut granted_any = false;
        if !self.waiter_views.is_empty() {
            let mut grants = std::mem::take(&mut self.grant_scratch);
            grants.clear();
            if self.track_view {
                let view = SchedView {
                    now: t,
                    free: self.effective_capacity().saturating_sub(self.in_use),
                    capacity: self.effective_capacity(),
                    waiters: &self.waiter_views,
                    running: &self.run_views,
                };
                self.scheduler.on_release(&view, &mut grants);
            } else {
                self.heap_grants(&mut grants);
            }
            granted_any = !grants.is_empty();
            self.apply_grants(t, &mut grants, out);
            self.grant_scratch = grants;
            self.maybe_compact();
        }
        // touch the monitors only when the tracked value changed: the
        // piecewise integral is partition-sensitive in the last float
        // bit, and pre-existing schedulers' digests must stay
        // byte-identical to the single-grant release path
        if self.in_use != in_use_before {
            self.busy.set(t, self.in_use as f64);
        }
        if granted_any {
            self.queue_len.set(t, self.waiter_views.len() as f64);
        }
    }

    /// Bring one failed slot back online at time `t` and grant waiters
    /// that now fit the restored effective capacity, appending them to
    /// `out` in grant order (the repaired slot never sits idle while
    /// work queues — the same invariant release holds).
    pub fn repair_slot(&mut self, t: SimTime, out: &mut Vec<Granted<T>>) {
        debug_assert!(self.offline > 0, "{}: repair with no slot offline", self.name);
        self.offline -= 1;
        let in_use_before = self.in_use;
        let mut granted_any = false;
        if !self.waiter_views.is_empty() {
            let mut grants = std::mem::take(&mut self.grant_scratch);
            grants.clear();
            if self.track_view {
                let view = SchedView {
                    now: t,
                    free: self.effective_capacity().saturating_sub(self.in_use),
                    capacity: self.effective_capacity(),
                    waiters: &self.waiter_views,
                    running: &self.run_views,
                };
                self.scheduler.on_release(&view, &mut grants);
            } else {
                self.heap_grants(&mut grants);
            }
            granted_any = !grants.is_empty();
            self.apply_grants(t, &mut grants, out);
            self.grant_scratch = grants;
            self.maybe_compact();
        }
        if self.in_use != in_use_before {
            self.busy.set(t, self.in_use as f64);
        }
        if granted_any {
            self.queue_len.set(t, self.waiter_views.len() as f64);
        }
    }

    /// Validate and apply a grant selection: occupy slots, record stats,
    /// and remove the granted waiters. `grants` is consumed (re-sorted
    /// in place for the removal pass — its order is scratch afterward).
    fn apply_grants(&mut self, t: SimTime, grants: &mut Vec<usize>, out: &mut Vec<Granted<T>>) {
        let mut free = self.effective_capacity().saturating_sub(self.in_use);
        for (n, &i) in grants.iter().enumerate() {
            assert!(
                i < self.waiter_views.len() && !grants[..n].contains(&i),
                "{}: scheduler {} granted an invalid waiter index",
                self.name,
                self.scheduler.name()
            );
            let w = self.waiter_views[i];
            assert!(
                w.job.slots as usize <= free,
                "{}: scheduler {} granted a job that does not fit",
                self.name,
                self.scheduler.name()
            );
            free -= w.job.slots as usize;
            let g = Granted {
                token: self.waiter_tok[i],
                waited: t - w.enq_t,
            };
            self.wait_stats.add(g.waited);
            self.in_use += w.job.slots as usize;
            if self.track_view {
                self.run_tok.push(self.waiter_tok[i]);
                self.run_views.push(RunningView {
                    job: w.job,
                    started_at: t,
                    expected_done: t + w.job.expected_occupancy,
                    seq: self.rseq,
                });
                self.rseq += 1;
            }
            out.push(g);
        }
        // remove granted waiters, highest index first so the remaining
        // indices stay valid under swap_remove (in place: the event path
        // stays allocation-free); each removal re-indexes the waiter it
        // moved
        grants.sort_unstable_by(|a, b| b.cmp(a));
        for &i in grants.iter() {
            self.waiter_tok.swap_remove(i);
            self.waiter_views.swap_remove(i);
            self.fix_moved_slot(i);
        }
    }

    /// The built-in grant rule on the index heap: repeatedly take the
    /// `QueueKey`-minimal live waiter while it fits the free slots,
    /// stopping at the first minimum that does not fit (head-of-line
    /// blocking). Byte-identical to the linear scan of
    /// [`default_grants`](super::sched::default_grants) — both are the
    /// strict `QueueKey` order — in O(g log n) instead of O(g·n).
    /// Granted waiters stay in the arrays (their heap entries are
    /// popped here); `apply_grants` removes them.
    fn heap_grants(&mut self, grants: &mut Vec<usize>) {
        let mut free = self.effective_capacity().saturating_sub(self.in_use);
        while free > 0 {
            let Some(i) = self.peek_min() else { break };
            let slots = self.waiter_views[i].job.slots as usize;
            if slots > free {
                break;
            }
            free -= slots;
            self.heap_pop_top();
            grants.push(i);
        }
    }

    /// Remove waiter `i` (its heap entry was already popped by the
    /// caller) and re-index the waiter `swap_remove` moved into its
    /// slot.
    fn take_waiter(&mut self, t: SimTime, i: usize) -> Granted<T> {
        let w = self.waiter_views.swap_remove(i);
        let token = self.waiter_tok.swap_remove(i);
        self.fix_moved_slot(i);
        Granted {
            token,
            waited: t - w.enq_t,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::sched::{EasyBackfill, PreemptivePriority, Priority, ShortestJobFirst};

    fn job(key: f64) -> JobCtx {
        // tests drive ordering through a single knob: use the same value
        // for occupancy and priority so either discipline sees it
        JobCtx::new(key, key, 0.0)
    }

    fn release_one<'a>(
        r: &mut Resource<&'a str>,
        t: SimTime,
        token: &'a str,
        slots: u32,
    ) -> Vec<&'a str> {
        let mut out = Vec::new();
        r.release_all(t, &token, slots, &mut out);
        out.iter().map(|g| g.token).collect()
    }

    #[test]
    fn acquire_until_capacity_then_queue() {
        let mut r: Resource<u32> = Resource::new("train", 2);
        assert_eq!(r.request(0.0, 1, job(0.0)), AcquireResult::Acquired);
        assert_eq!(r.request(0.0, 2, job(0.0)), AcquireResult::Acquired);
        assert_eq!(r.request(1.0, 3, job(0.0)), AcquireResult::Queued);
        assert_eq!(r.in_use(), 2);
        assert_eq!(r.queued(), 1);
        assert_eq!(r.scheduler_name(), "fifo");
    }

    #[test]
    fn release_grants_fifo_order() {
        let mut r: Resource<u32> = Resource::new("train", 1);
        r.request(0.0, 1, job(0.0));
        r.request(1.0, 2, job(0.0));
        r.request(2.0, 3, job(0.0));
        let g = r.release(5.0).unwrap();
        assert_eq!(g.token, 2);
        assert_eq!(g.waited, 4.0);
        let g = r.release(9.0).unwrap();
        assert_eq!(g.token, 3);
        assert_eq!(g.waited, 7.0);
        assert!(r.release(10.0).is_none());
        assert_eq!(r.in_use(), 0);
    }

    #[test]
    fn release_all_matches_release_for_unit_jobs() {
        let run = |wide: bool| {
            let mut r: Resource<u32> = Resource::new("train", 2);
            r.request(0.0, 1, job(0.0));
            r.request(0.0, 2, job(0.0));
            r.request(1.0, 3, job(0.5));
            r.request(2.0, 4, job(0.25));
            let mut order = Vec::new();
            for t in [3.0, 4.0, 5.0, 6.0] {
                if wide {
                    let mut out = Vec::new();
                    r.release_all(t, &0, 1, &mut out);
                    order.extend(out.iter().map(|g| g.token));
                } else if let Some(g) = r.release(t) {
                    order.push(g.token);
                }
            }
            (order, r.wait_stats.sum, r.utilization(6.0))
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn priority_scheduler_orders_by_class() {
        let mut r: Resource<&str> = Resource::with_scheduler("t", 1, Box::new(Priority));
        r.request(0.0, "running", job(0.0));
        r.request(1.0, "low", job(10.0));
        r.request(2.0, "high", job(1.0));
        r.request(3.0, "mid", job(5.0));
        assert_eq!(r.release(4.0).unwrap().token, "high");
        assert_eq!(r.release(5.0).unwrap().token, "mid");
        assert_eq!(r.release(6.0).unwrap().token, "low");
    }

    #[test]
    fn priority_ties_fall_back_to_fifo() {
        let mut r: Resource<u32> = Resource::with_scheduler("t", 1, Box::new(Priority));
        r.request(0.0, 0, job(0.0));
        for i in 1..=5 {
            r.request(i as f64, i, job(7.0));
        }
        for i in 1..=5 {
            assert_eq!(r.release(10.0 + i as f64).unwrap().token, i);
        }
    }

    #[test]
    fn sjf_grants_shortest_expected_occupancy() {
        let mut r: Resource<&str> = Resource::with_scheduler("t", 1, Box::new(ShortestJobFirst));
        r.request(0.0, "running", job(0.0));
        r.request(1.0, "long", JobCtx::new(500.0, 1.0, 1.0));
        r.request(2.0, "short", JobCtx::new(5.0, 9.0, 2.0));
        assert_eq!(r.release(3.0).unwrap().token, "short");
        assert_eq!(r.release(4.0).unwrap().token, "long");
    }

    #[test]
    fn idle_resource_admits_even_if_scheduler_refuses() {
        // anti-deadlock rule is enforced by the mechanism, not the policy
        struct RefuseAll;
        impl Scheduler for RefuseAll {
            fn name(&self) -> &'static str {
                "refuse_all"
            }
            fn admit(&mut self, _ctx: &SchedCtx) -> bool {
                false
            }
            fn queue_key(&mut self, _ctx: &SchedCtx) -> f64 {
                0.0
            }
        }
        let mut r: Resource<u32> = Resource::with_scheduler("t", 2, Box::new(RefuseAll));
        assert_eq!(r.request(0.0, 1, job(0.0)), AcquireResult::Acquired);
        // non-idle: the policy's refusal now applies
        assert_eq!(r.request(1.0, 2, job(0.0)), AcquireResult::Queued);
        // the queued job is still granted on release, so no job is lost
        assert_eq!(r.release(2.0).unwrap().token, 2);
    }

    #[test]
    fn admission_policy_can_reserve_headroom() {
        // a scheduler that keeps the last slot free for class <= 1
        struct Headroom;
        impl Scheduler for Headroom {
            fn name(&self) -> &'static str {
                "headroom"
            }
            fn admit(&mut self, ctx: &SchedCtx) -> bool {
                ctx.job.priority <= 1.0 || ctx.in_use + 1 < ctx.capacity
            }
            fn queue_key(&mut self, ctx: &SchedCtx) -> f64 {
                ctx.job.priority
            }
        }
        let mut r: Resource<&str> = Resource::with_scheduler("t", 2, Box::new(Headroom));
        assert_eq!(r.request(0.0, "bulk1", job(5.0)), AcquireResult::Acquired);
        // second slot is reserved: bulk work queues even though it's free
        assert_eq!(r.request(1.0, "bulk2", job(5.0)), AcquireResult::Queued);
        assert_eq!(r.in_use(), 1);
        // but class-1 work takes it immediately
        assert_eq!(r.request(2.0, "vip", job(1.0)), AcquireResult::Acquired);
        assert_eq!(r.in_use(), 2);
        // a release hands the freed slot to the best waiter as usual
        assert_eq!(r.release(3.0).unwrap().token, "bulk2");
    }

    #[test]
    fn utilization_and_queue_stats() {
        let mut r: Resource<u32> = Resource::new("c", 2);
        r.request(0.0, 1, job(0.0)); // busy 1
        r.request(10.0, 2, job(0.0)); // busy 2
        r.release(20.0); // busy 1
        r.release(30.0); // busy 0
        // busy integral: 1*10 + 2*10 + 1*10 = 40 over 30s * 2 slots
        assert!((r.utilization(30.0) - 40.0 / 60.0).abs() < 1e-12);
    }

    #[test]
    fn slot_never_idle_when_queue_nonempty() {
        let mut r: Resource<u32> = Resource::new("c", 1);
        r.request(0.0, 1, job(0.0));
        r.request(0.0, 2, job(0.0));
        let g = r.release(3.0).unwrap();
        assert_eq!(g.token, 2);
        assert_eq!(r.in_use(), 1); // transferred, not freed
    }

    #[test]
    #[should_panic]
    fn zero_capacity_rejected() {
        let _: Resource<u32> = Resource::new("bad", 0);
    }

    // ---- waiter index heap ----

    #[test]
    fn deep_queue_grants_in_exact_key_seq_order() {
        // the heap path must reproduce the strict (key, seq) order at
        // depth — a small LCG drives repeated keys so ties exercise the
        // seq tie-break
        let mut r: Resource<u32> = Resource::with_scheduler("deep", 1, Box::new(Priority));
        r.request(0.0, u32::MAX, job(0.0)); // occupy the slot
        let mut x = 0x9e37_79b9u64;
        let mut expect: Vec<(f64, u64, u32)> = Vec::new();
        for i in 0..5000u32 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(99);
            let pri = (x >> 33) % 16; // many ties
            r.request(i as f64, i, JobCtx::new(1.0, pri as f64, i as f64));
            expect.push((pri as f64, i as u64, i));
        }
        expect.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        for (n, &(_, _, tok)) in expect.iter().enumerate() {
            let g = r.release(10_000.0 + n as f64).unwrap();
            assert_eq!(g.token, tok, "grant {n} diverged from (key, seq) order");
        }
        assert_eq!(r.queued(), 0);
        // the drained queue may leave a few stale entries (reaped lazily),
        // but never more than the compaction floor
        assert!(r.index_heap_len() <= 64, "{} stale", r.index_heap_len());
    }

    #[test]
    fn index_heap_stale_entries_stay_bounded() {
        // mixed-width churn forces swap_remove moves (stale entries);
        // the compaction bound must hold after every public operation
        let bound_ok = |r: &Resource<u32>| {
            r.index_heap_stale() <= (r.index_heap_len() / 2).max(64)
        };
        let mut r: Resource<u32> = Resource::new("churn", 3);
        let mut x = 7u64;
        let mut t = 0.0;
        let mut widths = vec![0u32; 4000];
        let mut running: Vec<u32> = Vec::new();
        for i in 0..4000u32 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(99);
            t += 1.0;
            if x % 5 < 3 || running.is_empty() {
                let slots = 1 + (x >> 40) as u32 % 2;
                widths[i as usize] = slots;
                let job = JobCtx::new(5.0, 1.0, t).with_slots(slots);
                if r.request(t, i, job) == AcquireResult::Acquired {
                    running.push(i);
                }
            } else {
                let tok = running.remove(((x >> 20) as usize) % running.len());
                let mut out = Vec::new();
                r.release_all(t, &tok, widths[tok as usize], &mut out);
                running.extend(out.iter().map(|g| g.token));
            }
            assert!(
                bound_ok(&r),
                "op {i}: stale {} of {} unbounded",
                r.index_heap_stale(),
                r.index_heap_len()
            );
        }
    }

    #[test]
    fn re_decision_schedulers_never_build_the_heap() {
        let mut r: Resource<&str> =
            Resource::with_scheduler("t", 1, Box::new(EasyBackfill::default()));
        r.request(0.0, "run", job(0.0));
        r.request(1.0, "w1", job(0.0));
        r.request(2.0, "w2", job(0.0));
        assert_eq!(r.queued(), 2);
        assert_eq!(r.index_heap_len(), 0, "view schedulers use the Vec path");
        assert_eq!(r.index_heap_stale(), 0);
    }

    // ---- multi-slot jobs ----

    #[test]
    fn wide_jobs_occupy_multiple_slots() {
        let mut r: Resource<&str> = Resource::new("t", 4);
        let wide = JobCtx::new(10.0, 1.0, 0.0).with_slots(3);
        assert_eq!(r.request(0.0, "wide", wide), AcquireResult::Acquired);
        assert_eq!(r.in_use(), 3);
        assert_eq!(r.request(1.0, "unit", job(0.0)), AcquireResult::Acquired);
        assert_eq!(r.in_use(), 4);
        // queue drains on the wide release: both slots go out again
        let wide2 = JobCtx::new(5.0, 1.0, 0.0).with_slots(2);
        assert_eq!(r.request(2.0, "w2", wide2), AcquireResult::Queued);
        assert_eq!(r.request(3.0, "u2", job(0.0)), AcquireResult::Queued);
        let granted = release_one(&mut r, 9.0, "wide", 3);
        assert_eq!(granted, vec!["w2", "u2"]);
        assert_eq!(r.in_use(), 4);
    }

    #[test]
    fn fifo_blocks_head_of_line_and_never_overtakes() {
        // strict FCFS: a free slot does not let later work overtake a
        // blocked wide head — neither at release nor at request time
        let mut r: Resource<&str> = Resource::new("t", 3);
        r.request(0.0, "a", job(0.0));
        r.request(0.0, "b", job(0.0));
        r.request(0.0, "c", job(0.0));
        let wide = JobCtx::new(10.0, 1.0, 0.0).with_slots(2);
        assert_eq!(r.request(1.0, "wide", wide), AcquireResult::Queued);
        // one slot frees: the wide head does not fit, nothing granted
        assert_eq!(release_one(&mut r, 2.0, "a", 1), Vec::<&str>::new());
        assert_eq!(r.in_use(), 2);
        // an arriving unit job may not grab the free slot past the head
        assert_eq!(r.request(3.0, "late", job(0.0)), AcquireResult::Queued);
        // second slot frees: the head fits and takes both
        assert_eq!(release_one(&mut r, 4.0, "b", 1), vec!["wide"]);
        assert_eq!(r.in_use(), 3);
        assert_eq!(release_one(&mut r, 5.0, "c", 1), vec!["late"]);
    }

    // ---- preemption ----

    #[test]
    fn preemptive_priority_evicts_and_requeues_victim() {
        let mut r: Resource<&str> =
            Resource::with_scheduler("t", 2, Box::new(PreemptivePriority::default()));
        r.request(0.0, "bulk9", JobCtx::new(100.0, 9.0, 0.0));
        r.request(0.0, "bulk5", JobCtx::new(100.0, 5.0, 0.0));
        // a class-1 arrival evicts the class-9 job, not the class-5 one
        match r.request(10.0, "vip", JobCtx::new(20.0, 1.0, 10.0)) {
            AcquireResult::Preempted { victim } => assert_eq!(victim, "bulk9"),
            other => panic!("expected preemption, got {other:?}"),
        }
        assert_eq!(r.in_use(), 2);
        assert_eq!(r.queued(), 1);
        assert_eq!(r.total_preempted, 1);
        // the victim resumes with its remaining 90s when a slot frees
        let granted = release_one(&mut r, 30.0, "vip", 1);
        assert_eq!(granted, vec!["bulk9"]);
        assert_eq!(granted.len(), 1);
    }

    #[test]
    fn preemption_respects_class_gap_and_never_thrashes_same_class() {
        let mut r: Resource<&str> =
            Resource::with_scheduler("t", 1, Box::new(PreemptivePriority::default()));
        r.request(0.0, "a", JobCtx::new(100.0, 4.0, 0.0));
        // same class queues instead of evicting
        assert_eq!(
            r.request(1.0, "b", JobCtx::new(10.0, 4.0, 1.0)),
            AcquireResult::Queued
        );
        // worse class queues
        assert_eq!(
            r.request(2.0, "c", JobCtx::new(10.0, 9.0, 2.0)),
            AcquireResult::Queued
        );
        assert_eq!(r.total_preempted, 0);
    }

    #[test]
    fn preempted_victim_keeps_remaining_service_not_full() {
        let mut r: Resource<&str> =
            Resource::with_scheduler("t", 1, Box::new(PreemptivePriority::default()));
        r.request(0.0, "victim", JobCtx::new(100.0, 9.0, 0.0));
        // preempt at t=60: 40s of service remain
        match r.request(60.0, "vip", JobCtx::new(10.0, 0.0, 60.0)) {
            AcquireResult::Preempted { victim } => assert_eq!(victim, "victim"),
            other => panic!("{other:?}"),
        }
        let mut out = Vec::new();
        r.release_all(70.0, &"vip", 1, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].token, "victim");
        assert_eq!(out[0].waited, 10.0, "wait counts from preemption time");
        // the running view carries the remaining 40s, not the full 100
        let mut out2 = Vec::new();
        r.release_all(110.0, &"victim", 1, &mut out2);
        assert!(out2.is_empty());
        assert_eq!(r.in_use(), 0);
    }

    // ---- failure injection ----

    #[test]
    fn failed_slot_shrinks_effective_capacity_until_repair() {
        let mut r: Resource<u32> = Resource::new("t", 2);
        r.fail_slot();
        assert_eq!(r.capacity(), 2);
        assert_eq!(r.offline(), 1);
        assert_eq!(r.effective_capacity(), 1);
        // only one slot is schedulable now
        assert_eq!(r.request(0.0, 1, job(0.0)), AcquireResult::Acquired);
        assert_eq!(r.request(1.0, 2, job(0.0)), AcquireResult::Queued);
        // the repair grants the waiter straight into the restored slot
        let mut out = Vec::new();
        r.repair_slot(5.0, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].token, 2);
        assert_eq!(out[0].waited, 4.0);
        assert_eq!(r.in_use(), 2);
        assert_eq!(r.offline(), 0);
    }

    #[test]
    fn idle_repair_grants_nothing() {
        let mut r: Resource<u32> = Resource::new("t", 3);
        r.fail_slot();
        let mut out = Vec::new();
        r.repair_slot(1.0, &mut out);
        assert!(out.is_empty());
        assert_eq!(r.effective_capacity(), 3);
    }

    #[test]
    fn failure_blast_radius_requeues_released_victim_under_reduced_capacity() {
        // the simulation's failure flow: fail the slot first, then
        // release the victim's slots and re-request — the re-queue
        // decision must see the reduced capacity and hold the victim
        let mut r: Resource<u32> = Resource::new("t", 1);
        assert_eq!(r.request(0.0, 7, job(0.0)), AcquireResult::Acquired);
        r.fail_slot();
        let mut out = Vec::new();
        r.release_all(5.0, &7, 1, &mut out);
        assert!(out.is_empty(), "no capacity left: nothing may start");
        assert_eq!(r.request(5.0, 7, job(0.0)), AcquireResult::Queued);
        // repair resumes the victim
        r.repair_slot(25.0, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].token, 7);
    }

    #[test]
    fn repair_grants_respect_scheduler_order() {
        let mut r: Resource<&str> = Resource::with_scheduler("t", 2, Box::new(Priority));
        r.request(0.0, "run", job(3.0));
        r.fail_slot();
        r.request(1.0, "low", job(9.0));
        r.request(2.0, "high", job(1.0));
        let mut out = Vec::new();
        r.repair_slot(3.0, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].token, "high");
    }

    // ---- EASY backfill ----

    #[test]
    fn easy_backfill_grants_window_fitting_job_past_blocked_head() {
        let mut r: Resource<&str> =
            Resource::with_scheduler("t", 3, Box::new(EasyBackfill::default()));
        // two running: one frees 2 slots at t=50, one runs to t=100
        r.request(0.0, "w2", JobCtx::new(50.0, 5.0, 0.0).with_slots(2));
        r.request(0.0, "long", JobCtx::new(100.0, 5.0, 0.0));
        // head needs 2 slots -> must wait for w2 at t=50
        assert_eq!(
            r.request(1.0, "head", JobCtx::new(30.0, 5.0, 1.0).with_slots(2)),
            AcquireResult::Queued
        );
        // a short unit job arrives: fits the window (10 + 35 <= 50)
        assert_eq!(
            r.request(10.0, "short", JobCtx::new(35.0, 5.0, 10.0)),
            AcquireResult::Queued,
            "no free slot yet, so it queues"
        );
        // long unit job that would overrun the reservation: also queued
        assert_eq!(
            r.request(11.0, "over", JobCtx::new(200.0, 5.0, 11.0)),
            AcquireResult::Queued
        );
        // nothing free yet; now w2 finishes at 50: head takes its 2 slots
        let granted = release_one(&mut r, 50.0, "w2", 2);
        assert_eq!(granted, vec!["head"]);
        assert_eq!(r.in_use(), 3);
    }

    #[test]
    fn easy_backfill_arrival_backfills_into_free_slot() {
        let mut r: Resource<&str> =
            Resource::with_scheduler("t", 3, Box::new(EasyBackfill::default()));
        r.request(0.0, "w2", JobCtx::new(50.0, 5.0, 0.0).with_slots(2));
        r.request(0.0, "u", JobCtx::new(20.0, 5.0, 0.0));
        assert_eq!(
            r.request(1.0, "head", JobCtx::new(30.0, 5.0, 1.0).with_slots(2)),
            AcquireResult::Queued
        );
        // u releases at 20: head (needs 2) still blocked, 1 slot free
        assert_eq!(release_one(&mut r, 20.0, "u", 1), Vec::<&str>::new());
        assert_eq!(r.in_use(), 2);
        // reservation: w2 frees 2 slots at t=50 -> R = 50. A 25s arrival
        // fits (20 + 25 <= 50) and backfills immediately...
        assert_eq!(
            r.request(20.0, "fill", JobCtx::new(25.0, 5.0, 20.0)),
            AcquireResult::Acquired
        );
        // ...while a 40s arrival would overrun R and queues
        assert_eq!(release_one(&mut r, 45.0, "fill", 1), Vec::<&str>::new());
        assert_eq!(
            r.request(45.5, "over", JobCtx::new(40.0, 5.0, 45.5)),
            AcquireResult::Queued
        );
        // head granted at its reservation; the freed room also lets the
        // queued job behind it start (plain FCFS once the head fits)
        assert_eq!(release_one(&mut r, 50.0, "w2", 2), vec!["head", "over"]);
    }

    #[test]
    fn easy_backfill_release_backfills_window_fitting_waiter() {
        let mut r: Resource<&str> =
            Resource::with_scheduler("t", 3, Box::new(EasyBackfill::default()));
        r.request(0.0, "w2", JobCtx::new(50.0, 5.0, 0.0).with_slots(2));
        r.request(0.0, "u", JobCtx::new(20.0, 5.0, 0.0));
        assert_eq!(
            r.request(1.0, "head", JobCtx::new(30.0, 5.0, 1.0).with_slots(2)),
            AcquireResult::Queued
        );
        // two waiters behind the head: one fits the window, one overruns
        assert_eq!(
            r.request(2.0, "fit", JobCtx::new(25.0, 5.0, 2.0)),
            AcquireResult::Queued
        );
        assert_eq!(
            r.request(3.0, "over", JobCtx::new(200.0, 5.0, 3.0)),
            AcquireResult::Queued
        );
        // u releases at 20: head blocked (R=50); "fit" backfills, "over"
        // stays behind the reservation
        assert_eq!(release_one(&mut r, 20.0, "u", 1), vec!["fit"]);
        assert_eq!(release_one(&mut r, 45.0, "fit", 1), Vec::<&str>::new());
        // at the reservation the head starts, and FCFS resumes for the
        // remaining waiter in the space left over
        assert_eq!(release_one(&mut r, 50.0, "w2", 2), vec!["head", "over"]);
        assert_eq!(r.queued(), 0);
    }
}
