//! Shared resources with job capacity and wait queues.
//!
//! Mirrors SimPy's `Resource` (the paper models every compute cluster as
//! one, section V-B a): a congestion point with a fixed number of job
//! slots. Requests beyond capacity queue up; on release the next waiter
//! is granted according to the resource's [`Scheduler`].
//!
//! Scheduling beyond FIFO is the hook for the paper's envisioned
//! pipeline schedulers (Fig 4): every admission and waiter-ordering
//! decision is delegated to a pluggable [`Scheduler`] strategy (see
//! [`super::sched`]), selectable by name from experiment config.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::monitor::TimeWeighted;
use super::sched::{Fifo, JobCtx, SchedCtx, Scheduler};
use super::SimTime;
use crate::stats::Summary;

struct Waiter<T> {
    token: T,
    key: f64,
    enq_t: SimTime,
    seq: u64,
}

impl<T> PartialEq for Waiter<T> {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key && self.seq == other.seq
    }
}
impl<T> Eq for Waiter<T> {}
impl<T> PartialOrd for Waiter<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Waiter<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap on (key, seq) via reversal; total_cmp keeps the hot
        // comparator branch-free (NaN keys are rejected at `request`)
        other
            .key
            .total_cmp(&self.key)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Result of a resource request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AcquireResult {
    /// A slot was free; the job may start immediately.
    Acquired,
    /// All slots busy (or admission deferred); the token was enqueued and
    /// will be returned by a future `release` call.
    Queued,
}

/// A granted waiter returned by [`Resource::release`].
#[derive(Clone, Copy, Debug)]
pub struct Granted<T> {
    pub token: T,
    /// How long the job waited in queue.
    pub waited: SimTime,
}

/// A capacity-limited shared resource with queueing and instrumentation.
pub struct Resource<T> {
    pub name: String,
    capacity: usize,
    in_use: usize,
    scheduler: Box<dyn Scheduler>,
    queue: BinaryHeap<Waiter<T>>,
    seq: u64,
    // instrumentation
    pub busy: TimeWeighted,
    pub queue_len: TimeWeighted,
    pub wait_stats: Summary,
    pub total_requests: u64,
    pub total_queued: u64,
}

impl<T> Resource<T> {
    /// A FIFO resource (SimPy's default).
    pub fn new(name: impl Into<String>, capacity: usize) -> Self {
        Self::with_scheduler(name, capacity, Box::new(Fifo))
    }

    /// A resource driven by the given scheduling strategy. The resource
    /// owns the scheduler exclusively, so stateful strategies are
    /// per-resource and per-run.
    pub fn with_scheduler(
        name: impl Into<String>,
        capacity: usize,
        scheduler: Box<dyn Scheduler>,
    ) -> Self {
        assert!(capacity > 0, "resource capacity must be positive");
        Resource {
            name: name.into(),
            capacity,
            in_use: 0,
            scheduler,
            queue: BinaryHeap::new(),
            seq: 0,
            busy: TimeWeighted::new(0.0, 0.0),
            queue_len: TimeWeighted::new(0.0, 0.0),
            wait_stats: Summary::new(),
            total_requests: 0,
            total_queued: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn in_use(&self) -> usize {
        self.in_use
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Name of the scheduling strategy driving this resource.
    pub fn scheduler_name(&self) -> &'static str {
        self.scheduler.name()
    }

    /// Request one slot at time `t` for a job described by `job`. The
    /// scheduler decides admission (when a slot is free) and, if the job
    /// must queue, its ordering key.
    pub fn request(&mut self, t: SimTime, token: T, job: JobCtx) -> AcquireResult {
        self.total_requests += 1;
        let ctx = SchedCtx {
            now: t,
            job,
            in_use: self.in_use,
            capacity: self.capacity,
            queued: self.queue.len(),
        };
        // idle resources always admit (enforced here, not just documented):
        // with nothing running, nothing will ever be released to grant a
        // queued job, so a scheduler refusing at in_use == 0 would deadlock
        if self.in_use < self.capacity && (self.in_use == 0 || self.scheduler.admit(&ctx)) {
            self.in_use += 1;
            self.busy.set(t, self.in_use as f64);
            self.wait_stats.add(0.0);
            AcquireResult::Acquired
        } else {
            let key = self.scheduler.queue_key(&ctx);
            debug_assert!(!key.is_nan(), "NaN waiter key from {}", self.scheduler.name());
            self.queue.push(Waiter {
                token,
                key,
                enq_t: t,
                seq: self.seq,
            });
            self.seq += 1;
            self.total_queued += 1;
            self.queue_len.set(t, self.queue.len() as f64);
            AcquireResult::Queued
        }
    }

    /// Release one slot at time `t`. If waiters are queued, the next one
    /// (per the scheduler's ordering) is granted *immediately* — the slot
    /// never goes idle — and returned so the caller can schedule its
    /// continuation.
    pub fn release(&mut self, t: SimTime) -> Option<Granted<T>> {
        debug_assert!(self.in_use > 0, "release on idle resource {}", self.name);
        if let Some(w) = self.queue.pop() {
            self.queue_len.set(t, self.queue.len() as f64);
            let waited = t - w.enq_t;
            self.wait_stats.add(waited);
            // in_use unchanged: slot transfers to the waiter
            Some(Granted {
                token: w.token,
                waited,
            })
        } else {
            self.in_use -= 1;
            self.busy.set(t, self.in_use as f64);
            None
        }
    }

    /// Fraction of total slot-time busy over [0, t].
    pub fn utilization(&self, t: SimTime) -> f64 {
        if t <= 0.0 {
            return 0.0;
        }
        self.busy.integral_at(t) / (t * self.capacity as f64)
    }

    /// Time-averaged queue length over [0, t].
    pub fn avg_queue_len(&self, t: SimTime) -> f64 {
        self.queue_len.mean_at(t, 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::sched::{Priority, ShortestJobFirst};

    fn job(key: f64) -> JobCtx {
        // tests drive ordering through a single knob: use the same value
        // for occupancy and priority so either discipline sees it
        JobCtx::new(key, key, 0.0)
    }

    #[test]
    fn acquire_until_capacity_then_queue() {
        let mut r: Resource<u32> = Resource::new("train", 2);
        assert_eq!(r.request(0.0, 1, job(0.0)), AcquireResult::Acquired);
        assert_eq!(r.request(0.0, 2, job(0.0)), AcquireResult::Acquired);
        assert_eq!(r.request(1.0, 3, job(0.0)), AcquireResult::Queued);
        assert_eq!(r.in_use(), 2);
        assert_eq!(r.queued(), 1);
        assert_eq!(r.scheduler_name(), "fifo");
    }

    #[test]
    fn release_grants_fifo_order() {
        let mut r: Resource<u32> = Resource::new("train", 1);
        r.request(0.0, 1, job(0.0));
        r.request(1.0, 2, job(0.0));
        r.request(2.0, 3, job(0.0));
        let g = r.release(5.0).unwrap();
        assert_eq!(g.token, 2);
        assert_eq!(g.waited, 4.0);
        let g = r.release(9.0).unwrap();
        assert_eq!(g.token, 3);
        assert_eq!(g.waited, 7.0);
        assert!(r.release(10.0).is_none());
        assert_eq!(r.in_use(), 0);
    }

    #[test]
    fn priority_scheduler_orders_by_class() {
        let mut r: Resource<&str> = Resource::with_scheduler("t", 1, Box::new(Priority));
        r.request(0.0, "running", job(0.0));
        r.request(1.0, "low", job(10.0));
        r.request(2.0, "high", job(1.0));
        r.request(3.0, "mid", job(5.0));
        assert_eq!(r.release(4.0).unwrap().token, "high");
        assert_eq!(r.release(5.0).unwrap().token, "mid");
        assert_eq!(r.release(6.0).unwrap().token, "low");
    }

    #[test]
    fn priority_ties_fall_back_to_fifo() {
        let mut r: Resource<u32> = Resource::with_scheduler("t", 1, Box::new(Priority));
        r.request(0.0, 0, job(0.0));
        for i in 1..=5 {
            r.request(i as f64, i, job(7.0));
        }
        for i in 1..=5 {
            assert_eq!(r.release(10.0 + i as f64).unwrap().token, i);
        }
    }

    #[test]
    fn sjf_grants_shortest_expected_occupancy() {
        let mut r: Resource<&str> = Resource::with_scheduler("t", 1, Box::new(ShortestJobFirst));
        r.request(0.0, "running", job(0.0));
        r.request(1.0, "long", JobCtx::new(500.0, 1.0, 1.0));
        r.request(2.0, "short", JobCtx::new(5.0, 9.0, 2.0));
        assert_eq!(r.release(3.0).unwrap().token, "short");
        assert_eq!(r.release(4.0).unwrap().token, "long");
    }

    #[test]
    fn idle_resource_admits_even_if_scheduler_refuses() {
        // anti-deadlock rule is enforced by the mechanism, not the policy
        struct RefuseAll;
        impl Scheduler for RefuseAll {
            fn name(&self) -> &'static str {
                "refuse_all"
            }
            fn admit(&mut self, _ctx: &SchedCtx) -> bool {
                false
            }
            fn queue_key(&mut self, _ctx: &SchedCtx) -> f64 {
                0.0
            }
        }
        let mut r: Resource<u32> = Resource::with_scheduler("t", 2, Box::new(RefuseAll));
        assert_eq!(r.request(0.0, 1, job(0.0)), AcquireResult::Acquired);
        // non-idle: the policy's refusal now applies
        assert_eq!(r.request(1.0, 2, job(0.0)), AcquireResult::Queued);
        // the queued job is still granted on release, so no job is lost
        assert_eq!(r.release(2.0).unwrap().token, 2);
    }

    #[test]
    fn admission_policy_can_reserve_headroom() {
        // a scheduler that keeps the last slot free for class <= 1
        struct Headroom;
        impl Scheduler for Headroom {
            fn name(&self) -> &'static str {
                "headroom"
            }
            fn admit(&mut self, ctx: &SchedCtx) -> bool {
                ctx.job.priority <= 1.0 || ctx.in_use + 1 < ctx.capacity
            }
            fn queue_key(&mut self, ctx: &SchedCtx) -> f64 {
                ctx.job.priority
            }
        }
        let mut r: Resource<&str> = Resource::with_scheduler("t", 2, Box::new(Headroom));
        assert_eq!(r.request(0.0, "bulk1", job(5.0)), AcquireResult::Acquired);
        // second slot is reserved: bulk work queues even though it's free
        assert_eq!(r.request(1.0, "bulk2", job(5.0)), AcquireResult::Queued);
        assert_eq!(r.in_use(), 1);
        // but class-1 work takes it immediately
        assert_eq!(r.request(2.0, "vip", job(1.0)), AcquireResult::Acquired);
        assert_eq!(r.in_use(), 2);
        // a release hands the freed slot to the best waiter as usual
        assert_eq!(r.release(3.0).unwrap().token, "bulk2");
    }

    #[test]
    fn utilization_and_queue_stats() {
        let mut r: Resource<u32> = Resource::new("c", 2);
        r.request(0.0, 1, job(0.0)); // busy 1
        r.request(10.0, 2, job(0.0)); // busy 2
        r.release(20.0); // busy 1
        r.release(30.0); // busy 0
        // busy integral: 1*10 + 2*10 + 1*10 = 40 over 30s * 2 slots
        assert!((r.utilization(30.0) - 40.0 / 60.0).abs() < 1e-12);
    }

    #[test]
    fn slot_never_idle_when_queue_nonempty() {
        let mut r: Resource<u32> = Resource::new("c", 1);
        r.request(0.0, 1, job(0.0));
        r.request(0.0, 2, job(0.0));
        let g = r.release(3.0).unwrap();
        assert_eq!(g.token, 2);
        assert_eq!(r.in_use(), 1); // transferred, not freed
    }

    #[test]
    #[should_panic]
    fn zero_capacity_rejected() {
        let _: Resource<u32> = Resource::new("bad", 0);
    }
}
