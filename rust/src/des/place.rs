//! Class-aware placement: which hardware class a granted job runs on.
//!
//! Clusters stay capacity-limited [`Resource`](super::Resource)s — a
//! grant still means "the cluster has the slots". When the cluster is
//! configured with [`HwClass`]es, a [`Placer`] strategy then decides
//! *which* class-tagged slots the granted job occupies, and the chosen
//! class's speed profile scales the task's sampled service time while
//! its price accrues busy-time cost. Placement is layered strictly on
//! top of scheduling: admission, ordering, and preemption decisions are
//! untouched, so a single-class pool at speed 1.0 with no cost knobs is
//! byte-identical in digest to the homogeneous pool it replaces.
//!
//! Placers are registered alongside schedulers and retrain triggers in
//! `coordinator::strategy` (JSON `StrategySpec` + CLI + sweep axes) and
//! must draw no randomness: the simulation's RNG substream layout is
//! part of the determinism contract.

use super::sched::JobCtx;
use super::SimTime;
use crate::model::infra::HwClass;

/// What a [`Placer`] sees of one hardware class at placement time.
#[derive(Clone, Copy, Debug)]
pub struct ClassView {
    /// Index of the class in the cluster's ordered class list.
    pub idx: usize,
    /// Nominal slots of this class.
    pub slots: usize,
    /// Slots currently online (nominal minus failed).
    pub online: usize,
    /// Slots currently occupied by running jobs.
    pub in_use: usize,
    /// Slots available right now (`online - in_use`, floored at 0).
    pub free: usize,
    /// Execution-speed factor for *this job* (per-framework profile
    /// already resolved — see [`HwClass::speed_for`]).
    pub speed: f64,
    /// Price of one busy slot-second.
    pub cost_per_sec: f64,
}

/// Context of one placement decision.
#[derive(Clone, Copy, Debug)]
pub struct PlaceCtx<'a> {
    pub now: SimTime,
    /// The granted job (slots, priority, expected occupancy).
    pub job: JobCtx,
    /// Slots to allocate (`job.slots`).
    pub need: u32,
    /// One view per configured class, in config order.
    pub classes: &'a [ClassView],
}

/// A placement strategy: ranks classes and allocates a granted job's
/// slots across them. The contract mirrors `Scheduler`: pure decision
/// logic, no randomness, deterministic for identical inputs.
pub trait Placer: Send {
    /// Registry name (e.g. `"fastest_fit"`).
    fn name(&self) -> &'static str;

    /// Preference score for `class` — **lower is better**. Ties break
    /// toward the lower class index, so scores need not be unique.
    fn score(&mut self, class: &ClassView, ctx: &PlaceCtx) -> f64;

    /// Allocate `ctx.need` slots, appending `(class index, slots)`
    /// pairs to `out`. The default rule: place the whole job in the
    /// best-scoring class that can hold it; when no single class fits,
    /// spill greedily across classes in score order. Implementations
    /// may allocate fewer than `need` slots only when the cluster
    /// genuinely lacks free class slots (the caller tops up from any
    /// free class and keeps cluster accounting consistent).
    fn place(&mut self, ctx: &PlaceCtx, out: &mut Vec<(u32, u32)>) {
        let order = rank(self, ctx);
        let need = ctx.need as usize;
        for &i in &order {
            if ctx.classes[i].free >= need {
                out.push((i as u32, ctx.need));
                return;
            }
        }
        let mut left = ctx.need;
        for &i in &order {
            if left == 0 {
                break;
            }
            let take = (ctx.classes[i].free as u32).min(left);
            if take > 0 {
                out.push((i as u32, take));
                left -= take;
            }
        }
    }
}

/// Class indices sorted by ascending score, ties by index — the shared
/// ranking pass behind the default [`Placer::place`].
fn rank<P: Placer + ?Sized>(placer: &mut P, ctx: &PlaceCtx) -> Vec<usize> {
    let mut order: Vec<(f64, usize)> = ctx
        .classes
        .iter()
        .map(|c| (placer.score(c, ctx), c.idx))
        .collect();
    order.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    order.into_iter().map(|(_, i)| i).collect()
}

/// Prefer the class that runs this job fastest (highest effective
/// speed); among equally fast classes, config order wins.
pub struct FastestFit;

impl Placer for FastestFit {
    fn name(&self) -> &'static str {
        "fastest_fit"
    }
    fn score(&mut self, class: &ClassView, _ctx: &PlaceCtx) -> f64 {
        -class.speed
    }
}

/// Prefer the cheapest class (lowest cost per busy slot-second); among
/// equally priced classes, the faster one wins.
pub struct CheapestFit;

impl Placer for CheapestFit {
    fn name(&self) -> &'static str {
        "cheapest_fit"
    }
    fn score(&mut self, class: &ClassView, _ctx: &PlaceCtx) -> f64 {
        // speed as a bounded tie-break under the primary cost key
        class.cost_per_sec - class.speed * 1e-12
    }
}

/// Utilization packing: fill the most-utilized class that still fits,
/// keeping whole classes empty for future wide jobs (and for draining
/// under cost pressure).
pub struct Pack;

impl Placer for Pack {
    fn name(&self) -> &'static str {
        "pack"
    }
    fn score(&mut self, class: &ClassView, _ctx: &PlaceCtx) -> f64 {
        class.free as f64
    }
}

/// Failure-domain spread for gang jobs: allocate one slot at a time,
/// always to the class with the most remaining free slots, so a wide
/// job lands across as many classes (failure domains) as possible and
/// a single class failure costs the fewest of its slots.
pub struct Spread;

impl Placer for Spread {
    fn name(&self) -> &'static str {
        "spread"
    }
    fn score(&mut self, class: &ClassView, _ctx: &PlaceCtx) -> f64 {
        -(class.free as f64)
    }
    fn place(&mut self, ctx: &PlaceCtx, out: &mut Vec<(u32, u32)>) {
        let mut taken = vec![0u32; ctx.classes.len()];
        let mut left = ctx.need;
        while left > 0 {
            let mut best: Option<(usize, usize)> = None; // (free remaining, idx)
            for (i, c) in ctx.classes.iter().enumerate() {
                let rem = c.free.saturating_sub(taken[i] as usize);
                if rem == 0 {
                    continue;
                }
                // strictly-more-free wins; ties keep the earlier class
                if best.map(|(brem, _)| rem > brem).unwrap_or(true) {
                    best = Some((rem, i));
                }
            }
            let Some((_, i)) = best else { break };
            taken[i] += 1;
            left -= 1;
        }
        for (i, &k) in taken.iter().enumerate() {
            if k > 0 {
                out.push((i as u32, k));
            }
        }
    }
}

/// Live state of one hardware class: its config plus occupancy,
/// failed-slot count, and the busy slot-seconds integral that cost and
/// per-class utilization are computed from.
#[derive(Clone, Debug)]
pub struct ClassState {
    pub cfg: HwClass,
    pub in_use: usize,
    pub offline: usize,
    /// ∫ in_use dt — busy slot-seconds, advanced lazily on every
    /// occupancy change ([`ClassState::touch`]).
    busy_integral: f64,
    last_t: SimTime,
}

impl ClassState {
    /// Advance the busy integral to `t`. Out-of-order touches (a repair
    /// racing the final settle) clamp to zero elapsed time.
    fn touch(&mut self, t: SimTime) {
        let dt = (t - self.last_t).max(0.0);
        self.busy_integral += self.in_use as f64 * dt;
        self.last_t = self.last_t.max(t);
    }

    /// Slots currently online.
    pub fn online(&self) -> usize {
        self.cfg.slots.saturating_sub(self.offline)
    }

    /// Slots free for placement right now.
    pub fn free(&self) -> usize {
        self.online().saturating_sub(self.in_use)
    }

    /// Busy slot-seconds accrued so far (advance with
    /// [`ClassPool::settle`] first for an up-to-date figure).
    pub fn busy_slot_secs(&self) -> f64 {
        self.busy_integral
    }
}

/// Per-cluster placement state: the ordered class list plus the placer
/// that assigns granted jobs to classes. Occupancy here mirrors the
/// cluster [`Resource`](super::Resource) — the resource decides *how
/// many* slots a job gets and when; the pool decides *which class* they
/// come from.
pub struct ClassPool {
    pub classes: Vec<ClassState>,
    placer: Box<dyn Placer>,
    view_buf: Vec<ClassView>,
    alloc_buf: Vec<(u32, u32)>,
}

impl ClassPool {
    pub fn new(classes: &[HwClass], placer: Box<dyn Placer>) -> Self {
        ClassPool {
            classes: classes
                .iter()
                .map(|cfg| ClassState {
                    cfg: cfg.clone(),
                    in_use: 0,
                    offline: 0,
                    busy_integral: 0.0,
                    last_t: 0.0,
                })
                .collect(),
            placer,
            view_buf: Vec::new(),
            alloc_buf: Vec::new(),
        }
    }

    /// Name of the placement strategy driving this pool.
    pub fn placer_name(&self) -> &'static str {
        self.placer.name()
    }

    /// Place a granted job: allocate `job.slots` class slots at time
    /// `t`, append the `(class index, slots)` allocation to `out`, and
    /// return the job's effective speed factor — the *slowest*
    /// allocated class (a gang job runs at its slowest member's pace).
    /// `fw` resolves per-framework speed profiles. If the placer leaves
    /// slots unallocated despite free capacity (a buggy strategy), the
    /// remainder is topped up greedily in class order so pool occupancy
    /// never diverges from the cluster resource.
    pub fn place(
        &mut self,
        t: SimTime,
        job: &JobCtx,
        fw: Option<&str>,
        out: &mut Vec<(u32, u32)>,
    ) -> f64 {
        self.view_buf.clear();
        for (i, c) in self.classes.iter().enumerate() {
            self.view_buf.push(ClassView {
                idx: i,
                slots: c.cfg.slots,
                online: c.online(),
                in_use: c.in_use,
                free: c.free(),
                speed: c.cfg.speed_for(fw),
                cost_per_sec: c.cfg.cost_per_sec,
            });
        }
        let ctx = PlaceCtx {
            now: t,
            job: *job,
            need: job.slots,
            classes: &self.view_buf,
        };
        let mut alloc = std::mem::take(&mut self.alloc_buf);
        alloc.clear();
        self.placer.place(&ctx, &mut alloc);
        let mut placed: u32 = alloc.iter().map(|&(_, n)| n).sum();
        debug_assert!(
            placed <= job.slots,
            "placer {} over-allocated ({placed} > {})",
            self.placer.name(),
            job.slots
        );
        if placed > job.slots {
            alloc.clear();
            placed = 0;
        }
        if placed < job.slots {
            // top-up: the resource admitted this job, so free class
            // slots exist; take them in class order
            let mut left = job.slots - placed;
            for (i, c) in self.classes.iter().enumerate() {
                if left == 0 {
                    break;
                }
                let already: u32 = alloc
                    .iter()
                    .filter(|&&(ci, _)| ci as usize == i)
                    .map(|&(_, n)| n)
                    .sum();
                let take = (c.free() as u32).saturating_sub(already).min(left);
                if take > 0 {
                    alloc.push((i as u32, take));
                    left -= take;
                }
            }
            debug_assert_eq!(left, 0, "cluster granted a job its classes cannot hold");
        }
        let mut speed = f64::INFINITY;
        for &(ci, n) in alloc.iter() {
            let c = &mut self.classes[ci as usize];
            c.touch(t);
            c.in_use += n as usize;
            speed = speed.min(c.cfg.speed_for(fw));
        }
        out.extend_from_slice(&alloc);
        self.alloc_buf = alloc;
        if speed.is_finite() && speed > 0.0 {
            speed
        } else {
            1.0
        }
    }

    /// Release a previously placed allocation at time `t`.
    pub fn release(&mut self, t: SimTime, alloc: &[(u32, u32)]) {
        for &(ci, n) in alloc {
            let c = &mut self.classes[ci as usize];
            debug_assert!(c.in_use >= n as usize, "class release underflow");
            c.touch(t);
            c.in_use = c.in_use.saturating_sub(n as usize);
        }
    }

    /// Take one slot of class `ci` offline (an injected failure).
    pub fn fail_slot(&mut self, ci: usize) {
        debug_assert!(self.classes[ci].offline < self.classes[ci].cfg.slots);
        self.classes[ci].offline += 1;
    }

    /// Bring one failed slot of class `ci` back online.
    pub fn repair_slot(&mut self, ci: usize) {
        debug_assert!(self.classes[ci].offline > 0);
        self.classes[ci].offline -= 1;
    }

    /// Advance every class's busy integral to `t` (call once at the end
    /// of a run before reading costs/utilizations).
    pub fn settle(&mut self, t: SimTime) {
        for c in &mut self.classes {
            c.touch(t);
        }
    }

    /// Total accrued cost: busy slot-seconds × price, summed over
    /// classes.
    pub fn cost(&self) -> f64 {
        self.classes
            .iter()
            .map(|c| c.busy_integral * c.cfg.cost_per_sec)
            .sum()
    }

    /// Per-class utilization over `[0, horizon]` against nominal slots
    /// (offline slots still count as provisioned, matching
    /// `Resource::utilization`).
    pub fn utilization(&self, ci: usize, horizon: SimTime) -> f64 {
        let c = &self.classes[ci];
        if horizon <= 0.0 || c.cfg.slots == 0 {
            return 0.0;
        }
        c.busy_integral / (horizon * c.cfg.slots as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn classes() -> Vec<HwClass> {
        vec![
            HwClass::new("fast", 2).with_speed(2.0).with_cost(4.0),
            HwClass::new("slow", 4).with_speed(1.0).with_cost(1.0),
        ]
    }

    fn job(slots: u32) -> JobCtx {
        JobCtx::new(10.0, 1.0, 0.0).with_slots(slots)
    }

    fn place_one(pool: &mut ClassPool, t: SimTime, slots: u32) -> (Vec<(u32, u32)>, f64) {
        let mut out = Vec::new();
        let speed = pool.place(t, &job(slots), None, &mut out);
        (out, speed)
    }

    #[test]
    fn fastest_fit_prefers_high_speed() {
        let mut pool = ClassPool::new(&classes(), Box::new(FastestFit));
        let (alloc, speed) = place_one(&mut pool, 0.0, 1);
        assert_eq!(alloc, vec![(0, 1)]);
        assert_eq!(speed, 2.0);
        // fast class exhausted after two singles: spill to slow
        place_one(&mut pool, 0.0, 1);
        let (alloc, speed) = place_one(&mut pool, 0.0, 1);
        assert_eq!(alloc, vec![(1, 1)]);
        assert_eq!(speed, 1.0);
    }

    #[test]
    fn cheapest_fit_prefers_low_cost() {
        let mut pool = ClassPool::new(&classes(), Box::new(CheapestFit));
        let (alloc, speed) = place_one(&mut pool, 0.0, 3);
        assert_eq!(alloc, vec![(1, 3)]);
        assert_eq!(speed, 1.0);
    }

    #[test]
    fn default_place_spills_when_no_single_class_fits() {
        let mut pool = ClassPool::new(&classes(), Box::new(FastestFit));
        // 5 slots: no class holds 5; greedy spill fast-first 2 + 3
        let (alloc, speed) = place_one(&mut pool, 0.0, 5);
        assert_eq!(alloc, vec![(0, 2), (1, 3)]);
        // gang speed is the slowest allocated class
        assert_eq!(speed, 1.0);
    }

    #[test]
    fn pack_fills_most_utilized_class_first() {
        let mut pool = ClassPool::new(&classes(), Box::new(Pack));
        // both empty: fewer-free (fast, 2 slots) packs first
        assert_eq!(place_one(&mut pool, 0.0, 1).0, vec![(0, 1)]);
        assert_eq!(place_one(&mut pool, 0.0, 1).0, vec![(0, 1)]);
        assert_eq!(place_one(&mut pool, 0.0, 1).0, vec![(1, 1)]);
    }

    #[test]
    fn spread_round_robins_across_failure_domains() {
        let mut pool = ClassPool::new(
            &[
                HwClass::new("a", 3),
                HwClass::new("b", 3),
                HwClass::new("c", 3),
            ],
            Box::new(Spread),
        );
        let (alloc, _) = place_one(&mut pool, 0.0, 6);
        // one slot at a time to the most-free class: 2 + 2 + 2
        assert_eq!(alloc, vec![(0, 2), (1, 2), (2, 2)]);
    }

    #[test]
    fn framework_profile_overrides_class_speed() {
        let mut pool = ClassPool::new(
            &[
                HwClass::new("gpu", 2).with_speed(1.5).with_fw_speed("tensorflow", 4.0),
                HwClass::new("cpu", 2).with_speed(2.0),
            ],
            Box::new(FastestFit),
        );
        let mut out = Vec::new();
        // tensorflow profiles the gpu class faster than its generic factor
        let speed = pool.place(0.0, &job(1), Some("tensorflow"), &mut out);
        assert_eq!(out, vec![(0, 1)]);
        assert_eq!(speed, 4.0);
        out.clear();
        // untagged jobs see the generic factors: cpu wins
        let speed = pool.place(0.0, &job(1), None, &mut out);
        assert_eq!(out, vec![(1, 1)]);
        assert_eq!(speed, 2.0);
    }

    #[test]
    fn failed_slots_shrink_placement_capacity() {
        let mut pool = ClassPool::new(&classes(), Box::new(FastestFit));
        pool.fail_slot(0);
        pool.fail_slot(0);
        // fast class fully offline: everything lands on slow
        let (alloc, speed) = place_one(&mut pool, 0.0, 2);
        assert_eq!(alloc, vec![(1, 2)]);
        assert_eq!(speed, 1.0);
        pool.repair_slot(0);
        let (alloc, _) = place_one(&mut pool, 0.0, 1);
        assert_eq!(alloc, vec![(0, 1)]);
    }

    #[test]
    fn busy_integral_accrues_cost_and_utilization() {
        let mut pool = ClassPool::new(&classes(), Box::new(CheapestFit));
        let mut out = Vec::new();
        pool.place(0.0, &job(2), None, &mut out);
        pool.release(10.0, &out);
        pool.settle(20.0);
        // 2 slots × 10 s on the slow ($1/slot-s) class
        assert!((pool.cost() - 20.0).abs() < 1e-9);
        assert!((pool.utilization(1, 20.0) - 20.0 / 80.0).abs() < 1e-12);
        assert_eq!(pool.utilization(0, 20.0), 0.0);
    }

    #[test]
    fn top_up_covers_underallocating_placers() {
        // a placer that allocates nothing: the pool must still account
        // every granted slot
        struct Lazy;
        impl Placer for Lazy {
            fn name(&self) -> &'static str {
                "lazy"
            }
            fn score(&mut self, _c: &ClassView, _ctx: &PlaceCtx) -> f64 {
                0.0
            }
            fn place(&mut self, _ctx: &PlaceCtx, _out: &mut Vec<(u32, u32)>) {}
        }
        let mut pool = ClassPool::new(&classes(), Box::new(Lazy));
        let (alloc, _) = place_one(&mut pool, 0.0, 3);
        let total: u32 = alloc.iter().map(|&(_, n)| n).sum();
        assert_eq!(total, 3);
        assert_eq!(pool.classes[0].in_use + pool.classes[1].in_use, 3);
    }
}
