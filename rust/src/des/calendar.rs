//! Calendar event queue: a 4-ary min-heap keyed by (time, sequence).
//!
//! The sequence number makes event ordering fully deterministic: two
//! events scheduled for the same instant fire in scheduling order, which
//! is what makes simulations reproducible bit-for-bit across runs.
//!
//! A 4-ary heap beats the std binary heap on this workload: the tree is
//! half as deep, so a pop touches ~log4(n) cache lines instead of
//! log2(n), and the four children of a node sit in adjacent memory. Time
//! comparisons use `f64::total_cmp` — a branch-free total order, no NaN
//! panic path in the per-event comparator (NaN times are rejected once,
//! at `schedule_at`).

use super::SimTime;

const ARITY: usize = 4;

struct Entry<E> {
    time: SimTime,
    seq: u64,
    event: E,
}

impl<E> Entry<E> {
    /// Strict (time, seq) ordering; `seq` is unique so this is total.
    #[inline]
    fn earlier_than(&self, other: &Self) -> bool {
        match self.time.total_cmp(&other.time) {
            std::cmp::Ordering::Less => true,
            std::cmp::Ordering::Greater => false,
            std::cmp::Ordering::Equal => self.seq < other.seq,
        }
    }
}

/// Priority queue of future events of type `E`.
pub struct Calendar<E> {
    heap: Vec<Entry<E>>,
    seq: u64,
    now: SimTime,
}

impl<E> Default for Calendar<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Calendar<E> {
    pub fn new() -> Self {
        Calendar {
            heap: Vec::new(),
            seq: 0,
            now: 0.0,
        }
    }

    /// Current simulation time (the time of the last popped event).
    #[inline]
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `event` at absolute time `t`. `t` must not be in the past.
    pub fn schedule_at(&mut self, t: SimTime, event: E) {
        debug_assert!(t >= self.now, "scheduling into the past: {t} < {}", self.now);
        debug_assert!(!t.is_nan(), "NaN sim time");
        self.heap.push(Entry {
            time: t,
            seq: self.seq,
            event,
        });
        self.seq += 1;
        self.sift_up(self.heap.len() - 1);
    }

    /// Schedule `event` after a non-negative `delay` from now.
    #[inline]
    pub fn schedule(&mut self, delay: SimTime, event: E) {
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        self.schedule_at(self.now + delay, event);
    }

    /// Pop the next event, advancing the clock to its time.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        if self.heap.is_empty() {
            return None;
        }
        let last = self.heap.len() - 1;
        self.heap.swap(0, last);
        let e = self.heap.pop().expect("non-empty");
        if !self.heap.is_empty() {
            self.sift_down(0);
        }
        debug_assert!(e.time >= self.now);
        self.now = e.time;
        Some((e.time, e.event))
    }

    /// Time of the next event without popping it.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.first().map(|e| e.time)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Total events ever scheduled (the sequence counter).
    pub fn scheduled_total(&self) -> u64 {
        self.seq
    }

    #[inline]
    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / ARITY;
            if self.heap[i].earlier_than(&self.heap[parent]) {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    #[inline]
    fn sift_down(&mut self, mut i: usize) {
        let len = self.heap.len();
        loop {
            let first = ARITY * i + 1;
            if first >= len {
                break;
            }
            // earliest of up to four children
            let mut best = first;
            let end = (first + ARITY).min(len);
            for c in (first + 1)..end {
                if self.heap[c].earlier_than(&self.heap[best]) {
                    best = c;
                }
            }
            if self.heap[best].earlier_than(&self.heap[i]) {
                self.heap.swap(i, best);
                i = best;
            } else {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut c = Calendar::new();
        c.schedule_at(3.0, "c");
        c.schedule_at(1.0, "a");
        c.schedule_at(2.0, "b");
        assert_eq!(c.pop().unwrap(), (1.0, "a"));
        assert_eq!(c.pop().unwrap(), (2.0, "b"));
        assert_eq!(c.pop().unwrap(), (3.0, "c"));
        assert!(c.pop().is_none());
    }

    #[test]
    fn fifo_tie_break() {
        let mut c = Calendar::new();
        for i in 0..100 {
            c.schedule_at(5.0, i);
        }
        for i in 0..100 {
            assert_eq!(c.pop().unwrap(), (5.0, i));
        }
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut c = Calendar::new();
        c.schedule(10.0, ());
        c.schedule(5.0, ());
        assert_eq!(c.now(), 0.0);
        let (t1, _) = c.pop().unwrap();
        assert_eq!(t1, 5.0);
        assert_eq!(c.now(), 5.0);
        c.schedule(1.0, ()); // relative to now=5
        let (t2, _) = c.pop().unwrap();
        assert_eq!(t2, 6.0);
        let (t3, _) = c.pop().unwrap();
        assert_eq!(t3, 10.0);
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    #[cfg(debug_assertions)]
    fn rejects_past_scheduling() {
        let mut c = Calendar::new();
        c.schedule_at(10.0, ());
        c.pop();
        c.schedule_at(5.0, ());
    }

    #[test]
    #[should_panic(expected = "NaN sim time")]
    #[cfg(debug_assertions)]
    fn rejects_nan_time() {
        let mut c = Calendar::new();
        c.schedule_at(f64::NAN, ());
    }

    #[test]
    fn peek_does_not_advance() {
        let mut c = Calendar::new();
        c.schedule_at(7.0, ());
        assert_eq!(c.peek_time(), Some(7.0));
        assert_eq!(c.now(), 0.0);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn quaternary_heap_orders_large_random_schedules() {
        // exercise deep sift paths: many entries with duplicate times
        let mut c = Calendar::new();
        let mut x = 0x2545_f491_4f6c_dd1du64;
        let mut times = Vec::new();
        for i in 0..10_000u64 {
            // xorshift: deterministic pseudo-random times with collisions
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            let t = (x % 997) as f64;
            times.push((t, i));
            c.schedule_at(t, i);
        }
        times.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        for (want_t, want_id) in times {
            let (t, id) = c.pop().unwrap();
            assert_eq!((t, id), (want_t, want_id));
        }
        assert!(c.is_empty());
        assert_eq!(c.scheduled_total(), 10_000);
    }
}
