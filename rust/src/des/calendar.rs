//! Calendar event queue: a 4-ary min-heap keyed by (time, sequence),
//! with cancellable events.
//!
//! The sequence number makes event ordering fully deterministic: two
//! events scheduled for the same instant fire in scheduling order, which
//! is what makes simulations reproducible bit-for-bit across runs.
//!
//! A 4-ary heap beats the std binary heap on this workload: the tree is
//! half as deep, so a pop touches ~log4(n) cache lines instead of
//! log2(n), and the four children of a node sit in adjacent memory (the
//! sift/heapify primitives live in [`crate::util::heap4`], shared with
//! the resource's waiter index heap). Time
//! comparisons use `f64::total_cmp` — a branch-free total order, no NaN
//! panic path in the per-event comparator (NaN times are rejected once,
//! at `schedule_at`).
//!
//! ## Cancellation
//!
//! [`Calendar::schedule_at`] returns an [`EventHandle`] that
//! [`Calendar::cancel`] can later revoke — the hook preemptive and
//! re-ordering schedulers need to void an in-flight completion event.
//! Cancellation is *lazy*: the entry stays in the heap as a tombstone
//! (its comparator key untouched, so the heap invariant is preserved)
//! and is discarded when it surfaces in [`Calendar::pop`]. The hot path
//! is unperturbed when no cancellations occur: scheduling and popping
//! allocate nothing extra, and the only added cost is two well-predicted
//! branches per pop. When tombstones exceed half the backing heap —
//! checked on every cancel and every live pop — the calendar compacts:
//! drops every tombstone and re-heapifies in O(n), so the tombstone
//! count stays at or below `max(backing/2, 64)` (guarded by the
//! property tests in `rust/tests/props.rs`).

use super::SimTime;
use crate::util::heap4;

/// Compact below this backing size is never worthwhile.
const COMPACT_MIN: usize = 64;

/// A claim ticket for a scheduled event, returned by
/// [`Calendar::schedule_at`] / [`Calendar::schedule`] and consumed by
/// [`Calendar::cancel`]. Handles are unique per calendar for the whole
/// run (they wrap the monotone scheduling sequence number), so a stale
/// handle can never cancel a different event.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct EventHandle {
    seq: u64,
}

struct Entry<E> {
    time: SimTime,
    seq: u64,
    /// Lazily-reaped tombstone flag. Deliberately *not* part of the
    /// comparator: flipping it on cancel leaves the heap invariant
    /// intact, so no re-sifting is needed and live-event pop order is
    /// untouched.
    cancelled: bool,
    event: E,
}

impl<E> Entry<E> {
    /// Strict (time, seq) ordering; `seq` is unique so this is total.
    #[inline]
    fn earlier_than(&self, other: &Self) -> bool {
        match self.time.total_cmp(&other.time) {
            std::cmp::Ordering::Less => true,
            std::cmp::Ordering::Greater => false,
            std::cmp::Ordering::Equal => self.seq < other.seq,
        }
    }
}

/// Priority queue of future events of type `E`.
pub struct Calendar<E> {
    heap: Vec<Entry<E>>,
    seq: u64,
    now: SimTime,
    /// Cancelled entries still sitting in `heap`.
    tombstones: usize,
    /// Total cancellations ever accepted (stats/bench accounting).
    cancelled_total: u64,
    /// Total tombstone compactions performed (SimMeter accounting).
    compactions: u64,
}

impl<E> Default for Calendar<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Calendar<E> {
    pub fn new() -> Self {
        Calendar {
            heap: Vec::new(),
            seq: 0,
            now: 0.0,
            tombstones: 0,
            cancelled_total: 0,
            compactions: 0,
        }
    }

    /// Current simulation time (the time of the last popped event).
    #[inline]
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `event` at absolute time `t`. `t` must not be in the
    /// past. The returned handle cancels the event; it may be ignored
    /// for events that are never revoked.
    pub fn schedule_at(&mut self, t: SimTime, event: E) -> EventHandle {
        debug_assert!(t >= self.now, "scheduling into the past: {t} < {}", self.now);
        debug_assert!(!t.is_nan(), "NaN sim time");
        let seq = self.seq;
        self.heap.push(Entry {
            time: t,
            seq,
            cancelled: false,
            event,
        });
        self.seq += 1;
        self.sift_up(self.heap.len() - 1);
        EventHandle { seq }
    }

    /// Schedule `event` after a non-negative `delay` from now.
    #[inline]
    pub fn schedule(&mut self, delay: SimTime, event: E) -> EventHandle {
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        self.schedule_at(self.now + delay, event)
    }

    /// Cancel a pending event. Returns `true` when the handle named a
    /// still-pending event (now tombstoned and guaranteed never to
    /// fire); `false` when the event already fired, was already
    /// cancelled, or the handle is unknown. O(heap) scan — cancellation
    /// is the rare path; scheduling and popping pay nothing for it.
    pub fn cancel(&mut self, handle: EventHandle) -> bool {
        if handle.seq >= self.seq {
            return false; // never issued by this calendar
        }
        let Some(entry) = self
            .heap
            .iter_mut()
            .find(|e| e.seq == handle.seq && !e.cancelled)
        else {
            return false;
        };
        entry.cancelled = true;
        self.tombstones += 1;
        self.cancelled_total += 1;
        if self.heap.len() > COMPACT_MIN && self.tombstones * 2 > self.heap.len() {
            self.compact();
        }
        true
    }

    /// Pop the next live event, advancing the clock to its time.
    /// Tombstones surfacing at the top are reaped and skipped without
    /// advancing the clock.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        loop {
            if self.heap.is_empty() {
                return None;
            }
            let e = heap4::pop_root(&mut self.heap, Entry::earlier_than);
            if e.cancelled {
                self.tombstones -= 1;
                continue;
            }
            // a live pop shrinks the backing heap while tombstones stay,
            // so the ratio bound must be re-checked here too, not just
            // at cancel. The common zero-tombstone case short-circuits
            // on the first predictable compare.
            if self.tombstones != 0
                && self.heap.len() > COMPACT_MIN
                && self.tombstones * 2 > self.heap.len()
            {
                self.compact();
            }
            debug_assert!(e.time >= self.now);
            self.now = e.time;
            return Some((e.time, e.event));
        }
    }

    /// Time of the next *live* event without popping it. Reaps any
    /// tombstones blocking the top first, so the answer is exact.
    pub fn peek_time(&mut self) -> Option<SimTime> {
        while self.heap.first().is_some_and(|e| e.cancelled) {
            heap4::pop_root(&mut self.heap, Entry::earlier_than);
            self.tombstones -= 1;
        }
        self.heap.first().map(|e| e.time)
    }

    /// Live (non-cancelled) events pending.
    pub fn len(&self) -> usize {
        self.heap.len() - self.tombstones
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Backing-heap size including tombstones awaiting reap.
    pub fn backing_len(&self) -> usize {
        self.heap.len()
    }

    /// Tombstones currently awaiting lazy reap. Bounded: cancellation
    /// and live pops both trigger compaction, keeping this at or below
    /// `max(backing_len / 2, COMPACT_MIN)` after every operation (the
    /// property tests assert exactly that bound).
    pub fn tombstones(&self) -> usize {
        self.tombstones
    }

    /// Total events ever scheduled (the sequence counter).
    pub fn scheduled_total(&self) -> u64 {
        self.seq
    }

    /// Total cancellations ever accepted.
    pub fn cancelled_total(&self) -> u64 {
        self.cancelled_total
    }

    /// Total tombstone compactions ever performed.
    pub fn compactions_total(&self) -> u64 {
        self.compactions
    }

    /// Drop every tombstone and restore the heap invariant in O(n)
    /// (Floyd heapify via the shared [`heap4`] primitives).
    fn compact(&mut self) {
        self.heap.retain(|e| !e.cancelled);
        self.tombstones = 0;
        self.compactions += 1;
        heap4::heapify(&mut self.heap, Entry::earlier_than);
    }

    #[inline]
    fn sift_up(&mut self, i: usize) {
        heap4::sift_up(&mut self.heap, i, Entry::earlier_than);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut c = Calendar::new();
        c.schedule_at(3.0, "c");
        c.schedule_at(1.0, "a");
        c.schedule_at(2.0, "b");
        assert_eq!(c.pop().unwrap(), (1.0, "a"));
        assert_eq!(c.pop().unwrap(), (2.0, "b"));
        assert_eq!(c.pop().unwrap(), (3.0, "c"));
        assert!(c.pop().is_none());
    }

    #[test]
    fn fifo_tie_break() {
        let mut c = Calendar::new();
        for i in 0..100 {
            c.schedule_at(5.0, i);
        }
        for i in 0..100 {
            assert_eq!(c.pop().unwrap(), (5.0, i));
        }
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut c = Calendar::new();
        c.schedule(10.0, ());
        c.schedule(5.0, ());
        assert_eq!(c.now(), 0.0);
        let (t1, _) = c.pop().unwrap();
        assert_eq!(t1, 5.0);
        assert_eq!(c.now(), 5.0);
        c.schedule(1.0, ()); // relative to now=5
        let (t2, _) = c.pop().unwrap();
        assert_eq!(t2, 6.0);
        let (t3, _) = c.pop().unwrap();
        assert_eq!(t3, 10.0);
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    #[cfg(debug_assertions)]
    fn rejects_past_scheduling() {
        let mut c = Calendar::new();
        c.schedule_at(10.0, ());
        c.pop();
        c.schedule_at(5.0, ());
    }

    #[test]
    #[should_panic(expected = "NaN sim time")]
    #[cfg(debug_assertions)]
    fn rejects_nan_time() {
        let mut c = Calendar::new();
        c.schedule_at(f64::NAN, ());
    }

    #[test]
    fn peek_does_not_advance() {
        let mut c = Calendar::new();
        c.schedule_at(7.0, ());
        assert_eq!(c.peek_time(), Some(7.0));
        assert_eq!(c.now(), 0.0);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn cancelled_event_never_fires() {
        let mut c = Calendar::new();
        let a = c.schedule_at(1.0, "a");
        let _b = c.schedule_at(2.0, "b");
        assert_eq!(c.len(), 2);
        assert!(c.cancel(a));
        assert_eq!(c.len(), 1);
        assert_eq!(c.tombstones(), 1);
        assert_eq!(c.pop().unwrap(), (2.0, "b"));
        assert!(c.pop().is_none());
        assert_eq!(c.tombstones(), 0);
        assert_eq!(c.cancelled_total(), 1);
    }

    #[test]
    fn cancel_is_idempotent_and_rejects_fired_or_unknown_handles() {
        let mut c = Calendar::new();
        let a = c.schedule_at(1.0, ());
        assert!(c.cancel(a));
        assert!(!c.cancel(a), "double cancel must be a no-op");
        let b = c.schedule_at(2.0, ());
        assert_eq!(c.pop().unwrap().0, 2.0);
        assert!(!c.cancel(b), "fired events cannot be cancelled");
        assert!(!c.cancel(EventHandle { seq: 999 }), "unknown handle");
        assert_eq!(c.cancelled_total(), 1);
    }

    #[test]
    fn cancel_then_reschedule_preserves_order() {
        let mut c = Calendar::new();
        let h = c.schedule_at(5.0, "moved");
        c.schedule_at(4.0, "x");
        c.schedule_at(6.0, "y");
        assert!(c.cancel(h));
        c.schedule_at(4.5, "moved"); // rescheduled earlier
        assert_eq!(c.pop().unwrap(), (4.0, "x"));
        assert_eq!(c.pop().unwrap(), (4.5, "moved"));
        assert_eq!(c.pop().unwrap(), (6.0, "y"));
        assert!(c.pop().is_none());
    }

    #[test]
    fn tombstones_do_not_advance_clock() {
        let mut c = Calendar::new();
        let h = c.schedule_at(10.0, ());
        c.schedule_at(20.0, ());
        c.cancel(h);
        let (t, _) = c.pop().unwrap();
        assert_eq!(t, 20.0);
        assert_eq!(c.now(), 20.0);
    }

    #[test]
    fn peek_skips_tombstones() {
        let mut c = Calendar::new();
        let h = c.schedule_at(1.0, ());
        c.schedule_at(2.0, ());
        c.cancel(h);
        assert_eq!(c.peek_time(), Some(2.0));
        assert_eq!(c.tombstones(), 0, "peek reaps blocking tombstones");
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn compaction_bounds_tombstone_ratio() {
        let mut c = Calendar::new();
        let handles: Vec<EventHandle> = (0..1000).map(|i| c.schedule_at(i as f64, i)).collect();
        // cancel 90%: compaction must keep tombstones <= backing/2
        for (i, h) in handles.iter().enumerate() {
            if i % 10 != 0 {
                assert!(c.cancel(*h));
            }
            assert!(
                c.tombstones() <= (c.backing_len() / 2).max(COMPACT_MIN),
                "tombstone ratio unbounded: {}/{}",
                c.tombstones(),
                c.backing_len()
            );
        }
        assert_eq!(c.len(), 100);
        assert!(c.compactions_total() > 0, "compactions must be counted");
        // survivors pop in order
        let mut prev = -1.0;
        while let Some((t, v)) = c.pop() {
            assert!(t > prev);
            assert_eq!(v % 10, 0);
            prev = t;
        }
    }

    #[test]
    fn quaternary_heap_orders_large_random_schedules() {
        // exercise deep sift paths: many entries with duplicate times
        let mut c = Calendar::new();
        let mut x = 0x2545_f491_4f6c_dd1du64;
        let mut times = Vec::new();
        for i in 0..10_000u64 {
            // xorshift: deterministic pseudo-random times with collisions
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            let t = (x % 997) as f64;
            times.push((t, i));
            c.schedule_at(t, i);
        }
        times.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        for (want_t, want_id) in times {
            let (t, id) = c.pop().unwrap();
            assert_eq!((t, id), (want_t, want_id));
        }
        assert!(c.is_empty());
        assert_eq!(c.scheduled_total(), 10_000);
    }
}
