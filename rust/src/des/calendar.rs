//! Calendar event queue: a binary heap keyed by (time, sequence).
//!
//! The sequence number makes event ordering fully deterministic: two
//! events scheduled for the same instant fire in scheduling order, which
//! is what makes simulations reproducible bit-for-bit across runs.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::SimTime;

struct Entry<E> {
    time: SimTime,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        other
            .time
            .partial_cmp(&self.time)
            .expect("NaN sim time")
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Priority queue of future events of type `E`.
pub struct Calendar<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: SimTime,
}

impl<E> Default for Calendar<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Calendar<E> {
    pub fn new() -> Self {
        Calendar {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
        }
    }

    /// Current simulation time (the time of the last popped event).
    #[inline]
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `event` at absolute time `t`. `t` must not be in the past.
    pub fn schedule_at(&mut self, t: SimTime, event: E) {
        debug_assert!(t >= self.now, "scheduling into the past: {t} < {}", self.now);
        debug_assert!(!t.is_nan());
        self.heap.push(Entry {
            time: t,
            seq: self.seq,
            event,
        });
        self.seq += 1;
    }

    /// Schedule `event` after a non-negative `delay` from now.
    #[inline]
    pub fn schedule(&mut self, delay: SimTime, event: E) {
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        self.schedule_at(self.now + delay, event);
    }

    /// Pop the next event, advancing the clock to its time.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|e| {
            debug_assert!(e.time >= self.now);
            self.now = e.time;
            (e.time, e.event)
        })
    }

    /// Time of the next event without popping it.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Total events ever scheduled (the sequence counter).
    pub fn scheduled_total(&self) -> u64 {
        self.seq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut c = Calendar::new();
        c.schedule_at(3.0, "c");
        c.schedule_at(1.0, "a");
        c.schedule_at(2.0, "b");
        assert_eq!(c.pop().unwrap(), (1.0, "a"));
        assert_eq!(c.pop().unwrap(), (2.0, "b"));
        assert_eq!(c.pop().unwrap(), (3.0, "c"));
        assert!(c.pop().is_none());
    }

    #[test]
    fn fifo_tie_break() {
        let mut c = Calendar::new();
        for i in 0..100 {
            c.schedule_at(5.0, i);
        }
        for i in 0..100 {
            assert_eq!(c.pop().unwrap(), (5.0, i));
        }
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut c = Calendar::new();
        c.schedule(10.0, ());
        c.schedule(5.0, ());
        assert_eq!(c.now(), 0.0);
        let (t1, _) = c.pop().unwrap();
        assert_eq!(t1, 5.0);
        assert_eq!(c.now(), 5.0);
        c.schedule(1.0, ()); // relative to now=5
        let (t2, _) = c.pop().unwrap();
        assert_eq!(t2, 6.0);
        let (t3, _) = c.pop().unwrap();
        assert_eq!(t3, 10.0);
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    #[cfg(debug_assertions)]
    fn rejects_past_scheduling() {
        let mut c = Calendar::new();
        c.schedule_at(10.0, ());
        c.pop();
        c.schedule_at(5.0, ());
    }

    #[test]
    fn peek_does_not_advance() {
        let mut c = Calendar::new();
        c.schedule_at(7.0, ());
        assert_eq!(c.peek_time(), Some(7.0));
        assert_eq!(c.now(), 0.0);
        assert_eq!(c.len(), 1);
    }
}
