//! # PipeSim — trace-driven simulation of large-scale AI operations platforms
//!
//! A production-grade Rust reimplementation of *PipeSim* (Rausch, Hummer,
//! Muthusamy, 2020): a stochastic, standalone, discrete-event simulator for
//! AI lifecycle platforms, plus the experimentation and analytics
//! environment around it.
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the coordinator: discrete-event engine
//!   ([`des`]), system model ([`model`]), pipeline/asset synthesizers
//!   ([`synth`]), arrival processes ([`arrivals`]), the experiment runner
//!   and pluggable operational strategies ([`coordinator`]; schedulers in
//!   [`des::sched`], retraining triggers in [`coordinator::triggers`],
//!   the JSON-describable strategy registry in
//!   [`coordinator::strategy`]), an embedded time-series store
//!   ([`tsdb`]), first-class event traces with capture, a binary codec,
//!   and replay ([`trace`]), the synthetic empirical substrate
//!   ([`empirical`]), statistics ([`stats`]), analytics
//!   ([`analytics`]), and simulator self-observability with
//!   OpenMetrics/JSON export ([`obs`]).
//! * **L2/L1 (build-time Python)** — JAX compute graphs with a Pallas
//!   E-step kernel, AOT-lowered to HLO text under `artifacts/` and executed
//!   from [`runtime`] through the PJRT C API. Python never runs on the
//!   simulation path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use pipesim::prelude::*;
//!
//! let db = pipesim::empirical::GroundTruth::new(7).generate_weeks(8);
//! let params = pipesim::coordinator::fit_params(&db, None).unwrap();
//! let cfg = ExperimentConfig::default();
//! let result = Experiment::new(cfg, params).run().unwrap();
//! println!("{}", result.summary());
//! ```

pub mod analytics;
pub mod arrivals;
pub mod coordinator;
pub mod des;
pub mod empirical;
pub mod error;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod stats;
pub mod synth;
pub mod trace;
pub mod tsdb;
pub mod util;

pub use error::{Error, Result};

/// Convenient re-exports for the common experiment workflow.
pub mod prelude {
    pub use crate::coordinator::{Experiment, ExperimentConfig, SimParams, StrategySpec};
    pub use crate::coordinator::{RetrainTrigger, TriggerCtx};
    pub use crate::des::{JobCtx, Resource, SchedCtx, Scheduler, SimTime};
    pub use crate::empirical::{AnalyticsDb, GroundTruth};
    pub use crate::error::{Error, Result};
    pub use crate::model::{Framework, TaskType};
    pub use crate::stats::rng::Pcg64;
    pub use crate::trace::{Trace, TraceEvent, TraceEventKind, TraceWorkload};
    pub use crate::tsdb::TsStore;
}
