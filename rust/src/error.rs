//! Crate-wide error type.

use std::fmt;

/// Unified error for all PipeSim subsystems.
#[derive(Debug)]
pub enum Error {
    /// PJRT / XLA runtime failures (artifact load, compile, execute).
    Xla(xla::Error),
    /// Filesystem / serialization problems.
    Io(std::io::Error),
    /// Statistical routine failed to converge or received bad input.
    Stats(String),
    /// Experiment / simulation configuration is invalid.
    Config(String),
    /// Anything else.
    Other(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Xla(e) => write!(f, "xla: {e}"),
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Stats(m) => write!(f, "stats: {m}"),
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Other(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Self {
        Error::Config(format!("integer parse: {e}"))
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Self {
        Error::Config(format!("float parse: {e}"))
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_variants() {
        assert!(Error::Stats("nan".into()).to_string().contains("stats"));
        assert!(Error::Config("bad".into()).to_string().contains("config"));
        assert!(Error::Other("x".into()).to_string().contains('x'));
    }

    #[test]
    fn from_io() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "nope").into();
        assert!(matches!(e, Error::Io(_)));
    }
}
