//! Synthetic empirical substrate — the stand-in for the paper's
//! proprietary IBM analytics database (section V-A).
//!
//! The paper fits its simulation models on "several million rows of user
//! and system events … several thousand pipeline execution traces" from a
//! production platform. That database is not available, so this module
//! implements *hidden ground-truth processes* whose parameters match
//! every statistic the paper discloses (framework mix, per-framework
//! duration medians, arrival volumes, the preprocess duration curve,
//! asset-dimension clustering), generates a realistic usage database from
//! them, and exposes the query layer PipeSim's fitting pipeline consumes.
//!
//! Because the generating processes are known exactly, the Fig 12
//! accuracy evaluation becomes sharper than in the paper: simulated
//! output is compared against data whose true distribution is known.

pub mod db;
pub mod groundtruth;

pub use db::{AnalyticsDb, AssetRecord, EvalRecord, JobRecord, PreprocRecord};
pub use groundtruth::GroundTruth;
