//! The analytics database: record tables and the query layer the fitting
//! pipeline runs against (the paper's "we run queries on this database and
//! fit different statistical distributions on the extracted data").

use crate::des::{HOUR, WEEK};
use crate::error::Result;
use crate::model::Framework;

/// One training-job event (the paper uses training-job arrivals as the
/// proxy for pipeline arrivals, section V-A3).
#[derive(Clone, Copy, Debug)]
pub struct JobRecord {
    /// Arrival time, seconds since epoch start of the trace.
    pub t: f64,
    pub framework: Framework,
    /// Compute duration in seconds.
    pub duration: f64,
}

/// Metadata of one data asset processed by the platform.
#[derive(Clone, Copy, Debug)]
pub struct AssetRecord {
    pub rows: f64,
    pub cols: f64,
    pub bytes: f64,
}

/// One data-preprocessing trace: asset dimensions + compute time.
#[derive(Clone, Copy, Debug)]
pub struct PreprocRecord {
    pub rows: f64,
    pub cols: f64,
    pub duration: f64,
}

/// One model-evaluation trace.
#[derive(Clone, Copy, Debug)]
pub struct EvalRecord {
    pub duration: f64,
}

/// The synthetic production analytics database.
#[derive(Clone, Debug, Default)]
pub struct AnalyticsDb {
    pub weeks: u32,
    pub jobs: Vec<JobRecord>,
    pub assets: Vec<AssetRecord>,
    pub preproc: Vec<PreprocRecord>,
    pub evals: Vec<EvalRecord>,
}

impl AnalyticsDb {
    // -- query layer ---------------------------------------------------

    /// Job interarrival times in seconds (jobs are stored time-ordered).
    pub fn interarrivals(&self) -> Vec<f64> {
        self.jobs.windows(2).map(|w| w[1].t - w[0].t).collect()
    }

    /// Interarrivals bucketed by hour-of-week (0 = Monday 00:00), the
    /// 168 clusters of the realistic arrival profile (section V-A3).
    pub fn interarrivals_by_hour_of_week(&self) -> Vec<Vec<f64>> {
        let mut clusters: Vec<Vec<f64>> = vec![Vec::new(); 168];
        for w in self.jobs.windows(2) {
            let gap = w[1].t - w[0].t;
            let how = hour_of_week(w[0].t);
            clusters[how].push(gap);
        }
        clusters
    }

    /// Average arrivals per hour stratified by hour-of-week (Fig 10).
    pub fn arrivals_per_hour_of_week(&self) -> [f64; 168] {
        let mut counts = [0.0f64; 168];
        for j in &self.jobs {
            counts[hour_of_week(j.t)] += 1.0;
        }
        let weeks = self.weeks.max(1) as f64;
        for c in counts.iter_mut() {
            *c /= weeks;
        }
        counts
    }

    /// Observed framework shares.
    pub fn framework_share(&self) -> Vec<(Framework, f64)> {
        let mut counts = [0usize; 5];
        for j in &self.jobs {
            counts[j.framework.index()] += 1;
        }
        let total = self.jobs.len().max(1) as f64;
        Framework::ALL
            .iter()
            .map(|&f| (f, counts[f.index()] as f64 / total))
            .collect()
    }

    /// Training durations stratified by framework (Fig 9b input).
    pub fn durations_for(&self, fw: Framework) -> Vec<f64> {
        self.jobs
            .iter()
            .filter(|j| j.framework == fw)
            .map(|j| j.duration)
            .collect()
    }

    /// Log-transformed (ln rows, ln cols, ln bytes) asset matrix after the
    /// paper's plausibility filter (rows >= 50, cols >= 2) — the GMM fit
    /// input of section V-A1.
    pub fn asset_log_matrix(&self) -> Vec<[f64; 3]> {
        self.assets
            .iter()
            .filter(|a| a.rows >= 50.0 && a.cols >= 2.0 && a.bytes > 0.0)
            .map(|a| [a.rows.ln(), a.cols.ln(), a.bytes.ln()])
            .collect()
    }

    /// (ln(rows*cols), duration) pairs for the preprocess curve fit
    /// (Fig 9a input).
    pub fn preproc_pairs(&self) -> (Vec<f64>, Vec<f64>) {
        let mut xs = Vec::with_capacity(self.preproc.len());
        let mut ys = Vec::with_capacity(self.preproc.len());
        for p in &self.preproc {
            xs.push((p.rows * p.cols).max(1.0).ln());
            ys.push(p.duration);
        }
        (xs, ys)
    }

    /// Evaluation durations (Fig 12a "evaluate" stratum input).
    pub fn eval_durations(&self) -> Vec<f64> {
        self.evals.iter().map(|e| e.duration).collect()
    }

    /// Mean arrival rate over the trace, jobs/second.
    pub fn mean_arrival_rate(&self) -> f64 {
        if self.jobs.len() < 2 {
            return 0.0;
        }
        let span = self.jobs.last().unwrap().t - self.jobs[0].t;
        (self.jobs.len() - 1) as f64 / span.max(1e-9)
    }

    // -- persistence ----------------------------------------------------

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        use crate::util::jsonio::JsonIo;
        self.save_json(path)
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        use crate::util::jsonio::JsonIo;
        Self::load_json(path)
    }

    /// One-line summary for CLI output.
    pub fn summary(&self) -> String {
        format!(
            "analytics db: {} weeks, {} jobs, {} assets, {} preproc traces, {} eval traces",
            self.weeks,
            self.jobs.len(),
            self.assets.len(),
            self.preproc.len(),
            self.evals.len()
        )
    }
}

/// Hour-of-week index (0..168) of a trace timestamp; t=0 is Monday 00:00.
pub fn hour_of_week(t: f64) -> usize {
    let in_week = t.rem_euclid(WEEK);
    (in_week / HOUR) as usize % 168
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::DAY;

    fn tiny_db() -> AnalyticsDb {
        AnalyticsDb {
            weeks: 1,
            jobs: vec![
                JobRecord { t: 0.0, framework: Framework::SparkML, duration: 10.0 },
                JobRecord { t: 30.0, framework: Framework::TensorFlow, duration: 200.0 },
                JobRecord { t: 90.0, framework: Framework::SparkML, duration: 12.0 },
            ],
            assets: vec![
                AssetRecord { rows: 100.0, cols: 10.0, bytes: 8000.0 },
                AssetRecord { rows: 10.0, cols: 10.0, bytes: 800.0 }, // filtered
                AssetRecord { rows: 100.0, cols: 1.0, bytes: 800.0 }, // filtered
            ],
            preproc: vec![PreprocRecord { rows: 100.0, cols: 10.0, duration: 3.0 }],
            evals: vec![EvalRecord { duration: 5.0 }],
        }
    }

    #[test]
    fn interarrivals() {
        let db = tiny_db();
        assert_eq!(db.interarrivals(), vec![30.0, 60.0]);
    }

    #[test]
    fn framework_share_sums_to_one() {
        let db = tiny_db();
        let share = db.framework_share();
        let total: f64 = share.iter().map(|(_, s)| s).sum();
        assert!((total - 1.0).abs() < 1e-12);
        let spark = share.iter().find(|(f, _)| *f == Framework::SparkML).unwrap();
        assert!((spark.1 - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn asset_filter_applied() {
        let db = tiny_db();
        let m = db.asset_log_matrix();
        assert_eq!(m.len(), 1);
        assert!((m[0][0] - 100.0f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn hour_of_week_mapping() {
        assert_eq!(hour_of_week(0.0), 0);
        assert_eq!(hour_of_week(3600.0), 1);
        assert_eq!(hour_of_week(DAY), 24);
        assert_eq!(hour_of_week(WEEK), 0); // wraps
        assert_eq!(hour_of_week(WEEK + 2.5 * 3600.0), 2);
    }

    #[test]
    fn durations_stratified() {
        let db = tiny_db();
        assert_eq!(db.durations_for(Framework::SparkML), vec![10.0, 12.0]);
        assert_eq!(db.durations_for(Framework::Caffe), Vec::<f64>::new());
    }

    #[test]
    fn arrivals_per_hour_counts() {
        let db = tiny_db();
        let per_hour = db.arrivals_per_hour_of_week();
        assert_eq!(per_hour[0], 3.0); // all three jobs in hour 0 of week 1
        assert_eq!(per_hour[1], 0.0);
    }

    #[test]
    fn save_load_roundtrip() {
        let db = tiny_db();
        let dir = std::env::temp_dir().join("pipesim_test_db.json");
        db.save(&dir).unwrap();
        let back = AnalyticsDb::load(&dir).unwrap();
        assert_eq!(back.jobs.len(), 3);
        assert_eq!(back.weeks, 1);
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn mean_rate() {
        let db = tiny_db();
        assert!((db.mean_arrival_rate() - 2.0 / 90.0).abs() < 1e-12);
    }
}
