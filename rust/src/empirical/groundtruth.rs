//! Hidden ground-truth processes that generate the synthetic analytics DB.
//!
//! Every disclosed statistic from the paper is honored:
//! * framework mix 63/32/3/1/1 (section IV-B1);
//! * SparkML median duration ≈ 10 s, TensorFlow ≈ 180 s (section V-A2b);
//! * preprocess duration = 0.018·1.330^x + 2.156 + LogNormal(−1, 0.15)
//!   with x = ln(rows·cols) (section V-A2a — used here as the *true*
//!   generating process, which PipeSim must then re-fit);
//! * arrival volume ≈ 210 824 jobs/year ≈ 24 jobs/hour average, with a
//!   day/night + weekday/weekend intensity profile like Fig 10;
//! * 9 821 plausible asset observations in log-space clusters (Fig 8).

use super::db::{AnalyticsDb, AssetRecord, EvalRecord, JobRecord, PreprocRecord};
use crate::des::{HOUR, WEEK};
use crate::model::Framework;
use crate::stats::dist::{Distribution, LogNormal};
use crate::stats::rng::Pcg64;
use crate::stats::ExpCurve;

/// Mixture of two log-normals (duration laws).
#[derive(Clone, Copy, Debug)]
struct LnMix2 {
    w1: f64,
    c1: LogNormal,
    c2: LogNormal,
}

impl LnMix2 {
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        if rng.uniform() < self.w1 {
            self.c1.sample(rng)
        } else {
            self.c2.sample(rng)
        }
    }
}

/// One asset cluster in (ln rows, ln cols) with correlation, plus a
/// per-cell byte factor.
#[derive(Clone, Copy, Debug)]
struct AssetCluster {
    w: f64,
    mu_rows: f64,
    mu_cols: f64,
    sd_rows: f64,
    sd_cols: f64,
    corr: f64,
}

/// The hidden generator. All parameters are private by design: PipeSim's
/// fitting pipeline must recover them from the generated records alone.
pub struct GroundTruth {
    rng: Pcg64,
    /// Average arrivals/hour across the week (paper: ≈ 210 824 / year).
    pub base_rate: f64,
    duration_laws: [LnMix2; 5],
    asset_clusters: [AssetCluster; 4],
    preproc_curve: ExpCurve,
    preproc_noise: LogNormal,
    eval_law: LnMix2,
}

impl GroundTruth {
    pub fn new(seed: u64) -> Self {
        GroundTruth {
            rng: Pcg64::new(seed),
            base_rate: 210_824.0 / (52.0 * 168.0), // ≈ 24.1 jobs/hour
            duration_laws: [
                // SparkML: median ≈ 10 s, heavy tail
                LnMix2 { w1: 0.65, c1: LogNormal::new(6f64.ln(), 0.8), c2: LogNormal::new(80f64.ln(), 1.3) },
                // TensorFlow: median ≈ 180 s, long-running tail
                LnMix2 { w1: 0.60, c1: LogNormal::new(100f64.ln(), 0.9), c2: LogNormal::new(900f64.ln(), 1.1) },
                // PyTorch
                LnMix2 { w1: 0.70, c1: LogNormal::new(120f64.ln(), 0.8), c2: LogNormal::new(1500f64.ln(), 1.0) },
                // Caffe
                LnMix2 { w1: 0.60, c1: LogNormal::new(300f64.ln(), 0.9), c2: LogNormal::new(3000f64.ln(), 0.9) },
                // Other
                LnMix2 { w1: 0.80, c1: LogNormal::new(45f64.ln(), 1.2), c2: LogNormal::new(600f64.ln(), 1.4) },
            ],
            asset_clusters: [
                // small tabular
                AssetCluster { w: 0.40, mu_rows: 7.0, mu_cols: 2.2, sd_rows: 1.0, sd_cols: 0.5, corr: 0.3 },
                // medium wide
                AssetCluster { w: 0.30, mu_rows: 9.5, mu_cols: 3.4, sd_rows: 1.2, sd_cols: 0.7, corr: 0.2 },
                // tall narrow
                AssetCluster { w: 0.20, mu_rows: 12.0, mu_cols: 1.6, sd_rows: 1.0, sd_cols: 0.4, corr: -0.2 },
                // huge feature-rich
                AssetCluster { w: 0.10, mu_rows: 11.0, mu_cols: 5.0, sd_rows: 1.5, sd_cols: 0.8, corr: 0.4 },
            ],
            // the paper's production fit, used as the true process
            preproc_curve: ExpCurve { a: 0.018, b: 1.330, c: 2.156 },
            preproc_noise: LogNormal::new(-1.0, 0.15),
            eval_law: LnMix2 { w1: 0.75, c1: LogNormal::new(18f64.ln(), 0.9), c2: LogNormal::new(240f64.ln(), 1.2) },
        }
    }

    /// Hour-of-week intensity multiplier (mean 1.0 across the week):
    /// office-hours peak (≈16:00 as in Fig 11), evening shoulder, quiet
    /// nights, subdued weekends.
    pub fn intensity(how: usize) -> f64 {
        let day = how / 24;
        let hour = how % 24;
        let weekday = day < 5;
        let shape = if weekday {
            match hour {
                0..=5 => 0.25,
                6..=7 => 0.55,
                8..=11 => 1.35,
                12 => 1.05,
                13..=15 => 1.45,
                16 => 1.65, // afternoon peak
                17..=18 => 1.15,
                19..=21 => 0.65,
                _ => 0.40,
            }
        } else {
            match hour {
                0..=6 => 0.15,
                7..=10 => 0.30,
                11..=17 => 0.45,
                _ => 0.25,
            }
        };
        // normalize so the weekly mean multiplier is 1.0
        shape / Self::mean_shape()
    }

    fn mean_shape() -> f64 {
        // cached closed form of the weekly average of the raw shape above
        // (5 weekdays + 2 weekend days) / 168
        let weekday_sum = 6.0 * 0.25 + 2.0 * 0.55 + 4.0 * 1.35 + 1.05 + 3.0 * 1.45 + 1.65 + 2.0 * 1.15 + 3.0 * 0.65 + 2.0 * 0.40;
        let weekend_sum = 7.0 * 0.15 + 4.0 * 0.30 + 7.0 * 0.45 + 6.0 * 0.25;
        (5.0 * weekday_sum + 2.0 * weekend_sum) / 168.0
    }

    fn sample_framework(&mut self) -> Framework {
        let shares: Vec<f64> = Framework::ALL.iter().map(|f| f.paper_share()).collect();
        Framework::ALL[self.rng.categorical(&shares)]
    }

    fn sample_duration(&mut self, fw: Framework) -> f64 {
        let law = self.duration_laws[fw.index()];
        law.sample(&mut self.rng).max(0.2)
    }

    fn sample_asset(&mut self) -> AssetRecord {
        let ws: Vec<f64> = self.asset_clusters.iter().map(|c| c.w).collect();
        let c = self.asset_clusters[self.rng.categorical(&ws)];
        let z1 = self.rng.normal();
        let z2 = c.corr * z1 + (1.0 - c.corr * c.corr).sqrt() * self.rng.normal();
        let ln_rows = c.mu_rows + c.sd_rows * z1;
        let ln_cols = c.mu_cols + c.sd_cols * z2;
        let rows = ln_rows.exp().round().max(1.0);
        let cols = ln_cols.exp().round().max(1.0);
        // bytes ≈ rows*cols*cell_bytes with lognormal spread (Fig 8 right:
        // linear relation with large variability)
        let cell = (2.2 + 0.45 * self.rng.normal()).exp(); // ~9 B/cell median
        AssetRecord {
            rows,
            cols,
            bytes: (rows * cols * cell).max(64.0),
        }
    }

    /// True preprocess duration for an asset (the process PipeSim re-fits).
    pub fn preproc_duration(&mut self, rows: f64, cols: f64) -> f64 {
        let x = (rows * cols).max(1.0).ln();
        self.preproc_curve.eval(x) + self.preproc_noise.sample(&mut self.rng)
    }

    /// Generate a `weeks`-long usage database.
    pub fn generate_weeks(mut self, weeks: u32) -> AnalyticsDb {
        let horizon = weeks as f64 * WEEK;

        // --- job arrivals: piecewise-constant-rate Poisson process ----
        let mut jobs = Vec::new();
        let mut t = 0.0;
        while t < horizon {
            let how = super::db::hour_of_week(t);
            let rate_per_sec = self.base_rate * Self::intensity(how) / HOUR;
            let gap = self.rng.exponential(rate_per_sec.max(1e-9));
            // cap the jump so rate changes at hour boundaries are honored
            let next_boundary = (t / HOUR).floor() * HOUR + HOUR;
            if t + gap > next_boundary && rate_per_sec * (next_boundary - t) < 30.0 {
                // thinning across the boundary: restart from the boundary
                t = next_boundary;
                continue;
            }
            t += gap;
            if t >= horizon {
                break;
            }
            let fw = self.sample_framework();
            let duration = self.sample_duration(fw);
            jobs.push(JobRecord { t, framework: fw, duration });
        }

        // --- assets: scale the paper's 9 821 observations to trace length
        let n_assets = ((9_821.0 * weeks as f64 / 52.0).round() as usize).max(200);
        let mut assets = Vec::with_capacity(n_assets);
        while assets.len() < n_assets {
            let a = self.sample_asset();
            assets.push(a);
        }

        // --- preprocess traces: ~55% of pipelines have a preprocess step
        let n_preproc = (jobs.len() as f64 * 0.55) as usize;
        let mut preproc = Vec::with_capacity(n_preproc);
        let plausible: Vec<AssetRecord> = assets
            .iter()
            .cloned()
            .filter(|a| a.rows >= 50.0 && a.cols >= 2.0)
            .collect();
        for _ in 0..n_preproc {
            let a = plausible[self.rng.below(plausible.len())];
            let duration = self.preproc_duration(a.rows, a.cols);
            preproc.push(PreprocRecord { rows: a.rows, cols: a.cols, duration });
        }

        // --- evaluation traces: ~70% of pipelines evaluate
        let n_eval = (jobs.len() as f64 * 0.7) as usize;
        let evals = (0..n_eval)
            .map(|_| EvalRecord {
                duration: self.eval_law.sample(&mut self.rng).max(0.1),
            })
            .collect();

        AnalyticsDb {
            weeks,
            jobs,
            assets,
            preproc,
            evals,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::desc::quantile;

    fn db_8w() -> AnalyticsDb {
        GroundTruth::new(42).generate_weeks(8)
    }

    #[test]
    fn job_volume_matches_paper_rate() {
        let db = db_8w();
        // ≈ 24.1/h * 168h * 8w ≈ 32 400 jobs, ±10%
        let expect = 210_824.0 / 52.0 * 8.0;
        let got = db.jobs.len() as f64;
        assert!((got - expect).abs() / expect < 0.10, "jobs={got} expect≈{expect}");
    }

    #[test]
    fn framework_mix_matches_paper() {
        let db = db_8w();
        for (fw, share) in db.framework_share() {
            let want = fw.paper_share();
            assert!(
                (share - want).abs() < 0.02,
                "{fw}: {share} vs {want}"
            );
        }
    }

    #[test]
    fn duration_medians_match_paper() {
        let db = db_8w();
        let spark = db.durations_for(Framework::SparkML);
        let tf = db.durations_for(Framework::TensorFlow);
        let p50_spark = quantile(&spark, 0.5);
        let p50_tf = quantile(&tf, 0.5);
        // paper: 50% of Spark ML jobs < 10 s; 50% of TF jobs < 180 s
        assert!((6.0..16.0).contains(&p50_spark), "spark p50={p50_spark}");
        assert!((120.0..260.0).contains(&p50_tf), "tf p50={p50_tf}");
        assert!(p50_tf > 8.0 * p50_spark, "TF must dwarf Spark");
    }

    #[test]
    fn arrivals_show_weekly_pattern() {
        let db = db_8w();
        let per_hour = db.arrivals_per_hour_of_week();
        // weekday 16:00 (hour 16) must beat weekday 03:00 (hour 3) and
        // saturday afternoon (5*24+14)
        assert!(per_hour[16] > 2.0 * per_hour[3], "{} vs {}", per_hour[16], per_hour[3]);
        assert!(per_hour[16] > 2.0 * per_hour[5 * 24 + 14]);
    }

    #[test]
    fn intensity_normalized() {
        let mean: f64 = (0..168).map(GroundTruth::intensity).sum::<f64>() / 168.0;
        assert!((mean - 1.0).abs() < 1e-9, "mean intensity {mean}");
    }

    #[test]
    fn timestamps_sorted_and_in_horizon() {
        let db = db_8w();
        let horizon = 8.0 * WEEK;
        let mut prev = 0.0;
        for j in &db.jobs {
            assert!(j.t >= prev && j.t < horizon);
            prev = j.t;
        }
    }

    #[test]
    fn asset_population_plausible() {
        let db = db_8w();
        let m = db.asset_log_matrix();
        // most assets survive the filter and cluster structure is present
        assert!(m.len() > db.assets.len() / 2);
        let mean_lr = m.iter().map(|r| r[0]).sum::<f64>() / m.len() as f64;
        assert!((6.0..12.0).contains(&mean_lr), "mean ln rows {mean_lr}");
        // bytes correlate with rows*cols (Fig 8 right)
        let size: Vec<f64> = m.iter().map(|r| r[0] + r[1]).collect();
        let bytes: Vec<f64> = m.iter().map(|r| r[2]).collect();
        let corr = crate::stats::pearson(&size, &bytes);
        assert!(corr > 0.9, "log size/bytes corr {corr}");
    }

    #[test]
    fn preproc_durations_follow_curve() {
        let db = db_8w();
        let (xs, ys) = db.preproc_pairs();
        assert!(!xs.is_empty());
        // all durations above the asymptote c=2.156
        assert!(ys.iter().all(|&y| y > 2.0));
        // duration grows with log size: top-decile sizes slower than bottom
        let mut pairs: Vec<(f64, f64)> = xs.into_iter().zip(ys).collect();
        pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let lo_mean: f64 = pairs[..pairs.len() / 10].iter().map(|p| p.1).sum::<f64>() / (pairs.len() / 10) as f64;
        let hi_mean: f64 = pairs[pairs.len() * 9 / 10..].iter().map(|p| p.1).sum::<f64>() / (pairs.len() - pairs.len() * 9 / 10) as f64;
        assert!(hi_mean > lo_mean, "{hi_mean} !> {lo_mean}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = GroundTruth::new(7).generate_weeks(1);
        let b = GroundTruth::new(7).generate_weeks(1);
        assert_eq!(a.jobs.len(), b.jobs.len());
        assert_eq!(a.jobs[10].t, b.jobs[10].t);
        let c = GroundTruth::new(8).generate_weeks(1);
        assert_ne!(a.jobs.len(), c.jobs.len());
    }
}
