//! Windowed aggregation and group-by queries over the store.
//!
//! These are the queries the paper's Grafana dashboard issued against
//! InfluxDB (Fig 11): utilization per resource over time windows, task
//! arrivals per hour, wait-time aggregates — here O(n) over columnar
//! series with no index amplification.

use super::store::{Series, SeriesHandle, TsStore, WindowBucket, WindowedSeries};
use crate::des::SimTime;
use crate::stats::sketch::TDigest;

/// Aggregation functions over a window of values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Agg {
    Mean,
    Sum,
    Min,
    Max,
    Count,
    /// 50th percentile.
    P50,
    /// 95th percentile.
    P95,
    /// Last value in the window (gauge semantics).
    Last,
}

impl Agg {
    fn apply(self, vals: &mut Vec<f64>) -> Option<f64> {
        if vals.is_empty() {
            return None;
        }
        Some(match self {
            Agg::Mean => vals.iter().sum::<f64>() / vals.len() as f64,
            Agg::Sum => vals.iter().sum(),
            Agg::Min => vals.iter().cloned().fold(f64::INFINITY, f64::min),
            Agg::Max => vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
            Agg::Count => vals.len() as f64,
            Agg::P50 => percentile(vals, 0.50),
            Agg::P95 => percentile(vals, 0.95),
            Agg::Last => *vals.last().unwrap(),
        })
    }
}

fn percentile(vals: &mut [f64], p: f64) -> f64 {
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    crate::stats::desc::quantile_sorted(vals, p)
}

/// One aggregated window: [start, start+width) -> value (None if empty).
#[derive(Clone, Debug, PartialEq)]
pub struct WindowAgg {
    pub start: SimTime,
    pub value: Option<f64>,
}

/// Aggregate one series into fixed-width windows over [t0, t1).
pub fn window_aggregate(
    s: &Series,
    t0: SimTime,
    t1: SimTime,
    width: SimTime,
    agg: Agg,
) -> Vec<WindowAgg> {
    assert!(width > 0.0 && t1 > t0);
    let n_windows = ((t1 - t0) / width).ceil() as usize;
    let mut buckets: Vec<Vec<f64>> = vec![Vec::new(); n_windows];
    for (&t, &v) in s.times.iter().zip(&s.values) {
        if t >= t0 && t < t1 {
            let idx = ((t - t0) / width) as usize;
            if idx < n_windows {
                buckets[idx].push(v);
            }
        }
    }
    buckets
        .into_iter()
        .enumerate()
        .map(|(i, mut vals)| WindowAgg {
            start: t0 + i as f64 * width,
            value: agg.apply(&mut vals),
        })
        .collect()
}

/// Combine a set of retention buckets (and optionally loose raw values)
/// into one aggregate. `count`/`sum`/`min`/`max`/`mean` are exact;
/// `P50`/`P95` merge the bucket sketches (documented t-digest bound);
/// `Last` takes the most recent contribution.
fn combine_partials(buckets: &[&WindowBucket], raw: &mut Vec<f64>, agg: Agg) -> Option<f64> {
    if buckets.is_empty() {
        return agg.apply(raw);
    }
    Some(match agg {
        Agg::Count => buckets.iter().map(|b| b.count).sum::<u64>() as f64 + raw.len() as f64,
        Agg::Sum => buckets.iter().map(|b| b.sum).sum::<f64>() + raw.iter().sum::<f64>(),
        Agg::Min => buckets
            .iter()
            .map(|b| b.min)
            .chain(raw.iter().cloned())
            .fold(f64::INFINITY, f64::min),
        Agg::Max => buckets
            .iter()
            .map(|b| b.max)
            .chain(raw.iter().cloned())
            .fold(f64::NEG_INFINITY, f64::max),
        Agg::Mean => {
            let count = buckets.iter().map(|b| b.count).sum::<u64>() as f64 + raw.len() as f64;
            let sum = buckets.iter().map(|b| b.sum).sum::<f64>() + raw.iter().sum::<f64>();
            sum / count
        }
        Agg::P50 | Agg::P95 => {
            let q = if agg == Agg::P50 { 0.50 } else { 0.95 };
            let mut td: TDigest = buckets[0].sketch.clone();
            for b in &buckets[1..] {
                td.merge_from(&b.sketch);
            }
            for &v in raw.iter() {
                td.add(v);
            }
            td.quantile(q)
        }
        // buckets are time-ordered and raw values (if any) come from
        // series merged at bucket granularity; prefer the last bucket
        Agg::Last => buckets.last().unwrap().last,
    })
}

/// Aggregate a downsampled series into fixed-width query windows over
/// `[t0, t1)`. Each retention bucket is assigned wholly to the query
/// window containing its start — exact when `width` is a multiple of
/// the retention resolution and `t0` is aligned to it (the repo's
/// dashboards and tests use aligned windows), a one-bucket-blurred
/// approximation otherwise.
pub fn window_aggregate_downsampled(
    w: &WindowedSeries,
    t0: SimTime,
    t1: SimTime,
    width: SimTime,
    agg: Agg,
) -> Vec<WindowAgg> {
    assert!(width > 0.0 && t1 > t0);
    let n_windows = ((t1 - t0) / width).ceil() as usize;
    let mut groups: Vec<Vec<&WindowBucket>> = vec![Vec::new(); n_windows];
    for b in w.buckets() {
        if b.start >= t0 && b.start < t1 {
            let idx = ((b.start - t0) / width) as usize;
            if idx < n_windows {
                groups[idx].push(b);
            }
        }
    }
    groups
        .into_iter()
        .enumerate()
        .map(|(i, bs)| WindowAgg {
            start: t0 + i as f64 * width,
            value: combine_partials(&bs, &mut Vec::new(), agg),
        })
        .collect()
}

/// A group-by result: one aggregated series per tag value.
#[derive(Clone, Debug)]
pub struct GroupedSeries {
    pub group: String,
    pub windows: Vec<WindowAgg>,
}

impl TsStore {
    /// Windowed aggregation of a single series (raw or downsampled).
    pub fn window(
        &self,
        h: SeriesHandle,
        t0: SimTime,
        t1: SimTime,
        width: SimTime,
        agg: Agg,
    ) -> Vec<WindowAgg> {
        if let Some(w) = self.downsampled(h) {
            return window_aggregate_downsampled(w, t0, t1, width, agg);
        }
        window_aggregate(self.series(h), t0, t1, width, agg)
    }

    /// `GROUP BY <tag>`: aggregate all series of `measurement`, grouped by
    /// the value of `tag`, each into fixed-width windows.
    pub fn group_by(
        &self,
        measurement: &str,
        tag: &str,
        t0: SimTime,
        t1: SimTime,
        width: SimTime,
        agg: Agg,
    ) -> Vec<GroupedSeries> {
        use std::collections::BTreeMap;
        if self.any_downsampled() {
            return self.group_by_mixed(measurement, tag, t0, t1, width, agg);
        }
        // merge series sharing a tag value before aggregating
        let mut merged: BTreeMap<String, Series> = BTreeMap::new();
        for h in self.find(measurement) {
            let group = self
                .key(h)
                .tag_value(tag)
                .unwrap_or("<none>")
                .to_string();
            let s = self.series(h);
            let m = merged.entry(group).or_default();
            m.times.extend_from_slice(&s.times);
            m.values.extend_from_slice(&s.values);
        }
        merged
            .into_iter()
            .map(|(group, mut s)| {
                // restore time order after merge
                let mut idx: Vec<usize> = (0..s.times.len()).collect();
                idx.sort_by(|&a, &b| s.times[a].partial_cmp(&s.times[b]).unwrap());
                s.times = idx.iter().map(|&i| s.times[i]).collect();
                s.values = idx.iter().map(|&i| s.values[i]).collect();
                GroupedSeries {
                    group,
                    windows: window_aggregate(&s, t0, t1, width, agg),
                }
            })
            .collect()
    }

    /// Group-by over a store holding downsampled (and possibly some
    /// raw) series: per query window, members contribute retention
    /// buckets or raw points, combined by [`combine_partials`].
    fn group_by_mixed(
        &self,
        measurement: &str,
        tag: &str,
        t0: SimTime,
        t1: SimTime,
        width: SimTime,
        agg: Agg,
    ) -> Vec<GroupedSeries> {
        use std::collections::BTreeMap;
        assert!(width > 0.0 && t1 > t0);
        let n_windows = ((t1 - t0) / width).ceil() as usize;
        #[derive(Default)]
        struct Partial<'a> {
            buckets: Vec<Vec<&'a WindowBucket>>,
            raw: Vec<Vec<f64>>,
        }
        let mut groups: BTreeMap<String, Partial<'_>> = BTreeMap::new();
        for h in self.find(measurement) {
            let group = self
                .key(h)
                .tag_value(tag)
                .unwrap_or("<none>")
                .to_string();
            let p = groups.entry(group).or_default();
            if p.buckets.is_empty() {
                p.buckets = vec![Vec::new(); n_windows];
                p.raw = vec![Vec::new(); n_windows];
            }
            if let Some(w) = self.downsampled(h) {
                for b in w.buckets() {
                    if b.start >= t0 && b.start < t1 {
                        let idx = ((b.start - t0) / width) as usize;
                        if idx < n_windows {
                            p.buckets[idx].push(b);
                        }
                    }
                }
            } else {
                let s = self.series(h);
                for (&t, &v) in s.times.iter().zip(&s.values) {
                    if t >= t0 && t < t1 {
                        let idx = ((t - t0) / width) as usize;
                        if idx < n_windows {
                            p.raw[idx].push(v);
                        }
                    }
                }
            }
        }
        groups
            .into_iter()
            .map(|(group, mut p)| GroupedSeries {
                group,
                windows: (0..n_windows)
                    .map(|i| WindowAgg {
                        start: t0 + i as f64 * width,
                        value: combine_partials(&p.buckets[i], &mut p.raw[i], agg),
                    })
                    .collect(),
            })
            .collect()
    }

    /// Scalar aggregate over the full range of one series (raw or
    /// downsampled).
    pub fn aggregate(&self, h: SeriesHandle, agg: Agg) -> Option<f64> {
        if let Some(w) = self.downsampled(h) {
            let bs: Vec<&WindowBucket> = w.buckets().iter().collect();
            return combine_partials(&bs, &mut Vec::new(), agg);
        }
        let s = self.series(h);
        let mut vals = s.values.clone();
        agg.apply(&mut vals)
    }

    /// All raw values of a series (for Q-Q / distribution analytics).
    /// Downsampled series hold no raw values, so this returns an empty
    /// slice for them — use [`TsStore::window`] / [`TsStore::aggregate`]
    /// instead.
    pub fn values(&self, h: SeriesHandle) -> &[f64] {
        &self.series(h).values
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tsdb::SeriesKey;

    fn sample_store() -> (TsStore, SeriesHandle) {
        let mut db = TsStore::new();
        let h = db.handle(SeriesKey::new("m"));
        // points at t = 0..10, value = t
        for i in 0..10 {
            db.append(h, i as f64, i as f64);
        }
        (db, h)
    }

    #[test]
    fn window_mean() {
        let (db, h) = sample_store();
        let w = db.window(h, 0.0, 10.0, 5.0, Agg::Mean);
        assert_eq!(w.len(), 2);
        assert_eq!(w[0].value, Some(2.0)); // mean of 0..=4
        assert_eq!(w[1].value, Some(7.0)); // mean of 5..=9
    }

    #[test]
    fn window_count_and_empty() {
        let (db, h) = sample_store();
        let w = db.window(h, 0.0, 20.0, 5.0, Agg::Count);
        assert_eq!(w.len(), 4);
        assert_eq!(w[0].value, Some(5.0));
        assert_eq!(w[1].value, Some(5.0));
        assert_eq!(w[2].value, None);
        assert_eq!(w[3].value, None);
    }

    #[test]
    fn window_minmax_sum_last() {
        let (db, h) = sample_store();
        assert_eq!(db.window(h, 0.0, 10.0, 10.0, Agg::Min)[0].value, Some(0.0));
        assert_eq!(db.window(h, 0.0, 10.0, 10.0, Agg::Max)[0].value, Some(9.0));
        assert_eq!(db.window(h, 0.0, 10.0, 10.0, Agg::Sum)[0].value, Some(45.0));
        assert_eq!(db.window(h, 0.0, 10.0, 10.0, Agg::Last)[0].value, Some(9.0));
    }

    #[test]
    fn percentiles() {
        let (db, h) = sample_store();
        let p50 = db.window(h, 0.0, 10.0, 10.0, Agg::P50)[0].value.unwrap();
        assert!((p50 - 4.5).abs() < 1e-12);
        let p95 = db.window(h, 0.0, 10.0, 10.0, Agg::P95)[0].value.unwrap();
        assert!(p95 > 8.0);
    }

    #[test]
    fn group_by_tag() {
        let mut db = TsStore::new();
        db.record(SeriesKey::new("dur").tag("fw", "tf"), 0.0, 100.0);
        db.record(SeriesKey::new("dur").tag("fw", "tf"), 1.0, 200.0);
        db.record(SeriesKey::new("dur").tag("fw", "spark"), 0.5, 10.0);
        let groups = db.group_by("dur", "fw", 0.0, 2.0, 2.0, Agg::Mean);
        assert_eq!(groups.len(), 2);
        let spark = groups.iter().find(|g| g.group == "spark").unwrap();
        assert_eq!(spark.windows[0].value, Some(10.0));
        let tf = groups.iter().find(|g| g.group == "tf").unwrap();
        assert_eq!(tf.windows[0].value, Some(150.0));
    }

    #[test]
    fn full_range_aggregate() {
        let (db, h) = sample_store();
        assert_eq!(db.aggregate(h, Agg::Sum), Some(45.0));
        assert_eq!(db.values(h).len(), 10);
    }

    fn downsampled_store() -> (TsStore, SeriesHandle) {
        let mut db = TsStore::new();
        db.set_retention(1.0); // finer than the 5.0 query windows
        let h = db.handle(SeriesKey::new("m"));
        for i in 0..10 {
            db.append(h, i as f64, i as f64);
        }
        (db, h)
    }

    #[test]
    fn downsampled_window_matches_raw_for_aligned_queries() {
        let (raw_db, hr) = sample_store();
        let (down_db, hd) = downsampled_store();
        for agg in [Agg::Mean, Agg::Sum, Agg::Min, Agg::Max, Agg::Count, Agg::Last] {
            let a = raw_db.window(hr, 0.0, 10.0, 5.0, agg);
            let b = down_db.window(hd, 0.0, 10.0, 5.0, agg);
            assert_eq!(a, b, "{agg:?}");
        }
    }

    #[test]
    fn downsampled_quantiles_close_to_raw() {
        let (raw_db, hr) = sample_store();
        let (down_db, hd) = downsampled_store();
        for agg in [Agg::P50, Agg::P95] {
            let a = raw_db.window(hr, 0.0, 10.0, 10.0, agg)[0].value.unwrap();
            let b = down_db.window(hd, 0.0, 10.0, 10.0, agg)[0].value.unwrap();
            // 10 distinct values → sketch holds them exactly; allow
            // interpolation slack of one value step
            assert!((a - b).abs() <= 1.0, "{agg:?}: {a} vs {b}");
        }
    }

    #[test]
    fn downsampled_full_range_aggregate() {
        let (db, h) = downsampled_store();
        assert_eq!(db.aggregate(h, Agg::Sum), Some(45.0));
        assert_eq!(db.aggregate(h, Agg::Count), Some(10.0));
        assert_eq!(db.aggregate(h, Agg::Min), Some(0.0));
        assert_eq!(db.aggregate(h, Agg::Max), Some(9.0));
        assert_eq!(db.aggregate(h, Agg::Last), Some(9.0));
        // downsampled series expose no raw values
        assert!(db.values(h).is_empty());
    }

    #[test]
    fn group_by_with_downsampled_members() {
        let mut db = TsStore::new();
        db.set_retention(1.0);
        db.record(SeriesKey::new("dur").tag("fw", "tf"), 0.0, 100.0);
        db.record(SeriesKey::new("dur").tag("fw", "tf"), 1.0, 200.0);
        db.record(SeriesKey::new("dur").tag("fw", "spark"), 0.5, 10.0);
        let groups = db.group_by("dur", "fw", 0.0, 2.0, 2.0, Agg::Mean);
        assert_eq!(groups.len(), 2);
        let spark = groups.iter().find(|g| g.group == "spark").unwrap();
        assert_eq!(spark.windows[0].value, Some(10.0));
        let tf = groups.iter().find(|g| g.group == "tf").unwrap();
        assert_eq!(tf.windows[0].value, Some(150.0));
        // count across both groups is conserved
        let total: f64 = db
            .group_by("dur", "fw", 0.0, 2.0, 2.0, Agg::Count)
            .iter()
            .filter_map(|g| g.windows[0].value)
            .sum();
        assert_eq!(total, 3.0);
    }
}
