//! Series storage: interned keys, append-only columnar points.

use std::collections::HashMap;
use std::io::Write;

use crate::des::SimTime;
use crate::error::Result;

/// A measurement name plus sorted tag pairs, e.g.
/// `("task_duration", [("task","train"),("framework","tensorflow")])`.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct SeriesKey {
    pub measurement: String,
    pub tags: Vec<(String, String)>,
}

impl SeriesKey {
    pub fn new(measurement: impl Into<String>) -> Self {
        SeriesKey {
            measurement: measurement.into(),
            tags: Vec::new(),
        }
    }

    pub fn tag(mut self, k: impl Into<String>, v: impl Into<String>) -> Self {
        self.tags.push((k.into(), v.into()));
        self.tags.sort();
        self
    }

    pub fn tag_value(&self, key: &str) -> Option<&str> {
        self.tags
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }
}

impl std::fmt::Display for SeriesKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.measurement)?;
        for (k, v) in &self.tags {
            write!(f, ",{k}={v}")?;
        }
        Ok(())
    }
}

/// Interned handle for hot-path appends.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SeriesHandle(pub(crate) u32);

/// Columnar storage for one series.
#[derive(Clone, Debug, Default)]
pub struct Series {
    pub times: Vec<f64>,
    pub values: Vec<f64>,
}

impl Series {
    pub fn len(&self) -> usize {
        self.times.len()
    }
    pub fn is_empty(&self) -> bool {
        self.times.is_empty()
    }
}

/// The store: all series of one experiment run.
#[derive(Default)]
pub struct TsStore {
    keys: Vec<SeriesKey>,
    series: Vec<Series>,
    index: HashMap<SeriesKey, u32>,
}

impl TsStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Intern a key, returning a stable handle. Idempotent.
    pub fn handle(&mut self, key: SeriesKey) -> SeriesHandle {
        if let Some(&id) = self.index.get(&key) {
            return SeriesHandle(id);
        }
        let id = self.keys.len() as u32;
        self.index.insert(key.clone(), id);
        self.keys.push(key);
        self.series.push(Series::default());
        SeriesHandle(id)
    }

    /// Append a point. Times within one series must be non-decreasing
    /// (the simulator's clock is monotone, so this is free).
    #[inline]
    pub fn append(&mut self, h: SeriesHandle, t: SimTime, v: f64) {
        let s = &mut self.series[h.0 as usize];
        debug_assert!(
            s.times.last().map_or(true, |&last| t >= last),
            "out-of-order append to {}",
            self.keys[h.0 as usize]
        );
        s.times.push(t);
        s.values.push(v);
    }

    /// Convenience: intern + append in one call (cold paths only).
    pub fn record(&mut self, key: SeriesKey, t: SimTime, v: f64) {
        let h = self.handle(key);
        self.append(h, t, v);
    }

    pub fn series(&self, h: SeriesHandle) -> &Series {
        &self.series[h.0 as usize]
    }

    pub fn key(&self, h: SeriesHandle) -> &SeriesKey {
        &self.keys[h.0 as usize]
    }

    pub fn get(&self, key: &SeriesKey) -> Option<&Series> {
        self.index.get(key).map(|&id| &self.series[id as usize])
    }

    /// All handles whose measurement matches.
    pub fn find(&self, measurement: &str) -> Vec<SeriesHandle> {
        self.keys
            .iter()
            .enumerate()
            .filter(|(_, k)| k.measurement == measurement)
            .map(|(i, _)| SeriesHandle(i as u32))
            .collect()
    }

    /// All handles matching measurement + a tag filter.
    pub fn find_tagged(&self, measurement: &str, tag: &str, value: &str) -> Vec<SeriesHandle> {
        self.find(measurement)
            .into_iter()
            .filter(|h| self.key(*h).tag_value(tag) == Some(value))
            .collect()
    }

    pub fn num_series(&self) -> usize {
        self.keys.len()
    }

    pub fn num_points(&self) -> usize {
        self.series.iter().map(|s| s.len()).sum()
    }

    /// Approximate resident bytes of the stored points.
    pub fn approx_bytes(&self) -> usize {
        self.num_points() * 16
    }

    pub fn handles(&self) -> impl Iterator<Item = SeriesHandle> + '_ {
        (0..self.keys.len() as u32).map(SeriesHandle)
    }

    /// Export every series to CSV: `series,time,value` rows.
    pub fn export_csv<W: Write>(&self, w: &mut W) -> Result<()> {
        writeln!(w, "series,time,value")?;
        for h in self.handles() {
            let key = self.key(h).to_string();
            let s = self.series(h);
            for (t, v) in s.times.iter().zip(&s.values) {
                writeln!(w, "{key},{t},{v}")?;
            }
        }
        Ok(())
    }

    /// Export one series as JSON {key, times, values}.
    pub fn export_series_json(&self, h: SeriesHandle) -> Result<String> {
        use crate::util::Json;
        let s = self.series(h);
        Ok(Json::obj(vec![
            ("key", Json::Str(self.key(h).to_string())),
            ("times", Json::arr_f64(s.times.iter().cloned())),
            ("values", Json::arr_f64(s.values.iter().cloned())),
        ])
        .to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_is_idempotent() {
        let mut db = TsStore::new();
        let k = SeriesKey::new("util").tag("resource", "train");
        let h1 = db.handle(k.clone());
        let h2 = db.handle(k);
        assert_eq!(h1, h2);
        assert_eq!(db.num_series(), 1);
    }

    #[test]
    fn append_and_read_back() {
        let mut db = TsStore::new();
        let h = db.handle(SeriesKey::new("m"));
        db.append(h, 1.0, 10.0);
        db.append(h, 2.0, 20.0);
        let s = db.series(h);
        assert_eq!(s.times, vec![1.0, 2.0]);
        assert_eq!(s.values, vec![10.0, 20.0]);
        assert_eq!(db.num_points(), 2);
    }

    #[test]
    fn tags_sorted_and_queryable() {
        let k = SeriesKey::new("x").tag("b", "2").tag("a", "1");
        assert_eq!(k.tags[0].0, "a");
        assert_eq!(k.tag_value("b"), Some("2"));
        assert_eq!(k.tag_value("zz"), None);
        assert_eq!(k.to_string(), "x,a=1,b=2");
    }

    #[test]
    fn find_by_measurement_and_tag() {
        let mut db = TsStore::new();
        db.record(SeriesKey::new("dur").tag("task", "train"), 0.0, 1.0);
        db.record(SeriesKey::new("dur").tag("task", "eval"), 0.0, 2.0);
        db.record(SeriesKey::new("util").tag("task", "train"), 0.0, 3.0);
        assert_eq!(db.find("dur").len(), 2);
        assert_eq!(db.find_tagged("dur", "task", "train").len(), 1);
        assert_eq!(db.find_tagged("dur", "task", "nope").len(), 0);
    }

    #[test]
    fn csv_export() {
        let mut db = TsStore::new();
        db.record(SeriesKey::new("m").tag("t", "a"), 1.5, 2.5);
        let mut buf = Vec::new();
        db.export_csv(&mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("series,time,value"));
        assert!(text.contains("m,t=a,1.5,2.5"));
    }

    #[test]
    #[should_panic(expected = "out-of-order")]
    #[cfg(debug_assertions)]
    fn rejects_out_of_order() {
        let mut db = TsStore::new();
        let h = db.handle(SeriesKey::new("m"));
        db.append(h, 5.0, 0.0);
        db.append(h, 1.0, 0.0);
    }
}
