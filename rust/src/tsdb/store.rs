//! Series storage: interned keys, append-only columnar points.
//!
//! Two levels of interning keep the hot path string-free:
//! * tag/measurement strings intern to [`Sym`] ids in a per-store symbol
//!   table, so series-key lookups hash a few `u32`s instead of `String`s;
//! * full keys intern to [`SeriesHandle`]s, so recording a point is two
//!   `Vec::push`es.

use std::collections::HashMap;
use std::io::Write;

use crate::des::SimTime;
use crate::error::Result;
use crate::stats::sketch::TDigest;

/// A measurement name plus sorted tag pairs, e.g.
/// `("task_duration", [("task","train"),("framework","tensorflow")])`.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct SeriesKey {
    pub measurement: String,
    pub tags: Vec<(String, String)>,
}

impl SeriesKey {
    pub fn new(measurement: impl Into<String>) -> Self {
        SeriesKey {
            measurement: measurement.into(),
            tags: Vec::new(),
        }
    }

    pub fn tag(mut self, k: impl Into<String>, v: impl Into<String>) -> Self {
        let k = k.into();
        let v = v.into();
        // insert in sorted position: O(n) shift instead of an O(n log n)
        // re-sort per builder call
        let pos = self
            .tags
            .partition_point(|(ek, ev)| (ek.as_str(), ev.as_str()) < (k.as_str(), v.as_str()));
        self.tags.insert(pos, (k, v));
        self
    }

    pub fn tag_value(&self, key: &str) -> Option<&str> {
        self.tags
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }
}

impl std::fmt::Display for SeriesKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.measurement)?;
        for (k, v) in &self.tags {
            write!(f, ",{k}={v}")?;
        }
        Ok(())
    }
}

/// Interned handle for hot-path appends.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SeriesHandle(pub(crate) u32);

/// Interned string symbol (per-store scope).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Sym(u32);

/// String → u32 intern table.
#[derive(Default)]
struct SymbolTable {
    names: Vec<String>,
    index: HashMap<String, u32>,
}

impl SymbolTable {
    fn intern(&mut self, s: &str) -> Sym {
        if let Some(&id) = self.index.get(s) {
            return Sym(id);
        }
        let id = self.names.len() as u32;
        self.index.insert(s.to_string(), id);
        self.names.push(s.to_string());
        Sym(id)
    }

    fn lookup(&self, s: &str) -> Option<Sym> {
        self.index.get(s).map(|&id| Sym(id))
    }

    fn name(&self, s: Sym) -> &str {
        &self.names[s.0 as usize]
    }
}

/// Symbol-level series key: what the index actually hashes.
#[derive(Clone, PartialEq, Eq, Hash)]
struct CompactKey {
    measurement: Sym,
    /// Tag pairs sorted by the *string* order of the underlying symbols,
    /// matching [`SeriesKey::tags`] order exactly.
    tags: Vec<(Sym, Sym)>,
}

/// Columnar storage for one series.
#[derive(Clone, Debug, Default)]
pub struct Series {
    pub times: Vec<f64>,
    pub values: Vec<f64>,
}

impl Series {
    pub fn len(&self) -> usize {
        self.times.len()
    }
    pub fn is_empty(&self) -> bool {
        self.times.is_empty()
    }
}

/// One fixed-resolution retention window: streaming aggregates plus a
/// mergeable quantile sketch over every point that fell in
/// `[start, start + resolution)`.
#[derive(Clone, Debug)]
pub struct WindowBucket {
    pub start: SimTime,
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    /// Most recent value (gauge / `Agg::Last` semantics).
    pub last: f64,
    pub sketch: TDigest,
}

impl WindowBucket {
    fn new(start: SimTime, v: f64) -> Self {
        let mut sketch = TDigest::default();
        sketch.add(v);
        WindowBucket {
            start,
            count: 1,
            sum: v,
            min: v,
            max: v,
            last: v,
            sketch,
        }
    }

    fn absorb(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
        self.last = v;
        self.sketch.add(v);
    }

    pub fn mean(&self) -> f64 {
        self.sum / self.count as f64
    }

    fn approx_bytes(&self) -> usize {
        std::mem::size_of::<WindowBucket>() + self.sketch.approx_bytes()
    }
}

/// Downsampled representation of one series: points roll into
/// fixed-resolution [`WindowBucket`]s as they arrive, so memory is
/// O(elapsed_time / resolution) instead of O(points).
#[derive(Clone, Debug)]
pub struct WindowedSeries {
    resolution: SimTime,
    buckets: Vec<WindowBucket>,
    /// Total points absorbed (the raw-equivalent point count).
    observed: u64,
}

impl WindowedSeries {
    fn new(resolution: SimTime) -> Self {
        WindowedSeries {
            resolution,
            buckets: Vec::new(),
            observed: 0,
        }
    }

    pub fn resolution(&self) -> SimTime {
        self.resolution
    }

    pub fn buckets(&self) -> &[WindowBucket] {
        &self.buckets
    }

    /// Points absorbed across all buckets.
    pub fn observed(&self) -> u64 {
        self.observed
    }

    fn add(&mut self, t: SimTime, v: f64) {
        self.observed += 1;
        match self.buckets.last_mut() {
            // monotone clock: either the point lands in the open bucket…
            Some(b) if t < b.start + self.resolution => b.absorb(v),
            // …or it opens a new one further right
            _ => {
                let start = (t / self.resolution).floor() * self.resolution;
                self.buckets.push(WindowBucket::new(start, v));
            }
        }
    }

    pub fn approx_bytes(&self) -> usize {
        self.buckets.iter().map(|b| b.approx_bytes()).sum::<usize>() + 32
    }
}

/// The store: all series of one experiment run.
///
/// By default every point is stored raw (`times`/`values` columns).
/// With [`TsStore::set_retention`], appends instead roll into
/// fixed-resolution [`WindowedSeries`] buckets — memory-flat over the
/// run length — and the query layer ([`super::query`]) answers from
/// either representation. Retention-off behavior is byte-identical to
/// a store without the feature.
#[derive(Default)]
pub struct TsStore {
    keys: Vec<SeriesKey>,
    series: Vec<Series>,
    /// Parallel to `series` when retention is on; EMPTY when off, so
    /// the retention-off hot path is a single bounds-check miss.
    windowed: Vec<Option<WindowedSeries>>,
    retention: Option<SimTime>,
    symbols: SymbolTable,
    index: HashMap<CompactKey, u32>,
}

impl TsStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Switch the store to downsampled retention: from now on, appends
    /// to every series roll into `resolution`-second windows of
    /// `(count, sum, min, max, last, sketch)` instead of raw points.
    ///
    /// Series that already hold raw points keep their raw
    /// representation (retention applies to series whose life starts
    /// under the policy); call this before recording, as
    /// `Simulation::new` does.
    pub fn set_retention(&mut self, resolution: SimTime) {
        assert!(
            resolution > 0.0 && resolution.is_finite(),
            "retention resolution must be positive"
        );
        self.retention = Some(resolution);
        self.windowed = self
            .series
            .iter()
            .map(|s| {
                if s.is_empty() {
                    Some(WindowedSeries::new(resolution))
                } else {
                    None
                }
            })
            .collect();
    }

    /// The retention resolution, when downsampling is on.
    pub fn retention(&self) -> Option<SimTime> {
        self.retention
    }

    /// The downsampled representation of a series, when it has one.
    pub fn downsampled(&self, h: SeriesHandle) -> Option<&WindowedSeries> {
        self.windowed.get(h.0 as usize).and_then(|w| w.as_ref())
    }

    /// Intern a string, returning a stable symbol for
    /// [`TsStore::handle_interned`] lookups that never re-hash strings.
    pub fn sym(&mut self, s: &str) -> Sym {
        self.symbols.intern(s)
    }

    fn compact(&mut self, key: &SeriesKey) -> CompactKey {
        CompactKey {
            measurement: self.symbols.intern(&key.measurement),
            tags: key
                .tags
                .iter()
                .map(|(k, v)| (self.symbols.intern(k), self.symbols.intern(v)))
                .collect(),
        }
    }

    /// Intern a key, returning a stable handle. Idempotent.
    pub fn handle(&mut self, key: SeriesKey) -> SeriesHandle {
        let compact = self.compact(&key);
        if let Some(&id) = self.index.get(&compact) {
            return SeriesHandle(id);
        }
        self.insert_series(compact, key)
    }

    /// Handle lookup from pre-interned symbols: hashes only `u32`s, no
    /// string traffic at all. `tags` may be in any order.
    pub fn handle_interned(&mut self, measurement: Sym, tags: &[(Sym, Sym)]) -> SeriesHandle {
        let mut stags = tags.to_vec();
        // order by the underlying strings so equivalent keys collide
        stags.sort_by(|a, b| {
            (self.symbols.name(a.0), self.symbols.name(a.1))
                .cmp(&(self.symbols.name(b.0), self.symbols.name(b.1)))
        });
        let compact = CompactKey {
            measurement,
            tags: stags,
        };
        if let Some(&id) = self.index.get(&compact) {
            return SeriesHandle(id);
        }
        let mut key = SeriesKey::new(self.symbols.name(measurement));
        key.tags = compact
            .tags
            .iter()
            .map(|&(k, v)| {
                (
                    self.symbols.name(k).to_string(),
                    self.symbols.name(v).to_string(),
                )
            })
            .collect();
        self.insert_series(compact, key)
    }

    fn insert_series(&mut self, compact: CompactKey, key: SeriesKey) -> SeriesHandle {
        let id = self.keys.len() as u32;
        self.index.insert(compact, id);
        self.keys.push(key);
        self.series.push(Series::default());
        if let Some(res) = self.retention {
            self.windowed.push(Some(WindowedSeries::new(res)));
        }
        SeriesHandle(id)
    }

    /// Append a point. Times within one series must be non-decreasing
    /// (the simulator's clock is monotone, so this is free).
    #[inline]
    pub fn append(&mut self, h: SeriesHandle, t: SimTime, v: f64) {
        // retention off → `windowed` is empty → one bounds-check miss
        if let Some(Some(w)) = self.windowed.get_mut(h.0 as usize) {
            w.add(t, v);
            return;
        }
        let s = &mut self.series[h.0 as usize];
        debug_assert!(
            s.times.last().map_or(true, |&last| t >= last),
            "out-of-order append to {}",
            self.keys[h.0 as usize]
        );
        s.times.push(t);
        s.values.push(v);
    }

    /// Convenience: intern + append in one call (cold paths only).
    pub fn record(&mut self, key: SeriesKey, t: SimTime, v: f64) {
        let h = self.handle(key);
        self.append(h, t, v);
    }

    pub fn series(&self, h: SeriesHandle) -> &Series {
        &self.series[h.0 as usize]
    }

    pub fn key(&self, h: SeriesHandle) -> &SeriesKey {
        &self.keys[h.0 as usize]
    }

    pub fn get(&self, key: &SeriesKey) -> Option<&Series> {
        // read-only lookup: any string unknown to the symbol table means
        // the key was never interned
        let measurement = self.symbols.lookup(&key.measurement)?;
        let tags = key
            .tags
            .iter()
            .map(|(k, v)| Some((self.symbols.lookup(k)?, self.symbols.lookup(v)?)))
            .collect::<Option<Vec<_>>>()?;
        let compact = CompactKey { measurement, tags };
        self.index.get(&compact).map(|&id| &self.series[id as usize])
    }

    /// All handles whose measurement matches.
    pub fn find(&self, measurement: &str) -> Vec<SeriesHandle> {
        self.keys
            .iter()
            .enumerate()
            .filter(|(_, k)| k.measurement == measurement)
            .map(|(i, _)| SeriesHandle(i as u32))
            .collect()
    }

    /// All handles matching measurement + a tag filter.
    pub fn find_tagged(&self, measurement: &str, tag: &str, value: &str) -> Vec<SeriesHandle> {
        self.find(measurement)
            .into_iter()
            .filter(|h| self.key(*h).tag_value(tag) == Some(value))
            .collect()
    }

    pub fn num_series(&self) -> usize {
        self.keys.len()
    }

    /// Points *observed*: raw points stored plus points absorbed into
    /// retention windows. Invariant under the retention mode (it feeds
    /// the digest's `tsdb=` field).
    pub fn num_points(&self) -> usize {
        let raw: usize = self.series.iter().map(|s| s.len()).sum();
        let rolled: u64 = self
            .windowed
            .iter()
            .flatten()
            .map(|w| w.observed())
            .sum();
        raw + rolled as usize
    }

    /// Points *resident*: raw points held in RAM plus retention
    /// buckets. This is the quantity downsampling keeps flat (the
    /// sweep CSV's `peak_rss_points` column).
    pub fn resident_points(&self) -> usize {
        let raw: usize = self.series.iter().map(|s| s.len()).sum();
        let buckets: usize = self
            .windowed
            .iter()
            .flatten()
            .map(|w| w.buckets().len())
            .sum();
        raw + buckets
    }

    /// Approximate resident bytes of the stored points (raw columns
    /// plus retention buckets and their sketches).
    pub fn approx_bytes(&self) -> usize {
        let raw: usize = self.series.iter().map(|s| s.len()).sum::<usize>() * 16;
        let rolled: usize = self
            .windowed
            .iter()
            .flatten()
            .map(|w| w.approx_bytes())
            .sum();
        raw + rolled
    }

    pub fn handles(&self) -> impl Iterator<Item = SeriesHandle> + '_ {
        (0..self.keys.len() as u32).map(SeriesHandle)
    }

    /// Export every series to CSV: `series,time,value` rows. Windowed
    /// series export one row per retention bucket with the bucket mean
    /// as the value.
    pub fn export_csv<W: Write>(&self, w: &mut W) -> Result<()> {
        writeln!(w, "series,time,value")?;
        for h in self.handles() {
            let key = self.key(h).to_string();
            if let Some(ws) = self.downsampled(h) {
                for b in ws.buckets() {
                    writeln!(w, "{key},{},{}", b.start, b.mean())?;
                }
                continue;
            }
            let s = self.series(h);
            for (t, v) in s.times.iter().zip(&s.values) {
                writeln!(w, "{key},{t},{v}")?;
            }
        }
        Ok(())
    }

    /// Export one series as JSON. Raw series emit
    /// `{key, times, values}`; windowed series emit
    /// `{key, resolution, starts, counts, sums, mins, maxs}`.
    pub fn export_series_json(&self, h: SeriesHandle) -> Result<String> {
        use crate::util::Json;
        if let Some(ws) = self.downsampled(h) {
            let bs = ws.buckets();
            return Ok(Json::obj(vec![
                ("key", Json::Str(self.key(h).to_string())),
                ("resolution", Json::Num(ws.resolution())),
                ("starts", Json::arr_f64(bs.iter().map(|b| b.start))),
                (
                    "counts",
                    Json::arr_f64(bs.iter().map(|b| b.count as f64)),
                ),
                ("sums", Json::arr_f64(bs.iter().map(|b| b.sum))),
                ("mins", Json::arr_f64(bs.iter().map(|b| b.min))),
                ("maxs", Json::arr_f64(bs.iter().map(|b| b.max))),
            ])
            .to_string());
        }
        let s = self.series(h);
        Ok(Json::obj(vec![
            ("key", Json::Str(self.key(h).to_string())),
            ("times", Json::arr_f64(s.times.iter().cloned())),
            ("values", Json::arr_f64(s.values.iter().cloned())),
        ])
        .to_string())
    }

    /// True when any series in the store is downsampled.
    pub(crate) fn any_downsampled(&self) -> bool {
        self.windowed.iter().any(|w| w.is_some())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_is_idempotent() {
        let mut db = TsStore::new();
        let k = SeriesKey::new("util").tag("resource", "train");
        let h1 = db.handle(k.clone());
        let h2 = db.handle(k);
        assert_eq!(h1, h2);
        assert_eq!(db.num_series(), 1);
    }

    #[test]
    fn append_and_read_back() {
        let mut db = TsStore::new();
        let h = db.handle(SeriesKey::new("m"));
        db.append(h, 1.0, 10.0);
        db.append(h, 2.0, 20.0);
        let s = db.series(h);
        assert_eq!(s.times, vec![1.0, 2.0]);
        assert_eq!(s.values, vec![10.0, 20.0]);
        assert_eq!(db.num_points(), 2);
    }

    #[test]
    fn tags_sorted_and_queryable() {
        let k = SeriesKey::new("x").tag("b", "2").tag("a", "1");
        assert_eq!(k.tags[0].0, "a");
        assert_eq!(k.tag_value("b"), Some("2"));
        assert_eq!(k.tag_value("zz"), None);
        assert_eq!(k.to_string(), "x,a=1,b=2");
    }

    #[test]
    fn many_tags_insert_sorted_regardless_of_order() {
        // 5 tags added in scrambled order must come out sorted, and the
        // key must be identical to one built in sorted order
        let scrambled = SeriesKey::new("m")
            .tag("d", "4")
            .tag("a", "1")
            .tag("e", "5")
            .tag("b", "2")
            .tag("c", "3");
        let sorted = SeriesKey::new("m")
            .tag("a", "1")
            .tag("b", "2")
            .tag("c", "3")
            .tag("d", "4")
            .tag("e", "5");
        assert_eq!(scrambled, sorted);
        assert_eq!(scrambled.to_string(), "m,a=1,b=2,c=3,d=4,e=5");
        let keys: Vec<&str> = scrambled.tags.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["a", "b", "c", "d", "e"]);
        // duplicate tag keys order by value
        let dup = SeriesKey::new("m").tag("k", "9").tag("k", "1").tag("k", "5");
        let vals: Vec<&str> = dup.tags.iter().map(|(_, v)| v.as_str()).collect();
        assert_eq!(vals, vec!["1", "5", "9"]);
    }

    #[test]
    fn interned_symbols_reach_same_series() {
        let mut db = TsStore::new();
        let h_str = db.handle(SeriesKey::new("exec").tag("task", "train").tag("fw", "tf"));
        let m = db.sym("exec");
        let task = db.sym("task");
        let train = db.sym("train");
        let fw = db.sym("fw");
        let tf = db.sym("tf");
        // any tag order resolves to the same handle
        let h_sym = db.handle_interned(m, &[(fw, tf), (task, train)]);
        assert_eq!(h_str, h_sym);
        assert_eq!(db.num_series(), 1);
        // a fresh symbol-built series materializes a proper SeriesKey
        let eval = db.sym("eval");
        let h_new = db.handle_interned(m, &[(task, eval)]);
        assert_eq!(db.key(h_new).to_string(), "exec,task=eval");
        assert_eq!(db.handle(SeriesKey::new("exec").tag("task", "eval")), h_new);
    }

    #[test]
    fn find_by_measurement_and_tag() {
        let mut db = TsStore::new();
        db.record(SeriesKey::new("dur").tag("task", "train"), 0.0, 1.0);
        db.record(SeriesKey::new("dur").tag("task", "eval"), 0.0, 2.0);
        db.record(SeriesKey::new("util").tag("task", "train"), 0.0, 3.0);
        assert_eq!(db.find("dur").len(), 2);
        assert_eq!(db.find_tagged("dur", "task", "train").len(), 1);
        assert_eq!(db.find_tagged("dur", "task", "nope").len(), 0);
    }

    #[test]
    fn get_unknown_key_is_none() {
        let mut db = TsStore::new();
        db.record(SeriesKey::new("m").tag("t", "a"), 0.0, 1.0);
        assert!(db.get(&SeriesKey::new("m").tag("t", "a")).is_some());
        assert!(db.get(&SeriesKey::new("m").tag("t", "b")).is_none());
        assert!(db.get(&SeriesKey::new("nope")).is_none());
    }

    #[test]
    fn csv_export() {
        let mut db = TsStore::new();
        db.record(SeriesKey::new("m").tag("t", "a"), 1.5, 2.5);
        let mut buf = Vec::new();
        db.export_csv(&mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("series,time,value"));
        assert!(text.contains("m,t=a,1.5,2.5"));
    }

    #[test]
    #[should_panic(expected = "out-of-order")]
    #[cfg(debug_assertions)]
    fn rejects_out_of_order() {
        let mut db = TsStore::new();
        let h = db.handle(SeriesKey::new("m"));
        db.append(h, 5.0, 0.0);
        db.append(h, 1.0, 0.0);
    }

    #[test]
    fn retention_rolls_points_into_buckets() {
        let mut db = TsStore::new();
        db.set_retention(10.0);
        let h = db.handle(SeriesKey::new("m"));
        for i in 0..25 {
            db.append(h, i as f64, i as f64);
        }
        // raw column stays empty; everything lives in buckets
        assert!(db.series(h).is_empty());
        let w = db.downsampled(h).expect("windowed");
        assert_eq!(w.observed(), 25);
        assert_eq!(w.buckets().len(), 3);
        let b0 = &w.buckets()[0];
        assert_eq!(b0.start, 0.0);
        assert_eq!(b0.count, 10);
        assert_eq!(b0.sum, 45.0);
        assert_eq!(b0.min, 0.0);
        assert_eq!(b0.max, 9.0);
        assert_eq!(b0.last, 9.0);
        // observed points count as points; residency counts buckets
        assert_eq!(db.num_points(), 25);
        assert_eq!(db.resident_points(), 3);
    }

    #[test]
    fn retention_memory_stays_flat() {
        let mut raw = TsStore::new();
        let mut down = TsStore::new();
        down.set_retention(100.0);
        let hr = raw.handle(SeriesKey::new("m"));
        let hd = down.handle(SeriesKey::new("m"));
        for i in 0..100_000 {
            let t = i as f64 * 0.01; // 1000 s span → 10 buckets
            raw.append(hr, t, (i % 97) as f64);
            down.append(hd, t, (i % 97) as f64);
        }
        assert_eq!(raw.num_points(), down.num_points());
        assert!(down.resident_points() <= 10);
        assert!(
            down.approx_bytes() * 10 < raw.approx_bytes(),
            "downsampled {} vs raw {}",
            down.approx_bytes(),
            raw.approx_bytes()
        );
    }

    #[test]
    fn retention_skips_series_with_existing_raw_points() {
        let mut db = TsStore::new();
        let h = db.handle(SeriesKey::new("old"));
        db.append(h, 0.0, 1.0);
        db.set_retention(10.0);
        // pre-existing raw series keeps its representation…
        assert!(db.downsampled(h).is_none());
        db.append(h, 1.0, 2.0);
        assert_eq!(db.series(h).len(), 2);
        // …while a fresh series created under the policy downsamples
        let h2 = db.handle(SeriesKey::new("new"));
        db.append(h2, 1.0, 2.0);
        assert!(db.downsampled(h2).is_some());
        assert!(db.series(h2).is_empty());
    }

    #[test]
    fn windowed_csv_and_json_export() {
        let mut db = TsStore::new();
        db.set_retention(10.0);
        let h = db.handle(SeriesKey::new("m").tag("t", "a"));
        db.append(h, 1.0, 2.0);
        db.append(h, 2.0, 4.0);
        let mut buf = Vec::new();
        db.export_csv(&mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("m,t=a,0,3")); // bucket start 0, mean 3
        let json = db.export_series_json(h).unwrap();
        assert!(json.contains("\"resolution\""));
        assert!(json.contains("\"counts\""));
    }
}
