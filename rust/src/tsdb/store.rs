//! Series storage: interned keys, append-only columnar points.
//!
//! Two levels of interning keep the hot path string-free:
//! * tag/measurement strings intern to [`Sym`] ids in a per-store symbol
//!   table, so series-key lookups hash a few `u32`s instead of `String`s;
//! * full keys intern to [`SeriesHandle`]s, so recording a point is two
//!   `Vec::push`es.

use std::collections::HashMap;
use std::io::Write;

use crate::des::SimTime;
use crate::error::Result;

/// A measurement name plus sorted tag pairs, e.g.
/// `("task_duration", [("task","train"),("framework","tensorflow")])`.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct SeriesKey {
    pub measurement: String,
    pub tags: Vec<(String, String)>,
}

impl SeriesKey {
    pub fn new(measurement: impl Into<String>) -> Self {
        SeriesKey {
            measurement: measurement.into(),
            tags: Vec::new(),
        }
    }

    pub fn tag(mut self, k: impl Into<String>, v: impl Into<String>) -> Self {
        let k = k.into();
        let v = v.into();
        // insert in sorted position: O(n) shift instead of an O(n log n)
        // re-sort per builder call
        let pos = self
            .tags
            .partition_point(|(ek, ev)| (ek.as_str(), ev.as_str()) < (k.as_str(), v.as_str()));
        self.tags.insert(pos, (k, v));
        self
    }

    pub fn tag_value(&self, key: &str) -> Option<&str> {
        self.tags
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }
}

impl std::fmt::Display for SeriesKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.measurement)?;
        for (k, v) in &self.tags {
            write!(f, ",{k}={v}")?;
        }
        Ok(())
    }
}

/// Interned handle for hot-path appends.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SeriesHandle(pub(crate) u32);

/// Interned string symbol (per-store scope).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Sym(u32);

/// String → u32 intern table.
#[derive(Default)]
struct SymbolTable {
    names: Vec<String>,
    index: HashMap<String, u32>,
}

impl SymbolTable {
    fn intern(&mut self, s: &str) -> Sym {
        if let Some(&id) = self.index.get(s) {
            return Sym(id);
        }
        let id = self.names.len() as u32;
        self.index.insert(s.to_string(), id);
        self.names.push(s.to_string());
        Sym(id)
    }

    fn lookup(&self, s: &str) -> Option<Sym> {
        self.index.get(s).map(|&id| Sym(id))
    }

    fn name(&self, s: Sym) -> &str {
        &self.names[s.0 as usize]
    }
}

/// Symbol-level series key: what the index actually hashes.
#[derive(Clone, PartialEq, Eq, Hash)]
struct CompactKey {
    measurement: Sym,
    /// Tag pairs sorted by the *string* order of the underlying symbols,
    /// matching [`SeriesKey::tags`] order exactly.
    tags: Vec<(Sym, Sym)>,
}

/// Columnar storage for one series.
#[derive(Clone, Debug, Default)]
pub struct Series {
    pub times: Vec<f64>,
    pub values: Vec<f64>,
}

impl Series {
    pub fn len(&self) -> usize {
        self.times.len()
    }
    pub fn is_empty(&self) -> bool {
        self.times.is_empty()
    }
}

/// The store: all series of one experiment run.
#[derive(Default)]
pub struct TsStore {
    keys: Vec<SeriesKey>,
    series: Vec<Series>,
    symbols: SymbolTable,
    index: HashMap<CompactKey, u32>,
}

impl TsStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Intern a string, returning a stable symbol for
    /// [`TsStore::handle_interned`] lookups that never re-hash strings.
    pub fn sym(&mut self, s: &str) -> Sym {
        self.symbols.intern(s)
    }

    fn compact(&mut self, key: &SeriesKey) -> CompactKey {
        CompactKey {
            measurement: self.symbols.intern(&key.measurement),
            tags: key
                .tags
                .iter()
                .map(|(k, v)| (self.symbols.intern(k), self.symbols.intern(v)))
                .collect(),
        }
    }

    /// Intern a key, returning a stable handle. Idempotent.
    pub fn handle(&mut self, key: SeriesKey) -> SeriesHandle {
        let compact = self.compact(&key);
        if let Some(&id) = self.index.get(&compact) {
            return SeriesHandle(id);
        }
        self.insert_series(compact, key)
    }

    /// Handle lookup from pre-interned symbols: hashes only `u32`s, no
    /// string traffic at all. `tags` may be in any order.
    pub fn handle_interned(&mut self, measurement: Sym, tags: &[(Sym, Sym)]) -> SeriesHandle {
        let mut stags = tags.to_vec();
        // order by the underlying strings so equivalent keys collide
        stags.sort_by(|a, b| {
            (self.symbols.name(a.0), self.symbols.name(a.1))
                .cmp(&(self.symbols.name(b.0), self.symbols.name(b.1)))
        });
        let compact = CompactKey {
            measurement,
            tags: stags,
        };
        if let Some(&id) = self.index.get(&compact) {
            return SeriesHandle(id);
        }
        let mut key = SeriesKey::new(self.symbols.name(measurement));
        key.tags = compact
            .tags
            .iter()
            .map(|&(k, v)| {
                (
                    self.symbols.name(k).to_string(),
                    self.symbols.name(v).to_string(),
                )
            })
            .collect();
        self.insert_series(compact, key)
    }

    fn insert_series(&mut self, compact: CompactKey, key: SeriesKey) -> SeriesHandle {
        let id = self.keys.len() as u32;
        self.index.insert(compact, id);
        self.keys.push(key);
        self.series.push(Series::default());
        SeriesHandle(id)
    }

    /// Append a point. Times within one series must be non-decreasing
    /// (the simulator's clock is monotone, so this is free).
    #[inline]
    pub fn append(&mut self, h: SeriesHandle, t: SimTime, v: f64) {
        let s = &mut self.series[h.0 as usize];
        debug_assert!(
            s.times.last().map_or(true, |&last| t >= last),
            "out-of-order append to {}",
            self.keys[h.0 as usize]
        );
        s.times.push(t);
        s.values.push(v);
    }

    /// Convenience: intern + append in one call (cold paths only).
    pub fn record(&mut self, key: SeriesKey, t: SimTime, v: f64) {
        let h = self.handle(key);
        self.append(h, t, v);
    }

    pub fn series(&self, h: SeriesHandle) -> &Series {
        &self.series[h.0 as usize]
    }

    pub fn key(&self, h: SeriesHandle) -> &SeriesKey {
        &self.keys[h.0 as usize]
    }

    pub fn get(&self, key: &SeriesKey) -> Option<&Series> {
        // read-only lookup: any string unknown to the symbol table means
        // the key was never interned
        let measurement = self.symbols.lookup(&key.measurement)?;
        let tags = key
            .tags
            .iter()
            .map(|(k, v)| Some((self.symbols.lookup(k)?, self.symbols.lookup(v)?)))
            .collect::<Option<Vec<_>>>()?;
        let compact = CompactKey { measurement, tags };
        self.index.get(&compact).map(|&id| &self.series[id as usize])
    }

    /// All handles whose measurement matches.
    pub fn find(&self, measurement: &str) -> Vec<SeriesHandle> {
        self.keys
            .iter()
            .enumerate()
            .filter(|(_, k)| k.measurement == measurement)
            .map(|(i, _)| SeriesHandle(i as u32))
            .collect()
    }

    /// All handles matching measurement + a tag filter.
    pub fn find_tagged(&self, measurement: &str, tag: &str, value: &str) -> Vec<SeriesHandle> {
        self.find(measurement)
            .into_iter()
            .filter(|h| self.key(*h).tag_value(tag) == Some(value))
            .collect()
    }

    pub fn num_series(&self) -> usize {
        self.keys.len()
    }

    pub fn num_points(&self) -> usize {
        self.series.iter().map(|s| s.len()).sum()
    }

    /// Approximate resident bytes of the stored points.
    pub fn approx_bytes(&self) -> usize {
        self.num_points() * 16
    }

    pub fn handles(&self) -> impl Iterator<Item = SeriesHandle> + '_ {
        (0..self.keys.len() as u32).map(SeriesHandle)
    }

    /// Export every series to CSV: `series,time,value` rows.
    pub fn export_csv<W: Write>(&self, w: &mut W) -> Result<()> {
        writeln!(w, "series,time,value")?;
        for h in self.handles() {
            let key = self.key(h).to_string();
            let s = self.series(h);
            for (t, v) in s.times.iter().zip(&s.values) {
                writeln!(w, "{key},{t},{v}")?;
            }
        }
        Ok(())
    }

    /// Export one series as JSON {key, times, values}.
    pub fn export_series_json(&self, h: SeriesHandle) -> Result<String> {
        use crate::util::Json;
        let s = self.series(h);
        Ok(Json::obj(vec![
            ("key", Json::Str(self.key(h).to_string())),
            ("times", Json::arr_f64(s.times.iter().cloned())),
            ("values", Json::arr_f64(s.values.iter().cloned())),
        ])
        .to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_is_idempotent() {
        let mut db = TsStore::new();
        let k = SeriesKey::new("util").tag("resource", "train");
        let h1 = db.handle(k.clone());
        let h2 = db.handle(k);
        assert_eq!(h1, h2);
        assert_eq!(db.num_series(), 1);
    }

    #[test]
    fn append_and_read_back() {
        let mut db = TsStore::new();
        let h = db.handle(SeriesKey::new("m"));
        db.append(h, 1.0, 10.0);
        db.append(h, 2.0, 20.0);
        let s = db.series(h);
        assert_eq!(s.times, vec![1.0, 2.0]);
        assert_eq!(s.values, vec![10.0, 20.0]);
        assert_eq!(db.num_points(), 2);
    }

    #[test]
    fn tags_sorted_and_queryable() {
        let k = SeriesKey::new("x").tag("b", "2").tag("a", "1");
        assert_eq!(k.tags[0].0, "a");
        assert_eq!(k.tag_value("b"), Some("2"));
        assert_eq!(k.tag_value("zz"), None);
        assert_eq!(k.to_string(), "x,a=1,b=2");
    }

    #[test]
    fn many_tags_insert_sorted_regardless_of_order() {
        // 5 tags added in scrambled order must come out sorted, and the
        // key must be identical to one built in sorted order
        let scrambled = SeriesKey::new("m")
            .tag("d", "4")
            .tag("a", "1")
            .tag("e", "5")
            .tag("b", "2")
            .tag("c", "3");
        let sorted = SeriesKey::new("m")
            .tag("a", "1")
            .tag("b", "2")
            .tag("c", "3")
            .tag("d", "4")
            .tag("e", "5");
        assert_eq!(scrambled, sorted);
        assert_eq!(scrambled.to_string(), "m,a=1,b=2,c=3,d=4,e=5");
        let keys: Vec<&str> = scrambled.tags.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["a", "b", "c", "d", "e"]);
        // duplicate tag keys order by value
        let dup = SeriesKey::new("m").tag("k", "9").tag("k", "1").tag("k", "5");
        let vals: Vec<&str> = dup.tags.iter().map(|(_, v)| v.as_str()).collect();
        assert_eq!(vals, vec!["1", "5", "9"]);
    }

    #[test]
    fn interned_symbols_reach_same_series() {
        let mut db = TsStore::new();
        let h_str = db.handle(SeriesKey::new("exec").tag("task", "train").tag("fw", "tf"));
        let m = db.sym("exec");
        let task = db.sym("task");
        let train = db.sym("train");
        let fw = db.sym("fw");
        let tf = db.sym("tf");
        // any tag order resolves to the same handle
        let h_sym = db.handle_interned(m, &[(fw, tf), (task, train)]);
        assert_eq!(h_str, h_sym);
        assert_eq!(db.num_series(), 1);
        // a fresh symbol-built series materializes a proper SeriesKey
        let eval = db.sym("eval");
        let h_new = db.handle_interned(m, &[(task, eval)]);
        assert_eq!(db.key(h_new).to_string(), "exec,task=eval");
        assert_eq!(db.handle(SeriesKey::new("exec").tag("task", "eval")), h_new);
    }

    #[test]
    fn find_by_measurement_and_tag() {
        let mut db = TsStore::new();
        db.record(SeriesKey::new("dur").tag("task", "train"), 0.0, 1.0);
        db.record(SeriesKey::new("dur").tag("task", "eval"), 0.0, 2.0);
        db.record(SeriesKey::new("util").tag("task", "train"), 0.0, 3.0);
        assert_eq!(db.find("dur").len(), 2);
        assert_eq!(db.find_tagged("dur", "task", "train").len(), 1);
        assert_eq!(db.find_tagged("dur", "task", "nope").len(), 0);
    }

    #[test]
    fn get_unknown_key_is_none() {
        let mut db = TsStore::new();
        db.record(SeriesKey::new("m").tag("t", "a"), 0.0, 1.0);
        assert!(db.get(&SeriesKey::new("m").tag("t", "a")).is_some());
        assert!(db.get(&SeriesKey::new("m").tag("t", "b")).is_none());
        assert!(db.get(&SeriesKey::new("nope")).is_none());
    }

    #[test]
    fn csv_export() {
        let mut db = TsStore::new();
        db.record(SeriesKey::new("m").tag("t", "a"), 1.5, 2.5);
        let mut buf = Vec::new();
        db.export_csv(&mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("series,time,value"));
        assert!(text.contains("m,t=a,1.5,2.5"));
    }

    #[test]
    #[should_panic(expected = "out-of-order")]
    #[cfg(debug_assertions)]
    fn rejects_out_of_order() {
        let mut db = TsStore::new();
        let h = db.handle(SeriesKey::new("m"));
        db.append(h, 5.0, 0.0);
        db.append(h, 1.0, 0.0);
    }
}
