//! Embedded time-series store for simulation traces.
//!
//! The paper persisted synthetic traces to InfluxDB and concluded it "was
//! overall a poor choice" (section VI-C: index blow-up on group-by, OOM
//! past a few hundred thousand pipelines). This module is the fix they
//! call for: an in-process, append-only, tag-indexed store with windowed
//! aggregation and group-by queries, bounded memory, and CSV/JSON export.
//!
//! Hot-path design: series are interned to integer handles once
//! ([`TsStore::handle`]) so recording a point in the simulator's event
//! loop is two `Vec::push`es — no hashing, no allocation.
//!
//! Memory-flat mode: [`TsStore::set_retention`] rolls appends into
//! fixed-resolution windows of `(count, sum, min, max, last, sketch)`
//! instead of raw columns, so a year-scale run holds O(windows) rather
//! than O(points); the query layer answers from either representation.

mod query;
mod store;

pub use query::{Agg, GroupedSeries, WindowAgg};
pub use store::{SeriesHandle, SeriesKey, Sym, TsStore, WindowBucket, WindowedSeries};
