//! Binary (de)serialization primitives shared by the trace codec and the
//! cached fitted-parameter format (offline environment: no bincode).
//!
//! The vocabulary is deliberately small and fully self-inverse:
//! * fixed-width little-endian integers (`u8`/`u16`),
//! * LEB128 varints for counts and ids,
//! * `f64` as raw IEEE-754 bit patterns (bit-exact round-trips — digests
//!   and replay depend on it),
//! * length-prefixed UTF-8 strings,
//! * an [`InternTable`] building a deduplicated string table on write.

use std::collections::HashMap;

use crate::error::{Error, Result};

/// Append-only byte buffer with typed writers.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// The bytes written so far, without consuming the writer — the
    /// streaming path copies each encoded record out and then
    /// [`ByteWriter::clear`]s the scratch.
    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }

    /// Reset to empty, keeping the allocation (bounded-buffer reuse on
    /// hot paths).
    pub fn clear(&mut self) {
        self.buf.clear();
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Fixed-width little-endian u64 (for offsets that must be written
    /// before their value is known to fit a varint's variable width).
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// LEB128 unsigned varint (1–10 bytes).
    pub fn varint(&mut self, mut v: u64) {
        while v >= 0x80 {
            self.buf.push((v as u8) | 0x80);
            v >>= 7;
        }
        self.buf.push(v as u8);
    }

    /// Raw IEEE-754 bits, little-endian — exact for every finite and
    /// non-finite value.
    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }

    pub fn bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.varint(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Standard container header shared by every PipeSim binary format:
    /// 4-byte magic + u16 version + reserved u16 (0). Paired with
    /// [`ByteReader::check_header`].
    pub fn header(&mut self, magic: &[u8; 4], version: u16) {
        self.header_with_reserved(magic, version, 0);
    }

    /// Container header with an explicit reserved word — for formats
    /// that retro-fit meaning into the reserved field (e.g. the trace
    /// codec's streamed-layout flag). Readers that ignore the reserved
    /// word ([`ByteReader::check_header`] and friends) still accept it.
    pub fn header_with_reserved(&mut self, magic: &[u8; 4], version: u16, reserved: u16) {
        self.bytes(magic);
        self.u16(version);
        self.u16(reserved);
    }
}

/// Cursor over a byte slice with typed readers; every method fails
/// cleanly on truncated input.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(Error::Other(format!(
                "binio: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            )));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn varint(&mut self) -> Result<u64> {
        let mut v: u64 = 0;
        let mut shift = 0u32;
        loop {
            let b = self.u8()?;
            // at shift 63 only one payload bit remains: anything above 1
            // (including a continuation bit) would shift data out of the
            // u64 — reject instead of silently truncating
            if shift >= 63 && b > 1 {
                return Err(Error::Other("binio: varint overflows u64".into()));
            }
            v |= u64::from(b & 0x7f) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    /// Varint that must fit a `usize` (collection length).
    pub fn len_prefix(&mut self) -> Result<usize> {
        let v = self.varint()?;
        usize::try_from(v).map_err(|_| Error::Other(format!("binio: length {v} too large")))
    }

    /// Length prefix validated against the remaining input: every
    /// element needs at least `min_elem_bytes`, so a corrupt or
    /// malicious length can never trigger an allocation larger than the
    /// input itself (`Vec::with_capacity(n)` is then always safe).
    pub fn len_prefix_for(&mut self, min_elem_bytes: usize) -> Result<usize> {
        let n = self.len_prefix()?;
        if n.saturating_mul(min_elem_bytes.max(1)) > self.remaining() {
            return Err(Error::Other(format!(
                "binio: length {n} (x{min_elem_bytes} B) exceeds remaining {} bytes",
                self.remaining()
            )));
        }
        Ok(n)
    }

    /// Fixed-width little-endian u64, paired with [`ByteWriter::u64`].
    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    pub fn f64(&mut self) -> Result<f64> {
        let b = self.take(8)?;
        Ok(f64::from_bits(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ])))
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.len_prefix()?;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec()).map_err(|_| Error::Other("binio: invalid utf8".into()))
    }

    /// Validate a container header written by [`ByteWriter::header`]:
    /// exact magic and exact version (the shared versioning rule — no
    /// best-effort decoding of other versions). `what` labels errors.
    pub fn check_header(&mut self, magic: &[u8; 4], version: u16, what: &str) -> Result<()> {
        self.check_header_range(magic, version, version, what)?;
        Ok(())
    }

    /// Like [`ByteReader::check_header`], but for formats whose readers
    /// accept a range of versions (append-only evolutions where the
    /// writer stamps the lowest version that can represent the
    /// payload). Returns the file's version so the caller can gate
    /// version-specific records.
    pub fn check_header_range(
        &mut self,
        magic: &[u8; 4],
        min_version: u16,
        max_version: u16,
        what: &str,
    ) -> Result<u16> {
        let (v, _reserved) = self.check_header_range_with_reserved(magic, min_version, max_version, what)?;
        Ok(v)
    }

    /// Like [`ByteReader::check_header_range`], but also returns the
    /// header's reserved word for formats that assign it meaning (the
    /// trace codec uses it to distinguish streamed from buffered
    /// layouts at version 4+).
    pub fn check_header_range_with_reserved(
        &mut self,
        magic: &[u8; 4],
        min_version: u16,
        max_version: u16,
        what: &str,
    ) -> Result<(u16, u16)> {
        let got = [self.u8()?, self.u8()?, self.u8()?, self.u8()?];
        if &got != magic {
            return Err(Error::Other(format!(
                "{what}: bad magic (not a {what} file)"
            )));
        }
        let v = self.u16()?;
        if v < min_version || v > max_version {
            let readable = if min_version == max_version {
                format!("{min_version}")
            } else {
                format!("{min_version}..={max_version}")
            };
            return Err(Error::Other(format!(
                "{what}: format version {v}, this build reads {readable}"
            )));
        }
        let reserved = self.u16()?;
        Ok((v, reserved))
    }

    /// Error if any input remains — every container rejects trailing
    /// bytes so partial/concatenated files fail loudly.
    pub fn expect_eof(&mut self, what: &str) -> Result<()> {
        if !self.is_empty() {
            return Err(Error::Other(format!(
                "{what}: {} trailing bytes",
                self.remaining()
            )));
        }
        Ok(())
    }
}

/// Incremental typed reads shared by the slice-backed [`ByteReader`]
/// and file-backed streams (`trace::scan`). The trace record decoder is
/// generic over this, so a year-scale streamed `.pst` can be summarized
/// without ever materializing its body in memory. Only the primitives a
/// *record* needs are here — container plumbing (headers, string
/// tables, length-validated prefixes) stays on the concrete readers.
pub trait BinRead {
    fn u8(&mut self) -> Result<u8>;
    fn f64(&mut self) -> Result<f64>;

    /// LEB128 varint with the same canonical-form rule as
    /// [`ByteReader::varint`]: payload bits beyond bit 63 are an error,
    /// never a silent truncation.
    fn varint(&mut self) -> Result<u64> {
        let mut v: u64 = 0;
        let mut shift = 0u32;
        loop {
            let b = self.u8()?;
            if shift >= 63 && b > 1 {
                return Err(Error::Other("binio: varint overflows u64".into()));
            }
            v |= u64::from(b & 0x7f) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }
}

impl BinRead for ByteReader<'_> {
    fn u8(&mut self) -> Result<u8> {
        ByteReader::u8(self)
    }

    fn f64(&mut self) -> Result<f64> {
        ByteReader::f64(self)
    }

    // the inherent implementation already enforces the canonical-form
    // rule; delegating avoids running two copies of the same loop
    fn varint(&mut self) -> Result<u64> {
        ByteReader::varint(self)
    }
}

/// Deduplicating string table built while encoding; ids are `u32`s in
/// first-intern order, so the same logical content always produces the
/// same bytes.
#[derive(Default)]
pub struct InternTable {
    names: Vec<String>,
    index: HashMap<String, u32>,
}

impl InternTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Intern `s`, returning its stable id.
    pub fn intern(&mut self, s: &str) -> u32 {
        if let Some(&id) = self.index.get(s) {
            return id;
        }
        let id = self.names.len() as u32;
        self.index.insert(s.to_string(), id);
        self.names.push(s.to_string());
        id
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// Serialize as `varint count` + length-prefixed strings in id order.
    pub fn write(&self, w: &mut ByteWriter) {
        w.varint(self.names.len() as u64);
        for s in &self.names {
            w.str(s);
        }
    }

    /// Parse a table previously emitted by [`InternTable::write`] into an
    /// id-indexed vector.
    pub fn read(r: &mut ByteReader) -> Result<Vec<String>> {
        // every string costs >= 1 byte (its length varint)
        let n = r.len_prefix_for(1)?;
        let mut names = Vec::with_capacity(n);
        for _ in 0..n {
            names.push(r.str()?);
        }
        Ok(names)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn varint_roundtrip_edges() {
        let cases = [
            0u64,
            1,
            0x7f,
            0x80,
            0x3fff,
            0x4000,
            u32::MAX as u64,
            u64::MAX - 1,
            u64::MAX,
        ];
        let mut w = ByteWriter::new();
        for &v in &cases {
            w.varint(v);
        }
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        for &v in &cases {
            assert_eq!(r.varint().unwrap(), v);
        }
        assert!(r.is_empty());
    }

    #[test]
    fn varint_rejects_overflow_and_overlong_forms() {
        // 10th byte with payload bits beyond bit 63 would silently drop
        // data — must error, not truncate
        let mut overflowing = vec![0x80u8; 9];
        overflowing.push(0x7f);
        assert!(ByteReader::new(&overflowing).varint().is_err());
        // but the canonical u64::MAX encoding (10th byte == 1) decodes
        let mut w = ByteWriter::new();
        w.varint(u64::MAX);
        let bytes = w.into_bytes();
        assert_eq!(bytes.len(), 10);
        assert_eq!(ByteReader::new(&bytes).varint().unwrap(), u64::MAX);
    }

    #[test]
    fn f64_is_bit_exact() {
        let cases = [
            0.0,
            -0.0,
            1.0 / 3.0,
            f64::MIN_POSITIVE,
            f64::MAX,
            f64::NEG_INFINITY,
            f64::NAN,
        ];
        let mut w = ByteWriter::new();
        for &v in &cases {
            w.f64(v);
        }
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        for &v in &cases {
            assert_eq!(r.f64().unwrap().to_bits(), v.to_bits());
        }
    }

    #[test]
    fn strings_and_fixed_ints() {
        let mut w = ByteWriter::new();
        w.u8(7);
        w.u16(0xbeef);
        w.u64(0xdead_beef_cafe_f00d);
        w.str("héllo\nworld");
        w.str("");
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 0xbeef);
        assert_eq!(r.u64().unwrap(), 0xdead_beef_cafe_f00d);
        assert_eq!(r.str().unwrap(), "héllo\nworld");
        assert_eq!(r.str().unwrap(), "");
        assert!(r.is_empty());
        // u64 is fixed-width (8 bytes) regardless of value
        let mut w = ByteWriter::new();
        w.u64(1);
        assert_eq!(w.len(), 8);
        assert!(ByteReader::new(&w.into_bytes()[..7]).u64().is_err());
    }

    #[test]
    fn clear_keeps_capacity_and_resets_content() {
        let mut w = ByteWriter::new();
        w.str("some scratch content");
        assert!(!w.is_empty());
        assert_eq!(w.as_slice().len(), w.len());
        w.clear();
        assert!(w.is_empty());
        assert_eq!(w.as_slice(), &[] as &[u8]);
        w.u8(1);
        assert_eq!(w.as_slice(), &[1u8]);
    }

    #[test]
    fn length_prefix_bounded_by_remaining_input() {
        // a corrupt length can never drive an oversized pre-allocation
        let mut w = ByteWriter::new();
        w.varint(1 << 30); // claims ~1G elements...
        w.f64(0.0); // ...but only 8 bytes follow
        let bytes = w.into_bytes();
        let err = ByteReader::new(&bytes).len_prefix_for(8).unwrap_err();
        assert!(err.to_string().contains("exceeds remaining"), "{err}");
        // a consistent prefix passes
        let mut w = ByteWriter::new();
        w.varint(1);
        w.f64(3.5);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.len_prefix_for(8).unwrap(), 1);
        assert_eq!(r.f64().unwrap(), 3.5);
    }

    #[test]
    fn truncated_input_is_an_error() {
        let mut w = ByteWriter::new();
        w.str("abcdef");
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes[..3]);
        assert!(r.str().is_err());
        let mut r = ByteReader::new(&[]);
        assert!(r.u8().is_err());
        assert!(ByteReader::new(&[0x80; 12]).varint().is_err());
    }

    #[test]
    fn header_range_accepts_span_and_reports_version() {
        let mk = |version: u16| {
            let mut w = ByteWriter::new();
            w.header(b"TEST", version);
            w.into_bytes()
        };
        // exact helper: only its own version
        assert!(ByteReader::new(&mk(2)).check_header(b"TEST", 2, "t").is_ok());
        assert!(ByteReader::new(&mk(1)).check_header(b"TEST", 2, "t").is_err());
        // range helper: returns the stamped version inside the span
        for v in 1..=3u16 {
            let bytes = mk(v);
            let mut r = ByteReader::new(&bytes);
            assert_eq!(r.check_header_range(b"TEST", 1, 3, "t").unwrap(), v);
        }
        let bytes = mk(4);
        let err = ByteReader::new(&bytes)
            .check_header_range(b"TEST", 1, 3, "t")
            .unwrap_err();
        assert!(err.to_string().contains("1..=3"), "{err}");
        let bytes = mk(0);
        let err = ByteReader::new(&bytes)
            .check_header_range(b"TEST", 1, 3, "t")
            .unwrap_err();
        assert!(err.to_string().contains("format version 0"), "{err}");
        // wrong magic still rejected
        assert!(ByteReader::new(&mk(1)).check_header_range(b"NOPE", 1, 3, "t").is_err());
        // reserved word round-trips through the _with_reserved variant
        // (and defaults to 0 from the plain `header` writer)
        let mut w = ByteWriter::new();
        w.header_with_reserved(b"TEST", 2, 1);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(
            r.check_header_range_with_reserved(b"TEST", 1, 3, "t").unwrap(),
            (2, 1)
        );
        let bytes = mk(2);
        let mut r = ByteReader::new(&bytes);
        assert_eq!(
            r.check_header_range_with_reserved(b"TEST", 1, 3, "t").unwrap(),
            (2, 0)
        );
    }

    #[test]
    fn intern_table_dedups_and_roundtrips() {
        let mut tab = InternTable::new();
        assert_eq!(tab.intern("a"), 0);
        assert_eq!(tab.intern("b"), 1);
        assert_eq!(tab.intern("a"), 0);
        assert_eq!(tab.len(), 2);
        let mut w = ByteWriter::new();
        tab.write(&mut w);
        let bytes = w.into_bytes();
        let names = InternTable::read(&mut ByteReader::new(&bytes)).unwrap();
        assert_eq!(names, vec!["a".to_string(), "b".to_string()]);
    }
}
