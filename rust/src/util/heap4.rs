//! Shared 4-ary min-heap primitives: sift up/down + Floyd heapify.
//!
//! Both DES heaps — the calendar's `(time, seq)` event queue and the
//! resource's `QueueKey` waiter index — are 4-ary min-heaps with lazily
//! reaped tombstones bounded by compaction. Their sift/heapify core is
//! digest-critical (pop order IS event and grant order), so it lives
//! here exactly once, parameterized by a strict less-than; the owning
//! structures keep their own entry types and tombstone policies.
//!
//! A 4-ary layout beats a binary heap on these workloads: the tree is
//! half as deep, so a pop touches ~log4(n) cache lines instead of
//! log2(n), and the four children of a node sit adjacent in memory.

/// Children per node.
pub const ARITY: usize = 4;

/// Restore the heap invariant upward from `i` (a freshly pushed leaf).
/// `less(a, b)` must be a strict order: "a sorts before b".
#[inline]
pub fn sift_up<T>(heap: &mut [T], mut i: usize, less: impl Fn(&T, &T) -> bool) {
    while i > 0 {
        let parent = (i - 1) / ARITY;
        if less(&heap[i], &heap[parent]) {
            heap.swap(i, parent);
            i = parent;
        } else {
            break;
        }
    }
}

/// Restore the heap invariant downward from `i` (a replaced root).
#[inline]
pub fn sift_down<T>(heap: &mut [T], mut i: usize, less: impl Fn(&T, &T) -> bool) {
    let len = heap.len();
    loop {
        let first = ARITY * i + 1;
        if first >= len {
            break;
        }
        // earliest of up to four children
        let mut best = first;
        let end = (first + ARITY).min(len);
        for c in (first + 1)..end {
            if less(&heap[c], &heap[best]) {
                best = c;
            }
        }
        if less(&heap[best], &heap[i]) {
            heap.swap(i, best);
            i = best;
        } else {
            break;
        }
    }
}

/// Remove and return the root (swap to last, pop, re-sift) — the
/// drain-side companion of [`sift_up`]. Panics on an empty heap; both
/// DES heaps check emptiness first (the calendar to return `None`, the
/// resource in `peek_min`).
pub fn pop_root<T>(heap: &mut Vec<T>, less: impl Fn(&T, &T) -> bool) -> T {
    let last = heap.len() - 1;
    heap.swap(0, last);
    let root = heap.pop().expect("pop_root on empty heap");
    if !heap.is_empty() {
        sift_down(heap, 0, less);
    }
    root
}

/// Establish the heap invariant over arbitrary contents in O(n)
/// (Floyd: sift every internal node down, bottom-up). The compaction
/// path of both DES heaps rebuilds through this after dropping
/// tombstones.
pub fn heapify<T>(heap: &mut [T], less: impl Fn(&T, &T) -> bool) {
    let len = heap.len();
    if len > 1 {
        for i in (0..=(len - 2) / ARITY).rev() {
            sift_down(heap, i, &less);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn less(a: &u64, b: &u64) -> bool {
        a < b
    }

    /// Drain the min repeatedly via [`pop_root`].
    fn drain(mut heap: Vec<u64>) -> Vec<u64> {
        let mut out = Vec::with_capacity(heap.len());
        while !heap.is_empty() {
            out.push(pop_root(&mut heap, less));
        }
        out
    }

    #[test]
    fn push_pop_yields_sorted_order() {
        // deterministic pseudo-random input with duplicates
        let mut x = 0x1234_5678u64;
        let mut heap = Vec::new();
        let mut expect = Vec::new();
        for _ in 0..5000 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            let v = x % 997;
            heap.push(v);
            let leaf = heap.len() - 1;
            sift_up(&mut heap, leaf, less);
            expect.push(v);
        }
        expect.sort_unstable();
        assert_eq!(drain(heap), expect);
    }

    #[test]
    fn heapify_matches_incremental_construction() {
        let mut x = 0xdead_beefu64;
        let mut v = Vec::new();
        for _ in 0..3000 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            v.push(x % 101);
        }
        let mut expect = v.clone();
        expect.sort_unstable();
        heapify(&mut v, less);
        assert_eq!(drain(v), expect);
    }

    #[test]
    fn edge_sizes() {
        for n in 0..6u64 {
            let mut v: Vec<u64> = (0..n).rev().collect();
            heapify(&mut v, less);
            let drained = drain(v);
            let expect: Vec<u64> = (0..n).collect();
            assert_eq!(drained, expect, "n = {n}");
        }
    }
}
